"""Fused loss+gradient kernel: one VMEM pass instead of three.

Given precomputed margins z = X·w (from :mod:`margins`), this kernel
computes BOTH the scalar loss sum Σ l(zᵢ, yᵢ) and the gradient
g = Xᵀ l'(z) in a single ``pallas_call`` — replacing the separate
``point_loss`` + ``dloss`` + ``xt_r`` chain (three reads of z/r, one
X read) with a single X read and inline elementwise math. This is the
§Perf L1 optimization: the residual r never round-trips through HBM.

Grid: (feature blocks j, example blocks i); the example axis reduces
into the gradient output, and the loss accumulates in its own (1, 1)
output during the j == 0 sweep only (so it is counted once).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dloss import _loss_fns

BLOCK_N = 512
BLOCK_D = 128


def _pad(a, axis, mult):
    rem = (-a.shape[axis]) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("loss", "block_n", "block_d"))
def loss_grad_fused(
    x, z, y, *, loss: str = "logistic",
    block_n: int = BLOCK_N, block_d: int = BLOCK_D,
):
    """(Σ l(zᵢ, yᵢ), Xᵀ l'(z)) for X: (n, d), z, y: (n,).

    Padding note: padded example rows get y = +1, z = 0 margins, which
    would contribute a nonzero loss — so a 0/1 validity mask rides along
    and zeroes both their loss and their residual.
    """
    val, der = _loss_fns(loss)
    n, d = x.shape
    bn = min(block_n, max(n, 1))
    bd = min(block_d, max(d, 1))
    xp = _pad(_pad(x, 0, bn), 1, bd)
    zp = _pad(z.reshape(-1, 1), 0, bn)
    yp = _pad(y.reshape(-1, 1), 0, bn)
    mask = _pad(jnp.ones((n, 1), x.dtype), 0, bn)
    np_, dp = xp.shape

    def kernel(x_ref, z_ref, y_ref, m_ref, loss_ref, g_ref):
        j = pl.program_id(0)
        i = pl.program_id(1)
        zv = z_ref[...]
        yv = y_ref[...]
        mv = m_ref[...]
        r = der(zv, yv) * mv  # (bn, 1) masked residual

        @pl.when(i == 0)
        def _init_g():
            g_ref[...] = jnp.zeros_like(g_ref)

        acc = jnp.promote_types(g_ref.dtype, jnp.float32)
        g_ref[...] += jnp.dot(
            r.T, x_ref[...], preferred_element_type=acc
        ).astype(g_ref.dtype)

        # loss sum: only the j == 0 sweep counts each example once
        @pl.when(jnp.logical_and(j == 0, i == 0))
        def _init_l():
            loss_ref[...] = jnp.zeros_like(loss_ref)

        @pl.when(j == 0)
        def _acc_l():
            loss_ref[...] += jnp.sum(val(zv, yv) * mv).reshape(1, 1)

    loss_out, grad_out = pl.pallas_call(
        kernel,
        grid=(dp // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, i: (i, j)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda j, i: (0, 0)),
            pl.BlockSpec((1, bd), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), x.dtype),
            jax.ShapeDtypeStruct((1, dp), x.dtype),
        ],
        interpret=True,
    )(xp, zp, yp, mask)
    return loss_out[0, 0], grad_out[0, :d]
