"""Elementwise point-loss kernels (VPU work on TPU).

The paper's theory covers continuously differentiable convex losses with
Lipschitz gradient: least squares, logistic, squared hinge (hinge itself
is excluded — non-differentiable). Loss selection is a *static* kernel
specialization: each loss id closes over its own elementwise body so the
lowered HLO contains no branches on the hot path.

Kernels:
- ``point_loss``  — l(z_i, y_i)
- ``dloss``       — l'(z_i, y_i) (derivative w.r.t. the margin z)
- ``vr_residual`` — l'(z_i, y_i) − l'(z0_i, y_i), the fused SVRG
  variance-reduction residual (one VMEM pass instead of two).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024

LOSSES = ("logistic", "squared_hinge", "least_squares")


def _loss_fns(loss: str):
    """Return (value, derivative) elementwise closures for a loss id."""
    if loss == "logistic":
        # l = log(1 + exp(-y z)); numerically stable via softplus.
        def val(z, y):
            return jnp.logaddexp(0.0, -y * z)

        def der(z, y):
            # -y * sigmoid(-y z)
            return -y * jax.scipy.special.expit(-y * z)

    elif loss == "squared_hinge":
        def val(z, y):
            m = jnp.maximum(0.0, 1.0 - y * z)
            return m * m

        def der(z, y):
            return -2.0 * y * jnp.maximum(0.0, 1.0 - y * z)

    elif loss == "least_squares":
        def val(z, y):
            d = z - y
            return 0.5 * d * d

        def der(z, y):
            return z - y

    else:  # pragma: no cover - guarded by LOSSES
        raise ValueError(f"unknown loss {loss!r}")
    return val, der


def _pad1(a, mult):
    rem = (-a.shape[0]) % mult
    if rem:
        a = jnp.pad(a, ((0, rem), (0, 0)))
    return a


def _elementwise_call(body, args, n, bn):
    """Run an elementwise Pallas kernel over (n,) vectors."""
    bn = min(bn, max(n, 1))
    padded = [_pad1(a.reshape(-1, 1), bn) for a in args]
    np_ = padded[0].shape[0]
    out = pl.pallas_call(
        body,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((bn, 1), lambda i: (i, 0))] * len(padded),
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), padded[0].dtype),
        interpret=True,
    )(*padded)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("loss", "block_n"))
def point_loss(z, y, *, loss: str = "logistic", block_n: int = BLOCK_N):
    """Elementwise l(z_i, y_i) → (n,)."""
    val, _ = _loss_fns(loss)

    def kernel(z_ref, y_ref, o_ref):
        o_ref[...] = val(z_ref[...], y_ref[...])

    return _elementwise_call(kernel, (z, y), z.shape[0], block_n)


@functools.partial(jax.jit, static_argnames=("loss", "block_n"))
def dloss(z, y, *, loss: str = "logistic", block_n: int = BLOCK_N):
    """Elementwise l'(z_i, y_i) → (n,)."""
    _, der = _loss_fns(loss)

    def kernel(z_ref, y_ref, o_ref):
        o_ref[...] = der(z_ref[...], y_ref[...])

    return _elementwise_call(kernel, (z, y), z.shape[0], block_n)


@functools.partial(jax.jit, static_argnames=("loss", "block_n"))
def vr_residual(z, z0, y, *, loss: str = "logistic", block_n: int = BLOCK_N):
    """Fused SVRG residual l'(z_i) − l'(z0_i) in one VMEM pass."""
    _, der = _loss_fns(loss)

    def kernel(z_ref, z0_ref, y_ref, o_ref):
        yv = y_ref[...]
        o_ref[...] = der(z_ref[...], yv) - der(z0_ref[...], yv)

    return _elementwise_call(kernel, (z, z0, y), z.shape[0], block_n)
