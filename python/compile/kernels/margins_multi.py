"""Multi-RHS margin kernel: Z = X @ W for W = [w₁ … w_k].

The §Perf L1 finding (see ``compile.vmem``): a linear model's hot spot
is a mat*vec* — arithmetic intensity ~2 flops/byte, so the kernel is
HBM-bandwidth-bound and MXU utilization is structurally irrelevant. The
lever that *does* matter is streaming X fewer times. The SVRG inner
step needs margins against both the iterate w and the anchor w₀ on the
same minibatch; the line search needs X·w and X·d on the same shard.
Computing them as one X @ [w₁, w₂] halves the dominant X traffic and
doubles the MXU's (tiny) occupancy for free.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128
BLOCK_D = 512


def _pad(a, axis, mult):
    rem = (-a.shape[axis]) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


def _kernel(x_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.promote_types(o_ref.dtype, jnp.float32)
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=acc
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def margins_multi(x, ws, *, block_n: int = BLOCK_N, block_d: int = BLOCK_D):
    """Z = X @ W for X: (n, d), W: (d, k) → Z: (n, k).

    One HBM pass over X regardless of k (vs k passes of :func:`margins`).
    """
    n, d = x.shape
    k = ws.shape[1]
    bn = min(block_n, max(n, 1))
    bd = min(block_d, max(d, 1))
    xp = _pad(_pad(x, 0, bn), 1, bd)
    wp = _pad(ws, 0, bd)
    np_, dp = xp.shape
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bn, dp // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:n, :]
