"""Pure-jnp oracle for every Pallas kernel — the correctness ground truth.

No Pallas, no tiling, no padding: straight dense jnp expressions. The
pytest suite asserts ``assert_allclose(kernel(...), ref(...))`` over a
hypothesis sweep of shapes and dtypes.
"""

import jax
import jax.numpy as jnp


def margins_ref(x, w):
    return x @ w


def xt_r_ref(x, r):
    return x.T @ r


def point_loss_ref(z, y, loss: str = "logistic"):
    if loss == "logistic":
        return jnp.logaddexp(0.0, -y * z)
    if loss == "squared_hinge":
        m = jnp.maximum(0.0, 1.0 - y * z)
        return m * m
    if loss == "least_squares":
        return 0.5 * (z - y) ** 2
    raise ValueError(loss)


def dloss_ref(z, y, loss: str = "logistic"):
    if loss == "logistic":
        return -y * jax.scipy.special.expit(-y * z)
    if loss == "squared_hinge":
        return -2.0 * y * jnp.maximum(0.0, 1.0 - y * z)
    if loss == "least_squares":
        return z - y
    raise ValueError(loss)


def vr_residual_ref(z, z0, y, loss: str = "logistic"):
    return dloss_ref(z, y, loss) - dloss_ref(z0, y, loss)


def shard_loss_grad_ref(w, x, y, loss: str = "logistic"):
    """Un-regularized shard objective: (Σ l_i, ∇Σ l_i)."""
    z = x @ w
    val = point_loss_ref(z, y, loss).sum()
    grad = x.T @ dloss_ref(z, y, loss)
    return val, grad


def svrg_epoch_ref(w, x, y, tilt, lam, lr, perm, batch, loss="logistic"):
    """Reference SVRG epoch on the tilted local objective f̂_p.

    f̂_p(w) = (λ/2)‖w‖² + Σ_i l(w·x_i, y_i) + tilt·(w − w_r)
    Anchor w0 = w at epoch start; μ = ∇f̂_p(w0). For each minibatch B
    (rows perm[k·b : (k+1)·b]):

        g = (n/|B|) Σ_B [∇l_i(w) − ∇l_i(w0)] + μ + λ(w − w0)
        w ← w − lr·g

    (The λ(w−w0) term keeps the regularizer exact rather than anchored.)
    Plain python loop — the oracle for both the Pallas-backed L2 scan
    and the Rust dense SVRG.
    """
    n = x.shape[0]
    w0 = w
    _, gsum0 = shard_loss_grad_ref(w0, x, y, loss)
    mu = lam * w0 + gsum0 + tilt
    nb = n // batch
    for k in range(nb):
        idx = perm[k * batch : (k + 1) * batch]
        xb, yb = x[idx], y[idx]
        rb = vr_residual_ref(xb @ w, xb @ w0, yb, loss)
        g = (n / batch) * (xb.T @ rb) + mu + lam * (w - w0)
        w = w - lr * g
    return w
