"""Gradient scatter kernel: g = Xᵀ r.

The transpose-matvec that turns per-example residuals into a feature-
space gradient. Grid walks (feature blocks, example blocks); the example
axis is the reduction axis. The (BN, BD) X tile is the same VMEM layout
the margins kernel uses, so on real TPU both kernels share an HBM→VMEM
schedule and X streams through once per pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512
BLOCK_D = 128


def _xtr_kernel(x_ref, r_ref, o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BD,) += (1, BN) @ (BN, BD): residual row-vector against the tile.
    acc = jnp.promote_types(o_ref.dtype, jnp.float32)
    o_ref[...] += jnp.dot(
        r_ref[...].T, x_ref[...], preferred_element_type=acc
    ).astype(o_ref.dtype)


def _pad_to(a, axis, mult):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def xt_r(x, r, *, block_n: int = BLOCK_N, block_d: int = BLOCK_D):
    """Compute g = Xᵀ r for X: (n, d), r: (n,) → g: (d,)."""
    n, d = x.shape
    bn = min(block_n, max(n, 1))
    bd = min(block_d, max(d, 1))
    xp = _pad_to(_pad_to(x, 0, bn), 1, bd)
    rp = _pad_to(r.reshape(-1, 1), 0, bn)
    np_, dp = xp.shape
    out = pl.pallas_call(
        _xtr_kernel,
        grid=(dp // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, i: (i, j)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, dp), x.dtype),
        interpret=True,
    )(xp, rp)
    return out[0, :d]
