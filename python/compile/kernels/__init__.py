"""Layer-1 Pallas kernels for the parallel-SGD dense hot path.

Each kernel is written for TPU-shaped execution (VMEM tiles feeding the
MXU via BlockSpec) but lowered with ``interpret=True`` so the CPU PJRT
client can execute the resulting HLO (see DESIGN.md §8).

Public API (all shapes are padded internally to block multiples):

- :func:`margins` — z = X @ w, the per-example margin tile-matvec.
- :func:`xt_r` — g = Xᵀ r, the gradient scatter-accumulate.
- :func:`dloss` — elementwise point-loss derivative r_i = l'(z_i, y_i).
- :func:`vr_residual` — fused variance-reduced residual
  r_i = l'(z_i, y_i) − l'(z0_i, y_i) used by the SVRG inner step.
- :func:`loss_grad_fused` — single-pass (Σ l, Xᵀ l') given margins —
  the §Perf replacement for the point_loss + dloss + xt_r chain.
"""

from .margins import margins
from .xtr import xt_r
from .dloss import dloss, vr_residual, point_loss, LOSSES
from .fused import loss_grad_fused
from .margins_multi import margins_multi

__all__ = [
    "margins",
    "xt_r",
    "dloss",
    "vr_residual",
    "point_loss",
    "LOSSES",
    "loss_grad_fused",
    "margins_multi",
]
