"""Tiled margin kernel: z = X @ w.

TPU mapping (DESIGN.md §8): the example-tile × feature-tile product is
the MXU workload. The grid walks (example blocks, feature blocks); each
step loads an (BN, BD) tile of X and a (BD, 1) slice of w into VMEM and
accumulates into the (BN, 1) output block. The feature axis is the
reduction axis, so the output BlockSpec maps every j to the same block
and we zero it on j == 0 — the canonical Pallas reduction idiom.

Block defaults are MXU-native (128) on the example axis and 512 on the
feature (lane-reduction) axis; both are clamped and the inputs padded so
any shape works.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile: 128×512 f32 = 256 KiB for X, well under the
# ~16 MiB VMEM budget even with double-buffering.
BLOCK_N = 128
BLOCK_D = 512


def _margins_kernel(x_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BN, BD) @ (BD, 1): MXU matmul, accumulating at (at least) f32.
    acc = jnp.promote_types(o_ref.dtype, jnp.float32)
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=acc
    ).astype(o_ref.dtype)


def _pad_to(a, axis, mult):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def margins(x, w, *, block_n: int = BLOCK_N, block_d: int = BLOCK_D):
    """Compute z = X @ w for X: (n, d), w: (d,) → z: (n,).

    Pads to block multiples, runs the Pallas tile-matvec, slices back.
    """
    n, d = x.shape
    bn = min(block_n, max(n, 1))
    bd = min(block_d, max(d, 1))
    xp = _pad_to(_pad_to(x, 0, bn), 1, bd)
    wp = _pad_to(w.reshape(-1, 1), 0, bd)
    np_, dp = xp.shape
    out = pl.pallas_call(
        _margins_kernel,
        grid=(np_ // bn, dp // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:n, 0]
