"""L1 perf analysis: VMEM footprint + MXU utilization estimates.

Pallas under ``interpret=True`` gives CPU-numpy timings only — not a TPU
proxy — so the L1 performance pass (DESIGN.md §9) optimizes *structure*:
for each kernel's BlockSpec we bound the VMEM working set (inputs +
outputs + double-buffering) against the ~16 MiB budget and estimate MXU
utilization from tile-dimension alignment to the 128×128 systolic array.

Run ``python -m compile.vmem`` to print the table recorded in
EXPERIMENTS.md §Perf; the pytest suite asserts every production block
shape fits VMEM and keeps MXU utilization ≥ 50 %.
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU = 128


@dataclass
class KernelSpec:
    name: str
    # list of (rows, cols, dtype_bytes) VMEM-resident blocks per grid step
    blocks: list
    # (m, k, n) of the per-step matmul fed to the MXU; None = VPU-only
    matmul: tuple | None


def vmem_bytes(spec: KernelSpec, double_buffered: bool = True) -> int:
    total = sum(r * c * b for (r, c, b) in spec.blocks)
    return total * (2 if double_buffered else 1)


def mxu_utilization(spec: KernelSpec) -> float:
    """Fraction of the 128×128 array's MACs doing useful work per step,
    taking the contraction dimension's 128-chunking into account."""
    if spec.matmul is None:
        return 0.0
    m, k, n = spec.matmul

    def eff(dim):
        # a dim of 300 uses ceil(300/128)=3 passes at 300/384 efficiency
        import math

        passes = math.ceil(dim / MXU)
        return dim / (passes * MXU)

    return eff(m) * eff(k) * eff(n)


def production_specs(
    bn_margins=128, bd_margins=512, bn_xtr=512, bd_xtr=128, f32=4
):
    """The block shapes the shipped kernels use (see kernels/*.py)."""
    return [
        KernelSpec(
            "margins (X@w)",
            blocks=[(bn_margins, bd_margins, f32), (bd_margins, 1, f32),
                    (bn_margins, 1, f32)],
            matmul=(bn_margins, bd_margins, 1),
        ),
        KernelSpec(
            "xt_r (Xᵀr)",
            blocks=[(bn_xtr, bd_xtr, f32), (bn_xtr, 1, f32),
                    (1, bd_xtr, f32)],
            matmul=(1, bn_xtr, bd_xtr),
        ),
        KernelSpec(
            "loss_grad_fused",
            blocks=[(bn_xtr, bd_xtr, f32)] + [(bn_xtr, 1, f32)] * 3
            + [(1, 1, f32), (1, bd_xtr, f32)],
            matmul=(1, bn_xtr, bd_xtr),
        ),
        KernelSpec(
            "dloss/vr_residual (elementwise)",
            blocks=[(1024, 1, f32)] * 4,
            matmul=None,
        ),
    ]


def report(specs=None) -> str:
    specs = specs or production_specs()
    lines = [
        f"{'kernel':<34} {'VMEM (dbl-buf)':>14} {'of 16MiB':>9} {'MXU util':>9}"
    ]
    for s in specs:
        v = vmem_bytes(s)
        u = mxu_utilization(s)
        lines.append(
            f"{s.name:<34} {v / 1024:>11.1f}KiB {v / VMEM_BYTES:>8.2%} "
            f"{u:>8.1%}" + ("  (VPU)" if s.matmul is None else "")
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
