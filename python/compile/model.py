"""Layer-2 JAX compute graph for the parallel-SGD method.

The per-node compute of Algorithm 1, expressed in JAX on top of the
Layer-1 Pallas kernels (``kernels.*``):

- :func:`shard_loss_grad` — step 1's per-node gradient component
  (Σ l_i, ∇Σ l_i) over the node's shard; the master adds the λ terms
  and all-reduces.
- :func:`svrg_epoch` — step 5's inner solver: one SVRG epoch on the
  gradient-consistent tilted objective f̂_p, as a ``lax.scan`` over
  minibatches so XLA fuses the whole epoch into one executable.
- :func:`predict_margins` — margins for the line-search by-products
  (z_i = w·x_i, d·x_i) and for AUPRC evaluation.
- :func:`objective` — full regularized risk for a shard (testing).

Everything here is lowered ONCE by ``aot.py`` to HLO text; Rust executes
the artifacts via PJRT on the request path. The λ, lr and tilt inputs
are runtime arguments so a single artifact serves every outer iteration.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import (margins, margins_multi, xt_r, dloss, point_loss,
                      vr_residual, loss_grad_fused)


def shard_loss_grad(w, x, y, *, loss: str = "logistic", fused: bool = True):
    """Un-regularized shard loss and gradient: (Σ l_i, Xᵀ l'(z)).

    The by-product z = X·w (paper step 1) is returned too so the caller
    can reuse it for the line search. ``fused=True`` (default, §Perf)
    computes loss+gradient in one Pallas pass; ``fused=False`` keeps the
    original three-kernel chain (the tests assert both agree).
    """
    z = margins(x, w)
    if fused:
        val, grad = loss_grad_fused(x, z, y, loss=loss)
        return val, grad, z
    val = jnp.sum(point_loss(z, y, loss=loss))
    r = dloss(z, y, loss=loss)
    grad = xt_r(x, r)
    return val, grad, z


def objective(w, x, y, lam, *, loss: str = "logistic"):
    """Full regularized risk over one shard: (λ/2)‖w‖² + Σ l_i."""
    val, _, _ = shard_loss_grad(w, x, y, loss=loss)
    return 0.5 * lam * jnp.vdot(w, w) + val


def tilted_grad(w, x, y, w_r, g_r, lam, *, loss: str = "logistic"):
    """∇f̂_p(w) for the gradient-consistent local approximation (eq. 2).

    tilt = g_r − λ w_r − ∇L_p(w_r);  ∇f̂_p(w) = λw + ∇L_p(w) + tilt.
    By construction ∇f̂_p(w_r) = g_r exactly — asserted in the tests.
    """
    _, gl_r, _ = shard_loss_grad(w_r, x, y, loss=loss)
    tilt = g_r - lam * w_r - gl_r
    _, gl, _ = shard_loss_grad(w, x, y, loss=loss)
    return lam * w + gl + tilt


@functools.partial(jax.jit, static_argnames=("batch", "loss"))
def svrg_epoch(w, x, y, tilt, lam, lr, perm, *, batch: int, loss: str = "logistic"):
    """One SVRG epoch on f̂_p(w) = (λ/2)‖w‖² + Σ l_i + tilt·(w − w_r).

    Anchor w0 = entry w; μ = ∇f̂_p(w0) (the full tilted gradient — the
    expensive pass SVRG amortizes). The epoch scans ⌊n/batch⌋
    minibatches in the order given by ``perm`` (supplied by the caller,
    reshuffled per epoch on the Rust side), each step applying the
    variance-reduced update

        g = (n/b)·X_Bᵀ[l'(z_B(w)) − l'(z_B(w0))] + μ + λ(w − w0)
        w ← w − lr·g

    Matches ``ref.svrg_epoch_ref`` bit-for-bit in f64 and to allclose
    tolerance in f32.
    """
    n = x.shape[0]
    nb = n // batch
    w0 = w
    _, gsum0, _ = shard_loss_grad(w0, x, y, loss=loss)
    mu = lam * w0 + gsum0 + tilt
    scale = jnp.asarray(n / batch, dtype=w.dtype)

    idx_blocks = perm[: nb * batch].reshape(nb, batch)

    def step(wc, idx):
        xb = jnp.take(x, idx, axis=0)
        yb = jnp.take(y, idx, axis=0)
        # one X_B stream for both margins (§Perf: bandwidth-bound kernel)
        zz = margins_multi(xb, jnp.stack([wc, w0], axis=1))
        rb = vr_residual(zz[:, 0], zz[:, 1], yb, loss=loss)
        g = scale * xt_r(xb, rb) + mu + lam * (wc - w0)
        return wc - lr * g, None

    w_out, _ = jax.lax.scan(step, w, idx_blocks)
    return w_out


def predict_margins(x, w):
    """z = X·w — line-search by-products and test-set scoring."""
    return margins(x, w)
