"""AOT bridge: lower the L2 graph to HLO *text* artifacts for Rust.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Entry points lowered (shapes fixed at lowering time, recorded in
``manifest.json`` for the Rust side):

- ``value_grad``  (w[D], X[N,D], y[N]) → (Σl, ∇Σl [D], z [N])
- ``svrg_epoch``  (w, X, y, tilt[D], λ, lr, perm[N] i32) → w' [D]
- ``margins``     (X[N,D], w[D]) → z [N]

Python runs once (``make artifacts``); nothing here is on the request
path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(n: int, d: int, batch: int, loss: str, dtype: str):
    """Lower every entry point; returns {artifact name: hlo text}."""
    ft = jnp.dtype(dtype)
    w = jax.ShapeDtypeStruct((d,), ft)
    x = jax.ShapeDtypeStruct((n, d), ft)
    y = jax.ShapeDtypeStruct((n,), ft)
    tilt = jax.ShapeDtypeStruct((d,), ft)
    scalar = jax.ShapeDtypeStruct((), ft)
    perm = jax.ShapeDtypeStruct((n,), jnp.int32)

    def value_grad(w, x, y):
        val, grad, z = model.shard_loss_grad(w, x, y, loss=loss)
        return val, grad, z

    def svrg_epoch(w, x, y, tilt, lam, lr, perm):
        return (model.svrg_epoch(w, x, y, tilt, lam, lr, perm,
                                 batch=batch, loss=loss),)

    def margins(x, w):
        return (model.predict_margins(x, w),)

    return {
        "value_grad": to_hlo_text(jax.jit(value_grad).lower(w, x, y)),
        "svrg_epoch": to_hlo_text(
            jax.jit(svrg_epoch).lower(w, x, y, tilt, scalar, scalar, perm)
        ),
        "margins": to_hlo_text(jax.jit(margins).lower(x, w)),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=2048,
                    help="examples per shard (fixed in the artifact)")
    ap.add_argument("--d", type=int, default=1024, help="feature dim")
    ap.add_argument("--batch", type=int, default=256,
                    help="SVRG minibatch size (static scan length n//batch)")
    ap.add_argument("--loss", default="logistic",
                    choices=("logistic", "squared_hinge", "least_squares"))
    ap.add_argument("--dtype", default="float32")
    # Back-compat with the scaffold Makefile's `--out ../artifacts/...`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    arts = lower_all(args.n, args.d, args.batch, args.loss, args.dtype)
    for name, text in arts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "n": args.n,
        "d": args.d,
        "batch": args.batch,
        "loss": args.loss,
        "dtype": args.dtype,
        "artifacts": {k: f"{k}.hlo.txt" for k in arts},
        "entry_points": {
            "value_grad": {"in": ["w[d]", "x[n,d]", "y[n]"],
                           "out": ["loss_sum", "grad[d]", "z[n]"]},
            "svrg_epoch": {"in": ["w[d]", "x[n,d]", "y[n]", "tilt[d]",
                                  "lam", "lr", "perm[n]:i32"],
                           "out": ["w_out[d]"]},
            "margins": {"in": ["x[n,d]", "w[d]"], "out": ["z[n]"]},
        },
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    # The scaffold Makefile stamps a specific file; honour it.
    if args.out and os.path.basename(args.out) not in (
        "value_grad.hlo.txt", "svrg_epoch.hlo.txt", "margins.hlo.txt"
    ):
        with open(args.out, "w") as f:
            f.write(arts["value_grad"])
        print(f"wrote {args.out} (alias of value_grad)")


if __name__ == "__main__":
    main()
