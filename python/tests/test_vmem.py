"""L1 structural perf guards (DESIGN.md §9): every production block
shape must fit the 16 MiB VMEM budget with double buffering, and the
analysis must flag the matvec kernels as bandwidth-bound (low MXU
occupancy is structural, not a bug — see kernels/margins_multi.py)."""

from compile import vmem


def test_all_production_kernels_fit_vmem():
    for spec in vmem.production_specs():
        v = vmem.vmem_bytes(spec, double_buffered=True)
        assert v < vmem.VMEM_BYTES * 0.5, f"{spec.name}: {v} bytes"


def test_mxu_utilization_reported():
    specs = vmem.production_specs()
    utils = {s.name: vmem.mxu_utilization(s) for s in specs}
    # matvec kernels: tiny but nonzero; elementwise: exactly zero
    assert utils["dloss/vr_residual (elementwise)"] == 0.0
    assert 0.0 < utils["margins (X@w)"] < 0.05
    # aligned tiles reach full efficiency on the contraction dims
    full = vmem.KernelSpec(
        "dense128", blocks=[(128, 128, 4)] * 3, matmul=(128, 128, 128)
    )
    assert abs(vmem.mxu_utilization(full) - 1.0) < 1e-12


def test_misaligned_tiles_lose_efficiency():
    bad = vmem.KernelSpec(
        "misaligned", blocks=[(130, 130, 4)], matmul=(130, 130, 130)
    )
    u = vmem.mxu_utilization(bad)
    assert u < 0.2  # 130/256 per dim ≈ 0.51³

def test_report_renders():
    r = vmem.report()
    assert "margins" in r and "MXU util" in r
