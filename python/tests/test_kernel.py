"""Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (including non-block-multiple, degenerate and
single-row cases) and dtypes; every kernel must match ``ref.py`` to
dtype-appropriate tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    margins,
    xt_r,
    dloss,
    point_loss,
    vr_residual,
    LOSSES,
)
from compile.kernels import ref

DTYPES = [np.float32, np.float64]


def _tol(dtype):
    return dict(rtol=3e-4, atol=3e-4) if dtype == np.float32 else dict(
        rtol=1e-10, atol=1e-10
    )


def _mat(rng, n, d, dtype):
    return jnp.asarray(rng.normal(size=(n, d)), dtype=dtype)


def _vec(rng, n, dtype, scale=1.0):
    return jnp.asarray(rng.normal(size=(n,)) * scale, dtype=dtype)


def _labels(rng, n, dtype):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=(n,)), dtype=dtype)


shapes = st.tuples(st.integers(1, 400), st.integers(1, 300))


@settings(max_examples=25, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(DTYPES))
def test_margins_matches_ref(shape, seed, dtype):
    n, d = shape
    rng = np.random.default_rng(seed)
    x, w = _mat(rng, n, d, dtype), _vec(rng, d, dtype)
    np.testing.assert_allclose(
        margins(x, w), ref.margins_ref(x, w), **_tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(DTYPES))
def test_xt_r_matches_ref(shape, seed, dtype):
    n, d = shape
    rng = np.random.default_rng(seed)
    x, r = _mat(rng, n, d, dtype), _vec(rng, n, dtype)
    np.testing.assert_allclose(
        xt_r(x, r), ref.xt_r_ref(x, r), **_tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1),
       loss=st.sampled_from(LOSSES), dtype=st.sampled_from(DTYPES))
def test_dloss_and_point_loss_match_ref(n, seed, loss, dtype):
    rng = np.random.default_rng(seed)
    z, y = _vec(rng, n, dtype, scale=3.0), _labels(rng, n, dtype)
    np.testing.assert_allclose(
        dloss(z, y, loss=loss), ref.dloss_ref(z, y, loss), **_tol(dtype)
    )
    np.testing.assert_allclose(
        point_loss(z, y, loss=loss), ref.point_loss_ref(z, y, loss),
        **_tol(dtype),
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1),
       loss=st.sampled_from(LOSSES))
def test_vr_residual_matches_ref(n, seed, loss):
    rng = np.random.default_rng(seed)
    z = _vec(rng, n, np.float32, 3.0)
    z0 = _vec(rng, n, np.float32, 3.0)
    y = _labels(rng, n, np.float32)
    np.testing.assert_allclose(
        vr_residual(z, z0, y, loss=loss),
        ref.vr_residual_ref(z, z0, y, loss),
        rtol=3e-4, atol=3e-4,
    )


@pytest.mark.parametrize("loss", LOSSES)
def test_dloss_is_derivative_of_point_loss(loss):
    """Finite-difference check that l' really is dl/dz."""
    rng = np.random.default_rng(7)
    z = _vec(rng, 200, np.float64, 2.0)
    y = _labels(rng, 200, np.float64)
    eps = 1e-6
    fd = (
        np.asarray(ref.point_loss_ref(z + eps, y, loss))
        - np.asarray(ref.point_loss_ref(z - eps, y, loss))
    ) / (2 * eps)
    np.testing.assert_allclose(
        np.asarray(dloss(z, y, loss=loss)), fd, rtol=1e-4, atol=1e-6
    )


@pytest.mark.parametrize(
    "block_n,block_d", [(8, 8), (32, 16), (128, 512), (256, 64)]
)
def test_margins_block_shape_invariance(block_n, block_d):
    """Tiling must never change the numbers — only the schedule."""
    rng = np.random.default_rng(3)
    x, w = _mat(rng, 257, 129, np.float32), _vec(rng, 129, np.float32)
    base = ref.margins_ref(x, w)
    np.testing.assert_allclose(
        margins(x, w, block_n=block_n, block_d=block_d),
        base, rtol=3e-4, atol=3e-4,
    )


@pytest.mark.parametrize(
    "block_n,block_d", [(8, 8), (64, 32), (512, 128)]
)
def test_xtr_block_shape_invariance(block_n, block_d):
    rng = np.random.default_rng(4)
    x, r = _mat(rng, 201, 77, np.float32), _vec(rng, 201, np.float32)
    np.testing.assert_allclose(
        xt_r(x, r, block_n=block_n, block_d=block_d),
        ref.xt_r_ref(x, r), rtol=3e-4, atol=3e-4,
    )


def test_zero_inputs():
    """All-zero inputs give exactly-zero outputs (padding is inert)."""
    x = jnp.zeros((5, 7), jnp.float32)
    w = jnp.zeros((7,), jnp.float32)
    r = jnp.zeros((5,), jnp.float32)
    assert np.all(np.asarray(margins(x, w)) == 0)
    assert np.all(np.asarray(xt_r(x, r)) == 0)


def test_squared_hinge_flat_region():
    """Squared hinge must be exactly 0 (value and grad) when y·z ≥ 1."""
    z = jnp.asarray([2.0, 5.0, -3.0], jnp.float32)
    y = jnp.asarray([1.0, 1.0, -1.0], jnp.float32)
    assert np.all(np.asarray(point_loss(z, y, loss="squared_hinge")) == 0)
    assert np.all(np.asarray(dloss(z, y, loss="squared_hinge")) == 0)


@settings(max_examples=20, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1),
       loss=st.sampled_from(LOSSES))
def test_fused_loss_grad_matches_chain(shape, seed, loss):
    """The fused single-pass kernel ≡ point_loss + dloss + xt_r."""
    from compile.kernels import loss_grad_fused

    n, d = shape
    rng = np.random.default_rng(seed)
    x = _mat(rng, n, d, np.float32)
    w = _vec(rng, d, np.float32, 0.3)
    y = _labels(rng, n, np.float32)
    z = ref.margins_ref(x, w)
    ls, g = loss_grad_fused(x, z, y, loss=loss)
    np.testing.assert_allclose(
        ls, np.sum(np.asarray(ref.point_loss_ref(z, y, loss))),
        rtol=3e-4, atol=3e-4,
    )
    np.testing.assert_allclose(
        g, ref.xt_r_ref(x, ref.dloss_ref(z, y, loss)),
        rtol=3e-3, atol=3e-3,
    )


@pytest.mark.parametrize("fused", [True, False])
def test_model_shard_loss_grad_fused_flag(fused):
    from compile import model

    rng = np.random.default_rng(5)
    x = _mat(rng, 120, 40, np.float32)
    w = _vec(rng, 40, np.float32, 0.2)
    y = _labels(rng, 120, np.float32)
    val, grad, z = model.shard_loss_grad(w, x, y, loss="logistic",
                                         fused=fused)
    vw, gw = ref.shard_loss_grad_ref(w, x, y, "logistic")
    np.testing.assert_allclose(val, vw, rtol=3e-4)
    np.testing.assert_allclose(grad, gw, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(z, ref.margins_ref(x, w), rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(shape=shapes, k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_margins_multi_matches_stacked_single(shape, k, seed):
    from compile.kernels import margins_multi

    n, d = shape
    rng = np.random.default_rng(seed)
    x = _mat(rng, n, d, np.float32)
    ws = jnp.stack([_vec(rng, d, np.float32) for _ in range(k)], axis=1)
    got = margins_multi(x, ws)
    assert got.shape == (n, k)
    for c in range(k):
        np.testing.assert_allclose(
            got[:, c], ref.margins_ref(x, ws[:, c]), rtol=3e-4, atol=3e-4
        )
