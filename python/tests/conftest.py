"""Enable x64 so float64 hypothesis sweeps actually run in f64, and
make `compile.*` importable whether pytest runs from python/ or the
repo root."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", True)
