"""L2 model graph: gradients vs jax.grad, SVRG epoch vs oracle,
gradient consistency of the tilted approximation (the paper's eq. 2)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, LOSSES


def _problem(seed, n=64, d=24, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype=dtype)
    w = jnp.asarray(rng.normal(size=(d,)) * 0.3, dtype=dtype)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=(n,)), dtype=dtype)
    return x, w, y, rng


@pytest.mark.parametrize("loss", LOSSES)
def test_shard_loss_grad_matches_autodiff(loss):
    x, w, y, _ = _problem(0, dtype=np.float64)

    def total(w):
        return jnp.sum(ref.point_loss_ref(x @ w, y, loss))

    val, grad, z = model.shard_loss_grad(w, x, y, loss=loss)
    np.testing.assert_allclose(val, total(w), rtol=1e-10)
    np.testing.assert_allclose(grad, jax.grad(total)(w), rtol=1e-8,
                               atol=1e-10)
    np.testing.assert_allclose(z, x @ w, rtol=1e-10)


@pytest.mark.parametrize("loss", LOSSES)
def test_tilted_gradient_consistency(loss):
    """∇f̂_p(wʳ) = gʳ exactly — the heart of the method (eq. 2)."""
    x, w_r, y, rng = _problem(1, dtype=np.float64)
    g_r = jnp.asarray(rng.normal(size=w_r.shape), dtype=np.float64)
    lam = 0.05
    g_hat = model.tilted_grad(w_r, x, y, w_r, g_r, lam, loss=loss)
    np.testing.assert_allclose(g_hat, g_r, rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([32, 64, 96]),
       batch=st.sampled_from([8, 16, 32]),
       loss=st.sampled_from(LOSSES))
def test_svrg_epoch_matches_oracle(seed, n, batch, loss):
    rng = np.random.default_rng(seed)
    d = 20
    x = jnp.asarray(rng.normal(size=(n, d)), dtype=np.float32)
    w = jnp.asarray(rng.normal(size=(d,)) * 0.2, dtype=np.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=(n,)), dtype=np.float32)
    tilt = jnp.asarray(rng.normal(size=(d,)) * 0.01, dtype=np.float32)
    perm = jnp.asarray(rng.permutation(n), dtype=jnp.int32)
    lam, lr = 0.1, 1e-3
    got = model.svrg_epoch(
        w, x, y, tilt, jnp.float32(lam), jnp.float32(lr), perm,
        batch=batch, loss=loss,
    )
    want = ref.svrg_epoch_ref(
        np.asarray(w), np.asarray(x), np.asarray(y), np.asarray(tilt),
        lam, lr, np.asarray(perm), batch, loss,
    )
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_svrg_epoch_descends_on_tilted_objective():
    """One epoch with a sane lr must decrease f̂_p from wʳ (the descent
    property Algorithm 1 step 6 relies on)."""
    x, w_r, y, rng = _problem(5, n=128, d=16, dtype=np.float64)
    lam = 0.1
    # Global gradient stand-in from a second shard.
    x2 = jnp.asarray(rng.normal(size=(128, 16)), dtype=np.float64)
    y2 = jnp.asarray(rng.choice([-1.0, 1.0], size=(128,)), dtype=np.float64)
    _, gl1, _ = model.shard_loss_grad(w_r, x, y, loss="logistic")
    _, gl2, _ = model.shard_loss_grad(w_r, x2, y2, loss="logistic")
    g_r = lam * w_r + gl1 + gl2
    tilt = g_r - lam * w_r - gl1

    def f_hat(w):
        base = 0.5 * lam * jnp.vdot(w, w) + jnp.sum(
            ref.point_loss_ref(x @ w, y, "logistic")
        )
        return base + jnp.vdot(tilt, w - w_r)

    perm = jnp.asarray(np.random.default_rng(0).permutation(128),
                       dtype=jnp.int32)
    w1 = model.svrg_epoch(
        w_r, x, y, tilt, jnp.float64(lam), jnp.float64(1e-4), perm,
        batch=32, loss="logistic",
    )
    assert float(f_hat(w1)) < float(f_hat(w_r))


def test_objective_value():
    x, w, y, _ = _problem(9, dtype=np.float64)
    lam = 0.3
    got = model.objective(w, x, y, lam, loss="least_squares")
    want = 0.5 * lam * float(jnp.vdot(w, w)) + float(
        jnp.sum(ref.point_loss_ref(x @ w, y, "least_squares"))
    )
    np.testing.assert_allclose(float(got), want, rtol=1e-12)
