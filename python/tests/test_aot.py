"""AOT artifact pipeline: lowering succeeds, the HLO text parses back
into an HloModule with the expected entry layout, no un-runnable
custom-calls leak in, and the manifest is consistent.

(The authoritative execute-the-artifact round-trip check lives on the
Rust side — rust/tests/runtime_roundtrip.rs — which loads these very
files through the same PJRT path the coordinator uses.)
"""

import json
import os

import pytest
from jax._src.lib import xla_client as xc

from compile import aot

N, D, BATCH = 64, 16, 16


@pytest.fixture(scope="module")
def texts():
    return aot.lower_all(N, D, BATCH, "logistic", "float32")


def test_all_entry_points_lower(texts):
    assert set(texts) == {"value_grad", "svrg_epoch", "margins"}
    for name, text in texts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_entry_layouts(texts):
    # value_grad: (w[D], X[N,D], y[N]) -> tuple(scalar, [D], [N])
    lay = texts["value_grad"].splitlines()[0]
    assert f"f32[{D}]" in lay and f"f32[{N},{D}]" in lay
    assert f"(f32[], f32[{D}]" in lay
    lay = texts["svrg_epoch"].splitlines()[0]
    assert f"s32[{N}]" in lay and f"->(f32[{D}]" in lay
    lay = texts["margins"].splitlines()[0]
    assert f"->(f32[{N}]" in lay


def test_hlo_text_parses_back(texts):
    """The text must survive the same parse the Rust loader performs
    (HloModuleProto::from_text ↔ hlo_module_from_text)."""
    for name, text in texts.items():
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.as_serialized_hlo_module_proto(), name


def test_no_custom_calls(texts):
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for name, text in texts.items():
        assert "custom-call" not in text, name


@pytest.mark.parametrize("loss", ["squared_hinge", "least_squares"])
def test_other_losses_lower(loss):
    texts = aot.lower_all(32, 8, 8, loss, "float32")
    for text in texts.values():
        assert text.startswith("HloModule")


def test_manifest_written(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(tmp_path), "--n", "32", "--d", "8",
         "--batch", "8"],
    )
    aot.main()
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert m["n"] == 32 and m["d"] == 8 and m["batch"] == 8
    for rel in m["artifacts"].values():
        assert os.path.exists(tmp_path / rel)
