//! `artifacts/manifest.json` — the contract between `aot.py` and the
//! runtime: shard shapes baked into the HLO plus artifact file names.

use crate::util::json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// examples per shard baked into the executables
    pub n: usize,
    /// feature dimension
    pub d: usize,
    /// SVRG minibatch (scan length n/batch)
    pub batch: usize,
    pub loss: String,
    pub dtype: String,
    /// artifact name → file path (resolved against the manifest dir)
    pub artifacts: BTreeMap<String, PathBuf>,
}

impl Manifest {
    pub fn parse(src: &str, base_dir: &Path) -> Result<Manifest, String> {
        let v = json::parse(src)?;
        let get_usize = |k: &str| {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or(format!("manifest missing numeric '{k}'"))
        };
        let get_str = |k: &str| {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or(format!("manifest missing string '{k}'"))
        };
        let mut artifacts = BTreeMap::new();
        match v.get("artifacts") {
            Some(json::Value::Obj(m)) => {
                for (k, val) in m {
                    let rel = val
                        .as_str()
                        .ok_or(format!("artifact '{k}' path not a string"))?;
                    artifacts.insert(k.clone(), base_dir.join(rel));
                }
            }
            _ => return Err("manifest missing 'artifacts' object".into()),
        }
        Ok(Manifest {
            n: get_usize("n")?,
            d: get_usize("d")?,
            batch: get_usize("batch")?,
            loss: get_str("loss")?,
            dtype: get_str("dtype")?,
            artifacts,
        })
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let m = Manifest::parse(&src, dir)?;
        for (name, p) in &m.artifacts {
            if !p.exists() {
                return Err(format!(
                    "artifact '{name}' missing at {} — run `make artifacts`",
                    p.display()
                ));
            }
        }
        Ok(m)
    }

    pub fn path(&self, name: &str) -> Result<&Path, String> {
        self.artifacts
            .get(name)
            .map(PathBuf::as_path)
            .ok_or(format!("no artifact named '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"{
      "n": 2048, "d": 1024, "batch": 256,
      "loss": "logistic", "dtype": "float32",
      "artifacts": {"margins": "margins.hlo.txt",
                    "value_grad": "value_grad.hlo.txt"}
    }"#;

    #[test]
    fn parses_and_resolves_paths() {
        let m = Manifest::parse(SRC, Path::new("/tmp/arts")).unwrap();
        assert_eq!(m.n, 2048);
        assert_eq!(m.d, 1024);
        assert_eq!(m.batch, 256);
        assert_eq!(m.loss, "logistic");
        assert_eq!(
            m.path("margins").unwrap(),
            Path::new("/tmp/arts/margins.hlo.txt")
        );
        assert!(m.path("nonexistent").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"n": 1}"#, Path::new(".")).is_err());
    }
}
