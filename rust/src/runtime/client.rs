//! The dense three-layer execution path: compiled-once PJRT
//! executables for the L2 graph (which embeds the L1 Pallas kernels),
//! called from the coordinator per node-shard.

use anyhow::{Context, Result};
use std::path::Path;

use crate::runtime::manifest::Manifest;

/// Owns the PJRT client plus the compiled executables for every
/// artifact in the manifest. One instance per process; executables are
/// compiled once and reused across all outer iterations and nodes.
pub struct DenseRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    value_grad: xla::PjRtLoadedExecutable,
    svrg_epoch: xla::PjRtLoadedExecutable,
    margins: xla::PjRtLoadedExecutable,
}

/// Output of one `value_grad` call: shard loss-sum, shard loss-gradient
/// and the margin by-products (paper step 1).
#[derive(Clone, Debug)]
pub struct ValueGrad {
    pub loss_sum: f64,
    pub grad: Vec<f32>,
    pub margins: Vec<f32>,
}

impl DenseRuntime {
    /// Load every artifact from `dir` (default `artifacts/`) and
    /// compile on the CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<DenseRuntime> {
        let manifest = Manifest::load(&dir)
            .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest
                .path(name)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(DenseRuntime {
            value_grad: compile("value_grad")?,
            svrg_epoch: compile("svrg_epoch")?,
            margins: compile("margins")?,
            manifest,
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn check(&self, what: &str, len: usize, want: usize) -> Result<()> {
        anyhow::ensure!(
            len == want,
            "{what}: length {len} does not match artifact shape {want} \
             (shapes are baked at AOT time — see artifacts/manifest.json)"
        );
        Ok(())
    }

    /// (Σ l_i, ∇Σ l_i, z = X·w) over one dense shard.
    /// `x` is row-major (n × d), `w` length d, `y` length n (±1).
    pub fn value_grad(&self, w: &[f32], x: &[f32], y: &[f32]) -> Result<ValueGrad> {
        let (n, d) = (self.manifest.n, self.manifest.d);
        self.check("w", w.len(), d)?;
        self.check("x", x.len(), n * d)?;
        self.check("y", y.len(), n)?;
        let lw = xla::Literal::vec1(w);
        let lx = xla::Literal::vec1(x).reshape(&[n as i64, d as i64])?;
        let ly = xla::Literal::vec1(y);
        let out = self.value_grad.execute::<xla::Literal>(&[lw, lx, ly])?
            [0][0]
            .to_literal_sync()?;
        let (val, grad, z) = out.to_tuple3()?;
        Ok(ValueGrad {
            loss_sum: val.get_first_element::<f32>()? as f64,
            grad: grad.to_vec::<f32>()?,
            margins: z.to_vec::<f32>()?,
        })
    }

    /// One SVRG epoch on the tilted local objective (L2's `svrg_epoch`,
    /// whose inner kernels are the L1 Pallas tiles). `perm` is this
    /// epoch's example order (length n, a permutation of 0..n).
    #[allow(clippy::too_many_arguments)]
    pub fn svrg_epoch(
        &self,
        w: &[f32],
        x: &[f32],
        y: &[f32],
        tilt: &[f32],
        lam: f32,
        lr: f32,
        perm: &[i32],
    ) -> Result<Vec<f32>> {
        let (n, d) = (self.manifest.n, self.manifest.d);
        self.check("w", w.len(), d)?;
        self.check("x", x.len(), n * d)?;
        self.check("y", y.len(), n)?;
        self.check("tilt", tilt.len(), d)?;
        self.check("perm", perm.len(), n)?;
        let args = [
            xla::Literal::vec1(w),
            xla::Literal::vec1(x).reshape(&[n as i64, d as i64])?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(tilt),
            xla::Literal::scalar(lam),
            xla::Literal::scalar(lr),
            xla::Literal::vec1(perm),
        ];
        let out = self.svrg_epoch.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// z = X·w (margins / test scoring).
    pub fn margins(&self, x: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let (n, d) = (self.manifest.n, self.manifest.d);
        self.check("w", w.len(), d)?;
        self.check("x", x.len(), n * d)?;
        let lx = xla::Literal::vec1(x).reshape(&[n as i64, d as i64])?;
        let lw = xla::Literal::vec1(w);
        let out = self.margins.execute::<xla::Literal>(&[lx, lw])?[0][0]
            .to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }
}

// No unit tests here: exercising the runtime needs the artifacts, which
// are a build product. The gated integration suite lives in
// rust/tests/runtime_roundtrip.rs (skips with a notice if artifacts/ is
// absent) and compares every executable against the Rust oracle.
