//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` → `python/compile/aot.py`) and
//! executes them on the CPU PJRT client from the L3 hot path. Python is
//! never involved at runtime — the HLO text is parsed, compiled and
//! cached here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;

pub use client::DenseRuntime;
pub use manifest::Manifest;
