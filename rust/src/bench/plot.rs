//! ASCII line plots for terminal rendering of the figure panels —
//! multi-series scatter on a character grid with optional log-y.

pub struct AsciiPlot {
    pub width: usize,
    pub height: usize,
    pub log_y: bool,
}

impl Default for AsciiPlot {
    fn default() -> Self {
        AsciiPlot { width: 72, height: 20, log_y: true }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

impl AsciiPlot {
    /// Render labeled series of (x, y) points.
    pub fn render(&self, title: &str, series: &[(String, Vec<(f64, f64)>)]) -> String {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for (_, s) in series {
            for &(x, y) in s {
                if x.is_finite() && y.is_finite() && (!self.log_y || y > 0.0) {
                    pts.push((x, y));
                }
            }
        }
        if pts.is_empty() {
            return format!("{title}\n  (no finite data)\n");
        }
        let ty = |y: f64| if self.log_y { y.log10() } else { y };
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(ty(y));
            y1 = y1.max(ty(y));
        }
        if (x1 - x0).abs() < 1e-300 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-300 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, s)) in series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in s {
                if !x.is_finite() || !y.is_finite() || (self.log_y && y <= 0.0) {
                    continue;
                }
                let cx = ((x - x0) / (x1 - x0) * (self.width - 1) as f64)
                    .round() as usize;
                let cy = ((ty(y) - y0) / (y1 - y0) * (self.height - 1) as f64)
                    .round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = mark;
            }
        }
        let mut out = String::new();
        out.push_str(title);
        out.push('\n');
        let ylab = |v: f64| {
            if self.log_y {
                format!("1e{v:>6.1}")
            } else {
                format!("{v:>8.3}")
            }
        };
        for (r, row) in grid.iter().enumerate() {
            let yv = y1 - (y1 - y0) * r as f64 / (self.height - 1) as f64;
            let lab = if r % 4 == 0 { ylab(yv) } else { " ".repeat(8) };
            out.push_str(&format!("{lab} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!(
            "{} +{}\n{} {:<12.4} {:>width$.4}\n",
            " ".repeat(8),
            "-".repeat(self.width),
            " ".repeat(8),
            x0,
            x1,
            width = self.width - 8
        ));
        for (si, (label, _)) in series.iter().enumerate() {
            out.push_str(&format!(
                "    {} {label}\n",
                MARKS[si % MARKS.len()]
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic_series() {
        let s = vec![
            (
                "fs-2".to_string(),
                vec![(0.0, 1.0), (10.0, 0.1), (20.0, 0.01)],
            ),
            ("sqm".to_string(), vec![(0.0, 1.0), (20.0, 0.5)]),
        ];
        let plot = AsciiPlot::default().render("gap vs passes", &s);
        assert!(plot.contains("gap vs passes"));
        assert!(plot.contains('*') && plot.contains('o'));
        assert!(plot.contains("fs-2") && plot.contains("sqm"));
    }

    #[test]
    fn handles_empty_and_degenerate() {
        let plot = AsciiPlot::default().render("empty", &[]);
        assert!(plot.contains("no finite data"));
        let s = vec![("one".to_string(), vec![(1.0, 1.0)])];
        let p = AsciiPlot::default().render("single", &s);
        assert!(p.contains('*'));
    }

    #[test]
    fn linear_scale_allows_zero() {
        let plot = AsciiPlot { log_y: false, ..Default::default() };
        let s = vec![("a".to_string(), vec![(0.0, 0.0), (1.0, 0.9)])];
        assert!(plot.render("auprc", &s).contains('*'));
    }
}
