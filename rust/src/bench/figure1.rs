//! The Figure-1 experiment: FS-s vs SQM vs Hybrid on kdd2010-shaped
//! data, producing the three panel series (relative objective gap vs
//! communication passes, vs simulated time, and AUPRC vs time) for a
//! given node count. Shared by `examples/figure1.rs` and the
//! `fig1_*` bench targets (DESIGN.md §5).

use crate::algo::fs::{FsConfig, FsDriver};
use crate::algo::hybrid::{HybridConfig, HybridDriver};
use crate::algo::sqm::{SqmConfig, SqmDriver};
use crate::algo::{Driver, StopRule};
use crate::cluster::{Cluster, CostModel};
use crate::data::partition::Partition;
use crate::data::synth::SynthConfig;
use crate::loss::LossKind;
use crate::metrics::trace::Trace;

#[derive(Clone, Debug)]
pub struct Figure1Config {
    pub nodes: usize,
    pub examples: usize,
    pub features: usize,
    pub nnz: usize,
    /// λ for the sum-form objective. The paper's kdd2010 setup (as in
    /// [8]) normalizes per-example; λ = rel_lambda · n_examples.
    pub rel_lambda: f64,
    pub loss: LossKind,
    /// the FS-s variants to plot
    pub epochs_list: Vec<usize>,
    /// outer-iteration budget per method
    pub iters: usize,
    pub seed: u64,
    pub cost: CostModel,
}

/// Communication-equivalent cost model: a size-`features` vector pass
/// in the simulation is charged what a size-20.21M (kdd2010) pass cost
/// on the paper's 1 Gbit/s cluster — so the *time* axis reflects the
/// paper's communication-to-computation ratio even though the repro
/// dimensionality is smaller (DESIGN.md §2).
pub fn kdd_equivalent_cost(features: usize) -> CostModel {
    const KDD_FEATURES: f64 = 20.21e6;
    CostModel {
        bandwidth_bytes_per_s: 125e6 * features as f64 / KDD_FEATURES,
        ..Default::default()
    }
}

impl Figure1Config {
    /// Bench-scale: runs in seconds, same qualitative shapes.
    pub fn small(nodes: usize) -> Figure1Config {
        Figure1Config {
            nodes,
            examples: 20_000,
            features: 1_000,
            nnz: 10,
            rel_lambda: 1e-5,
            loss: LossKind::SquaredHinge,
            epochs_list: vec![1, 2, 4],
            iters: 30,
            seed: 42,
            cost: kdd_equivalent_cost(1_000),
        }
    }

    /// Repro-scale (examples/figure1.rs --full): kdd2010 shape
    /// statistics scaled ~40× down on examples (DESIGN.md §2).
    pub fn full(nodes: usize) -> Figure1Config {
        Figure1Config {
            examples: 200_000,
            features: 500_000,
            nnz: 35,
            iters: 40,
            cost: kdd_equivalent_cost(500_000),
            ..Figure1Config::small(nodes)
        }
    }
}

#[derive(Clone, Debug)]
pub struct Figure1Output {
    pub traces: Vec<Trace>,
    pub f_star: f64,
    pub config_label: String,
}

pub fn run(cfg: &Figure1Config) -> Figure1Output {
    let data = SynthConfig {
        n_examples: cfg.examples,
        n_features: cfg.features,
        nnz_per_example: cfg.nnz,
        skew: 0.5,
        ..SynthConfig::default()
    }
    .generate(cfg.seed);
    let (train, test) = data.split(0.9, cfg.seed ^ 0xAB);
    let lam = cfg.rel_lambda * train.n_examples() as f64;
    let part = Partition::shuffled(train.n_examples(), cfg.nodes, cfg.seed ^ 0xCD);

    // --- reference optimum: single-node TRON to tiny tolerance ---
    let mut ref_cluster =
        Cluster::partition(train.clone(), 1, CostModel::free());
    let mut ref_cfg = SqmConfig { loss: cfg.loss, lam, ..Default::default() };
    ref_cfg.tron.eps = 1e-12;
    ref_cfg.tron.max_iter = 400;
    let f_star = SqmDriver::new(ref_cfg)
        .run(&mut ref_cluster, None, &StopRule::iters(400))
        .f;

    let mut traces = Vec::new();
    let fresh_cluster =
        || Cluster::partition_with(train.clone(), &part, cfg.cost);

    // FS-s variants
    for &s in &cfg.epochs_list {
        let mut cluster = fresh_cluster();
        let run = FsDriver::new(FsConfig {
            loss: cfg.loss,
            lam,
            epochs: s,
            seed: cfg.seed,
            ..Default::default()
        })
        .run(&mut cluster, Some(&test), &StopRule::iters(cfg.iters));
        traces.push(run.trace);
    }
    // SQM
    {
        let mut cluster = fresh_cluster();
        let run = SqmDriver::new(SqmConfig {
            loss: cfg.loss,
            lam,
            ..Default::default()
        })
        .run(&mut cluster, Some(&test), &StopRule::iters(cfg.iters));
        traces.push(run.trace);
    }
    // Hybrid
    {
        let mut cluster = fresh_cluster();
        let hcfg = HybridConfig {
            sqm: SqmConfig { loss: cfg.loss, lam, ..Default::default() },
            ..Default::default()
        };
        let run = HybridDriver::with_objective(hcfg).run(
            &mut cluster,
            Some(&test),
            &StopRule::iters(cfg.iters),
        );
        traces.push(run.trace);
    }

    Figure1Output {
        traces,
        f_star,
        config_label: format!(
            "{} nodes, {}x{} (nnz/ex {}), λ={:.1e}·n, {}",
            cfg.nodes,
            cfg.examples,
            cfg.features,
            cfg.nnz,
            cfg.rel_lambda,
            cfg.loss.name()
        ),
    }
}

/// Panel selector for rendering/emission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Panel {
    GapVsPasses,
    GapVsTime,
    AuprcVsTime,
}

impl Panel {
    pub fn series(&self, trace: &Trace, f_star: f64) -> Vec<(f64, f64)> {
        trace
            .points
            .iter()
            .map(|p| match self {
                Panel::GapVsPasses => {
                    (p.comm_passes, (p.f - f_star) / f_star.max(f64::MIN_POSITIVE))
                }
                Panel::GapVsTime => {
                    (p.seconds, (p.f - f_star) / f_star.max(f64::MIN_POSITIVE))
                }
                Panel::AuprcVsTime => (p.seconds, p.auprc),
            })
            .collect()
    }

    pub fn title(&self) -> &'static str {
        match self {
            Panel::GapVsPasses => "(f - f*)/f* vs communication passes",
            Panel::GapVsTime => "(f - f*)/f* vs simulated seconds",
            Panel::AuprcVsTime => "test AUPRC vs simulated seconds",
        }
    }

    pub fn log_y(&self) -> bool {
        !matches!(self, Panel::AuprcVsTime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_figure1_produces_all_series() {
        let cfg = Figure1Config {
            examples: 600,
            features: 150,
            nnz: 6,
            iters: 4,
            epochs_list: vec![1, 2],
            ..Figure1Config::small(4)
        };
        let out = run(&cfg);
        // FS-1, FS-2, SQM, Hybrid
        assert_eq!(out.traces.len(), 4);
        assert!(out.f_star.is_finite() && out.f_star > 0.0);
        let labels: Vec<&str> =
            out.traces.iter().map(|t| t.label.as_str()).collect();
        assert!(labels.contains(&"fs-1"));
        assert!(labels.contains(&"fs-2"));
        assert!(labels.contains(&"sqm"));
        assert!(labels.contains(&"hybrid"));
        for t in &out.traces {
            assert!(!t.points.is_empty(), "{}", t.label);
            for panel in [Panel::GapVsPasses, Panel::GapVsTime, Panel::AuprcVsTime] {
                assert_eq!(panel.series(t, out.f_star).len(), t.points.len());
            }
        }
    }
}
