//! In-tree micro/macro benchmark harness (criterion is unavailable
//! offline). `cargo bench` targets use `harness = false` and drive
//! [`Bencher`] directly.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum total time are reached; reports mean,
//! std-dev, median and p95 over per-iteration times.

pub mod figure1;
pub mod plot;

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: usize,
    pub min_iters: usize,
    pub min_time: Duration,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: 3,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

/// quick config for expensive end-to-end benches
impl BenchConfig {
    pub fn macro_bench() -> BenchConfig {
        BenchConfig {
            warmup: 1,
            min_iters: 3,
            min_time: Duration::from_millis(100),
            max_iters: 10,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {}  ±{}  median {}  p95 {}  min {}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.std_s),
            fmt_s(self.median_s),
            fmt_s(self.p95_s),
            fmt_s(self.min_s),
        )
    }
}

fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` under `cfg`; a `black_box`-style sink prevents the closure
/// result from being optimized away.
pub fn run<T>(
    name: &str,
    cfg: &BenchConfig,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    for _ in 0..cfg.warmup {
        sink(f());
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while (times.len() < cfg.min_iters || start.elapsed() < cfg.min_time)
        && times.len() < cfg.max_iters
    {
        let t0 = Instant::now();
        sink(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    stats_from(name, times)
}

fn stats_from(name: &str, mut times: Vec<f64>) -> BenchStats {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    let mean = times.iter().sum::<f64>() / n as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / n.max(2) as f64;
    let p95_idx = ((n as f64 * 0.95) as usize).min(n - 1);
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        std_s: var.sqrt(),
        median_s: times[n / 2],
        p95_s: times[p95_idx],
        min_s: times[0],
    }
}

/// prevent the optimizer from discarding a value
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_reasonable_stats() {
        let cfg = BenchConfig {
            warmup: 1,
            min_iters: 20,
            min_time: Duration::from_millis(1),
            max_iters: 50,
        };
        let s = run("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.iters >= 20);
        assert!(s.mean_s > 0.0);
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s);
        assert!(!s.report().is_empty());
    }
}
