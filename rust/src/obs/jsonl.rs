//! JSONL telemetry sink: one line per record, manifest first.
//!
//! The round writer is hand-rolled over a reusable `String` buffer —
//! after the first few rounds size it, a steady-state `round()` call
//! performs **zero heap acquisitions** (pinned by the `audit`-feature
//! test in `tests/obs.rs`): integer/float formatting goes through
//! `core::fmt`'s stack buffers, the line buffer and the `BufWriter`'s
//! fixed 8 KiB block are reused, and a flush is a plain syscall.
//! Numbers are emitted via [`crate::util::json::write_num`], the exact
//! same path `Value::Num` uses, so `util::json::parse` round-trips
//! every float to identical bits and non-finite values (the auprc NaN
//! sentinel) become `null`.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, BufWriter, Write};

use super::{Recorder, RoundRecord, RunManifest};
use crate::util::json::write_num;

/// Streams records as JSON Lines into any `io::Write` sink.
pub struct JsonlRecorder<W: io::Write + Send> {
    out: W,
    buf: String,
    failed: bool,
}

impl JsonlRecorder<BufWriter<File>> {
    /// The `--metrics-out PATH` constructor.
    pub fn create(
        path: &str,
    ) -> io::Result<JsonlRecorder<BufWriter<File>>> {
        Ok(JsonlRecorder::new(BufWriter::new(File::create(path)?)))
    }
}

impl<W: io::Write + Send> JsonlRecorder<W> {
    pub fn new(out: W) -> JsonlRecorder<W> {
        JsonlRecorder {
            out,
            buf: String::with_capacity(2048),
            failed: false,
        }
    }

    fn emit(&mut self) {
        if self.failed {
            return;
        }
        self.buf.push('\n');
        if let Err(e) = self.out.write_all(self.buf.as_bytes()) {
            // never fail the run over telemetry: warn once, go quiet
            self.failed = true;
            eprintln!(
                "warning: metrics sink write failed ({e}); \
                 recording disabled for the rest of the run"
            );
        }
    }
}

impl<W: io::Write + Send> Recorder for JsonlRecorder<W> {
    fn manifest(&mut self, m: &RunManifest) {
        self.buf.clear();
        let v = m.to_value().to_json(0);
        self.buf.push_str(&v);
        self.emit();
    }

    fn round(&mut self, rec: &RoundRecord) {
        self.buf.clear();
        write_round_line(&mut self.buf, rec);
        self.emit();
    }

    fn close(&mut self) {
        if !self.failed {
            if let Err(e) = self.out.flush() {
                eprintln!("warning: metrics sink flush failed ({e})");
            }
        }
    }
}

fn write_usize_arr(buf: &mut String, xs: &[usize]) {
    buf.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        let _ = write!(buf, "{x}");
    }
    buf.push(']');
}

fn write_f64_arr(buf: &mut String, xs: &[f64]) {
    buf.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        write_num(buf, x);
    }
    buf.push(']');
}

/// Serialize one round record. Key order is fixed so two runs of the
/// same build produce line-diffable streams; the reader is
/// order-insensitive.
fn write_round_line(buf: &mut String, r: &RoundRecord) {
    buf.push_str("{\"kind\":\"round\",\"round\":");
    let _ = write!(buf, "{}", r.round);
    buf.push_str(",\"f\":");
    write_num(buf, r.f);
    buf.push_str(",\"gnorm\":");
    write_num(buf, r.gnorm);
    buf.push_str(",\"auprc\":");
    write_num(buf, r.auprc);
    buf.push_str(",\"passes\":");
    write_num(buf, r.passes);
    buf.push_str(",\"secs\":");
    write_num(buf, r.secs);
    buf.push_str(",\"sg_hits\":");
    let _ = write!(buf, "{}", r.sg_hits);
    buf.push_str(",\"sg_replaced\":");
    write_usize_arr(buf, &r.sg_replaced);
    buf.push_str(",\"combined_ok\":");
    match r.combined_ok {
        Some(true) => buf.push_str("true"),
        Some(false) => buf.push_str("false"),
        None => buf.push_str("null"),
    }
    buf.push_str(",\"fallback\":");
    match r.fallback {
        // static reason tokens: no escaping needed
        Some(why) => {
            buf.push('"');
            buf.push_str(why);
            buf.push('"');
        }
        None => buf.push_str("null"),
    }
    buf.push_str(",\"step\":");
    match r.step {
        Some(t) => write_num(buf, t),
        None => buf.push_str("null"),
    }
    buf.push_str(",\"ls_evals\":");
    match r.ls_evals {
        Some(n) => {
            let _ = write!(buf, "{n}");
        }
        None => buf.push_str("null"),
    }
    buf.push_str(",\"async\":");
    buf.push_str(if r.is_async { "true" } else { "false" });
    buf.push_str(",\"quorum\":");
    write_usize_arr(buf, &r.quorum);
    buf.push_str(",\"staleness\":");
    write_usize_arr(buf, &r.staleness);
    buf.push_str(",\"rebased\":");
    let _ = write!(buf, "{}", r.rebased);
    buf.push_str(",\"members\":");
    write_usize_arr(buf, &r.members);
    buf.push_str(",\"faults\":[");
    for i in 0..r.fault_nodes.len() {
        if i > 0 {
            buf.push(',');
        }
        buf.push_str("{\"node\":");
        let _ = write!(buf, "{}", r.fault_nodes[i]);
        buf.push_str(",\"what\":\"");
        buf.push_str(r.fault_whats[i]);
        buf.push_str("\"}");
    }
    buf.push(']');
    buf.push_str(",\"compact\":");
    buf.push_str(if r.compact { "true" } else { "false" });
    buf.push_str(",\"live_u\":");
    let _ = write!(buf, "{}", r.live_u);
    buf.push_str(",\"d_passes\":");
    write_num(buf, r.d_passes);
    buf.push_str(",\"d_bytes\":");
    write_num(buf, r.d_bytes);
    buf.push_str(",\"d_scalar\":");
    let _ = write!(buf, "{}", r.d_scalar);
    buf.push_str(",\"d_makespan\":");
    write_num(buf, r.d_makespan);
    buf.push_str(",\"d_level_bytes\":");
    write_f64_arr(buf, &r.d_level_bytes);
    buf.push_str(",\"recovery_s\":");
    write_num(buf, r.recovery_s);
    buf.push_str(",\"retry_s\":");
    write_num(buf, r.retry_s);
    buf.push_str(",\"link_retries\":");
    let _ = write!(buf, "{}", r.link_retries);
    buf.push_str(",\"reroutes\":");
    let _ = write!(buf, "{}", r.reroutes);
    buf.push_str(",\"spec_hits\":");
    let _ = write!(buf, "{}", r.spec_hits);
    buf.push_str(",\"spec_misses\":");
    let _ = write!(buf, "{}", r.spec_misses);
    buf.push_str(",\"ctrl_tau\":");
    match r.ctrl_tau {
        Some(t) => {
            let _ = write!(buf, "{t}");
        }
        None => buf.push_str("null"),
    }
    buf.push_str(",\"ctrl_q\":");
    match r.ctrl_q {
        Some(q) => {
            let _ = write!(buf, "{q}");
        }
        None => buf.push_str("null"),
    }
    buf.push('}');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn round_line_is_valid_json_with_null_sentinels() {
        let mut r = RoundRecord::with_capacity(4);
        r.round = 3;
        r.f = 0.5;
        r.gnorm = 1.25e-3;
        r.auprc = f64::NAN; // sentinel: test set absent
        r.passes = 12.0;
        r.secs = 3.5;
        r.sg_hits = 1;
        r.sg_replaced.push(2);
        r.combined_ok = Some(false);
        r.fallback = Some("safeguard");
        r.is_async = true;
        r.quorum.extend([0, 2, 3]);
        r.staleness.extend([0, 1, 0]);
        r.members.extend([0, 1, 2, 3]);
        r.fault_nodes.push(1);
        r.fault_whats.push("crash");
        r.live_u = 100;
        r.d_passes = 4.0;
        r.d_level_bytes.push(2048.0);
        r.spec_hits = 2;
        r.spec_misses = 1;
        r.ctrl_tau = Some(3);
        r.retry_s = 0.25;
        r.link_retries = 4;
        r.reroutes = 1;
        let mut buf = String::new();
        write_round_line(&mut buf, &r);
        let v = json::parse(&buf).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("round"));
        assert_eq!(v.get("round").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("auprc"), Some(&json::Value::Null));
        assert_eq!(v.get("combined_ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("fallback").unwrap().as_str(), Some("safeguard"));
        assert_eq!(v.get("step"), Some(&json::Value::Null));
        assert_eq!(v.get("quorum").unwrap().as_arr().unwrap().len(), 3);
        let faults = v.get("faults").unwrap().as_arr().unwrap();
        assert_eq!(faults[0].get("what").unwrap().as_str(), Some("crash"));
        assert_eq!(v.get("spec_hits").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("spec_misses").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("ctrl_tau").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("ctrl_q"), Some(&json::Value::Null));
        assert_eq!(v.get("retry_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(v.get("link_retries").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("reroutes").unwrap().as_usize(), Some(1));
        // float fields round-trip to identical bits
        assert_eq!(
            v.get("gnorm").unwrap().as_f64().unwrap().to_bits(),
            r.gnorm.to_bits()
        );
    }

    #[test]
    fn recorder_streams_manifest_then_rounds() {
        let mut rec = JsonlRecorder::new(Vec::new());
        rec.manifest(&RunManifest {
            method: "fs".to_string(),
            nodes: 2,
            ..RunManifest::default()
        });
        let r = RoundRecord::with_capacity(2);
        rec.round(&r);
        rec.close();
        let text = String::from_utf8(rec.out).unwrap();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 2);
        let m = json::parse(lines[0]).unwrap();
        assert_eq!(m.get("kind").unwrap().as_str(), Some("manifest"));
        let v = json::parse(lines[1]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("round"));
    }
}
