//! Flight recorder: one typed [`RoundRecord`] per outer round, behind
//! a zero-cost-when-off [`Recorder`] trait.
//!
//! Every driver (`fs`, `async-fs`, `param-mix`, `sqm`) threads a
//! [`RoundObs`] through its outer loop: `begin()` snapshots the
//! [`Ledger`](crate::cluster::Ledger)/[`Engine`](crate::cluster::Engine)
//! baselines at the top of a round, the driver fills in its decisions
//! (safeguard outcomes, combined-test verdict, fallback reason, step
//! size, line-search trials, quorum composition, staleness, weather),
//! and `commit()` computes the per-round *deltas* (comm bytes,
//! makespan, per-level payload, fault events) and hands the record to
//! the cluster's installed [`Recorder`] sink.
//!
//! Guarantees (pinned by `tests/obs.rs`):
//!
//! - **zero virtual cost**: recording only *reads* the ledger and the
//!   engine; it never charges time, passes, or bytes;
//! - **off path bit-identical**: with no recorder installed every hook
//!   is an early-return on a cached `bool` — the run's arithmetic and
//!   its trace are byte-for-byte the pre-recorder behavior;
//! - **allocation-free steady state**: the record's vectors and the
//!   JSONL sink's buffers are pre-sized and reused; after warm-up a
//!   recorded round performs zero heap acquisitions (the `audit`
//!   feature proves it).
//!
//! The stream starts with a [`RunManifest`] header record
//! (`kind:"manifest"`), then one `kind:"round"` record per outer
//! round. `metrics::report::RecordedRun` reads the stream back and
//! reproduces the in-process markdown report byte-for-byte.

pub mod jsonl;
pub mod registry;

pub use jsonl::JsonlRecorder;
pub use registry::{Metric, MetricKind, Registry};

use crate::cluster::Cluster;
use crate::metrics::TracePoint;
use crate::util::json::Value;

/// Version of the JSONL record schema; bumped on any breaking field
/// change so `from_jsonl` can refuse streams it cannot interpret.
pub const SCHEMA_VERSION: u64 = 1;

/// A telemetry sink. Implementations must not charge the virtual
/// clock or the ledger — they only observe.
pub trait Recorder: Send {
    /// The run-manifest header; called exactly once, before any round.
    fn manifest(&mut self, m: &RunManifest);
    /// One record per outer round, in round order.
    fn round(&mut self, rec: &RoundRecord);
    /// Flush buffered output at end of run (default: no-op).
    fn close(&mut self) {}
}

/// The stream header: enough config + seeds + dataset shape to
/// interpret (and re-run) the recorded stream. Build info is
/// deliberately git-describe-free — package name + version only, so
/// records are reproducible from a tarball.
#[derive(Clone, Debug, Default)]
pub struct RunManifest {
    pub method: String,
    pub nodes: usize,
    pub threads: usize,
    pub examples: usize,
    pub features: usize,
    pub loss: String,
    pub lam: f64,
    pub iters: usize,
    pub seed: u64,
    pub master: String,
    pub pipeline: bool,
    pub staleness: Option<usize>,
    pub quorum: Option<usize>,
    /// asynchrony policy tag ([`Asynchrony::tag`]
    /// (crate::algo::adapt::Asynchrony::tag)), e.g. "t2-q3" or
    /// "adapt-t1.4-q4.1"; `None` on synchronous methods
    pub policy: Option<String>,
    pub fault: Option<String>,
    pub fault_seed: Option<u64>,
    /// `--link-profile` script (or "seeded"); `None` = uniform links
    pub link_profile: Option<String>,
    /// `--link-fault` script (or "seeded"); `None` = no link weather
    pub link_fault: Option<String>,
    /// seed for seeded link profile/weather; `None` when both are off
    pub link_seed: Option<u64>,
}

impl RunManifest {
    pub fn to_value(&self) -> Value {
        fn opt_num(v: Option<u64>) -> Value {
            v.map_or(Value::Null, |n| Value::Num(n as f64))
        }
        fn opt_str(v: &Option<String>) -> Value {
            v.clone().map_or(Value::Null, Value::Str)
        }
        let fault = self
            .fault
            .clone()
            .map_or(Value::Null, Value::Str);
        let policy = self
            .policy
            .clone()
            .map_or(Value::Null, Value::Str);
        Value::obj(vec![
            ("kind", Value::Str("manifest".to_string())),
            ("schema", Value::Num(SCHEMA_VERSION as f64)),
            ("method", Value::Str(self.method.clone())),
            ("nodes", Value::Num(self.nodes as f64)),
            ("threads", Value::Num(self.threads as f64)),
            ("examples", Value::Num(self.examples as f64)),
            ("features", Value::Num(self.features as f64)),
            ("loss", Value::Str(self.loss.clone())),
            ("lam", Value::Num(self.lam)),
            ("iters", Value::Num(self.iters as f64)),
            ("seed", Value::Num(self.seed as f64)),
            ("master", Value::Str(self.master.clone())),
            ("pipeline", Value::Bool(self.pipeline)),
            ("staleness", opt_num(self.staleness.map(|v| v as u64))),
            ("quorum", opt_num(self.quorum.map(|v| v as u64))),
            ("policy", policy),
            ("fault", fault),
            ("fault_seed", opt_num(self.fault_seed)),
            ("link_profile", opt_str(&self.link_profile)),
            ("link_fault", opt_str(&self.link_fault)),
            ("link_seed", opt_num(self.link_seed)),
            (
                "build",
                Value::obj(vec![
                    (
                        "pkg",
                        Value::Str(env!("CARGO_PKG_NAME").to_string()),
                    ),
                    (
                        "version",
                        Value::Str(env!("CARGO_PKG_VERSION").to_string()),
                    ),
                ]),
            ),
        ])
    }
}

/// One outer round, fully typed. All `Vec` fields keep their capacity
/// across rounds (see [`RoundRecord::clear`]); `Option` fields are
/// `None` on rounds that never reached the corresponding decision
/// (e.g. the final evaluation-only round before a stop).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    // --- trace mirror (exactly the TracePoint of this round) ---
    pub f: f64,
    pub gnorm: f64,
    pub auprc: f64,
    pub passes: f64,
    pub secs: f64,
    pub sg_hits: usize,
    // --- algorithm decisions ---
    /// nodes whose hybrid the safeguard replaced with −gʳ this round
    pub sg_replaced: Vec<usize>,
    /// combined-direction safeguard verdict, when it was evaluated
    pub combined_ok: Option<bool>,
    /// why the round fell back: "empty-quorum" | "safeguard"
    pub fallback: Option<&'static str>,
    /// accepted line-search step size
    pub step: Option<f64>,
    /// strong-Wolfe trial evaluations this round
    pub ls_evals: Option<usize>,
    // --- async state ---
    /// true iff this round ran the bounded-staleness quorum path
    pub is_async: bool,
    /// nodes whose contribution entered the quorum, node order
    pub quorum: Vec<usize>,
    /// per-contribution staleness, aligned with `quorum`
    pub staleness: Vec<usize>,
    /// rejoin re-bases charged this round (crash recovery)
    pub rebased: usize,
    /// speculative solves whose reconciled direction passed the
    /// safeguard (head starts banked) this round
    pub spec_hits: usize,
    /// speculative solves rejected and restarted at the commit
    pub spec_misses: usize,
    /// staleness bound τ in force this round (adaptive policy only)
    pub ctrl_tau: Option<usize>,
    /// quorum size q in force this round (adaptive policy only)
    pub ctrl_q: Option<usize>,
    // --- fleet weather ---
    /// live membership this round
    pub members: Vec<usize>,
    /// fault events applied this round (nodes, aligned with whats)
    pub fault_nodes: Vec<usize>,
    /// "crash" | "restart" | "degrade" | "flap" | "retry" | "drop"
    pub fault_whats: Vec<&'static str>,
    // --- compact-master state ---
    /// density-gate decision: master loop runs in |U| coordinates
    pub compact: bool,
    /// live union-support size (= d on the dense path)
    pub live_u: usize,
    // --- ledger/engine deltas over this round ---
    pub d_passes: f64,
    pub d_bytes: f64,
    pub d_scalar: usize,
    pub d_makespan: f64,
    pub d_level_bytes: Vec<f64>,
    /// cumulative recovery seconds (not a delta: the resilience table
    /// wants the running total, and cumulative survives round loss)
    pub recovery_s: f64,
    /// cumulative retry/backoff seconds on tree links (same cumulative
    /// convention as `recovery_s`)
    pub retry_s: f64,
    /// link-level retry attempts charged this round (window delta)
    pub link_retries: usize,
    /// hops rerouted around a dead edge this round (window delta)
    pub reroutes: usize,
}

impl RoundRecord {
    pub fn with_capacity(nodes: usize) -> RoundRecord {
        RoundRecord {
            sg_replaced: Vec::with_capacity(nodes),
            quorum: Vec::with_capacity(nodes),
            staleness: Vec::with_capacity(nodes),
            members: Vec::with_capacity(nodes),
            fault_nodes: Vec::with_capacity(4 * nodes),
            fault_whats: Vec::with_capacity(4 * nodes),
            d_level_bytes: Vec::with_capacity(8),
            ..RoundRecord::default()
        }
    }

    /// Reset for the next round, preserving every `Vec`'s capacity.
    pub fn clear(&mut self) {
        let RoundRecord {
            round,
            f,
            gnorm,
            auprc,
            passes,
            secs,
            sg_hits,
            sg_replaced,
            combined_ok,
            fallback,
            step,
            ls_evals,
            is_async,
            quorum,
            staleness,
            rebased,
            spec_hits,
            spec_misses,
            ctrl_tau,
            ctrl_q,
            members,
            fault_nodes,
            fault_whats,
            compact,
            live_u,
            d_passes,
            d_bytes,
            d_scalar,
            d_makespan,
            d_level_bytes,
            recovery_s,
            retry_s,
            link_retries,
            reroutes,
        } = self;
        *round = 0;
        *f = 0.0;
        *gnorm = 0.0;
        *auprc = f64::NAN;
        *passes = 0.0;
        *secs = 0.0;
        *sg_hits = 0;
        sg_replaced.clear();
        *combined_ok = None;
        *fallback = None;
        *step = None;
        *ls_evals = None;
        *is_async = false;
        quorum.clear();
        staleness.clear();
        *rebased = 0;
        *spec_hits = 0;
        *spec_misses = 0;
        *ctrl_tau = None;
        *ctrl_q = None;
        members.clear();
        fault_nodes.clear();
        fault_whats.clear();
        *compact = false;
        *live_u = 0;
        *d_passes = 0.0;
        *d_bytes = 0.0;
        *d_scalar = 0;
        *d_makespan = 0.0;
        d_level_bytes.clear();
        *recovery_s = 0.0;
        *retry_s = 0.0;
        *link_retries = 0;
        *reroutes = 0;
    }
}

/// Driver-side helper: owns the in-flight [`RoundRecord`] plus the
/// ledger/engine baselines, so instrumentation in a driver is three
/// calls — `begin` / field fills / `commit` — each a no-op when no
/// recorder is installed.
pub struct RoundObs {
    on: bool,
    rec: RoundRecord,
    base_passes: f64,
    base_bytes: f64,
    base_scalar: usize,
    base_makespan: f64,
    base_levels: Vec<f64>,
    base_faults: usize,
    /// separate watermark for the link-event log — it grows
    /// independently of the node-fault log within a round
    base_link_faults: usize,
    base_link_retries: usize,
    base_reroutes: usize,
}

impl RoundObs {
    pub fn new(cluster: &Cluster) -> RoundObs {
        let nodes = cluster.shards.len();
        RoundObs {
            on: cluster.is_recording(),
            rec: RoundRecord::with_capacity(nodes),
            base_passes: 0.0,
            base_bytes: 0.0,
            base_scalar: 0,
            base_makespan: 0.0,
            base_levels: Vec::with_capacity(8),
            base_faults: 0,
            base_link_faults: 0,
            base_link_retries: 0,
            base_reroutes: 0,
        }
    }

    /// True iff a recorder is installed — guard any per-round `Vec`
    /// fills with this so the off path does no work at all.
    pub fn on(&self) -> bool {
        self.on
    }

    /// Snapshot baselines at the top of round `round` (before fault
    /// weather is applied, so weather lands in this round's record).
    pub fn begin(&mut self, cluster: &Cluster, round: usize) {
        if !self.on {
            return;
        }
        self.rec.clear();
        self.rec.round = round;
        let l = &cluster.ledger;
        self.base_passes = l.comm_passes;
        self.base_bytes = l.comm_bytes;
        self.base_scalar = l.scalar_rounds;
        self.base_makespan = cluster.engine.makespan();
        self.base_levels.clear();
        self.base_levels.extend_from_slice(&l.level_bytes);
        self.base_faults = cluster.fault_log_len();
        self.base_link_faults = cluster.link_log_len();
        self.base_link_retries = l.link_retries;
        self.base_reroutes = l.reroutes;
    }

    /// The in-flight record, for the driver to fill decision fields.
    pub fn rec(&mut self) -> &mut RoundRecord {
        &mut self.rec
    }

    /// Mirror the round's [`TracePoint`] so the offline reader can
    /// rebuild the trace bit-for-bit.
    pub fn trace_point(&mut self, p: &TracePoint) {
        if !self.on {
            return;
        }
        self.rec.f = p.f;
        self.rec.gnorm = p.gnorm;
        self.rec.auprc = p.auprc;
        self.rec.passes = p.comm_passes;
        self.rec.secs = p.seconds;
        self.rec.sg_hits = p.safeguard_hits;
    }

    /// Compute the round's ledger/engine deltas + applied-fault slice
    /// and emit the record through the cluster's sink. Call exactly
    /// once per begun round — at the bottom of the loop body *and*
    /// before every `break`, so the final evaluation-only round still
    /// gets its record.
    pub fn commit(&mut self, cluster: &mut Cluster) {
        if !self.on {
            return;
        }
        {
            let l = &cluster.ledger;
            self.rec.d_passes = l.comm_passes - self.base_passes;
            self.rec.d_bytes = l.comm_bytes - self.base_bytes;
            self.rec.d_scalar = l.scalar_rounds - self.base_scalar;
            self.rec.d_makespan =
                cluster.engine.makespan() - self.base_makespan;
            self.rec.d_level_bytes.clear();
            for (i, &b) in l.level_bytes.iter().enumerate() {
                let b0 = self.base_levels.get(i).copied().unwrap_or(0.0);
                self.rec.d_level_bytes.push(b - b0);
            }
            self.rec.recovery_s = l.recovery_seconds;
            self.rec.retry_s = l.retry_seconds;
            self.rec.link_retries =
                l.link_retries - self.base_link_retries;
            self.rec.reroutes = l.reroutes - self.base_reroutes;
        }
        for i in self.base_faults..cluster.fault_log_len() {
            if let Some((_, node, what)) = cluster.fault_log_entry(i) {
                self.rec.fault_nodes.push(node);
                self.rec.fault_whats.push(what);
            }
        }
        // link events ("partition"/"heal") ride in the same applied-
        // fault slice, diffed on their own watermark.
        for i in self.base_link_faults..cluster.link_log_len() {
            if let Some((_, node, what)) = cluster.link_log_entry(i) {
                self.rec.fault_nodes.push(node);
                self.rec.fault_whats.push(what);
            }
        }
        cluster.record_round(&self.rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_record_clear_keeps_capacity() {
        let mut r = RoundRecord::with_capacity(8);
        for i in 0..8 {
            r.quorum.push(i);
            r.members.push(i);
            r.sg_replaced.push(i);
        }
        r.step = Some(0.5);
        r.fallback = Some("safeguard");
        let cap = r.quorum.capacity();
        r.clear();
        assert!(r.quorum.is_empty());
        assert!(r.members.is_empty());
        assert_eq!(r.step, None);
        assert_eq!(r.fallback, None);
        assert!(r.auprc.is_nan());
        assert_eq!(r.quorum.capacity(), cap);
    }

    #[test]
    fn manifest_value_has_kind_and_schema() {
        let m = RunManifest {
            method: "afs".to_string(),
            nodes: 4,
            policy: Some("t2-q3".to_string()),
            link_fault: Some("congest:p=0.2".to_string()),
            ..RunManifest::default()
        };
        let v = m.to_value();
        let s = v.to_json(0);
        assert!(s.contains("\"kind\": \"manifest\""), "{s}");
        assert!(s.contains("\"schema\": 1"), "{s}");
        assert!(s.contains("\"policy\": \"t2-q3\""), "{s}");
        assert!(s.contains("\"pkg\": \"psgd\""), "{s}");
        assert!(s.contains("\"link_profile\": null"), "{s}");
        assert!(s.contains("\"link_fault\": \"congest:p=0.2\""), "{s}");
    }
}
