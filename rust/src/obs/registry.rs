//! Ordered metrics registry: the one render path every telemetry
//! surface publishes through.
//!
//! [`Ledger`](crate::cluster::Ledger), [`Engine`](crate::cluster::Engine)
//! and the fault layer each expose a `publish(&self, &mut Registry)`
//! that pushes named counters/gauges in a **fixed order**; the former
//! bespoke `*_profile()` string renderers are now thin wrappers that
//! publish into a registry and call [`Registry::render`]. The registry
//! is `Vec`-indexed on purpose — no `HashMap` (pallas-lint
//! `no-unordered-iteration` covers this module), so publish order *is*
//! render order and two identical runs render identical strings.
//!
//! Registries are render-time objects: they allocate freely because
//! they are built only when a human-readable profile or a report is
//! requested, never inside a steady-state round.

use std::fmt::Write as _;

/// What a metric means — and how [`Registry::render`] formats it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// monotone integer count; rendered as `name 42`
    Counter,
    /// point-in-time float; rendered as `name 0.125s` (per-metric
    /// precision + unit suffix)
    Gauge,
}

/// One named metric. Histograms are published as a run of counters
/// sharing a prefix (`s0`, `s1`, …) so the registry stays a flat,
/// ordered `Vec`.
#[derive(Clone, Debug)]
pub struct Metric {
    pub name: String,
    pub kind: MetricKind,
    pub value: f64,
    /// render precision for gauges (ignored for counters)
    pub decimals: usize,
    /// render suffix for gauges, e.g. `"s"` or `"KB"`
    pub unit: &'static str,
}

/// Ordered, `Vec`-indexed metric registry.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    items: Vec<Metric>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { items: Vec::new() }
    }

    pub fn counter(&mut self, name: impl Into<String>, v: u64) {
        self.items.push(Metric {
            name: name.into(),
            kind: MetricKind::Counter,
            value: v as f64,
            decimals: 0,
            unit: "",
        });
    }

    pub fn gauge(
        &mut self,
        name: impl Into<String>,
        v: f64,
        decimals: usize,
        unit: &'static str,
    ) {
        self.items.push(Metric {
            name: name.into(),
            kind: MetricKind::Gauge,
            value: v,
            decimals,
            unit,
        });
    }

    /// Publish a histogram as `prefix0 .. prefixN` counters (one per
    /// bucket), keeping the registry flat and ordered.
    pub fn histogram(&mut self, prefix: &str, counts: &[usize]) {
        for (i, &n) in counts.iter().enumerate() {
            self.counter(format!("{prefix}{i}"), n as u64);
        }
    }

    /// Linear lookup by name (the registry is small and ordered; no
    /// hashing anywhere near a render path).
    pub fn get(&self, name: &str) -> Option<f64> {
        self.items.iter().find(|m| m.name == name).map(|m| m.value)
    }

    pub fn items(&self) -> &[Metric] {
        &self.items
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// THE render path: `name value` segments joined by `" | "`, in
    /// publish order. Counters render as integers, gauges with their
    /// declared precision and unit. An empty registry renders as `""`
    /// (the quiet-profile contract the ledger tests pin).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, m) in self.items.iter().enumerate() {
            if i > 0 {
                out.push_str(" | ");
            }
            match m.kind {
                MetricKind::Counter => {
                    let _ = write!(out, "{} {}", m.name, m.value as u64);
                }
                MetricKind::Gauge => {
                    let _ = write!(
                        out,
                        "{} {:.*}{}",
                        m.name, m.decimals, m.value, m.unit
                    );
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_order_is_render_order() {
        let mut reg = Registry::new();
        reg.counter("crash", 2);
        reg.gauge("recovery", 0.125, 3, "s");
        reg.counter("lost", 3);
        assert_eq!(reg.render(), "crash 2 | recovery 0.125s | lost 3");
        assert_eq!(reg.get("crash"), Some(2.0));
        assert_eq!(reg.get("recovery"), Some(0.125));
        assert_eq!(reg.get("nope"), None);
    }

    #[test]
    fn histogram_flattens_to_prefixed_counters() {
        let mut reg = Registry::new();
        reg.histogram("s", &[3, 1, 1]);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.render(), "s0 3 | s1 1 | s2 1");
    }

    #[test]
    fn empty_registry_renders_empty() {
        let reg = Registry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.render(), "");
    }

    #[test]
    fn gauge_precision_and_unit() {
        let mut reg = Registry::new();
        reg.gauge("L0", 2.0, 1, "KB");
        reg.gauge("L1", 0.25, 1, "KB");
        assert_eq!(reg.render(), "L0 2.0KB | L1 0.2KB");
    }
}
