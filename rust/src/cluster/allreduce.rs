//! Tree-ordered reduction — the summation order a physical AllReduce
//! binary tree produces. Using the *actual* tree order (rather than a
//! left fold) keeps the simulation faithful to [8]'s arrangement and
//! lets the property suite assert the floating-point discrepancy vs
//! sequential summation stays within tolerance.

/// Sum a set of equal-length vectors pairwise in binary-tree order.
///
/// §Perf: the first combine level reads the borrowed inputs directly
/// (allocating only ⌈n/2⌉ pair buffers instead of cloning all n
/// vectors); higher levels merge in place — halves peak allocation and
/// removed the 20 MB memcpy the 25-node reduction was paying.
pub fn tree_sum(vectors: &[Vec<f64>]) -> Vec<f64> {
    assert!(!vectors.is_empty(), "tree_sum of zero nodes");
    let dim = vectors[0].len();
    assert!(
        vectors.iter().all(|v| v.len() == dim),
        "ragged vectors in reduction"
    );
    // level 1: pair the borrowed inputs
    let mut level: Vec<Vec<f64>> = vectors
        .chunks(2)
        .map(|pair| match pair {
            [a, b] => a.iter().zip(b).map(|(x, y)| x + y).collect(),
            [a] => a.clone(),
            _ => unreachable!(),
        })
        .collect();
    // higher levels: in-place pairwise merge
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (ai, bi) in a.iter_mut().zip(&b) {
                    *ai += bi;
                }
            }
            next.push(a);
        }
        level = next;
    }
    level.pop().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_sequential_sum() {
        let mut rng = Rng::new(1);
        for nodes in [1usize, 2, 3, 5, 8, 13, 25, 100] {
            let vs: Vec<Vec<f64>> = (0..nodes)
                .map(|_| (0..17).map(|_| rng.normal()).collect())
                .collect();
            let tree = tree_sum(&vs);
            for j in 0..17 {
                let seq: f64 = vs.iter().map(|v| v[j]).sum();
                assert!(
                    (tree[j] - seq).abs() < 1e-10 * (1.0 + seq.abs()),
                    "nodes={nodes} j={j}"
                );
            }
        }
    }

    #[test]
    fn single_node_is_identity() {
        let v = vec![vec![1.0, 2.0, 3.0]];
        assert_eq!(tree_sum(&v), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged() {
        tree_sum(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    #[should_panic(expected = "zero nodes")]
    fn rejects_empty() {
        tree_sum(&[]);
    }
}
