//! Tree-ordered reduction — the summation order a physical AllReduce
//! binary tree produces. Using the *actual* tree order (rather than a
//! left fold) keeps the simulation faithful to [8]'s arrangement and
//! lets the property suite assert the floating-point discrepancy vs
//! sequential summation stays within tolerance.
//!
//! [`tree_sum`] is the dense path; [`tree_sum_sparse`] merges
//! index/value gradients by column at the leaves and auto-switches to a
//! dense accumulator once the merged density crosses
//! [`DENSE_SWITCH_DENSITY`] — the sound-combiner trick that makes the
//! reduction cost follow the data's support instead of d.

use crate::linalg::sparse::{
    SparseVec, BYTES_PER_DENSE_SCALAR,
};

/// Sum a set of equal-length vectors pairwise in binary-tree order.
///
/// §Perf: the first combine level reads the borrowed inputs directly
/// (allocating only ⌈n/2⌉ pair buffers instead of cloning all n
/// vectors); higher levels merge in place — halves peak allocation and
/// removed the 20 MB memcpy the 25-node reduction was paying.
pub fn tree_sum(vectors: &[Vec<f64>]) -> Vec<f64> {
    assert!(!vectors.is_empty(), "tree_sum of zero nodes");
    let dim = vectors[0].len();
    assert!(
        vectors.iter().all(|v| v.len() == dim),
        "ragged vectors in reduction"
    );
    // level 1: pair the borrowed inputs
    let mut level: Vec<Vec<f64>> = vectors
        .chunks(2)
        .map(|pair| match pair {
            [a, b] => a.iter().zip(b).map(|(x, y)| x + y).collect(),
            [a] => a.clone(),
            _ => unreachable!(),
        })
        .collect();
    // higher levels: in-place pairwise merge
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (ai, bi) in a.iter_mut().zip(&b) {
                    *ai += bi;
                }
            }
            next.push(a);
        }
        level = next;
    }
    level.pop().unwrap()
}

/// Merged density at which [`tree_sum_sparse`] flips its accumulator to
/// dense (wire break-even: nnz·12 B ≥ d·8 B at density 2/3).
pub const DENSE_SWITCH_DENSITY: f64 = 2.0 / 3.0;

/// Result of a sparse-aware reduction: stays index/value while the
/// union support is small, dense once it crossed the switch threshold
/// somewhere up the tree.
#[derive(Clone, Debug)]
pub enum Reduced {
    Sparse(SparseVec),
    Dense(Vec<f64>),
}

impl Reduced {
    pub fn dim(&self) -> usize {
        match self {
            Reduced::Sparse(s) => s.dim,
            Reduced::Dense(v) => v.len(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Reduced::Sparse(s) => s.nnz(),
            Reduced::Dense(v) => v.len(),
        }
    }

    /// Bytes this payload occupies on the wire, in whichever encoding
    /// is smaller (a real system sends the cheaper one).
    pub fn wire_bytes(&self) -> usize {
        let dense = self.dim() * BYTES_PER_DENSE_SCALAR;
        match self {
            Reduced::Sparse(s) => s.wire_bytes().min(dense),
            Reduced::Dense(_) => dense,
        }
    }

    pub fn into_dense(self) -> Vec<f64> {
        match self {
            Reduced::Sparse(s) => s.to_dense(),
            Reduced::Dense(v) => v,
        }
    }
}

fn promote(s: SparseVec, switch_nnz: usize) -> Reduced {
    if s.nnz() > switch_nnz {
        Reduced::Dense(s.to_dense())
    } else {
        Reduced::Sparse(s)
    }
}

fn merge_reduced(a: Reduced, b: Reduced, switch_nnz: usize) -> Reduced {
    match (a, b) {
        (Reduced::Sparse(a), Reduced::Sparse(b)) => {
            promote(a.merge(&b), switch_nnz)
        }
        (Reduced::Sparse(s), Reduced::Dense(mut d))
        | (Reduced::Dense(mut d), Reduced::Sparse(s)) => {
            s.axpy_into(1.0, &mut d);
            Reduced::Dense(d)
        }
        (Reduced::Dense(mut a), Reduced::Dense(b)) => {
            for (ai, bi) in a.iter_mut().zip(&b) {
                *ai += bi;
            }
            Reduced::Dense(a)
        }
    }
}

/// Sparse binary-tree reduction over per-node index/value gradients.
///
/// Returns the merged result plus, per tree level, the largest message
/// (in wire bytes, cheaper of sparse/dense encoding) any node sent at
/// that level — what the cluster charges the clock with, since sends
/// within one level are concurrent. The merge order pairs nodes exactly
/// like [`tree_sum`], so the two paths agree coordinate-for-coordinate
/// up to the identity a + 0 = a.
pub fn tree_sum_sparse(parts: &[SparseVec]) -> (Reduced, Vec<usize>) {
    assert!(!parts.is_empty(), "tree_sum of zero nodes");
    let dim = parts[0].dim;
    assert!(
        parts.iter().all(|p| p.dim == dim),
        "ragged vectors in reduction"
    );
    let switch_nnz = (dim as f64 * DENSE_SWITCH_DENSITY) as usize;
    let dense_bytes = dim * BYTES_PER_DENSE_SCALAR;
    let mut level_bytes = Vec::new();
    // level 1: merge the borrowed inputs pairwise
    let mut sent = 0usize;
    let mut level: Vec<Reduced> = parts
        .chunks(2)
        .map(|pair| match pair {
            [a, b] => {
                sent = sent.max(b.wire_bytes().min(dense_bytes));
                promote(a.merge(b), switch_nnz)
            }
            [a] => promote((*a).clone(), switch_nnz),
            _ => unreachable!(),
        })
        .collect();
    if parts.len() > 1 {
        level_bytes.push(sent);
    }
    // higher levels
    while level.len() > 1 {
        let mut sent = 0usize;
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => {
                    sent = sent.max(b.wire_bytes());
                    next.push(merge_reduced(a, b, switch_nnz));
                }
                None => next.push(a),
            }
        }
        level_bytes.push(sent);
        level = next;
    }
    (level.pop().unwrap(), level_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_sequential_sum() {
        let mut rng = Rng::new(1);
        for nodes in [1usize, 2, 3, 5, 8, 13, 25, 100] {
            let vs: Vec<Vec<f64>> = (0..nodes)
                .map(|_| (0..17).map(|_| rng.normal()).collect())
                .collect();
            let tree = tree_sum(&vs);
            for j in 0..17 {
                let seq: f64 = vs.iter().map(|v| v[j]).sum();
                assert!(
                    (tree[j] - seq).abs() < 1e-10 * (1.0 + seq.abs()),
                    "nodes={nodes} j={j}"
                );
            }
        }
    }

    #[test]
    fn single_node_is_identity() {
        let v = vec![vec![1.0, 2.0, 3.0]];
        assert_eq!(tree_sum(&v), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged() {
        tree_sum(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    #[should_panic(expected = "zero nodes")]
    fn rejects_empty() {
        tree_sum(&[]);
    }

    #[test]
    fn sparse_tree_matches_dense_tree() {
        let mut rng = Rng::new(4);
        for nodes in [1usize, 2, 3, 5, 8, 13, 25] {
            let dim = 37;
            let dense_parts: Vec<Vec<f64>> = (0..nodes)
                .map(|_| {
                    (0..dim)
                        .map(|_| {
                            if rng.below(3) == 0 {
                                rng.normal()
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let sparse_parts: Vec<SparseVec> =
                dense_parts.iter().map(|p| SparseVec::from_dense(p)).collect();
            let want = tree_sum(&dense_parts);
            let (got, levels) = tree_sum_sparse(&sparse_parts);
            let got = got.into_dense();
            for j in 0..dim {
                assert!(
                    (want[j] - got[j]).abs() < 1e-12,
                    "nodes={nodes} j={j}"
                );
            }
            if nodes > 1 {
                assert!(!levels.is_empty());
                assert!(levels
                    .iter()
                    .all(|&b| b <= dim * BYTES_PER_DENSE_SCALAR));
            }
        }
    }

    #[test]
    fn sparse_reduction_switches_to_dense_accumulator() {
        // two near-full sparse vectors: the merge crosses 2/3 density
        let dim = 30;
        let a = SparseVec::from_pairs(
            dim,
            (0..25u32).map(|c| (c, 1.0)).collect(),
        );
        let b = SparseVec::from_pairs(
            dim,
            (5..30u32).map(|c| (c, 2.0)).collect(),
        );
        let (out, _) = tree_sum_sparse(&[a.clone(), b.clone()]);
        assert!(matches!(out, Reduced::Dense(_)), "should have promoted");
        let mut want = a.to_dense();
        b.axpy_into(1.0, &mut want);
        assert_eq!(out.into_dense(), want);
    }

    #[test]
    fn sparse_single_node_is_identity() {
        let s = SparseVec::from_pairs(9, vec![(2, 1.0), (7, -3.0)]);
        let (out, levels) = tree_sum_sparse(&[s.clone()]);
        assert!(levels.is_empty());
        assert_eq!(out.into_dense(), s.to_dense());
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn sparse_rejects_ragged() {
        tree_sum_sparse(&[SparseVec::new(3), SparseVec::new(4)]);
    }
}
