//! Per-node state: the shard of examples node p owns (the paper's I_p).

use crate::linalg::Csr;

#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Csr,
    pub y: Vec<f64>,
}

impl Shard {
    pub fn n_examples(&self) -> usize {
        self.y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_counts() {
        let s = Shard {
            x: Csr::from_rows(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]),
            y: vec![1.0, -1.0],
        };
        assert_eq!(s.n_examples(), 2);
    }
}
