//! Per-node state: the shard of examples node p owns (the paper's I_p),
//! stored in *compact support coordinates*: the CSR's column ids are
//! local positions `0..support.len()` and [`SupportMap`] is the
//! local↔global dictionary. Every per-node phase (gradient sweeps,
//! inner solves, Hessian products, margin matvecs) runs on
//! |support|-length buffers; global size-d vectors are gathered onto
//! the support at phase entry and results scatter back as sparse
//! index/value payloads.

use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::linalg::Csr;

#[derive(Clone, Debug)]
pub struct Shard {
    /// shard examples with columns remapped to local ids
    /// `0..map.support.len()` — built once at partition time
    pub xl: Csr,
    pub y: Vec<f64>,
    /// sorted unique global columns this shard touches (the compact
    /// coordinate dictionary)
    pub map: SupportMap,
    /// global feature dimension d
    pub dim: usize,
    /// position of each support column inside the cluster's *union*
    /// support U (strictly increasing; filled by `Cluster::partition`
    /// via `SupportMap::positions_of`) — the local↔U translation every
    /// compact-master phase gathers/scatters through
    pub upos: Vec<u32>,
}

impl Shard {
    /// Build from a global-column sub-matrix (remaps and drops it).
    /// `upos` stays empty until the owning cluster composes the shard
    /// support into its union map.
    pub fn new(x: Csr, y: Vec<f64>) -> Shard {
        let dim = x.n_cols;
        let (map, xl) = SupportMap::compact(&x);
        Shard { xl, y, map, dim, upos: Vec::new() }
    }

    /// Gather the master iterate onto the shard support. The master
    /// frame is either the full-d dense vector (gather through the
    /// global support columns) or the length-|U| compact vector
    /// (gather through the composed U positions) — same values either
    /// way, which is what keeps the two masters ε-identical.
    pub fn gather_frame(&self, compact: bool, v: &[f64], out: &mut Vec<f64>) {
        if compact {
            debug_assert_eq!(self.upos.len(), self.map.len());
            out.clear();
            out.extend(self.upos.iter().map(|&p| v[p as usize]));
        } else {
            self.map.gather(v, out);
        }
    }

    /// Support-aligned values as a [`SparseVec`] in the master frame:
    /// global column indices over dim d (dense master) or U positions
    /// over dim |U| (compact master). The index sets are related by a
    /// monotone bijection, so tree merges produce bit-identical sums.
    pub fn support_sparse(
        &self,
        compact: bool,
        fdim: usize,
        vals: &[f64],
    ) -> SparseVec {
        if compact {
            debug_assert_eq!(vals.len(), self.upos.len());
            SparseVec { dim: fdim, idx: self.upos.clone(), val: vals.to_vec() }
        } else {
            self.map.to_sparse_aligned(fdim, vals)
        }
    }

    /// The index dictionary direction corrections use in the given
    /// master frame (see [`Self::support_sparse`]).
    pub fn dir_idx(&self, compact: bool) -> &[u32] {
        if compact { &self.upos } else { &self.map.support }
    }

    pub fn n_examples(&self) -> usize {
        self.y.len()
    }

    /// Row i in global coordinates (tests / stitching diagnostics).
    pub fn row_global(&self, i: usize) -> Vec<(u32, f32)> {
        let (cols, vals) = self.xl.row(i);
        cols.iter()
            .zip(vals)
            .map(|(&c, &v)| (self.map.support[c as usize], v))
            .collect()
    }

    /// Rebuild the global-column matrix of this shard — the
    /// single-machine oracle tests compare the compact pipeline against.
    pub fn stitch(&self, dim: usize) -> Csr {
        let rows: Vec<Vec<(u32, f32)>> =
            (0..self.xl.n_rows()).map(|i| self.row_global(i)).collect();
        Csr::from_rows(dim, &rows)
    }

    /// Fraction of the `dim` feature columns this shard's examples
    /// touch.
    pub fn support_density(&self, dim: usize) -> f64 {
        self.map.density(dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_counts_and_support() {
        let s = Shard::new(
            Csr::from_rows(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]),
            vec![1.0, -1.0],
        );
        assert_eq!(s.n_examples(), 2);
        assert_eq!(s.dim, 3);
        assert_eq!(s.map.support, vec![0, 2]);
        // compact storage: two columns, local ids
        assert_eq!(s.xl.n_cols, 2);
        assert_eq!(s.xl.row(0).0, &[0]);
        assert_eq!(s.xl.row(1).0, &[1]);
        assert_eq!(s.row_global(1), vec![(2, 2.0)]);
        assert!((s.support_density(3) - 2.0 / 3.0).abs() < 1e-15);
    }
}
