//! Per-node state: the shard of examples node p owns (the paper's I_p),
//! plus the column-support index the sparse gradient pipeline uses.

use crate::linalg::sparse::SupportMap;
use crate::linalg::Csr;

#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Csr,
    pub y: Vec<f64>,
    /// sorted unique columns this shard touches + per-nnz positions —
    /// built once at partition time, reused by every sparse gradient
    /// pass
    pub map: SupportMap,
}

impl Shard {
    pub fn new(x: Csr, y: Vec<f64>) -> Shard {
        let map = SupportMap::build(&x);
        Shard { x, y, map }
    }

    pub fn n_examples(&self) -> usize {
        self.y.len()
    }

    /// Fraction of the `dim` feature columns this shard's examples
    /// touch.
    pub fn support_density(&self, dim: usize) -> f64 {
        self.map.density(dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_counts_and_support() {
        let s = Shard::new(
            Csr::from_rows(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]),
            vec![1.0, -1.0],
        );
        assert_eq!(s.n_examples(), 2);
        assert_eq!(s.map.support, vec![0, 2]);
        assert!((s.support_density(3) - 2.0 / 3.0).abs() < 1e-15);
    }
}
