//! Event-driven cluster execution engine: one virtual clock per node.
//!
//! The legacy timing model was a flat accumulator — every phase added
//! `max(per-node seconds)` or `hops × cost` to a single global clock,
//! which cannot express heterogeneous nodes, partial straggler hiding
//! inside the reduction tree, or overlap of local solves with an
//! in-flight reduction. This module replaces it with an explicit
//! schedule:
//!
//! - **Per-node virtual clocks.** Every compute phase advances node
//!   p's clock by its own measured seconds × the node's
//!   [`NodeProfile`] speed. In the default *barrier schedule* each
//!   phase ends with a global barrier, so the makespan reproduces the
//!   legacy flat accumulator exactly (the equivalence regression in
//!   `tests/engine.rs` pins this); in pipelined mode nodes are
//!   *self-paced* — a node's next phase starts the moment its
//!   previous one ends.
//! - **Event-driven reductions.** A reduction-tree parent hop starts
//!   at `max(children ready)` rather than after a global barrier, so
//!   when leaves inject at different times (pipelined runs, direct
//!   engine use) fast subtrees climb the tree while slow ones still
//!   compute, and an odd-tail node joins the tree one level up with
//!   no leaf hop.
//! - **Two lanes.** Results land either on the *node lane* (an
//!   allreduce whose output feeds the next node-local compute — the
//!   gradient round) or on the *control lane* (a master-side chain:
//!   safeguard scalars, direction broadcast, line-search rounds). In
//!   pipelined mode ([`Engine::pipeline`]) control-lane traffic no
//!   longer stalls the node clocks: round r's direction allreduce and
//!   line search overlap round r+1's sweeps/solves on the self-paced
//!   nodes, and the safeguard consumes the reduced direction when it
//!   lands on the control clock. The arithmetic of the simulated run
//!   is unchanged — pipelining is a *schedule* (the optimistic-overlap
//!   bound of the async-parallel SGD literature, arXiv:1505.04956 /
//!   1705.08030); objective traces are bit-identical either way.
//!
//! - **Solver lanes and quorum collection (async FS).** The
//!   bounded-staleness driver ([`crate::algo::async_fs`]) runs each
//!   node's local solves on a per-node *solver lane* it schedules
//!   itself ([`Engine::solver_event`] records them), while the node's
//!   main lane keeps doing gradient sweeps and line-search scalars.
//!   The direction combine becomes an **arrival-time-ordered quorum
//!   reduction** ([`Engine::quorum_reduce`]): combining-tree leaves
//!   inject at each contribution's solver-lane completion time instead
//!   of the node clocks, `async_arrival` events carry the staleness
//!   (in outer rounds) each combined contribution had, and the
//!   committed direction gates the main lanes only.
//!
//! Every phase is recorded as a timed [`Event`] (capped; see
//! [`Engine::dropped_events`]) and exported as a JSON timeline via
//! [`Engine::timeline_json`] for benches and plots
//! (`psgd train --trace-timeline out.json`). The export shape is
//! `{makespan, nodes, pipeline, profile[], dropped_events,
//! events[{label, node, level, start, end, staleness}]}` —
//! `tests/engine.rs` pins it.

use crate::util::json::Value;
use crate::util::rng::Rng;

/// Per-node relative compute speed — the one straggler/heterogeneity
/// surface. `speed[p]` multiplies node p's measured compute seconds:
/// 1.0 = this machine's single core, 3.0 = a node three times slower.
/// The global `CostModel::compute_scale` still applies on top (so
/// `CostModel::free()` keeps costing nothing).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeProfile {
    pub speed: Vec<f64>,
}

impl NodeProfile {
    /// Every node identical to the reference machine.
    pub fn homogeneous(n: usize) -> NodeProfile {
        NodeProfile { speed: vec![1.0; n] }
    }

    /// Seeded heterogeneous cluster: `speed[p] = 1 + spread·u_p` with
    /// `u_p ~ U[0,1)` from the deterministic stream — the reproducible
    /// way to model a skewed fleet.
    pub fn seeded(n: usize, seed: u64, spread: f64) -> NodeProfile {
        let mut rng = Rng::new(seed ^ 0xC1A5_7E12_9B1D_F00D);
        NodeProfile {
            speed: (0..n).map(|_| 1.0 + spread * rng.uniform()).collect(),
        }
    }

    /// Homogeneous except one straggler running `factor`× slower — the
    /// canonical failure-injection scenario.
    pub fn with_straggler(n: usize, node: usize, factor: f64) -> NodeProfile {
        let mut p = NodeProfile::homogeneous(n);
        if node < n {
            p.speed[node] = factor;
        }
        p
    }

    /// Node p's speed multiplier (1.0 past the profile's end, so a
    /// profile of the wrong length degrades gracefully).
    #[inline]
    pub fn scale(&self, p: usize) -> f64 {
        self.speed.get(p).copied().unwrap_or(1.0)
    }

    pub fn is_homogeneous(&self) -> bool {
        self.speed.iter().all(|&s| s == 1.0)
    }
}

/// Where a reduction's result lands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The result feeds the next node-local compute (gradient
    /// allreduce): node clocks advance to the arrival time.
    Node,
    /// The result feeds the master-side control chain (direction
    /// combine, safeguard, line search): only the control clock
    /// advances, nodes keep computing. Callers request this lane and
    /// the engine honors it only in pipelined mode — otherwise it
    /// falls back to [`Lane::Node`] semantics, which is exactly the
    /// barrier schedule.
    Control,
}

/// One timed entry of the schedule.
#[derive(Clone, Debug)]
pub struct Event {
    /// phase tag: "compute", "local_solve", "grad_sweep", "reduce",
    /// "broadcast", "scalar_round", "ring", "async_solve",
    /// "async_arrival", ...
    pub label: &'static str,
    /// owning node for compute events; None for tree/control events
    pub node: Option<usize>,
    /// reduction-tree level for hop events (0 = leaf level)
    pub level: Option<usize>,
    pub start: f64,
    pub end: f64,
    /// how many outer rounds old the contribution behind this event
    /// was when the master combined it (async FS quorum arrivals:
    /// 0 = fresh). None for ordinary schedule events.
    pub staleness: Option<usize>,
}

/// Cost of one scheduled tree hop under the link layer, decided by
/// the cluster's per-edge closure (profile multipliers, congestion,
/// and the timeout/retry/backoff ladder). The closure is pure in
/// `(round, level, sender)` so one seed replays the same outcomes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HopOutcome {
    /// total virtual seconds the hop occupies: base × multipliers,
    /// plus any backoff ladder, plus the reroute detour when the edge
    /// was abandoned
    pub secs: f64,
    /// the share of `secs` spent waiting on timeout/backoff rungs —
    /// attributed to the ledger's `retry_seconds`, never to
    /// `comm_seconds`
    pub retry_secs: f64,
    /// the edge died past the retry budget and the payload re-parented
    /// one level up (the engine records a `reroute` span for it)
    pub rerouted: bool,
}

/// Flat-component totals of one linked tree climb: per level, the
/// slowest pair's cost split into its wire share and its
/// timeout/backoff share — what the ledger charges to `comm_seconds`
/// and `retry_seconds` respectively (the barrier-equivalent serial
/// chain up the tree, link-weather edition).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkTotals {
    pub comm_secs: f64,
    pub retry_secs: f64,
}

/// Hard cap on recorded events so multi-thousand-round runs cannot
/// grow memory without bound; past it only clocks advance and
/// [`Engine::dropped_events`] counts the overflow.
const MAX_EVENTS: usize = 1 << 18;

/// Audit-mode invariant: virtual clocks only ever advance. A landing
/// time before the floor it chained from means a negative hop/duration
/// snuck into the schedule — the "clock ran backwards" class of bug the
/// `audit` feature exists to catch at the source.
#[cfg(feature = "audit")]
fn audit_clock_advances(before: f64, after: f64, what: &str) {
    assert!(
        after >= before,
        "engine clock ran backwards in {what}: {before} -> {after}"
    );
}

#[derive(Clone, Debug)]
pub struct Engine {
    pub profile: NodeProfile,
    /// pipelined schedule: control-lane ops overlap node compute
    pub pipeline: bool,
    /// when node p's current work finishes (virtual seconds)
    node_clock: Vec<f64>,
    /// when the master/control chain is free
    control_clock: f64,
    events: Vec<Event>,
    dropped_events: usize,
    /// label the next compute phase's events carry (set by drivers via
    /// [`Engine::set_phase`]; consumed once)
    next_label: Option<&'static str>,
    /// count of scheduled comm operations (tree/quorum reduces,
    /// broadcasts, ring traversals, scalar rounds) — the audit layer
    /// pairs every ledger byte charge against this, so no wire crossing
    /// can be charged without a matching engine event. Unlike
    /// `events`, marks are never capped or dropped.
    comm_marks: usize,
}

impl Engine {
    pub fn new(profile: NodeProfile) -> Engine {
        let n = profile.speed.len();
        Engine {
            profile,
            pipeline: false,
            node_clock: vec![0.0; n],
            control_clock: 0.0,
            events: Vec::new(),
            dropped_events: 0,
            next_label: None,
            comm_marks: 0,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.node_clock.len()
    }

    /// The full node set — what every legacy (membership-unaware)
    /// entry point delegates with, so the zero-fault schedule is the
    /// members schedule with `members = 0..n` by construction.
    fn all_members(&self) -> Vec<usize> {
        (0..self.node_clock.len()).collect()
    }

    /// The simulated wall clock: when the last node AND the control
    /// chain are done — the critical path of the whole schedule.
    pub fn makespan(&self) -> f64 {
        self.node_clock
            .iter()
            .fold(self.control_clock, |a, &c| a.max(c))
    }

    /// Tag the next compute phase's events (e.g. "local_solve").
    pub fn set_phase(&mut self, label: &'static str) {
        self.next_label = Some(label);
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn dropped_events(&self) -> usize {
        self.dropped_events
    }

    /// How many comm operations have been scheduled on the engine.
    /// The [`Cluster`](super::Cluster) audit asserts compare this
    /// before/after each ledger byte charge.
    pub fn comm_marks(&self) -> usize {
        self.comm_marks
    }

    /// Publish the engine-level run gauges into an ordered
    /// [`Registry`](crate::obs::Registry) — same render path as the
    /// ledger profiles.
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        reg.gauge("makespan", self.makespan(), 3, "s");
        reg.counter("events", self.events.len() as u64);
        reg.counter("dropped_events", self.dropped_events as u64);
        reg.counter("comm_marks", self.comm_marks as u64);
    }

    fn push_event(&mut self, ev: Event) {
        if self.events.len() < MAX_EVENTS {
            self.events.push(ev);
        } else {
            self.dropped_events += 1;
        }
    }

    /// Per-node compute phase: node p runs for
    /// `times[p]·scale·profile[p]` starting at its own clock. In the
    /// barrier schedule (pipelining off) the phase ends with a global
    /// barrier — exactly the legacy flat accumulator; in pipelined
    /// mode nodes stay self-paced and only reductions/broadcasts gate
    /// them. Returns the barrier-equivalent charge (max scaled
    /// duration) for the ledger's legacy component breakdown.
    pub fn compute(&mut self, scale: f64, times: &[f64]) -> f64 {
        debug_assert_eq!(times.len(), self.node_clock.len());
        let members = self.all_members();
        self.compute_members(scale, &members, times)
    }

    /// Membership-aware compute phase: `times[i]` is member
    /// `members[i]`'s measured seconds; nodes outside `members` (dead
    /// or flapped out of the round) are untouched — their clocks stay
    /// frozen where the fault left them, and the barrier only gates
    /// the members. With `members = 0..n` this IS [`Engine::compute`].
    pub fn compute_members(
        &mut self,
        scale: f64,
        members: &[usize],
        times: &[f64],
    ) -> f64 {
        debug_assert_eq!(times.len(), members.len());
        let label = self.next_label.take().unwrap_or("compute");
        let mut max_dur = 0.0f64;
        let mut max_end = 0.0f64;
        for (&p, &t) in members.iter().zip(times.iter()) {
            let dur = t * scale * self.profile.scale(p);
            #[cfg(feature = "audit")]
            assert!(
                dur >= 0.0,
                "negative compute duration {dur} on node {p}"
            );
            max_dur = max_dur.max(dur);
            let start = self.node_clock[p];
            self.node_clock[p] = start + dur;
            max_end = max_end.max(start + dur);
            self.push_event(Event {
                label,
                node: Some(p),
                level: None,
                start,
                end: start + dur,
                staleness: None,
            });
        }
        if !self.pipeline {
            for &p in members {
                let c = &mut self.node_clock[p];
                *c = (*c).max(max_end);
            }
        }
        max_dur
    }

    /// Control-lane compute (pipelined mode only — callers fall back
    /// to [`Engine::compute`] otherwise): the whole phase rides the
    /// master chain, nodes are not stalled. Used for the tiny
    /// direction-margin matvec and line-search evaluations, which
    /// briefly preempt the workers in a real async pipeline. Returns
    /// the charged duration.
    pub fn compute_control(&mut self, scale: f64, times: &[f64]) -> f64 {
        debug_assert_eq!(times.len(), self.node_clock.len());
        let members = self.all_members();
        self.compute_control_members(scale, &members, times)
    }

    /// Membership-aware control-lane compute: `times[i]` is member
    /// `members[i]`'s measured seconds, scaled by *that node's* speed
    /// (position in the subset is not a node id). Nodes are never
    /// stalled on this lane, so non-members need no special casing —
    /// they simply contribute no duration. With `members = 0..n` this
    /// IS [`Engine::compute_control`].
    pub fn compute_control_members(
        &mut self,
        scale: f64,
        members: &[usize],
        times: &[f64],
    ) -> f64 {
        debug_assert_eq!(times.len(), members.len());
        let label = self.next_label.take().unwrap_or("compute");
        let dur = members
            .iter()
            .zip(times.iter())
            .map(|(&p, &t)| t * scale * self.profile.scale(p))
            .fold(0.0f64, f64::max);
        let start = self.control_clock;
        #[cfg(feature = "audit")]
        audit_clock_advances(start, start + dur, "compute_control");
        self.control_clock = start + dur;
        self.push_event(Event {
            label,
            node: None,
            level: None,
            start,
            end: start + dur,
            staleness: None,
        });
        dur
    }

    /// Event-driven binary-tree reduction. Leaf p injects at
    /// `max(node_clock[p], control_clock)` (a round can only combine
    /// after the previous one committed — information never flows
    /// backward); a parent at level ℓ is ready at
    /// `max(children) + hops[ℓ]`. `down = Some((depth, hop))` appends
    /// the result broadcast. Landing follows `lane` (see [`Lane`];
    /// [`Lane::Control`] only takes effect in pipelined mode).
    /// Returns the time the result is available on its lane.
    pub fn tree_reduce(
        &mut self,
        label: &'static str,
        hops: &[f64],
        down: Option<(usize, f64)>,
        lane: Lane,
    ) -> f64 {
        let members = self.all_members();
        self.tree_reduce_members(label, hops, down, lane, &members)
    }

    /// Membership-aware tree reduce: only `members` contribute leaves
    /// and only their main lanes are gated by the landing — a dead
    /// node's frozen clock neither feeds the tree nor waits on it.
    /// With `members = 0..n` this IS [`Engine::tree_reduce`].
    pub fn tree_reduce_members(
        &mut self,
        label: &'static str,
        hops: &[f64],
        down: Option<(usize, f64)>,
        lane: Lane,
        members: &[usize],
    ) -> f64 {
        self.comm_marks += 1;
        #[cfg(feature = "audit")]
        let span0 = members
            .iter()
            .fold(self.control_clock, |a, &p| a.max(self.node_clock[p]));
        let floor = self.control_clock;
        let ready: Vec<f64> = members
            .iter()
            .map(|&p| self.node_clock[p].max(floor))
            .collect();
        let root = self.climb(label, ready, hops);
        let landed = self.descend(root, down);
        // every member leaf injects at or after its clock, so a landing
        // time before the members' pre-reduce span means a hop ran
        // backwards (dead nodes' frozen clocks are excluded on purpose:
        // a node that crashed mid-solve can sit ahead of the quorum)
        #[cfg(feature = "audit")]
        audit_clock_advances(span0, landed, "tree_reduce");
        self.control_clock = self.control_clock.max(landed);
        if !(self.pipeline && lane == Lane::Control) {
            // barrier schedule: every member waits for the landing time
            // (in the synchronous algorithm nothing can proceed until
            // the result is committed — this is what makes the
            // homogeneous schedule collapse to the legacy flat sum
            // exactly). Straggler hiding still happens INSIDE the
            // tree via the max(children) hop starts.
            for &p in members {
                let c = &mut self.node_clock[p];
                *c = (*c).max(landed);
            }
        }
        landed
    }

    /// The pairing loop shared by [`Self::tree_reduce`] and
    /// [`Self::quorum_reduce`]: climb a binary combining tree whose
    /// leaves become ready at the given times; a parent at level ℓ is
    /// ready at `max(children) + hops[ℓ]`, an odd tail joins one level
    /// up with no hop. Returns the root-ready time and records one
    /// event per level.
    fn climb(
        &mut self,
        label: &'static str,
        mut ready: Vec<f64>,
        hops: &[f64],
    ) -> f64 {
        let fallback = self.control_clock;
        let mut level = 0usize;
        while ready.len() > 1 {
            let hop = hops.get(level).copied().unwrap_or(0.0);
            let mut next = Vec::with_capacity(ready.len().div_ceil(2));
            let mut start = f64::INFINITY;
            let mut end = 0.0f64;
            let mut it = ready.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => {
                        let s = a.max(b);
                        let t = s + hop;
                        start = start.min(s);
                        end = end.max(t);
                        next.push(t);
                    }
                    // odd tail: joins the tree one level up, no hop
                    None => next.push(a),
                }
            }
            if start.is_finite() {
                self.push_event(Event {
                    label,
                    node: None,
                    level: Some(level),
                    start,
                    end,
                    staleness: None,
                });
            }
            ready = next;
            level += 1;
        }
        ready.first().copied().unwrap_or(fallback)
    }

    /// Link-aware variant of [`Self::climb`]: every pair merge asks
    /// the `link` closure what its hop costs, keyed by the tree level
    /// and the *sending subtree's representative* (the right child's
    /// leaf-level node — the physical uplink the merged payload rides;
    /// the parent keeps the left child's representative, and an odd
    /// tail carries its representative one level up untouched). With
    /// the identity closure (`secs = base`) this reproduces
    /// [`Self::climb`] exactly — `tests` pin that. Rerouted hops get
    /// their own `reroute` span on the timeline, and `totals`
    /// accumulates the per-level critical pair's wire/retry split.
    fn climb_linked(
        &mut self,
        label: &'static str,
        mut ready: Vec<f64>,
        mut reps: Vec<usize>,
        hops: &[f64],
        link: &mut dyn FnMut(usize, usize, f64) -> HopOutcome,
        totals: &mut LinkTotals,
    ) -> f64 {
        debug_assert_eq!(ready.len(), reps.len());
        let fallback = self.control_clock;
        let mut level = 0usize;
        while ready.len() > 1 {
            let base = hops.get(level).copied().unwrap_or(0.0);
            let mut next = Vec::with_capacity(ready.len().div_ceil(2));
            let mut next_reps = Vec::with_capacity(ready.len().div_ceil(2));
            let mut start = f64::INFINITY;
            let mut end = 0.0f64;
            let mut crit = HopOutcome::default();
            let mut i = 0usize;
            while i < ready.len() {
                if i + 1 < ready.len() {
                    let sender = reps[i + 1];
                    let out = link(level, sender, base);
                    #[cfg(feature = "audit")]
                    assert!(
                        out.secs >= out.retry_secs && out.retry_secs >= 0.0,
                        "bad link hop outcome at level {level}: {out:?}"
                    );
                    let s = ready[i].max(ready[i + 1]);
                    let t = s + out.secs;
                    if out.rerouted {
                        self.push_event(Event {
                            label: "reroute",
                            node: Some(sender),
                            level: Some(level),
                            start: s,
                            end: t,
                            staleness: None,
                        });
                    }
                    if out.secs > crit.secs {
                        crit = out;
                    }
                    start = start.min(s);
                    end = end.max(t);
                    next.push(t);
                    next_reps.push(reps[i]);
                } else {
                    // odd tail: joins the tree one level up, no hop
                    next.push(ready[i]);
                    next_reps.push(reps[i]);
                }
                i += 2;
            }
            if start.is_finite() {
                self.push_event(Event {
                    label,
                    node: None,
                    level: Some(level),
                    start,
                    end,
                    staleness: None,
                });
            }
            totals.comm_secs += crit.secs - crit.retry_secs;
            totals.retry_secs += crit.retry_secs;
            ready = next;
            reps = next_reps;
            level += 1;
        }
        ready.first().copied().unwrap_or(fallback)
    }

    /// Link-aware membership tree reduce: identical schedule semantics
    /// to [`Self::tree_reduce_members`], but every pair merge is
    /// costed by the `link` closure (see [`Self::climb_linked`]).
    /// Returns the landing time plus the flat wire/retry split for the
    /// ledger.
    pub fn tree_reduce_linked_members(
        &mut self,
        label: &'static str,
        hops: &[f64],
        down: Option<(usize, f64)>,
        lane: Lane,
        members: &[usize],
        link: &mut dyn FnMut(usize, usize, f64) -> HopOutcome,
    ) -> (f64, LinkTotals) {
        self.comm_marks += 1;
        #[cfg(feature = "audit")]
        let span0 = members
            .iter()
            .fold(self.control_clock, |a, &p| a.max(self.node_clock[p]));
        let floor = self.control_clock;
        let ready: Vec<f64> = members
            .iter()
            .map(|&p| self.node_clock[p].max(floor))
            .collect();
        let mut totals = LinkTotals::default();
        let root = self.climb_linked(
            label,
            ready,
            members.to_vec(),
            hops,
            link,
            &mut totals,
        );
        let landed = self.descend(root, down);
        #[cfg(feature = "audit")]
        audit_clock_advances(span0, landed, "tree_reduce_linked");
        self.control_clock = self.control_clock.max(landed);
        if !(self.pipeline && lane == Lane::Control) {
            for &p in members {
                let c = &mut self.node_clock[p];
                *c = (*c).max(landed);
            }
        }
        (landed, totals)
    }

    /// Link-aware quorum reduction: identical schedule semantics to
    /// [`Self::quorum_reduce_members`], with every pair merge costed
    /// by the `link` closure keyed to the contributing node's uplink.
    /// Returns the landing time plus the flat wire/retry split.
    pub fn quorum_reduce_linked_members(
        &mut self,
        label: &'static str,
        arrivals: &[(usize, f64, usize)],
        hops: &[f64],
        down: Option<(usize, f64)>,
        members: &[usize],
        link: &mut dyn FnMut(usize, usize, f64) -> HopOutcome,
    ) -> (f64, LinkTotals) {
        self.comm_marks += 1;
        let floor = self.control_clock;
        for &(node, ready, staleness) in arrivals {
            self.push_event(Event {
                label: "async_arrival",
                node: Some(node),
                level: None,
                start: ready,
                end: ready.max(floor),
                staleness: Some(staleness),
            });
        }
        let ready: Vec<f64> =
            arrivals.iter().map(|&(_, t, _)| t.max(floor)).collect();
        let reps: Vec<usize> = arrivals.iter().map(|&(n, _, _)| n).collect();
        let mut totals = LinkTotals::default();
        let root =
            self.climb_linked(label, ready, reps, hops, link, &mut totals);
        let landed = self.descend(root, down);
        #[cfg(feature = "audit")]
        audit_clock_advances(floor, landed, "quorum_reduce_linked");
        self.control_clock = self.control_clock.max(landed);
        for &p in members {
            let c = &mut self.node_clock[p];
            *c = (*c).max(landed);
        }
        (landed, totals)
    }

    /// Optional result broadcast below a combining-tree root.
    fn descend(&mut self, root: f64, down: Option<(usize, f64)>) -> f64 {
        match down {
            Some((depth, hop)) => {
                let arrival = root + depth as f64 * hop;
                if depth > 0 {
                    self.push_event(Event {
                        label: "broadcast",
                        node: None,
                        level: None,
                        start: root,
                        end: arrival,
                        staleness: None,
                    });
                }
                arrival
            }
            None => root,
        }
    }

    /// Record one asynchronously-scheduled local solve on node p's
    /// *solver lane*. Solver lanes are the async FS driver's own
    /// bookkeeping (a node's solver grinds on while its main lane
    /// does gradient sweeps and line-search scalars); the engine only
    /// records the event for the timeline — no clock is touched.
    pub fn solver_event(
        &mut self,
        label: &'static str,
        node: usize,
        start: f64,
        end: f64,
    ) {
        self.push_event(Event {
            label,
            node: Some(node),
            level: None,
            start,
            end,
            staleness: None,
        });
    }

    /// Arrival-time-ordered quorum reduction on the control lane — the
    /// async FS direction combine. Each entry of `arrivals` is one
    /// contribution `(node, ready, staleness)`: leaf i of the combining
    /// tree injects at `ready` (a solver-lane completion, or the round
    /// start for an already-delivered stale hybrid) rather than at the
    /// node clocks, and one `async_arrival` event per contribution
    /// records the staleness the master combined at. The committed
    /// result gates every node's *main* lane (nodes need dʳ for the
    /// line search) — solver lanes stay self-paced. Returns the time
    /// the combined result lands.
    pub fn quorum_reduce(
        &mut self,
        label: &'static str,
        arrivals: &[(usize, f64, usize)],
        hops: &[f64],
        down: Option<(usize, f64)>,
    ) -> f64 {
        let members = self.all_members();
        self.quorum_reduce_members(label, arrivals, hops, down, &members)
    }

    /// Membership-aware quorum reduction: the committed direction gates
    /// only the members' main lanes — a dead node's clock stays frozen
    /// at its crash point (it re-syncs through the rejoin re-base, not
    /// through a reduce it never saw). With `members = 0..n` this IS
    /// [`Engine::quorum_reduce`].
    pub fn quorum_reduce_members(
        &mut self,
        label: &'static str,
        arrivals: &[(usize, f64, usize)],
        hops: &[f64],
        down: Option<(usize, f64)>,
        members: &[usize],
    ) -> f64 {
        self.comm_marks += 1;
        let floor = self.control_clock;
        for &(node, ready, staleness) in arrivals {
            self.push_event(Event {
                label: "async_arrival",
                node: Some(node),
                level: None,
                start: ready,
                end: ready.max(floor),
                staleness: Some(staleness),
            });
        }
        let ready: Vec<f64> =
            arrivals.iter().map(|&(_, t, _)| t.max(floor)).collect();
        let root = self.climb(label, ready, hops);
        let landed = self.descend(root, down);
        // every leaf is floored at the control clock (a round combines
        // only after the previous one committed), so a landing time
        // before that floor means a quorum hop ran backwards
        #[cfg(feature = "audit")]
        audit_clock_advances(floor, landed, "quorum_reduce");
        self.control_clock = self.control_clock.max(landed);
        for &p in members {
            let c = &mut self.node_clock[p];
            *c = (*c).max(landed);
        }
        landed
    }

    /// Master → nodes broadcast (no preceding reduce): starts when the
    /// control chain holds the payload, arrives `depth·hop` later and
    /// gates the node clocks. In the barrier schedule the send also
    /// waits for every node (the serial flat model — otherwise a
    /// broadcast issued right after a compute-only phase would hide
    /// entirely behind stale node clocks and underreport the
    /// makespan); in pipelined mode it is a pure control-lane op.
    pub fn broadcast(&mut self, depth: usize, hop: f64) -> f64 {
        let members = self.all_members();
        self.broadcast_members(depth, hop, &members)
    }

    /// Membership-aware broadcast: the barrier start and the arrival
    /// gate consider only `members` — dead nodes neither delay the
    /// send nor advance on it. With `members = 0..n` this IS
    /// [`Engine::broadcast`].
    pub fn broadcast_members(
        &mut self,
        depth: usize,
        hop: f64,
        members: &[usize],
    ) -> f64 {
        self.comm_marks += 1;
        let span = members
            .iter()
            .fold(self.control_clock, |a, &p| a.max(self.node_clock[p]));
        let start = if self.pipeline { self.control_clock } else { span };
        let arrival = start + depth as f64 * hop;
        #[cfg(feature = "audit")]
        audit_clock_advances(span.min(start), arrival, "broadcast");
        if depth > 0 {
            self.push_event(Event {
                label: "broadcast",
                node: None,
                level: None,
                start,
                end: arrival,
                staleness: None,
            });
        }
        self.control_clock = arrival;
        for &p in members {
            let c = &mut self.node_clock[p];
            *c = (*c).max(arrival);
        }
        arrival
    }

    /// Ring traversal(s): every node participates in every chunk hop,
    /// so the ring is inherently a barrier — it starts once all nodes
    /// (and the control chain) are ready and synchronizes everyone at
    /// the end. Pipelined overlap therefore only hides ring traffic
    /// behind nothing; the pipeline bench runs on the Tree topology.
    pub fn ring_traversal(&mut self, label: &'static str, secs: f64) -> f64 {
        self.comm_marks += 1;
        let start = self.makespan();
        let end = start + secs;
        #[cfg(feature = "audit")]
        audit_clock_advances(start, end, "ring_traversal");
        if secs > 0.0 {
            self.push_event(Event {
                label,
                node: None,
                level: None,
                start,
                end,
                staleness: None,
            });
        }
        self.control_clock = end;
        for c in self.node_clock.iter_mut() {
            *c = (*c).max(end);
        }
        end
    }

    /// Scalar aggregation round: up-sweep + down-sweep of `depth`
    /// latency-sized hops each. Control-lane in pipelined mode (line
    /// searches and coefficient rounds are the control plane).
    pub fn scalar_round(&mut self, depth: usize, hop: f64) -> f64 {
        let hops = vec![hop; depth];
        self.tree_reduce(
            "scalar_round",
            &hops,
            Some((depth, hop)),
            Lane::Control,
        )
    }

    /// Membership-aware scalar round (see [`Engine::scalar_round`]).
    pub fn scalar_round_members(
        &mut self,
        depth: usize,
        hop: f64,
        members: &[usize],
    ) -> f64 {
        let hops = vec![hop; depth];
        self.tree_reduce_members(
            "scalar_round",
            &hops,
            Some((depth, hop)),
            Lane::Control,
            members,
        )
    }

    // ---- fault-injection hooks (see `cluster/faults.rs`) ----------

    /// In-place speed change for one node (mid-run compute/link
    /// degradation). Unlike swapping the whole [`NodeProfile`] via
    /// `Cluster::set_profile`, this does NOT reset any clock — the node
    /// simply runs at the new speed from its current virtual time on.
    pub fn set_speed(&mut self, node: usize, speed: f64) {
        if let Some(s) = self.profile.speed.get_mut(node) {
            *s = speed;
        }
    }

    /// When node p's main lane is next free (its virtual clock).
    pub fn node_ready(&self, node: usize) -> f64 {
        self.node_clock.get(node).copied().unwrap_or(0.0)
    }

    /// Advance node p's clock to at least `t` — a revived node cannot
    /// do work in its own past, so rejoin pulls its frozen clock
    /// forward to the recovery completion time. Never moves a clock
    /// backwards.
    pub fn hold_node_until(&mut self, node: usize, t: f64) {
        if let Some(c) = self.node_clock.get_mut(node) {
            *c = c.max(t);
        }
    }

    /// Zero-duration fault marker on the timeline ("fault_crash",
    /// "fault_restart", "fault_degrade", "fault_flap", "fault_drop").
    /// Pure record — clocks and membership are the caller's job.
    pub fn fault_event(&mut self, label: &'static str, node: usize, at: f64) {
        self.push_event(Event {
            label,
            node: Some(node),
            level: None,
            start: at,
            end: at,
            staleness: None,
        });
    }

    /// Master → one node unicast (rejoin state transfer): the payload
    /// leaves the control chain at `at`, lands `secs` later on node
    /// `node`'s clock only. Counts as a comm operation so the ledger
    /// pairing audit sees the wire crossing.
    pub fn unicast(
        &mut self,
        label: &'static str,
        node: usize,
        at: f64,
        secs: f64,
    ) -> f64 {
        self.comm_marks += 1;
        let end = at + secs;
        #[cfg(feature = "audit")]
        audit_clock_advances(at, end, "unicast");
        self.push_event(Event {
            label,
            node: Some(node),
            level: None,
            start: at,
            end,
            staleness: None,
        });
        self.control_clock = self.control_clock.max(at);
        if let Some(c) = self.node_clock.get_mut(node) {
            *c = c.max(end);
        }
        end
    }

    /// Export the recorded schedule for plots/benches.
    ///
    /// **Check `dropped_events` before trusting `events`.** The ring
    /// caps at [`MAX_EVENTS`] (2¹⁸) records; past the cap the clocks
    /// stay exact but further events are *silently absent from
    /// `events[]`* — `dropped_events` in the exported JSON counts
    /// exactly how many. A truncated timeline looks complete (it ends
    /// mid-schedule with no marker), so any consumer plotting or
    /// diffing `events[]` must treat `dropped_events > 0` as "this is
    /// a prefix, not the run". This exporter also warns on stderr in
    /// that case so an interactive `--trace-timeline` can't silently
    /// pass a prefix off as the full schedule.
    pub fn timeline_json(&self) -> Value {
        if self.dropped_events > 0 {
            eprintln!(
                "warning: engine timeline dropped {} event(s) past \
                 the {MAX_EVENTS}-event cap; the exported `events[]` \
                 is a prefix of the schedule (clocks remain exact — \
                 see `dropped_events` in the JSON)",
                self.dropped_events
            );
        }
        let events: Vec<Value> = self
            .events
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("label", Value::Str(e.label.to_string())),
                    (
                        "node",
                        match e.node {
                            Some(p) => Value::Num(p as f64),
                            None => Value::Null,
                        },
                    ),
                    (
                        "level",
                        match e.level {
                            Some(l) => Value::Num(l as f64),
                            None => Value::Null,
                        },
                    ),
                    ("start", Value::Num(e.start)),
                    ("end", Value::Num(e.end)),
                    (
                        "staleness",
                        match e.staleness {
                            Some(s) => Value::Num(s as f64),
                            None => Value::Null,
                        },
                    ),
                ])
            })
            .collect();
        Value::obj(vec![
            ("makespan", Value::Num(self.makespan())),
            ("nodes", Value::Num(self.n_nodes() as f64)),
            ("pipeline", Value::Bool(self.pipeline)),
            (
                "profile",
                Value::Arr(
                    self.profile.speed.iter().map(|&s| Value::Num(s)).collect(),
                ),
            ),
            ("dropped_events", Value::Num(self.dropped_events as f64)),
            ("events", Value::Arr(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(engine: &Engine) -> f64 {
        engine.makespan()
    }

    #[test]
    fn homogeneous_schedule_equals_flat_sum() {
        // compute (max 3s) + 2-level reduce (1s hops) + broadcast
        // (2 × 1s) must chain to exactly 3 + 2 + 2 = 7s
        let mut e = Engine::new(NodeProfile::homogeneous(4));
        e.compute(1.0, &[2.0, 3.0, 2.5, 3.0]);
        e.tree_reduce("reduce", &[1.0, 1.0], Some((2, 1.0)), Lane::Node);
        assert!((flat(&e) - 7.0).abs() < 1e-12, "{}", flat(&e));
        // every node gated on the arrival
        e.compute(1.0, &[1.0; 4]);
        assert!((flat(&e) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn straggler_subtree_is_partially_hidden() {
        // nodes 0..2 ready at 1s, node 3 at 10s (self-paced pipelined
        // schedule): the (0,1) merge and the level-0 hop of (2,3) all
        // complete while node 3 works; root = max(2+h, 10+h) + h —
        // NOT 10 + 2h + barrier slack
        let mut e = Engine::new(NodeProfile::homogeneous(4));
        e.pipeline = true;
        e.compute(1.0, &[1.0, 1.0, 1.0, 10.0]);
        let root =
            e.tree_reduce("reduce", &[1.0, 1.0], None, Lane::Node);
        assert!((root - 12.0).abs() < 1e-12, "root {root}");
        // odd-node passthrough: 3 nodes, straggler is the lone tail —
        // it skips the leaf-level hop entirely
        let mut e3 = Engine::new(NodeProfile::homogeneous(3));
        e3.pipeline = true;
        e3.compute(1.0, &[1.0, 1.0, 10.0]);
        let root3 =
            e3.tree_reduce("reduce", &[1.0, 1.0], None, Lane::Node);
        assert!((root3 - 11.0).abs() < 1e-12, "root3 {root3}");
        // barrier schedule: the same reduce pays the full flat sum
        let mut b = Engine::new(NodeProfile::homogeneous(4));
        b.compute(1.0, &[1.0, 1.0, 1.0, 10.0]);
        let broot = b.tree_reduce("reduce", &[1.0, 1.0], None, Lane::Node);
        assert!((broot - 12.0).abs() < 1e-12, "barrier root {broot}");
    }

    #[test]
    fn profile_scales_per_node_compute() {
        let mut e = Engine::new(NodeProfile::with_straggler(4, 2, 3.0));
        let max = e.compute(2.0, &[1.0; 4]);
        // straggler: 1.0 × scale 2 × speed 3 = 6
        assert!((max - 6.0).abs() < 1e-12);
        assert!((e.makespan() - 6.0).abs() < 1e-12);
        let seeded = NodeProfile::seeded(8, 7, 1.5);
        assert_eq!(seeded, NodeProfile::seeded(8, 7, 1.5));
        assert!(seeded.speed.iter().all(|&s| (1.0..2.5).contains(&s)));
        assert!(!seeded.is_homogeneous());
    }

    #[test]
    fn pipeline_overlaps_control_with_node_compute() {
        // two "rounds": solve, control-lane reduce+scalars, next solve.
        // barrier schedule serializes control; pipelined hides it
        // under the next solve.
        let solve = [4.0, 4.0, 4.0, 12.0];
        let run = |pipeline: bool| {
            let mut e = Engine::new(NodeProfile::homogeneous(4));
            e.pipeline = pipeline;
            for _ in 0..3 {
                e.compute(1.0, &solve);
                e.tree_reduce(
                    "reduce",
                    &[1.0, 1.0],
                    Some((2, 1.0)),
                    Lane::Control,
                );
                e.scalar_round(2, 0.5);
            }
            e.makespan()
        };
        let barrier = run(false);
        let pipelined = run(true);
        assert!(
            pipelined < barrier - 1.0,
            "pipelined {pipelined} vs barrier {barrier}"
        );
        // control still lands after the solves that feed it
        assert!(pipelined >= 3.0 * 12.0);
    }

    #[test]
    fn control_lane_is_barrier_when_pipeline_off() {
        let mut sync = Engine::new(NodeProfile::homogeneous(2));
        sync.compute(1.0, &[1.0, 5.0]);
        sync.tree_reduce("reduce", &[1.0], Some((1, 1.0)), Lane::Control);
        // nodes gated on arrival: 5 + 1 + 1
        sync.compute(1.0, &[1.0, 1.0]);
        assert!((sync.makespan() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn control_reduce_leaves_workers_running_only_when_pipelined() {
        // non-pipelined: even a master-only reduce is a barrier
        let mut e = Engine::new(NodeProfile::homogeneous(2));
        e.compute(1.0, &[1.0, 1.0]);
        let root = e.tree_reduce("reduce", &[1.0], None, Lane::Node);
        assert!((root - 2.0).abs() < 1e-12);
        e.compute(1.0, &[1.0, 1.0]);
        assert!((e.node_clock[0] - 3.0).abs() < 1e-12);

        // pipelined + control lane: workers keep their own pace and a
        // later broadcast gates them on the control chain
        let mut p = Engine::new(NodeProfile::homogeneous(2));
        p.pipeline = true;
        p.compute(1.0, &[1.0, 1.0]);
        p.tree_reduce("reduce", &[1.0], None, Lane::Control);
        p.compute(1.0, &[1.0, 1.0]);
        assert!((p.node_clock[0] - 2.0).abs() < 1e-12);
        p.broadcast(1, 0.5);
        assert!((p.makespan() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn barrier_broadcast_waits_for_all_nodes() {
        // compute-only phase then broadcast: the send must not hide
        // behind the stale control clock (regression: makespan would
        // gain 0 while the flat ledger charged the hop)
        let mut e = Engine::new(NodeProfile::homogeneous(2));
        e.compute(1.0, &[1.0, 3.0]);
        let arrival = e.broadcast(1, 0.5);
        assert!((arrival - 3.5).abs() < 1e-12, "arrival {arrival}");
        assert!((e.makespan() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn quorum_reduce_collects_by_arrival_and_gates_main_lanes() {
        let mut e = Engine::new(NodeProfile::homogeneous(4));
        e.compute(1.0, &[1.0; 4]); // node clocks at 1
        // three contributions land at 2, 5 and 3 virtual seconds:
        // level 0 pairs (2,5) → 6, the odd tail 3 joins one level up,
        // level 1 merges (6,3) → 7, then a 2-hop broadcast → 9
        let arrivals = [(0usize, 2.0, 0usize), (1, 5.0, 1), (2, 3.0, 0)];
        let landed =
            e.quorum_reduce("async_reduce", &arrivals, &[1.0, 1.0], Some((2, 1.0)));
        assert!((landed - 9.0).abs() < 1e-12, "landed {landed}");
        // the committed direction gates every main lane
        e.compute(1.0, &[1.0; 4]);
        assert!((e.makespan() - 10.0).abs() < 1e-12);
        // arrival events carry the combined staleness
        assert!(e
            .events()
            .iter()
            .any(|ev| ev.label == "async_arrival" && ev.staleness == Some(1)));
        // solver-lane events are pure records
        let before = e.makespan();
        e.solver_event("async_solve", 3, 0.0, 99.0);
        assert_eq!(e.makespan(), before);
        assert!(e.events().iter().any(|ev| ev.label == "async_solve"));
    }

    #[test]
    fn member_subset_leaves_dead_clocks_frozen() {
        let mut e = Engine::new(NodeProfile::homogeneous(4));
        e.compute(1.0, &[1.0; 4]); // everyone at 1
        // node 3 "dies": the next phase runs on members {0,1,2} only
        let members = [0usize, 1, 2];
        e.compute_members(1.0, &members, &[2.0, 1.0, 1.0]);
        // barrier gates members at 3, the dead clock stays at 1
        assert!((e.node_ready(0) - 3.0).abs() < 1e-12);
        assert!((e.node_ready(3) - 1.0).abs() < 1e-12);
        let landed =
            e.tree_reduce_members("reduce", &[1.0], None, Lane::Node, &members);
        assert!((landed - 4.0).abs() < 1e-12, "landed {landed}");
        assert!((e.node_ready(3) - 1.0).abs() < 1e-12);
        // degrade in place: no clock reset, future compute is slower
        e.set_speed(0, 4.0);
        e.compute_members(1.0, &members, &[1.0, 1.0, 1.0]);
        assert!((e.node_ready(0) - 8.0).abs() < 1e-12);
        // rejoin: the unicast pulls the frozen clock to the transfer end
        let end = e.unicast("rejoin_rebase", 3, 8.0, 0.5);
        assert!((end - 8.5).abs() < 1e-12);
        assert!((e.node_ready(3) - 8.5).abs() < 1e-12);
        e.fault_event("fault_crash", 3, 1.0);
        assert!(e.events().iter().any(|ev| ev.label == "fault_crash"));
        assert!(e.events().iter().any(|ev| ev.label == "rejoin_rebase"));
    }

    #[test]
    fn full_membership_delegation_is_identical() {
        // the legacy entry points and the members variants with the
        // full node set must produce the same clocks and events —
        // this is the structural half of zero-fault bit-identity
        let run = |via_members: bool| {
            let mut e = Engine::new(NodeProfile::with_straggler(4, 1, 3.0));
            let all: Vec<usize> = (0..4).collect();
            if via_members {
                e.compute_members(2.0, &all, &[1.0, 1.5, 1.0, 2.0]);
                e.tree_reduce_members(
                    "reduce",
                    &[1.0, 1.0],
                    Some((2, 1.0)),
                    Lane::Node,
                    &all,
                );
                e.broadcast_members(2, 0.5, &all);
                e.scalar_round_members(2, 0.25, &all);
            } else {
                e.compute(2.0, &[1.0, 1.5, 1.0, 2.0]);
                e.tree_reduce("reduce", &[1.0, 1.0], Some((2, 1.0)), Lane::Node);
                e.broadcast(2, 0.5);
                e.scalar_round(2, 0.25);
            }
            (e.makespan(), e.events().len(), e.comm_marks())
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn linked_reduce_with_identity_closure_matches_legacy_exactly() {
        let build = || {
            let mut e = Engine::new(NodeProfile::with_straggler(5, 2, 3.0));
            e.compute(1.0, &[1.0, 2.0, 1.0, 3.0, 2.0]);
            e
        };
        let all: Vec<usize> = (0..5).collect();
        let mut legacy = build();
        let l_land = legacy.tree_reduce_members(
            "reduce",
            &[1.0, 0.5, 0.25],
            Some((3, 0.5)),
            Lane::Node,
            &all,
        );
        let mut linked = build();
        let mut ident = |_l: usize, _s: usize, base: f64| HopOutcome {
            secs: base,
            retry_secs: 0.0,
            rerouted: false,
        };
        let (k_land, totals) = linked.tree_reduce_linked_members(
            "reduce",
            &[1.0, 0.5, 0.25],
            Some((3, 0.5)),
            Lane::Node,
            &all,
            &mut ident,
        );
        assert_eq!(l_land, k_land, "bitwise-identical landing");
        assert_eq!(legacy.makespan(), linked.makespan());
        assert_eq!(legacy.events().len(), linked.events().len());
        assert_eq!(legacy.comm_marks(), linked.comm_marks());
        // identity closure: flat wire = per-level hop chain, no retry
        assert!((totals.comm_secs - 1.75).abs() < 1e-12);
        assert_eq!(totals.retry_secs, 0.0);

        // quorum variant too
        let arrivals = [(0usize, 2.0, 0usize), (1, 5.0, 1), (2, 3.0, 0)];
        let mut lq = build();
        let a = lq.quorum_reduce_members(
            "async_reduce",
            &arrivals,
            &[1.0, 1.0],
            Some((2, 1.0)),
            &all,
        );
        let mut kq = build();
        let (b, _) = kq.quorum_reduce_linked_members(
            "async_reduce",
            &arrivals,
            &[1.0, 1.0],
            Some((2, 1.0)),
            &all,
            &mut ident,
        );
        assert_eq!(a, b);
        assert_eq!(lq.makespan(), kq.makespan());
        assert_eq!(lq.events().len(), kq.events().len());
    }

    #[test]
    fn linked_reduce_records_reroutes_and_splits_retry_time() {
        let mut e = Engine::new(NodeProfile::homogeneous(4));
        e.compute(1.0, &[1.0; 4]);
        // sender 3's level-0 uplink is dead: 0.5s of backoff then a
        // reroute doubling the 1s hop; everything else at base cost
        let mut link = |level: usize, sender: usize, base: f64| {
            if level == 0 && sender == 3 {
                HopOutcome { secs: 2.0 * base + 0.5, retry_secs: 0.5, rerouted: true }
            } else {
                HopOutcome { secs: base, retry_secs: 0.0, rerouted: false }
            }
        };
        let (landed, totals) = e.tree_reduce_linked_members(
            "reduce",
            &[1.0, 1.0],
            None,
            Lane::Node,
            &[0, 1, 2, 3],
            &mut link,
        );
        // level 0: pair (2,3) takes 2.5s (crit), level 1 takes 1s
        assert!((landed - 4.5).abs() < 1e-12, "landed {landed}");
        assert!((totals.comm_secs - 3.0).abs() < 1e-12);
        assert!((totals.retry_secs - 0.5).abs() < 1e-12);
        let reroute = e
            .events()
            .iter()
            .find(|ev| ev.label == "reroute")
            .expect("reroute span recorded");
        assert_eq!(reroute.node, Some(3));
        assert_eq!(reroute.level, Some(0));
        assert!((reroute.end - reroute.start - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ring_is_a_barrier_and_timeline_exports() {
        let mut e = Engine::new(NodeProfile::homogeneous(3));
        e.pipeline = true;
        e.set_phase("local_solve");
        e.compute(1.0, &[1.0, 2.0, 3.0]);
        e.ring_traversal("ring", 2.0);
        assert!((e.makespan() - 5.0).abs() < 1e-12);
        let json = e.timeline_json().to_json(0);
        assert!(json.contains("\"local_solve\""), "{json}");
        assert!(json.contains("\"makespan\""));
        assert!(json.contains("\"ring\""));
        assert_eq!(e.dropped_events(), 0);
    }

    #[test]
    fn engine_publishes_ordered_gauges() {
        let mut e = Engine::new(NodeProfile::homogeneous(2));
        e.compute(1.0, &[1.0, 1.0]);
        e.broadcast(2, 0.5);
        let mut reg = crate::obs::Registry::new();
        e.publish(&mut reg);
        assert_eq!(reg.items()[0].name, "makespan");
        assert_eq!(reg.get("makespan"), Some(e.makespan()));
        assert_eq!(reg.get("events"), Some(e.events().len() as f64));
        assert_eq!(reg.get("dropped_events"), Some(0.0));
        assert_eq!(reg.get("comm_marks"), Some(e.comm_marks() as f64));
    }
}
