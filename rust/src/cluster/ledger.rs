//! The run accounting every driver reports: communication passes
//! (Figure 1's left panels), simulated seconds (middle/right panels),
//! the raw component breakdown, and the per-level sparse payload
//! profile benches use to report wire shapes.
//!
//! Since the event-driven engine landed, the ledger is a *view* over
//! the engine's timeline: [`Ledger::seconds`] reports the critical-path
//! makespan the [`Engine`](super::engine::Engine) computed from
//! per-node virtual clocks, while `comm_seconds`/`compute_seconds`
//! remain the flat *component* accumulators (the barrier-equivalent
//! breakdown). Without pipelining the schedule IS the barrier
//! schedule and the two agree to floating-point ε — `tests/engine.rs`
//! pins that equivalence; under `--pipeline` the makespan is the
//! smaller, honest number (control-lane overlap and in-tree straggler
//! hiding).

use crate::obs::Registry;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Ledger {
    /// size-d vector traversals (paper footnote 5)
    pub comm_passes: f64,
    /// modeled communication seconds, flat component sum (every hop
    /// charged as if serial — the barrier-equivalent comm share)
    pub comm_seconds: f64,
    /// payload bytes per logical traversal, summed over traversals —
    /// d·8 for a dense pass, min(nnz·12, d·8) for a sparse one. This is
    /// where the sparse pipeline's wire win shows up even when the
    /// logical pass count is identical.
    pub comm_bytes: f64,
    /// measured compute seconds (max over concurrent nodes per phase,
    /// scaled by the per-node profile — the barrier-equivalent compute
    /// share)
    pub compute_seconds: f64,
    /// scalar aggregation rounds (line-search trials etc.)
    pub scalar_rounds: usize,
    /// cumulative largest-message bytes per combining-tree level
    /// (index 0 = leaf level), summed over every sparse reduction in
    /// the run — the wire profile `tree_sum_sparse` observes. Recorded
    /// under BOTH time models: on the Ring the profile describes the
    /// logical combining tree's payload growth (what the chunked hops
    /// carry in aggregate), while time is charged by `(P−1)` chunk
    /// hops of the merged payload.
    pub level_bytes: Vec<f64>,
    /// how many sparse reductions are folded into `level_bytes`
    pub sparse_reductions: usize,
    /// critical-path makespan from the event engine; `None` on a
    /// hand-built ledger (falls back to the flat component sum)
    pub makespan: Option<f64>,
    /// async FS: histogram of the staleness (in outer rounds) of every
    /// contribution the master combined — index s counts contributions
    /// that were s rounds old, so `staleness_hist[0]` is the fresh
    /// share and the vector never grows past τ+1 entries
    pub staleness_hist: Vec<usize>,
    /// async FS combine rounds recorded into `staleness_hist`
    pub async_rounds: usize,
    /// async FS rounds whose quorum direction failed the safeguard
    /// gate and fell back to the synchronous barrier direction
    pub fallback_rounds: usize,
    /// fault layer: nodes that crashed out of the membership
    pub crash_events: usize,
    /// crashed nodes that rejoined and were re-based onto the current
    /// iterate via the compact wire format
    pub rejoin_rebases: usize,
    /// direction contributions lost on the wire even after the retry
    /// (absorbed by the partial quorum + safeguard, never a hang)
    pub lost_messages: usize,
    /// direction contributions that needed one retry before delivery
    pub retry_rounds: usize,
    /// in-place compute-degradation events applied to the profile
    pub degrade_events: usize,
    /// node-rounds lost to transient flaps (no state to recover)
    pub flap_events: usize,
    /// virtual seconds of rejoin state transfer on the critical path
    pub recovery_seconds: f64,
    /// virtual seconds spent on the link layer's timeout/backoff
    /// ladders (per-level critical share) — deliberately NOT folded
    /// into `comm_seconds`, so the wire share and the waiting-on-dead-
    /// links share stay separately attributable
    pub retry_seconds: f64,
    /// link layer: hop attempts that timed out and were retried
    pub link_retries: usize,
    /// link layer: hops that exhausted the retry budget and re-parented
    /// their subtree around the dead edge
    pub reroutes: usize,
    /// link layer: hops charged under a transient congestion window
    pub congested_hops: usize,
    /// link layer: partition activations applied to the membership
    pub partition_events: usize,
    /// speculative solver lanes: solves whose predicted basis survived
    /// the commit and kept their early start on the virtual clock
    pub spec_hits: usize,
    /// speculative solves whose prediction was discarded — the lane
    /// re-based and restarted at the commit (plain-async timing)
    pub spec_misses: usize,
    /// virtual seconds of speculative work discarded by mispredictions
    /// (the `speculation_rebase` spans; never on the critical path)
    pub spec_rebase_seconds: f64,
    /// adaptive asynchrony: the (τ, q) decision sequence the
    /// controller took, in order — pure ledger functions, so a seeded
    /// run replays this trace bit-identically
    pub tune_trace: Vec<(usize, usize)>,
}

impl Ledger {
    /// The simulated wall clock: the engine's critical-path makespan
    /// when an engine drove this ledger, else the flat component sum.
    pub fn seconds(&self) -> f64 {
        self.makespan
            .unwrap_or(self.comm_seconds + self.compute_seconds)
    }

    /// Snapshot for trace records.
    pub fn snapshot(&self) -> (f64, f64) {
        (self.comm_passes, self.seconds())
    }

    /// Fold one sparse reduction's per-level message sizes into the
    /// cumulative profile.
    pub fn record_sparse_levels(&mut self, levels: &[usize]) {
        if self.level_bytes.len() < levels.len() {
            self.level_bytes.resize(levels.len(), 0.0);
        }
        for (slot, &b) in self.level_bytes.iter_mut().zip(levels) {
            *slot += b as f64;
        }
        self.sparse_reductions += 1;
    }

    /// Fold one async FS combine round into the per-run staleness
    /// histogram: `staleness` holds, per combined contribution, how
    /// many outer rounds old its reference was; `fallback` marks a
    /// round whose quorum direction failed the safeguard gate (its
    /// discarded contributions still count — the histogram describes
    /// what arrived, not what survived).
    pub fn record_async_round(&mut self, staleness: &[usize], fallback: bool) {
        for &s in staleness {
            if self.staleness_hist.len() <= s {
                self.staleness_hist.resize(s + 1, 0);
            }
            self.staleness_hist[s] += 1;
        }
        self.async_rounds += 1;
        if fallback {
            self.fallback_rounds += 1;
        }
    }

    /// Publish the cross-cutting run counters into an ordered
    /// [`Registry`] — the machine-readable face of this ledger.
    pub fn publish(&self, reg: &mut Registry) {
        reg.counter("passes", self.comm_passes as u64);
        reg.gauge("bytes", self.comm_bytes, 0, "B");
        reg.gauge("comm", self.comm_seconds, 3, "s");
        reg.gauge("compute", self.compute_seconds, 3, "s");
        reg.counter("scalar_rounds", self.scalar_rounds as u64);
        reg.gauge("seconds", self.seconds(), 3, "s");
        self.publish_staleness(reg);
        self.publish_speculation(reg);
        self.publish_faults(reg);
    }

    /// Publish the async-FS staleness histogram + fallback counters.
    /// Publishes nothing when no async round ran (quiet profile).
    pub fn publish_staleness(&self, reg: &mut Registry) {
        if self.async_rounds == 0 {
            return;
        }
        reg.histogram("s", &self.staleness_hist);
        reg.counter("fallback", self.fallback_rounds as u64);
        reg.counter("rounds", self.async_rounds as u64);
    }

    /// Staleness histogram rendered for bench reports through the one
    /// registry render path: "s0 42 | s1 7 | fallback 1 | rounds 20".
    /// Empty when no async round ran.
    pub fn staleness_profile(&self) -> String {
        let mut reg = Registry::new();
        self.publish_staleness(&mut reg);
        reg.render()
    }

    /// Did speculation or the adaptive controller touch this run?
    pub fn has_speculation_activity(&self) -> bool {
        self.spec_hits + self.spec_misses + self.tune_trace.len() > 0
    }

    /// Publish the speculation/self-tuning counters. Publishes nothing
    /// when neither speculative lanes nor the adaptive controller ran
    /// (quiet profile).
    pub fn publish_speculation(&self, reg: &mut Registry) {
        if !self.has_speculation_activity() {
            return;
        }
        reg.counter("spec_hit", self.spec_hits as u64);
        reg.counter("spec_miss", self.spec_misses as u64);
        reg.gauge("spec_rebase", self.spec_rebase_seconds, 3, "s");
        reg.counter("tuned", self.tune_trace.len() as u64);
    }

    /// Speculation counters rendered for bench reports through the one
    /// registry render path: "spec_hit 12 | spec_miss 2 |
    /// spec_rebase 0.250s | tuned 3". Empty when the run saw neither
    /// speculation nor tuning.
    pub fn speculation_profile(&self) -> String {
        let mut reg = Registry::new();
        self.publish_speculation(&mut reg);
        reg.render()
    }

    /// Did the fault layer (node or link) touch this run at all?
    pub fn has_fault_activity(&self) -> bool {
        self.crash_events
            + self.rejoin_rebases
            + self.lost_messages
            + self.retry_rounds
            + self.degrade_events
            + self.flap_events
            + self.link_retries
            + self.reroutes
            + self.congested_hops
            + self.partition_events
            > 0
    }

    /// Publish the fault-layer counters. Publishes nothing when the
    /// run saw no fault activity (quiet profile).
    pub fn publish_faults(&self, reg: &mut Registry) {
        if !self.has_fault_activity() {
            return;
        }
        reg.counter("crash", self.crash_events as u64);
        reg.counter("rejoin", self.rejoin_rebases as u64);
        reg.gauge("recovery", self.recovery_seconds, 3, "s");
        reg.counter("lost", self.lost_messages as u64);
        reg.counter("retry", self.retry_rounds as u64);
        reg.counter("degrade", self.degrade_events as u64);
        reg.counter("flap", self.flap_events as u64);
        reg.gauge("retry_wait", self.retry_seconds, 3, "s");
        reg.counter("link_retry", self.link_retries as u64);
        reg.counter("reroute", self.reroutes as u64);
        reg.counter("congested", self.congested_hops as u64);
        reg.counter("partition", self.partition_events as u64);
    }

    /// Fault counters rendered for bench reports through the one
    /// registry render path: "crash 2 | rejoin 2 | recovery 0.125s |
    /// lost 3 | retry 5 | degrade 1 | flap 4 | retry_wait 0.050s |
    /// link_retry 6 | reroute 1 | congested 9 | partition 1". Empty
    /// when the run saw no fault activity.
    pub fn fault_profile(&self) -> String {
        let mut reg = Registry::new();
        self.publish_faults(&mut reg);
        reg.render()
    }

    /// Publish the mean per-level payload of the sparse reductions as
    /// `L0..Ln` KB gauges. Publishes nothing when no sparse reduction
    /// ran.
    pub fn publish_levels(&self, reg: &mut Registry) {
        if self.sparse_reductions == 0 {
            return;
        }
        let n = self.sparse_reductions as f64;
        for (l, &b) in self.level_bytes.iter().enumerate() {
            reg.gauge(format!("L{l}"), b / n / 1024.0, 1, "KB");
        }
    }

    /// Mean per-level payload of the sparse reductions, rendered for
    /// bench reports through the one registry render path:
    /// "L0 24.0KB | L1 31.5KB | ...". Empty string when no sparse
    /// reduction ran.
    pub fn level_profile(&self) -> String {
        let mut reg = Registry::new();
        self.publish_levels(&mut reg);
        reg.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_sum_components() {
        let l = Ledger {
            comm_passes: 4.0,
            comm_seconds: 1.5,
            comm_bytes: 320.0,
            compute_seconds: 2.5,
            scalar_rounds: 3,
            ..Ledger::default()
        };
        assert_eq!(l.seconds(), 4.0);
        assert_eq!(l.snapshot(), (4.0, 4.0));
        // engine-driven ledgers report the critical-path makespan
        // instead of the flat sum (overlap makes it smaller)
        let engine_view = Ledger { makespan: Some(3.2), ..l };
        assert_eq!(engine_view.seconds(), 3.2);
        assert_eq!(engine_view.snapshot(), (4.0, 3.2));
    }

    #[test]
    fn staleness_histogram_accumulates() {
        let mut l = Ledger::default();
        assert_eq!(l.staleness_profile(), "");
        l.record_async_round(&[0, 0, 1], false);
        l.record_async_round(&[0, 2], true);
        assert_eq!(l.staleness_hist, vec![3, 1, 1]);
        assert_eq!(l.async_rounds, 2);
        assert_eq!(l.fallback_rounds, 1);
        let p = l.staleness_profile();
        assert!(p.starts_with("s0 3 | s1 1 | s2 1"), "{p}");
        assert!(p.contains("fallback 1 | rounds 2"), "{p}");
        // the profile IS the registry render — one render path
        let mut reg = Registry::new();
        l.publish_staleness(&mut reg);
        assert_eq!(p, reg.render());
    }

    #[test]
    fn fault_profile_renders_counters() {
        let quiet = Ledger::default();
        assert!(!quiet.has_fault_activity());
        assert_eq!(quiet.fault_profile(), "");
        let l = Ledger {
            crash_events: 2,
            rejoin_rebases: 2,
            recovery_seconds: 0.125,
            lost_messages: 3,
            retry_rounds: 5,
            ..Ledger::default()
        };
        assert!(l.has_fault_activity());
        let p = l.fault_profile();
        assert!(
            p.starts_with("crash 2 | rejoin 2 | recovery 0.125s"),
            "{p}"
        );
        assert!(p.contains("lost 3 | retry 5"), "{p}");
        assert!(p.contains("degrade 0 | flap 0"), "{p}");
        let mut reg = Registry::new();
        l.publish_faults(&mut reg);
        assert_eq!(p, reg.render());
        assert_eq!(reg.get("crash"), Some(2.0));
    }

    #[test]
    fn link_counters_trip_fault_activity_and_render() {
        // link-only weather must light the resilience surface even
        // with zero node faults, and retry time stays a distinct
        // counter (never folded into comm seconds)
        let l = Ledger {
            retry_seconds: 0.05,
            link_retries: 6,
            reroutes: 1,
            congested_hops: 9,
            partition_events: 1,
            ..Ledger::default()
        };
        assert!(l.has_fault_activity());
        assert_eq!(l.comm_seconds, 0.0);
        let p = l.fault_profile();
        assert!(p.contains("retry_wait 0.050s"), "{p}");
        assert!(p.contains("link_retry 6 | reroute 1"), "{p}");
        assert!(p.contains("congested 9 | partition 1"), "{p}");
        let mut reg = Registry::new();
        l.publish_faults(&mut reg);
        assert_eq!(reg.get("reroute"), Some(1.0));
        assert_eq!(reg.get("retry_wait"), Some(0.05));
    }

    #[test]
    fn speculation_profile_renders_counters() {
        let quiet = Ledger::default();
        assert!(!quiet.has_speculation_activity());
        assert_eq!(quiet.speculation_profile(), "");
        let l = Ledger {
            spec_hits: 12,
            spec_misses: 2,
            spec_rebase_seconds: 0.25,
            tune_trace: vec![(2, 4), (1, 4), (2, 5)],
            ..Ledger::default()
        };
        assert!(l.has_speculation_activity());
        let p = l.speculation_profile();
        assert!(p.starts_with("spec_hit 12 | spec_miss 2"), "{p}");
        assert!(p.contains("spec_rebase 0.250s | tuned 3"), "{p}");
        // the profile IS the registry render — one render path
        let mut reg = Registry::new();
        l.publish_speculation(&mut reg);
        assert_eq!(p, reg.render());
        assert_eq!(reg.get("spec_hit"), Some(12.0));
    }

    #[test]
    fn level_profile_accumulates_and_averages() {
        let mut l = Ledger::default();
        assert_eq!(l.level_profile(), "");
        l.record_sparse_levels(&[2048, 1024]);
        l.record_sparse_levels(&[2048, 1024, 512]);
        assert_eq!(l.sparse_reductions, 2);
        assert_eq!(l.level_bytes, vec![4096.0, 2048.0, 512.0]);
        let profile = l.level_profile();
        assert!(profile.starts_with("L0 2.0KB"), "{profile}");
        assert!(profile.contains("L2 0.2KB"), "{profile}");
        let mut reg = Registry::new();
        l.publish_levels(&mut reg);
        assert_eq!(profile, reg.render());
    }

    #[test]
    fn full_publish_orders_core_counters_first() {
        let l = Ledger {
            comm_passes: 4.0,
            comm_bytes: 320.0,
            scalar_rounds: 3,
            ..Ledger::default()
        };
        let mut reg = Registry::new();
        l.publish(&mut reg);
        assert_eq!(reg.items()[0].name, "passes");
        assert_eq!(reg.get("passes"), Some(4.0));
        assert_eq!(reg.get("scalar_rounds"), Some(3.0));
        // quiet run: no staleness / fault metrics published
        assert_eq!(reg.get("rounds"), None);
        assert_eq!(reg.get("crash"), None);
    }
}
