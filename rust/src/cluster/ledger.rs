//! The run accounting every driver reports: communication passes
//! (Figure 1's left panels), simulated seconds (middle/right panels),
//! and the raw component breakdown.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Ledger {
    /// size-d vector traversals (paper footnote 5)
    pub comm_passes: f64,
    /// modeled communication seconds (tree hops × cost model)
    pub comm_seconds: f64,
    /// payload bytes per logical traversal, summed over traversals —
    /// d·8 for a dense pass, min(nnz·12, d·8) for a sparse one. This is
    /// where the sparse pipeline's wire win shows up even when the
    /// logical pass count is identical.
    pub comm_bytes: f64,
    /// measured compute seconds (max over concurrent nodes per phase)
    pub compute_seconds: f64,
    /// scalar aggregation rounds (line-search trials etc.)
    pub scalar_rounds: usize,
}

impl Ledger {
    /// The simulated wall clock.
    pub fn seconds(&self) -> f64 {
        self.comm_seconds + self.compute_seconds
    }

    /// Snapshot for trace records.
    pub fn snapshot(&self) -> (f64, f64) {
        (self.comm_passes, self.seconds())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_sum_components() {
        let l = Ledger {
            comm_passes: 4.0,
            comm_seconds: 1.5,
            comm_bytes: 320.0,
            compute_seconds: 2.5,
            scalar_rounds: 3,
        };
        assert_eq!(l.seconds(), 4.0);
        assert_eq!(l.snapshot(), (4.0, 4.0));
    }
}
