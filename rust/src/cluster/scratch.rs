//! Per-node reusable scratch buffers. A [`NodeScratch`] lives in the
//! [`Cluster`](super::Cluster) (one slot per node, behind a `Mutex` so
//! threaded map phases can borrow their own slot mutably) and is handed
//! to every `map_each_scratch` closure. Steady-state gradient rounds
//! and inner solves therefore allocate nothing: gathers, support-
//! aligned accumulators and the solver working sets all reuse these
//! buffers across outer iterations. Every buffer is O(|support_p|) or
//! O(n_p) — never O(d).

use crate::opt::sag::SagScratch;
use crate::opt::svrg::SvrgScratch;

#[derive(Debug, Default)]
pub struct NodeScratch {
    /// compact gather of the global iterate w on the shard support
    pub wloc: Vec<f64>,
    /// compact gather of the global gradient (or a second operand)
    pub gloc: Vec<f64>,
    /// support-aligned accumulator (loss gradients, Hv products)
    pub vals: Vec<f64>,
    /// general compact buffer (direction gathers, corrections)
    pub buf: Vec<f64>,
    /// per-row direction margins dz = X·dʳ for the line search
    /// (length n_p, reused across outer iterations — the dir-matvec
    /// phase allocates nothing in steady state)
    pub dz: Vec<f64>,
    /// SVRG inner-solver working set
    pub svrg: SvrgScratch,
    /// SAG inner-solver working set
    pub sag: SagScratch,
}

impl NodeScratch {
    pub fn pool(n_nodes: usize) -> Vec<std::sync::Mutex<NodeScratch>> {
        (0..n_nodes)
            .map(|_| std::sync::Mutex::new(NodeScratch::default()))
            .collect()
    }
}
