//! Communication cost model for the simulated AllReduce tree.
//!
//! Defaults model the paper's Hadoop-era gigabit cluster: 0.5 ms
//! per-hop latency, 1 Gbit/s links. With kdd2010's d = 20.21M features
//! a single f64 pass is ~162 MB ⇒ ~1.3 s/hop — communication dominates,
//! exactly the regime that makes FS's few-passes-per-iteration design
//! pay off. At the repro scale (d = 500k) a pass is ~4 MB ⇒ ~32 ms/hop.

/// Physical reduction topology — affects modeled *time* only (the
/// paper's communication-pass count is topology-independent: footnote 5
/// counts size-d vector traversals).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// binary AllReduce tree (the paper's [8] arrangement)
    #[default]
    Tree,
    /// bandwidth-optimal ring: reduce-scatter + all-gather, 2(P−1)
    /// hops of d/P-sized chunks
    Ring,
}

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-hop latency α (seconds)
    pub latency_s: f64,
    /// link bandwidth (bytes/second)
    pub bandwidth_bytes_per_s: f64,
    /// wire size of one vector component (8 = f64)
    pub bytes_per_scalar: usize,
    /// multiplier applied to measured per-node compute seconds —
    /// models nodes slower/faster than this machine's single core
    pub compute_scale: f64,
    /// physical reduction arrangement (time model only)
    pub topology: Topology,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            latency_s: 5e-4,
            bandwidth_bytes_per_s: 125e6, // 1 Gbit/s
            bytes_per_scalar: 8,
            compute_scale: 1.0,
            topology: Topology::Tree,
        }
    }
}

impl CostModel {
    /// A zero-cost model: pure algorithmic accounting (tests).
    pub fn free() -> CostModel {
        CostModel {
            latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
            bytes_per_scalar: 8,
            compute_scale: 0.0,
            topology: Topology::Tree,
        }
    }

    /// Seconds for one size-`dim` vector pass over one tree level.
    pub fn pass_seconds(&self, dim: usize) -> f64 {
        self.latency_s
            + (dim * self.bytes_per_scalar) as f64 / self.bandwidth_bytes_per_s
    }

    /// Seconds for one tree hop carrying `bytes` of payload — the
    /// building block the sparse phases charge per reduction level.
    pub fn hop_seconds(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bytes_per_s
    }

    /// Seconds for ONE logical traversal of a *sparse* payload of
    /// `bytes` over `nodes` on the Ring topology: the reduce-scatter
    /// (or all-gather) phase moves (P−1) chunk hops of bytes/P — the
    /// ring analogue of charging each tree level by its actual nnz
    /// payload. A single node has no wire.
    pub fn ring_sparse_traversal_seconds(
        &self,
        bytes: f64,
        nodes: usize,
    ) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        let p = nodes as f64;
        (p - 1.0) * self.hop_seconds(bytes / p)
    }

    /// Modeled seconds for ONE logical size-`dim` traversal (reduce or
    /// broadcast) over `nodes` nodes under the configured topology.
    /// A single-node cluster has no wire: zero seconds.
    pub fn traversal_seconds(&self, dim: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        let bytes = (dim * self.bytes_per_scalar) as f64;
        match self.topology {
            Topology::Tree => {
                let depth = (nodes.max(2) as f64).log2().ceil();
                depth * self.pass_seconds(dim)
            }
            Topology::Ring => {
                // (P−1) chunk hops of size d/P for one phase
                // (reduce-scatter OR all-gather = one logical traversal)
                let p = nodes.max(2) as f64;
                (p - 1.0)
                    * (self.latency_s + bytes / p / self.bandwidth_bytes_per_s)
            }
        }
    }
}

/// Nodes per rack in the seeded link profile: leaf tree levels merge
/// inside the rack, levels ≥ `RACK_LEVELS` cross the top-of-rack
/// uplink.
pub const RACK_WIDTH: usize = 4;
const RACK_LEVELS: usize = 2; // log2(RACK_WIDTH)
const SALT_LINK: u64 = 0x11E5;

/// Per-link multipliers over the reduction tree, replacing the single
/// global wire of [`CostModel`]: an up-sweep hop at tree level `l`
/// whose sending subtree is represented by node `s` is charged
/// `base × uplink[s] × level[l]`. The identity profile (all 1.0)
/// multiplies every hop by exactly 1.0, and the cluster's comm methods
/// additionally *delegate structurally* to the pre-link code path when
/// the profile is uniform and no link plan is installed — uniform
/// runs stay bit-identical to the global-wire model by construction.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkProfile {
    /// per-node uplink multiplier: the cost factor on every tree hop
    /// whose *sender* (the child-side subtree representative) is this
    /// node — a slow NIC or oversubscribed cable drags every merge the
    /// node feeds
    pub uplink: Vec<f64>,
    /// per-tree-level multiplier, index 0 = leaf merges; missing
    /// levels cost 1.0 — this is where top-of-rack oversubscription
    /// lives
    pub level: Vec<f64>,
}

impl LinkProfile {
    /// The identity profile: every link at nominal speed.
    pub fn uniform(nodes: usize) -> LinkProfile {
        LinkProfile { uplink: vec![1.0; nodes], level: Vec::new() }
    }

    /// Does this profile change any hop at all?
    pub fn is_uniform(&self) -> bool {
        self.uplink.iter().all(|&m| m == 1.0)
            && self.level.iter().all(|&m| m == 1.0)
    }

    /// Multiplier for the hop at tree `level` sent by node `sender`.
    pub fn mult(&self, level: usize, sender: usize) -> f64 {
        self.uplink.get(sender).copied().unwrap_or(1.0)
            * self.level.get(level).copied().unwrap_or(1.0)
    }

    /// Mean hop multiplier — how ring segments, broadcasts and scalar
    /// control rounds (paths without a per-edge schedule) scale under
    /// this profile. Exactly 1.0 for the uniform profile.
    pub fn mean_mult(&self) -> f64 {
        let up = if self.uplink.is_empty() {
            1.0
        } else {
            self.uplink.iter().sum::<f64>() / self.uplink.len() as f64
        };
        let lvl = if self.level.is_empty() {
            1.0
        } else {
            self.level.iter().sum::<f64>() / self.level.len() as f64
        };
        up * lvl
    }

    /// Seeded heterogeneous fabric: racks of [`RACK_WIDTH`], one
    /// hash-picked slow rack (uplinks ~2.5× with ±15% per-NIC jitter),
    /// and 2× oversubscribed levels above the top-of-rack switch. Pure
    /// in `(nodes, seed)` — the same seed always builds the same
    /// fabric.
    pub fn seeded(nodes: usize, seed: u64) -> LinkProfile {
        use super::faults::mix;
        let n_racks = nodes.div_ceil(RACK_WIDTH).max(1);
        let slow_rack =
            (mix(seed, 0, 0, SALT_LINK) % n_racks as u64) as usize;
        let uplink = (0..nodes)
            .map(|p| {
                let base =
                    if p / RACK_WIDTH == slow_rack { 2.5 } else { 1.0 };
                let u = (mix(seed, p as u64, 1, SALT_LINK) >> 11) as f64
                    / (1u64 << 53) as f64;
                base * (0.85 + 0.3 * u)
            })
            .collect();
        let depth = if nodes <= 1 {
            0
        } else {
            (nodes.max(2) as f64).log2().ceil() as usize
        };
        let level = (0..depth)
            .map(|l| if l >= RACK_LEVELS { 2.0 } else { 1.0 })
            .collect();
        LinkProfile { uplink, level }
    }

    /// Parse a comma-separated CLI link-profile script. Grammar (one
    /// spec per item; `N` a node index < `nodes`, `F` a multiplier
    /// > 0 written `2.5x`):
    ///
    /// - `uplink:N:Fx` — node `N`'s uplink costs ×F
    /// - `level:L:Fx` — every hop at tree level `L` costs ×F
    /// - `rack:I:Fx` — uplinks of rack `I` (nodes 4I..4I+4) cost ×F
    ///
    /// Returns a one-line error naming the offending spec otherwise.
    pub fn parse(script: &str, nodes: usize) -> Result<LinkProfile, String> {
        let mut out = LinkProfile::uniform(nodes);
        let bad = |spec: &str, why: &str| {
            format!("bad --link-profile spec {spec:?}: {why}")
        };
        for spec in script.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            let parts: Vec<&str> = spec.split(':').collect();
            let [kind, idx, factor] = parts[..] else {
                return Err(bad(spec, "want kind:index:Fx"));
            };
            let f = factor
                .strip_suffix('x')
                .ok_or_else(|| bad(spec, "multiplier must end in 'x'"))?
                .parse::<f64>()
                .map_err(|_| bad(spec, "bad multiplier"))?;
            if !f.is_finite() || f <= 0.0 {
                return Err(bad(spec, "multiplier must be finite and > 0"));
            }
            let i = idx
                .parse::<usize>()
                .map_err(|_| bad(spec, "index must be an integer"))?;
            match kind {
                "uplink" => {
                    if i >= nodes {
                        return Err(bad(
                            spec,
                            &format!("node {i} out of range (P = {nodes})"),
                        ));
                    }
                    out.uplink[i] = f;
                }
                "level" => {
                    if i >= 32 {
                        return Err(bad(spec, "level out of range (< 32)"));
                    }
                    if out.level.len() <= i {
                        out.level.resize(i + 1, 1.0);
                    }
                    out.level[i] = f;
                }
                "rack" => {
                    if i * RACK_WIDTH >= nodes {
                        return Err(bad(
                            spec,
                            &format!(
                                "rack {i} out of range (P = {nodes})"
                            ),
                        ));
                    }
                    let hi = ((i + 1) * RACK_WIDTH).min(nodes);
                    for slot in &mut out.uplink[i * RACK_WIDTH..hi] {
                        *slot = f;
                    }
                }
                _ => {
                    return Err(bad(
                        spec,
                        "unknown link kind (uplink|level|rack)",
                    ))
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_comm_bound_at_paper_scale() {
        let c = CostModel::default();
        // kdd2010-scale pass must dwarf latency
        let t = c.pass_seconds(20_210_000);
        assert!(t > 1.0, "pass at paper scale: {t}s");
        assert!(c.pass_seconds(1) < 1e-3);
    }

    #[test]
    fn free_model_costs_nothing() {
        let c = CostModel::free();
        assert_eq!(c.pass_seconds(1_000_000), 0.0);
    }

    #[test]
    fn single_node_traversal_is_free() {
        let c = CostModel::default();
        assert_eq!(c.traversal_seconds(1_000_000, 1), 0.0);
        assert!(c.traversal_seconds(1_000_000, 2) > 0.0);
        let ring = CostModel { topology: Topology::Ring, ..c };
        assert_eq!(ring.traversal_seconds(1_000_000, 1), 0.0);
    }

    #[test]
    fn uniform_link_profile_is_the_exact_identity() {
        let lp = LinkProfile::uniform(6);
        assert!(lp.is_uniform());
        assert_eq!(lp.mult(0, 3), 1.0);
        assert_eq!(lp.mult(7, 99), 1.0); // out-of-range defaults to 1.0
        assert_eq!(lp.mean_mult(), 1.0);
    }

    #[test]
    fn seeded_link_profile_is_deterministic_and_heterogeneous() {
        let a = LinkProfile::seeded(8, 7);
        assert_eq!(a, LinkProfile::seeded(8, 7));
        assert_ne!(a, LinkProfile::seeded(8, 8));
        assert!(!a.is_uniform());
        assert_eq!(a.uplink.len(), 8);
        // one slow rack: some uplink well above nominal
        assert!(a.uplink.iter().cloned().fold(0.0, f64::max) > 2.0);
        // top-of-rack levels oversubscribed
        assert_eq!(a.level.last(), Some(&2.0));
        for &m in a.uplink.iter().chain(&a.level) {
            assert!(m.is_finite() && m > 0.0);
        }
    }

    #[test]
    fn link_profile_parses_and_range_checks() {
        let lp =
            LinkProfile::parse("uplink:2:3x,level:1:2x,rack:1:1.5x", 6)
                .unwrap();
        assert_eq!(lp.uplink[2], 3.0);
        assert_eq!(lp.level[1], 2.0);
        assert_eq!(lp.uplink[4], 1.5); // rack 1 = nodes 4..6 here
        assert_eq!(lp.uplink[5], 1.5);
        assert_eq!(lp.mult(1, 2), 6.0);
        for s in [
            "uplink:9:2x", // node out of range
            "rack:2:2x",   // rack past the fleet (P = 6 → racks 0..1)
            "level:40:2x", // level out of range
            "uplink:1:2",  // multiplier missing 'x'
            "uplink:1:0x", // zero multiplier
            "tor:1:2x",    // unknown kind
        ] {
            let e = LinkProfile::parse(s, 6).unwrap_err();
            assert!(e.starts_with("bad --link-profile spec"), "{s}: {e}");
            assert!(!e.contains('\n'), "one-line error: {e}");
        }
    }

    #[test]
    fn ring_sparse_traversal_charges_nnz_payload() {
        let c = CostModel::default();
        assert_eq!(c.ring_sparse_traversal_seconds(1e6, 1), 0.0);
        // a low-density payload must cost less than the dense ring pass
        // of the same dimension (1M coords × 8 B vs 120 KB of nnz)
        let sparse = c.ring_sparse_traversal_seconds(120e3, 8);
        let dense = c.traversal_seconds(1_000_000, 8);
        assert!(sparse < dense, "sparse {sparse} vs dense {dense}");
        // more nodes → more (cheaper) hops; latency-dominated growth
        assert!(
            c.ring_sparse_traversal_seconds(120e3, 16)
                > c.ring_sparse_traversal_seconds(120e3, 2)
        );
    }
}
