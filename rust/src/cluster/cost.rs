//! Communication cost model for the simulated AllReduce tree.
//!
//! Defaults model the paper's Hadoop-era gigabit cluster: 0.5 ms
//! per-hop latency, 1 Gbit/s links. With kdd2010's d = 20.21M features
//! a single f64 pass is ~162 MB ⇒ ~1.3 s/hop — communication dominates,
//! exactly the regime that makes FS's few-passes-per-iteration design
//! pay off. At the repro scale (d = 500k) a pass is ~4 MB ⇒ ~32 ms/hop.

/// Physical reduction topology — affects modeled *time* only (the
/// paper's communication-pass count is topology-independent: footnote 5
/// counts size-d vector traversals).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// binary AllReduce tree (the paper's [8] arrangement)
    #[default]
    Tree,
    /// bandwidth-optimal ring: reduce-scatter + all-gather, 2(P−1)
    /// hops of d/P-sized chunks
    Ring,
}

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-hop latency α (seconds)
    pub latency_s: f64,
    /// link bandwidth (bytes/second)
    pub bandwidth_bytes_per_s: f64,
    /// wire size of one vector component (8 = f64)
    pub bytes_per_scalar: usize,
    /// multiplier applied to measured per-node compute seconds —
    /// models nodes slower/faster than this machine's single core
    pub compute_scale: f64,
    /// physical reduction arrangement (time model only)
    pub topology: Topology,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            latency_s: 5e-4,
            bandwidth_bytes_per_s: 125e6, // 1 Gbit/s
            bytes_per_scalar: 8,
            compute_scale: 1.0,
            topology: Topology::Tree,
        }
    }
}

impl CostModel {
    /// A zero-cost model: pure algorithmic accounting (tests).
    pub fn free() -> CostModel {
        CostModel {
            latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
            bytes_per_scalar: 8,
            compute_scale: 0.0,
            topology: Topology::Tree,
        }
    }

    /// Seconds for one size-`dim` vector pass over one tree level.
    pub fn pass_seconds(&self, dim: usize) -> f64 {
        self.latency_s
            + (dim * self.bytes_per_scalar) as f64 / self.bandwidth_bytes_per_s
    }

    /// Seconds for one tree hop carrying `bytes` of payload — the
    /// building block the sparse phases charge per reduction level.
    pub fn hop_seconds(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bytes_per_s
    }

    /// Seconds for ONE logical traversal of a *sparse* payload of
    /// `bytes` over `nodes` on the Ring topology: the reduce-scatter
    /// (or all-gather) phase moves (P−1) chunk hops of bytes/P — the
    /// ring analogue of charging each tree level by its actual nnz
    /// payload. A single node has no wire.
    pub fn ring_sparse_traversal_seconds(
        &self,
        bytes: f64,
        nodes: usize,
    ) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        let p = nodes as f64;
        (p - 1.0) * self.hop_seconds(bytes / p)
    }

    /// Modeled seconds for ONE logical size-`dim` traversal (reduce or
    /// broadcast) over `nodes` nodes under the configured topology.
    /// A single-node cluster has no wire: zero seconds.
    pub fn traversal_seconds(&self, dim: usize, nodes: usize) -> f64 {
        if nodes <= 1 {
            return 0.0;
        }
        let bytes = (dim * self.bytes_per_scalar) as f64;
        match self.topology {
            Topology::Tree => {
                let depth = (nodes.max(2) as f64).log2().ceil();
                depth * self.pass_seconds(dim)
            }
            Topology::Ring => {
                // (P−1) chunk hops of size d/P for one phase
                // (reduce-scatter OR all-gather = one logical traversal)
                let p = nodes.max(2) as f64;
                (p - 1.0)
                    * (self.latency_s + bytes / p / self.bandwidth_bytes_per_s)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_comm_bound_at_paper_scale() {
        let c = CostModel::default();
        // kdd2010-scale pass must dwarf latency
        let t = c.pass_seconds(20_210_000);
        assert!(t > 1.0, "pass at paper scale: {t}s");
        assert!(c.pass_seconds(1) < 1e-3);
    }

    #[test]
    fn free_model_costs_nothing() {
        let c = CostModel::free();
        assert_eq!(c.pass_seconds(1_000_000), 0.0);
    }

    #[test]
    fn single_node_traversal_is_free() {
        let c = CostModel::default();
        assert_eq!(c.traversal_seconds(1_000_000, 1), 0.0);
        assert!(c.traversal_seconds(1_000_000, 2) > 0.0);
        let ring = CostModel { topology: Topology::Ring, ..c };
        assert_eq!(ring.traversal_seconds(1_000_000, 1), 0.0);
    }

    #[test]
    fn ring_sparse_traversal_charges_nnz_payload() {
        let c = CostModel::default();
        assert_eq!(c.ring_sparse_traversal_seconds(1e6, 1), 0.0);
        // a low-density payload must cost less than the dense ring pass
        // of the same dimension (1M coords × 8 B vs 120 KB of nnz)
        let sparse = c.ring_sparse_traversal_seconds(120e3, 8);
        let dense = c.traversal_seconds(1_000_000, 8);
        assert!(sparse < dense, "sparse {sparse} vs dense {dense}");
        // more nodes → more (cheaper) hops; latency-dominated growth
        assert!(
            c.ring_sparse_traversal_seconds(120e3, 16)
                > c.ring_sparse_traversal_seconds(120e3, 2)
        );
    }
}
