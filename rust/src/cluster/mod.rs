//! Simulated master–slave cluster with an AllReduce tree (the paper's
//! experimental substrate was an AllReduce tree on a Hadoop cluster
//! [8]; DESIGN.md §2 documents the substitution).
//!
//! The simulator executes the *actual* distributed protocol data-flow —
//! per-node shards, per-node compute closures, tree-ordered reductions —
//! and charges two ledgers:
//!
//! - **communication passes**: the paper's primary x-axis (footnote 5:
//!   one pass = one size-d vector traversal between nodes). A broadcast
//!   or a reduce is 1 pass; an allreduce is 2. Scalar rounds (line
//!   search trials) cost time but no passes.
//! - **simulated seconds**: measured per-node compute (max over nodes
//!   per phase, as P nodes would run concurrently) + modeled tree
//!   communication time (α per hop + bytes/bandwidth).

pub mod allreduce;
pub mod cost;
pub mod ledger;
pub mod node;

pub use cost::CostModel;
pub use ledger::Ledger;
pub use node::Shard;

use crate::data::dataset::Dataset;
use crate::data::partition::Partition;
use std::time::Instant;

/// The simulated cluster: P shards + the accounting state.
pub struct Cluster {
    pub shards: Vec<Shard>,
    pub cost: CostModel,
    pub dim: usize,
    pub ledger: Ledger,
    /// worker threads for map phases (1 = sequential)
    pub threads: usize,
}

impl Cluster {
    /// Partition `data` over `n_nodes` contiguous shards.
    pub fn partition(data: Dataset, n_nodes: usize, cost: CostModel) -> Cluster {
        let part = Partition::contiguous(data_len(&data), n_nodes);
        Self::partition_with(data, &part, cost)
    }

    pub fn partition_with(
        data: Dataset,
        partition: &Partition,
        cost: CostModel,
    ) -> Cluster {
        let dim = data.n_features();
        let shards = partition
            .assignment
            .iter()
            .map(|rows| {
                let sub = data.take(rows);
                Shard { x: sub.x, y: sub.y }
            })
            .collect();
        Cluster { shards, cost, dim, ledger: Ledger::default(), threads: 1 }
    }

    /// Same shards and cost model, fresh ledger — for computing
    /// reference optima or re-running a second method on identical data
    /// without inheriting the first run's accounting.
    pub fn fork_fresh(&self) -> Cluster {
        Cluster {
            shards: self.shards.clone(),
            cost: self.cost,
            dim: self.dim,
            ledger: Ledger::default(),
            threads: self.threads,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    pub fn n_examples(&self) -> usize {
        self.shards.iter().map(|s| s.x.n_rows()).sum()
    }

    /// Compute-only phase: run `f` on every node, charge the clock with
    /// the max per-node elapsed time (nodes run concurrently in the
    /// modeled cluster). No communication.
    pub fn map_each<T: Send>(
        &mut self,
        f: impl Fn(usize, &Shard) -> T + Sync,
    ) -> Vec<T> {
        let (outs, times) = self.run_nodes(&f);
        let max = times
            .iter()
            .enumerate()
            .map(|(p, t)| t * self.cost.node_compute_scale(p))
            .fold(0.0f64, f64::max);
        self.ledger.compute_seconds += max;
        outs
    }

    /// Compute phase followed by a size-d vector reduce (summed in tree
    /// order) whose result the master keeps. Charges 1 pass.
    pub fn map_reduce_vec(
        &mut self,
        f: impl Fn(usize, &Shard) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        let outs = self.map_each(f);
        let sum = allreduce::tree_sum(&outs);
        self.charge_vector_pass(1);
        sum
    }

    /// Allreduce: every node ends up holding the sum. Charges 2 passes
    /// (reduce up + broadcast down). The rust simulation returns the
    /// single master copy; node-local copies are implied.
    pub fn map_allreduce_vec(
        &mut self,
        f: impl Fn(usize, &Shard) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        let outs = self.map_each(f);
        let sum = allreduce::tree_sum(&outs);
        self.charge_vector_pass(2);
        sum
    }

    /// Tree-sum vectors the nodes already produced (via [`map_each`])
    /// and charge the passes: 1 for a master-only reduce, 2 for an
    /// allreduce leaving every node with the sum. Lets drivers keep the
    /// per-node parts (e.g. ∇L_p for the tilt) AND account the
    /// aggregation.
    pub fn reduce_parts(&mut self, parts: &[Vec<f64>], all: bool) -> Vec<f64> {
        let sum = allreduce::tree_sum(parts);
        self.charge_vector_pass(if all { 2 } else { 1 });
        sum
    }

    /// Master → nodes broadcast of a size-d vector. Charges 1 pass.
    /// (The data flow itself is implicit — nodes read the master copy —
    /// but the cost is real.)
    pub fn broadcast_vec(&mut self) {
        self.charge_vector_pass(1);
    }

    /// Scalar aggregation round (line-search trial): each node returns
    /// a handful of f64s which the tree sums. Costs latency-only time,
    /// zero passes (paper footnote 5 counts size-d vectors).
    pub fn map_reduce_scalars<const K: usize>(
        &mut self,
        f: impl Fn(usize, &Shard) -> [f64; K] + Sync,
    ) -> [f64; K] {
        let outs = self.map_each(f);
        let mut acc = [0.0; K];
        for o in outs {
            for (a, v) in acc.iter_mut().zip(o) {
                *a += v;
            }
        }
        let hops = 2.0 * self.tree_depth() as f64;
        self.ledger.comm_seconds += hops
            * (self.cost.latency_s
                + (K * 8) as f64 / self.cost.bandwidth_bytes_per_s);
        self.ledger.scalar_rounds += 1;
        acc
    }

    fn tree_depth(&self) -> u32 {
        (self.n_nodes().max(2) as f64).log2().ceil() as u32
    }

    fn charge_vector_pass(&mut self, passes: usize) {
        let per_pass = self.cost.traversal_seconds(self.dim, self.n_nodes());
        self.ledger.comm_passes += passes as f64;
        self.ledger.comm_seconds += passes as f64 * per_pass;
    }

    /// Run one closure per node, returning outputs and per-node seconds.
    fn run_nodes<T: Send>(
        &self,
        f: &(impl Fn(usize, &Shard) -> T + Sync),
    ) -> (Vec<T>, Vec<f64>) {
        if self.threads <= 1 || self.n_nodes() == 1 {
            let mut outs = Vec::with_capacity(self.n_nodes());
            let mut times = Vec::with_capacity(self.n_nodes());
            for (p, shard) in self.shards.iter().enumerate() {
                let t0 = Instant::now();
                outs.push(f(p, shard));
                times.push(t0.elapsed().as_secs_f64());
            }
            (outs, times)
        } else {
            let n = self.n_nodes();
            let mut slots: Vec<Option<(T, f64)>> = (0..n).map(|_| None).collect();
            let next = std::sync::atomic::AtomicUsize::new(0);
            let slots_ptr = std::sync::Mutex::new(&mut slots);
            std::thread::scope(|scope| {
                for _ in 0..self.threads.min(n) {
                    scope.spawn(|| loop {
                        let p = next
                            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        if p >= n {
                            break;
                        }
                        let t0 = Instant::now();
                        let out = f(p, &self.shards[p]);
                        let dt = t0.elapsed().as_secs_f64();
                        slots_ptr.lock().unwrap()[p] = Some((out, dt));
                    });
                }
            });
            let mut outs = Vec::with_capacity(n);
            let mut times = Vec::with_capacity(n);
            for s in slots {
                let (o, t) = s.expect("node closure completed");
                outs.push(o);
                times.push(t);
            }
            (outs, times)
        }
    }
}

fn data_len(d: &Dataset) -> usize {
    d.n_examples()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn cluster(nodes: usize) -> Cluster {
        let data = SynthConfig {
            n_examples: 120,
            n_features: 30,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(1);
        Cluster::partition(data, nodes, CostModel::default())
    }

    #[test]
    fn partition_preserves_examples() {
        let c = cluster(7);
        assert_eq!(c.n_nodes(), 7);
        assert_eq!(c.n_examples(), 120);
        assert!(c.shards.iter().all(|s| s.x.n_rows() > 0));
    }

    #[test]
    fn map_reduce_vec_sums_over_nodes() {
        let mut c = cluster(5);
        // per-node example counts, one-hot by node index
        let v = c.map_reduce_vec(|p, shard| {
            let mut out = vec![0.0; 30];
            out[p] = shard.x.n_rows() as f64;
            out
        });
        let total: f64 = v.iter().sum();
        assert_eq!(total, 120.0);
        assert_eq!(c.ledger.comm_passes, 1.0);
    }

    #[test]
    fn allreduce_charges_two_passes() {
        let mut c = cluster(4);
        let _ = c.map_allreduce_vec(|_, _| vec![1.0; 30]);
        assert_eq!(c.ledger.comm_passes, 2.0);
        assert!(c.ledger.comm_seconds > 0.0);
    }

    #[test]
    fn scalar_rounds_cost_no_passes() {
        let mut c = cluster(4);
        let [s] = c.map_reduce_scalars(|_, shard| [shard.x.n_rows() as f64]);
        assert_eq!(s, 120.0);
        assert_eq!(c.ledger.comm_passes, 0.0);
        assert_eq!(c.ledger.scalar_rounds, 1);
        assert!(c.ledger.comm_seconds > 0.0);
    }

    #[test]
    fn compute_clock_takes_max_over_nodes() {
        let mut c = cluster(3);
        c.map_each(|p, _| {
            // node 2 does 3x the work
            let mut acc = 0.0f64;
            let iters = if p == 2 { 300_000 } else { 100_000 };
            for i in 0..iters {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert!(c.ledger.compute_seconds > 0.0);
    }

    #[test]
    fn threaded_map_matches_sequential() {
        let mut c1 = cluster(6);
        let seq = c1.map_each(|p, s| (p, s.x.nnz()));
        let mut c2 = cluster(6);
        c2.threads = 3;
        let par = c2.map_each(|p, s| (p, s.x.nnz()));
        assert_eq!(seq, par);
    }

    #[test]
    fn more_nodes_means_deeper_tree_costs() {
        let mut c4 = cluster(4);
        let mut c16 = cluster(16);
        c4.broadcast_vec();
        c16.broadcast_vec();
        assert!(c16.ledger.comm_seconds > c4.ledger.comm_seconds);
        assert_eq!(c4.ledger.comm_passes, c16.ledger.comm_passes);
    }
}
