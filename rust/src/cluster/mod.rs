//! Simulated master–slave cluster with an AllReduce tree (the paper's
//! experimental substrate was an AllReduce tree on a Hadoop cluster
//! [8]; DESIGN.md §2 documents the substitution).
//!
//! The simulator executes the *actual* distributed protocol data-flow —
//! per-node shards, per-node compute closures, tree-ordered reductions —
//! and charges two ledgers:
//!
//! - **communication passes**: the paper's primary x-axis (footnote 5:
//!   one pass = one size-d vector traversal between nodes). A broadcast
//!   or a reduce is 1 pass; an allreduce is 2. Scalar rounds (line
//!   search trials) cost time but no passes.
//! - **simulated seconds**: an event-driven schedule computed by the
//!   [`engine::Engine`] — one virtual clock per node (scaled by the
//!   seeded [`engine::NodeProfile`]), reduction-tree hops that start at
//!   `max(children ready)`, and an optional pipelined mode where
//!   control-lane traffic (direction combine, safeguard, line search)
//!   overlaps the next round's node compute. [`Ledger::seconds`]
//!   reports the schedule's critical-path makespan;
//!   `comm_seconds`/`compute_seconds` keep the flat barrier-equivalent
//!   component breakdown (identical to the makespan for homogeneous,
//!   non-pipelined runs).

pub mod allreduce;
pub mod cost;
pub mod engine;
pub mod faults;
pub mod ledger;
pub mod node;
pub mod scratch;

pub use cost::{CostModel, LinkProfile};
pub use engine::{Engine, NodeProfile};
pub use faults::{
    FaultPlan, FaultState, LinkFaultPlan, LinkFaultState, LinkPartition,
    RoundWeather,
};
pub use ledger::Ledger;
pub use node::Shard;
pub use scratch::NodeScratch;

use crate::data::dataset::Dataset;
use crate::data::partition::Partition;
use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::obs;
use crate::util::json::Value;
use self::allreduce::Reduced;
use self::engine::Lane;
use std::sync::Mutex;
use std::time::Instant;

/// Union-support density below which drivers run their outer loop on
/// the compact master (see [`Cluster::prefer_compact_master`]). Matches
/// the `prefer_sparse` wire threshold: past 0.5 the support-position
/// indirection stops paying for itself.
pub const COMPACT_MASTER_MAX_DENSITY: f64 = 0.5;

/// Default worker-thread count for map phases: every available core.
/// The `--threads` CLI flag (0 = this auto value) overrides it.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Debug/audit invariant: every coordinate leaving a reduction must be
/// finite. A NaN/Inf gradient or direction should fail loudly at the
/// reduce that produced it, not surface three modules later as a silent
/// AUPRC regression.
#[cfg(any(debug_assertions, feature = "audit"))]
fn assert_reduced_finite(label: &str, vals: &[f64]) {
    for (j, v) in vals.iter().enumerate() {
        assert!(
            v.is_finite(),
            "non-finite coordinate {j} ({v}) out of {label}"
        );
    }
}

#[cfg(not(any(debug_assertions, feature = "audit")))]
#[inline(always)]
fn assert_reduced_finite(_label: &str, _vals: &[f64]) {}

/// The reduced values behind either wire format, for the finite guard.
#[cfg(any(debug_assertions, feature = "audit"))]
fn reduced_vals(out: &Reduced) -> &[f64] {
    match out {
        Reduced::Sparse(s) => &s.val,
        Reduced::Dense(v) => v,
    }
}

/// The simulated cluster: P shards + the accounting state.
pub struct Cluster {
    pub shards: Vec<Shard>,
    pub cost: CostModel,
    pub dim: usize,
    /// union support U = ⋃_p support_p, built once at partition time —
    /// the global column dictionary the union-support compact master
    /// runs its entire outer loop in (length-|U| buffers instead of
    /// full-d vectors; see `algo::fs`). Each shard carries its
    /// composed positions within U (`Shard::upos`).
    pub umap: SupportMap,
    pub ledger: Ledger,
    /// worker threads for map phases (defaults to every available
    /// core; set to 1 for sequential execution). Results are
    /// bit-identical across thread counts; note that *measured*
    /// per-node compute seconds include real memory/cache contention
    /// when nodes run concurrently — for contention-free per-node
    /// compute modeling, run with `threads = 1`.
    pub threads: usize,
    /// per-node reusable scratch buffers (see [`NodeScratch`]) — the
    /// reason steady-state compact solves allocate nothing
    pub scratch: Vec<Mutex<NodeScratch>>,
    /// the event-driven timing engine: per-node virtual clocks, the
    /// control lane, and the recorded timeline (see [`engine`])
    pub engine: Engine,
    /// per-node liveness under the fault layer: `alive[p] == false`
    /// means node p crashed out of the membership (its shard is absent
    /// from the round) and has not yet been restarted. All-true
    /// without a fault plan.
    pub alive: Vec<bool>,
    /// seeded fault-injection state ([`faults::FaultState`]); `None`
    /// when no plan is installed — and an installed *empty* plan
    /// behaves bit-identically to `None` (`tests/faults.rs` pins it)
    pub faults: Option<FaultState>,
    /// per-link bandwidth/latency multipliers over the reduction tree
    /// ([`cost::LinkProfile`]); `None` — and an installed *uniform*
    /// profile — leaves every hop at the global [`CostModel`] cost
    /// (`tests/faults.rs` pins the bit-identity)
    pub links: Option<LinkProfile>,
    /// seeded link-weather state ([`faults::LinkFaultState`]):
    /// congestion/flap coins and partition windows on the tree edges;
    /// `None` — and an installed *empty* plan — is the ideal wire
    pub link_faults: Option<LinkFaultState>,
    /// flight-recorder sink (`--metrics-out`); `None` means recording
    /// is off and every `record_*` hook is an early-return — the off
    /// path is bit-identical (`tests/obs.rs` pins it). The recorder
    /// only *observes*: it charges no virtual time, passes, or bytes.
    recorder: Option<Box<dyn obs::Recorder>>,
}

impl Cluster {
    /// Partition `data` over `n_nodes` contiguous shards.
    pub fn partition(data: Dataset, n_nodes: usize, cost: CostModel) -> Cluster {
        let part = Partition::contiguous(data_len(&data), n_nodes);
        Self::partition_with(data, &part, cost)
    }

    pub fn partition_with(
        data: Dataset,
        partition: &Partition,
        cost: CostModel,
    ) -> Cluster {
        let dim = data.n_features();
        let mut shards: Vec<Shard> = partition
            .assignment
            .iter()
            .map(|rows| {
                let sub = data.take(rows);
                Shard::new(sub.x, sub.y)
            })
            .collect();
        // union support + each shard's composed positions within it —
        // the compact master's global dictionary (built once, O(Σ|S_p|))
        let umap = SupportMap::union_of(shards.iter().map(|s| &s.map));
        for shard in &mut shards {
            shard.upos = umap.positions_of(&shard.map);
        }
        let scratch = NodeScratch::pool(shards.len());
        // nodes start homogeneous; straggler/heterogeneous scenarios
        // install a profile via Cluster::set_profile
        let engine = Engine::new(NodeProfile::homogeneous(shards.len()));
        let alive = vec![true; engine.n_nodes()];
        Cluster {
            shards,
            cost,
            dim,
            umap,
            ledger: Ledger::default(),
            threads: default_threads(),
            scratch,
            engine,
            alive,
            faults: None,
            links: None,
            link_faults: None,
            recorder: None,
        }
    }

    /// Same shards, cost model and node profile, fresh ledger and
    /// virtual clocks — for computing reference optima or re-running a
    /// second method on identical data without inheriting the first
    /// run's accounting.
    pub fn fork_fresh(&self) -> Cluster {
        let mut engine = Engine::new(self.engine.profile.clone());
        engine.pipeline = self.engine.pipeline;
        Cluster {
            shards: self.shards.clone(),
            cost: self.cost,
            dim: self.dim,
            umap: self.umap.clone(),
            ledger: Ledger::default(),
            threads: self.threads,
            scratch: NodeScratch::pool(self.shards.len()),
            engine,
            alive: vec![true; self.shards.len()],
            // same plan, fresh runtime state (nothing fired, empty log)
            faults: self
                .faults
                .as_ref()
                .map(|s| FaultState::new(s.plan.clone())),
            // the wire's shape travels with the fork; its weather
            // state restarts (nothing fired, empty link log)
            links: self.links.clone(),
            link_faults: self
                .link_faults
                .as_ref()
                .map(|s| LinkFaultState::new(s.plan.clone())),
            // a fork is a new run: it does not inherit the sink
            recorder: None,
        }
    }

    /// Install a flight-recorder sink (see [`crate::obs`]). The
    /// manifest should be recorded immediately after, before the
    /// driver runs.
    pub fn set_recorder(&mut self, rec: Box<dyn obs::Recorder>) {
        self.recorder = Some(rec);
    }

    /// Is a recorder installed? Drivers cache this once per run
    /// (via [`crate::obs::RoundObs`]) so the off path costs one branch.
    pub fn is_recording(&self) -> bool {
        self.recorder.is_some()
    }

    /// Emit the run-manifest header record (no-op when off).
    pub fn record_manifest(&mut self, m: &obs::RunManifest) {
        if let Some(r) = self.recorder.as_mut() {
            r.manifest(m);
        }
    }

    /// Emit one round record (no-op when off).
    pub fn record_round(&mut self, rec: &obs::RoundRecord) {
        if let Some(r) = self.recorder.as_mut() {
            r.round(rec);
        }
    }

    /// Flush and drop the sink (end of run). Safe to call when off.
    pub fn finish_recording(&mut self) {
        if let Some(mut r) = self.recorder.take() {
            r.close();
        }
    }

    /// Applied-fault log length — the watermark [`crate::obs::RoundObs`]
    /// diffs to attribute fault events to rounds. 0 without a plan.
    pub fn fault_log_len(&self) -> usize {
        self.faults.as_ref().map_or(0, |s| s.log.len())
    }

    /// One applied-fault log entry as `(round, node, what)`.
    pub fn fault_log_entry(
        &self,
        i: usize,
    ) -> Option<(usize, usize, &'static str)> {
        self.faults
            .as_ref()
            .and_then(|s| s.log.get(i))
            .map(|e| (e.round, e.node, e.what))
    }

    /// Applied link-event log length (partitions/heals) — a *separate*
    /// watermark from [`Self::fault_log_len`]: the two logs grow
    /// independently within a round, so concatenated indexing would
    /// break the per-round diffs. 0 without a link plan.
    pub fn link_log_len(&self) -> usize {
        self.link_faults.as_ref().map_or(0, |s| s.log.len())
    }

    /// One applied link-event log entry as `(round, node, what)`.
    pub fn link_log_entry(
        &self,
        i: usize,
    ) -> Option<(usize, usize, &'static str)> {
        self.link_faults
            .as_ref()
            .and_then(|s| s.log.get(i))
            .map(|e| (e.round, e.node, e.what))
    }

    /// Install a per-node speed profile (resets the engine's clocks —
    /// call before running a method). Panics on a length mismatch.
    pub fn set_profile(&mut self, profile: NodeProfile) {
        assert_eq!(
            profile.speed.len(),
            self.n_nodes(),
            "profile length must match node count"
        );
        let pipeline = self.engine.pipeline;
        self.engine = Engine::new(profile);
        self.engine.pipeline = pipeline;
    }

    /// Toggle the pipelined schedule (drivers set this from their
    /// config; it affects *timing only* — results are bit-identical).
    pub fn set_pipeline(&mut self, on: bool) {
        self.engine.pipeline = on;
    }

    /// Install a seeded fault plan (see [`faults`]). Call before
    /// running a method; the fault-tolerant async FS driver advances
    /// it once per outer round via [`Self::apply_fault_weather`].
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.faults = Some(FaultState::new(plan));
    }

    /// Install a per-link cost profile (see [`cost::LinkProfile`]).
    /// A uniform profile is structurally inert: every comm entry point
    /// keeps the legacy single-cost code path. Panics on a length
    /// mismatch, mirroring [`Self::set_profile`].
    pub fn set_link_profile(&mut self, profile: LinkProfile) {
        assert_eq!(
            profile.uplink.len(),
            self.n_nodes(),
            "link profile length must match node count"
        );
        self.links = Some(profile);
    }

    /// Install a seeded link-weather plan (see
    /// [`faults::LinkFaultPlan`]). An empty plan is structurally inert,
    /// like an empty [`FaultPlan`].
    pub fn set_link_fault_plan(&mut self, plan: LinkFaultPlan) {
        self.link_faults = Some(LinkFaultState::new(plan));
    }

    /// Does any comm phase need the link layer at all? False for no
    /// profile / a uniform profile AND no plan / an empty plan — the
    /// gate behind the structural bit-identity guarantee: when it is
    /// false every entry point runs the legacy code path verbatim.
    pub fn link_active(&self) -> bool {
        self.links.as_ref().is_some_and(|l| !l.is_uniform())
            || self
                .link_faults
                .as_ref()
                .is_some_and(|s| !s.plan.is_empty())
    }

    /// Mean link multiplier for acked fan-out paths (broadcasts, ring
    /// segments, scalar rounds, rejoin unicasts): those carry no
    /// per-edge retry discipline, so they scale by the profile's mean.
    /// Exactly 1.0 for a uniform (or absent) profile.
    fn link_mean_mult(&self) -> f64 {
        if !self.link_active() {
            return 1.0;
        }
        self.links.as_ref().map_or(1.0, |p| p.mean_mult())
    }

    /// The currently-alive node ids, ascending.
    pub fn alive_nodes(&self) -> Vec<usize> {
        (0..self.n_nodes()).filter(|&p| self.alive[p]).collect()
    }

    /// Advance the fault layer to round `r` and apply everything due:
    /// crashes flip `alive` off (never the last survivor — the final
    /// member ignores its crash order so the membership can't empty),
    /// restarts flip it back on and are reported for the driver to
    /// re-base, degrades rescale the profile in place, flaps pick this
    /// round's transient dropouts, and the wire-loss coins decide
    /// which member contributions retry or drop. Without a plan this
    /// returns clear weather over the full membership and touches
    /// nothing — the zero-fault path.
    pub fn apply_fault_weather(&mut self, r: usize) -> RoundWeather {
        let n = self.n_nodes();
        if self.faults.is_none() && self.link_faults.is_none() {
            return RoundWeather::clear(n);
        }
        let now = self.engine.makespan();
        let mut weather = RoundWeather::default();
        let due = self
            .faults
            .as_mut()
            .map(|s| s.due(r, now))
            .unwrap_or_default();
        for kind in due {
            match kind {
                faults::FaultKind::Crash(p) => {
                    let survivors =
                        self.alive.iter().filter(|&&a| a).count();
                    if p < n && self.alive[p] && survivors > 1 {
                        self.alive[p] = false;
                        weather.crashed.push(p);
                        self.ledger.crash_events += 1;
                        if let Some(s) = self.faults.as_mut() {
                            s.record(r, p, "crash");
                        }
                        self.engine.fault_event("fault_crash", p, now);
                    }
                }
                faults::FaultKind::Restart(p) => {
                    if p < n && !self.alive[p] {
                        self.alive[p] = true;
                        weather.restarted.push(p);
                        if let Some(s) = self.faults.as_mut() {
                            s.record(r, p, "restart");
                        }
                        self.engine.fault_event("fault_restart", p, now);
                    }
                }
                faults::FaultKind::Degrade(p, factor) => {
                    if p < n {
                        // 0.25x throughput ⇒ 4× the compute seconds,
                        // in place — clocks are NOT reset
                        let speed = self.engine.profile.scale(p) / factor;
                        self.engine.set_speed(p, speed);
                        self.ledger.degrade_events += 1;
                        if let Some(s) = self.faults.as_mut() {
                            s.record(r, p, "degrade");
                        }
                        self.engine.fault_event("fault_degrade", p, now);
                    }
                }
            }
        }
        // transient flaps: alive nodes sitting this round out, capped
        // so the round always keeps at least one member
        let alive_now = self.alive_nodes();
        let mut out: Vec<usize> = Vec::new();
        if let Some(s) = self.faults.as_ref() {
            for &p in &alive_now {
                if s.flaps(r, p) {
                    out.push(p);
                }
            }
        }
        while !out.is_empty() && out.len() >= alive_now.len() {
            out.pop();
        }
        for &p in &out {
            self.ledger.flap_events += 1;
            if let Some(s) = self.faults.as_mut() {
                s.record(r, p, "flap");
            }
            self.engine.fault_event("fault_flap", p, now);
        }
        let members: Vec<usize> = alive_now
            .into_iter()
            .filter(|p| !out.contains(p))
            .collect();
        // wire loss on each member's direction contribution:
        // retry-then-timeout, absorbed by the partial quorum
        for &p in &members {
            match self.faults.as_ref().and_then(|s| s.wire_fate(r, p)) {
                None => {}
                Some(Some(delay)) => {
                    weather.delayed.push((p, delay));
                    self.ledger.retry_rounds += 1;
                    if let Some(s) = self.faults.as_mut() {
                        s.record(r, p, "retry");
                    }
                }
                Some(None) => {
                    weather.dropped.push(p);
                    self.ledger.lost_messages += 1;
                    if let Some(s) = self.faults.as_mut() {
                        s.record(r, p, "drop");
                    }
                    self.engine.fault_event("fault_drop", p, now);
                }
            }
        }
        let mut members = members;
        // link partitions: the cut component vanishes from the quorum's
        // view exactly like a crashed member set — but the nodes are
        // NOT dead: their solver lanes keep running, and on heal
        // anything within the staleness bound rejoins the quorum. The
        // script grammar guarantees node 0 (the master's component) is
        // never cut, so the surviving frame always holds the reference
        // iterate; if a cut would empty the round anyway (every other
        // member crashed or flapped), the cut is ignored — no link
        // state can hang a round.
        let mut cut_now: Vec<usize> = Vec::new();
        let mut healed_now: Vec<usize> = Vec::new();
        let mut n_cuts = 0usize;
        let mut active_cut: Vec<usize> = Vec::new();
        if let Some(state) = self.link_faults.as_mut() {
            state.round = r;
            for i in state.due_cuts(r) {
                n_cuts += 1;
                let nodes = state.plan.partitions[i].nodes.clone();
                for &p in &nodes {
                    state.record(r, p, "partition");
                    cut_now.push(p);
                }
            }
            for i in state.due_heals(r) {
                let nodes = state.plan.partitions[i].nodes.clone();
                for &p in &nodes {
                    state.record(r, p, "heal");
                    healed_now.push(p);
                }
            }
            if !healed_now.is_empty() && state.master_isolated {
                // the cut that just healed had isolated the master:
                // route this round through the certified synchronous
                // fallback so the whole fleet resynchronizes
                weather.heal_resync = true;
                state.master_isolated = false;
            }
            active_cut = state.plan.cut_at(r);
        }
        self.ledger.partition_events += n_cuts;
        for &p in &cut_now {
            self.engine.fault_event("link_partition", p, now);
        }
        healed_now.sort_unstable();
        healed_now.dedup();
        healed_now.retain(|&p| self.alive[p]);
        for &p in &healed_now {
            self.engine.fault_event("link_heal", p, now);
        }
        weather.healed = healed_now;
        if !active_cut.is_empty() {
            let kept: Vec<usize> = members
                .iter()
                .copied()
                .filter(|p| active_cut.binary_search(p).is_err())
                .collect();
            if !kept.is_empty() {
                members = kept;
            }
            if members.len() == 1 && members[0] == 0 {
                if let Some(state) = self.link_faults.as_mut() {
                    state.master_isolated = true;
                }
            }
        }
        weather.members = members;
        weather
    }

    /// Re-base a restarted node onto the current iterate: the master
    /// unicasts the O(`len`) compact state down the node's tree path,
    /// the node's frozen clock resumes at the transfer's completion
    /// (it cannot act in its own past), and the recovery rides the
    /// ledger (`rejoin_rebases`, `recovery_seconds`, plus the wire
    /// bytes). The payload reuses the affine wire format's compact
    /// representation, so it doubles as the O(|U|) checkpoint.
    pub fn rejoin_rebase(&mut self, node: usize, len: usize) {
        let now = self.engine.makespan();
        let bytes = (len * self.cost.bytes_per_scalar) as f64;
        let secs = self.tree_depth() as f64
            * self.cost.hop_seconds(bytes)
            * self.link_mean_mult();
        self.ledger.comm_passes += 1.0;
        self.ledger.comm_bytes += bytes;
        self.ledger.comm_seconds += secs;
        self.ledger.rejoin_rebases += 1;
        self.ledger.recovery_seconds += secs;
        self.engine.unicast("rejoin_rebase", node, now, secs);
        self.sync_ledger();
    }

    /// The engine timeline plus a `resilience` block: the PR-4
    /// staleness/fallback counters and the fault-layer accounting, so
    /// `--trace-timeline` exports carry the whole robustness story.
    /// The engine's own export shape is unchanged (`tests/engine.rs`);
    /// the added fields are pinned by `tests/faults.rs`.
    pub fn timeline_json(&self) -> Value {
        let mut v = self.engine.timeline_json();
        if let Value::Obj(map) = &mut v {
            let l = &self.ledger;
            let hist: Vec<Value> = l
                .staleness_hist
                .iter()
                .map(|&c| Value::Num(c as f64))
                .collect();
            let alive: Vec<Value> =
                self.alive.iter().map(|&a| Value::Bool(a)).collect();
            map.insert(
                "resilience".to_string(),
                Value::obj(vec![
                    ("staleness_hist", Value::Arr(hist)),
                    ("async_rounds", Value::Num(l.async_rounds as f64)),
                    (
                        "fallback_rounds",
                        Value::Num(l.fallback_rounds as f64),
                    ),
                    ("crash_events", Value::Num(l.crash_events as f64)),
                    (
                        "rejoin_rebases",
                        Value::Num(l.rejoin_rebases as f64),
                    ),
                    ("lost_messages", Value::Num(l.lost_messages as f64)),
                    ("retry_rounds", Value::Num(l.retry_rounds as f64)),
                    (
                        "degrade_events",
                        Value::Num(l.degrade_events as f64),
                    ),
                    ("flap_events", Value::Num(l.flap_events as f64)),
                    (
                        "recovery_seconds",
                        Value::Num(l.recovery_seconds),
                    ),
                    ("retry_seconds", Value::Num(l.retry_seconds)),
                    ("alive", Value::Arr(alive)),
                ]),
            );
            map.insert(
                "link_events".to_string(),
                Value::obj(vec![
                    ("link_retries", Value::Num(l.link_retries as f64)),
                    ("reroutes", Value::Num(l.reroutes as f64)),
                    (
                        "congested_hops",
                        Value::Num(l.congested_hops as f64),
                    ),
                    (
                        "partition_events",
                        Value::Num(l.partition_events as f64),
                    ),
                    ("retry_seconds", Value::Num(l.retry_seconds)),
                ]),
            );
        }
        v
    }

    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    pub fn n_examples(&self) -> usize {
        self.shards.iter().map(|s| s.xl.n_rows()).sum()
    }

    /// Mean over shards of the fraction of columns the shard touches —
    /// the auto-switch signal for the sparse gradient pipeline.
    pub fn support_density(&self) -> f64 {
        if self.shards.is_empty() || self.dim == 0 {
            return 1.0;
        }
        let sum: f64 = self
            .shards
            .iter()
            .map(|s| s.map.support.len() as f64)
            .sum();
        sum / (self.shards.len() * self.dim) as f64
    }

    /// Should gradient rounds use the sparse phases? Sparse pays
    /// 12 B/nnz vs 8 B/coordinate, so it wins well below the 2/3 wire
    /// break-even; 0.5 leaves headroom for union growth up the tree.
    /// Both topologies are modeled: the Tree path charges per-level
    /// message sizes, the Ring path charges the reduce-scatter by the
    /// merged nnz payload (see [`CostModel::ring_sparse_traversal_seconds`]).
    pub fn prefer_sparse(&self) -> bool {
        self.support_density() < 0.5
    }

    /// Fraction of the d columns the *union* support covers — the
    /// density the compact-master gate tests. Always ≥ the mean shard
    /// density [`Self::support_density`], so `prefer_compact_master`
    /// implies `prefer_sparse`.
    pub fn union_density(&self) -> f64 {
        self.umap.density(self.dim)
    }

    /// Density gate for the union-support compact master (the
    /// companion of [`Self::prefer_sparse`], same 0.5 threshold): run
    /// the drivers' entire outer loop in O(|U|) compact buffers when
    /// the union support covers less than
    /// [`COMPACT_MASTER_MAX_DENSITY`] of the d columns. Below the
    /// threshold the compact master wins on every O(d) pass it
    /// replaces (norms, dots, the step-7 combine, the line-search λ
    /// scalars, the step-9 axpy) *and* on master memory; above it the
    /// |U|-indirection buys nothing over plain dense vectors, so
    /// drivers fall back to the dense master. Arithmetic is
    /// ε-identical either way (`tests/compact_master.rs` pins it);
    /// only buffer sizes and wire/byte accounting change.
    pub fn prefer_compact_master(&self) -> bool {
        self.union_density() < COMPACT_MASTER_MAX_DENSITY
    }

    /// Compute-only phase: run `f` on every node, charge the clock with
    /// the max per-node elapsed time (nodes run concurrently in the
    /// modeled cluster). No communication.
    pub fn map_each<T: Send>(
        &mut self,
        f: impl Fn(usize, &Shard) -> T + Sync,
    ) -> Vec<T> {
        let (outs, times) = self.run_nodes(&f);
        self.charge_compute(&times);
        outs
    }

    /// [`Self::map_each`] handing every node its reusable
    /// [`NodeScratch`] slot. Each node's slot is locked for exactly the
    /// duration of its closure (one worker per node — the lock is never
    /// contended), so threaded map phases stay safe while steady-state
    /// per-node buffers persist across outer iterations.
    pub fn map_each_scratch<T: Send>(
        &mut self,
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
    ) -> Vec<T> {
        self.map_each_scratch_lane(f, false)
    }

    /// [`Self::map_each_scratch`] on the control lane: in pipelined
    /// mode the phase rides the master chain (the tiny direction
    /// matvec the line search needs) instead of stalling the
    /// self-paced node clocks; otherwise identical to
    /// [`Self::map_each_scratch`].
    pub fn map_each_scratch_ctrl<T: Send>(
        &mut self,
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
    ) -> Vec<T> {
        self.map_each_scratch_lane(f, true)
    }

    fn map_each_scratch_lane<T: Send>(
        &mut self,
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
        ctrl: bool,
    ) -> Vec<T> {
        let scratch = &self.scratch;
        let g = |p: usize, shard: &Shard| -> T {
            let mut slot = scratch[p].lock().expect("scratch lock");
            f(p, shard, &mut slot)
        };
        let (outs, times) = self.run_nodes(&g);
        self.charge_compute_lane(&times, ctrl);
        outs
    }

    /// Run `f` on a *subset* of nodes (each with its scratch slot),
    /// returning per-node outputs paired with their measured seconds
    /// and charging NOTHING — the async FS driver schedules these
    /// solves on its own per-node solver lanes (see
    /// [`engine::Engine::solver_event`]) instead of the barrier'd
    /// node clocks.
    pub fn map_nodes_timed<T: Send>(
        &self,
        nodes: &[usize],
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
    ) -> Vec<(T, f64)> {
        let scratch = &self.scratch;
        let g = |p: usize, shard: &Shard| -> T {
            let mut slot = scratch[p].lock().expect("scratch lock");
            f(p, shard, &mut slot)
        };
        self.run_subset(nodes, &g)
    }

    fn charge_compute(&mut self, times: &[f64]) {
        self.charge_compute_lane(times, false);
    }

    fn charge_compute_lane(&mut self, times: &[f64], ctrl: bool) {
        let max = if ctrl && self.engine.pipeline {
            self.engine.compute_control(self.cost.compute_scale, times)
        } else {
            self.engine.compute(self.cost.compute_scale, times)
        };
        self.ledger.compute_seconds += max;
        self.sync_ledger();
    }

    /// Mirror the engine's critical path onto the ledger after every
    /// charge — [`Ledger::seconds`] is a view over the timeline.
    fn sync_ledger(&mut self) {
        self.ledger.makespan = Some(self.engine.makespan());
    }

    fn lane(ctrl: bool) -> Lane {
        if ctrl {
            Lane::Control
        } else {
            Lane::Node
        }
    }

    /// Schedule one dense tree/ring traversal set on the engine
    /// (`up` = reduce toward the master, `down` = broadcast of the
    /// result). The ledger's flat `comm_seconds` charge stays in
    /// [`Self::charge_vector_pass`]; this models *when* the hops run.
    fn engine_dense_traversal(&mut self, up: bool, down: bool, ctrl: bool) {
        let depth = self.tree_depth() as usize;
        match self.cost.topology {
            cost::Topology::Tree => {
                let hop = if self.n_nodes() <= 1 {
                    0.0
                } else {
                    self.cost.pass_seconds(self.dim)
                };
                if up {
                    let hops = vec![hop; depth];
                    let d = if down { Some((depth, hop)) } else { None };
                    self.engine.tree_reduce("reduce", &hops, d, Self::lane(ctrl));
                } else if down {
                    self.engine.broadcast(depth, hop);
                }
            }
            cost::Topology::Ring => {
                let per = self.cost.traversal_seconds(self.dim, self.n_nodes());
                let passes = (up as usize + down as usize) as f64;
                self.engine.ring_traversal("ring", passes * per);
            }
        }
        self.sync_ledger();
    }

    /// The one link-aware climb behind every reduce entry point when
    /// [`Self::link_active`] is true: builds the per-hop outcome
    /// closure (profile multiplier → congestion coin → timeout/retry
    /// ladder → reroute past the budget), schedules the climb on the
    /// engine (barrier-ordered, or arrival-ordered when `arrivals` is
    /// given), and charges the ledger — the critical chain's wire
    /// share to `comm_seconds`, its timeout/backoff share to the
    /// distinct `retry_seconds`, plus the per-hop event counters. The
    /// optional down-sweep hop is scaled by the mean link multiplier
    /// (the fan-out is acked multicast: no per-edge retry discipline).
    /// Returns the landing time.
    fn linked_reduce(
        &mut self,
        label: &'static str,
        arrivals: Option<&[(usize, f64, usize)]>,
        hops: &[f64],
        down: Option<(usize, f64)>,
        ctrl: bool,
        members: &[usize],
    ) -> f64 {
        let mean = self.links.as_ref().map_or(1.0, |p| p.mean_mult());
        let down = down.map(|(d, h)| (d, h * mean));
        let round = self.link_faults.as_ref().map_or(0, |s| s.round);
        let links = &self.links;
        let lf = &self.link_faults;
        let ledger = &mut self.ledger;
        let mut link = |level: usize,
                        sender: usize,
                        base: f64|
         -> engine::HopOutcome {
            let m = links.as_ref().map_or(1.0, |p| p.mult(level, sender));
            let mut secs = base * m;
            let mut retry_secs = 0.0;
            let mut rerouted = false;
            if let Some(state) = lf.as_ref() {
                let plan = &state.plan;
                if plan.congested(round, level, sender) {
                    secs *= plan.congest_mult;
                    ledger.congested_hops += 1;
                }
                let k = plan.failed_attempts(round, level, sender);
                if k > 0 {
                    if plan.no_retry {
                        // the ablation arm: no deadline discipline, the
                        // payload sits out the whole dead window until
                        // the link recovers on its own
                        let wait =
                            plan.timeout_s * (1u64 << k) as f64;
                        secs += wait;
                        retry_secs = wait;
                    } else if k <= plan.retry_budget {
                        // exponential backoff: rungs t, 2t, 4t, … sum
                        // to t·(2^k − 1) before the attempt that lands
                        let back = plan.timeout_s
                            * ((1u64 << k) as f64 - 1.0);
                        secs += back;
                        retry_secs = back;
                        ledger.link_retries += k as usize;
                    } else {
                        // budget exhausted: abandon the edge and
                        // re-parent one level up — the detour doubles
                        // the wire time on top of the burned ladder
                        let back = plan.timeout_s
                            * ((1u64 << plan.retry_budget) as f64 - 1.0);
                        secs = 2.0 * secs + back;
                        retry_secs = back;
                        rerouted = true;
                        ledger.link_retries += plan.retry_budget as usize;
                        ledger.reroutes += 1;
                    }
                }
            }
            engine::HopOutcome { secs, retry_secs, rerouted }
        };
        let (landed, totals) = match arrivals {
            Some(arr) => self.engine.quorum_reduce_linked_members(
                label, arr, hops, down, members, &mut link,
            ),
            None => self.engine.tree_reduce_linked_members(
                label,
                hops,
                down,
                Self::lane(ctrl),
                members,
                &mut link,
            ),
        };
        self.ledger.comm_seconds += totals.comm_secs
            + down.map_or(0.0, |(d, h)| d as f64 * h);
        self.ledger.retry_seconds += totals.retry_secs;
        self.sync_ledger();
        landed
    }

    /// Linked analogue of [`Self::charge_vector_pass`] +
    /// [`Self::engine_dense_traversal`] for the dense size-d reduce
    /// entry points: per-hop outcomes on the Tree, the mean link
    /// multiplier on the Ring (ring segments are acked pipelines — no
    /// per-edge retry discipline; see lib.rs `## Network model`).
    fn dense_linked_traversal(&mut self, all: bool, ctrl: bool) {
        let passes = if all { 2usize } else { 1 };
        #[cfg(feature = "audit")]
        let marks = self.engine.comm_marks();
        self.ledger.comm_passes += passes as f64;
        self.ledger.comm_bytes +=
            (passes * self.dim * self.cost.bytes_per_scalar) as f64;
        match self.cost.topology {
            cost::Topology::Tree => {
                let depth = self.tree_depth() as usize;
                let hop = if self.n_nodes() <= 1 {
                    0.0
                } else {
                    self.cost.pass_seconds(self.dim)
                };
                let hops = vec![hop; depth];
                let down = if all { Some((depth, hop)) } else { None };
                let members: Vec<usize> = (0..self.n_nodes()).collect();
                self.linked_reduce("reduce", None, &hops, down, ctrl, &members);
            }
            cost::Topology::Ring => {
                let per = self
                    .cost
                    .traversal_seconds(self.dim, self.n_nodes())
                    * self.link_mean_mult();
                let secs = passes as f64 * per;
                self.ledger.comm_seconds += secs;
                self.engine.ring_traversal("ring", secs);
                self.sync_ledger();
            }
        }
        #[cfg(feature = "audit")]
        assert!(
            self.engine.comm_marks() > marks,
            "linked traversal charged comm bytes with no engine event"
        );
    }

    /// Compute phase followed by a size-d vector reduce (summed in tree
    /// order) whose result the master keeps. Charges 1 pass.
    pub fn map_reduce_vec(
        &mut self,
        f: impl Fn(usize, &Shard) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        let outs = self.map_each(f);
        let sum = allreduce::tree_sum(&outs);
        assert_reduced_finite("map_reduce_vec", &sum);
        if self.link_active() {
            self.dense_linked_traversal(false, false);
        } else {
            self.charge_vector_pass(1);
            self.engine_dense_traversal(true, false, false);
        }
        sum
    }

    /// Allreduce: every node ends up holding the sum. Charges 2 passes
    /// (reduce up + broadcast down). The rust simulation returns the
    /// single master copy; node-local copies are implied.
    pub fn map_allreduce_vec(
        &mut self,
        f: impl Fn(usize, &Shard) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        let outs = self.map_each(f);
        let sum = allreduce::tree_sum(&outs);
        assert_reduced_finite("map_allreduce_vec", &sum);
        if self.link_active() {
            self.dense_linked_traversal(true, false);
        } else {
            self.charge_vector_pass(2);
            self.engine_dense_traversal(true, true, false);
        }
        sum
    }

    /// Tree-sum vectors the nodes already produced (via [`map_each`])
    /// and charge the passes: 1 for a master-only reduce, 2 for an
    /// allreduce leaving every node with the sum. Lets drivers keep the
    /// per-node parts (e.g. ∇L_p for the tilt) AND account the
    /// aggregation.
    pub fn reduce_parts(&mut self, parts: &[Vec<f64>], all: bool) -> Vec<f64> {
        self.reduce_parts_lane(parts, all, false)
    }

    /// [`Self::reduce_parts`] whose result lands on the engine's
    /// control lane (pipelined direction combine); identical to the
    /// plain version when pipelining is off.
    pub fn reduce_parts_ctrl(
        &mut self,
        parts: &[Vec<f64>],
        all: bool,
    ) -> Vec<f64> {
        self.reduce_parts_lane(parts, all, true)
    }

    fn reduce_parts_lane(
        &mut self,
        parts: &[Vec<f64>],
        all: bool,
        ctrl: bool,
    ) -> Vec<f64> {
        let sum = allreduce::tree_sum(parts);
        assert_reduced_finite("reduce_parts", &sum);
        if self.link_active() {
            self.dense_linked_traversal(all, ctrl);
            return sum;
        }
        #[cfg(feature = "audit")]
        let marks = self.engine.comm_marks();
        self.charge_vector_pass(if all { 2 } else { 1 });
        self.engine_dense_traversal(true, all, ctrl);
        #[cfg(feature = "audit")]
        assert!(
            self.engine.comm_marks() > marks,
            "reduce_parts charged comm bytes with no matching engine event"
        );
        sum
    }

    /// Compute phase followed by a sparse-aware tree reduce; the master
    /// keeps the (possibly densified) sum. Charges 1 logical pass, with
    /// comm-seconds and comm-bytes based on the actual index/value
    /// payload (nnz·12 B vs d·8 B) each tree level moves.
    pub fn map_reduce_sparse(
        &mut self,
        f: impl Fn(usize, &Shard) -> SparseVec + Sync,
    ) -> Reduced {
        let outs = self.map_each(f);
        self.reduce_parts_sparse(&outs, false)
    }

    /// Sparse allreduce: reduce up + broadcast of the merged result
    /// down. Charges 2 logical passes, seconds/bytes by actual payload.
    pub fn map_allreduce_sparse(
        &mut self,
        f: impl Fn(usize, &Shard) -> SparseVec + Sync,
    ) -> Reduced {
        let outs = self.map_each(f);
        self.reduce_parts_sparse(&outs, true)
    }

    /// Sparse analogue of [`Self::reduce_parts`]: tree-merge by column
    /// index (dense accumulator past the density switch), charging the
    /// clock by the bytes actually moved rather than d·8. The summation
    /// itself always uses the binary-tree order (so sparse and dense
    /// reductions agree coordinate-for-coordinate); the *time* model
    /// follows the configured [`cost::Topology`]: per-level message
    /// sizes on the Tree, (P−1) chunked hops of the merged nnz payload
    /// per logical traversal on the Ring.
    pub fn reduce_parts_sparse(
        &mut self,
        parts: &[SparseVec],
        all: bool,
    ) -> Reduced {
        self.reduce_parts_sparse_lane(parts, all, false)
    }

    /// [`Self::reduce_parts_sparse`] whose result lands on the
    /// engine's control lane — the FS direction combine, which in
    /// pipelined mode overlaps the next round's node compute ("the
    /// safeguard consumes the reduced direction when it lands").
    /// Identical to the plain version when pipelining is off.
    pub fn reduce_parts_sparse_ctrl(
        &mut self,
        parts: &[SparseVec],
        all: bool,
    ) -> Reduced {
        self.reduce_parts_sparse_lane(parts, all, true)
    }

    fn reduce_parts_sparse_lane(
        &mut self,
        parts: &[SparseVec],
        all: bool,
        ctrl: bool,
    ) -> Reduced {
        let (out, level_bytes) = allreduce::tree_sum_sparse(parts);
        #[cfg(any(debug_assertions, feature = "audit"))]
        assert_reduced_finite("reduce_parts_sparse", reduced_vals(&out));
        if self.link_active() {
            // link weather always runs the tree time model: a ring
            // reduce-scatter has no per-edge hop to retry (mirrors the
            // async quorum's rule)
            let result_bytes = out.wire_bytes() as f64;
            let hops: Vec<f64> = level_bytes
                .iter()
                .map(|&b| self.cost.hop_seconds(b as f64))
                .collect();
            let down = if all {
                Some((
                    self.tree_depth() as usize,
                    self.cost.hop_seconds(result_bytes),
                ))
            } else {
                None
            };
            self.ledger.comm_passes += if all { 2.0 } else { 1.0 };
            self.ledger.comm_bytes +=
                if all { 2.0 * result_bytes } else { result_bytes };
            self.ledger.record_sparse_levels(&level_bytes);
            let members: Vec<usize> = (0..self.n_nodes()).collect();
            self.linked_reduce(
                "sparse_reduce",
                None,
                &hops,
                down,
                ctrl,
                &members,
            );
            return out;
        }
        #[cfg(feature = "audit")]
        let marks = self.engine.comm_marks();
        let result_bytes = out.wire_bytes() as f64;
        let nodes = self.n_nodes();
        let secs = match self.cost.topology {
            cost::Topology::Tree => {
                // up-sweep: one hop per level, payload = largest
                // concurrent message at that level (level_bytes is
                // empty on 1 node)
                let mut s: f64 = level_bytes
                    .iter()
                    .map(|&b| self.cost.hop_seconds(b as f64))
                    .sum();
                if all {
                    // broadcast of the merged result back down the tree
                    // (tree_depth = 0 on a single node: no wire)
                    s += self.tree_depth() as f64
                        * self.cost.hop_seconds(result_bytes);
                }
                s
            }
            cost::Topology::Ring => {
                // reduce-scatter (+ all-gather when every node keeps
                // the sum), charged by the merged nnz payload
                let per = self
                    .cost
                    .ring_sparse_traversal_seconds(result_bytes, nodes);
                if all {
                    2.0 * per
                } else {
                    per
                }
            }
        };
        let bytes = if all { 2.0 * result_bytes } else { result_bytes };
        self.ledger.comm_passes += if all { 2.0 } else { 1.0 };
        self.ledger.comm_seconds += secs;
        self.ledger.comm_bytes += bytes;
        // the per-level profile describes the logical combining tree's
        // payload growth and is recorded under BOTH time models (on
        // the Ring the chunked hops carry the same merged payload in
        // aggregate — see Ledger::level_bytes)
        self.ledger.record_sparse_levels(&level_bytes);
        // schedule the hops on the event engine
        match self.cost.topology {
            cost::Topology::Tree => {
                let hops: Vec<f64> = level_bytes
                    .iter()
                    .map(|&b| self.cost.hop_seconds(b as f64))
                    .collect();
                let down = if all {
                    Some((
                        self.tree_depth() as usize,
                        self.cost.hop_seconds(result_bytes),
                    ))
                } else {
                    None
                };
                self.engine.tree_reduce(
                    "sparse_reduce",
                    &hops,
                    down,
                    Self::lane(ctrl),
                );
            }
            cost::Topology::Ring => {
                self.engine.ring_traversal("ring", secs);
            }
        }
        #[cfg(feature = "audit")]
        assert!(
            self.engine.comm_marks() > marks,
            "reduce_parts_sparse charged bytes with no matching engine event"
        );
        self.sync_ledger();
        out
    }

    /// Sparse direction combine for the bounded-staleness async FS
    /// schedule. Arithmetic and flat wire accounting are identical to
    /// [`Self::reduce_parts_sparse`] (same tree-ordered merge, same
    /// per-level byte charges), but the *schedule* is arrival-ordered:
    /// combining-tree leaf i injects at `arrivals[i]`'s ready time (a
    /// solver-lane completion) instead of the node clocks, and the
    /// combine rides the control chain
    /// ([`engine::Engine::quorum_reduce`]). The quorum collection is
    /// always modeled as a tree — on a Ring topology a partial-arrival
    /// reduce-scatter has no faithful analogue, so async runs keep the
    /// tree time model for this one round. Returns the merged result
    /// and the virtual time it landed.
    pub fn async_quorum_reduce_sparse(
        &mut self,
        parts: &[SparseVec],
        arrivals: &[(usize, f64, usize)],
        all: bool,
    ) -> (Reduced, f64) {
        debug_assert_eq!(parts.len(), arrivals.len());
        let (out, level_bytes) = allreduce::tree_sum_sparse(parts);
        #[cfg(any(debug_assertions, feature = "audit"))]
        assert_reduced_finite("async_quorum_reduce_sparse", reduced_vals(&out));
        let result_bytes = out.wire_bytes() as f64;
        let hops: Vec<f64> = level_bytes
            .iter()
            .map(|&b| self.cost.hop_seconds(b as f64))
            .collect();
        let down_depth = self.tree_depth() as usize;
        let down = if all {
            Some((down_depth, self.cost.hop_seconds(result_bytes)))
        } else {
            None
        };
        self.ledger.comm_passes += if all { 2.0 } else { 1.0 };
        self.ledger.comm_bytes +=
            if all { 2.0 * result_bytes } else { result_bytes };
        self.ledger.record_sparse_levels(&level_bytes);
        if self.link_active() {
            let members: Vec<usize> = (0..self.n_nodes()).collect();
            let landed = self.linked_reduce(
                "async_reduce",
                Some(arrivals),
                &hops,
                down,
                false,
                &members,
            );
            return (out, landed);
        }
        let mut secs: f64 = hops.iter().sum();
        if all {
            secs += down_depth as f64 * self.cost.hop_seconds(result_bytes);
        }
        self.ledger.comm_seconds += secs;
        let landed =
            self.engine.quorum_reduce("async_reduce", arrivals, &hops, down);
        self.sync_ledger();
        (out, landed)
    }

    /// Dense analogue of [`Self::async_quorum_reduce_sparse`]: same
    /// tree-ordered sum and flat pass charges as
    /// [`Self::reduce_parts`], arrival-ordered schedule. Returns the
    /// sum and its landing time.
    pub fn async_quorum_reduce(
        &mut self,
        parts: &[Vec<f64>],
        arrivals: &[(usize, f64, usize)],
        all: bool,
    ) -> (Vec<f64>, f64) {
        debug_assert_eq!(parts.len(), arrivals.len());
        let sum = allreduce::tree_sum(parts);
        assert_reduced_finite("async_quorum_reduce", &sum);
        if self.link_active() {
            // linked climbs charge their own (possibly retried) wire
            // time; only the flat pass/byte accounting happens here
            let passes = if all { 2usize } else { 1 };
            self.ledger.comm_passes += passes as f64;
            self.ledger.comm_bytes +=
                (passes * self.dim * self.cost.bytes_per_scalar) as f64;
        } else {
            self.charge_vector_pass(if all { 2 } else { 1 });
        }
        let hop = if self.n_nodes() <= 1 {
            0.0
        } else {
            self.cost.pass_seconds(self.dim)
        };
        let up_depth = if parts.len() <= 1 {
            0
        } else {
            (parts.len() as f64).log2().ceil() as usize
        };
        let hops = vec![hop; up_depth];
        let down = if all {
            Some((self.tree_depth() as usize, hop))
        } else {
            None
        };
        if self.link_active() {
            let members: Vec<usize> = (0..self.n_nodes()).collect();
            let landed = self.linked_reduce(
                "async_reduce",
                Some(arrivals),
                &hops,
                down,
                false,
                &members,
            );
            return (sum, landed);
        }
        let landed =
            self.engine.quorum_reduce("async_reduce", arrivals, &hops, down);
        self.sync_ledger();
        (sum, landed)
    }

    /// Charge one cross-node aggregation round of `k` scalars that is
    /// not mediated by [`Self::map_reduce_scalars`] — e.g. the hybrid
    /// direction round's per-node affine coefficients. Latency-only
    /// time, zero passes (footnote 5 counts size-d vectors).
    pub fn charge_scalar_round(&mut self, k: usize) {
        let depth = self.tree_depth() as usize;
        let hop = (self.cost.latency_s
            + (k * 8) as f64 / self.cost.bandwidth_bytes_per_s)
            * self.link_mean_mult();
        self.ledger.comm_seconds += 2.0 * depth as f64 * hop;
        self.ledger.scalar_rounds += 1;
        // scalar rounds are control-plane by nature: in pipelined mode
        // they never stall the self-paced node clocks
        self.engine.scalar_round(depth, hop);
        self.sync_ledger();
    }

    /// Master → nodes broadcast of a size-d vector. Charges 1 pass.
    /// (The data flow itself is implicit — nodes read the master copy —
    /// but the cost is real.)
    pub fn broadcast_vec(&mut self) {
        let bytes = (self.dim * self.cost.bytes_per_scalar) as f64;
        self.broadcast_payload(bytes);
    }

    /// Master → nodes broadcast of a support-sized payload (`len`
    /// coordinates, len·8 wire bytes): what shipping w costs in the
    /// compact regime, where the iterate provably lives in the union
    /// support U. Still 1 logical pass (the paper's footnote-5 count is
    /// wire-format independent, exactly as for the sparse reductions);
    /// bytes and modeled seconds follow the actual |U|·8 payload
    /// instead of d·8.
    pub fn broadcast_support(&mut self, len: usize) {
        let bytes = (len * self.cost.bytes_per_scalar) as f64;
        self.broadcast_payload(bytes);
    }

    /// The one broadcast charge/schedule implementation behind both
    /// sizes above (for a dim-sized payload it reproduces the classic
    /// `traversal_seconds` charge exactly: depth × per-hop on the
    /// Tree, (P−1) chunk hops on the Ring, zero wire on one node).
    /// Flat charge and engine schedule stay mirror images so the
    /// barrier makespan equivalence (`tests/engine.rs`) is preserved.
    fn broadcast_payload(&mut self, bytes: f64) {
        let depth = self.tree_depth() as usize;
        #[cfg(feature = "audit")]
        let marks = self.engine.comm_marks();
        // broadcasts are acked multicast fan-out: no per-edge retry
        // discipline, the link layer contributes its mean multiplier
        // (exactly 1.0 when inactive)
        let lm = self.link_mean_mult();
        self.ledger.comm_passes += 1.0;
        self.ledger.comm_bytes += bytes;
        match self.cost.topology {
            cost::Topology::Tree => {
                let hop = if self.n_nodes() <= 1 {
                    0.0
                } else {
                    self.cost.hop_seconds(bytes) * lm
                };
                self.ledger.comm_seconds += depth as f64 * hop;
                self.engine.broadcast(depth, hop);
            }
            cost::Topology::Ring => {
                let secs = self
                    .cost
                    .ring_sparse_traversal_seconds(bytes, self.n_nodes())
                    * lm;
                self.ledger.comm_seconds += secs;
                self.engine.ring_traversal("ring", secs);
            }
        }
        #[cfg(feature = "audit")]
        assert!(
            self.engine.comm_marks() > marks,
            "broadcast charged comm bytes with no matching engine event"
        );
        self.sync_ledger();
    }

    /// Broadcast the master iterate in its cheapest representation:
    /// O(|U|) support values under the compact-master density gate,
    /// the dense size-d vector otherwise. SQM's per-iteration w and v
    /// broadcasts route through this so the compact regime's ledger
    /// stops overcharging d·8 for payloads that live in U.
    pub fn broadcast_master(&mut self) {
        if self.prefer_compact_master() {
            let len = self.umap.len();
            self.broadcast_support(len);
        } else {
            self.broadcast_vec();
        }
    }

    /// Scalar aggregation round (line-search trial): each node returns
    /// a handful of f64s which the tree sums. Costs latency-only time,
    /// zero passes (paper footnote 5 counts size-d vectors).
    pub fn map_reduce_scalars<const K: usize>(
        &mut self,
        f: impl Fn(usize, &Shard) -> [f64; K] + Sync,
    ) -> [f64; K] {
        let (outs, times) = self.run_nodes(&f);
        self.finish_scalar_round(outs, &times)
    }

    /// [`Self::map_reduce_scalars`] handing every node its reusable
    /// [`NodeScratch`] slot — the line-search trials read the dʳ·xᵢ
    /// margin deltas straight out of `NodeScratch::dz` instead of a
    /// per-round allocation (same lane/charge semantics otherwise).
    pub fn map_reduce_scalars_scratch<const K: usize>(
        &mut self,
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> [f64; K] + Sync,
    ) -> [f64; K] {
        let (outs, times) = {
            let scratch = &self.scratch;
            let g = |p: usize, shard: &Shard| -> [f64; K] {
                let mut slot = scratch[p].lock().expect("scratch lock");
                f(p, shard, &mut slot)
            };
            self.run_nodes(&g)
        };
        self.finish_scalar_round(outs, &times)
    }

    /// The one scalar-round charge-and-sum body behind both variants
    /// above: the per-node evaluation is tiny (margins are cached) and
    /// in pipelined mode rides the control lane with the round itself
    /// (line-search trials ARE the control plane); the K scalars
    /// tree-sum and cost one scalar round.
    fn finish_scalar_round<const K: usize>(
        &mut self,
        outs: Vec<[f64; K]>,
        times: &[f64],
    ) -> [f64; K] {
        self.charge_compute_lane(times, true);
        let mut acc = [0.0; K];
        for o in outs {
            for (a, v) in acc.iter_mut().zip(o) {
                *a += v;
            }
        }
        self.charge_scalar_round(K);
        acc
    }

    /// Depth of the reduction tree: 0 on a single node (no wire at
    /// all — charging a lone node per-hop latency was a bug).
    fn tree_depth(&self) -> u32 {
        Self::subset_depth(self.n_nodes())
    }

    /// Tree depth over an `m`-member subset — the same 0-on-one-node
    /// rule the full tree uses, so a degraded round's wire shrinks
    /// with its membership.
    fn subset_depth(m: usize) -> u32 {
        if m <= 1 {
            0
        } else {
            (m as f64).log2().ceil() as u32
        }
    }

    /// Is this membership the full cluster? Full-membership calls on
    /// every `*_members` entry point below delegate to the legacy
    /// body, so a zero-fault run is *structurally* bit-identical to
    /// the pre-fault code path (`tests/faults.rs` pins it).
    fn full_membership(&self, members: &[usize]) -> bool {
        members.len() == self.n_nodes()
    }

    /// [`Self::map_each_scratch`] over a node subset: only `members`
    /// run (and get charged on their clocks); dead nodes' shards are
    /// absent from the round. Outputs are slotted by *member
    /// position*, not node id.
    pub fn map_each_scratch_members<T: Send>(
        &mut self,
        members: &[usize],
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
    ) -> Vec<T> {
        self.map_each_scratch_members_lane(members, f, false)
    }

    /// [`Self::map_each_scratch_ctrl`] over a node subset.
    pub fn map_each_scratch_ctrl_members<T: Send>(
        &mut self,
        members: &[usize],
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
    ) -> Vec<T> {
        self.map_each_scratch_members_lane(members, f, true)
    }

    fn map_each_scratch_members_lane<T: Send>(
        &mut self,
        members: &[usize],
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> T + Sync,
        ctrl: bool,
    ) -> Vec<T> {
        if self.full_membership(members) {
            return self.map_each_scratch_lane(f, ctrl);
        }
        let scratch = &self.scratch;
        let g = |p: usize, shard: &Shard| -> T {
            let mut slot = scratch[p].lock().expect("scratch lock");
            f(p, shard, &mut slot)
        };
        let (outs, times): (Vec<T>, Vec<f64>) =
            self.run_subset(members, &g).into_iter().unzip();
        self.charge_compute_members_lane(members, &times, ctrl);
        outs
    }

    /// Member-subset analogue of [`Self::charge_compute_lane`]: only
    /// member clocks advance and only members are barrier'd; a dead
    /// node's clock stays frozen where the fault left it.
    fn charge_compute_members_lane(
        &mut self,
        members: &[usize],
        times: &[f64],
        ctrl: bool,
    ) {
        let max = if ctrl && self.engine.pipeline {
            self.engine.compute_control_members(
                self.cost.compute_scale,
                members,
                times,
            )
        } else {
            self.engine
                .compute_members(self.cost.compute_scale, members, times)
        };
        self.ledger.compute_seconds += max;
        self.sync_ledger();
    }

    /// [`Self::reduce_parts`] over a member subset: `parts[i]` is
    /// member `members[i]`'s vector, the tree has
    /// [`Self::subset_depth`] levels, and only member clocks gate on
    /// the landing. A partial-membership ring has no faithful
    /// reduce-scatter analogue, so degraded rounds use the tree time
    /// model regardless of topology (mirroring the async quorum).
    pub fn reduce_parts_members(
        &mut self,
        parts: &[Vec<f64>],
        all: bool,
        members: &[usize],
    ) -> Vec<f64> {
        self.reduce_parts_members_lane(parts, all, members, false)
    }

    /// [`Self::reduce_parts_ctrl`] over a member subset.
    pub fn reduce_parts_ctrl_members(
        &mut self,
        parts: &[Vec<f64>],
        all: bool,
        members: &[usize],
    ) -> Vec<f64> {
        self.reduce_parts_members_lane(parts, all, members, true)
    }

    fn reduce_parts_members_lane(
        &mut self,
        parts: &[Vec<f64>],
        all: bool,
        members: &[usize],
        ctrl: bool,
    ) -> Vec<f64> {
        if self.full_membership(members) {
            return self.reduce_parts_lane(parts, all, ctrl);
        }
        debug_assert_eq!(parts.len(), members.len());
        let sum = allreduce::tree_sum(parts);
        assert_reduced_finite("reduce_parts_members", &sum);
        let m = members.len();
        let depth = Self::subset_depth(m) as usize;
        let hop = if m <= 1 {
            0.0
        } else {
            self.cost.pass_seconds(self.dim)
        };
        let passes = if all { 2.0 } else { 1.0 };
        self.ledger.comm_passes += passes;
        self.ledger.comm_bytes +=
            passes * (self.dim * self.cost.bytes_per_scalar) as f64;
        let hops = vec![hop; depth];
        let down = if all { Some((depth, hop)) } else { None };
        if self.link_active() {
            self.linked_reduce("reduce", None, &hops, down, ctrl, members);
            return sum;
        }
        self.ledger.comm_seconds +=
            passes * depth as f64 * hop;
        self.engine.tree_reduce_members(
            "reduce",
            &hops,
            down,
            Self::lane(ctrl),
            members,
        );
        self.sync_ledger();
        sum
    }

    /// [`Self::reduce_parts_sparse`] over a member subset: same
    /// tree-ordered merge over the members' parts, per-level byte
    /// charges from the subset combining tree, and only member clocks
    /// gated. Tree time model regardless of topology (see
    /// [`Self::reduce_parts_members`]).
    pub fn reduce_parts_sparse_members(
        &mut self,
        parts: &[SparseVec],
        all: bool,
        members: &[usize],
    ) -> Reduced {
        self.reduce_parts_sparse_members_lane(parts, all, members, false)
    }

    /// [`Self::reduce_parts_sparse_ctrl`] over a member subset.
    pub fn reduce_parts_sparse_ctrl_members(
        &mut self,
        parts: &[SparseVec],
        all: bool,
        members: &[usize],
    ) -> Reduced {
        self.reduce_parts_sparse_members_lane(parts, all, members, true)
    }

    fn reduce_parts_sparse_members_lane(
        &mut self,
        parts: &[SparseVec],
        all: bool,
        members: &[usize],
        ctrl: bool,
    ) -> Reduced {
        if self.full_membership(members) {
            return self.reduce_parts_sparse_lane(parts, all, ctrl);
        }
        debug_assert_eq!(parts.len(), members.len());
        let (out, level_bytes) = allreduce::tree_sum_sparse(parts);
        #[cfg(any(debug_assertions, feature = "audit"))]
        assert_reduced_finite(
            "reduce_parts_sparse_members",
            reduced_vals(&out),
        );
        let result_bytes = out.wire_bytes() as f64;
        let hops: Vec<f64> = level_bytes
            .iter()
            .map(|&b| self.cost.hop_seconds(b as f64))
            .collect();
        let down_depth = Self::subset_depth(members.len()) as usize;
        let down = if all {
            Some((down_depth, self.cost.hop_seconds(result_bytes)))
        } else {
            None
        };
        self.ledger.comm_passes += if all { 2.0 } else { 1.0 };
        self.ledger.comm_bytes +=
            if all { 2.0 * result_bytes } else { result_bytes };
        self.ledger.record_sparse_levels(&level_bytes);
        if self.link_active() {
            self.linked_reduce(
                "sparse_reduce",
                None,
                &hops,
                down,
                ctrl,
                members,
            );
            return out;
        }
        let mut secs: f64 = hops.iter().sum();
        if all {
            secs += down_depth as f64 * self.cost.hop_seconds(result_bytes);
        }
        self.ledger.comm_seconds += secs;
        self.engine.tree_reduce_members(
            "sparse_reduce",
            &hops,
            down,
            Self::lane(ctrl),
            members,
        );
        self.sync_ledger();
        out
    }

    /// [`Self::async_quorum_reduce_sparse`] under elastic membership:
    /// same arrival-ordered combine over whatever contributions made
    /// the quorum, but the result broadcast only spans (and only
    /// gates) the current members — a dead node neither receives the
    /// direction nor delays it.
    pub fn async_quorum_reduce_sparse_members(
        &mut self,
        parts: &[SparseVec],
        arrivals: &[(usize, f64, usize)],
        all: bool,
        members: &[usize],
    ) -> (Reduced, f64) {
        if self.full_membership(members) {
            return self.async_quorum_reduce_sparse(parts, arrivals, all);
        }
        debug_assert_eq!(parts.len(), arrivals.len());
        let (out, level_bytes) = allreduce::tree_sum_sparse(parts);
        #[cfg(any(debug_assertions, feature = "audit"))]
        assert_reduced_finite(
            "async_quorum_reduce_sparse_members",
            reduced_vals(&out),
        );
        let result_bytes = out.wire_bytes() as f64;
        let hops: Vec<f64> = level_bytes
            .iter()
            .map(|&b| self.cost.hop_seconds(b as f64))
            .collect();
        let down_depth = Self::subset_depth(members.len()) as usize;
        let down = if all {
            Some((down_depth, self.cost.hop_seconds(result_bytes)))
        } else {
            None
        };
        self.ledger.comm_passes += if all { 2.0 } else { 1.0 };
        self.ledger.comm_bytes +=
            if all { 2.0 * result_bytes } else { result_bytes };
        self.ledger.record_sparse_levels(&level_bytes);
        if self.link_active() {
            let landed = self.linked_reduce(
                "async_reduce",
                Some(arrivals),
                &hops,
                down,
                false,
                members,
            );
            return (out, landed);
        }
        let mut secs: f64 = hops.iter().sum();
        if all {
            secs += down_depth as f64 * self.cost.hop_seconds(result_bytes);
        }
        self.ledger.comm_seconds += secs;
        let landed = self.engine.quorum_reduce_members(
            "async_reduce",
            arrivals,
            &hops,
            down,
            members,
        );
        self.sync_ledger();
        (out, landed)
    }

    /// Dense analogue of
    /// [`Self::async_quorum_reduce_sparse_members`].
    pub fn async_quorum_reduce_members(
        &mut self,
        parts: &[Vec<f64>],
        arrivals: &[(usize, f64, usize)],
        all: bool,
        members: &[usize],
    ) -> (Vec<f64>, f64) {
        if self.full_membership(members) {
            return self.async_quorum_reduce(parts, arrivals, all);
        }
        debug_assert_eq!(parts.len(), arrivals.len());
        let sum = allreduce::tree_sum(parts);
        assert_reduced_finite("async_quorum_reduce_members", &sum);
        let m = members.len();
        let hop = if m <= 1 {
            0.0
        } else {
            self.cost.pass_seconds(self.dim)
        };
        let up_depth = if parts.len() <= 1 {
            0
        } else {
            (parts.len() as f64).log2().ceil() as usize
        };
        let passes = if all { 2.0 } else { 1.0 };
        self.ledger.comm_passes += passes;
        self.ledger.comm_bytes +=
            passes * (self.dim * self.cost.bytes_per_scalar) as f64;
        let hops = vec![hop; up_depth];
        let down = if all {
            Some((Self::subset_depth(m) as usize, hop))
        } else {
            None
        };
        if self.link_active() {
            let landed = self.linked_reduce(
                "async_reduce",
                Some(arrivals),
                &hops,
                down,
                false,
                members,
            );
            return (sum, landed);
        }
        self.ledger.comm_seconds += passes
            * Self::subset_depth(m) as f64
            * hop;
        let landed = self.engine.quorum_reduce_members(
            "async_reduce",
            arrivals,
            &hops,
            down,
            members,
        );
        self.sync_ledger();
        (sum, landed)
    }

    /// [`Self::charge_scalar_round`] over a member subset: the
    /// aggregation tree spans only the members, and only their clocks
    /// are gated.
    pub fn charge_scalar_round_members(
        &mut self,
        k: usize,
        members: &[usize],
    ) {
        if self.full_membership(members) {
            return self.charge_scalar_round(k);
        }
        let depth = Self::subset_depth(members.len()) as usize;
        let hop = (self.cost.latency_s
            + (k * 8) as f64 / self.cost.bandwidth_bytes_per_s)
            * self.link_mean_mult();
        self.ledger.comm_seconds += 2.0 * depth as f64 * hop;
        self.ledger.scalar_rounds += 1;
        self.engine.scalar_round_members(depth, hop, members);
        self.sync_ledger();
    }

    /// [`Self::map_reduce_scalars_scratch`] over a member subset —
    /// line-search trials during a degraded round sum only the
    /// members' contributions (their margins are the only current
    /// ones).
    pub fn map_reduce_scalars_scratch_members<const K: usize>(
        &mut self,
        members: &[usize],
        f: impl Fn(usize, &Shard, &mut NodeScratch) -> [f64; K] + Sync,
    ) -> [f64; K] {
        if self.full_membership(members) {
            return self.map_reduce_scalars_scratch(f);
        }
        let (outs, times): (Vec<[f64; K]>, Vec<f64>) = {
            let scratch = &self.scratch;
            let g = |p: usize, shard: &Shard| -> [f64; K] {
                let mut slot = scratch[p].lock().expect("scratch lock");
                f(p, shard, &mut slot)
            };
            self.run_subset(members, &g).into_iter().unzip()
        };
        self.charge_compute_members_lane(members, &times, true);
        let mut acc = [0.0; K];
        for o in outs {
            for (a, v) in acc.iter_mut().zip(o) {
                *a += v;
            }
        }
        self.charge_scalar_round_members(K, members);
        acc
    }

    /// Flat ledger accounting for dense passes (passes/seconds/bytes);
    /// the *schedule* of those hops is modeled separately by
    /// [`Self::engine_dense_traversal`].
    fn charge_vector_pass(&mut self, passes: usize) {
        let per_pass = self.cost.traversal_seconds(self.dim, self.n_nodes());
        self.ledger.comm_passes += passes as f64;
        self.ledger.comm_seconds += passes as f64 * per_pass;
        self.ledger.comm_bytes +=
            (passes * self.dim * self.cost.bytes_per_scalar) as f64;
    }

    /// Run one closure per node, returning outputs and per-node seconds.
    fn run_nodes<T: Send>(
        &self,
        f: &(impl Fn(usize, &Shard) -> T + Sync),
    ) -> (Vec<T>, Vec<f64>) {
        let all: Vec<usize> = (0..self.n_nodes()).collect();
        self.run_subset(&all, f).into_iter().unzip()
    }

    /// The shared worker loop behind [`Self::run_nodes`] and
    /// [`Self::map_nodes_timed`]: run `f` on the given node subset
    /// (threaded past the sequential cutoffs, outputs slotted by
    /// position so results are deterministic), returning each node's
    /// output with its measured seconds.
    fn run_subset<T: Send>(
        &self,
        nodes: &[usize],
        f: &(impl Fn(usize, &Shard) -> T + Sync),
    ) -> Vec<(T, f64)> {
        if self.threads <= 1 || nodes.len() <= 1 {
            nodes
                .iter()
                .map(|&p| {
                    let t0 = Instant::now();
                    let out = f(p, &self.shards[p]);
                    (out, t0.elapsed().as_secs_f64())
                })
                .collect()
        } else {
            let n = nodes.len();
            let mut slots: Vec<Option<(T, f64)>> =
                (0..n).map(|_| None).collect();
            let next = std::sync::atomic::AtomicUsize::new(0);
            let slots_ptr = Mutex::new(&mut slots);
            std::thread::scope(|scope| {
                for _ in 0..self.threads.min(n) {
                    scope.spawn(|| loop {
                        let i = next
                            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        if i >= n {
                            break;
                        }
                        let p = nodes[i];
                        let t0 = Instant::now();
                        let out = f(p, &self.shards[p]);
                        let dt = t0.elapsed().as_secs_f64();
                        slots_ptr.lock().unwrap()[i] = Some((out, dt));
                    });
                }
            });
            slots
                .into_iter()
                .map(|s| s.expect("node closure completed"))
                .collect()
        }
    }
}

fn data_len(d: &Dataset) -> usize {
    d.n_examples()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn cluster(nodes: usize) -> Cluster {
        let data = SynthConfig {
            n_examples: 120,
            n_features: 30,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(1);
        Cluster::partition(data, nodes, CostModel::default())
    }

    #[test]
    fn partition_preserves_examples() {
        let c = cluster(7);
        assert_eq!(c.n_nodes(), 7);
        assert_eq!(c.n_examples(), 120);
        assert!(c.shards.iter().all(|s| s.xl.n_rows() > 0));
    }

    #[test]
    fn map_reduce_vec_sums_over_nodes() {
        let mut c = cluster(5);
        // per-node example counts, one-hot by node index
        let v = c.map_reduce_vec(|p, shard| {
            let mut out = vec![0.0; 30];
            out[p] = shard.xl.n_rows() as f64;
            out
        });
        let total: f64 = v.iter().sum();
        assert_eq!(total, 120.0);
        assert_eq!(c.ledger.comm_passes, 1.0);
    }

    #[test]
    fn allreduce_charges_two_passes() {
        let mut c = cluster(4);
        let _ = c.map_allreduce_vec(|_, _| vec![1.0; 30]);
        assert_eq!(c.ledger.comm_passes, 2.0);
        assert!(c.ledger.comm_seconds > 0.0);
    }

    #[test]
    fn scalar_rounds_cost_no_passes() {
        let mut c = cluster(4);
        let [s] = c.map_reduce_scalars(|_, shard| [shard.xl.n_rows() as f64]);
        assert_eq!(s, 120.0);
        assert_eq!(c.ledger.comm_passes, 0.0);
        assert_eq!(c.ledger.scalar_rounds, 1);
        assert!(c.ledger.comm_seconds > 0.0);
    }

    #[test]
    fn compute_clock_takes_max_over_nodes() {
        let mut c = cluster(3);
        c.map_each(|p, _| {
            // node 2 does 3x the work
            let mut acc = 0.0f64;
            let iters = if p == 2 { 300_000 } else { 100_000 };
            for i in 0..iters {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert!(c.ledger.compute_seconds > 0.0);
    }

    #[test]
    fn threaded_map_matches_sequential() {
        let mut c1 = cluster(6);
        let seq = c1.map_each(|p, s| (p, s.xl.nnz()));
        let mut c2 = cluster(6);
        c2.threads = 3;
        let par = c2.map_each(|p, s| (p, s.xl.nnz()));
        assert_eq!(seq, par);
    }

    #[test]
    fn single_node_charges_zero_comm_seconds() {
        // regression: tree_depth used n.max(2), so a lone node paid two
        // tree hops of latency per scalar round and per-pass traversal
        // time it could never incur
        let mut c = cluster(1);
        let [s] = c.map_reduce_scalars(|_, shard| [shard.xl.n_rows() as f64]);
        assert_eq!(s, 120.0);
        c.broadcast_vec();
        let _ = c.map_reduce_vec(|_, _| vec![0.0; 30]);
        let _ = c.reduce_parts_sparse(
            &[SparseVec::from_pairs(30, vec![(3, 1.0)])],
            true,
        );
        assert_eq!(
            c.ledger.comm_seconds, 0.0,
            "1-node cluster paid for communication"
        );
        // logical accounting is untouched
        assert_eq!(c.ledger.scalar_rounds, 1);
        assert!(c.ledger.comm_passes > 0.0);
    }

    #[test]
    fn sparse_allreduce_matches_dense_and_moves_fewer_bytes() {
        let mut c_dense = cluster(5);
        let dim = c_dense.dim;
        let dense = c_dense.map_allreduce_vec(|p, _| {
            let mut v = vec![0.0; dim];
            v[p] = 1.0 + p as f64;
            v
        });
        let mut c_sparse = cluster(5);
        let sparse = c_sparse
            .map_allreduce_sparse(|p, _| {
                SparseVec::from_pairs(dim, vec![(p as u32, 1.0 + p as f64)])
            })
            .into_dense();
        assert_eq!(dense, sparse);
        assert_eq!(c_sparse.ledger.comm_passes, 2.0);
        assert!(
            c_sparse.ledger.comm_bytes < c_dense.ledger.comm_bytes,
            "sparse {} vs dense {}",
            c_sparse.ledger.comm_bytes,
            c_dense.ledger.comm_bytes
        );
        assert!(c_sparse.ledger.comm_seconds <= c_dense.ledger.comm_seconds);
    }

    #[test]
    fn partition_builds_union_support_and_positions() {
        let c = cluster(4);
        // every shard support column appears in U at its composed slot
        for s in &c.shards {
            assert_eq!(s.upos.len(), s.map.len());
            for (l, &p) in s.upos.iter().enumerate() {
                assert_eq!(c.umap.support[p as usize], s.map.support[l]);
            }
        }
        // U is exactly the set of columns with data
        let mut want: Vec<u32> = c
            .shards
            .iter()
            .flat_map(|s| s.map.support.iter().copied())
            .collect();
        want.sort_unstable();
        want.dedup();
        assert_eq!(c.umap.support, want);
        // fork_fresh preserves the dictionary
        let f = c.fork_fresh();
        assert_eq!(f.umap.support, c.umap.support);
        assert_eq!(f.shards[0].upos, c.shards[0].upos);
    }

    #[test]
    fn compact_broadcast_charges_support_bytes() {
        // satellite regression: the compact regime ships O(|U|)
        // broadcast payloads (w lives in U), not d·8
        let data = SynthConfig {
            n_examples: 60,
            n_features: 5_000,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(23);
        let c0 = Cluster::partition(data, 4, CostModel::default());
        assert!(c0.prefer_compact_master());
        let u = c0.umap.len();
        assert!(u < c0.dim / 2);
        let mut c_compact = c0.fork_fresh();
        c_compact.broadcast_master();
        // bytes pinned to the support payload, 1 logical pass
        assert_eq!(c_compact.ledger.comm_bytes, (u * 8) as f64);
        assert_eq!(c_compact.ledger.comm_passes, 1.0);
        let mut c_dense = c0.fork_fresh();
        c_dense.broadcast_vec();
        assert_eq!(c_dense.ledger.comm_bytes, (c0.dim * 8) as f64);
        assert!(c_compact.ledger.comm_seconds < c_dense.ledger.comm_seconds);
        // the engine schedule stays consistent with the flat charge
        assert!(
            (c_compact.ledger.seconds()
                - (c_compact.ledger.comm_seconds
                    + c_compact.ledger.compute_seconds))
                .abs()
                < 1e-12
        );
        // dense-regime clusters keep the classic d·8 broadcast
        let dense_cluster = cluster(4);
        assert!(!dense_cluster.prefer_compact_master());
        let mut d = dense_cluster.fork_fresh();
        d.broadcast_master();
        assert_eq!(d.ledger.comm_bytes, (d.dim * 8) as f64);
    }

    #[test]
    fn support_density_reflects_shard_sparsity() {
        // 120 examples × ~5 nnz over 30 cols: dense-ish shards
        let c = cluster(4);
        assert!(c.support_density() > 0.5);
        assert!(!c.prefer_sparse());
    }

    #[test]
    fn homogeneous_makespan_matches_flat_component_sum() {
        // the engine's non-pipelined schedule IS the barrier schedule:
        // it must collapse to the legacy flat accumulator exactly
        let mut c = cluster(8);
        assert!(c.engine.profile.is_homogeneous());
        c.broadcast_vec();
        let _ = c.map_reduce_vec(|_, _| vec![1.0; 30]);
        let _ = c.map_allreduce_vec(|_, _| vec![1.0; 30]);
        let [_] = c.map_reduce_scalars(|_, s| [s.xl.n_rows() as f64]);
        let parts: Vec<SparseVec> = (0..8)
            .map(|p| SparseVec::from_pairs(30, vec![(p as u32, 1.0)]))
            .collect();
        let _ = c.reduce_parts_sparse(&parts, true);
        let flat = c.ledger.comm_seconds + c.ledger.compute_seconds;
        let makespan = c.ledger.seconds();
        assert!(c.ledger.makespan.is_some());
        assert!(
            (makespan - flat).abs() <= 1e-9 * (1.0 + flat),
            "makespan {makespan} vs flat {flat}"
        );
    }

    #[test]
    fn straggler_profile_stretches_compute_and_makespan() {
        let mut c = cluster(4);
        c.threads = 1; // contention-free measured compute
        c.set_profile(NodeProfile::with_straggler(4, 1, 3.0));
        let mut c_base = cluster(4);
        c_base.threads = 1;
        let work = |_: usize, _: &Shard| {
            let mut acc = 0.0f64;
            for i in 0..200_000 {
                acc += (i as f64).sqrt();
            }
            acc
        };
        c.map_each(&work);
        c_base.map_each(&work);
        // the 3× node dominates the barrier-equivalent compute charge
        assert!(
            c.ledger.compute_seconds > 2.0 * c_base.ledger.compute_seconds,
            "straggler {} vs base {}",
            c.ledger.compute_seconds,
            c_base.ledger.compute_seconds
        );
        assert!(c.ledger.seconds() >= c.ledger.compute_seconds * 0.999);
    }

    #[test]
    fn ring_sparse_reduction_records_level_profile() {
        // satellite regression: the per-level sparse payload profile
        // is recorded under the Ring time model too (was Tree-only)
        let mut c = cluster(5);
        c.cost.topology = cost::Topology::Ring;
        let parts: Vec<SparseVec> = (0..5)
            .map(|p| SparseVec::from_pairs(30, vec![(p as u32, 1.0)]))
            .collect();
        let _ = c.reduce_parts_sparse(&parts, true);
        assert_eq!(c.ledger.sparse_reductions, 1);
        assert!(
            !c.ledger.level_bytes.is_empty(),
            "ring reduction must record the combining-tree profile"
        );
        assert!(!c.ledger.level_profile().is_empty());
        // ring time model still charges by chunked merged payload
        assert!(c.ledger.comm_seconds > 0.0);
        // and the tree model records the same logical profile
        let mut t = cluster(5);
        let _ = t.reduce_parts_sparse(&parts, true);
        assert_eq!(t.ledger.level_bytes, c.ledger.level_bytes);
    }

    #[test]
    fn async_quorum_reduce_matches_sync_arithmetic_and_charges() {
        // the arrival-ordered combine must move the same bytes/passes
        // and produce the same tree-ordered sum as the barrier reduce —
        // only the schedule differs
        let parts: Vec<SparseVec> = (0..5)
            .map(|p| SparseVec::from_pairs(30, vec![(p as u32, 1.0 + p as f64)]))
            .collect();
        let mut sync = cluster(5);
        let want = sync.reduce_parts_sparse(&parts, true).into_dense();
        let mut async_c = cluster(5);
        let arrivals: Vec<(usize, f64, usize)> =
            (0..5).map(|p| (p, 0.5 + p as f64, p % 2)).collect();
        let (got, landed) =
            async_c.async_quorum_reduce_sparse(&parts, &arrivals, true);
        assert_eq!(got.into_dense(), want);
        assert_eq!(sync.ledger.comm_passes, async_c.ledger.comm_passes);
        assert_eq!(sync.ledger.comm_bytes, async_c.ledger.comm_bytes);
        assert_eq!(sync.ledger.level_bytes, async_c.ledger.level_bytes);
        // the combine cannot land before the last arrival it consumed
        assert!(landed >= 4.5);
        assert!(async_c.ledger.seconds() >= landed - 1e-12);
        // dense analogue sums identically too
        let dense_parts: Vec<Vec<f64>> =
            parts.iter().map(|s| s.to_dense()).collect();
        let mut d = cluster(5);
        let (sum, _) = d.async_quorum_reduce(&dense_parts, &arrivals, true);
        assert_eq!(sum, want);
        assert_eq!(d.ledger.comm_passes, 2.0);
    }

    #[test]
    fn more_nodes_means_deeper_tree_costs() {
        let mut c4 = cluster(4);
        let mut c16 = cluster(16);
        c4.broadcast_vec();
        c16.broadcast_vec();
        assert!(c16.ledger.comm_seconds > c4.ledger.comm_seconds);
        assert_eq!(c4.ledger.comm_passes, c16.ledger.comm_passes);
    }

    #[test]
    fn fault_weather_tracks_membership_and_ledger() {
        let mut c = cluster(4);
        let plan = FaultPlan::parse(
            "crash:1@r2,restart:1@r5,degrade:2@r1:0.5x",
            4,
        )
        .unwrap();
        c.set_fault_plan(plan);
        // round 0: clear weather, full membership, nothing charged
        let w = c.apply_fault_weather(0);
        assert_eq!(w.members, vec![0, 1, 2, 3]);
        assert!(!c.ledger.has_fault_activity());
        // round 1: the degrade fires (profile rescaled in place)
        let w = c.apply_fault_weather(1);
        assert_eq!(w.members, vec![0, 1, 2, 3]);
        assert_eq!(c.ledger.degrade_events, 1);
        // round 2: node 1 crashes out
        let w = c.apply_fault_weather(2);
        assert_eq!(w.crashed, vec![1]);
        assert_eq!(w.members, vec![0, 2, 3]);
        assert_eq!(c.alive_nodes(), vec![0, 2, 3]);
        assert_eq!(c.ledger.crash_events, 1);
        // rounds 3–4: it stays dead, no double-fire
        let w = c.apply_fault_weather(3);
        assert!(w.crashed.is_empty());
        assert_eq!(w.members, vec![0, 2, 3]);
        assert_eq!(c.ledger.crash_events, 1);
        let _ = c.apply_fault_weather(4);
        // round 5: restart reported so the driver can re-base
        let w = c.apply_fault_weather(5);
        assert_eq!(w.restarted, vec![1]);
        assert_eq!(w.members, vec![0, 1, 2, 3]);
        c.rejoin_rebase(1, c.dim);
        assert_eq!(c.ledger.rejoin_rebases, 1);
        assert!(c.ledger.recovery_seconds > 0.0);
        // the fault log replays the whole story in order
        let log = &c.faults.as_ref().unwrap().log;
        let kinds: Vec<&str> = log.iter().map(|a| a.what).collect();
        assert_eq!(kinds, vec!["degrade", "crash", "restart"]);
    }

    #[test]
    fn crash_never_empties_the_membership() {
        let mut c = cluster(2);
        let plan =
            FaultPlan::parse("crash:0@r1,crash:1@r1", 2).unwrap();
        c.set_fault_plan(plan);
        let w = c.apply_fault_weather(1);
        // one crash lands, the survivor's crash order is ignored
        assert_eq!(w.members.len(), 1);
        assert_eq!(c.ledger.crash_events, 1);
        let w = c.apply_fault_weather(2);
        assert_eq!(w.members.len(), 1);
    }

    #[test]
    fn member_subset_ops_charge_less_and_skip_dead_clocks() {
        let mut c = cluster(4);
        let members = vec![0, 2, 3];
        let outs = c.map_each_scratch_members(&members, |p, _, _| p);
        assert_eq!(outs, vec![0, 2, 3]);
        // dead node 1's clock never moved
        assert_eq!(c.engine.node_ready(1), 0.0);
        let parts: Vec<Vec<f64>> =
            members.iter().map(|_| vec![1.0; 30]).collect();
        let sum = c.reduce_parts_members(&parts, true, &members);
        assert_eq!(sum[0], 3.0);
        assert_eq!(c.engine.node_ready(1), 0.0);
        // subset tree is shallower than the full tree: 3 members ⇒
        // depth 2 (same here), but 2 members ⇒ depth 1 < depth 2
        let mut c2 = cluster(4);
        let two = vec![0, 3];
        let parts2: Vec<Vec<f64>> = vec![vec![1.0; 30]; 2];
        let _ = c2.reduce_parts_members(&parts2, false, &two);
        let mut c3 = cluster(4);
        let _ = c3.reduce_parts(&[vec![1.0; 30]; 4], false);
        assert!(c2.ledger.comm_seconds < c3.ledger.comm_seconds);
    }

    #[test]
    fn full_membership_members_calls_match_legacy_exactly() {
        let all: Vec<usize> = (0..4).collect();
        let mut legacy = cluster(4);
        let mut via = cluster(4);
        let parts: Vec<SparseVec> = (0..4)
            .map(|p| {
                SparseVec::from_pairs(30, vec![(p as u32, 1.0), (7, 0.5)])
            })
            .collect();
        let a = legacy.reduce_parts_sparse(&parts, true);
        let b = via.reduce_parts_sparse_members(&parts, true, &all);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(legacy.ledger, via.ledger);
        let sa = legacy.map_reduce_scalars_scratch(|_, s, _| {
            [s.xl.n_rows() as f64]
        });
        let sb = via.map_reduce_scalars_scratch_members(&all, |_, s, _| {
            [s.xl.n_rows() as f64]
        });
        assert_eq!(sa, sb);
        assert_eq!(legacy.ledger.scalar_rounds, via.ledger.scalar_rounds);
    }

    #[test]
    fn timeline_json_carries_resilience_block() {
        let mut c = cluster(3);
        c.ledger.record_async_round(&[0, 1], true);
        c.ledger.crash_events = 2;
        c.ledger.recovery_seconds = 0.25;
        let v = c.timeline_json();
        let r = v.get("resilience").expect("resilience block");
        assert_eq!(r.get("crash_events").unwrap().as_usize(), Some(2));
        assert_eq!(
            r.get("fallback_rounds").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            r.get("recovery_seconds").unwrap().as_f64(),
            Some(0.25)
        );
        match r.get("alive") {
            Some(Value::Arr(a)) => assert_eq!(a.len(), 3),
            other => panic!("alive not an array: {other:?}"),
        }
        // the link-weather block rides along with its own schema
        let le = v.get("link_events").expect("link_events block");
        assert_eq!(le.get("link_retries").unwrap().as_usize(), Some(0));
        assert_eq!(le.get("reroutes").unwrap().as_usize(), Some(0));
        assert_eq!(le.get("congested_hops").unwrap().as_usize(), Some(0));
        assert_eq!(le.get("partition_events").unwrap().as_usize(), Some(0));
        assert_eq!(le.get("retry_seconds").unwrap().as_f64(), Some(0.0));
        assert_eq!(r.get("retry_seconds").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn uniform_links_and_empty_plan_are_structurally_inert() {
        // the bit-identity mechanism: with a uniform profile and an
        // empty plan, link_active() is false and every comm entry
        // point takes the legacy code path verbatim
        let mut base = cluster(5);
        let mut linked = cluster(5);
        linked.set_link_profile(LinkProfile::uniform(5));
        linked.set_link_fault_plan(LinkFaultPlan::default());
        assert!(!linked.link_active());
        let run = |c: &mut Cluster| {
            c.broadcast_vec();
            let _ = c.map_allreduce_vec(|_, _| vec![1.0; 30]);
            let parts: Vec<SparseVec> = (0..5)
                .map(|p| SparseVec::from_pairs(30, vec![(p as u32, 1.0)]))
                .collect();
            let _ = c.reduce_parts_sparse(&parts, true);
            c.charge_scalar_round(3);
            let w = c.apply_fault_weather(0);
            assert_eq!(w.members, vec![0, 1, 2, 3, 4]);
            assert!(!w.heal_resync);
        };
        run(&mut base);
        run(&mut linked);
        assert_eq!(base.ledger, linked.ledger);
        assert_eq!(base.engine.makespan(), linked.engine.makespan());
        assert_eq!(
            base.engine.events().len(),
            linked.engine.events().len()
        );
    }

    #[test]
    fn heterogeneous_links_stretch_reduces_and_split_retry_time() {
        let mut slow = cluster(4);
        // node 1's uplink runs at 1/3 speed
        slow.set_link_profile(LinkProfile {
            uplink: vec![1.0, 3.0, 1.0, 1.0],
            level: Vec::new(),
        });
        assert!(slow.link_active());
        let mut base = cluster(4);
        let parts: Vec<Vec<f64>> = vec![vec![1.0; 30]; 4];
        let a = slow.reduce_parts(&parts, true);
        let b = base.reduce_parts(&parts, true);
        // arithmetic is untouched; only the wire time stretches
        assert_eq!(a, b);
        assert!(
            slow.ledger.comm_seconds > base.ledger.comm_seconds,
            "slow {} vs base {}",
            slow.ledger.comm_seconds,
            base.ledger.comm_seconds
        );
        // pure profile skew: no retries, no retry time
        assert_eq!(slow.ledger.retry_seconds, 0.0);
        assert_eq!(slow.ledger.link_retries, 0);

        // a flapping plan accrues the distinct retry counter
        let mut flappy = cluster(4);
        let plan = LinkFaultPlan {
            flap_p: 1.0,
            ..LinkFaultPlan::default()
        };
        flappy.set_link_fault_plan(plan);
        let _ = flappy.reduce_parts(&parts, true);
        assert!(flappy.ledger.link_retries > 0);
        assert!(flappy.ledger.retry_seconds > 0.0);
        assert!(flappy.ledger.has_fault_activity());
        // retry time is NOT folded into comm time: the comm component
        // alone stays at least the clean wire's
        assert!(
            flappy.ledger.comm_seconds >= base.ledger.comm_seconds
        );
    }

    #[test]
    fn partition_cuts_members_and_heals_with_resync() {
        let mut c = cluster(4);
        let plan = LinkFaultPlan {
            partitions: vec![faults::LinkPartition {
                from: 1,
                until: 3,
                nodes: vec![1, 2, 3],
            }],
            ..LinkFaultPlan::default()
        };
        c.set_link_fault_plan(plan);
        // round 0: clear
        let w = c.apply_fault_weather(0);
        assert_eq!(w.members, vec![0, 1, 2, 3]);
        // round 1: the cut fires — master alone in its component
        let w = c.apply_fault_weather(1);
        assert_eq!(w.members, vec![0]);
        assert!(w.healed.is_empty());
        assert_eq!(c.ledger.partition_events, 1);
        assert!(c.link_faults.as_ref().unwrap().master_isolated);
        // round 2: still cut, no double-fire
        let w = c.apply_fault_weather(2);
        assert_eq!(w.members, vec![0]);
        assert_eq!(c.ledger.partition_events, 1);
        // round 3: heal — everyone back, master-isolation forces the
        // certified synchronous resync
        let w = c.apply_fault_weather(3);
        assert_eq!(w.members, vec![0, 1, 2, 3]);
        assert_eq!(w.healed, vec![1, 2, 3]);
        assert!(w.heal_resync);
        assert!(!c.link_faults.as_ref().unwrap().master_isolated);
        // round 4: clear again, heal fired once
        let w = c.apply_fault_weather(4);
        assert!(w.healed.is_empty());
        assert!(!w.heal_resync);
        // the link log replays the story on its own watermark
        assert_eq!(c.link_log_len(), 6);
        assert_eq!(c.link_log_entry(0), Some((1, 1, "partition")));
        assert_eq!(c.link_log_entry(3), Some((3, 1, "heal")));
        assert_eq!(c.fault_log_len(), 0);
    }

    #[test]
    fn total_partition_of_survivors_never_empties_members() {
        let mut c = cluster(3);
        // crash node 0's peers' membership down to {0,2}, then cut 2:
        // the cut would leave {0} — allowed (master frame). But if the
        // whole non-crashed set were cut the cut is ignored.
        let fp = FaultPlan::parse("crash:1@r1", 3).unwrap();
        c.set_fault_plan(fp);
        let plan = LinkFaultPlan {
            partitions: vec![faults::LinkPartition {
                from: 1,
                until: 4,
                nodes: vec![2],
            }],
            ..LinkFaultPlan::default()
        };
        c.set_link_fault_plan(plan);
        let w = c.apply_fault_weather(1);
        assert_eq!(w.members, vec![0]);
        // now crash node 0 too (last survivor rule keeps one member);
        // a cut of the only member is ignored rather than emptying
        let mut c2 = cluster(2);
        let plan2 = LinkFaultPlan {
            partitions: vec![faults::LinkPartition {
                from: 0,
                until: 2,
                nodes: vec![1],
            }],
            ..LinkFaultPlan::default()
        };
        c2.set_link_fault_plan(plan2);
        let fp2 = FaultPlan::parse("crash:0@r0", 2).unwrap();
        c2.set_fault_plan(fp2);
        let w = c2.apply_fault_weather(0);
        assert_eq!(w.members.len(), 1, "membership never empties");
    }
}
