//! Deterministic, seeded fault injection for the virtual-clock
//! cluster — "fleet weather" the algorithm layer must survive.
//!
//! A [`FaultPlan`] schedules node **crash/restart** (elastic
//! membership: a dead node's shard is absent from the round, a
//! restarted node is re-based onto the current iterate), transient
//! **flaps** (a node sits one round out, nothing to recover),
//! **compute degradation** (the node's [`NodeProfile`] speed changes
//! in place mid-run), and **message loss** on the direction wire (a
//! lost contribution retries once after a virtual timeout; a second
//! loss drops it for the round, absorbed by the partial quorum + the
//! paper's safeguard). Plans come from an explicit CLI script
//! (`--fault crash:3@r2,restart:3@r6,degrade:1@5s:0.25x,flap:2:p=0.05`)
//! or the seeded generator ([`FaultPlan::seeded`]).
//!
//! **Determinism.** Nothing here draws from a sequential RNG stream or
//! a wall clock. Scripted events fire on outer-round indices (`@rN`)
//! or virtual-time thresholds (`@Ts`, quantized to the first round
//! boundary at or past `T`), and every probabilistic decision (flap,
//! wire loss) is a pure hash of `(seed, round, node, salt)` — so the
//! same seed replays the identical fault timeline regardless of
//! thread count or event order, and the [`FaultState::log`] of applied
//! faults is bit-comparable across runs. `@rN` triggers replay exactly
//! under *measured* compute too; `@Ts` thresholds are exact only when
//! compute is modeled (`CostModel::free()`-style scales), since
//! measured per-node seconds move the round boundaries.

/// When a scripted fault fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// at the start of outer round `r`
    Round(usize),
    /// at the first round boundary whose virtual clock is ≥ `t` secs
    Time(f64),
}

/// What fails.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// node leaves the membership: shard absent, quorum shrinks
    Crash(usize),
    /// a crashed node rejoins (the driver re-bases it onto the
    /// current iterate and rebuilds its margin cache)
    Restart(usize),
    /// node's throughput multiplies by `factor` (0.25 = quarter
    /// speed, i.e. compute durations ×4) from now on
    Degrade(usize, f64),
}

/// One applied fault, as recorded in [`FaultState::log`] — the
/// replayable chaos record the determinism tests compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppliedFault {
    pub round: usize,
    pub node: usize,
    /// "crash" | "restart" | "degrade" | "flap" | "retry" | "drop"
    /// — or, on the link log, "partition" | "heal"
    pub what: &'static str,
}

/// The per-round weather the driver acts on: who rejoined (needs a
/// re-base), who participates, and what happened on the wire.
#[derive(Clone, Debug, Default)]
pub struct RoundWeather {
    /// nodes alive and not flapped this round, ascending
    pub members: Vec<usize>,
    /// nodes that crashed out this round (driver clears their lanes)
    pub crashed: Vec<usize>,
    /// nodes that rejoined this round (driver re-bases them)
    pub restarted: Vec<usize>,
    /// members whose direction contribution is lost even after the
    /// retry — absent from the quorum this round
    pub dropped: Vec<usize>,
    /// members whose contribution needed one retry: extra virtual
    /// seconds added to its quorum arrival
    pub delayed: Vec<(usize, f64)>,
    /// nodes whose partition healed this round (driver re-bases them;
    /// unlike `restarted`, their solver lanes survive — anything ≤ τ
    /// stale rejoins the quorum, anything older was already expired)
    pub healed: Vec<usize>,
    /// a master-isolating partition healed this round: the driver must
    /// route the round through the certified synchronous fallback so
    /// the whole fleet resynchronizes on one iterate
    pub heal_resync: bool,
}

impl RoundWeather {
    /// Weather for a cluster with no fault plan: everyone plays.
    pub fn clear(n: usize) -> RoundWeather {
        RoundWeather { members: (0..n).collect(), ..RoundWeather::default() }
    }
}

const SALT_FLAP: u64 = 0xF1A9;
const SALT_LOSS: u64 = 0x10E5;
const SALT_RETRY: u64 = 0x9E7B;
const SALT_GEN: u64 = 0x5EED;
const SALT_CONGEST: u64 = 0xC0F3;
const SALT_LINKFLAP: u64 = 0x1F1A;
const SALT_ATTEMPTS: u64 = 0xA77E;

/// SplitMix64 over a mix of the inputs: an order-independent,
/// replayable hash — NOT a sequential stream, so fault decisions do
/// not depend on how many other decisions were drawn before them.
/// `pub(crate)` so the link layer ([`LinkFaultPlan`],
/// [`LinkProfile`](super::cost::LinkProfile)) draws from the same
/// primitive.
pub(crate) fn mix(seed: u64, a: u64, b: u64, salt: u64) -> u64 {
    let mut z = seed
        ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ b.wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ salt.wrapping_mul(0x94D0_49BB_1331_11EB);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Bernoulli(p) from the hash of `(seed, round, node, salt)`.
fn coin(seed: u64, round: usize, node: usize, salt: u64, p: f64) -> bool {
    if p <= 0.0 {
        return false;
    }
    if p >= 1.0 {
        return true;
    }
    let u = (mix(seed, round as u64, node as u64, salt) >> 11) as f64
        / (1u64 << 53) as f64;
    u < p
}

/// A seeded fault schedule. `Default` is the empty plan (no faults) —
/// installing it must leave every run bit-identical to no plan at all
/// (`tests/faults.rs` pins this).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// scripted crash/restart/degrade events
    pub events: Vec<(Trigger, FaultKind)>,
    /// `(node, p)`: node flaps out of any given round w.p. `p`
    pub flaps: Vec<(usize, f64)>,
    /// per-member per-round probability a direction contribution is
    /// lost on the wire (retry once, then drop)
    pub loss_p: f64,
    /// virtual seconds a retried contribution arrives late
    pub retry_delay_s: f64,
    /// seed driving the flap/loss coins
    pub seed: u64,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.flaps.is_empty() && self.loss_p <= 0.0
    }

    /// Parse a comma-separated CLI fault script. Grammar (one spec per
    /// comma-separated item; `N` a node index < `nodes`):
    ///
    /// - `crash:N@rR` / `crash:N@T s`-style `crash:N@12.5s`
    /// - `restart:N@rR` / `restart:N@30s`
    /// - `degrade:N@rR:Fx` / `degrade:N@5s:0.25x` (`F` = throughput
    ///   multiplier, 0 < F)
    /// - `flap:N:p=P` (0 ≤ P ≤ 1)
    /// - `loss:p=P` (0 ≤ P ≤ 1, applies to every member's wire)
    ///
    /// Returns a one-line error naming the offending spec otherwise.
    pub fn parse(script: &str, nodes: usize) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan { retry_delay_s: 0.005, ..FaultPlan::default() };
        for spec in script.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let mut parts = spec.split(':');
            let kind = parts.next().unwrap_or("");
            let rest: Vec<&str> = parts.collect();
            match kind {
                "crash" | "restart" => {
                    let (node, trig) = parse_node_at(spec, &rest, nodes)?;
                    let ev = if kind == "crash" {
                        FaultKind::Crash(node)
                    } else {
                        FaultKind::Restart(node)
                    };
                    plan.events.push((trig, ev));
                }
                "degrade" => {
                    if rest.len() != 2 {
                        return Err(bad(spec, "want degrade:N@T:Fx"));
                    }
                    let (node, trig) =
                        parse_node_at(spec, &rest[..1], nodes)?;
                    let f = rest[1]
                        .strip_suffix('x')
                        .ok_or_else(|| bad(spec, "factor must end in 'x'"))?
                        .parse::<f64>()
                        .map_err(|_| bad(spec, "bad degrade factor"))?;
                    if f.is_nan() || f <= 0.0 {
                        return Err(bad(spec, "degrade factor must be > 0"));
                    }
                    plan.events.push((trig, FaultKind::Degrade(node, f)));
                }
                "flap" => {
                    if rest.len() != 2 {
                        return Err(bad(spec, "want flap:N:p=P"));
                    }
                    let node = parse_node(spec, rest[0], nodes)?;
                    let p = parse_prob(spec, rest[1])?;
                    plan.flaps.push((node, p));
                }
                "loss" => {
                    if rest.len() != 1 {
                        return Err(bad(spec, "want loss:p=P"));
                    }
                    plan.loss_p = parse_prob(spec, rest[0])?;
                }
                _ => {
                    return Err(bad(
                        spec,
                        "unknown fault kind (crash|restart|degrade|flap|loss)",
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Seeded fleet-weather generator: one crash + later restart of a
    /// hashed victim, one degrade of a different node, a low-rate flap
    /// and a low-rate wire loss — all round-indexed so the plan
    /// replays exactly under measured compute. The bench matrix runs
    /// this across seeds.
    pub fn seeded(nodes: usize, seed: u64) -> FaultPlan {
        if nodes < 2 {
            return FaultPlan { seed, ..FaultPlan::default() };
        }
        let pick = |k: u64, m: usize| (mix(seed, k, 0, SALT_GEN) as usize) % m;
        let victim = pick(1, nodes);
        let crash_r = 2 + pick(2, 4);
        let down_for = 2 + pick(3, 4);
        let slow = (victim + 1 + pick(4, nodes - 1)) % nodes;
        let flappy = (victim + 1 + pick(5, nodes - 1)) % nodes;
        FaultPlan {
            events: vec![
                (Trigger::Round(crash_r), FaultKind::Crash(victim)),
                (
                    Trigger::Round(crash_r + down_for),
                    FaultKind::Restart(victim),
                ),
                (Trigger::Round(1), FaultKind::Degrade(slow, 0.5)),
            ],
            flaps: vec![(flappy, 0.1)],
            loss_p: 0.05,
            retry_delay_s: 0.005,
            seed,
        }
    }
}

fn bad(spec: &str, why: &str) -> String {
    format!("bad --fault spec {spec:?}: {why}")
}

fn parse_node(spec: &str, s: &str, nodes: usize) -> Result<usize, String> {
    let node = s
        .parse::<usize>()
        .map_err(|_| bad(spec, "node must be an integer"))?;
    if node >= nodes {
        return Err(bad(
            spec,
            &format!("node {node} out of range (P = {nodes})"),
        ));
    }
    Ok(node)
}

fn parse_prob(spec: &str, s: &str) -> Result<f64, String> {
    let p = s
        .strip_prefix("p=")
        .ok_or_else(|| bad(spec, "probability must be written p=P"))?
        .parse::<f64>()
        .map_err(|_| bad(spec, "bad probability"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(bad(spec, "probability must be in [0, 1]"));
    }
    Ok(p)
}

/// `N@rR` or `N@Ts` → (node, trigger).
fn parse_node_at(
    spec: &str,
    rest: &[&str],
    nodes: usize,
) -> Result<(usize, Trigger), String> {
    let [nat] = rest else {
        return Err(bad(spec, "want N@rR or N@Ts"));
    };
    let (n, at) = nat
        .split_once('@')
        .ok_or_else(|| bad(spec, "missing @trigger"))?;
    let node = parse_node(spec, n, nodes)?;
    let trig = if let Some(r) = at.strip_prefix('r') {
        Trigger::Round(
            r.parse::<usize>()
                .map_err(|_| bad(spec, "bad round trigger"))?,
        )
    } else {
        let t = at
            .strip_suffix('s')
            .unwrap_or(at)
            .parse::<f64>()
            .map_err(|_| bad(spec, "bad time trigger"))?;
        if t.is_nan() || t < 0.0 {
            return Err(bad(spec, "time trigger must be ≥ 0"));
        }
        Trigger::Time(t)
    };
    Ok((node, trig))
}

/// Runtime state of a plan: which scripted events already fired, and
/// the applied-fault log.
#[derive(Clone, Debug)]
pub struct FaultState {
    pub plan: FaultPlan,
    fired: Vec<bool>,
    /// every fault actually applied, in application order
    pub log: Vec<AppliedFault>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        let fired = vec![false; plan.events.len()];
        FaultState { plan, fired, log: Vec::new() }
    }

    /// Scripted events due at round `r` / virtual time `now`, in
    /// script order; each fires exactly once.
    pub fn due(&mut self, r: usize, now: f64) -> Vec<FaultKind> {
        let mut out = Vec::new();
        for (i, &(trig, kind)) in self.plan.events.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            let hit = match trig {
                Trigger::Round(tr) => r >= tr,
                Trigger::Time(t) => now >= t,
            };
            if hit {
                self.fired[i] = true;
                out.push(kind);
            }
        }
        out
    }

    /// Does `node` flap out of round `r`?
    pub fn flaps(&self, r: usize, node: usize) -> bool {
        self.plan
            .flaps
            .iter()
            .any(|&(p, prob)| p == node && coin(self.plan.seed, r, node, SALT_FLAP, prob))
    }

    /// Fate of `node`'s direction contribution in round `r` under the
    /// wire-loss model: `None` = delivered, `Some(Some(delay))` =
    /// retried (arrives `delay` late), `Some(None)` = dropped after
    /// the retry also failed.
    pub fn wire_fate(&self, r: usize, node: usize) -> Option<Option<f64>> {
        let p = self.plan.loss_p;
        if !coin(self.plan.seed, r, node, SALT_LOSS, p) {
            return None;
        }
        if coin(self.plan.seed, r, node, SALT_RETRY, p) {
            Some(None) // lost twice: dropped for the round
        } else {
            Some(Some(self.plan.retry_delay_s))
        }
    }

    pub fn record(&mut self, round: usize, node: usize, what: &'static str) {
        self.log.push(AppliedFault { round, node, what });
    }

    /// Publish the applied-fault log as per-kind counters, in the
    /// fixed kind order, through the one
    /// [`Registry`](crate::obs::Registry) render path.
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        reg.counter("applied", self.log.len() as u64);
        for what in ["crash", "restart", "degrade", "flap", "retry", "drop"]
        {
            let n = self.log.iter().filter(|e| e.what == what).count();
            reg.counter(what, n as u64);
        }
    }
}

/// One scripted partition: the listed component is cut away from the
/// master's component for rounds `from..until`. Node 0 can never be
/// listed — the master's side is the reference frame, so "isolating
/// the master" is expressed by cutting every *other* node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LinkPartition {
    /// first round the cut is active
    pub from: usize,
    /// first round after the heal (exclusive)
    pub until: usize,
    /// the cut component, ascending, never containing node 0
    pub nodes: Vec<usize>,
}

/// A seeded link-weather schedule over the reduction tree's edges.
/// `Default` is the empty plan (clear wire) — installing it must leave
/// every run bit-identical to no plan at all (`tests/faults.rs` pins
/// this). Every probabilistic decision is a pure hash of
/// `(seed, round, edge)` where an edge is `(tree level, sending
/// subtree representative)` — one seed replays the identical weather
/// regardless of evaluation order.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkFaultPlan {
    /// per-round per-edge probability the link is congested: its hop
    /// cost multiplies by `congest_mult` for that round's window
    pub congest_p: f64,
    /// bandwidth-collapse factor on a congested edge
    pub congest_mult: f64,
    /// per-round per-edge probability the link flaps: the hop times
    /// out and enters the retry/backoff ladder
    pub flap_p: f64,
    /// scripted partitions splitting the tree into components
    pub partitions: Vec<LinkPartition>,
    /// virtual seconds before a hop attempt is declared dead — the
    /// base rung of the exponential-backoff ladder
    pub timeout_s: f64,
    /// failed attempts allowed before rerouting around the dead edge
    pub retry_budget: u32,
    /// diagnostic arm for the benches: disable the timeout discipline
    /// and wait out a dead link's full flap window instead (strictly
    /// slower; `benches/link_weather.rs` pins that)
    pub no_retry: bool,
    /// seed driving the congestion/flap coins
    pub seed: u64,
}

impl Default for LinkFaultPlan {
    fn default() -> Self {
        LinkFaultPlan {
            congest_p: 0.0,
            congest_mult: 8.0,
            flap_p: 0.0,
            partitions: Vec::new(),
            timeout_s: 2e-3,
            retry_budget: 3,
            no_retry: false,
            seed: 0,
        }
    }
}

impl LinkFaultPlan {
    pub fn is_empty(&self) -> bool {
        self.congest_p <= 0.0
            && self.flap_p <= 0.0
            && self.partitions.is_empty()
    }

    /// Parse a comma-separated CLI link-fault script. Grammar (one
    /// spec per item; node indices < `nodes`, node 0 never cut):
    ///
    /// - `congest:p=P` / `congest:p=P:Fx` — per-edge congestion
    ///   probability, optional bandwidth-collapse factor (default 8x)
    /// - `flap:p=P` — per-edge flap probability
    /// - `part:A+B@rF..rU` — cut nodes {A, B, ...} away for rounds
    ///   F..U (heals at U)
    /// - `timeout:T` — hop deadline in virtual seconds
    /// - `budget:K` — failed attempts before rerouting
    /// - `noretry` — wait out dead links instead (bench arm)
    ///
    /// Returns a one-line error naming the offending spec otherwise.
    pub fn parse(script: &str, nodes: usize) -> Result<LinkFaultPlan, String> {
        let bad = |spec: &str, why: &str| {
            format!("bad --link-fault spec {spec:?}: {why}")
        };
        let mut plan = LinkFaultPlan::default();
        for spec in script.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            let mut parts = spec.split(':');
            let kind = parts.next().unwrap_or("");
            let rest: Vec<&str> = parts.collect();
            match kind {
                "congest" => {
                    if rest.is_empty() || rest.len() > 2 {
                        return Err(bad(spec, "want congest:p=P[:Fx]"));
                    }
                    plan.congest_p = parse_prob(spec, rest[0])
                        .map_err(|_| bad(spec, "bad probability"))?;
                    if let Some(fs) = rest.get(1) {
                        let f = fs
                            .strip_suffix('x')
                            .ok_or_else(|| {
                                bad(spec, "factor must end in 'x'")
                            })?
                            .parse::<f64>()
                            .map_err(|_| bad(spec, "bad congest factor"))?;
                        if !f.is_finite() || f < 1.0 {
                            return Err(bad(
                                spec,
                                "congest factor must be ≥ 1",
                            ));
                        }
                        plan.congest_mult = f;
                    }
                }
                "flap" => {
                    if rest.len() != 1 {
                        return Err(bad(spec, "want flap:p=P"));
                    }
                    plan.flap_p = parse_prob(spec, rest[0])
                        .map_err(|_| bad(spec, "bad probability"))?;
                }
                "part" => {
                    if rest.len() != 1 {
                        return Err(bad(spec, "want part:A+B@rF..rU"));
                    }
                    let (who, span) = rest[0]
                        .split_once('@')
                        .ok_or_else(|| bad(spec, "missing @rF..rU"))?;
                    let (f, u) = span
                        .split_once("..")
                        .ok_or_else(|| bad(spec, "want @rF..rU"))?;
                    let from = f
                        .strip_prefix('r')
                        .and_then(|s| s.parse::<usize>().ok())
                        .ok_or_else(|| bad(spec, "bad from-round"))?;
                    let until = u
                        .strip_prefix('r')
                        .and_then(|s| s.parse::<usize>().ok())
                        .ok_or_else(|| bad(spec, "bad until-round"))?;
                    if until <= from {
                        return Err(bad(spec, "need from < until"));
                    }
                    let mut cut = Vec::new();
                    for n in who.split('+') {
                        let node = n.parse::<usize>().map_err(|_| {
                            bad(spec, "cut nodes must be integers")
                        })?;
                        if node == 0 {
                            return Err(bad(
                                spec,
                                "node 0 is the reference frame — cut the \
                                 other side",
                            ));
                        }
                        if node >= nodes {
                            return Err(bad(
                                spec,
                                &format!(
                                    "node {node} out of range (P = {nodes})"
                                ),
                            ));
                        }
                        cut.push(node);
                    }
                    cut.sort_unstable();
                    cut.dedup();
                    plan.partitions.push(LinkPartition {
                        from,
                        until,
                        nodes: cut,
                    });
                }
                "timeout" => {
                    if rest.len() != 1 {
                        return Err(bad(spec, "want timeout:T"));
                    }
                    let t = rest[0]
                        .strip_suffix('s')
                        .unwrap_or(rest[0])
                        .parse::<f64>()
                        .map_err(|_| bad(spec, "bad timeout"))?;
                    if !t.is_finite() || t <= 0.0 {
                        return Err(bad(spec, "timeout must be > 0"));
                    }
                    plan.timeout_s = t;
                }
                "budget" => {
                    if rest.len() != 1 {
                        return Err(bad(spec, "want budget:K"));
                    }
                    plan.retry_budget = rest[0]
                        .parse::<u32>()
                        .map_err(|_| bad(spec, "bad retry budget"))?;
                    if plan.retry_budget == 0 || plan.retry_budget > 16 {
                        return Err(bad(spec, "budget must be in 1..=16"));
                    }
                }
                "noretry" => {
                    if !rest.is_empty() {
                        return Err(bad(spec, "noretry takes no arguments"));
                    }
                    plan.no_retry = true;
                }
                _ => {
                    return Err(bad(
                        spec,
                        "unknown link fault kind \
                         (congest|flap|part|timeout|budget|noretry)",
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Seeded link-weather generator: moderate congestion, a flappy
    /// fabric, and one short partition of the last node. Round-indexed
    /// and hash-driven, so the plan replays exactly.
    pub fn seeded(nodes: usize, seed: u64) -> LinkFaultPlan {
        if nodes < 2 {
            return LinkFaultPlan { seed, ..LinkFaultPlan::default() };
        }
        LinkFaultPlan {
            congest_p: 0.15,
            congest_mult: 6.0,
            flap_p: 0.1,
            partitions: vec![LinkPartition {
                from: 3,
                until: 6,
                nodes: vec![nodes - 1],
            }],
            seed,
            ..LinkFaultPlan::default()
        }
    }

    fn edge(level: usize, sender: usize) -> u64 {
        ((level as u64) << 32) | sender as u64
    }

    /// Is the edge `(level, sender)` congested in round `r`?
    pub fn congested(&self, r: usize, level: usize, sender: usize) -> bool {
        if self.congest_p <= 0.0 {
            return false;
        }
        let u = (mix(self.seed, r as u64, Self::edge(level, sender),
                SALT_CONGEST)
            >> 11) as f64
            / (1u64 << 53) as f64;
        u < self.congest_p
    }

    /// How many attempts on edge `(level, sender)` time out in round
    /// `r` before the transfer would go through — 0 on a healthy
    /// edge, up to `retry_budget + 2` on a flapping one (a draw past
    /// the budget forces a reroute under the retry discipline).
    pub fn failed_attempts(
        &self,
        r: usize,
        level: usize,
        sender: usize,
    ) -> u32 {
        if self.flap_p <= 0.0 {
            return 0;
        }
        let e = Self::edge(level, sender);
        let u = (mix(self.seed, r as u64, e, SALT_LINKFLAP) >> 11) as f64
            / (1u64 << 53) as f64;
        if u >= self.flap_p {
            return 0;
        }
        1 + (mix(self.seed, r as u64, e, SALT_ATTEMPTS)
            % (self.retry_budget as u64 + 2)) as u32
    }

    /// Union of the nodes cut away by every partition active at round
    /// `r`, ascending and deduplicated.
    pub fn cut_at(&self, r: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .partitions
            .iter()
            .filter(|p| p.from <= r && r < p.until)
            .flat_map(|p| p.nodes.iter().copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Runtime state of a link plan: the current round (comm calls hash
/// their edges against it), once-only partition start/heal firing,
/// whether the active cut left the master alone, and the applied link
/// event log ("partition"/"heal" entries, bit-comparable across
/// replays).
#[derive(Clone, Debug)]
pub struct LinkFaultState {
    pub plan: LinkFaultPlan,
    /// current outer round, set by the driver's weather application
    pub round: usize,
    /// the active cut isolates the master: when it heals the driver
    /// must force the certified synchronous resync
    pub master_isolated: bool,
    started: Vec<bool>,
    healed: Vec<bool>,
    /// every applied link event, in application order
    pub log: Vec<AppliedFault>,
}

impl LinkFaultState {
    pub fn new(plan: LinkFaultPlan) -> LinkFaultState {
        let n = plan.partitions.len();
        LinkFaultState {
            plan,
            round: 0,
            master_isolated: false,
            started: vec![false; n],
            healed: vec![false; n],
            log: Vec::new(),
        }
    }

    /// Indices of partitions activating at round `r`; each fires once.
    pub fn due_cuts(&mut self, r: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, p) in self.plan.partitions.iter().enumerate() {
            if !self.started[i] && p.from <= r && r < p.until {
                self.started[i] = true;
                out.push(i);
            }
        }
        out
    }

    /// Indices of partitions healing at round `r`; each fires once and
    /// only after its activation actually fired.
    pub fn due_heals(&mut self, r: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, p) in self.plan.partitions.iter().enumerate() {
            if self.started[i] && !self.healed[i] && r >= p.until {
                self.healed[i] = true;
                out.push(i);
            }
        }
        out
    }

    pub fn record(&mut self, round: usize, node: usize, what: &'static str) {
        self.log.push(AppliedFault { round, node, what });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_script() {
        let plan = FaultPlan::parse(
            "crash:3@12.5s,restart:3@30s,degrade:1@5s:0.25x,flap:2:p=0.05",
            4,
        )
        .unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(plan.events[0], (Trigger::Time(12.5), FaultKind::Crash(3)));
        assert_eq!(
            plan.events[2],
            (Trigger::Time(5.0), FaultKind::Degrade(1, 0.25))
        );
        assert_eq!(plan.flaps, vec![(2, 0.05)]);
        let r = FaultPlan::parse("crash:0@r4,loss:p=0.1", 2).unwrap();
        assert_eq!(r.events, vec![(Trigger::Round(4), FaultKind::Crash(0))]);
        assert!((r.loss_p - 0.1).abs() < 1e-15);
    }

    #[test]
    fn rejects_malformed_specs() {
        for s in [
            "crash:9@r2",       // node out of range
            "crash:1",          // missing trigger
            "degrade:1@r2:0.5", // factor missing 'x'
            "degrade:1@r2:0x",  // zero factor
            "flap:1:0.05",      // probability missing p=
            "flap:1:p=1.5",     // out of [0,1]
            "loss:p=nope",
            "reboot:1@r2", // unknown kind
        ] {
            let e = FaultPlan::parse(s, 4).unwrap_err();
            assert!(e.starts_with("bad --fault spec"), "{s}: {e}");
            assert!(!e.contains('\n'), "one-line error: {e}");
        }
    }

    #[test]
    fn coins_replay_and_events_fire_once() {
        let plan = FaultPlan::parse("crash:1@r3,restart:1@r5", 4)
            .unwrap();
        let mut st = FaultState::new(FaultPlan { seed: 7, ..plan });
        assert!(st.due(0, 0.0).is_empty());
        assert_eq!(st.due(3, 0.0), vec![FaultKind::Crash(1)]);
        assert!(st.due(3, 0.0).is_empty(), "fires once");
        assert_eq!(st.due(9, 0.0), vec![FaultKind::Restart(1)]);
        // hashes are pure in (seed, round, node)
        let a = FaultState::new(FaultPlan {
            flaps: vec![(2, 0.5)],
            loss_p: 0.5,
            seed: 11,
            ..FaultPlan::default()
        });
        let b = a.clone();
        for r in 0..64 {
            assert_eq!(a.flaps(r, 2), b.flaps(r, 2));
            assert_eq!(a.wire_fate(r, 3), b.wire_fate(r, 3));
        }
        // and at p=0.5 both branches actually occur
        assert!((0..64).any(|r| a.flaps(r, 2)));
        assert!((0..64).any(|r| !a.flaps(r, 2)));
    }

    #[test]
    fn link_plan_parses_the_full_grammar() {
        let p = LinkFaultPlan::parse(
            "congest:p=0.2:6x,flap:p=0.1,part:2+3@r3..r7,timeout:0.05,\
             budget:2,noretry",
            4,
        )
        .unwrap();
        assert!((p.congest_p - 0.2).abs() < 1e-15);
        assert_eq!(p.congest_mult, 6.0);
        assert!((p.flap_p - 0.1).abs() < 1e-15);
        assert_eq!(
            p.partitions,
            vec![LinkPartition { from: 3, until: 7, nodes: vec![2, 3] }]
        );
        assert_eq!(p.timeout_s, 0.05);
        assert_eq!(p.retry_budget, 2);
        assert!(p.no_retry);
        assert!(!p.is_empty());
        assert!(LinkFaultPlan::default().is_empty());
    }

    #[test]
    fn link_plan_rejects_malformed_specs() {
        for s in [
            "part:9@r1..r3",      // node out of range
            "part:0+1@r1..r3",    // node 0 is the reference frame
            "part:1@r3..r3",      // empty window
            "part:1@r3",          // missing span
            "congest:0.2",        // probability missing p=
            "flap:p=1.5",         // out of [0,1]
            "congest:p=0.1:0.5x", // factor < 1
            "timeout:-1",         // non-positive deadline
            "budget:0",           // no attempts at all
            "noretry:1",          // stray argument
            "sever:1@r1..r2",     // unknown kind
        ] {
            let e = LinkFaultPlan::parse(s, 4).unwrap_err();
            assert!(e.starts_with("bad --link-fault spec"), "{s}: {e}");
            assert!(!e.contains('\n'), "one-line error: {e}");
        }
    }

    #[test]
    fn link_coins_are_pure_in_seed_round_edge() {
        let a = LinkFaultPlan {
            congest_p: 0.5,
            flap_p: 0.5,
            seed: 11,
            ..LinkFaultPlan::default()
        };
        let b = a.clone();
        for r in 0..64 {
            assert_eq!(a.congested(r, 1, 2), b.congested(r, 1, 2));
            assert_eq!(
                a.failed_attempts(r, 0, 3),
                b.failed_attempts(r, 0, 3)
            );
        }
        // both branches occur, and attempt counts stay in range
        assert!((0..64).any(|r| a.congested(r, 1, 2)));
        assert!((0..64).any(|r| !a.congested(r, 1, 2)));
        assert!((0..64).any(|r| a.failed_attempts(r, 0, 3) > 0));
        assert!((0..64)
            .all(|r| a.failed_attempts(r, 0, 3) <= a.retry_budget + 2));
        // a different seed draws different weather somewhere
        let c = LinkFaultPlan { seed: 12, ..a.clone() };
        assert!((0..64).any(|r| a.congested(r, 1, 2) != c.congested(r, 1, 2)));
    }

    #[test]
    fn partitions_cut_and_heal_once() {
        let plan = LinkFaultPlan::parse("part:1+2@r2..r4", 4).unwrap();
        assert_eq!(plan.cut_at(1), Vec::<usize>::new());
        assert_eq!(plan.cut_at(2), vec![1, 2]);
        assert_eq!(plan.cut_at(3), vec![1, 2]);
        assert_eq!(plan.cut_at(4), Vec::<usize>::new());
        let mut st = LinkFaultState::new(plan);
        assert!(st.due_cuts(1).is_empty());
        assert_eq!(st.due_cuts(2), vec![0]);
        assert!(st.due_cuts(3).is_empty(), "fires once");
        assert!(st.due_heals(3).is_empty());
        assert_eq!(st.due_heals(4), vec![0]);
        assert!(st.due_heals(5).is_empty(), "heals once");
    }

    #[test]
    fn seeded_link_generator_is_deterministic_and_in_range() {
        for seed in [1u64, 2, 3] {
            let p = LinkFaultPlan::seeded(5, seed);
            assert_eq!(p, LinkFaultPlan::seeded(5, seed));
            assert!(!p.is_empty());
            for part in &p.partitions {
                assert!(part.nodes.iter().all(|&n| n > 0 && n < 5));
                assert!(part.from < part.until);
            }
        }
        assert!(LinkFaultPlan::seeded(1, 7).is_empty());
    }

    #[test]
    fn seeded_generator_is_deterministic_and_in_range() {
        for seed in [1u64, 2, 3, 1234] {
            let p = FaultPlan::seeded(5, seed);
            assert_eq!(p, FaultPlan::seeded(5, seed));
            assert!(!p.is_empty());
            for &(_, k) in &p.events {
                let node = match k {
                    FaultKind::Crash(n)
                    | FaultKind::Restart(n)
                    | FaultKind::Degrade(n, _) => n,
                };
                assert!(node < 5);
            }
        }
        assert_ne!(FaultPlan::seeded(5, 1), FaultPlan::seeded(5, 2));
    }
}
