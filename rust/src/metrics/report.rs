//! Experiment report generation: turn a set of traces into the
//! markdown tables EXPERIMENTS.md records — passes/time to target gaps,
//! final metrics, safeguard counts, and (when a run carried a
//! [`Ledger`]) the resilience story: async staleness/fallback counters
//! plus the fault-layer accounting.

use crate::cluster::Ledger;
use crate::metrics::trace::Trace;
use std::fmt::Write as _;

/// Comparison report over several method traces against a shared f*.
pub struct Report<'a> {
    pub traces: &'a [Trace],
    pub f_star: f64,
    /// relative-gap milestones for the to-target table
    pub targets: Vec<f64>,
    /// per-method run ledgers for the resilience table (label, ledger);
    /// empty = the table is omitted (pre-async reports)
    pub ledgers: Vec<(String, Ledger)>,
}

impl<'a> Report<'a> {
    pub fn new(traces: &'a [Trace], f_star: f64) -> Report<'a> {
        Report {
            traces,
            f_star,
            targets: vec![1e-1, 1e-2, 1e-3, 1e-4],
            ledgers: Vec::new(),
        }
    }

    /// Attach run ledgers so [`Self::render`] includes the resilience
    /// table.
    pub fn with_ledgers(
        mut self,
        ledgers: Vec<(String, Ledger)>,
    ) -> Report<'a> {
        self.ledgers = ledgers;
        self
    }

    /// First (passes, seconds) at which a trace's relative gap ≤ t.
    fn first_at(&self, trace: &Trace, t: f64) -> Option<(f64, f64)> {
        trace
            .points
            .iter()
            .find(|p| (p.f - self.f_star) / self.f_star.abs() <= t)
            .map(|p| (p.comm_passes, p.seconds))
    }

    /// Markdown: comm passes to reach each milestone, per method.
    pub fn passes_table(&self) -> String {
        let mut out = String::from("| method |");
        for t in &self.targets {
            let _ = write!(out, " gap ≤ {t:.0e} |");
        }
        out.push('\n');
        out.push_str("|---|");
        out.push_str(&"---|".repeat(self.targets.len()));
        out.push('\n');
        for trace in self.traces {
            let _ = write!(out, "| {} |", trace.label);
            for &t in &self.targets {
                match self.first_at(trace, t) {
                    Some((p, _)) => {
                        let _ = write!(out, " {p:.0} |");
                    }
                    None => out.push_str(" — |"),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Markdown: final state of each method.
    pub fn summary_table(&self) -> String {
        let mut out = String::from(
            "| method | iters | final gap | passes | sim-sec | auprc | safeguard hits |\n|---|---|---|---|---|---|---|\n",
        );
        for trace in self.traces {
            if let Some(p) = trace.points.last() {
                let gap = (p.f - self.f_star) / self.f_star.abs();
                let hits: usize =
                    trace.points.iter().map(|q| q.safeguard_hits).sum();
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.3e} | {:.0} | {:.1} | {:.4} | {} |",
                    trace.label,
                    trace.points.len(),
                    gap,
                    p.comm_passes,
                    p.seconds,
                    p.auprc,
                    hits
                );
            }
        }
        out
    }

    /// Markdown: the resilience counters each attached ledger carries —
    /// async staleness histogram + fallbacks, and the fault accounting
    /// (crashes, rejoins + recovery seconds, wire losses, retries,
    /// degrades, flaps). Empty string when no ledger was attached.
    pub fn resilience_table(&self) -> String {
        if self.ledgers.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "| method | async rounds | fallbacks | staleness | crashes | rejoins | recovery s | lost | retries | degrades | flaps |\n|---|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for (label, l) in &self.ledgers {
            let hist = if l.staleness_hist.is_empty() {
                "—".to_string()
            } else {
                l.staleness_hist
                    .iter()
                    .enumerate()
                    .map(|(s, &n)| format!("s{s}:{n}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            let _ = writeln!(
                out,
                "| {} | {} | {} | {} | {} | {} | {:.3} | {} | {} | {} | {} |",
                label,
                l.async_rounds,
                l.fallback_rounds,
                hist,
                l.crash_events,
                l.rejoin_rebases,
                l.recovery_seconds,
                l.lost_messages,
                l.retry_rounds,
                l.degrade_events,
                l.flap_events,
            );
        }
        out
    }

    pub fn render(&self, title: &str) -> String {
        let mut out = format!(
            "## {title}\n\nf* = {:.8e}\n\n### passes to target gap\n\n{}\n### final state\n\n{}",
            self.f_star,
            self.passes_table(),
            self.summary_table()
        );
        let resilience = self.resilience_table();
        if !resilience.is_empty() {
            let _ = write!(out, "\n### resilience\n\n{resilience}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::TracePoint;

    fn trace(label: &str, gaps: &[f64]) -> Trace {
        let mut t = Trace::new(label);
        for (i, g) in gaps.iter().enumerate() {
            t.push(TracePoint {
                iter: i,
                f: 1.0 + g,
                gnorm: 1.0,
                comm_passes: 4.0 * (i as f64 + 1.0),
                seconds: 0.5 * (i as f64 + 1.0),
                auprc: 0.7,
                safeguard_hits: usize::from(i == 0),
            });
        }
        t
    }

    #[test]
    fn passes_table_finds_milestones() {
        let traces =
            vec![trace("fs-2", &[0.5, 0.05, 0.005]), trace("sqm", &[0.5, 0.2, 0.05])];
        let r = Report::new(&traces, 1.0);
        let table = r.passes_table();
        // fs reaches 1e-2 at point index 2 → 12 passes
        assert!(table.contains("| fs-2 | 8 | 12 |"), "{table}");
        // sqm never reaches 1e-3
        assert!(table.lines().last().unwrap().contains("—"), "{table}");
    }

    #[test]
    fn summary_has_all_methods() {
        let traces = vec![trace("a", &[0.1]), trace("b", &[0.2, 0.1])];
        let r = Report::new(&traces, 1.0);
        let s = r.summary_table();
        assert!(s.contains("| a |") && s.contains("| b |"));
        let full = r.render("test run");
        assert!(full.contains("## test run"));
        // no ledgers attached: the resilience section is omitted
        assert!(!full.contains("### resilience"));
    }

    #[test]
    fn resilience_table_surfaces_fault_counters() {
        let traces = vec![trace("afs", &[0.1])];
        let mut ledger = Ledger {
            crash_events: 1,
            rejoin_rebases: 1,
            recovery_seconds: 0.125,
            lost_messages: 2,
            retry_rounds: 3,
            ..Ledger::default()
        };
        ledger.record_async_round(&[0, 0, 1], false);
        ledger.record_async_round(&[0], true);
        let r = Report::new(&traces, 1.0)
            .with_ledgers(vec![("afs".to_string(), ledger)]);
        let t = r.resilience_table();
        assert!(t.contains("| afs | 2 | 1 | s0:3 s1:1 | 1 | 1 | 0.125 | 2 | 3 | 0 | 0 |"), "{t}");
        let full = r.render("chaos run");
        assert!(full.contains("### resilience"), "{full}");
    }
}
