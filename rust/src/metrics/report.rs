//! Experiment report generation: turn a set of traces into the
//! markdown tables EXPERIMENTS.md records — passes/time to target gaps,
//! final metrics, safeguard counts, and (when a run carried a
//! [`Ledger`]) the resilience story: async staleness/fallback counters
//! plus the fault-layer accounting.
//!
//! Post-hoc: [`RecordedRun::from_jsonl`] reads a `--metrics-out`
//! telemetry stream back and reproduces the in-process run report
//! byte-for-byte ([`render_run_report`] is the single render path both
//! sides share); [`diff_recorded`] compares two streams and names the
//! first divergent round.

use crate::cluster::Ledger;
use crate::metrics::trace::{Trace, TracePoint};
use crate::obs::SCHEMA_VERSION;
use crate::util::json::{self, Value};
use std::fmt::Write as _;

/// Comparison report over several method traces against a shared f*.
pub struct Report<'a> {
    pub traces: &'a [Trace],
    pub f_star: f64,
    /// relative-gap milestones for the to-target table
    pub targets: Vec<f64>,
    /// per-method run ledgers for the resilience table (label, ledger);
    /// empty = the table is omitted (pre-async reports)
    pub ledgers: Vec<(String, Ledger)>,
}

impl<'a> Report<'a> {
    pub fn new(traces: &'a [Trace], f_star: f64) -> Report<'a> {
        Report {
            traces,
            f_star,
            targets: vec![1e-1, 1e-2, 1e-3, 1e-4],
            ledgers: Vec::new(),
        }
    }

    /// Attach run ledgers so [`Self::render`] includes the resilience
    /// table.
    pub fn with_ledgers(
        mut self,
        ledgers: Vec<(String, Ledger)>,
    ) -> Report<'a> {
        self.ledgers = ledgers;
        self
    }

    /// First (passes, seconds) at which a trace's relative gap ≤ t.
    fn first_at(&self, trace: &Trace, t: f64) -> Option<(f64, f64)> {
        trace
            .points
            .iter()
            .find(|p| (p.f - self.f_star) / self.f_star.abs() <= t)
            .map(|p| (p.comm_passes, p.seconds))
    }

    /// Markdown: comm passes to reach each milestone, per method.
    pub fn passes_table(&self) -> String {
        let mut out = String::from("| method |");
        for t in &self.targets {
            let _ = write!(out, " gap ≤ {t:.0e} |");
        }
        out.push('\n');
        out.push_str("|---|");
        out.push_str(&"---|".repeat(self.targets.len()));
        out.push('\n');
        for trace in self.traces {
            let _ = write!(out, "| {} |", trace.label);
            for &t in &self.targets {
                match self.first_at(trace, t) {
                    Some((p, _)) => {
                        let _ = write!(out, " {p:.0} |");
                    }
                    None => out.push_str(" — |"),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Markdown: final state of each method.
    pub fn summary_table(&self) -> String {
        let mut out = String::from(
            "| method | iters | final gap | passes | sim-sec | auprc | safeguard hits |\n|---|---|---|---|---|---|---|\n",
        );
        for trace in self.traces {
            if let Some(p) = trace.points.last() {
                let gap = (p.f - self.f_star) / self.f_star.abs();
                let hits: usize =
                    trace.points.iter().map(|q| q.safeguard_hits).sum();
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.3e} | {:.0} | {:.1} | {:.4} | {} |",
                    trace.label,
                    trace.points.len(),
                    gap,
                    p.comm_passes,
                    p.seconds,
                    p.auprc,
                    hits
                );
            }
        }
        out
    }

    /// Markdown: the resilience counters each attached ledger carries —
    /// async staleness histogram + fallbacks, the fault accounting
    /// (crashes, rejoins + recovery seconds, link retry/backoff
    /// seconds, wire losses, retries, degrades, flaps), and the
    /// speculation outcome (hits/misses). Recovery and retry seconds
    /// are separate columns on purpose: recovery is rejoin re-base
    /// time, retry is link timeout/backoff/reroute time, and neither
    /// is folded into comm seconds. Empty string when no ledger was
    /// attached.
    pub fn resilience_table(&self) -> String {
        if self.ledgers.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "| method | async rounds | fallbacks | staleness | crashes | rejoins | recovery s | retry s | lost | retries | degrades | flaps | spec hits | spec misses |\n|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for (label, l) in &self.ledgers {
            let hist = if l.staleness_hist.is_empty() {
                "—".to_string()
            } else {
                l.staleness_hist
                    .iter()
                    .enumerate()
                    .map(|(s, &n)| format!("s{s}:{n}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            let _ = writeln!(
                out,
                "| {} | {} | {} | {} | {} | {} | {:.3} | {:.3} | {} | {} | {} | {} | {} | {} |",
                label,
                l.async_rounds,
                l.fallback_rounds,
                hist,
                l.crash_events,
                l.rejoin_rebases,
                l.recovery_seconds,
                l.retry_seconds,
                l.lost_messages,
                l.retry_rounds,
                l.degrade_events,
                l.flap_events,
                l.spec_hits,
                l.spec_misses,
            );
        }
        out
    }

    pub fn render(&self, title: &str) -> String {
        let mut out = format!(
            "## {title}\n\nf* = {:.8e}\n\n### passes to target gap\n\n{}\n### final state\n\n{}",
            self.f_star,
            self.passes_table(),
            self.summary_table()
        );
        let resilience = self.resilience_table();
        if !resilience.is_empty() {
            let _ = write!(out, "\n### resilience\n\n{resilience}");
        }
        out
    }
}

/// The single-run report both the CLI (in-process, at the end of a
/// `--metrics-out` run) and the offline reader
/// ([`RecordedRun::report`]) render — one implementation, so the two
/// are byte-identical on the same (trace, ledger, f*). The resilience
/// table appears iff the ledger saw async rounds or fault activity.
pub fn render_run_report(
    trace: &Trace,
    ledger: &Ledger,
    f_star: f64,
) -> String {
    let traces = std::slice::from_ref(trace);
    let mut report = Report::new(traces, f_star);
    if ledger.async_rounds > 0
        || ledger.has_fault_activity()
        || ledger.has_speculation_activity()
    {
        report.ledgers = vec![(trace.label.clone(), ledger.clone())];
    }
    report.render("run")
}

/// A `--metrics-out` JSONL stream read back: the parsed manifest and
/// round records, plus the [`Trace`] and resilience [`Ledger`] rebuilt
/// from them (the trace from each record's trace-mirror fields, the
/// ledger by replaying `record_async_round` and the fault events).
pub struct RecordedRun {
    /// parsed `kind:"manifest"` header
    pub manifest: Value,
    /// parsed `kind:"round"` records, in round order
    pub rounds: Vec<Value>,
    /// trace rebuilt bit-for-bit from the trace-mirror fields
    pub trace: Trace,
    /// resilience counters replayed from the records
    pub ledger: Ledger,
    /// the last recorded objective value (= the run's final f)
    pub f_star: f64,
}

impl RecordedRun {
    /// Parse and validate a telemetry stream: manifest first, matching
    /// schema, then exactly one `round` record per outer round, in
    /// order. Errors name the offending line (1-based).
    pub fn from_jsonl(src: &str) -> Result<RecordedRun, String> {
        let mut lines = src.lines().filter(|l| !l.trim().is_empty());
        let first = lines
            .next()
            .ok_or_else(|| "empty stream: no manifest line".to_string())?;
        let manifest =
            json::parse(first).map_err(|e| format!("line 1: {e}"))?;
        if manifest.get("kind").and_then(Value::as_str) != Some("manifest")
        {
            return Err(
                "line 1: first record must have kind \"manifest\"".into()
            );
        }
        let schema = manifest.get("schema").and_then(Value::as_usize);
        if schema != Some(SCHEMA_VERSION as usize) {
            return Err(format!(
                "unsupported schema {schema:?} (this reader understands {SCHEMA_VERSION})"
            ));
        }
        let label = manifest
            .get("method")
            .and_then(Value::as_str)
            .unwrap_or("run")
            .to_string();
        let mut trace = Trace::new(label);
        let mut ledger = Ledger::default();
        let mut rounds: Vec<Value> = Vec::new();
        let mut stale_buf: Vec<usize> = Vec::new();
        for (i, line) in lines.enumerate() {
            let lineno = i + 2;
            let v = json::parse(line)
                .map_err(|e| format!("line {lineno}: {e}"))?;
            if v.get("kind").and_then(Value::as_str) != Some("round") {
                return Err(format!(
                    "line {lineno}: expected kind \"round\""
                ));
            }
            let round = v.get("round").and_then(Value::as_usize);
            if round != Some(i) {
                return Err(format!(
                    "line {lineno}: round {round:?}, expected {i} \
                     (one record per round, in order)"
                ));
            }
            // null (the non-finite sentinel) reads back as NaN
            let num = |key: &str| {
                v.get(key).and_then(Value::as_f64).unwrap_or(f64::NAN)
            };
            trace.push(TracePoint {
                iter: i,
                f: num("f"),
                gnorm: num("gnorm"),
                comm_passes: num("passes"),
                seconds: num("secs"),
                auprc: num("auprc"),
                safeguard_hits: v
                    .get("sg_hits")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
            });
            // replay the ledger exactly as the drivers fed it: one
            // record_async_round per quorum-path round ...
            if v.get("async").and_then(Value::as_bool) == Some(true) {
                stale_buf.clear();
                if let Some(xs) = v.get("staleness").and_then(Value::as_arr)
                {
                    stale_buf.extend(xs.iter().filter_map(Value::as_usize));
                }
                let fell_back = v
                    .get("fallback")
                    .is_some_and(|f| !matches!(f, Value::Null));
                ledger.record_async_round(&stale_buf, fell_back);
            }
            // ... one counter bump per applied fault event ...
            if let Some(events) = v.get("faults").and_then(Value::as_arr) {
                for ev in events {
                    match ev.get("what").and_then(Value::as_str) {
                        Some("crash") => ledger.crash_events += 1,
                        Some("restart") => ledger.rejoin_rebases += 1,
                        Some("degrade") => ledger.degrade_events += 1,
                        Some("flap") => ledger.flap_events += 1,
                        Some("drop") => ledger.lost_messages += 1,
                        Some("retry") => ledger.retry_rounds += 1,
                        Some("partition") => ledger.partition_events += 1,
                        _ => {}
                    }
                }
            }
            // ... and recovery/retry seconds are recorded cumulative,
            // so the last round's value is the run total
            if let Some(rs) = v.get("recovery_s").and_then(Value::as_f64) {
                ledger.recovery_seconds = rs;
            }
            if let Some(rs) = v.get("retry_s").and_then(Value::as_f64) {
                ledger.retry_seconds = rs;
            }
            // link retry/reroute counts are per-round deltas (absent on
            // pre-link-weather streams → zero)
            ledger.link_retries += v
                .get("link_retries")
                .and_then(Value::as_usize)
                .unwrap_or(0);
            ledger.reroutes +=
                v.get("reroutes").and_then(Value::as_usize).unwrap_or(0);
            // speculation outcomes accumulate round by round (absent on
            // pre-speculation streams → zero)
            ledger.spec_hits +=
                v.get("spec_hits").and_then(Value::as_usize).unwrap_or(0);
            ledger.spec_misses +=
                v.get("spec_misses").and_then(Value::as_usize).unwrap_or(0);
            rounds.push(v);
        }
        let f_star = trace
            .last()
            .map(|p| p.f)
            .ok_or_else(|| "stream has no round records".to_string())?;
        Ok(RecordedRun { manifest, rounds, trace, ledger, f_star })
    }

    /// The offline run report — byte-identical to what the recording
    /// process printed ([`render_run_report`] on its own trace/ledger).
    pub fn report(&self) -> String {
        render_run_report(&self.trace, &self.ledger, self.f_star)
    }
}

/// Keys whose values differ between two records, with both renderings.
fn differing_fields(x: &Value, y: &Value) -> Vec<String> {
    let (Value::Obj(mx), Value::Obj(my)) = (x, y) else {
        return vec!["<record>".to_string()];
    };
    let mut keys: Vec<&String> = mx.keys().chain(my.keys()).collect();
    keys.sort();
    keys.dedup();
    keys.into_iter()
        .filter(|k| mx.get(*k) != my.get(*k))
        .map(|k| {
            let show = |m: &std::collections::BTreeMap<String, Value>| {
                m.get(k).map_or("<absent>".to_string(), |v| v.to_json(0))
            };
            format!("{k}: {} vs {}", show(mx), show(my))
        })
        .collect()
}

/// Run-diff mode: `None` when the two streams describe identical runs,
/// else a description of the first divergence — a manifest mismatch,
/// the first divergent round (with the differing fields), or a length
/// mismatch past the common prefix.
pub fn diff_recorded(a: &RecordedRun, b: &RecordedRun) -> Option<String> {
    if a.manifest != b.manifest {
        return Some(format!(
            "manifests differ: {}",
            differing_fields(&a.manifest, &b.manifest).join("; ")
        ));
    }
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        if ra != rb {
            return Some(format!(
                "first divergent round: {i}\n  {}",
                differing_fields(ra, rb).join("\n  ")
            ));
        }
    }
    if a.rounds.len() != b.rounds.len() {
        return Some(format!(
            "identical through round {}, then lengths differ: {} vs {} rounds",
            a.rounds.len().min(b.rounds.len()).saturating_sub(1),
            a.rounds.len(),
            b.rounds.len()
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::TracePoint;

    fn trace(label: &str, gaps: &[f64]) -> Trace {
        let mut t = Trace::new(label);
        for (i, g) in gaps.iter().enumerate() {
            t.push(TracePoint {
                iter: i,
                f: 1.0 + g,
                gnorm: 1.0,
                comm_passes: 4.0 * (i as f64 + 1.0),
                seconds: 0.5 * (i as f64 + 1.0),
                auprc: 0.7,
                safeguard_hits: usize::from(i == 0),
            });
        }
        t
    }

    #[test]
    fn passes_table_finds_milestones() {
        let traces =
            vec![trace("fs-2", &[0.5, 0.05, 0.005]), trace("sqm", &[0.5, 0.2, 0.05])];
        let r = Report::new(&traces, 1.0);
        let table = r.passes_table();
        // fs reaches 1e-2 at point index 2 → 12 passes
        assert!(table.contains("| fs-2 | 8 | 12 |"), "{table}");
        // sqm never reaches 1e-3
        assert!(table.lines().last().unwrap().contains("—"), "{table}");
    }

    #[test]
    fn summary_has_all_methods() {
        let traces = vec![trace("a", &[0.1]), trace("b", &[0.2, 0.1])];
        let r = Report::new(&traces, 1.0);
        let s = r.summary_table();
        assert!(s.contains("| a |") && s.contains("| b |"));
        let full = r.render("test run");
        assert!(full.contains("## test run"));
        // no ledgers attached: the resilience section is omitted
        assert!(!full.contains("### resilience"));
    }

    /// The two-point async+fault fixture the golden and offline tests
    /// share: hand-computable values, every table populated.
    fn golden_fixture() -> (Trace, Ledger) {
        let mut t = Trace::new("afs");
        t.push(TracePoint {
            iter: 0,
            f: 1.5,
            gnorm: 1.0,
            comm_passes: 4.0,
            seconds: 1.0,
            auprc: f64::NAN,
            safeguard_hits: 1,
        });
        t.push(TracePoint {
            iter: 1,
            f: 0.5,
            gnorm: 0.5,
            comm_passes: 8.0,
            seconds: 1.5,
            auprc: 0.75,
            safeguard_hits: 0,
        });
        let mut ledger = Ledger {
            crash_events: 1,
            rejoin_rebases: 1,
            recovery_seconds: 0.125,
            lost_messages: 2,
            retry_rounds: 3,
            ..Ledger::default()
        };
        ledger.record_async_round(&[0, 0, 1], false);
        ledger.record_async_round(&[0], true);
        (t, ledger)
    }

    const GOLDEN_RUN_REPORT: &str = "\
## run

f* = 5.00000000e-1

### passes to target gap

| method | gap ≤ 1e-1 | gap ≤ 1e-2 | gap ≤ 1e-3 | gap ≤ 1e-4 |
|---|---|---|---|---|
| afs | 8 | 8 | 8 | 8 |

### final state

| method | iters | final gap | passes | sim-sec | auprc | safeguard hits |
|---|---|---|---|---|---|---|
| afs | 2 | 0.000e0 | 8 | 1.5 | 0.7500 | 1 |

### resilience

| method | async rounds | fallbacks | staleness | crashes | rejoins | recovery s | retry s | lost | retries | degrades | flaps | spec hits | spec misses |
|---|---|---|---|---|---|---|---|---|---|---|---|---|---|
| afs | 2 | 1 | s0:3 s1:1 | 1 | 1 | 0.125 | 0.000 | 2 | 3 | 0 | 0 | 0 | 0 |
";

    #[test]
    fn golden_full_render_markdown_is_pinned() {
        // pins the complete Report::render output (summary +
        // resilience) for a seeded async+fault-shaped run — any render
        // change must update this string consciously
        let (trace, ledger) = golden_fixture();
        let got = render_run_report(&trace, &ledger, 0.5);
        assert_eq!(got, GOLDEN_RUN_REPORT);
    }

    /// The JSONL stream a recorded run of [`golden_fixture`] produces
    /// (trimmed to the fields the reader consumes).
    const GOLDEN_STREAM: &str = concat!(
        "{\"kind\":\"manifest\",\"schema\":1,\"method\":\"afs\",\"nodes\":3}\n",
        "{\"kind\":\"round\",\"round\":0,\"f\":1.5,\"gnorm\":1,\"auprc\":null,",
        "\"passes\":4,\"secs\":1,\"sg_hits\":1,\"async\":true,",
        "\"staleness\":[0,0,1],\"fallback\":null,",
        "\"faults\":[{\"node\":1,\"what\":\"crash\"},{\"node\":1,\"what\":\"restart\"},",
        "{\"node\":2,\"what\":\"drop\"},{\"node\":2,\"what\":\"drop\"},",
        "{\"node\":0,\"what\":\"retry\"},{\"node\":0,\"what\":\"retry\"},",
        "{\"node\":0,\"what\":\"retry\"}],\"recovery_s\":0.125}\n",
        "{\"kind\":\"round\",\"round\":1,\"f\":0.5,\"gnorm\":0.5,\"auprc\":0.75,",
        "\"passes\":8,\"secs\":1.5,\"sg_hits\":0,\"async\":true,",
        "\"staleness\":[0],\"fallback\":\"safeguard\",\"recovery_s\":0.125}\n",
    );

    #[test]
    fn from_jsonl_reproduces_the_in_process_report() {
        let run = RecordedRun::from_jsonl(GOLDEN_STREAM).unwrap();
        assert_eq!(run.rounds.len(), 2);
        assert_eq!(run.trace.label, "afs");
        assert_eq!(run.f_star, 0.5);
        // the replayed ledger carries the fixture's counters ...
        assert_eq!(run.ledger.async_rounds, 2);
        assert_eq!(run.ledger.fallback_rounds, 1);
        assert_eq!(run.ledger.staleness_hist, vec![3, 1]);
        assert_eq!(run.ledger.crash_events, 1);
        assert_eq!(run.ledger.lost_messages, 2);
        assert_eq!(run.ledger.retry_rounds, 3);
        // pre-link-weather streams (no retry_s/link keys) replay clean
        assert_eq!(run.ledger.retry_seconds, 0.0);
        assert_eq!(run.ledger.link_retries, 0);
        assert_eq!(run.ledger.partition_events, 0);
        // ... and the offline report is byte-identical to the
        // in-process render of the same run
        assert_eq!(run.report(), GOLDEN_RUN_REPORT);
    }

    #[test]
    fn from_jsonl_replays_link_weather_counters() {
        let stream = concat!(
            "{\"kind\":\"manifest\",\"schema\":1,\"method\":\"afs\"}\n",
            "{\"kind\":\"round\",\"round\":0,\"f\":1.5,\"async\":true,",
            "\"staleness\":[0],\"fallback\":null,",
            "\"faults\":[{\"node\":2,\"what\":\"partition\"}],",
            "\"retry_s\":0.125,\"link_retries\":3,\"reroutes\":1}\n",
            "{\"kind\":\"round\",\"round\":1,\"f\":0.5,\"async\":true,",
            "\"staleness\":[0],\"fallback\":null,",
            "\"faults\":[{\"node\":2,\"what\":\"heal\"}],",
            "\"retry_s\":0.5,\"link_retries\":2,\"reroutes\":0}\n",
        );
        let run = RecordedRun::from_jsonl(stream).unwrap();
        // retry_s is cumulative → last round wins; counts accumulate
        assert_eq!(run.ledger.retry_seconds, 0.5);
        assert_eq!(run.ledger.link_retries, 5);
        assert_eq!(run.ledger.reroutes, 1);
        // a partition bumps the counter; its heal does not
        assert_eq!(run.ledger.partition_events, 1);
        let report = run.report();
        assert!(report.contains("| 0.000 | 0.500 |"), "{report}");
    }

    #[test]
    fn from_jsonl_rejects_malformed_streams() {
        // no manifest first
        let e = RecordedRun::from_jsonl(
            "{\"kind\":\"round\",\"round\":0}\n",
        )
        .unwrap_err();
        assert!(e.contains("manifest"), "{e}");
        // wrong schema
        let e = RecordedRun::from_jsonl(
            "{\"kind\":\"manifest\",\"schema\":99}\n",
        )
        .unwrap_err();
        assert!(e.contains("schema"), "{e}");
        // out-of-order rounds
        let e = RecordedRun::from_jsonl(concat!(
            "{\"kind\":\"manifest\",\"schema\":1}\n",
            "{\"kind\":\"round\",\"round\":1}\n",
        ))
        .unwrap_err();
        assert!(e.contains("expected 0"), "{e}");
        // manifest but zero rounds
        let e = RecordedRun::from_jsonl(
            "{\"kind\":\"manifest\",\"schema\":1}\n",
        )
        .unwrap_err();
        assert!(e.contains("no round records"), "{e}");
    }

    #[test]
    fn diff_finds_first_divergent_round() {
        let a = RecordedRun::from_jsonl(GOLDEN_STREAM).unwrap();
        let b = RecordedRun::from_jsonl(GOLDEN_STREAM).unwrap();
        assert_eq!(diff_recorded(&a, &b), None);
        // perturb round 1's f
        let perturbed = GOLDEN_STREAM.replace("\"f\":0.5", "\"f\":0.625");
        let c = RecordedRun::from_jsonl(&perturbed).unwrap();
        let msg = diff_recorded(&a, &c).unwrap();
        assert!(msg.contains("first divergent round: 1"), "{msg}");
        assert!(msg.contains("f: 0.5 vs 0.625"), "{msg}");
        // a truncated stream diverges by length
        let shorter: String = GOLDEN_STREAM
            .lines()
            .take(2)
            .map(|l| format!("{l}\n"))
            .collect();
        let d = RecordedRun::from_jsonl(&shorter).unwrap();
        let msg = diff_recorded(&a, &d).unwrap();
        assert!(msg.contains("lengths differ"), "{msg}");
    }

    #[test]
    fn resilience_table_surfaces_fault_counters() {
        let traces = vec![trace("afs", &[0.1])];
        let mut ledger = Ledger {
            crash_events: 1,
            rejoin_rebases: 1,
            recovery_seconds: 0.125,
            retry_seconds: 0.25,
            lost_messages: 2,
            retry_rounds: 3,
            spec_hits: 4,
            spec_misses: 1,
            ..Ledger::default()
        };
        ledger.record_async_round(&[0, 0, 1], false);
        ledger.record_async_round(&[0], true);
        let r = Report::new(&traces, 1.0)
            .with_ledgers(vec![("afs".to_string(), ledger)]);
        let t = r.resilience_table();
        assert!(t.contains("| afs | 2 | 1 | s0:3 s1:1 | 1 | 1 | 0.125 | 0.250 | 2 | 3 | 0 | 0 | 4 | 1 |"), "{t}");
        let full = r.render("chaos run");
        assert!(full.contains("### resilience"), "{full}");
    }
}
