//! Evaluation metrics and run recording: AUPRC (the paper's
//! generalization criterion), relative objective gap, and the per-
//! iteration trace each driver emits.

pub mod auprc;
pub mod report;
pub mod trace;

pub use auprc::auprc;
pub use trace::{Trace, TracePoint};
