//! Convergence traces: one point per outer iteration, carrying exactly
//! the quantities Figure 1 plots — objective value against both
//! communication passes and simulated time, plus test AUPRC.

use crate::util::csv::Table;
use crate::util::json::Value;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TracePoint {
    pub iter: usize,
    pub f: f64,
    pub gnorm: f64,
    pub comm_passes: f64,
    pub seconds: f64,
    /// test-set AUPRC if evaluated this iteration (NaN = skipped)
    pub auprc: f64,
    /// Algorithm 1 step 6: how many nodes' d_p were replaced by −gʳ
    pub safeguard_hits: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// name of the method that produced this trace (plot label)
    pub label: String,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Trace {
        Trace { points: Vec::new(), label: label.into() }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn last(&self) -> Option<&TracePoint> {
        self.points.last()
    }

    /// Relative objective gap series (f − f*)/f* against a reference
    /// optimum (Figure 1's y-axis, log scale).
    pub fn rel_gap(&self, f_star: f64) -> Vec<f64> {
        self.points
            .iter()
            .map(|p| (p.f - f_star) / f_star.abs().max(f64::MIN_POSITIVE))
            .collect()
    }

    /// Figure-1-shaped table: iter, comm passes, seconds, f, relgap, auprc.
    pub fn to_table(&self, f_star: f64) -> Table {
        let mut t = Table::new(&[
            "iter", "comm_passes", "seconds", "f", "rel_gap", "auprc",
            "safeguard_hits",
        ]);
        for (p, gap) in self.points.iter().zip(self.rel_gap(f_star)) {
            t.push(vec![
                p.iter as f64,
                p.comm_passes,
                p.seconds,
                p.f,
                gap,
                p.auprc,
                p.safeguard_hits as f64,
            ]);
        }
        t
    }

    pub fn to_json(&self, f_star: f64) -> Value {
        Value::obj(vec![
            ("label", Value::Str(self.label.clone())),
            ("f_star", Value::Num(f_star)),
            (
                "points",
                Value::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Value::obj(vec![
                                ("iter", Value::Num(p.iter as f64)),
                                ("f", Value::Num(p.f)),
                                ("gnorm", Value::Num(p.gnorm)),
                                ("comm_passes", Value::Num(p.comm_passes)),
                                ("seconds", Value::Num(p.seconds)),
                                ("auprc", Value::Num(p.auprc)),
                                (
                                    "safeguard_hits",
                                    Value::Num(p.safeguard_hits as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new("fs-2");
        for i in 0..3 {
            t.push(TracePoint {
                iter: i,
                f: 10.0 / (i + 1) as f64,
                gnorm: 1.0,
                comm_passes: 4.0 * i as f64,
                seconds: 0.5 * i as f64,
                auprc: 0.8,
                safeguard_hits: 0,
            });
        }
        t
    }

    #[test]
    fn rel_gap_decreasing() {
        let t = sample();
        let g = t.rel_gap(1.0);
        assert_eq!(g.len(), 3);
        assert!(g[0] > g[1] && g[1] > g[2]);
        assert!((g[0] - 9.0).abs() < 1e-12);
    }

    #[test]
    fn table_has_figure1_columns() {
        let t = sample().to_table(1.0);
        assert_eq!(t.columns[1], "comm_passes");
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn json_roundtrips() {
        let j = sample().to_json(1.0);
        let parsed =
            crate::util::json::parse(&j.to_json(2)).expect("valid json");
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("fs-2"));
    }
}
