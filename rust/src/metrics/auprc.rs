//! Area under the precision–recall curve, computed exactly by the
//! standard score-sweep (ties handled as a block, AP-style
//! interpolation: area = Σ_k (R_k − R_{k−1})·P_k over distinct
//! thresholds).

/// `scores[i]` is the classifier margin for example i, `labels[i]` ±1.
/// Returns AUPRC in [0, 1]; 0/0-degenerate inputs (no positives) give 0.
pub fn auprc(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    if n_pos == 0 || scores.is_empty() {
        return 0.0;
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).expect("NaN score")
    });
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut area = 0.0;
    let mut prev_recall = 0.0;
    let mut k = 0;
    while k < idx.len() {
        // consume the whole tie block at this threshold
        let threshold = scores[idx[k]];
        while k < idx.len() && scores[idx[k]] == threshold {
            if labels[idx[k]] > 0.0 {
                tp += 1;
            } else {
                fp += 1;
            }
            k += 1;
        }
        let recall = tp as f64 / n_pos as f64;
        let precision = tp as f64 / (tp + fp) as f64;
        area += (recall - prev_recall) * precision;
        prev_recall = recall;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_ranking_gives_one() {
        let scores = vec![3.0, 2.0, 1.0, -1.0, -2.0];
        let labels = vec![1.0, 1.0, 1.0, -1.0, -1.0];
        assert!((auprc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_ranking_is_poor() {
        let scores = vec![-2.0, -1.0, 1.0, 2.0];
        let labels = vec![1.0, 1.0, -1.0, -1.0];
        let a = auprc(&scores, &labels);
        assert!(a < 0.5, "a={a}");
    }

    #[test]
    fn random_scores_approach_positive_rate() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let labels: Vec<f64> =
            (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { -1.0 }).collect();
        let a = auprc(&scores, &labels);
        let base = labels.iter().filter(|&&y| y > 0.0).count() as f64 / n as f64;
        assert!((a - base).abs() < 0.03, "a={a} base={base}");
    }

    #[test]
    fn ties_handled_as_block() {
        // all scores equal → single PR point (recall 1, precision = base)
        let scores = vec![0.5; 6];
        let labels = vec![1.0, -1.0, 1.0, -1.0, -1.0, -1.0];
        let a = auprc(&scores, &labels);
        assert!((a - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn no_positives_is_zero() {
        assert_eq!(auprc(&[1.0, 2.0], &[-1.0, -1.0]), 0.0);
        assert_eq!(auprc(&[], &[]), 0.0);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        // brute force: AP = mean over positives, of precision at that
        // positive's rank (equivalent for distinct scores)
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let n = 3 + rng.below(60);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let labels: Vec<f64> =
                (0..n).map(|_| rng.sign()).collect();
            if !labels.iter().any(|&y| y > 0.0) {
                continue;
            }
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let mut tp = 0.0;
            let mut ap = 0.0;
            let npos = labels.iter().filter(|&&y| y > 0.0).count() as f64;
            for (rank, &i) in idx.iter().enumerate() {
                if labels[i] > 0.0 {
                    tp += 1.0;
                    ap += tp / (rank as f64 + 1.0);
                }
            }
            ap /= npos;
            let a = auprc(&scores, &labels);
            assert!((a - ap).abs() < 1e-12, "a={a} ap={ap}");
        }
    }
}
