//! Sparse f64 vectors for the gradient hot path.
//!
//! At the paper's scale (kdd2010: d ≈ 20.21M, ~15 nnz/row) a node's
//! local loss-gradient ∇L_p is supported only on the columns its shard
//! actually touches — a few hundred thousand out of tens of millions.
//! Materializing it as a dense `Vec<f64>` of length d wastes O(P·d)
//! memory and reduction time per outer iteration. [`SparseVec`] is the
//! index/value wire format those gradients travel in, and
//! [`SupportMap`] is the per-shard column index that lets gradient
//! accumulation run over a compact support-length buffer.
//!
//! Wire accounting: one sparse component costs a u32 index + f64 value
//! (12 B) versus 8 B for a dense coordinate, so the sparse encoding
//! wins below density 2/3 — the cluster's cost model charges whichever
//! encoding is smaller.

use crate::linalg::csr::Csr;

/// Wire size of one sparse component: u32 index + f64 value.
pub const BYTES_PER_SPARSE_NNZ: usize = 12;
/// Wire size of one dense component (f64).
pub const BYTES_PER_DENSE_SCALAR: usize = 8;

/// A sparse vector in R^dim: strictly increasing `idx` with aligned
/// `val`. Exact zeros are dropped at construction (a sum is unchanged
/// by omitting them, and they cost wire bytes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    /// strictly increasing column indices
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl SparseVec {
    pub fn new(dim: usize) -> SparseVec {
        SparseVec { dim, idx: Vec::new(), val: Vec::new() }
    }

    /// Build from (col, val) pairs: sorts, merges duplicate columns,
    /// drops exact zeros.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, f64)>) -> SparseVec {
        pairs.sort_unstable_by_key(|&(c, _)| c);
        let mut out = SparseVec::new(dim);
        for (c, v) in pairs {
            assert!((c as usize) < dim, "col {c} out of bounds");
            match out.idx.last() {
                Some(&last) if last == c => {
                    *out.val.last_mut().unwrap() += v;
                }
                _ => {
                    out.idx.push(c);
                    out.val.push(v);
                }
            }
        }
        out.drop_zeros();
        out
    }

    /// Keep the nonzero coordinates of a dense vector.
    pub fn from_dense(w: &[f64]) -> SparseVec {
        SparseVec::from_dense_scaled(w, 1.0)
    }

    /// Sparsify α·w (exact zeros of w dropped).
    pub fn from_dense_scaled(w: &[f64], alpha: f64) -> SparseVec {
        let mut out = SparseVec::new(w.len());
        for (j, &x) in w.iter().enumerate() {
            if x != 0.0 {
                out.idx.push(j as u32);
                out.val.push(alpha * x);
            }
        }
        out
    }

    /// Build from a sorted support + aligned values, dropping zeros.
    /// `idx` must be strictly increasing (a [`SupportMap`] support is).
    pub fn from_support(dim: usize, idx: &[u32], val: &[f64]) -> SparseVec {
        debug_assert_eq!(idx.len(), val.len());
        let mut out = SparseVec::new(dim);
        for (&c, &v) in idx.iter().zip(val) {
            if v != 0.0 {
                out.idx.push(c);
                out.val.push(v);
            }
        }
        out
    }

    fn drop_zeros(&mut self) {
        if self.val.iter().any(|&v| v == 0.0) {
            let mut idx = Vec::with_capacity(self.idx.len());
            let mut val = Vec::with_capacity(self.val.len());
            for (&c, &v) in self.idx.iter().zip(&self.val) {
                if v != 0.0 {
                    idx.push(c);
                    val.push(v);
                }
            }
            self.idx = idx;
            self.val = val;
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn density(&self) -> f64 {
        if self.dim == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.dim as f64
        }
    }

    /// Bytes this vector occupies in the sparse wire encoding. Stored
    /// exact zeros (support-aligned carriers keep them so `val` stays
    /// aligned with the shard support) are stripped before sending — a
    /// real system wouldn't ship them, so they cost no wire bytes.
    pub fn wire_bytes(&self) -> usize {
        self.val.iter().filter(|v| **v != 0.0).count()
            * BYTES_PER_SPARSE_NNZ
    }

    /// self·w against a dense vector.
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        debug_assert!(w.len() >= self.dim);
        self.idx
            .iter()
            .zip(&self.val)
            .map(|(&c, &v)| v * w[c as usize])
            .sum()
    }

    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|v| v * v).sum()
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.val {
            *v *= alpha;
        }
    }

    /// out ← out + α·self (dense scatter).
    pub fn axpy_into(&self, alpha: f64, out: &mut [f64]) {
        debug_assert!(out.len() >= self.dim);
        for (&c, &v) in self.idx.iter().zip(&self.val) {
            out[c as usize] += alpha * v;
        }
    }

    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.axpy_into(1.0, &mut out);
        out
    }

    /// Union-sum of two sparse vectors (two-pointer merge). The
    /// coordinate-wise addition order matches what a dense add of the
    /// same two operands produces, so sparse and dense reductions agree
    /// beyond mere tolerance.
    pub fn merge(&self, other: &SparseVec) -> SparseVec {
        debug_assert_eq!(self.dim, other.dim, "merging mismatched dims");
        let mut out = SparseVec::new(self.dim);
        out.idx.reserve(self.nnz() + other.nnz());
        out.val.reserve(self.nnz() + other.nnz());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.nnz() && j < other.nnz() {
            let (ci, cj) = (self.idx[i], other.idx[j]);
            if ci < cj {
                out.idx.push(ci);
                out.val.push(self.val[i]);
                i += 1;
            } else if cj < ci {
                out.idx.push(cj);
                out.val.push(other.val[j]);
                j += 1;
            } else {
                out.idx.push(ci);
                out.val.push(self.val[i] + other.val[j]);
                i += 1;
                j += 1;
            }
        }
        while i < self.nnz() {
            out.idx.push(self.idx[i]);
            out.val.push(self.val[i]);
            i += 1;
        }
        while j < other.nnz() {
            out.idx.push(other.idx[j]);
            out.val.push(other.val[j]);
            j += 1;
        }
        out
    }
}

/// Per-shard column-support dictionary: the sorted unique global
/// columns a CSR shard touches. Built once at partition time, it is the
/// local↔global translation every compact-coordinate phase uses — the
/// shard's CSR itself stores *local* ids `0..support.len()` (see
/// [`SupportMap::compact`]), so gradient passes, inner solves and
/// Hessian products all run over |support|-length buffers instead of
/// size-d dense vectors (the O(P·d) → O(Σ|support_p|) win the sparse
/// pipeline is about).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SupportMap {
    /// sorted unique global columns present in the shard
    pub support: Vec<u32>,
}

impl SupportMap {
    pub fn build(x: &Csr) -> SupportMap {
        let mut support = x.indices.clone();
        support.sort_unstable();
        support.dedup();
        SupportMap { support }
    }

    /// Union of several supports: U = ⋃_p support_p, the *global*
    /// column dictionary the union-support compact master runs on
    /// (every iterate, gradient and direction of the outer loop
    /// provably lives in U — features with no data column never move).
    pub fn union_of<'a>(
        maps: impl IntoIterator<Item = &'a SupportMap>,
    ) -> SupportMap {
        let mut all: Vec<u32> = Vec::new();
        for m in maps {
            all.extend_from_slice(&m.support);
        }
        all.sort_unstable();
        all.dedup();
        SupportMap { support: all }
    }

    /// Compose a sub-support into this one: the position (in
    /// `self.support`) of every column of `inner` — the local↔union
    /// translation each shard carries under the compact master.
    /// Positions are strictly increasing (both supports are sorted).
    /// Panics if `inner` is not a subset.
    pub fn positions_of(&self, inner: &SupportMap) -> Vec<u32> {
        let mut out = Vec::with_capacity(inner.support.len());
        let mut i = 0usize;
        for &c in &inner.support {
            while i < self.support.len() && self.support[i] < c {
                i += 1;
            }
            assert!(
                i < self.support.len() && self.support[i] == c,
                "column {c} missing from the union support"
            );
            out.push(i as u32);
            i += 1;
        }
        out
    }

    /// Remap a foreign global-column CSR onto this support's positions,
    /// dropping columns outside it. Under the compact master those
    /// columns carry weight exactly 0 (they have no training data), so
    /// dropping their terms changes no margin — this is how the
    /// test-set AUPRC probe scores a compact iterate without ever
    /// materializing the full-d vector.
    pub fn remap_csr(&self, x: &Csr) -> Csr {
        let mut out = Csr {
            n_cols: self.support.len(),
            offsets: Vec::with_capacity(x.offsets.len()),
            indices: Vec::new(),
            values: Vec::new(),
        };
        out.offsets.push(0);
        for i in 0..x.n_rows() {
            let (cols, vals) = x.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if let Ok(pos) = self.support.binary_search(&c) {
                    out.indices.push(pos as u32);
                    out.values.push(v);
                }
            }
            out.offsets.push(out.indices.len());
        }
        out
    }

    /// Materialize a support-aligned compact vector into full-d space —
    /// the single O(d) pass the compact master pays, at `RunResult`
    /// construction.
    pub fn expand(&self, vals: &[f64], dim: usize) -> Vec<f64> {
        let mut out = vec![0.0; dim];
        self.scatter_add(vals, 1.0, &mut out);
        out
    }

    /// Remap a global-column CSR to compact local ids: returns the
    /// support dictionary plus a CSR whose `n_cols == support.len()`
    /// and whose indices are positions within the support. Row order
    /// and within-row entry order are preserved (support is sorted, so
    /// sorted global indices stay sorted locally) — compact sweeps
    /// accumulate in exactly the order the global-space sweeps did.
    pub fn compact(x: &Csr) -> (SupportMap, Csr) {
        let map = SupportMap::build(x);
        let indices = x
            .indices
            .iter()
            .map(|c| {
                map.support.binary_search(c).expect("col in support") as u32
            })
            .collect();
        let local = Csr {
            n_cols: map.support.len(),
            offsets: x.offsets.clone(),
            indices,
            values: x.values.clone(),
        };
        (map, local)
    }

    /// Number of support columns (the compact dimension m).
    #[inline]
    pub fn len(&self) -> usize {
        self.support.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.support.is_empty()
    }

    /// Gather a global dense vector onto the support:
    /// out[l] = global[support[l]]. Reuses `out`'s allocation.
    pub fn gather(&self, global: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.support.iter().map(|&c| global[c as usize]));
    }

    /// out ← out + α·vals scattered to global coordinates.
    pub fn scatter_add(&self, vals: &[f64], alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(vals.len(), self.support.len());
        for (&c, &v) in self.support.iter().zip(vals) {
            out[c as usize] += alpha * v;
        }
    }

    /// Support-aligned values as a global [`SparseVec`] carrying every
    /// support coordinate (zeros included, so `val` stays aligned with
    /// the shard support on the receiving side).
    pub fn to_sparse_aligned(&self, dim: usize, vals: &[f64]) -> SparseVec {
        debug_assert_eq!(vals.len(), self.support.len());
        SparseVec { dim, idx: self.support.clone(), val: vals.to_vec() }
    }

    /// Fraction of the `dim` columns this shard touches.
    pub fn density(&self, dim: usize) -> f64 {
        if dim == 0 {
            0.0
        } else {
            self.support.len() as f64 / dim as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;

    #[test]
    fn from_pairs_sorts_merges_and_drops_zeros() {
        let s = SparseVec::from_pairs(
            10,
            vec![(7, 1.0), (2, 3.0), (7, -1.0), (4, 0.0), (1, 2.0)],
        );
        assert_eq!(s.idx, vec![1, 2]);
        assert_eq!(s.val, vec![2.0, 3.0]);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.wire_bytes(), 24);
    }

    #[test]
    fn dense_roundtrip() {
        let w = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseVec::from_dense(&w);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(), w);
        let scaled = SparseVec::from_dense_scaled(&w, 2.0);
        assert_eq!(scaled.to_dense(), vec![0.0, 3.0, 0.0, -4.0, 0.0]);
    }

    #[test]
    fn dot_and_norm_match_dense() {
        let w = vec![0.5, 0.0, -1.0, 2.0];
        let s = SparseVec::from_dense(&w);
        let v = vec![1.0, 7.0, 2.0, 0.5];
        assert!((s.dot_dense(&v) - dense::dot(&w, &v)).abs() < 1e-15);
        assert!((s.norm_sq() - dense::norm_sq(&w)).abs() < 1e-15);
    }

    #[test]
    fn merge_is_union_sum() {
        let a = SparseVec::from_pairs(8, vec![(0, 1.0), (3, 2.0), (7, 1.0)]);
        let b = SparseVec::from_pairs(8, vec![(3, 0.5), (5, -1.0)]);
        let m = a.merge(&b);
        assert_eq!(m.idx, vec![0, 3, 5, 7]);
        assert_eq!(m.val, vec![1.0, 2.5, -1.0, 1.0]);
        // commutes
        assert_eq!(b.merge(&a).to_dense(), m.to_dense());
        // identity
        let empty = SparseVec::new(8);
        assert_eq!(a.merge(&empty), a);
    }

    #[test]
    fn axpy_scatters() {
        let s = SparseVec::from_pairs(4, vec![(1, 2.0), (3, -1.0)]);
        let mut out = vec![1.0; 4];
        s.axpy_into(0.5, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn support_map_compacts_and_scatters() {
        let x = Csr::from_rows(
            6,
            &[
                vec![(5, 1.0), (0, 2.0)],
                vec![(3, 1.0)],
                vec![(0, 4.0), (3, -1.0)],
            ],
        );
        let (map, xl) = SupportMap::compact(&x);
        assert_eq!(map.support, vec![0, 3, 5]);
        assert_eq!(map.len(), 3);
        assert_eq!(xl.n_cols, 3);
        assert_eq!(xl.nnz(), x.nnz());
        // rows keep their order, columns become support positions
        assert_eq!(xl.row(0).0, &[0, 2]);
        assert_eq!(xl.row(2).0, &[0, 1]);
        // accumulate row 2 into a support-length buffer via the local csr
        let mut vals = vec![0.0; 3];
        xl.add_row_scaled(2, 2.0, &mut vals);
        assert_eq!(vals, vec![8.0, -2.0, 0.0]);
        assert!((map.density(6) - 0.5).abs() < 1e-15);

        // gather/scatter round-trip against a global vector
        let w = vec![0.5, 0.0, 0.0, -1.0, 0.0, 2.0];
        let mut wc = Vec::new();
        map.gather(&w, &mut wc);
        assert_eq!(wc, vec![0.5, -1.0, 2.0]);
        let mut back = vec![0.0; 6];
        map.scatter_add(&wc, 2.0, &mut back);
        assert_eq!(back, vec![1.0, 0.0, 0.0, -2.0, 0.0, 4.0]);
        let sv = map.to_sparse_aligned(6, &[0.0, 7.0, 1.0]);
        assert_eq!(sv.idx, map.support);
        assert_eq!(sv.to_dense(), vec![0.0, 0.0, 0.0, 7.0, 0.0, 1.0]);
    }

    #[test]
    fn union_and_positions_compose() {
        let a = SupportMap { support: vec![1, 4, 9] };
        let b = SupportMap { support: vec![0, 4, 7] };
        let u = SupportMap::union_of([&a, &b]);
        assert_eq!(u.support, vec![0, 1, 4, 7, 9]);
        assert_eq!(u.positions_of(&a), vec![1, 2, 4]);
        assert_eq!(u.positions_of(&b), vec![0, 2, 3]);
        // gather through the composed positions == gather through the
        // shard support from the expanded vector
        let w_u = vec![10.0, 11.0, 12.0, 13.0, 14.0];
        let w_full = u.expand(&w_u, 12);
        let mut via_map = Vec::new();
        a.gather(&w_full, &mut via_map);
        let via_pos: Vec<f64> = u
            .positions_of(&a)
            .iter()
            .map(|&p| w_u[p as usize])
            .collect();
        assert_eq!(via_map, via_pos);
        // expand scatters to the right global coordinates
        assert_eq!(w_full[4], 12.0);
        assert_eq!(w_full[5], 0.0);
    }

    #[test]
    #[should_panic(expected = "missing from the union support")]
    fn positions_of_rejects_non_subset() {
        let u = SupportMap { support: vec![1, 4] };
        let inner = SupportMap { support: vec![2] };
        u.positions_of(&inner);
    }

    #[test]
    fn remap_csr_drops_out_of_support_columns() {
        let x = Csr::from_rows(
            10,
            &[
                vec![(1, 1.0), (5, 2.0), (8, 3.0)],
                vec![(0, 4.0)],
                vec![],
            ],
        );
        let u = SupportMap { support: vec![1, 8] };
        let r = u.remap_csr(&x);
        assert_eq!(r.n_cols, 2);
        assert_eq!(r.n_rows(), 3);
        assert_eq!(r.row(0), (&[0u32, 1][..], &[1.0f32, 3.0][..]));
        assert!(r.row(1).0.is_empty());
        // margins agree with the full matrix against an expanded w
        let w_u = vec![0.5, -2.0];
        let w_full = u.expand(&w_u, 10);
        let mut z_c = vec![0.0; 3];
        let mut z_f = vec![0.0; 3];
        r.matvec(&w_u, &mut z_c);
        x.matvec(&w_full, &mut z_f);
        assert_eq!(z_c, z_f);
    }

    #[test]
    fn from_support_drops_zero_values() {
        let s = SparseVec::from_support(9, &[1, 4, 8], &[0.0, 2.0, 0.0]);
        assert_eq!(s.idx, vec![4]);
        assert_eq!(s.val, vec![2.0]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_pairs_bounds_checked() {
        SparseVec::from_pairs(3, vec![(3, 1.0)]);
    }
}
