//! Sparse f64 vectors for the gradient hot path.
//!
//! At the paper's scale (kdd2010: d ≈ 20.21M, ~15 nnz/row) a node's
//! local loss-gradient ∇L_p is supported only on the columns its shard
//! actually touches — a few hundred thousand out of tens of millions.
//! Materializing it as a dense `Vec<f64>` of length d wastes O(P·d)
//! memory and reduction time per outer iteration. [`SparseVec`] is the
//! index/value wire format those gradients travel in, and
//! [`SupportMap`] is the per-shard column index that lets gradient
//! accumulation run over a compact support-length buffer.
//!
//! Wire accounting: one sparse component costs a u32 index + f64 value
//! (12 B) versus 8 B for a dense coordinate, so the sparse encoding
//! wins below density 2/3 — the cluster's cost model charges whichever
//! encoding is smaller.

use crate::linalg::csr::Csr;

/// Wire size of one sparse component: u32 index + f64 value.
pub const BYTES_PER_SPARSE_NNZ: usize = 12;
/// Wire size of one dense component (f64).
pub const BYTES_PER_DENSE_SCALAR: usize = 8;

/// A sparse vector in R^dim: strictly increasing `idx` with aligned
/// `val`. Exact zeros are dropped at construction (a sum is unchanged
/// by omitting them, and they cost wire bytes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    /// strictly increasing column indices
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl SparseVec {
    pub fn new(dim: usize) -> SparseVec {
        SparseVec { dim, idx: Vec::new(), val: Vec::new() }
    }

    /// Build from (col, val) pairs: sorts, merges duplicate columns,
    /// drops exact zeros.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, f64)>) -> SparseVec {
        pairs.sort_unstable_by_key(|&(c, _)| c);
        let mut out = SparseVec::new(dim);
        for (c, v) in pairs {
            assert!((c as usize) < dim, "col {c} out of bounds");
            match out.idx.last() {
                Some(&last) if last == c => {
                    *out.val.last_mut().unwrap() += v;
                }
                _ => {
                    out.idx.push(c);
                    out.val.push(v);
                }
            }
        }
        out.drop_zeros();
        out
    }

    /// Keep the nonzero coordinates of a dense vector.
    pub fn from_dense(w: &[f64]) -> SparseVec {
        SparseVec::from_dense_scaled(w, 1.0)
    }

    /// Sparsify α·w (exact zeros of w dropped).
    pub fn from_dense_scaled(w: &[f64], alpha: f64) -> SparseVec {
        let mut out = SparseVec::new(w.len());
        for (j, &x) in w.iter().enumerate() {
            if x != 0.0 {
                out.idx.push(j as u32);
                out.val.push(alpha * x);
            }
        }
        out
    }

    /// Build from a sorted support + aligned values, dropping zeros.
    /// `idx` must be strictly increasing (a [`SupportMap`] support is).
    pub fn from_support(dim: usize, idx: &[u32], val: &[f64]) -> SparseVec {
        debug_assert_eq!(idx.len(), val.len());
        let mut out = SparseVec::new(dim);
        for (&c, &v) in idx.iter().zip(val) {
            if v != 0.0 {
                out.idx.push(c);
                out.val.push(v);
            }
        }
        out
    }

    fn drop_zeros(&mut self) {
        if self.val.iter().any(|&v| v == 0.0) {
            let mut idx = Vec::with_capacity(self.idx.len());
            let mut val = Vec::with_capacity(self.val.len());
            for (&c, &v) in self.idx.iter().zip(&self.val) {
                if v != 0.0 {
                    idx.push(c);
                    val.push(v);
                }
            }
            self.idx = idx;
            self.val = val;
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn density(&self) -> f64 {
        if self.dim == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.dim as f64
        }
    }

    /// Bytes this vector occupies in the sparse wire encoding.
    pub fn wire_bytes(&self) -> usize {
        self.nnz() * BYTES_PER_SPARSE_NNZ
    }

    /// self·w against a dense vector.
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        debug_assert!(w.len() >= self.dim);
        self.idx
            .iter()
            .zip(&self.val)
            .map(|(&c, &v)| v * w[c as usize])
            .sum()
    }

    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|v| v * v).sum()
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.val {
            *v *= alpha;
        }
    }

    /// out ← out + α·self (dense scatter).
    pub fn axpy_into(&self, alpha: f64, out: &mut [f64]) {
        debug_assert!(out.len() >= self.dim);
        for (&c, &v) in self.idx.iter().zip(&self.val) {
            out[c as usize] += alpha * v;
        }
    }

    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.axpy_into(1.0, &mut out);
        out
    }

    /// Union-sum of two sparse vectors (two-pointer merge). The
    /// coordinate-wise addition order matches what a dense add of the
    /// same two operands produces, so sparse and dense reductions agree
    /// beyond mere tolerance.
    pub fn merge(&self, other: &SparseVec) -> SparseVec {
        debug_assert_eq!(self.dim, other.dim, "merging mismatched dims");
        let mut out = SparseVec::new(self.dim);
        out.idx.reserve(self.nnz() + other.nnz());
        out.val.reserve(self.nnz() + other.nnz());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.nnz() && j < other.nnz() {
            let (ci, cj) = (self.idx[i], other.idx[j]);
            if ci < cj {
                out.idx.push(ci);
                out.val.push(self.val[i]);
                i += 1;
            } else if cj < ci {
                out.idx.push(cj);
                out.val.push(other.val[j]);
                j += 1;
            } else {
                out.idx.push(ci);
                out.val.push(self.val[i] + other.val[j]);
                i += 1;
                j += 1;
            }
        }
        while i < self.nnz() {
            out.idx.push(self.idx[i]);
            out.val.push(self.val[i]);
            i += 1;
        }
        while j < other.nnz() {
            out.idx.push(other.idx[j]);
            out.val.push(other.val[j]);
            j += 1;
        }
        out
    }
}

/// Per-shard column-support index: the sorted unique columns a CSR
/// shard touches plus, for every stored nnz, its position within that
/// support. Built once at partition time; lets every gradient pass
/// accumulate into a |support|-length buffer instead of a size-d dense
/// vector (the O(P·d) → O(Σ|support_p|) win the sparse pipeline is
/// about).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SupportMap {
    /// sorted unique columns present in the shard
    pub support: Vec<u32>,
    /// position of csr.indices[k] within `support`, for every k
    pub local: Vec<u32>,
}

impl SupportMap {
    pub fn build(x: &Csr) -> SupportMap {
        let mut support = x.indices.clone();
        support.sort_unstable();
        support.dedup();
        let local = x
            .indices
            .iter()
            .map(|c| support.binary_search(c).expect("col in support") as u32)
            .collect();
        SupportMap { support, local }
    }

    /// g_vals ← g_vals + α·xᵢ, with g_vals indexed by support position.
    #[inline]
    pub fn add_row_scaled(
        &self,
        x: &Csr,
        i: usize,
        alpha: f64,
        g_vals: &mut [f64],
    ) {
        debug_assert_eq!(g_vals.len(), self.support.len());
        let (lo, hi) = (x.offsets[i], x.offsets[i + 1]);
        for k in lo..hi {
            g_vals[self.local[k] as usize] += alpha * x.values[k] as f64;
        }
    }

    /// Fraction of the `dim` columns this shard touches.
    pub fn density(&self, dim: usize) -> f64 {
        if dim == 0 {
            0.0
        } else {
            self.support.len() as f64 / dim as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;

    #[test]
    fn from_pairs_sorts_merges_and_drops_zeros() {
        let s = SparseVec::from_pairs(
            10,
            vec![(7, 1.0), (2, 3.0), (7, -1.0), (4, 0.0), (1, 2.0)],
        );
        assert_eq!(s.idx, vec![1, 2]);
        assert_eq!(s.val, vec![2.0, 3.0]);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.wire_bytes(), 24);
    }

    #[test]
    fn dense_roundtrip() {
        let w = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseVec::from_dense(&w);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(), w);
        let scaled = SparseVec::from_dense_scaled(&w, 2.0);
        assert_eq!(scaled.to_dense(), vec![0.0, 3.0, 0.0, -4.0, 0.0]);
    }

    #[test]
    fn dot_and_norm_match_dense() {
        let w = vec![0.5, 0.0, -1.0, 2.0];
        let s = SparseVec::from_dense(&w);
        let v = vec![1.0, 7.0, 2.0, 0.5];
        assert!((s.dot_dense(&v) - dense::dot(&w, &v)).abs() < 1e-15);
        assert!((s.norm_sq() - dense::norm_sq(&w)).abs() < 1e-15);
    }

    #[test]
    fn merge_is_union_sum() {
        let a = SparseVec::from_pairs(8, vec![(0, 1.0), (3, 2.0), (7, 1.0)]);
        let b = SparseVec::from_pairs(8, vec![(3, 0.5), (5, -1.0)]);
        let m = a.merge(&b);
        assert_eq!(m.idx, vec![0, 3, 5, 7]);
        assert_eq!(m.val, vec![1.0, 2.5, -1.0, 1.0]);
        // commutes
        assert_eq!(b.merge(&a).to_dense(), m.to_dense());
        // identity
        let empty = SparseVec::new(8);
        assert_eq!(a.merge(&empty), a);
    }

    #[test]
    fn axpy_scatters() {
        let s = SparseVec::from_pairs(4, vec![(1, 2.0), (3, -1.0)]);
        let mut out = vec![1.0; 4];
        s.axpy_into(0.5, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn support_map_indexes_every_nnz() {
        let x = Csr::from_rows(
            6,
            &[
                vec![(5, 1.0), (0, 2.0)],
                vec![(3, 1.0)],
                vec![(0, 4.0), (3, -1.0)],
            ],
        );
        let map = SupportMap::build(&x);
        assert_eq!(map.support, vec![0, 3, 5]);
        assert_eq!(map.local.len(), x.nnz());
        // accumulate row 2 into a support-length buffer
        let mut vals = vec![0.0; 3];
        map.add_row_scaled(&x, 2, 2.0, &mut vals);
        assert_eq!(vals, vec![8.0, -2.0, 0.0]);
        assert!((map.density(6) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn from_support_drops_zero_values() {
        let s = SparseVec::from_support(9, &[1, 4, 8], &[0.0, 2.0, 0.0]);
        assert_eq!(s.idx, vec![4]);
        assert_eq!(s.val, vec![2.0]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_pairs_bounds_checked() {
        SparseVec::from_pairs(3, vec![(3, 1.0)]);
    }
}
