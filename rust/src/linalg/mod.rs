//! Linear-algebra substrate: dense f64 vector kernels and the CSR
//! sparse matrix every shard is stored as. Weights are f64 (the
//! optimizer's working precision); feature values are f32 (what
//! kdd2010-class data actually needs), promoted at multiply time.

pub mod csr;
pub mod dense;

pub use csr::Csr;
