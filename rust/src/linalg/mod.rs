//! Linear-algebra substrate: dense f64 vector kernels, the CSR sparse
//! matrix every shard is stored as, and the sparse index/value vectors
//! the gradient pipeline ships over the simulated wire. Weights are f64
//! (the optimizer's working precision); feature values are f32 (what
//! kdd2010-class data actually needs), promoted at multiply time.

pub mod csr;
pub mod dense;
pub mod sparse;

pub use csr::Csr;
pub use sparse::{SparseVec, SupportMap};
