//! Compressed-sparse-row matrix — the storage format for every data
//! shard. kdd2010-class data is ~15 nnz/row over 20M columns, so all
//! per-example work is nnz-proportional:
//!
//! - [`Csr::row_dot`] — zᵢ = xᵢ·w (margins)
//! - [`Csr::add_row_scaled`] — g += α·xᵢ (gradient scatter)
//! - [`Csr::matvec`] / [`Csr::tmatvec`] — full-shard X·w and Xᵀ·r
//!
//! Column indices are u32 (kdd2010's 20.21M features fit comfortably),
//! values f32, offsets usize.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub n_cols: usize,
    /// row i occupies indices[offsets[i]..offsets[i+1]]
    pub offsets: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn new(n_cols: usize) -> Csr {
        Csr { n_cols, offsets: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Build from row triplets; each row is a (sorted-or-not) list of
    /// (col, val). Duplicates within a row are summed.
    ///
    /// §Perf: one reusable scratch row instead of cloning every input
    /// row — shard construction is on the partition hot path.
    pub fn from_rows(n_cols: usize, rows: &[Vec<(u32, f32)>]) -> Csr {
        let mut m = Csr::new(n_cols);
        m.offsets.reserve(rows.len());
        m.indices.reserve(rows.iter().map(Vec::len).sum());
        m.values.reserve(rows.iter().map(Vec::len).sum());
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for row in rows {
            scratch.clear();
            scratch.extend_from_slice(row);
            m.append_row_scratch(&mut scratch);
        }
        m
    }

    /// Append one row, sorting and merging duplicate columns.
    pub fn push_row(&mut self, mut entries: Vec<(u32, f32)>) {
        self.append_row_scratch(&mut entries);
    }

    /// Sort `entries`, merge duplicate columns directly into the CSR
    /// arrays (no per-row temporaries), close the row.
    fn append_row_scratch(&mut self, entries: &mut Vec<(u32, f32)>) {
        entries.sort_unstable_by_key(|&(c, _)| c);
        let row_start = self.indices.len();
        for &(c, v) in entries.iter() {
            assert!((c as usize) < self.n_cols, "col {c} out of bounds");
            match self.indices.last() {
                Some(&lc) if lc == c && self.indices.len() > row_start => {
                    *self.values.last_mut().unwrap() += v;
                }
                _ => {
                    self.indices.push(c);
                    self.values.push(v);
                }
            }
        }
        self.offsets.push(self.indices.len());
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// (column indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.offsets[i], self.offsets[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// zᵢ = xᵢ·w
    ///
    /// §Perf: column indices are validated once at construction
    /// (`push_row` asserts c < n_cols), so the release hot loop uses
    /// unchecked indexing — bounds checks cost ~15% on the
    /// scatter/gather paths. Debug builds (and therefore Miri and the
    /// audit CI jobs) take the checked-index path instead, so the
    /// construction-time invariant is re-verified on every access
    /// wherever we can afford it.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        debug_assert!(w.len() >= self.n_cols);
        let (cols, vals) = self.row(i);
        let mut s = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            #[cfg(debug_assertions)]
            {
                s += *v as f64 * w[*c as usize];
            }
            #[cfg(not(debug_assertions))]
            {
                // SAFETY: c < n_cols ≤ w.len(), enforced by push_row
                s += *v as f64 * unsafe { *w.get_unchecked(*c as usize) };
            }
        }
        s
    }

    /// g ← g + α·xᵢ (the nnz-sparse gradient scatter; checked indexing
    /// on debug/Miri builds, see [`Csr::row_dot`])
    #[inline]
    pub fn add_row_scaled(&self, i: usize, alpha: f64, g: &mut [f64]) {
        debug_assert!(g.len() >= self.n_cols);
        let (cols, vals) = self.row(i);
        for (c, v) in cols.iter().zip(vals) {
            #[cfg(debug_assertions)]
            {
                g[*c as usize] += alpha * *v as f64;
            }
            #[cfg(not(debug_assertions))]
            {
                // SAFETY: c < n_cols ≤ g.len(), enforced by push_row
                unsafe {
                    *g.get_unchecked_mut(*c as usize) += alpha * *v as f64;
                }
            }
        }
    }

    /// z = X·w over the whole shard (reuses `z`; z.len() == n_rows).
    pub fn matvec(&self, w: &[f64], z: &mut [f64]) {
        debug_assert_eq!(z.len(), self.n_rows());
        for i in 0..self.n_rows() {
            z[i] = self.row_dot(i, w);
        }
    }

    /// g = Xᵀ·r accumulated into `g` (g.len() == n_cols).
    pub fn tmatvec(&self, r: &[f64], g: &mut [f64]) {
        debug_assert_eq!(r.len(), self.n_rows());
        for i in 0..self.n_rows() {
            let ri = r[i];
            if ri != 0.0 {
                self.add_row_scaled(i, ri, g);
            }
        }
    }

    /// ‖xᵢ‖² per row — used for Lipschitz/learning-rate estimates.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.n_rows())
            .map(|i| {
                let (_, vals) = self.row(i);
                vals.iter().map(|v| (*v as f64).powi(2)).sum()
            })
            .collect()
    }

    /// Extract the sub-matrix of the given rows (shard construction).
    pub fn take_rows(&self, rows: &[usize]) -> Csr {
        let mut out = Csr::new(self.n_cols);
        out.indices.reserve(rows.iter().map(|&i| self.offsets[i + 1] - self.offsets[i]).sum());
        for &i in rows {
            let (lo, hi) = (self.offsets[i], self.offsets[i + 1]);
            out.indices.extend_from_slice(&self.indices[lo..hi]);
            out.values.extend_from_slice(&self.values[lo..hi]);
            out.offsets.push(out.indices.len());
        }
        out
    }

    /// Dense copy (tests and the PJRT dense path).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows()];
        for i in 0..self.n_rows() {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                out[i][*c as usize] += *v as f64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2], [0, 3, 0], [0, 0, 0], [4, 5, 6]]
        Csr::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![],
                vec![(2, 6.0), (0, 4.0), (1, 5.0)], // unsorted on purpose
            ],
        )
    }

    #[test]
    fn shape_and_nnz() {
        let m = sample();
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_cols, 3);
        assert_eq!(m.nnz(), 6);
    }

    #[test]
    fn rows_sorted_and_duplicates_merged() {
        let mut m = Csr::new(4);
        m.push_row(vec![(2, 1.0), (0, 1.0), (2, 3.0)]);
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[1.0, 4.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let w = vec![0.5, -1.0, 2.0];
        let mut z = vec![0.0; 4];
        m.matvec(&w, &mut z);
        let dense = m.to_dense();
        for i in 0..4 {
            let want: f64 = dense[i].iter().zip(&w).map(|(a, b)| a * b).sum();
            assert!((z[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn tmatvec_matches_dense() {
        let m = sample();
        let r = vec![1.0, -2.0, 7.0, 0.5];
        let mut g = vec![0.0; 3];
        m.tmatvec(&r, &mut g);
        let dense = m.to_dense();
        for c in 0..3 {
            let want: f64 = (0..4).map(|i| dense[i][c] * r[i]).sum();
            assert!((g[c] - want).abs() < 1e-12, "col {c}");
        }
    }

    #[test]
    fn take_rows_subsets() {
        let m = sample();
        let s = m.take_rows(&[3, 1]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.row(0).0, &[0, 1, 2]);
        assert_eq!(s.row(1), ( &[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn row_norms() {
        let m = sample();
        let n = m.row_norms_sq();
        assert_eq!(n, vec![5.0, 9.0, 0.0, 77.0]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn col_bounds_enforced() {
        let mut m = Csr::new(2);
        m.push_row(vec![(2, 1.0)]);
    }
}
