//! Dense f64 vector kernels — the coordinator's hot loop primitives.
//! Kept free-standing (not methods on a Vector newtype) so the
//! optimizers read like the math in the paper.

/// a·b
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane manual unroll: keeps the compiler on the vectorized path
    // even at opt-level where autovectorization of the naive loop is
    // blocked by float reassociation rules.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// ‖a‖²
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// ‖a‖₂
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    norm_sq(a).sqrt()
}

/// y ← y + αx
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y ← x + αy  (useful for CG's direction update)
#[inline]
pub fn xpay(x: &[f64], alpha: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + alpha * *yi;
    }
}

/// a ← αa
#[inline]
pub fn scale(a: &mut [f64], alpha: f64) {
    for ai in a {
        *ai *= alpha;
    }
}

/// out ← a + αb (allocating)
pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(ai, bi)| ai + alpha * bi).collect()
}

/// Elementwise a − b (allocating)
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(ai, bi)| ai - bi).collect()
}

/// Angle between a and b in radians, in [0, π]. Returns `None` when
/// either vector is (numerically) zero — callers decide the policy
/// (Algorithm 1 step 6 treats that as "replace by −g").
pub fn angle(a: &[f64], b: &[f64]) -> Option<f64> {
    let na = norm(a);
    let nb = norm(b);
    if na <= f64::EPSILON || nb <= f64::EPSILON {
        return None;
    }
    let c = (dot(a, b) / (na * nb)).clamp(-1.0, 1.0);
    Some(c.acos())
}

/// max_i |a_i − b_i|
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..103).map(|i| i as f64 * 0.1).collect();
        let b: Vec<f64> = (0..103).map(|i| (103 - i) as f64 * 0.01).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy_and_friends() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        xpay(&x, 0.5, &mut y);
        assert_eq!(y, vec![7.0, 14.0, 21.0]);
        scale(&mut y, 2.0);
        assert_eq!(y, vec![14.0, 28.0, 42.0]);
        assert_eq!(sub(&y, &y), vec![0.0; 3]);
    }

    #[test]
    fn angle_basics() {
        let e1 = [1.0, 0.0];
        let e2 = [0.0, 1.0];
        let neg = [-1.0, 0.0];
        assert!((angle(&e1, &e2).unwrap() - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
        assert!(angle(&e1, &e1).unwrap() < 1e-8);
        assert!((angle(&e1, &neg).unwrap() - std::f64::consts::PI).abs() < 1e-12);
        assert_eq!(angle(&e1, &[0.0, 0.0]), None);
    }

    #[test]
    fn norms() {
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
        assert_eq!(norm_sq(&[3.0, 4.0]), 25.0);
    }
}
