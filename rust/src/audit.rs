//! Runtime audit layer (`--features audit`): a counting global
//! allocator plus the inline Cluster/Engine asserts, so CI can prove —
//! at the allocator and virtual-clock level — the invariants
//! `pallas-lint` checks statically:
//!
//! - a compact-master round must not allocate an O(d) buffer (the one
//!   sanctioned size-d allocation is the final `RunResult::w`
//!   expansion): `tests/audit.rs` sets the large-allocation threshold
//!   to d·8 bytes around a run and asserts the counter;
//! - a virtual clock must never run backwards (asserts in
//!   [`crate::cluster::Engine`]);
//! - comm bytes must never be charged to the [`crate::cluster::Ledger`]
//!   without a matching engine event
//!   ([`crate::cluster::Engine::comm_marks`]).
//!
//! With the feature off every function here is a no-op returning zero,
//! so callers need no `cfg` of their own.
//!
//! The counters are process-global (a `#[global_allocator]` cannot be
//! anything else), so tests that read them must serialize themselves —
//! `tests/audit.rs` shares one mutex.

#[cfg(feature = "audit")]
mod imp {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicUsize, Ordering};

    pub static ALLOCS: AtomicUsize = AtomicUsize::new(0);
    pub static BYTES: AtomicUsize = AtomicUsize::new(0);
    pub static MAX_SINGLE: AtomicUsize = AtomicUsize::new(0);
    pub static LARGE_THRESHOLD: AtomicUsize = AtomicUsize::new(usize::MAX);
    pub static LARGE_COUNT: AtomicUsize = AtomicUsize::new(0);

    fn record(size: usize) {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(size, Ordering::Relaxed);
        MAX_SINGLE.fetch_max(size, Ordering::Relaxed);
        if size >= LARGE_THRESHOLD.load(Ordering::Relaxed) {
            LARGE_COUNT.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Pure pass-through to the system allocator with lock-free
    /// counter updates on every acquisition path (alloc, alloc_zeroed
    /// — `vec![0.0; d]` lands there — and realloc growth).
    pub struct CountingAlloc;

    // lint: allow-file(unsafe-contract) — delegating GlobalAlloc impl:
    // every method forwards verbatim to `System` after touching only
    // lock-free atomics, and the audit CI job runs the whole tier-1
    // suite through it.
    unsafe impl GlobalAlloc for CountingAlloc {
        // SAFETY: same contract as `System::alloc` — layout is
        // non-zero-sized per GlobalAlloc's caller contract; counting
        // first cannot allocate (atomics only), so no reentrancy.
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            record(layout.size());
            System.alloc(layout)
        }

        // SAFETY: delegates to `System::alloc_zeroed` unchanged.
        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            record(layout.size());
            System.alloc_zeroed(layout)
        }

        // SAFETY: ptr/layout come from a previous alloc on this
        // allocator, which is exactly `System`'s dealloc contract.
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        // SAFETY: same contract as `System::realloc`; the new size is
        // counted as a fresh acquisition.
        unsafe fn realloc(
            &self,
            ptr: *mut u8,
            layout: Layout,
            new_size: usize,
        ) -> *mut u8 {
            record(new_size);
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static AUDIT_ALLOC: CountingAlloc = CountingAlloc;
}

/// Total heap acquisitions observed so far (0 with the feature off).
pub fn alloc_count() -> usize {
    #[cfg(feature = "audit")]
    {
        imp::ALLOCS.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "audit"))]
    {
        0
    }
}

/// Total bytes requested from the allocator (0 with the feature off).
pub fn alloc_bytes() -> usize {
    #[cfg(feature = "audit")]
    {
        imp::BYTES.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "audit"))]
    {
        0
    }
}

/// Largest single acquisition seen since process start.
pub fn max_single_alloc() -> usize {
    #[cfg(feature = "audit")]
    {
        imp::MAX_SINGLE.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "audit"))]
    {
        0
    }
}

/// Count every future acquisition of at least `bytes` bytes (the O(d)
/// detector: set it to d·8 around a compact-master run). `usize::MAX`
/// disarms it. No-op with the feature off.
pub fn set_large_alloc_threshold(bytes: usize) {
    #[cfg(feature = "audit")]
    imp::LARGE_THRESHOLD.store(bytes, std::sync::atomic::Ordering::Relaxed);
    #[cfg(not(feature = "audit"))]
    let _ = bytes;
}

/// Zero the large-acquisition counter.
pub fn reset_large_allocs() {
    #[cfg(feature = "audit")]
    imp::LARGE_COUNT.store(0, std::sync::atomic::Ordering::Relaxed);
}

/// Acquisitions at or above the configured threshold since the last
/// reset (0 with the feature off).
pub fn large_alloc_count() -> usize {
    #[cfg(feature = "audit")]
    {
        imp::LARGE_COUNT.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "audit"))]
    {
        0
    }
}

/// Snapshot-style view over the global counters: `begin()` before the
/// region of interest, then read deltas.
pub struct AllocWatch {
    count0: usize,
    bytes0: usize,
    large0: usize,
}

impl AllocWatch {
    pub fn begin() -> AllocWatch {
        AllocWatch {
            count0: alloc_count(),
            bytes0: alloc_bytes(),
            large0: large_alloc_count(),
        }
    }

    /// Acquisitions since `begin()`.
    pub fn allocations(&self) -> usize {
        alloc_count() - self.count0
    }

    /// Bytes requested since `begin()`.
    pub fn bytes(&self) -> usize {
        alloc_bytes() - self.bytes0
    }

    /// Threshold-sized acquisitions since `begin()`.
    pub fn large_allocs(&self) -> usize {
        large_alloc_count() - self.large0
    }
}
