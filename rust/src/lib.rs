//! # psgd — "A Parallel SGD Method with Strong Convergence"
//!
//! Full-system reproduction of Mahajan, Sundararajan, Keerthi & Bottou
//! (cs.LG 2013). The paper's contribution — Algorithm 1, a batch
//! descent method whose search direction comes from *parallel SGD runs
//! on gradient-consistent local approximations* — lives in
//! [`algo::fs`]; everything else is the substrate it needs:
//!
//! - [`linalg`] — CSR sparse matrix, dense vector kernels, and the
//!   [`linalg::sparse`] index/value vectors + per-shard
//!   [`linalg::SupportMap`] dictionaries (sorted global columns ↔
//!   compact local ids) the whole compact-coordinate pipeline runs on.
//! - [`data`] — libsvm I/O, the kdd2010-shaped synthetic generator,
//!   example partitioning.
//! - [`loss`] — the differentiable convex losses the theory covers.
//! - [`objective`] — regularized risk, shard-local views, the tilted
//!   approximation f̂_p (eq. 2) in full space ([`objective::LocalApprox`],
//!   the reference) and in **compact support coordinates**
//!   ([`objective::CompactApprox`]: |support| coordinates plus an
//!   orthonormal ≤2-dim tail spanning the off-support affine dynamics),
//!   so every inner solver reproduces the full-space solve with
//!   O(|support|) buffers.
//! - [`opt`] — inner/core optimizers: SVRG, SAG, SGD, TRON, L-BFGS, CG
//!   and the distributed Armijo–Wolfe line search; the stochastic
//!   solvers take reusable scratch working sets from the cluster pool.
//! - [`cluster`] — the simulated AllReduce cluster. Shards store
//!   column-remapped CSRs ([`cluster::Shard::xl`]); map phases are
//!   **threaded by default** (`--threads 0` = auto-detect cores) and
//!   hand each node a [`cluster::NodeScratch`] so steady-state solves
//!   allocate nothing (including the line search's dʳ·xᵢ margins,
//!   `NodeScratch::dz`). Gradient/direction rounds auto-route through
//!   sparse merge-by-index reductions when shard supports are small
//!   relative to d (`Cluster::prefer_sparse`), charging by actual
//!   bytes moved (nnz·12 vs d·8) on both Tree (per-level messages) and
//!   Ring (chunked nnz payload) topologies, with per-level wire
//!   profiles recorded on the [`cluster::Ledger`] under both time
//!   models.
//!
//!   **Union-support compact master.** The cluster also builds the
//!   global union support U = ⋃_p support_p at partition time
//!   ([`cluster::Cluster::umap`], with each shard's composed positions
//!   in [`cluster::Shard::upos`]). Because every outer-loop quantity —
//!   wʳ, gʳ, dʳ, every hybrid correction, SQM's CG directions — is an
//!   affine combination of w⁰ = 0, loss gradients (supported in U)
//!   and support-sized corrections, the whole master side provably
//!   lives in U: under the density gate
//!   (`Cluster::prefer_compact_master`, |U|/d < 0.5 — the companion
//!   of `prefer_sparse` with the same threshold) the FS, async-FS and
//!   parameter-mixing drivers run *every master buffer* at length |U|
//!   (wire payloads become U-position index/value pairs — a monotone
//!   index bijection, so reductions sum coordinate-for-coordinate
//!   identically and traces are ε-identical to the dense master,
//!   pinned by `tests/compact_master.rs`), broadcasts ship O(|U|)
//!   bytes (`Cluster::broadcast_support`), the async re-basing ring
//!   drops from O(τ·d) to O(τ·|U|), and the full-d vector is
//!   materialized exactly once into `RunResult::w`.
//!   `benches/master_side.rs` gates the win in CI: strictly faster
//!   seconds/round than the dense master at d = 5M and 50M with
//!   |U| ≈ 100k. CLI `--master auto|dense|compact` overrides the gate.
//!
//!   **Timing** is an event-driven schedule computed by
//!   [`cluster::Engine`]: one virtual clock per node, scaled by a
//!   seeded [`cluster::NodeProfile`] (the one straggler/heterogeneity
//!   surface); every phase — local solve, gradient
//!   sweep, Hv product, each tree hop, scalar round — is a timed event,
//!   and a reduction-tree parent hop starts at `max(children ready)`,
//!   so in pipelined schedules fast subtrees hide slow ones.
//!   [`cluster::Ledger::seconds`] is a view over this timeline (the
//!   critical-path makespan); `comm_seconds`/`compute_seconds` keep
//!   the flat barrier-equivalent component breakdown, and the two
//!   agree to ε for non-pipelined runs (pinned by `tests/engine.rs`).
//!   FS's
//!   `--pipeline` mode re-schedules the direction allreduce, safeguard
//!   scalars and line search onto the engine's *control lane* so they
//!   overlap the next round's self-paced node compute — a schedule
//!   change only, arithmetic bit-identical. `--trace-timeline out.json`
//!   exports the schedule as JSON:
//!   `{makespan, nodes, pipeline, profile[], dropped_events,
//!   events[{label, node, level, start, end, staleness}]}` —
//!   `tests/engine.rs` pins the shape; `benches/pipeline.rs` and the
//!   plots consume it. `staleness` is non-null on async quorum
//!   arrivals only.
//!
//!   **Asynchrony in the maths** goes one step further
//!   ([`algo::async_fs`], CLI `--async-fs --staleness τ --quorum q`):
//!   local solves run on per-node *solver lanes* while the main lanes
//!   keep the cheap synchronous gradient/commit path; the master
//!   combines an arrival-ordered quorum — it waits for q fresh
//!   round-r solves and represents stragglers by their most recent
//!   hybrid at most τ rounds old, re-based onto the current wʳ via
//!   the affine machinery the wire format already carries (the master
//!   keeps the last τ+1 (wʳ′, gʳ′) references, O(τ·d) master memory).
//!   The paper's safeguard is the correctness gate: fresh parts get
//!   the per-direction angle test, and a combined direction that
//!   fails sufficient descent is discarded — that round falls back to
//!   the synchronous barrier direction, so strong convergence holds
//!   for any (τ, q). τ=0 with a full quorum *is* Algorithm 1
//!   (bit-identical to `--method fs`, pinned by `tests/async_fs.rs`);
//!   under a straggler profile the quorum stops waiting for the slow
//!   node and `benches/async_fs.rs` asserts the makespan-to-ε
//!   strictly beats the pipelined schedule. Per-round staleness
//!   histograms land on the [`cluster::Ledger`]
//!   (`staleness_hist` / `fallback_rounds`).
//!   **Fault model** ([`cluster::faults`], CLI `--fault SCRIPT
//!   --fault-seed S`): deterministic, seeded fleet weather on top of
//!   the virtual clocks. A [`cluster::FaultPlan`] — parsed from a
//!   script like
//!   `crash:3@12.5s,restart:3@30s,degrade:1@5s:0.25x,flap:2:p=0.05,loss:p=0.1`
//!   or generated by `FaultPlan::seeded` — schedules node
//!   **crash/restart** (elastic membership: the dead node's shard is
//!   absent, the quorum shrinks, combine weights recompute over the
//!   survivors; a restarted node re-bases onto the current iterate
//!   through the same affine wire format, charged as a
//!   `rejoin_rebase` unicast), transient **flaps** (one round out,
//!   nothing to recover), in-place **compute degradation** (the
//!   node's profile speed changes mid-run), and **wire loss** on
//!   direction contributions (retry once after a virtual timeout,
//!   then drop — absorbed by the partial quorum, and an empty quorum
//!   routes through the certified synchronous fallback, so no fault
//!   can hang a round). Every decision is a pure hash of
//!   `(seed, round, node)` — no sequential RNG, no wall clock
//!   (pallas-lint extends its no-wall-clock rule over
//!   `cluster/faults.rs`) — so one seed replays the identical fault
//!   timeline and bit-identical trace, and the empty plan is
//!   bit-identical to no plan at all: full-membership rounds delegate
//!   structurally to the exact pre-fault code paths
//!   (`tests/faults.rs` pins all three, `benches/fault_tolerance.rs`
//!   + the CI `chaos` job gate convergence under a 3-seed ×
//!   crash/flap/degrade matrix). Fault accounting lands on the
//!   [`cluster::Ledger`] (`crash_events`, `rejoin_rebases` +
//!   `recovery_seconds`, `lost_messages`, `retry_rounds`,
//!   `degrade_events`, `flap_events`), in the timeline JSON's
//!   `resilience` block, and in the experiment report's resilience
//!   table.
//! - [`algo`] — FS-s (Algorithm 1) aggregating hybrid directions
//!   (a_w·wʳ + a_g·gʳ + support-sized sparse corrections — the only
//!   payload the direction allreduce moves), its bounded-staleness
//!   asynchronous variant ([`algo::async_fs`]), SQM, Hybrid, parameter
//!   mixing and the auto-switching extension.
//! - [`metrics`] — AUPRC, convergence traces, run recording, and the
//!   offline report reader (`metrics::report::RecordedRun`).
//! - [`obs`] — the flight recorder: per-round telemetry records, the
//!   ordered metrics registry, and the JSONL sink (see
//!   `## Observability` below).
//! - `runtime` — PJRT executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); the dense three-layer path.
//!   Gated behind the off-by-default `xla` cargo feature so the
//!   offline build never needs the xla_extension shared library.
//! - [`util`], [`bench`] — in-tree CLI/config/JSON/RNG/property-test/
//!   bench-harness substrates (offline registry: see Cargo.toml).
//!
//! ## Invariants
//!
//! The properties above rest on rules the compiler cannot see. They
//! are enforced statically by the workspace's `pallas-lint` crate
//! (`make lint-invariants`, blocking in CI and part of `make verify`)
//! and dynamically by the [`audit`] layer (`--features audit`):
//!
//! 1. **no-dense-master** — no `vec![_; dim]` / `with_capacity(dim)`
//!    O(d) allocation in the outer-loop driver files
//!    (`algo/{fs,async_fs,param_mix,common,theory}.rs`). The compact
//!    master materializes full-d exactly once, into `RunResult::w`;
//!    any other O(d) buffer silently re-densifies the O(|U|) loop.
//! 2. **no-wall-clock** — `Instant`/`SystemTime` are banned in `algo/`,
//!    `cluster/engine.rs`, `cluster/allreduce.rs`, `cluster/faults.rs`,
//!    `cluster/cost.rs` and `obs/`: all timing flows through the
//!    engine's virtual
//!    clocks so runs (and seeded fault replays, and recorded
//!    telemetry streams) are reproducible.
//!    (The measured-threading sites in `cluster/mod.rs` and
//!    `util/timer.rs` are outside the rule's scope by design — they
//!    *feed* the virtual clocks.)
//! 3. **no-unordered-iteration** — `HashMap`/`HashSet` are banned in
//!    code feeding reductions, wire payloads, or telemetry streams
//!    (`algo/`, `cluster/`, `objective/`, `linalg/`, `obs/`):
//!    iteration order must be deterministic or bit-identical traces
//!    (and line-diffable record streams) die. Use BTree or sorted
//!    Vecs — the [`obs::Registry`] is `Vec`-indexed for exactly this
//!    reason.
//! 4. **ledger-pairing** — `reduce_parts*`/`broadcast*`/`map_reduce*`/
//!    `async_quorum_reduce*` may only be called on a cluster handle
//!    (receiver containing `cluster`), and raw `tree_sum` calls are
//!    banned outside `cluster/` — so no wire crossing can bypass the
//!    [`cluster::Ledger`] charge.
//! 5. **no-alloc-in-steady-state** — `Vec::new`/`vec![`/`.clone()` are
//!    banned inside the per-round closure bodies served by
//!    [`cluster::NodeScratch`] (`map_each_scratch*`,
//!    `map_reduce_scalars_scratch`, `map_nodes_timed`): steady-state
//!    rounds must be allocation-free.
//! 6. **unsafe-contract** — every `unsafe` block needs a `// SAFETY:`
//!    comment on/above it and must live in a Miri-covered module
//!    (`linalg/{csr,sparse,dense}.rs`; CI runs Miri over the `linalg`
//!    tests).
//!
//! Escape hatch: a justified inline comment on (or immediately above)
//! the offending line —
//! `// lint: allow(<rule>[, <rule>]) — <reason>` — or
//! `// lint: allow-file(<rule>) — <reason>` anywhere in the file. The
//! reason is mandatory; an allow without one is ignored. `#[cfg(test)]
//! mod` bodies are exempt.
//!
//! The [`audit`] feature backs rules 1/2/4 at runtime: a counting
//! global allocator (`tests/audit.rs` fails if a compact-master run
//! makes an O(d·8) acquisition beyond the single sanctioned `w`
//! expansion), clock-monotonicity asserts in [`cluster::Engine`], and
//! comm-byte↔event pairing asserts in [`cluster::Cluster`]. CI runs
//! the full tier-1 suite under `--features audit`.
//!
//! ## Observability
//!
//! The flight recorder ([`obs`]) turns a run into a replayable record
//! stream: `--metrics-out run.jsonl` streams one JSON line per outer
//! round behind the [`obs::Recorder`] trait.
//!
//! **Record schema** (version [`obs::SCHEMA_VERSION`]). Line 1 is the
//! run manifest (`kind:"manifest"`): config, seeds, dataset shape and
//! git-describe-free build info (package name + version). Every
//! following line is one `kind:"round"` record — [`obs::RoundRecord`]
//! is the authoritative field list — carrying
//!
//! - the round's trace mirror (`f`, `gnorm`, `auprc`, cumulative
//!   `passes`/`secs`, `sg_hits`) — exactly the round's
//!   [`metrics::TracePoint`], so the trace rebuilds bit-for-bit;
//! - algorithm decisions: per-node safeguard replacements
//!   (`sg_replaced`), the combined-test verdict (`combined_ok`), the
//!   fallback reason (`"empty-quorum"` | `"safeguard"` |
//!   `"partition-heal"`), the accepted
//!   step size and the strong-Wolfe trial count (`null` on rounds
//!   that stopped before the decision);
//! - async state: quorum composition, per-contribution staleness,
//!   rejoin re-base count, speculation outcomes (`spec_hits`/
//!   `spec_misses`) and the (τ, q) in force under the adaptive policy
//!   (`ctrl_tau`/`ctrl_q`, `null` otherwise); fleet weather: live
//!   membership + the fault events applied this round; compact-master
//!   state: density-gate decision + live |U|;
//! - ledger/engine *deltas* over the round (`d_passes`, `d_bytes`,
//!   `d_scalar`, `d_makespan`, `d_level_bytes`), the cumulative
//!   `recovery_s`/`retry_s`, and the round's link weather
//!   (`link_retries`/`reroutes` deltas; partition/heal events ride
//!   the applied-fault slice).
//!
//! Non-finite floats serialize as `null` (the auprc NaN sentinel);
//! finite floats print shortest-round-trip, so
//! [`util::json::parse`] recovers identical bits.
//!
//! **Sink guarantees.** Recording charges zero virtual time, passes,
//! or bytes — a recorder only *reads* the [`cluster::Ledger`] and
//! [`cluster::Engine`]. Steady-state rounds stay allocation-free: the
//! record's vectors and the JSONL sink's buffers are pre-sized and
//! reused (the `audit` feature pins zero acquisitions per recorded
//! round in `tests/obs.rs`).
//!
//! **Off-path bit-identity.** With no recorder installed every hook
//! is an early-return on one cached branch; traces, iterates and
//! ledgers are byte-for-byte the pre-recorder behavior
//! (`tests/obs.rs` pins this against a seeded async+fault run).
//!
//! **Post-hoc analysis.** `metrics::report::RecordedRun::from_jsonl`
//! validates a stream (manifest first, consecutive rounds) and
//! rebuilds the trace + ledger, so `psgd --report-from run.jsonl`
//! reproduces the in-process markdown report byte-for-byte offline;
//! `--report-from a.jsonl b.jsonl` diffs two runs and flags the first
//! divergent round (the PR-7 bitwise-replay property, made
//! diagnosable); `--check` validates the schema for CI. The ordered
//! [`obs::Registry`] (counters/gauges/histograms) is the one render
//! path behind every `*_profile()` string the ledger, engine and
//! fault layer expose.
//!
//! ## Network model
//!
//! Link-level weather on the reduction tree ([`cluster::LinkProfile`]
//! + [`cluster::LinkFaultPlan`], CLI
//! `--link-profile SCRIPT --link-fault SCRIPT --link-seed S`):
//!
//! **Link profile.** Grammar `uplink:N:Fx | level:L:Fx | rack:I:Fx`
//! (comma-separated), or `seeded` (one slow rack + slow top levels),
//! or `uniform`. Every tree hop in which node `N` sends at tree level
//! `L` costs `base · uplink[N] · level[L]` virtual seconds; fan-out
//! paths without per-edge hops (broadcast, ring segments, scalar
//! rounds, rejoin unicasts) scale by the profile's mean multiplier.
//! The uniform profile is exactly ×1.0 on every edge and the cluster
//! takes the legacy code paths verbatim — bit-identical to no profile
//! at all (`tests/faults.rs` pins it).
//!
//! **Timeout / retry / backoff** (`--link-fault`, async driver only).
//! A hop that misses its `timeout:T` deadline retries with exponential
//! backoff: `k` failed attempts cost `T·(2^k − 1)` extra, charged to
//! the ledger's `retry_seconds` — never folded into comm seconds.
//! Past `budget:K` attempts the sender reroutes around the dead edge —
//! re-parented one level up, charged as a `reroute` span at twice the
//! hop cost plus the exhausted backoff. `noretry` waits out the full
//! dead window `T·2^k` instead (the bench's control arm — strictly
//! worse). `congest:p=P[:Fx]` stretches a hop ×F; `flap:p=P` fails
//! whole attempts; `part:A+B@rF..rU` cuts nodes out of the tree for
//! rounds F..U — the quorum treats the cut set like crashed members
//! (lanes kept; ≤τ-stale hybrids rejoin on heal), node 0 is the
//! reference frame and cannot be cut, and a partition that isolates
//! the master heals through the certified synchronous fallback
//! (`"partition-heal"`) — no link state can hang a round. Every coin
//! is a pure hash of `(seed, round, edge)`: one seed replays the
//! identical weather, bit for bit.
//!
//! **Accounting & telemetry.** Distinct ledger counters:
//! `retry_seconds`, `link_retries`, `reroutes`, `congested_hops`,
//! `partition_events` (the resilience table renders `recovery s` and
//! `retry s` side by side). The timeline JSON gains a `link_events`
//! block with exactly those five fields; partition/heal events land
//! on their own applied-link log (separate watermark from the node
//! fault log). The adaptive controller reads the same counters: a
//! congested window — link retry/reroute activity with a retry-stall
//! share above 20% of wire time — widens τ and shrinks q
//! ([`algo::adapt`] rule 2).
//!
//! ## Speculation & adaptive asynchrony
//!
//! Two layers on top of the bounded-staleness driver, both pure
//! schedule/policy changes with the safeguard as the unchanged
//! correctness gate ([`algo::adapt`] + [`algo::async_fs`]):
//!
//! **Speculative solver lanes** (`--speculate`). Between shipping its
//! round-r solve and the round-r commit a solver lane is idle; with
//! speculation on it starts the round-(r+1) solve early against a
//! predicted iterate (its own uncombined hybrid applied to wʳ). At the
//! commit the master reconciles the prediction through the same affine
//! re-basing the stale quorum path uses, and the safeguard's cone test
//! decides: a **hit** banks the head start on the virtual clock (the
//! lane's solve is done earlier, so the arrival-ordered quorum
//! deadline moves up); a **miss** is charged to the ledger as
//! `speculation_rebase` wasted seconds and the solve restarts at the
//! commit — exactly the plain async schedule, so speculation never
//! loses time. The *maths never moves*: every combined direction is
//! still computed against the true reference, so `--speculate` is
//! bit-identical in iterates to the same run without it
//! (`tests/speculation.rs` pins this; `benches/speculation.rs` gates
//! the strict virtual-seconds win on the straggler and chaos
//! matrices). Outcomes land on the [`cluster::Ledger`] (`spec_hits`,
//! `spec_misses`, `spec_rebase_seconds`).
//!
//! **First-class asynchrony policy** ([`algo::adapt::Asynchrony`]).
//! The driver's schedule is configured by a typed policy — `Sync`
//! (≡ the synchronous driver, bit-identical), `Bounded{tau, quorum}`
//! (the fixed regime; [`algo::adapt::Quorum::All`] retires the old
//! `usize::MAX` sentinel), or `Adaptive{init, bounds}`
//! (`--adaptive`): a deterministic [`algo::adapt::Controller`]
//! re-decides (τ, q) every [`algo::adapt::TUNE_WINDOW`] async rounds
//! from the ledger's own staleness histogram and fallback/fault
//! counters — fallback spikes shrink τ, a widening straggler gap
//! shrinks q, fault-active windows hold, calm windows re-expand toward
//! `tau_max`/the live membership. Every decision is a pure function of
//! ledger counters (no wall clock, no RNG — pallas-lint's scope covers
//! the module), recorded on [`cluster::Ledger::tune_trace`], so seeded
//! runs replay their (τ, q) trajectory bit-identically.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the -Wl,-rpath flag the
//! # // workspace builds use, so the xla runtime .so can't be loaded.
//! use psgd::prelude::*;
//!
//! let data = psgd::data::synth::SynthConfig::small().generate(42);
//! let (train, test) = data.split(0.9, 7);
//! let lam = 1e-5 * train.n_examples() as f64;
//! let mut cluster = Cluster::partition(train, 4, CostModel::default());
//! let fs = FsDriver::new(FsConfig { lam, epochs: 2, ..Default::default() });
//! let run = fs.run(&mut cluster, Some(&test), &StopRule::iters(5));
//! println!("f = {}, {} comm passes", run.f, run.ledger.comm_passes);
//! ```

pub mod algo;
pub mod audit;
pub mod bench;
pub mod cluster;
pub mod data;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod objective;
pub mod obs;
pub mod opt;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod util;

/// Convenience re-exports for the common driver workflow.
pub mod prelude {
    pub use crate::algo::adapt::{Asynchrony, Quorum, TuneBounds};
    pub use crate::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
    pub use crate::algo::fs::{FsConfig, FsDriver};
    pub use crate::algo::hybrid::HybridDriver;
    pub use crate::algo::param_mix::ParamMixDriver;
    pub use crate::algo::sqm::{SqmConfig, SqmDriver};
    pub use crate::algo::{Driver, RunResult, StopRule};
    pub use crate::cluster::{Cluster, CostModel};
    pub use crate::data::dataset::Dataset;
    pub use crate::loss::LossKind;
    pub use crate::metrics::trace::Trace;
}
