//! Plain stochastic gradient descent (Bottou [1]) on the *untilted*
//! local objective f̃_p = (λ/2)‖w‖² + L_p(w) — what Hybrid and
//! parameter-mixing run for their single local epoch.
//!
//! Sparse-efficient: the weight vector is represented as w = s·v so the
//! L2 shrink is O(1) per step and only nnz coordinates are touched
//! ("scale trick", as in Bottou's svmsgd). Learning rate schedule
//! η_t = η0 / (1 + λ·η0·t).

use crate::linalg::Csr;
use crate::loss::LossKind;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SgdParams {
    pub epochs: usize,
    pub eta0: f64,
    pub seed: u64,
}

impl Default for SgdParams {
    fn default() -> Self {
        SgdParams { epochs: 1, eta0: 0.1, seed: 0 }
    }
}

/// Scale-represented weight vector: w = scale · v.
struct ScaledVec {
    scale: f64,
    v: Vec<f64>,
}

impl ScaledVec {
    fn new(w: &[f64]) -> ScaledVec {
        ScaledVec { scale: 1.0, v: w.to_vec() }
    }

    #[inline]
    fn dot_row(&self, x: &Csr, i: usize) -> f64 {
        self.scale * x.row_dot(i, &self.v)
    }

    /// w ← (1 − ηλ)·w  (the L2 shrink), O(1)
    #[inline]
    fn shrink(&mut self, factor: f64) {
        self.scale *= factor;
        if self.scale.abs() < 1e-100 {
            self.materialize(); // avoid denormal underflow
        }
    }

    /// w ← w + α·xᵢ (sparse), adjusting for the scale
    #[inline]
    fn add_row(&mut self, x: &Csr, i: usize, alpha: f64) {
        x.add_row_scaled(i, alpha / self.scale, &mut self.v);
    }

    fn materialize(&mut self) -> Vec<f64> {
        for vj in self.v.iter_mut() {
            *vj *= self.scale;
        }
        self.scale = 1.0;
        self.v.clone()
    }
}

/// Run SGD epochs on f̃_p over shard (x, y); returns the final iterate.
pub fn sgd_epochs(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    w0: &[f64],
    params: &SgdParams,
) -> Vec<f64> {
    sgd_epochs_shrink(x, y, loss, lam, w0, params).0
}

/// [`sgd_epochs`] that also reports the total L2 shrink Π_t(1 − η_tλ).
/// On a support-compact shard this is the whole off-support story: a
/// coordinate no row touches only ever shrinks, so
/// w_off_final = shrink·w_off — the scalar the hybrid direction
/// aggregation needs to reconstruct the full-space SGD result from a
/// |support|-sized solve.
pub fn sgd_epochs_shrink(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    w0: &[f64],
    params: &SgdParams,
) -> (Vec<f64>, f64) {
    let n = x.n_rows();
    if n == 0 {
        return (w0.to_vec(), 1.0);
    }
    let mut rng = Rng::new(params.seed);
    let mut w = ScaledVec::new(w0);
    let mut shrink_total = 1.0f64;
    let mut t = 0u64;
    for _ in 0..params.epochs {
        let order = rng.permutation(n);
        for &i in &order {
            let i = i as usize;
            let eta = params.eta0 / (1.0 + lam * params.eta0 * t as f64);
            // ∇ᵢ f̃_p = λw + l'(w·xᵢ)·xᵢ  (per-example, λ on every step —
            // the classic "pattern" λ scaling for sum objectives uses
            // λ/n per step; we keep the paper's sum form so the shrink
            // uses λ directly)
            let z = w.dot_row(x, i);
            let r = loss.deriv(z, y[i]);
            let factor = 1.0 - eta * lam;
            w.shrink(factor);
            shrink_total *= factor;
            if r != 0.0 {
                w.add_row(x, i, -eta * r);
            }
            t += 1;
        }
    }
    (w.materialize(), shrink_total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::linalg::dense;
    use crate::objective::{Objective, RegularizedLoss};

    /// Dense reference implementation (no scale trick) for equivalence.
    fn sgd_dense(
        x: &Csr,
        y: &[f64],
        loss: LossKind,
        lam: f64,
        w0: &[f64],
        params: &SgdParams,
    ) -> Vec<f64> {
        let mut rng = Rng::new(params.seed);
        let mut w = w0.to_vec();
        let mut t = 0u64;
        for _ in 0..params.epochs {
            let order = rng.permutation(x.n_rows());
            for &i in &order {
                let i = i as usize;
                let eta = params.eta0 / (1.0 + lam * params.eta0 * t as f64);
                let z = x.row_dot(i, &w);
                let r = loss.deriv(z, y[i]);
                for wj in w.iter_mut() {
                    *wj *= 1.0 - eta * lam;
                }
                if r != 0.0 {
                    x.add_row_scaled(i, -eta * r, &mut w);
                }
                t += 1;
            }
        }
        w
    }

    #[test]
    fn scale_trick_matches_dense_reference() {
        let d = SynthConfig {
            n_examples: 60,
            n_features: 30,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(2);
        let w0 = vec![0.01; 30];
        let params = SgdParams { epochs: 2, eta0: 0.05, seed: 3 };
        let fast = sgd_epochs(&d.x, &d.y, LossKind::Logistic, 0.1, &w0, &params);
        let slow = sgd_dense(&d.x, &d.y, LossKind::Logistic, 0.1, &w0, &params);
        assert!(
            dense::max_abs_diff(&fast, &slow) < 1e-10,
            "max diff {}",
            dense::max_abs_diff(&fast, &slow)
        );
    }

    #[test]
    fn one_epoch_decreases_objective_from_zero() {
        let d = SynthConfig::small().generate(3);
        let dim = d.n_features();
        let lam = 1e-3 * d.n_examples() as f64; // sum-form λ
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam,
        };
        let w0 = vec![0.0; dim];
        let w1 = sgd_epochs(
            &d.x, &d.y, LossKind::Logistic, lam, &w0,
            &SgdParams { epochs: 1, eta0: 0.05, seed: 1 },
        );
        assert!(obj.value(&w1) < obj.value(&w0));
    }

    #[test]
    fn empty_shard_is_identity() {
        let x = Csr::new(5);
        let w0 = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let w1 = sgd_epochs(
            &x, &[], LossKind::Logistic, 0.1, &w0, &SgdParams::default(),
        );
        assert_eq!(w0, w1);
    }

    #[test]
    fn deterministic_in_seed() {
        let d = SynthConfig {
            n_examples: 50,
            n_features: 20,
            ..SynthConfig::default()
        }
        .generate(5);
        let w0 = vec![0.0; 20];
        let p = SgdParams { epochs: 1, eta0: 0.1, seed: 9 };
        let a = sgd_epochs(&d.x, &d.y, LossKind::SquaredHinge, 0.2, &w0, &p);
        let b = sgd_epochs(&d.x, &d.y, LossKind::SquaredHinge, 0.2, &w0, &p);
        assert_eq!(a, b);
    }
}
