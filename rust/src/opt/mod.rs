//! Optimizers.
//!
//! Inner solvers for Algorithm 1 step 5 (per-node, on f̂_p — generic
//! over `objective::TiltedShard`, so they run identically on the
//! full-space `LocalApprox` and the support-compact `CompactApprox`;
//! the stochastic ones take reusable scratch working sets from the
//! cluster's per-node pool):
//! - [`svrg`] — the paper's choice [3]: strongly convergent SGD.
//! - [`sag`] — the other strongly-convergent option Theorem 2 covers.
//! - [`sgd`] — plain Bottou SGD (used by Hybrid/ParamMix init).
//!
//! Core batch optimizers (the SQM baseline and inner-solver swaps):
//! - [`tron`] — trust-region Newton-CG (LIBLINEAR-style), the paper's
//!   SQM core.
//! - [`lbfgs`] — limited-memory BFGS (the [8] variant).
//! - [`cg`] — linear CG + Steihaug trust-region CG.
//!
//! Shared machinery:
//! - [`linesearch`] — strong-Wolfe (Armijo (3) + Wolfe (4)) search, and
//!   the margin-based 1-D evaluator the paper's step 8 uses.

pub mod cg;
pub mod dca;
pub mod lbfgs;
pub mod linesearch;
pub mod sag;
pub mod sgd;
pub mod svrg;
pub mod tron;
