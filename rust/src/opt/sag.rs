//! SAG — Stochastic Average Gradient (Le Roux, Schmidt & Bach [2]),
//! the *other* strongly-convergent SGD the paper cites as satisfying
//! Theorem 2's hypothesis ("Recent SGD methods [3, 2] possess the
//! strong convergence property needed in Theorem 2").
//!
//! Maintains a memory of the last gradient of every example; each step
//! updates one example's slot and moves along the running average:
//!
//!   y_i ← ∇l_i(w)  (for the sampled i),   ḡ = (Σ_j y_j)/n
//!   w ← w − η(ḡ + λw + tilt/n·?)           — sum-form handled below
//!
//! For the sum-form tilted objective f̂_p = (λ/2)‖w‖² + Σ l_i + tilt·w,
//! the step is w ← w − η(Σ_j y_j + λw + tilt). Like the SVRG path, the
//! dense (λw + tilt) part is affine-constant between sparse touches, so
//! the same lazy fast-forward trick applies; here the gradient *sum*
//! also changes sparsely (one row swapped per step), so the epoch is
//! O(nnz) amortized... except the sum vector update: swapping row i
//! changes Σy on x_i's support only — sparse as well.
//!
//! Memory: one scalar per example (the margin-derivative r_i), since
//! ∇l_i = r_i·x_i — the standard linear-model compression of SAG.

use crate::objective::TiltedShard;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SagParams {
    pub epochs: usize,
    /// None → 1/(16·L_max) with L_max from max row norm (SAG theory)
    pub lr: Option<f64>,
    pub seed: u64,
}

impl Default for SagParams {
    fn default() -> Self {
        SagParams { epochs: 2, lr: None, seed: 0 }
    }
}

/// Reusable SAG working set (cluster scratch pool): O(n_p) example
/// memory plus O(dim) gradient-sum buffer in the solve space.
#[derive(Clone, Debug, Default)]
pub struct SagScratch {
    r_mem: Vec<f64>,
    s_sum: Vec<f64>,
    seen: Vec<bool>,
}

/// Run SAG epochs on f̂_p from `w0`. Returns the output point.
///
/// Implementation note: the dense part of the step,
/// w ← w − η(S + λw + tilt) with S = Σ_j y_j, is NOT affine-constant
/// across steps (S itself changes every step), so the SVRG-style lazy
/// fast-forward does not apply directly. For clarity and correctness we
/// apply the dense O(dim) update per step, making an epoch O(n·dim):
/// SAG here is the *ablation* inner solver; SVRG stays the production
/// choice (see the inner_solver bench). On the support-compact path
/// dim = |support| + tail, which is what makes even this dense-per-step
/// sweep affordable on high-d shards.
pub fn sag_epochs<O: TiltedShard>(
    approx: &O,
    w0: &[f64],
    params: &SagParams,
) -> Vec<f64> {
    sag_epochs_with(approx, w0, params, &mut SagScratch::default())
}

/// [`sag_epochs`] with an explicit reusable working set.
pub fn sag_epochs_with<O: TiltedShard>(
    approx: &O,
    w0: &[f64],
    params: &SagParams,
    scratch: &mut SagScratch,
) -> Vec<f64> {
    let x = approx.shard_x();
    let n = x.n_rows();
    let d = approx.dim();
    debug_assert_eq!(w0.len(), d);
    if n == 0 || params.epochs == 0 {
        return w0.to_vec();
    }
    let lam = approx.l2();
    let loss = approx.loss_kind();
    let y = approx.shard_y();
    let tilt = approx.tilt_coeffs();
    let lr = params.lr.unwrap_or_else(|| {
        // SAG's 1/(16·L_max) is stated for the AVERAGE-form objective;
        // the paper's objective is the SUM form (n× the average), so
        // the sum-form rate is 1/(16·L_max·n).
        let lmax = x
            .row_norms_sq()
            .into_iter()
            .fold(0.0f64, f64::max)
            .max(f64::MIN_POSITIVE)
            * loss.dd_max();
        1.0 / (16.0 * lmax * n as f64).max(lam * 2.0)
    });
    let mut rng = Rng::new(params.seed);
    let mut w = w0.to_vec();
    // r_mem[i] = stored margin-derivative of example i; S = Σ r_i·x_i
    let SagScratch { r_mem, s_sum, seen } = scratch;
    r_mem.clear();
    r_mem.resize(n, 0.0);
    s_sum.clear();
    s_sum.resize(d, 0.0);
    seen.clear();
    seen.resize(n, false);
    let mut n_seen = 0usize;

    for _ in 0..params.epochs {
        for _ in 0..n {
            let i = rng.below(n);
            let zi = x.row_dot(i, &w);
            let r_new = loss.deriv(zi, y[i]);
            // S += (r_new − r_old)·x_i  (sparse)
            let delta = r_new - r_mem[i];
            if delta != 0.0 {
                x.add_row_scaled(i, delta, s_sum);
            }
            r_mem[i] = r_new;
            if !seen[i] {
                seen[i] = true;
                n_seen += 1;
            }
            // unbiased-ish early phase: scale stored sum to full n as
            // SAG's practical variant does (n/n_seen correction)
            let scale = n as f64 / n_seen as f64;
            for j in 0..d {
                w[j] -= lr * (scale * s_sum[j] + lam * w[j] + tilt[j]);
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::linalg::dense;
    use crate::loss::LossKind;
    use crate::objective::{shard_loss_grad, LocalApprox, Objective};
    use crate::opt::tron::{self, TronParams};

    fn approx_for<'a>(
        d: &'a crate::data::dataset::Dataset,
        w_r: &[f64],
        lam: f64,
    ) -> LocalApprox<'a> {
        let dim = d.n_features();
        let mut grad_lp = vec![0.0; dim];
        shard_loss_grad(
            &d.x, &d.y, w_r, LossKind::Logistic, &mut grad_lp, None,
        );
        let mut g_r = grad_lp.clone();
        dense::axpy(lam, w_r, &mut g_r);
        LocalApprox::new(
            &d.x, &d.y, LossKind::Logistic, lam, w_r, &g_r, &grad_lp,
        )
    }

    #[test]
    fn descends_the_tilted_objective() {
        let data = SynthConfig {
            n_examples: 150,
            n_features: 30,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(1);
        let w_r = vec![0.0; 30];
        let approx = approx_for(&data, &w_r, 0.5);
        let w1 = sag_epochs(&approx, &w_r, &SagParams::default());
        assert!(approx.value(&w1) < approx.value(&w_r));
    }

    #[test]
    fn approaches_minimizer_with_epochs() {
        let data = SynthConfig {
            n_examples: 120,
            n_features: 20,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(2);
        let w_r = vec![0.05; 20];
        let lam = 1.0;
        let approx = approx_for(&data, &w_r, lam);
        let wstar = tron::minimize(&approx, &w_r, &TronParams {
            eps: 1e-12,
            ..Default::default()
        })
        .w;
        let d0 = dense::norm(&dense::sub(&w_r, &wstar));
        let mut prev = d0;
        for epochs in [2usize, 8, 24] {
            let w = sag_epochs(
                &approx,
                &w_r,
                &SagParams { epochs, lr: None, seed: 3 },
            );
            let dist = dense::norm(&dense::sub(&w, &wstar));
            assert!(dist < prev * 1.05, "epochs {epochs}: {dist} vs {prev}");
            prev = dist;
        }
        assert!(prev < 0.5 * d0, "no real contraction: {prev} vs {d0}");
    }

    #[test]
    fn zero_epochs_identity() {
        let data = SynthConfig::small().generate(3);
        let w_r = vec![0.1; data.n_features()];
        let approx = approx_for(&data, &w_r, 0.3);
        let w = sag_epochs(
            &approx,
            &w_r,
            &SagParams { epochs: 0, ..Default::default() },
        );
        assert_eq!(w, w_r);
    }
}
