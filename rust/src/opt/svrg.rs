//! SVRG (Johnson & Zhang [3]) on the tilted local objective f̂_p — the
//! paper's inner solver for Algorithm 1 step 5. SVRG is the reason
//! Theorem 2 applies: it has the *strong stochastic convergence*
//! property E‖w_s − ŵ*‖² ≤ K·αˢ‖w⁰ − ŵ*‖² the safeguard analysis needs.
//!
//! Epoch structure (matches `python/compile/model.py::svrg_epoch` and
//! `ref.svrg_epoch_ref` — cross-checked in the integration tests):
//! anchor w₀ = epoch-entry iterate, μ = ∇f̂_p(w₀); for each minibatch B
//!
//!   g = (n/|B|) Σ_{i∈B} [l'(w·xᵢ) − l'(w₀·xᵢ)]·xᵢ + μ + λ(w − w₀)
//!   w ← w − η·g
//!
//! The minibatch update splits into an O(d) dense part (μ, λ-term) and
//! an O(nnz_B) sparse part, so epoch cost is (n/b)·O(d) + O(nnz_p).

use crate::linalg::{dense, Csr};
use crate::objective::{Objective, TiltedShard};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SvrgParams {
    /// s in the paper: number of epochs (local passes)
    pub epochs: usize,
    /// 1 = the paper's per-example SVRG [3]; larger batches trade inner
    /// progress for throughput (the dense/PJRT path uses 256)
    pub batch: usize,
    /// None → 1/L̂ with L̂ from [`lipschitz_estimate`]
    pub lr: Option<f64>,
    pub seed: u64,
}

impl Default for SvrgParams {
    fn default() -> Self {
        SvrgParams { epochs: 2, batch: 1, lr: None, seed: 0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct SvrgStats {
    pub epochs_run: usize,
    pub lr_used: f64,
    /// full-gradient (anchor) passes — one per epoch
    pub full_grad_passes: usize,
}

/// Estimate L = λ + l''_max · σ_max(XᵀX) by power iteration on XᵀX.
/// σ_max here is the largest *eigenvalue* (sum over all rows), which is
/// the Lipschitz constant of w ↦ ∇Σᵢ l(w·xᵢ) up to the l'' bound.
/// On a support-compact shard matrix the iterate buffers are
/// O(|support|); the spectrum (and hence the estimate) is identical to
/// the global-column matrix since untouched columns contribute nothing.
pub fn lipschitz_estimate(x: &Csr, dd_max: f64, lam: f64, iters: usize) -> f64 {
    let d = x.n_cols;
    let n = x.n_rows();
    if n == 0 || x.nnz() == 0 {
        return lam.max(f64::MIN_POSITIVE);
    }
    let mut v = vec![0.0f64; d];
    // deterministic start touching every used column
    for &j in &x.indices {
        v[j as usize] = 1.0;
    }
    let norm0 = dense::norm(&v);
    dense::scale(&mut v, 1.0 / norm0.max(f64::MIN_POSITIVE));
    let mut z = vec![0.0; n];
    // §Perf: one buffer swapped across power iterations — the
    // per-iteration `vnew` allocation sat on the solve hot path
    let mut vnew = vec![0.0f64; d];
    let mut sigma = 0.0;
    for _ in 0..iters {
        x.matvec(&v, &mut z);
        vnew.iter_mut().for_each(|t| *t = 0.0);
        x.tmatvec(&z, &mut vnew);
        sigma = dense::norm(&vnew);
        if sigma <= f64::MIN_POSITIVE {
            break;
        }
        dense::scale(&mut vnew, 1.0 / sigma);
        std::mem::swap(&mut v, &mut vnew);
    }
    lam + dd_max * sigma
}

/// Reusable SVRG working set — owned per node by the cluster's scratch
/// pool so steady-state inner solves allocate nothing. Every buffer is
/// O(dim) of the *solve space* (|support| + tail on the compact path),
/// never O(d_global).
#[derive(Clone, Debug, Default)]
pub struct SvrgScratch {
    mu: Vec<f64>,
    z0: Vec<f64>,
    anchor: Vec<f64>,
    bvec: Vec<f64>,
    last: Vec<u32>,
    geom: Vec<(f64, f64)>,
    order: Vec<u32>,
    updates: Vec<(usize, f64)>,
}

/// Run `params.epochs` SVRG epochs on f̂_p starting from `w0`
/// (Algorithm 1 sets w0 = wʳ). Returns the output point w_p.
///
/// Hot-path implementation (EXPERIMENTS.md §Perf): the per-step update
///
///   w ← w − η(μ + λ(w − w₀) + (n/b)·Σ_B rᵢxᵢ)
///     = a·w + b_vec − η(n/b)·Σ_B rᵢxᵢ,   a = 1 − ηλ,  b = η(λw₀ − μ)
///
/// has an *affine* dense part that is constant within an epoch, so
/// coordinates untouched by the sparse term are fast-forwarded lazily:
/// after k silent steps, w_j ← aᵏw_j + ((1 − aᵏ)/(1 − a))·b_j. Epoch
/// cost drops from O(steps·dim) to O(nnz + dim); on the support-compact
/// path dim = |support| + tail, so the whole solve runs in the shard's
/// own coordinate space (the compact tail coordinates are never touched
/// by a row and ride the same lazy fast-forward).
pub fn svrg_epochs<O: TiltedShard>(
    approx: &O,
    w0: &[f64],
    params: &SvrgParams,
) -> (Vec<f64>, SvrgStats) {
    svrg_epochs_with(approx, w0, params, &mut SvrgScratch::default())
}

/// [`svrg_epochs`] with an explicit reusable working set — the cluster
/// scratch pool hands each node its own, so steady-state outer
/// iterations allocate only the returned iterate.
pub fn svrg_epochs_with<O: TiltedShard>(
    approx: &O,
    w0: &[f64],
    params: &SvrgParams,
    scratch: &mut SvrgScratch,
) -> (Vec<f64>, SvrgStats) {
    let x = approx.shard_x();
    let n = x.n_rows();
    let d = approx.dim();
    debug_assert_eq!(w0.len(), d);
    if n == 0 || params.epochs == 0 {
        return (w0.to_vec(), SvrgStats::default());
    }
    let lam = approx.l2();
    let loss = approx.loss_kind();
    let y = approx.shard_y();
    let lr = params.lr.unwrap_or_else(|| {
        1.0 / lipschitz_estimate(x, loss.dd_max(), lam, 12)
    });
    let batch = params.batch.clamp(1, n);
    let mut rng = Rng::new(params.seed);
    let mut w = w0.to_vec();
    let SvrgScratch { mu, z0, anchor, bvec, last, geom, order, updates } =
        scratch;
    mu.clear();
    mu.resize(d, 0.0);
    z0.clear();
    z0.resize(n, 0.0);
    anchor.clear();
    anchor.resize(d, 0.0);
    // lazy bookkeeping: b_j and the step index of w_j's last update
    bvec.clear();
    bvec.resize(d, 0.0);
    last.clear();
    last.resize(d, 0u32);
    let mut stats = SvrgStats { epochs_run: 0, lr_used: lr, full_grad_passes: 0 };

    let a = 1.0 - lr * lam;
    debug_assert!(a > 0.0, "lr·λ ≥ 1: unstable epoch (lr {lr})");
    // §Perf: precompute (aᵏ, (1−aᵏ)/(1−a)) for every possible lag —
    // the per-nnz a.powi(lag) was the epoch's top cost (~40% of
    // wall); a table lookup replaces it. λ=0 ⇒ a=1 ⇒ (1, k).
    let max_steps = n / batch + 2;
    geom.clear();
    geom.reserve(max_steps);
    {
        let (mut ak, mut s) = (1.0f64, 0.0f64);
        for _ in 0..max_steps {
            geom.push((ak, s));
            s += ak;
            ak *= a;
        }
    }
    let geom_at = |k: u32| -> (f64, f64) { geom[k as usize] };

    for _ in 0..params.epochs {
        // --- anchor pass: μ = ∇f̂_p(w) and margins z0 = X·w ---
        anchor.copy_from_slice(&w);
        approx.grad(anchor, mu);
        x.matvec(anchor, z0);
        stats.full_grad_passes += 1;

        for j in 0..d {
            bvec[j] = lr * (lam * anchor[j] - mu[j]);
        }
        last.iter_mut().for_each(|t| *t = 0);

        order.clear();
        order.extend(0..n as u32);
        rng.shuffle(order);
        let scale = n as f64 / batch as f64;
        let nb = (n / batch).max(1);
        let mut step = 0u32; // steps completed so far this epoch
        for k in 0..nb {
            let lo = k * batch;
            let hi = (lo + batch).min(n);
            // ---- compute residuals at CURRENT w (after fast-forward) ----
            // then apply: one dense-affine step + the sparse scatter
            updates.clear();
            for &oi in &order[lo..hi] {
                let i = oi as usize;
                let (cols, vals) = x.row(i);
                let mut zi = 0.0;
                for (c, v) in cols.iter().zip(vals) {
                    let j = *c as usize;
                    let lag = step - last[j];
                    if lag > 0 {
                        let (ak, s) = geom_at(lag);
                        w[j] = ak * w[j] + s * bvec[j];
                        last[j] = step;
                    }
                    zi += *v as f64 * w[j];
                }
                let r = loss.deriv(zi, y[i]) - loss.deriv(z0[i], y[i]);
                if r != 0.0 {
                    for (c, v) in cols.iter().zip(vals) {
                        updates.push((*c as usize, r * *v as f64));
                    }
                }
            }
            // the affine step happens "now": touched coordinates take
            // it explicitly (they are already current at `step` from
            // the residual pass), everyone else catches up lazily.
            // Duplicate j (several examples sharing a feature in one
            // minibatch) are merged so the affine part applies once.
            updates.sort_unstable_by_key(|&(j, _)| j);
            let mut m = 0;
            while m < updates.len() {
                let (j, mut ru) = updates[m];
                m += 1;
                while m < updates.len() && updates[m].0 == j {
                    ru += updates[m].1;
                    m += 1;
                }
                w[j] = a * w[j] + bvec[j] - lr * scale * ru;
                last[j] = step + 1;
            }
            step += 1;
        }
        // ---- epoch flush: fast-forward every coordinate to `step` ----
        for j in 0..d {
            let lag = step - last[j];
            if lag > 0 {
                let (ak, s) = geom_at(lag);
                w[j] = ak * w[j] + s * bvec[j];
            }
        }
        stats.epochs_run += 1;
    }
    (w, stats)
}

/// Straightforward O(steps·dim) reference implementation (no lazy
/// fast-forward) — kept for the equivalence tests and as documentation
/// of the update rule.
pub fn svrg_epochs_dense<O: TiltedShard>(
    approx: &O,
    w0: &[f64],
    params: &SvrgParams,
) -> (Vec<f64>, SvrgStats) {
    let x = approx.shard_x();
    let n = x.n_rows();
    let d = approx.dim();
    if n == 0 || params.epochs == 0 {
        return (w0.to_vec(), SvrgStats::default());
    }
    let lam = approx.l2();
    let loss = approx.loss_kind();
    let y = approx.shard_y();
    let lr = params.lr.unwrap_or_else(|| {
        1.0 / lipschitz_estimate(x, loss.dd_max(), lam, 12)
    });
    let batch = params.batch.clamp(1, n);
    let mut rng = Rng::new(params.seed);
    let mut w = w0.to_vec();
    let mut mu = vec![0.0; d];
    let mut z0 = vec![0.0; n];
    let mut anchor = vec![0.0; d];
    let mut stats = SvrgStats { epochs_run: 0, lr_used: lr, full_grad_passes: 0 };
    for _ in 0..params.epochs {
        anchor.copy_from_slice(&w);
        approx.grad(&anchor, &mut mu);
        x.matvec(&anchor, &mut z0);
        stats.full_grad_passes += 1;
        let order = rng.permutation(n);
        let scale = n as f64 / batch as f64;
        let nb = (n / batch).max(1);
        for k in 0..nb {
            let lo = k * batch;
            let hi = (lo + batch).min(n);
            // residuals at current w first (matching the lazy path)
            let rs: Vec<(usize, f64)> = order[lo..hi]
                .iter()
                .map(|&oi| {
                    let i = oi as usize;
                    let zi = x.row_dot(i, &w);
                    (i, loss.deriv(zi, y[i]) - loss.deriv(z0[i], y[i]))
                })
                .collect();
            for j in 0..d {
                w[j] -= lr * (mu[j] + lam * (w[j] - anchor[j]));
            }
            for (i, r) in rs {
                if r != 0.0 {
                    x.add_row_scaled(i, -lr * scale * r, &mut w);
                }
            }
        }
        stats.epochs_run += 1;
    }
    (w, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::loss::LossKind;
    use crate::objective::{shard_loss_grad, LocalApprox};
    use crate::opt::tron::{self, TronParams};

    #[test]
    fn lazy_matches_dense_reference() {
        for (batch, seed) in [(1usize, 1u64), (4, 2), (16, 3), (100, 4)] {
            let data = SynthConfig {
                n_examples: 120,
                n_features: 50,
                nnz_per_example: 6,
                ..SynthConfig::default()
            }
            .generate(seed);
            let dim = data.n_features();
            let w_r: Vec<f64> = (0..dim).map(|j| (j as f64 * 0.1).sin() * 0.1).collect();
            let lam = 0.3;
            let loss = LossKind::Logistic;
            let mut grad_lp = vec![0.0; dim];
            shard_loss_grad(&data.x, &data.y, &w_r, loss, &mut grad_lp, None);
            let mut g_r = grad_lp.clone();
            dense::axpy(lam, &w_r, &mut g_r);
            // perturb to exercise a nonzero tilt
            g_r[0] += 0.5;
            let approx =
                LocalApprox::new(&data.x, &data.y, loss, lam, &w_r, &g_r, &grad_lp);
            let params = SvrgParams { epochs: 3, batch, lr: None, seed: 7 };
            let (w_lazy, _) = svrg_epochs(&approx, &w_r, &params);
            let (w_dense, _) = svrg_epochs_dense(&approx, &w_r, &params);
            let err = dense::max_abs_diff(&w_lazy, &w_dense);
            assert!(
                err < 1e-10,
                "batch={batch}: lazy vs dense deviation {err}"
            );
        }
    }

    fn make_approx<'a>(
        d: &'a crate::data::dataset::Dataset,
        w_r: &[f64],
        lam: f64,
        loss: LossKind,
    ) -> LocalApprox<'a> {
        // single-shard setting: g_r is the *true* global gradient of
        // this shard's regularized risk, so tilt = 0; heterogeneous
        // tilts are exercised in the algo::fs tests.
        let dim = d.n_features();
        let mut grad_lp = vec![0.0; dim];
        shard_loss_grad(&d.x, &d.y, w_r, loss, &mut grad_lp, None);
        let mut g_r = grad_lp.clone();
        dense::axpy(lam, w_r, &mut g_r);
        LocalApprox::new(&d.x, &d.y, loss, lam, w_r, &g_r, &grad_lp)
    }

    #[test]
    fn lipschitz_estimate_bounds_rayleigh_quotients() {
        let d = SynthConfig {
            n_examples: 80,
            n_features: 25,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(1);
        let lam = 0.1;
        let lhat = lipschitz_estimate(&d.x, 1.0, lam, 30);
        // check v̂ᵀ XᵀX v̂ ≤ σ̂ for a few random unit vectors
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let v: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
            let vn = dense::norm(&v);
            let mut z = vec![0.0; 80];
            d.x.matvec(&v, &mut z);
            let quad = dense::norm_sq(&z) / (vn * vn);
            assert!(
                quad <= (lhat - lam) * 1.001 + 1e-9,
                "rayleigh {quad} > estimate {}",
                lhat - lam
            );
        }
    }

    #[test]
    fn epoch_descends_fhat() {
        let d = SynthConfig {
            n_examples: 200,
            n_features: 40,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(3);
        let w_r = vec![0.0; 40];
        let approx = make_approx(&d, &w_r, 0.5, LossKind::Logistic);
        let (w1, stats) = svrg_epochs(
            &approx,
            &w_r,
            &SvrgParams { epochs: 1, batch: 32, lr: None, seed: 4 },
        );
        assert_eq!(stats.epochs_run, 1);
        assert!(approx.value(&w1) < approx.value(&w_r));
    }

    #[test]
    fn strong_convergence_contracts_distance_to_minimizer() {
        // the Theorem-2 hypothesis: E‖w_s − ŵ*‖² shrinks geometrically.
        // deterministic proxy: distance after s epochs strictly shrinks.
        let d = SynthConfig {
            n_examples: 150,
            n_features: 30,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(5);
        let w_r = vec![0.1; 30];
        let lam = 1.0;
        let approx = make_approx(&d, &w_r, lam, LossKind::Logistic);
        // ground-truth minimizer of f̂_p via TRON
        let wstar = tron::minimize(&approx, &w_r, &TronParams {
            eps: 1e-12,
            ..Default::default()
        })
        .w;
        let mut dists = vec![dense::norm(&dense::sub(&w_r, &wstar))];
        for s in [1usize, 3, 6, 10] {
            let (ws, _) = svrg_epochs(
                &approx,
                &w_r,
                &SvrgParams { epochs: s, batch: 16, lr: None, seed: 6 },
            );
            dists.push(dense::norm(&dense::sub(&ws, &wstar)));
        }
        for k in 1..dists.len() {
            assert!(
                dists[k] < dists[k - 1],
                "no contraction: {dists:?}"
            );
        }
        // 10 epochs should get close
        assert!(dists.last().unwrap() / dists[0] < 0.2, "{dists:?}");
    }

    #[test]
    fn direction_aligns_with_negative_gradient_under_tilt() {
        // two heterogeneous shards; node 0's tilted optimization from
        // w_r must produce a descent direction of the *global* f
        // (paper: d_p descent ⟺ f̂_p(w_p) < f̂_p(w_r))
        let data = SynthConfig {
            n_examples: 300,
            n_features: 35,
            nnz_per_example: 6,
            skew: 2.0,
            ..SynthConfig::default()
        }
        .generate(7);
        let rows0: Vec<usize> = (0..150).collect();
        let rows1: Vec<usize> = (150..300).collect();
        let d0 = data.take(&rows0);
        let d1 = data.take(&rows1);
        let dim = data.n_features();
        let lam = 0.5;
        let loss = LossKind::Logistic;
        let mut rng = Rng::new(8);
        let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.05).collect();
        // global gradient
        let mut g_r = vec![0.0; dim];
        let mut gl0 = vec![0.0; dim];
        let mut gl1 = vec![0.0; dim];
        shard_loss_grad(&d0.x, &d0.y, &w_r, loss, &mut gl0, None);
        shard_loss_grad(&d1.x, &d1.y, &w_r, loss, &mut gl1, None);
        for j in 0..dim {
            g_r[j] = lam * w_r[j] + gl0[j] + gl1[j];
        }
        let approx = LocalApprox::new(&d0.x, &d0.y, loss, lam, &w_r, &g_r, &gl0);
        let (w_p, _) = svrg_epochs(
            &approx,
            &w_r,
            &SvrgParams { epochs: 2, batch: 16, lr: None, seed: 9 },
        );
        // descent of f̂_p...
        assert!(approx.value(&w_p) < approx.value(&w_r));
        // ...and therefore d_p is a global descent direction
        let d_p = dense::sub(&w_p, &w_r);
        assert!(dense::dot(&d_p, &g_r) < 0.0);
    }

    #[test]
    fn zero_epochs_is_identity() {
        let d = SynthConfig::small().generate(10);
        let dim = d.n_features();
        let w_r = vec![0.3; dim];
        let approx = make_approx(&d, &w_r, 0.2, LossKind::LeastSquares);
        let (w, stats) = svrg_epochs(
            &approx,
            &w_r,
            &SvrgParams { epochs: 0, ..Default::default() },
        );
        assert_eq!(w, w_r);
        assert_eq!(stats.epochs_run, 0);
    }
}
