//! L-BFGS with strong-Wolfe line search — the core optimizer of the
//! terascale system [8] that SQM derives from, and an alternative inner
//! solver for step 5 (paper §Discussion (b)).

use crate::linalg::dense;
use crate::objective::Objective;
use crate::opt::linesearch::{strong_wolfe, WolfeParams};
use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct LbfgsParams {
    pub memory: usize,
    /// relative gradient stop ‖g‖ ≤ eps·max(1, ‖g⁰‖)
    pub eps: f64,
    pub max_iter: usize,
    pub wolfe: WolfeParams,
}

impl Default for LbfgsParams {
    fn default() -> Self {
        LbfgsParams {
            memory: 10,
            eps: 1e-10,
            max_iter: 200,
            wolfe: WolfeParams::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LbfgsIter {
    pub f: f64,
    pub gnorm: f64,
    /// φ evaluations the line search spent (each costs a full
    /// value+grad pass — the driver charges comm accordingly)
    pub ls_evals: usize,
}

#[derive(Clone, Debug)]
pub struct LbfgsResult {
    pub w: Vec<f64>,
    pub f: f64,
    pub gnorm: f64,
    pub iters: Vec<LbfgsIter>,
    pub converged: bool,
}

/// Two-loop recursion: r = H_k·q given curvature pairs (s, y).
fn two_loop(
    q: &[f64],
    pairs: &VecDeque<(Vec<f64>, Vec<f64>, f64)>, // (s, y, 1/yᵀs)
) -> Vec<f64> {
    let mut r = q.to_vec();
    let mut alphas = Vec::with_capacity(pairs.len());
    for (s, y, rho) in pairs.iter().rev() {
        let a = rho * dense::dot(s, &r);
        dense::axpy(-a, y, &mut r);
        alphas.push(a);
    }
    // initial scaling γ = sᵀy / yᵀy of the newest pair
    if let Some((s, y, _)) = pairs.back() {
        let gamma = dense::dot(s, y) / dense::norm_sq(y).max(f64::MIN_POSITIVE);
        dense::scale(&mut r, gamma);
    }
    for ((s, y, rho), a) in pairs.iter().zip(alphas.iter().rev()) {
        let b = rho * dense::dot(y, &r);
        dense::axpy(a - b, s, &mut r);
    }
    r
}

pub fn minimize(
    obj: &impl Objective,
    w0: &[f64],
    params: &LbfgsParams,
) -> LbfgsResult {
    minimize_cb(obj, w0, params, |_, _| {})
}

/// [`minimize`] with a per-iteration hook `(iter_stats, new w)` for
/// distributed drivers that snapshot comm ledgers between iterations.
pub fn minimize_cb(
    obj: &impl Objective,
    w0: &[f64],
    params: &LbfgsParams,
    mut on_iter: impl FnMut(&LbfgsIter, &[f64]),
) -> LbfgsResult {
    let n = obj.dim();
    let mut w = w0.to_vec();
    let mut g = vec![0.0; n];
    let mut f = obj.value_grad(&w, &mut g);
    let gnorm0 = dense::norm(&g).max(1.0);
    let mut pairs: VecDeque<(Vec<f64>, Vec<f64>, f64)> = VecDeque::new();
    let mut iters = Vec::new();

    for k in 0..params.max_iter {
        let gnorm = dense::norm(&g);
        if gnorm <= params.eps * gnorm0 {
            return LbfgsResult { w, f, gnorm, iters, converged: true };
        }
        let mut dir = two_loop(&g, &pairs);
        dense::scale(&mut dir, -1.0);
        if dense::dot(&dir, &g) >= 0.0 {
            // safeguard: fall back to steepest descent
            dir = g.iter().map(|x| -x).collect();
            pairs.clear();
        }
        // line search on φ(t) = f(w + t·dir)
        let mut g_trial = vec![0.0; n];
        let mut w_trial = vec![0.0; n];
        let t_init = if k == 0 { (1.0 / gnorm).min(1.0) } else { 1.0 };
        let ls = strong_wolfe(
            |t| {
                for j in 0..n {
                    w_trial[j] = w[j] + t * dir[j];
                }
                let v = obj.value_grad(&w_trial, &mut g_trial);
                (v, dense::dot(&g_trial, &dir))
            },
            &WolfeParams { t_init, ..params.wolfe },
        );
        let ls = match ls {
            Ok(r) => r,
            Err(_) => break,
        };
        if ls.t <= 0.0 || !ls.phi_t.is_finite() {
            break;
        }
        // w_trial/g_trial hold the *last evaluated* t, which the Wolfe
        // search guarantees is the accepted one.
        let w_new: Vec<f64> =
            (0..n).map(|j| w[j] + ls.t * dir[j]).collect();
        let mut g_new = vec![0.0; n];
        let f_new = obj.value_grad(&w_new, &mut g_new);

        let s: Vec<f64> = dense::sub(&w_new, &w);
        let yv: Vec<f64> = dense::sub(&g_new, &g);
        let ys = dense::dot(&yv, &s);
        if ys > 1e-12 * dense::norm(&yv) * dense::norm(&s) {
            if pairs.len() == params.memory {
                pairs.pop_front();
            }
            pairs.push_back((s, yv, 1.0 / ys));
        }
        let it = LbfgsIter { f, gnorm, ls_evals: ls.evals };
        on_iter(&it, &w_new);
        iters.push(it);
        w = w_new;
        g = g_new;
        f = f_new;
    }
    let gnorm = dense::norm(&g);
    let converged = gnorm <= params.eps * gnorm0;
    LbfgsResult { w, f, gnorm, iters, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::loss::LossKind;
    use crate::objective::RegularizedLoss;
    use crate::opt::tron::{self, TronParams};

    struct Rosenbrock;

    impl Objective for Rosenbrock {
        fn dim(&self) -> usize {
            2
        }
        fn value(&self, w: &[f64]) -> f64 {
            let (x, y) = (w[0], w[1]);
            (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2)
        }
        fn grad(&self, w: &[f64], out: &mut [f64]) {
            let (x, y) = (w[0], w[1]);
            out[0] = -2.0 * (1.0 - x) - 400.0 * x * (y - x * x);
            out[1] = 200.0 * (y - x * x);
        }
    }

    #[test]
    fn rosenbrock_converges() {
        let r = minimize(
            &Rosenbrock,
            &[-1.2, 1.0],
            &LbfgsParams { eps: 1e-8, max_iter: 500, ..Default::default() },
        );
        assert!(r.converged, "gnorm={}", r.gnorm);
        assert!((r.w[0] - 1.0).abs() < 1e-5 && (r.w[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn matches_tron_on_logistic_regression() {
        let d = SynthConfig {
            n_examples: 120,
            n_features: 20,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(3);
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam: 0.3,
        };
        let lb = minimize(&obj, &vec![0.0; 20], &LbfgsParams {
            eps: 1e-7,
            ..Default::default()
        });
        let tr = tron::minimize(&obj, &vec![0.0; 20], &TronParams {
            eps: 1e-7,
            ..Default::default()
        });
        assert!(lb.converged && tr.converged);
        assert!(
            (lb.f - tr.f).abs() < 1e-6 * lb.f.abs().max(1.0),
            "lbfgs {} vs tron {}",
            lb.f,
            tr.f
        );
    }

    #[test]
    fn monotone_decrease() {
        let d = SynthConfig {
            n_examples: 80,
            n_features: 15,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(4);
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::SquaredHinge,
            lam: 0.2,
        };
        let r = minimize(&obj, &vec![0.0; 15], &LbfgsParams::default());
        for k in 1..r.iters.len() {
            assert!(r.iters[k].f <= r.iters[k - 1].f + 1e-12);
        }
    }

    #[test]
    fn reports_line_search_evals() {
        let r = minimize(&Rosenbrock, &[-1.2, 1.0], &LbfgsParams::default());
        assert!(r.iters.iter().all(|it| it.ls_evals >= 1));
    }
}
