//! Strong-Wolfe line search (Nocedal & Wright Alg. 3.5/3.6) and the
//! margin-based 1-D restriction the paper's step 8 evaluates cheaply.
//!
//! Acceptance conditions are exactly the paper's (3)+(4):
//!   Armijo:  φ(t) ≤ φ(0) + α·t·φ'(0)
//!   Wolfe:   φ'(t) ≥ β·φ'(0)
//! with defaults α = 1e-4, β = 0.9 (the paper's recommended values).

use crate::linalg::dense;
use crate::loss::LossKind;

#[derive(Clone, Copy, Debug)]
pub struct WolfeParams {
    pub alpha: f64,
    pub beta: f64,
    pub t_init: f64,
    pub max_evals: usize,
}

impl Default for WolfeParams {
    fn default() -> Self {
        WolfeParams { alpha: 1e-4, beta: 0.9, t_init: 1.0, max_evals: 50 }
    }
}

#[derive(Clone, Debug)]
pub struct LineSearchResult {
    pub t: f64,
    pub phi_t: f64,
    pub dphi_t: f64,
    /// number of φ evaluations — the driver charges one (scalar)
    /// aggregation round per eval
    pub evals: usize,
    /// both Wolfe conditions verified
    pub satisfied: bool,
}

/// Strong-Wolfe search on φ; `eval(t)` returns (φ(t), φ'(t)).
/// Requires φ'(0) < 0 (descent); returns an error otherwise.
pub fn strong_wolfe(
    mut eval: impl FnMut(f64) -> (f64, f64),
    params: &WolfeParams,
) -> Result<LineSearchResult, String> {
    let (phi0, dphi0) = eval(0.0);
    if dphi0 >= 0.0 {
        return Err(format!("not a descent direction: φ'(0) = {dphi0}"));
    }
    let mut evals = 1usize;
    let armijo =
        |t: f64, phi: f64| phi <= phi0 + params.alpha * t * dphi0;
    let wolfe = |dphi: f64| dphi >= params.beta * dphi0;

    let mut t_prev = 0.0;
    let mut phi_prev = phi0;
    let mut dphi_prev = dphi0;
    let mut t = params.t_init;
    let t_max = 1e10;

    // Bracketing phase (N&W Algorithm 3.5).
    for _ in 0..params.max_evals {
        let (phi_t, dphi_t) = eval(t);
        evals += 1;
        if !armijo(t, phi_t) || (phi_t >= phi_prev && evals > 2) {
            return zoom(
                &mut eval, phi0, dphi0, t_prev, phi_prev, dphi_prev, t,
                phi_t, dphi_t, params, &mut evals,
            );
        }
        if wolfe(dphi_t) {
            return Ok(LineSearchResult {
                t, phi_t, dphi_t, evals, satisfied: true,
            });
        }
        if dphi_t >= 0.0 {
            return zoom(
                &mut eval, phi0, dphi0, t, phi_t, dphi_t, t_prev, phi_prev,
                dphi_prev, params, &mut evals,
            );
        }
        t_prev = t;
        phi_prev = phi_t;
        dphi_prev = dphi_t;
        t = (2.0 * t).min(t_max);
    }
    Err(format!("line search failed after {evals} evaluations"))
}

/// Zoom phase (N&W Algorithm 3.6): lo satisfies Armijo, the interval
/// [lo, hi] brackets a Wolfe point. Cubic interpolation with bisection
/// fallback.
#[allow(clippy::too_many_arguments)]
fn zoom(
    eval: &mut impl FnMut(f64) -> (f64, f64),
    phi0: f64,
    dphi0: f64,
    mut t_lo: f64,
    mut phi_lo: f64,
    mut dphi_lo: f64,
    mut t_hi: f64,
    mut phi_hi: f64,
    mut _dphi_hi: f64,
    params: &WolfeParams,
    evals: &mut usize,
) -> Result<LineSearchResult, String> {
    let armijo =
        |t: f64, phi: f64| phi <= phi0 + params.alpha * t * dphi0;
    let wolfe = |dphi: f64| dphi >= params.beta * dphi0;
    for _ in 0..params.max_evals {
        // cubic minimizer of the (lo, hi) Hermite data; fall back to
        // bisection when it lands outside the safeguarded interior
        let t = {
            let d1 = dphi_lo + _dphi_hi
                - 3.0 * (phi_lo - phi_hi) / (t_lo - t_hi);
            let disc = d1 * d1 - dphi_lo * _dphi_hi;
            let mut cand = if disc >= 0.0 {
                let d2 = disc.sqrt() * (t_hi - t_lo).signum();
                t_hi
                    - (t_hi - t_lo) * (_dphi_hi + d2 - d1)
                        / (_dphi_hi - dphi_lo + 2.0 * d2)
            } else {
                f64::NAN
            };
            let (a, b) = if t_lo < t_hi { (t_lo, t_hi) } else { (t_hi, t_lo) };
            let margin = 0.1 * (b - a);
            if !cand.is_finite() || cand < a + margin || cand > b - margin {
                cand = 0.5 * (t_lo + t_hi);
            }
            cand
        };
        let (phi_t, dphi_t) = eval(t);
        *evals += 1;
        if !armijo(t, phi_t) || phi_t >= phi_lo {
            t_hi = t;
            phi_hi = phi_t;
            _dphi_hi = dphi_t;
        } else {
            if wolfe(dphi_t) {
                return Ok(LineSearchResult {
                    t, phi_t, dphi_t, evals: *evals, satisfied: true,
                });
            }
            if dphi_t * (t_hi - t_lo) >= 0.0 {
                t_hi = t_lo;
                phi_hi = phi_lo;
                _dphi_hi = dphi_lo;
            }
            t_lo = t;
            phi_lo = phi_t;
            dphi_lo = dphi_t;
        }
        if (t_hi - t_lo).abs() < 1e-16 * t_lo.abs().max(1.0) {
            break;
        }
    }
    // Interval collapsed: return the best Armijo point we hold. This is
    // the standard safeguard (e.g. at a kink of squared hinge where φ'
    // jumps); Armijo alone still guarantees sufficient decrease.
    Ok(LineSearchResult {
        t: t_lo,
        phi_t: phi_lo,
        dphi_t: dphi_lo,
        evals: *evals,
        satisfied: wolfe(dphi_lo),
    })
}

/// The paper's cheap distributed line search: with by-products
/// z = X·w and dz = X·d in hand, φ(t) and φ'(t) need only elementwise
/// passes over (z, dz) plus three scalars for the λ-term:
///
///   φ(t)  = (λ/2)(w·w + 2t w·d + t² d·d) + Σᵢ l(zᵢ + t·dzᵢ, yᵢ)
///   φ'(t) = λ(w·d + t d·d) + Σᵢ dzᵢ · l'(zᵢ + t·dzᵢ, yᵢ)
///
/// In the cluster this struct lives on each node with its shard's
/// (z, dz, y); the master sums the per-node partials and adds the
/// λ-part (a scalar aggregation per trial t — NOT a size-d pass).
pub struct MarginPhi<'a> {
    pub z: &'a [f64],
    pub dz: &'a [f64],
    pub y: &'a [f64],
    pub loss: LossKind,
}

impl<'a> MarginPhi<'a> {
    /// (Σ l, Σ dz·l') at step t — the node-local partials.
    pub fn partial(&self, t: f64) -> (f64, f64) {
        let mut v = 0.0;
        let mut dv = 0.0;
        for i in 0..self.z.len() {
            let zt = self.z[i] + t * self.dz[i];
            v += self.loss.value(zt, self.y[i]);
            dv += self.dz[i] * self.loss.deriv(zt, self.y[i]);
        }
        (v, dv)
    }
}

/// Master-side composition of [`MarginPhi::partial`] sums with the λ
/// terms. `ww = w·w`, `wd = w·d`, `dd = d·d`.
pub struct PhiLambda {
    pub lam: f64,
    pub ww: f64,
    pub wd: f64,
    pub dd: f64,
}

impl PhiLambda {
    pub fn new(lam: f64, w: &[f64], d: &[f64]) -> PhiLambda {
        PhiLambda {
            lam,
            ww: dense::norm_sq(w),
            wd: dense::dot(w, d),
            dd: dense::norm_sq(d),
        }
    }

    /// Combine loss partials into (φ(t), φ'(t)).
    pub fn compose(&self, t: f64, loss_sum: f64, dloss_sum: f64) -> (f64, f64) {
        let phi = 0.5 * self.lam * (self.ww + 2.0 * t * self.wd + t * t * self.dd)
            + loss_sum;
        let dphi = self.lam * (self.wd + t * self.dd) + dloss_sum;
        (phi, dphi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1-D strongly convex quadratic: φ(t) = (t-3)², φ'(t) = 2(t-3).
    #[test]
    fn quadratic_finds_wolfe_point() {
        let r = strong_wolfe(
            |t| ((t - 3.0) * (t - 3.0), 2.0 * (t - 3.0)),
            &WolfeParams::default(),
        )
        .unwrap();
        assert!(r.satisfied);
        // Wolfe region for this quadratic with β=0.9: t ≥ 0.3·3
        assert!(r.t > 0.3 && r.t < 6.0, "t={}", r.t);
        // conditions hold
        let phi0 = 9.0;
        let dphi0 = -6.0;
        assert!(r.phi_t <= phi0 + 1e-4 * r.t * dphi0);
        assert!(r.dphi_t >= 0.9 * dphi0);
    }

    #[test]
    fn rejects_ascent_direction() {
        assert!(strong_wolfe(
            |t| (t * t + t, 2.0 * t + 1.0),
            &WolfeParams::default()
        )
        .is_err());
    }

    #[test]
    fn handles_far_minimum_via_doubling() {
        // minimum at t = 1000
        let r = strong_wolfe(
            |t| {
                let u = t - 1000.0;
                (u * u, 2.0 * u)
            },
            &WolfeParams::default(),
        )
        .unwrap();
        assert!(r.satisfied);
        // Wolfe region for this quadratic: φ'(t) ≥ 0.9·φ'(0) ⇔ t ≥ 100
        assert!(r.t >= 100.0, "t={}", r.t);
    }

    #[test]
    fn nonconvex_with_multiple_dips() {
        // φ(t) = −sin(t) + t²/10: φ'(0) = −1, several local dips.
        let eval = |t: f64| (-t.sin() + t * t / 10.0, -t.cos() + t / 5.0);
        let r = strong_wolfe(
            &mut { eval },
            &WolfeParams { t_init: 0.5, ..Default::default() },
        )
        .unwrap();
        assert!(r.satisfied);
        let (phi0, dphi0) = (0.0, -1.0);
        assert!(r.phi_t <= phi0 + 1e-4 * r.t * dphi0);
        assert!(r.dphi_t >= 0.9 * dphi0);
    }

    #[test]
    fn margin_phi_matches_direct_evaluation() {
        use crate::data::synth::SynthConfig;
        use crate::objective::{Objective, RegularizedLoss};
        use crate::util::rng::Rng;

        let d = SynthConfig {
            n_examples: 60,
            n_features: 15,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(4);
        let mut rng = Rng::new(1);
        let w: Vec<f64> = (0..15).map(|_| rng.normal() * 0.2).collect();
        let dir: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let lam = 0.2;
        let loss = LossKind::Logistic;

        let mut z = vec![0.0; 60];
        let mut dz = vec![0.0; 60];
        d.x.matvec(&w, &mut z);
        d.x.matvec(&dir, &mut dz);
        let phi = MarginPhi { z: &z, dz: &dz, y: &d.y, loss };
        let lam_part = PhiLambda::new(lam, &w, &dir);

        let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam };
        for &t in &[0.0, 0.1, 0.7, 2.5] {
            let (ls, dls) = phi.partial(t);
            let (phi_t, dphi_t) = lam_part.compose(t, ls, dls);
            // direct: f(w + t d) and ∇f(w+td)·d
            let wt: Vec<f64> = w
                .iter()
                .zip(&dir)
                .map(|(wi, di)| wi + t * di)
                .collect();
            let mut g = vec![0.0; 15];
            let v = obj.value_grad(&wt, &mut g);
            assert!((phi_t - v).abs() < 1e-9, "t={t}");
            assert!(
                (dphi_t - dense::dot(&g, &dir)).abs() < 1e-9,
                "t={t}"
            );
        }
    }

    #[test]
    fn wolfe_on_margin_phi_decreases_objective() {
        use crate::data::synth::SynthConfig;
        use crate::objective::{Objective, RegularizedLoss};

        let d = SynthConfig::small().generate(6);
        let dim = d.n_features();
        let w = vec![0.0; dim];
        let lam = 1.0;
        let loss = LossKind::SquaredHinge;
        let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam };
        let mut g = vec![0.0; dim];
        obj.grad(&w, &mut g);
        let dir: Vec<f64> = g.iter().map(|gi| -gi).collect();

        let mut z = vec![0.0; d.n_examples()];
        let mut dz = vec![0.0; d.n_examples()];
        d.x.matvec(&w, &mut z);
        d.x.matvec(&dir, &mut dz);
        let phi = MarginPhi { z: &z, dz: &dz, y: &d.y, loss };
        let lam_part = PhiLambda::new(lam, &w, &dir);

        let r = strong_wolfe(
            |t| {
                let (ls, dls) = phi.partial(t);
                lam_part.compose(t, ls, dls)
            },
            &WolfeParams::default(),
        )
        .unwrap();
        assert!(r.phi_t < obj.value(&w));
    }
}
