//! TRON — trust-region Newton method (Lin, Weng & Keerthi, JMLR 2008),
//! the core optimizer the paper's SQM baseline uses ("instead of
//! L-BFGS we use the better-performing TRON").
//!
//! Each outer iteration: solve the TR subproblem with Steihaug-CG,
//! take the ratio of actual to predicted reduction, adjust the radius
//! with the LIBLINEAR schedule, accept/reject. The per-iteration stats
//! (CG iterations, evals) are exported so the distributed driver can
//! charge the right number of communication passes (one Hv product =
//! one broadcast + one reduce of a size-d vector).

use crate::linalg::dense;
use crate::objective::Objective;
use crate::opt::cg;

#[derive(Clone, Copy, Debug)]
pub struct TronParams {
    /// relative gradient-norm stop: ‖g‖ ≤ eps·‖g⁰‖
    pub eps: f64,
    /// absolute gradient-norm stop (guards warm starts that begin at
    /// the optimum, where the relative test is self-referential)
    pub eps_abs: f64,
    pub max_iter: usize,
    pub max_cg_iter: usize,
    /// CG forcing tolerance: residual ≤ cg_tol·‖g‖
    pub cg_tol: f64,
}

impl Default for TronParams {
    fn default() -> Self {
        TronParams {
            eps: 1e-10,
            eps_abs: 0.0,
            max_iter: 100,
            max_cg_iter: 250,
            cg_tol: 0.1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TronIter {
    pub f: f64,
    pub gnorm: f64,
    pub cg_iters: usize,
    pub accepted: bool,
    pub delta: f64,
}

#[derive(Clone, Debug)]
pub struct TronResult {
    pub w: Vec<f64>,
    pub f: f64,
    pub gnorm: f64,
    pub iters: Vec<TronIter>,
    pub converged: bool,
}

// LIBLINEAR's radius-update constants.
const ETA0: f64 = 1e-4;
const ETA1: f64 = 0.25;
const ETA2: f64 = 0.75;
const SIGMA1: f64 = 0.25;
const SIGMA2: f64 = 0.5;
const SIGMA3: f64 = 4.0;

pub fn minimize(
    obj: &impl Objective,
    w0: &[f64],
    params: &TronParams,
) -> TronResult {
    minimize_cb(obj, w0, params, |_, _| {})
}

/// [`minimize`] with a per-iteration hook `(iter_stats, current w)` —
/// the distributed SQM driver snapshots its comm ledger and evaluates
/// AUPRC from here.
pub fn minimize_cb(
    obj: &impl Objective,
    w0: &[f64],
    params: &TronParams,
    mut on_iter: impl FnMut(&TronIter, &[f64]),
) -> TronResult {
    let n = obj.dim();
    let mut w = w0.to_vec();
    let mut g = vec![0.0; n];
    let mut f = obj.value_grad(&w, &mut g);
    let gnorm0 = dense::norm(&g);
    let mut gnorm = gnorm0;
    let mut delta = gnorm;
    let mut iters = Vec::new();

    if gnorm0 == 0.0 {
        return TronResult { w, f, gnorm, iters, converged: true };
    }

    let mut w_new = vec![0.0; n];
    let mut g_new = vec![0.0; n];
    for _ in 0..params.max_iter {
        if gnorm <= (params.eps * gnorm0).max(params.eps_abs) {
            return TronResult { w, f, gnorm, iters, converged: true };
        }
        let sub = cg::steihaug(
            |v, out| obj.hess_vec(&w, v, out),
            &g,
            delta,
            params.cg_tol,
            params.max_cg_iter,
        );
        let step = sub.x;
        for j in 0..n {
            w_new[j] = w[j] + step[j];
        }
        let f_new = obj.value_grad(&w_new, &mut g_new);
        // predicted reduction from the quadratic model:
        // −(gᵀs + ½ sᵀHs); compute Hs with one more product
        let mut hs = vec![0.0; n];
        obj.hess_vec(&w, &step, &mut hs);
        let gs = dense::dot(&g, &step);
        let pred = -(gs + 0.5 * dense::dot(&step, &hs));
        let actual = f - f_new;

        // LIBLINEAR tron.cpp radius update: a quadratic-interpolation
        // step-scale alpha, then a ratio-bucketed radius adjustment.
        let snorm = dense::norm(&step);
        if iters.is_empty() {
            delta = delta.min(snorm);
        }
        let denom = f_new - f - gs;
        let alpha = if denom <= 0.0 {
            SIGMA3
        } else {
            SIGMA1.max(-0.5 * (gs / denom))
        };
        delta = if actual < ETA0 * pred {
            (alpha.max(SIGMA1) * snorm).min(SIGMA2 * delta)
        } else if actual < ETA1 * pred {
            (SIGMA1 * delta).max((alpha * snorm).min(SIGMA2 * delta))
        } else if actual < ETA2 * pred {
            (SIGMA1 * delta).max((alpha * snorm).min(SIGMA3 * delta))
        } else {
            delta.max((alpha * snorm).min(SIGMA3 * delta))
        };

        let accepted = pred > 0.0 && actual > ETA0 * pred;
        let it = TronIter { f, gnorm, cg_iters: sub.iters, accepted, delta };
        on_iter(&it, if accepted { &w_new } else { &w });
        iters.push(it);
        if accepted {
            std::mem::swap(&mut w, &mut w_new);
            std::mem::swap(&mut g, &mut g_new);
            f = f_new;
            gnorm = dense::norm(&g);
        }
        if delta < 1e-300 || !f.is_finite() {
            break;
        }
    }
    let converged = gnorm <= (params.eps * gnorm0).max(params.eps_abs);
    TronResult { w, f, gnorm, iters, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::loss::LossKind;
    use crate::objective::RegularizedLoss;

    /// Strongly convex quadratic with known minimizer:
    /// f(w) = ½ (w−c)ᵀ A (w−c), A = diag(1..n)
    struct Quad {
        c: Vec<f64>,
    }

    impl Objective for Quad {
        fn dim(&self) -> usize {
            self.c.len()
        }
        fn value(&self, w: &[f64]) -> f64 {
            w.iter()
                .zip(&self.c)
                .enumerate()
                .map(|(i, (wi, ci))| 0.5 * (i + 1) as f64 * (wi - ci) * (wi - ci))
                .sum()
        }
        fn grad(&self, w: &[f64], out: &mut [f64]) {
            for i in 0..w.len() {
                out[i] = (i + 1) as f64 * (w[i] - self.c[i]);
            }
        }
        fn hess_vec(&self, _w: &[f64], v: &[f64], out: &mut [f64]) {
            for i in 0..v.len() {
                out[i] = (i + 1) as f64 * v[i];
            }
        }
    }

    #[test]
    fn quadratic_exact() {
        let q = Quad { c: vec![1.0, -2.0, 3.0, 0.5] };
        let r = minimize(&q, &[0.0; 4], &TronParams::default());
        assert!(r.converged);
        assert!(dense::max_abs_diff(&r.w, &q.c) < 1e-6, "{:?}", r.w);
    }

    #[test]
    fn logistic_regression_converges_to_stationary_point() {
        let d = SynthConfig {
            n_examples: 150,
            n_features: 30,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(7);
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam: 0.5,
        };
        let r = minimize(&obj, &vec![0.0; 30], &TronParams {
            eps: 1e-6,
            ..Default::default()
        });
        assert!(r.converged, "gnorm={}", r.gnorm);
        // monotone objective over accepted iterations
        let fs: Vec<f64> = r
            .iters
            .iter()
            .filter(|it| it.accepted)
            .map(|it| it.f)
            .collect();
        for k in 1..fs.len() {
            assert!(fs[k] <= fs[k - 1] + 1e-12);
        }
    }

    #[test]
    fn squared_hinge_converges() {
        let d = SynthConfig {
            n_examples: 120,
            n_features: 25,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(8);
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::SquaredHinge,
            lam: 0.1,
        };
        let r = minimize(&obj, &vec![0.0; 25], &TronParams {
            eps: 1e-6,
            ..Default::default()
        });
        assert!(r.converged, "gnorm={}", r.gnorm);
    }

    #[test]
    fn already_optimal_returns_immediately() {
        let q = Quad { c: vec![0.0; 3] };
        let r = minimize(&q, &[0.0; 3], &TronParams::default());
        assert!(r.converged);
        assert!(r.iters.is_empty());
    }

    #[test]
    fn reports_cg_iteration_counts() {
        let q = Quad { c: vec![2.0; 6] };
        let r = minimize(&q, &[0.0; 6], &TronParams::default());
        assert!(r.iters.iter().map(|i| i.cg_iters).sum::<usize>() > 0);
    }
}
