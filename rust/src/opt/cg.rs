//! Conjugate gradient: a plain SPD solver (tests, diagnostics) and the
//! Steihaug trust-region variant TRON's subproblem needs.

use crate::linalg::dense;

#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual_norm: f64,
    /// Steihaug: stopped on the trust-region boundary
    pub hit_boundary: bool,
    /// encountered a direction of non-positive curvature
    pub neg_curvature: bool,
}

/// Solve A x = b for SPD A given `apply(v, out)` computing out = A·v.
pub fn solve(
    mut apply: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> CgResult {
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec(); // r = b − A·0
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs = dense::norm_sq(&r);
    let stop = tol * tol * dense::norm_sq(b).max(f64::MIN_POSITIVE);
    let mut iters = 0;
    while rs > stop && iters < max_iter {
        apply(&p, &mut ap);
        let pap = dense::dot(&p, &ap);
        if pap <= 0.0 {
            return CgResult {
                x, iters, residual_norm: rs.sqrt(),
                hit_boundary: false, neg_curvature: true,
            };
        }
        let alpha = rs / pap;
        dense::axpy(alpha, &p, &mut x);
        dense::axpy(-alpha, &ap, &mut r);
        let rs_new = dense::norm_sq(&r);
        dense::xpay(&r, rs_new / rs, &mut p);
        rs = rs_new;
        iters += 1;
    }
    CgResult {
        x, iters, residual_norm: rs.sqrt(),
        hit_boundary: false, neg_curvature: false,
    }
}

/// Steihaug-Toint CG: approximately minimize m(p) = gᵀp + ½ pᵀHp
/// subject to ‖p‖ ≤ delta. Stops at the boundary, on negative
/// curvature, or when the residual drops below `tol·‖g‖`.
pub fn steihaug(
    mut apply: impl FnMut(&[f64], &mut [f64]),
    g: &[f64],
    delta: f64,
    tol: f64,
    max_iter: usize,
) -> CgResult {
    let n = g.len();
    let mut p = vec![0.0; n];
    let mut r: Vec<f64> = g.iter().map(|x| -x).collect(); // r = −g − H·0
    let mut d = r.clone();
    let mut hd = vec![0.0; n];
    let gnorm = dense::norm(g);
    let stop = (tol * gnorm).max(f64::MIN_POSITIVE);
    let mut iters = 0;

    /// largest τ ≥ 0 with ‖p + τ d‖ = delta
    fn boundary_tau(p: &[f64], d: &[f64], delta: f64) -> f64 {
        let pp = dense::norm_sq(p);
        let pd = dense::dot(p, d);
        let dd = dense::norm_sq(d).max(f64::MIN_POSITIVE);
        let disc = (pd * pd + dd * (delta * delta - pp)).max(0.0);
        (-pd + disc.sqrt()) / dd
    }

    loop {
        if dense::norm(&r) <= stop || iters >= max_iter {
            return CgResult {
                x: p, iters, residual_norm: dense::norm(&r),
                hit_boundary: false, neg_curvature: false,
            };
        }
        apply(&d, &mut hd);
        let dhd = dense::dot(&d, &hd);
        if dhd <= 0.0 {
            // follow d to the boundary
            let tau = boundary_tau(&p, &d, delta);
            dense::axpy(tau, &d, &mut p);
            return CgResult {
                x: p, iters, residual_norm: dense::norm(&r),
                hit_boundary: true, neg_curvature: true,
            };
        }
        let rs = dense::norm_sq(&r);
        let alpha = rs / dhd;
        // would the step leave the region?
        let pp = dense::norm_sq(&p);
        let pd = dense::dot(&p, &d);
        let dd = dense::norm_sq(&d);
        if pp + 2.0 * alpha * pd + alpha * alpha * dd >= delta * delta {
            let tau = boundary_tau(&p, &d, delta);
            dense::axpy(tau, &d, &mut p);
            return CgResult {
                x: p, iters: iters + 1, residual_norm: dense::norm(&r),
                hit_boundary: true, neg_curvature: false,
            };
        }
        dense::axpy(alpha, &d, &mut p);
        dense::axpy(-alpha, &hd, &mut r);
        let rs_new = dense::norm_sq(&r);
        dense::xpay(&r, rs_new / rs, &mut d);
        iters += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// dense symmetric apply for tests
    fn apply_mat(a: &[Vec<f64>]) -> impl FnMut(&[f64], &mut [f64]) + '_ {
        move |v, out| {
            for (i, row) in a.iter().enumerate() {
                out[i] = dense::dot(row, v);
            }
        }
    }

    fn spd3() -> Vec<Vec<f64>> {
        vec![
            vec![4.0, 1.0, 0.0],
            vec![1.0, 3.0, 1.0],
            vec![0.0, 1.0, 5.0],
        ]
    }

    #[test]
    fn solves_spd_system() {
        let a = spd3();
        let b = vec![1.0, 2.0, 3.0];
        let r = solve(apply_mat(&a), &b, 1e-12, 100);
        let mut ax = vec![0.0; 3];
        apply_mat(&a)(&r.x, &mut ax);
        assert!(dense::max_abs_diff(&ax, &b) < 1e-9);
        assert!(!r.neg_curvature);
        assert!(r.iters <= 3 + 1, "CG must converge in ≤ n iters");
    }

    #[test]
    fn steihaug_interior_matches_newton_step() {
        let a = spd3();
        let g = vec![1.0, -2.0, 0.5];
        // huge radius → unconstrained Newton step −A⁻¹g
        let r = steihaug(apply_mat(&a), &g, 1e6, 1e-12, 100);
        assert!(!r.hit_boundary);
        let minus_g: Vec<f64> = g.iter().map(|x| -x).collect();
        let newton = solve(apply_mat(&a), &minus_g, 1e-12, 100).x;
        assert!(dense::max_abs_diff(&r.x, &newton) < 1e-8);
    }

    #[test]
    fn steihaug_respects_radius() {
        let a = spd3();
        let g = vec![10.0, -20.0, 5.0];
        let delta = 0.1;
        let r = steihaug(apply_mat(&a), &g, delta, 1e-12, 100);
        assert!(r.hit_boundary);
        assert!((dense::norm(&r.x) - delta).abs() < 1e-10);
        // model decreased: gᵀp + ½pᵀHp < 0
        let mut hp = vec![0.0; 3];
        apply_mat(&a)(&r.x, &mut hp);
        let m = dense::dot(&g, &r.x) + 0.5 * dense::dot(&r.x, &hp);
        assert!(m < 0.0);
    }

    #[test]
    fn steihaug_negative_curvature_goes_to_boundary() {
        // indefinite matrix
        let a = vec![
            vec![1.0, 0.0, 0.0],
            vec![0.0, -2.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ];
        let g = vec![0.0, 1.0, 0.0];
        let r = steihaug(apply_mat(&a), &g, 2.0, 1e-10, 100);
        assert!(r.hit_boundary);
        assert!(r.neg_curvature);
        assert!((dense::norm(&r.x) - 2.0).abs() < 1e-10);
    }

    #[test]
    fn zero_gradient_returns_zero_step() {
        let a = spd3();
        let g = vec![0.0; 3];
        let r = steihaug(apply_mat(&a), &g, 1.0, 0.1, 100);
        assert_eq!(r.x, vec![0.0; 3]);
        assert_eq!(r.iters, 0);
    }
}
