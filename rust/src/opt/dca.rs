//! Dual coordinate ascent (Hsieh, Chang, Lin, Keerthi & Sundararajan
//! [4]) — the other example-wise method the introduction names as "much
//! faster than batch gradient-based methods" on a single machine.
//! Implemented for L2-regularized squared hinge and least squares
//! (closed-form coordinate updates) and logistic (Newton steps on the
//! dual coordinate).
//!
//! Solves min_w (λ/2)‖w‖² + Σ l(w·xᵢ, yᵢ) through the dual variables
//! αᵢ with the primal maintained as w = (1/λ) Σ αᵢ yᵢ xᵢ. Used by the
//! `single_machine` bench to reproduce the introduction's motivating
//! claim, and available as a reference solver.

use crate::linalg::Csr;
use crate::loss::LossKind;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DcaParams {
    pub epochs: usize,
    pub seed: u64,
}

impl Default for DcaParams {
    fn default() -> Self {
        DcaParams { epochs: 10, seed: 0 }
    }
}

pub struct DcaResult {
    pub w: Vec<f64>,
    pub alpha: Vec<f64>,
    pub epochs_run: usize,
}

/// Run DCA. Supports `SquaredHinge` (box-free closed form with the
/// 1/2-smoothing as in [4]'s L2-loss SVM), `LeastSquares` (exact
/// coordinate minimization) and `Logistic` (one guarded Newton step per
/// coordinate visit).
pub fn solve(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    params: &DcaParams,
) -> DcaResult {
    let n = x.n_rows();
    let d = x.n_cols;
    let mut w = vec![0.0f64; d];
    let mut alpha = vec![0.0f64; n];
    if n == 0 {
        return DcaResult { w, alpha, epochs_run: 0 };
    }
    let qii: Vec<f64> = x.row_norms_sq(); // ‖xᵢ‖²
    let mut rng = Rng::new(params.seed);
    for _ in 0..params.epochs {
        let order = rng.permutation(n);
        for &oi in &order {
            let i = oi as usize;
            if qii[i] == 0.0 {
                continue;
            }
            let zi = x.row_dot(i, &w);
            // (delta on αᵢ, weight of xᵢ added to λw)
            let (delta, emit) = match loss {
                // L2-SVM dual (squared hinge, sum form): minimize
                // ½αᵀQ̄α − Σα + Σα²/4 over α ≥ 0, Q̄ᵢᵢ = ‖xᵢ‖²/λ.
                // ascent grad = 1 − yᵢzᵢ − αᵢ/2, curvature Q̄ᵢᵢ + ½;
                // w tracks (1/λ)Σ αᵢyᵢxᵢ.
                LossKind::SquaredHinge => {
                    let grad = 1.0 - y[i] * zi - alpha[i] / 2.0;
                    let q = qii[i] / lam + 0.5;
                    let new = (alpha[i] + grad / q).max(0.0);
                    (new - alpha[i], (new - alpha[i]) * y[i])
                }
                // least squares: optimality αᵢ = yᵢ − zᵢ with
                // w = (1/λ)Σ αᵢxᵢ; exact coordinate minimizer
                LossKind::LeastSquares => {
                    let d = (y[i] - zi - alpha[i]) / (qii[i] / lam + 1.0);
                    (d, d)
                }
                // logistic dual: αᵢ ∈ (0,1), optimality αᵢ = σ(−yᵢzᵢ);
                // guarded fixed-point step with curvature damping —
                // practical variant (the tests assert descent, not
                // exact duality)
                LossKind::Logistic => {
                    let target = 1.0 / (1.0 + (y[i] * zi).exp());
                    let step = (target - alpha[i])
                        / (1.0 + qii[i] / (lam * 4.0));
                    let new = (alpha[i] + step).clamp(1e-12, 1.0 - 1e-12);
                    (new - alpha[i], (new - alpha[i]) * y[i])
                }
            };
            if delta != 0.0 {
                alpha[i] += delta;
                x.add_row_scaled(i, emit / lam, &mut w);
            }
        }
    }
    DcaResult { w, alpha, epochs_run: params.epochs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::objective::{Objective, RegularizedLoss};
    use crate::opt::tron::{self, TronParams};

    #[test]
    fn squared_hinge_approaches_primal_optimum() {
        let d = SynthConfig {
            n_examples: 200,
            n_features: 40,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(1);
        let lam = 1.0;
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::SquaredHinge,
            lam,
        };
        let fstar = tron::minimize(&obj, &vec![0.0; 40], &TronParams {
            eps: 1e-12,
            ..Default::default()
        })
        .f;
        let r = solve(
            &d.x,
            &d.y,
            LossKind::SquaredHinge,
            lam,
            &DcaParams { epochs: 200, seed: 2 },
        );
        let gap = (obj.value(&r.w) - fstar) / fstar;
        assert!(gap < 1e-3, "gap {gap}");
    }

    #[test]
    fn least_squares_matches_primal_optimum() {
        let d = SynthConfig {
            n_examples: 150,
            n_features: 25,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(2);
        let lam = 0.7;
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::LeastSquares,
            lam,
        };
        let fstar = tron::minimize(&obj, &vec![0.0; 25], &TronParams {
            eps: 1e-12,
            ..Default::default()
        })
        .f;
        let r = solve(
            &d.x,
            &d.y,
            LossKind::LeastSquares,
            lam,
            &DcaParams { epochs: 300, seed: 3 },
        );
        let gap = (obj.value(&r.w) - fstar) / fstar.abs().max(1.0);
        assert!(gap < 1e-6, "gap {gap}");
    }

    #[test]
    fn logistic_decreases_objective_fast() {
        let d = SynthConfig {
            n_examples: 300,
            n_features: 50,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(3);
        let lam = 0.5;
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam,
        };
        let f0 = obj.value(&vec![0.0; 50]);
        let r3 = solve(&d.x, &d.y, LossKind::Logistic, lam,
                       &DcaParams { epochs: 3, seed: 4 });
        let r30 = solve(&d.x, &d.y, LossKind::Logistic, lam,
                        &DcaParams { epochs: 30, seed: 4 });
        let f3 = obj.value(&r3.w);
        let f30 = obj.value(&r30.w);
        assert!(f3 < f0 && f30 < f3, "{f0} -> {f3} -> {f30}");
    }

    #[test]
    fn empty_problem() {
        let x = Csr::new(4);
        let r = solve(&x, &[], LossKind::SquaredHinge, 1.0,
                      &DcaParams::default());
        assert_eq!(r.w, vec![0.0; 4]);
        assert_eq!(r.epochs_run, 0);
    }
}
