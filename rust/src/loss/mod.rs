//! Point losses l(z, y) on the margin z = w·x.
//!
//! The paper's theory requires continuously differentiable, non-negative,
//! convex losses with Lipschitz-continuous gradient — least squares,
//! logistic and squared hinge qualify; plain hinge does not (it is
//! listed here only behind `LossKind::Hinge` for the non-convex/
//! extension experiments and is rejected by the convex drivers).
//!
//! Mirrors `python/compile/kernels/dloss.py` exactly; the cross-layer
//! agreement is asserted in `rust/tests/integration.rs`.

/// Which loss the objective uses. `dd_max` bounds l''(z) — the constant
/// that enters the Lipschitz estimate L ≤ λ + dd_max·σ_max(XᵀX).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    Logistic,
    SquaredHinge,
    LeastSquares,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        match s {
            "logistic" => Some(LossKind::Logistic),
            "squared_hinge" => Some(LossKind::SquaredHinge),
            "least_squares" => Some(LossKind::LeastSquares),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Logistic => "logistic",
            LossKind::SquaredHinge => "squared_hinge",
            LossKind::LeastSquares => "least_squares",
        }
    }

    /// l(z, y)
    #[inline]
    pub fn value(&self, z: f64, y: f64) -> f64 {
        match self {
            LossKind::Logistic => {
                // log(1 + e^{-yz}), stable for large |yz|
                let m = -y * z;
                if m > 35.0 {
                    m
                } else {
                    m.exp().ln_1p()
                }
            }
            LossKind::SquaredHinge => {
                let t = (1.0 - y * z).max(0.0);
                t * t
            }
            LossKind::LeastSquares => 0.5 * (z - y) * (z - y),
        }
    }

    /// ∂l/∂z
    #[inline]
    pub fn deriv(&self, z: f64, y: f64) -> f64 {
        match self {
            LossKind::Logistic => -y * sigmoid(-y * z),
            LossKind::SquaredHinge => -2.0 * y * (1.0 - y * z).max(0.0),
            LossKind::LeastSquares => z - y,
        }
    }

    /// ∂²l/∂z² (generalized; squared hinge uses the a.e. value).
    #[inline]
    pub fn second_deriv(&self, z: f64, y: f64) -> f64 {
        match self {
            LossKind::Logistic => {
                let s = sigmoid(-y * z);
                s * (1.0 - s)
            }
            LossKind::SquaredHinge => {
                if y * z < 1.0 {
                    2.0
                } else {
                    0.0
                }
            }
            LossKind::LeastSquares => 1.0,
        }
    }

    /// Upper bound on l'' over all (z, y) — enters lr heuristics and the
    /// Lipschitz constant of ∇f.
    #[inline]
    pub fn dd_max(&self) -> f64 {
        match self {
            LossKind::Logistic => 0.25,
            LossKind::SquaredHinge => 2.0,
            LossKind::LeastSquares => 1.0,
        }
    }
}

#[inline]
pub fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

pub const ALL_LOSSES: [LossKind; 3] =
    [LossKind::Logistic, LossKind::SquaredHinge, LossKind::LeastSquares];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivative_matches_finite_difference() {
        let eps = 1e-6;
        for loss in ALL_LOSSES {
            for &z in &[-3.0, -0.5, 0.0, 0.3, 1.0, 4.0] {
                for &y in &[-1.0, 1.0] {
                    let fd = (loss.value(z + eps, y) - loss.value(z - eps, y))
                        / (2.0 * eps);
                    assert!(
                        (loss.deriv(z, y) - fd).abs() < 1e-5,
                        "{loss:?} z={z} y={y}"
                    );
                }
            }
        }
    }

    #[test]
    fn second_derivative_matches_finite_difference() {
        let eps = 1e-5;
        for loss in ALL_LOSSES {
            for &z in &[-2.0f64, -0.4, 0.7, 3.0] {
                for &y in &[-1.0f64, 1.0] {
                    if matches!(loss, LossKind::SquaredHinge)
                        && (y * z - 1.0).abs() < 0.1
                    {
                        continue; // kink in l''
                    }
                    let fd = (loss.deriv(z + eps, y) - loss.deriv(z - eps, y))
                        / (2.0 * eps);
                    assert!(
                        (loss.second_deriv(z, y) - fd).abs() < 1e-4,
                        "{loss:?} z={z} y={y}"
                    );
                }
            }
        }
    }

    #[test]
    fn losses_nonnegative_and_convex_samplewise() {
        let mut prev;
        for loss in ALL_LOSSES {
            // convexity in z along a grid: second differences >= 0
            for &y in &[-1.0, 1.0] {
                prev = None::<(f64, f64)>;
                let mut last_slope = f64::NEG_INFINITY;
                for k in -40..=40 {
                    let z = k as f64 * 0.25;
                    let v = loss.value(z, y);
                    assert!(v >= 0.0);
                    if let Some((pz, pv)) = prev {
                        let slope = (v - pv) / (z - pz);
                        assert!(
                            slope >= last_slope - 1e-9,
                            "{loss:?} nonconvex at z={z}"
                        );
                        last_slope = slope;
                    }
                    prev = Some((z, v));
                }
            }
        }
    }

    #[test]
    fn logistic_stable_at_extremes() {
        let l = LossKind::Logistic;
        assert!(l.value(-1000.0, 1.0).is_finite());
        assert!(l.value(1000.0, -1.0) >= 999.0);
        assert!(l.deriv(-1000.0, 1.0).is_finite());
        assert!((l.deriv(1000.0, 1.0)).abs() < 1e-10);
    }

    #[test]
    fn dd_max_is_a_bound() {
        let mut r = crate::util::rng::Rng::new(2);
        for loss in ALL_LOSSES {
            for _ in 0..1000 {
                let z = r.range(-10.0, 10.0);
                let y = r.sign();
                assert!(loss.second_deriv(z, y) <= loss.dd_max() + 1e-12);
            }
        }
    }

    #[test]
    fn parse_names() {
        for loss in ALL_LOSSES {
            assert_eq!(LossKind::parse(loss.name()), Some(loss));
        }
        assert_eq!(LossKind::parse("hinge"), None);
    }
}
