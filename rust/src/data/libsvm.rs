//! LIBSVM text format: `label idx:val idx:val ...` with 1-based feature
//! indices — the format kdd2010 ships in, so a user with the real file
//! can drop it straight in (`psgd train --data path.libsvm`).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::data::dataset::Dataset;
use crate::linalg::Csr;

/// Parse from a reader. `n_features = 0` means "infer from max index".
pub fn read(
    reader: impl std::io::Read,
    n_features: usize,
) -> Result<Dataset, String> {
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_col = 0u32;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line.map_err(|e| e.to_string())?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .map_err(|e| format!("line {}: bad label: {e}", lineno + 1))?;
        // normalize 0/1 labels to ±1 (some kdd2010 splits use 0/1)
        let label = if label > 0.0 { 1.0 } else { -1.0 };
        let mut row = Vec::new();
        for tok in parts {
            let (idx, val) = tok.split_once(':').ok_or(format!(
                "line {}: expected idx:val, got {tok:?}",
                lineno + 1
            ))?;
            let idx: u32 = idx
                .parse()
                .map_err(|e| format!("line {}: bad index: {e}", lineno + 1))?;
            if idx == 0 {
                return Err(format!("line {}: libsvm indices are 1-based", lineno + 1));
            }
            let val: f32 = val
                .parse()
                .map_err(|e| format!("line {}: bad value: {e}", lineno + 1))?;
            max_col = max_col.max(idx);
            row.push((idx - 1, val));
        }
        rows.push(row);
        labels.push(label);
    }
    let d = if n_features > 0 {
        if (max_col as usize) > n_features {
            return Err(format!(
                "feature index {max_col} exceeds declared dimension {n_features}"
            ));
        }
        n_features
    } else {
        max_col as usize
    };
    Ok(Dataset::new(Csr::from_rows(d.max(1), &rows), labels))
}

pub fn read_file(path: impl AsRef<Path>, n_features: usize) -> Result<Dataset, String> {
    let f = std::fs::File::open(path.as_ref())
        .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
    read(f, n_features)
}

/// Write in libsvm format (1-based indices).
pub fn write(data: &Dataset, mut out: impl Write) -> std::io::Result<()> {
    for i in 0..data.n_examples() {
        let (cols, vals) = data.x.row(i);
        write!(out, "{}", if data.y[i] > 0.0 { "+1" } else { "-1" })?;
        for (c, v) in cols.iter().zip(vals) {
            write!(out, " {}:{}", c + 1, v)?;
        }
        writeln!(out)?;
    }
    Ok(())
}

pub fn write_file(data: &Dataset, path: impl AsRef<Path>) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write(data, &mut f)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "+1 3:0.5 1:1.0\n-1 2:2.0\n\n# comment\n+1 1:1\n";

    #[test]
    fn parses_sample() {
        let d = read(SAMPLE.as_bytes(), 0).unwrap();
        assert_eq!(d.n_examples(), 3);
        assert_eq!(d.n_features(), 3);
        assert_eq!(d.y, vec![1.0, -1.0, 1.0]);
        // row 0 sorted: (0,1.0), (2,0.5)
        assert_eq!(d.x.row(0).0, &[0, 2]);
        assert_eq!(d.x.row(0).1, &[1.0, 0.5]);
    }

    #[test]
    fn roundtrip() {
        let d = read(SAMPLE.as_bytes(), 0).unwrap();
        let mut buf = Vec::new();
        write(&d, &mut buf).unwrap();
        let d2 = read(buf.as_slice(), d.n_features()).unwrap();
        assert_eq!(d.x, d2.x);
        assert_eq!(d.y, d2.y);
    }

    #[test]
    fn zero_one_labels_normalized() {
        let d = read("1 1:1\n0 1:1\n".as_bytes(), 0).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(read("+1 0:1\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_overflowing_declared_dim() {
        assert!(read("+1 5:1\n".as_bytes(), 3).is_err());
    }
}
