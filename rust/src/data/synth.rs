//! kdd2010-shaped synthetic data (DESIGN.md §2 substitution).
//!
//! The real kdd2010 ("bridge to algebra") matrix: 8.41M examples,
//! 20.21M features, ~0.3B nnz (≈35 nnz/example), binary {0,1}-ish
//! values, long-tailed feature frequencies, mildly imbalanced labels.
//! What actually drives the FS-vs-SQM comparison is (a) shard-level
//! gradient diversity, (b) conditioning, (c) sparsity — so the
//! generator controls exactly those:
//!
//! - feature popularity ~ Zipf(alpha): few head features appear in most
//!   rows; a long tail appears once or twice — matching the hashed
//!   n-gram statistics of the real matrix;
//! - labels from a planted sparse `w_true` with margin noise, so AUPRC
//!   has headroom and a meaningful optimum exists;
//! - per-node heterogeneity knob (`skew`): rotates which head features
//!   a region of rows prefers, mimicking the student/session locality
//!   that makes kdd2010 shards disagree (the paper's variance issue
//!   (a) in the introduction).

use crate::data::dataset::Dataset;
use crate::linalg::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n_examples: usize,
    pub n_features: usize,
    /// mean nnz per example (actual count is ±50% uniform)
    pub nnz_per_example: usize,
    /// Zipf exponent for feature popularity (1.0 ≈ web-text-like)
    pub zipf_alpha: f64,
    /// density of the planted true weight vector
    pub w_true_density: f64,
    /// probability a label is flipped against the planted margin
    pub label_noise: f64,
    /// 0 = iid rows; >0 = row blocks prefer different head features,
    /// creating the shard heterogeneity the paper discusses
    pub skew: f64,
}

impl SynthConfig {
    /// Laptop-scale smoke config.
    pub fn small() -> SynthConfig {
        SynthConfig {
            n_examples: 2_000,
            n_features: 5_000,
            nnz_per_example: 20,
            ..SynthConfig::default()
        }
    }

    /// The Figure-1 reproduction scale (fits this box; same *shape*
    /// statistics as kdd2010, scaled down ~40× on examples).
    pub fn kdd_shaped() -> SynthConfig {
        SynthConfig {
            n_examples: 200_000,
            n_features: 500_000,
            nnz_per_example: 35,
            ..SynthConfig::default()
        }
    }

    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        // --- feature popularity CDF (Zipf over a capped head) ---
        // Sampling 20M-entry inverse CDFs is wasteful; features beyond
        // `head` are drawn uniformly (they are the tail anyway).
        let head = self.n_features.min(65_536);
        let mut cdf = Vec::with_capacity(head);
        let mut acc = 0.0;
        for i in 0..head {
            acc += 1.0 / ((i + 1) as f64).powf(self.zipf_alpha);
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        // --- planted truth ---
        let mut w_true = vec![0.0f64; self.n_features];
        let n_active = ((self.n_features as f64) * self.w_true_density)
            .ceil()
            .max(1.0) as usize;
        // put most of the signal on *popular* features (drawn through
        // the same Zipf CDF the rows use) so margins are informative at
        // realistic sparsity; the rest goes on the uniform tail
        for k in 0..n_active {
            let j = if k < (3 * n_active) / 4 {
                Rng::zipf_u01_to_index(rng.uniform(), &cdf)
            } else {
                rng.below(self.n_features)
            };
            w_true[j] = rng.normal() * 2.0;
        }

        let mut rows: Vec<Vec<(u32, f32)>> =
            Vec::with_capacity(self.n_examples);
        let mut labels = Vec::with_capacity(self.n_examples);
        let tail_frac = 0.3; // fraction of nnz drawn from the flat tail
        for i in 0..self.n_examples {
            let target = {
                let lo = self.nnz_per_example / 2;
                let hi = (self.nnz_per_example * 3) / 2;
                lo + rng.below(hi - lo + 1)
            };
            // per-block head rotation: block b shifts its Zipf head by
            // skew*b*sqrt(head); 10 blocks over the row range
            let block = (i * 10) / self.n_examples.max(1);
            let shift = ((self.skew * block as f64)
                * (head as f64).sqrt()) as usize;
            let mut row: Vec<(u32, f32)> = Vec::with_capacity(target);
            for _ in 0..target {
                let j = if self.n_features > head && rng.bernoulli(tail_frac)
                {
                    head + rng.below(self.n_features - head)
                } else {
                    let u = rng.uniform();
                    (Rng::zipf_u01_to_index(u, &cdf) + shift) % head
                };
                row.push((j as u32, 1.0));
            }
            // margin from the planted truth; normalize by sqrt(nnz) so
            // logistic margins stay O(1)
            let mut m = 0.0;
            for &(j, v) in &row {
                m += w_true[j as usize] * v as f64;
            }
            m /= (row.len().max(1) as f64).sqrt();
            let mut y = if m + 0.25 * rng.normal() >= 0.0 { 1.0 } else { -1.0 };
            if rng.bernoulli(self.label_noise) {
                y = -y;
            }
            rows.push(row);
            labels.push(y);
        }
        Dataset::new(Csr::from_rows(self.n_features, &rows), labels)
    }
}

impl Default for SynthConfig {
    fn default() -> SynthConfig {
        SynthConfig {
            n_examples: 10_000,
            n_features: 50_000,
            nnz_per_example: 35,
            zipf_alpha: 1.1,
            w_true_density: 0.01,
            label_noise: 0.05,
            skew: 0.5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_statistics_match_config() {
        let cfg = SynthConfig::small();
        let d = cfg.generate(1);
        assert_eq!(d.n_examples(), cfg.n_examples);
        assert_eq!(d.n_features(), cfg.n_features);
        let mean_nnz = d.nnz() as f64 / d.n_examples() as f64;
        // duplicates merge, so mean can land slightly under the target
        assert!(
            mean_nnz > cfg.nnz_per_example as f64 * 0.6
                && mean_nnz < cfg.nnz_per_example as f64 * 1.4,
            "mean nnz {mean_nnz}"
        );
    }

    #[test]
    fn labels_learnable_not_degenerate() {
        let d = SynthConfig::small().generate(2);
        let p = d.positive_rate();
        assert!(p > 0.15 && p < 0.85, "positive rate {p}");
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = SynthConfig {
            n_examples: 100,
            n_features: 500,
            ..SynthConfig::default()
        };
        let a = cfg.generate(9);
        let b = cfg.generate(9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = cfg.generate(10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn popularity_is_long_tailed() {
        let cfg = SynthConfig {
            n_examples: 3000,
            n_features: 20_000,
            skew: 0.0,
            ..SynthConfig::default()
        };
        let d = cfg.generate(3);
        let mut counts = vec![0usize; cfg.n_features];
        for &j in &d.x.indices {
            counts[j as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 as f64 > d.nnz() as f64 * 0.08,
            "head mass too small: {top10}/{}",
            d.nnz()
        );
        let singletons = counts.iter().filter(|&&c| c <= 2).count();
        assert!(singletons > 100, "no tail: {singletons}");
    }
}
