//! Feature preprocessing for libsvm-style data — the transformations a
//! real kdd2010 pipeline applies before training: L2 row normalization
//! (what [8] uses), TF-IDF weighting for count features, and binary
//! clipping.

use crate::data::dataset::Dataset;
use crate::linalg::Csr;

/// Normalize every row to unit L2 norm (zero rows left untouched).
/// With unit rows, per-example curvature is bounded by l''_max and the
/// auto learning rates become shard-size-only dependent.
pub fn l2_normalize_rows(data: &Dataset) -> Dataset {
    let mut x = Csr::new(data.n_features());
    for i in 0..data.n_examples() {
        let (cols, vals) = data.x.row(i);
        let norm: f64 =
            vals.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let row: Vec<(u32, f32)> = if norm > 0.0 {
            cols.iter()
                .zip(vals)
                .map(|(&c, &v)| (c, (v as f64 / norm) as f32))
                .collect()
        } else {
            Vec::new()
        };
        x.push_row(row);
    }
    Dataset::new(x, data.y.clone())
}

/// Clip every value to {0, 1} presence indicators (kdd2010's features
/// are mostly binary already; this makes synthetic count data match).
pub fn binarize(data: &Dataset) -> Dataset {
    let mut x = Csr::new(data.n_features());
    for i in 0..data.n_examples() {
        let (cols, vals) = data.x.row(i);
        let row: Vec<(u32, f32)> = cols
            .iter()
            .zip(vals)
            .filter(|(_, &v)| v != 0.0)
            .map(|(&c, _)| (c, 1.0))
            .collect();
        x.push_row(row);
    }
    Dataset::new(x, data.y.clone())
}

/// TF-IDF re-weighting: value ← value · ln(n / df(feature)), where df
/// is the number of rows the feature occurs in. Features present in
/// every row get weight 0 (standard smooth-less variant).
pub fn tfidf(data: &Dataset) -> Dataset {
    let n = data.n_examples();
    let mut df = vec![0u32; data.n_features()];
    for i in 0..n {
        let (cols, _) = data.x.row(i);
        for &c in cols {
            df[c as usize] += 1;
        }
    }
    let mut x = Csr::new(data.n_features());
    for i in 0..n {
        let (cols, vals) = data.x.row(i);
        let row: Vec<(u32, f32)> = cols
            .iter()
            .zip(vals)
            .map(|(&c, &v)| {
                let idf = (n as f64 / df[c as usize].max(1) as f64).ln();
                (c, (v as f64 * idf) as f32)
            })
            .filter(|(_, v)| *v != 0.0)
            .collect();
        x.push_row(row);
    }
    Dataset::new(x, data.y.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn sample() -> Dataset {
        SynthConfig {
            n_examples: 80,
            n_features: 60,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(3)
    }

    #[test]
    fn l2_rows_have_unit_norm() {
        let d = l2_normalize_rows(&sample());
        for (i, nsq) in d.x.row_norms_sq().iter().enumerate() {
            if d.x.row(i).0.is_empty() {
                continue;
            }
            assert!((nsq - 1.0).abs() < 1e-6, "row {i}: {nsq}");
        }
        // labels unchanged
        assert_eq!(d.y, sample().y);
    }

    #[test]
    fn binarize_gives_unit_values() {
        let d = binarize(&sample());
        assert!(d.x.values.iter().all(|&v| v == 1.0));
        assert_eq!(d.n_examples(), 80);
    }

    #[test]
    fn tfidf_downweights_ubiquitous_features() {
        // construct: feature 0 in every row, feature 1 in one row
        let x = Csr::from_rows(
            2,
            &[
                vec![(0, 1.0), (1, 1.0)],
                vec![(0, 1.0)],
                vec![(0, 1.0)],
            ],
        );
        let d = Dataset::new(x, vec![1.0, -1.0, 1.0]);
        let t = tfidf(&d);
        // feature 0: idf = ln(3/3) = 0 → dropped entirely
        for i in 0..3 {
            assert!(!t.x.row(i).0.contains(&0), "row {i} kept idf-0 feature");
        }
        // feature 1: idf = ln 3
        let (c, v) = t.x.row(0);
        assert_eq!(c, &[1]);
        assert!((v[0] as f64 - 3.0f64.ln()).abs() < 1e-6);
    }

    #[test]
    fn training_works_after_preprocessing() {
        use crate::algo::fs::{FsConfig, FsDriver};
        use crate::algo::{Driver, StopRule};
        use crate::cluster::{Cluster, CostModel};

        let d = l2_normalize_rows(&sample());
        let mut cluster = Cluster::partition(d, 4, CostModel::free());
        let run = FsDriver::new(FsConfig { lam: 0.3, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(5));
        assert!(run.f.is_finite());
        assert!(run.trace.points.last().unwrap().f <= run.trace.points[0].f);
    }
}
