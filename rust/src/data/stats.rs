//! Dataset summary statistics — what `psgd gen-data --stats` prints and
//! what EXPERIMENTS.md records next to each run.

use crate::data::dataset::Dataset;

#[derive(Clone, Debug, PartialEq)]
pub struct DataStats {
    pub n_examples: usize,
    pub n_features: usize,
    pub nnz: usize,
    pub mean_nnz_per_example: f64,
    pub max_nnz_per_example: usize,
    pub positive_rate: f64,
    /// number of features that never occur
    pub unused_features: usize,
}

impl DataStats {
    pub fn compute(d: &Dataset) -> DataStats {
        let mut used = vec![false; d.n_features()];
        let mut max_row = 0;
        for i in 0..d.n_examples() {
            let (cols, _) = d.x.row(i);
            max_row = max_row.max(cols.len());
            for &c in cols {
                used[c as usize] = true;
            }
        }
        DataStats {
            n_examples: d.n_examples(),
            n_features: d.n_features(),
            nnz: d.nnz(),
            mean_nnz_per_example: d.nnz() as f64 / d.n_examples().max(1) as f64,
            max_nnz_per_example: max_row,
            positive_rate: d.positive_rate(),
            unused_features: used.iter().filter(|&&u| !u).count(),
        }
    }

    pub fn render(&self) -> String {
        format!(
            "examples={} features={} nnz={} mean_nnz/ex={:.1} max_nnz/ex={} pos_rate={:.3} unused_features={}",
            self.n_examples,
            self.n_features,
            self.nnz,
            self.mean_nnz_per_example,
            self.max_nnz_per_example,
            self.positive_rate,
            self.unused_features
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    #[test]
    fn stats_consistent() {
        let d = SynthConfig::small().generate(5);
        let s = DataStats::compute(&d);
        assert_eq!(s.n_examples, d.n_examples());
        assert_eq!(s.nnz, d.nnz());
        assert!(s.mean_nnz_per_example > 1.0);
        assert!(s.max_nnz_per_example >= s.mean_nnz_per_example as usize);
        assert!(s.unused_features < s.n_features);
        assert!(!s.render().is_empty());
    }
}
