//! Example partitioning over P nodes (the I_p of the paper).
//!
//! Two policies:
//! - [`Partition::contiguous`] — block ranges in row order. With the
//!   generator's `skew` knob this produces heterogeneous shards (nodes
//!   see different feature neighborhoods), the regime the paper's
//!   introduction worries about.
//! - [`Partition::shuffled`] — random assignment, the homogeneous/iid
//!   regime.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Partition {
    /// node p owns rows `assignment[p]`
    pub assignment: Vec<Vec<usize>>,
}

impl Partition {
    pub fn contiguous(n_examples: usize, n_nodes: usize) -> Partition {
        assert!(n_nodes > 0 && n_nodes <= n_examples);
        let base = n_examples / n_nodes;
        let extra = n_examples % n_nodes;
        let mut assignment = Vec::with_capacity(n_nodes);
        let mut start = 0;
        for p in 0..n_nodes {
            let len = base + usize::from(p < extra);
            assignment.push((start..start + len).collect());
            start += len;
        }
        Partition { assignment }
    }

    pub fn shuffled(n_examples: usize, n_nodes: usize, seed: u64) -> Partition {
        assert!(n_nodes > 0 && n_nodes <= n_examples);
        let mut rng = Rng::new(seed);
        let mut idx: Vec<usize> = (0..n_examples).collect();
        rng.shuffle(&mut idx);
        let mut part = Partition::contiguous(n_examples, n_nodes);
        for rows in part.assignment.iter_mut() {
            for r in rows.iter_mut() {
                *r = idx[*r];
            }
        }
        part
    }

    pub fn n_nodes(&self) -> usize {
        self.assignment.len()
    }

    /// Invariant: the shards form a disjoint cover of 0..n. Checked by
    /// the property suite for both policies.
    pub fn is_disjoint_cover(&self, n_examples: usize) -> bool {
        let mut seen = vec![false; n_examples];
        let mut count = 0;
        for rows in &self.assignment {
            for &r in rows {
                if r >= n_examples || seen[r] {
                    return false;
                }
                seen[r] = true;
                count += 1;
            }
        }
        count == n_examples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_covers_with_balanced_sizes() {
        let p = Partition::contiguous(103, 10);
        assert!(p.is_disjoint_cover(103));
        let sizes: Vec<usize> = p.assignment.iter().map(|a| a.len()).collect();
        assert!(sizes.iter().all(|&s| s == 10 || s == 11));
        assert_eq!(sizes.iter().sum::<usize>(), 103);
    }

    #[test]
    fn shuffled_covers_and_differs_from_contiguous() {
        let p = Partition::shuffled(200, 7, 1);
        assert!(p.is_disjoint_cover(200));
        let c = Partition::contiguous(200, 7);
        assert_ne!(p.assignment, c.assignment);
    }

    #[test]
    fn shuffled_deterministic_in_seed() {
        assert_eq!(
            Partition::shuffled(50, 5, 3).assignment,
            Partition::shuffled(50, 5, 3).assignment
        );
    }

    #[test]
    #[should_panic]
    fn more_nodes_than_examples_rejected() {
        Partition::contiguous(3, 5);
    }
}
