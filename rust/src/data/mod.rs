//! Dataset substrate: storage ([`dataset`]), libsvm-format I/O
//! ([`libsvm`]), the kdd2010-shaped synthetic generator ([`synth`]) and
//! the example partitioner ([`partition`]).

pub mod dataset;
pub mod libsvm;
pub mod partition;
pub mod scale;
pub mod stats;
pub mod synth;
