//! A labeled sparse dataset: CSR features + ±1 labels.

use crate::linalg::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub x: Csr,
    /// labels in {−1, +1}
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(x: Csr, y: Vec<f64>) -> Dataset {
        assert_eq!(x.n_rows(), y.len(), "feature/label count mismatch");
        assert!(
            y.iter().all(|&v| v == 1.0 || v == -1.0),
            "labels must be ±1"
        );
        Dataset { x, y }
    }

    pub fn n_examples(&self) -> usize {
        self.y.len()
    }

    pub fn n_features(&self) -> usize {
        self.x.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Subset by row index (keeps order).
    pub fn take(&self, rows: &[usize]) -> Dataset {
        Dataset {
            x: self.x.take_rows(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Shuffled train/test split; `train_frac` in (0, 1].
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!(train_frac > 0.0 && train_frac <= 1.0);
        let mut rng = Rng::new(seed);
        let mut idx: Vec<usize> = (0..self.n_examples()).collect();
        rng.shuffle(&mut idx);
        let cut = ((self.n_examples() as f64) * train_frac).round() as usize;
        let cut = cut.clamp(1, self.n_examples());
        (self.take(&idx[..cut]), self.take(&idx[cut..]))
    }

    /// Fraction of +1 labels.
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().filter(|&&v| v > 0.0).count() as f64
            / self.n_examples().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Csr;

    fn tiny() -> Dataset {
        let x = Csr::from_rows(
            2,
            &[
                vec![(0, 1.0)],
                vec![(1, 1.0)],
                vec![(0, 1.0), (1, 1.0)],
                vec![],
            ],
        );
        Dataset::new(x, vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn split_partitions_examples() {
        let d = tiny();
        let (tr, te) = d.split(0.5, 3);
        assert_eq!(tr.n_examples() + te.n_examples(), 4);
        assert_eq!(tr.n_examples(), 2);
        assert_eq!(tr.n_features(), 2);
    }

    #[test]
    fn positive_rate() {
        assert_eq!(tiny().positive_rate(), 0.5);
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        Dataset::new(Csr::from_rows(1, &[vec![(0, 1.0)]]), vec![0.5]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn rejects_length_mismatch() {
        Dataset::new(Csr::from_rows(1, &[vec![(0, 1.0)]]), vec![1.0, -1.0]);
    }
}
