//! `psgd` — the launcher. Subcommands:
//!
//! ```text
//! psgd gen-data  --out data.libsvm --examples 200000 --features 500000
//! psgd train     --method fs --nodes 25 --epochs 2 --lambda 1e-3 ...
//! psgd figure1   --nodes 25 --out-dir results/
//! psgd info      [--artifacts artifacts/]
//! ```
//!
//! `train` accepts either `--data file.libsvm` or synthetic-generator
//! knobs, and either CLI flags or `--config exp.toml` (CLI wins).

use psgd::algo::adapt::{Asynchrony, Quorum, TuneBounds};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::autoswitch::{AutoSwitchConfig, AutoSwitchDriver};
use psgd::algo::fs::{FsConfig, FsDriver, InnerSolver, MasterMode};
use psgd::algo::hybrid::{HybridConfig, HybridDriver};
use psgd::algo::param_mix::{ParamMixConfig, ParamMixDriver};
use psgd::algo::safeguard::Safeguard;
use psgd::algo::sqm::{CoreOpt, SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{
    Cluster, CostModel, FaultPlan, LinkFaultPlan, LinkProfile, NodeProfile,
};
use psgd::data::dataset::Dataset;
use psgd::data::stats::DataStats;
use psgd::data::synth::SynthConfig;
use psgd::data::libsvm;
use psgd::loss::LossKind;
use psgd::bench::figure1::{self, Figure1Config, Panel};
use psgd::bench::plot::AsciiPlot;
use psgd::metrics::report::{diff_recorded, render_run_report, RecordedRun};
use psgd::obs::{JsonlRecorder, RunManifest};
use psgd::util::cli::Args;
use psgd::util::config::Config;
use psgd::util::validate::validate_train;

const USAGE: &str = "\
psgd — A Parallel SGD Method with Strong Convergence (reproduction)

USAGE: psgd <command> [flags]

COMMANDS
  gen-data   generate kdd2010-shaped synthetic data (libsvm format)
               --out PATH --examples N --features D --nnz K --skew S
               --seed S [--stats]
  train      run one distributed training method
               --method fs|sqm|sqm-lbfgs|hybrid|parammix|autoswitch
               --nodes P --lambda L --loss logistic|squared_hinge|least_squares
               --epochs s --batch B --iters N --theta-deg T
               --inner svrg|sag|sgd|lbfgs|tron
               [--data FILE | --examples N --features D --skew S]
               [--config exp.toml] [--trace out.csv] [--fstar]
               [--test-frac F] [--seed S]
               [--threads T]   local-solve worker threads; 0 = auto
                               (all cores, the default), 1 = sequential
               [--pipeline]    overlap the direction allreduce + line
                               search with the next round's node compute
                               (fs only; timing model — results are
                               bit-identical to the barrier schedule)
               [--master M]    master-side frame (fs only, like
                               --pipeline; other methods follow the
                               density gate automatically): auto
                               (default; union-support compact when
                               |U|/d < 0.5), dense, or compact. The
                               compact master runs the whole outer
                               loop in O(|U|) buffers and materializes
                               full-d w once; traces are ε-identical
                               either way.
               [--async-fs]    bounded-staleness asynchronous FS (fs
                               only): per-node solver lanes, the master
                               combines an arrival-ordered quorum of
                               directions at most τ rounds stale; a
                               combined direction that fails the
                               safeguard falls back to the synchronous
                               barrier direction. τ=0 with a full
                               quorum is bit-identical to plain fs.
               [--staleness N] τ for --async-fs (default 1)
               [--quorum N]    quorum size q for --async-fs
                               (default P−1, min 1; N ≥ P waits for
                               everyone)
               [--adaptive]    self-tuning asynchrony (--async-fs
                               only): a deterministic controller
                               re-tunes (τ, q) every few rounds from
                               the run's own staleness/fallback/fault
                               counters — fallback spikes shrink τ, a
                               widening straggler gap shrinks q, calm
                               weather re-expands both. --staleness/
                               --quorum set the starting point.
               [--tau-max N]   adaptive τ ceiling (default 4)
               [--q-min N]     adaptive quorum floor (default 1)
               [--speculate]   speculative solver lanes (--async-fs
                               only): idle lanes start the next solve
                               against a predicted iterate before the
                               current round commits; a prediction the
                               safeguard certifies banks the head
                               start on the virtual clock, a miss is
                               charged as speculation_rebase and the
                               solve restarts at the commit — results
                               are bit-identical either way (the
                               schedule moves, the maths never does).
               [--straggler N:F]    node N runs F× slower (e.g. 0:3)
               [--profile-spread X] seeded heterogeneous node speeds
                                    1 + X·U[0,1)  [--profile-seed S]
               [--fault SCRIPT]     seeded fault injection (--async-fs
                                    only): comma-separated events, flag
                                    repeatable. crash:N@rR | crash:N@Ts
                                    restart:N@... degrade:N@T:Fx
                                    flap:N:p=P loss:p=P — or the single
                                    word `seeded` for a generated plan.
                                    e.g. --fault crash:3@12.5s,restart:3@30s
                                         --fault degrade:1@5s:0.25x
                                         --fault flap:2:p=0.05
               [--fault-seed S]     seed for flap/loss coins and the
                                    `seeded` plan generator (default 42)
               [--link-profile SCRIPT]  heterogeneous link speeds on the
                                    reduction tree (any method):
                                    uplink:N:Fx | level:L:Fx | rack:I:Fx
                                    comma-separated — or `seeded` (one
                                    slow rack + slow top levels) or
                                    `uniform`. Every tree hop node N
                                    sends at level L costs ×(uplink ×
                                    level); a uniform profile is
                                    bit-identical to no profile.
               [--link-fault SCRIPT]    link weather on the tree
                                    (--async-fs only): congest:p=P[:Fx]
                                    flap:p=P | part:A+B@rF..rU |
                                    timeout:T | budget:K | noretry — or
                                    `seeded`. A hop that misses its
                                    timeout retries with exponential
                                    backoff; past `budget` attempts it
                                    reroutes one level up. Partitioned
                                    nodes drop from the quorum like
                                    crashes; a partition isolating the
                                    master heals through the certified
                                    synchronous fallback.
               [--link-seed S]      seed for link congest/flap coins and
                                    the `seeded` profile/plan (default
                                    42)
               [--trace-timeline out.json]  export the event engine's
                                            per-node schedule + the
                                            resilience counter block
               [--metrics-out run.jsonl]    flight recorder: stream one
                                            typed record per outer round
                                            (JSONL; manifest header
                                            first) and print the run
                                            report. Recording charges
                                            no simulated time or bytes;
                                            results are bit-identical
                                            with or without it.

MODES (no subcommand)
  --report-from run.jsonl          offline run report from a recorded
                                   stream (byte-identical to the one
                                   the recording run printed)
  --report-from run.jsonl --check  validate only: manifest first,
                                   matching schema, one record per
                                   round in order
  --report-from a.jsonl b.jsonl    diff two recorded runs; names the
                                   first divergent round and fields
                                   (exit 1 when they differ)
  figure1    regenerate the paper's Figure 1 panels for one node count
               --nodes P [--full] [--out-dir results/] [--iters N]
  info       show the AOT artifact manifest and PJRT platform
               [--artifacts DIR]
  help       this message
";

fn main() {
    let args = Args::from_env();
    // `--report-from a.jsonl [b.jsonl]` is a top-level mode, not a
    // subcommand: the parser binds the first file as the flag's value
    // and any second file lands as a positional, so this dispatch must
    // run before the positional match below.
    if args.has("report-from") {
        report_from(&args);
        return;
    }
    match args.positional.first().map(String::as_str) {
        Some("gen-data") => gen_data(&args),
        Some("train") => train(&args),
        Some("figure1") => figure1_cmd(&args),
        Some("info") => info(&args),
        _ => print!("{USAGE}"),
    }
}

/// Post-hoc analysis of `--metrics-out` streams, fully offline: one
/// file renders the run report (or just validates with `--check`),
/// two files diff round-by-round and name the first divergence.
fn report_from(args: &Args) {
    let mut files: Vec<&str> = args
        .get("report-from")
        .map(|v| v.split(',').filter(|s| !s.is_empty()).collect())
        .unwrap_or_default();
    files.extend(args.positional.iter().map(String::as_str));
    let load = |path: &str| -> RecordedRun {
        let src = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("error: cannot read {path}: {e}");
            std::process::exit(2);
        });
        RecordedRun::from_jsonl(&src).unwrap_or_else(|e| {
            eprintln!("error: {path}: {e}");
            std::process::exit(2);
        })
    };
    match files.as_slice() {
        [one] => {
            let run = load(one);
            if args.bool("check", false) {
                println!(
                    "{one}: ok ({} rounds, method {})",
                    run.rounds.len(),
                    run.trace.label
                );
            } else {
                println!("{}", run.report());
            }
        }
        [a, b] => {
            let ra = load(a);
            let rb = load(b);
            match diff_recorded(&ra, &rb) {
                None => println!(
                    "runs are identical ({} rounds)",
                    ra.rounds.len()
                ),
                Some(msg) => {
                    println!("{msg}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            eprintln!(
                "error: --report-from expects one file (render its run \
                 report) or two (diff them)"
            );
            std::process::exit(2);
        }
    }
}

fn figure1_cmd(args: &Args) {
    let nodes = args.usize("nodes", 25);
    let mut cfg = if args.bool("full", false) {
        Figure1Config::full(nodes)
    } else {
        Figure1Config::small(nodes)
    };
    cfg.iters = args.usize("iters", cfg.iters);
    cfg.seed = args.usize("seed", 42) as u64;
    let out_dir = args.get_or("out-dir", "results");
    eprintln!("running {cfg:?}");
    let out = figure1::run(&cfg);
    println!("f* = {:.8e}", out.f_star);
    for trace in &out.traces {
        let path = format!("{out_dir}/fig1_{nodes}nodes_{}.csv", trace.label);
        trace.to_table(out.f_star).save(&path).expect("write csv");
        println!("wrote {path}");
    }
    for panel in [Panel::GapVsPasses, Panel::GapVsTime, Panel::AuprcVsTime] {
        let series: Vec<(String, Vec<(f64, f64)>)> = out
            .traces
            .iter()
            .map(|t| {
                (
                    t.label.clone(),
                    panel
                        .series(t, out.f_star)
                        .into_iter()
                        .filter(|&(_, y)| !panel.log_y() || y > 0.0)
                        .collect(),
                )
            })
            .collect();
        let plot = AsciiPlot { log_y: panel.log_y(), ..Default::default() };
        println!("\n{}", plot.render(panel.title(), &series));
    }
}

fn gen_data(args: &Args) {
    let cfg = SynthConfig {
        n_examples: args.usize("examples", 10_000),
        n_features: args.usize("features", 50_000),
        nnz_per_example: args.usize("nnz", 35),
        skew: args.f64("skew", 0.5),
        label_noise: args.f64("noise", 0.05),
        ..SynthConfig::default()
    };
    let seed = args.usize("seed", 42) as u64;
    eprintln!("generating {cfg:?} (seed {seed})...");
    let data = cfg.generate(seed);
    if args.bool("stats", false) {
        println!("{}", DataStats::compute(&data).render());
    }
    let out = args.get_or("out", "data.libsvm");
    libsvm::write_file(&data, out).expect("write dataset");
    eprintln!("wrote {out}");
}

fn load_data(args: &Args, cfg: &Config) -> Dataset {
    if let Some(path) = args.get("data") {
        eprintln!("loading {path}...");
        libsvm::read_file(path, args.usize("declared-features", 0))
            .expect("parse libsvm")
    } else {
        let synth = SynthConfig {
            n_examples: args
                .usize("examples", cfg.usize("data", "examples", 20_000)),
            n_features: args
                .usize("features", cfg.usize("data", "features", 100_000)),
            nnz_per_example: args.usize("nnz", cfg.usize("data", "nnz", 35)),
            skew: args.f64("skew", cfg.f64("data", "skew", 0.5)),
            ..SynthConfig::default()
        };
        synth.generate(args.usize("seed", 42) as u64)
    }
}

/// Build the per-node speed profile from `--straggler N:F` /
/// `--profile-spread X [--profile-seed S]`; None keeps the default
/// homogeneous profile.
fn node_profile(args: &Args, nodes: usize) -> Option<NodeProfile> {
    let mut profile = None;
    let spread = args.f64("profile-spread", 0.0);
    if spread > 0.0 {
        let seed = args.usize("profile-seed", 42) as u64;
        profile = Some(NodeProfile::seeded(nodes, seed, spread));
    }
    if let Some(spec) = args.get("straggler") {
        let (node, factor) = spec
            .split_once(':')
            .unwrap_or_else(|| panic!("--straggler expects N:F, got {spec:?}"));
        let node: usize = node
            .parse()
            .unwrap_or_else(|_| panic!("--straggler node index: {node:?}"));
        let factor: f64 = factor
            .parse()
            .unwrap_or_else(|_| panic!("--straggler factor: {factor:?}"));
        assert!(
            node < nodes,
            "--straggler node {node} out of range (cluster has {nodes} \
             nodes, indices 0..{nodes})"
        );
        let mut p =
            profile.unwrap_or_else(|| NodeProfile::homogeneous(nodes));
        p.speed[node] = factor;
        profile = Some(p);
    }
    profile
}

/// Resolve `--staleness`/`--quorum`/`--adaptive [--tau-max --q-min]`
/// into the typed [`Asynchrony`] policy the async driver and the obs
/// manifest share.
fn async_policy(args: &Args, nodes: usize) -> Asynchrony {
    let tau = args.usize("staleness", 1);
    let q = args.usize("quorum", nodes.saturating_sub(1).max(1));
    if args.bool("adaptive", false) {
        let d = TuneBounds::default();
        Asynchrony::Adaptive {
            init: (tau, q),
            bounds: TuneBounds {
                tau_max: args.usize("tau-max", d.tau_max),
                q_min: args.usize("q-min", d.q_min),
            },
        }
    } else {
        let quorum =
            if q >= nodes { Quorum::All } else { Quorum::AtLeast(q) };
        Asynchrony::Bounded { tau, quorum }
    }
}

fn train(args: &Args) {
    let cfg = match args.get("config") {
        Some(p) => Config::load(p).expect("config file"),
        None => Config::default(),
    };
    let loss = LossKind::parse(
        args.get_or("loss", cfg.get("train", "loss").unwrap_or("logistic")),
    )
    .expect("unknown loss");
    let lam = args.f64("lambda", cfg.f64("train", "lambda", 1e-3));
    let nodes = args.usize("nodes", cfg.usize("train", "nodes", 4));
    let epochs = args.usize("epochs", cfg.usize("train", "epochs", 2));
    let batch = args.usize("batch", cfg.usize("train", "batch", 64));
    let iters = args.usize("iters", cfg.usize("train", "iters", 30));
    let seed = args.usize("seed", 42) as u64;
    let test_frac = args.f64("test-frac", 0.1);

    // reject bad flag combinations up front with a one-line error
    // (instead of a panic after the data is already loaded)
    if let Err(e) = validate_train(args, nodes) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    let data = load_data(args, &cfg);
    eprintln!("data: {}", DataStats::compute(&data).render());
    let (train_set, test_set) = data.split(1.0 - test_frac, seed ^ 1);
    let mut cluster = Cluster::partition(train_set, nodes, CostModel::default());
    // threads: 0 (the default) = auto-detect every available core —
    // map phases are threaded by default; pass 1 to force sequential
    let threads = args.usize("threads", 0);
    if threads > 0 {
        cluster.threads = threads;
    }
    if let Some(profile) = node_profile(args, nodes) {
        cluster.set_profile(profile);
    }
    if let Some(spec) = args.get("fault") {
        let fseed = args.usize("fault-seed", 42) as u64;
        let plan = if spec == "seeded" {
            FaultPlan::seeded(nodes, fseed)
        } else {
            let mut plan =
                FaultPlan::parse(spec, nodes).unwrap_or_else(|e| {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                });
            plan.seed = fseed;
            plan
        };
        cluster.set_fault_plan(plan);
    }
    if let Some(spec) = args.get("link-profile") {
        let lseed = args.usize("link-seed", 42) as u64;
        let profile = match spec {
            "seeded" => LinkProfile::seeded(nodes, lseed),
            "uniform" => LinkProfile::uniform(nodes),
            _ => LinkProfile::parse(spec, nodes).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(2);
            }),
        };
        cluster.set_link_profile(profile);
    }
    if let Some(spec) = args.get("link-fault") {
        let lseed = args.usize("link-seed", 42) as u64;
        let plan = if spec == "seeded" {
            LinkFaultPlan::seeded(nodes, lseed)
        } else {
            let mut plan =
                LinkFaultPlan::parse(spec, nodes).unwrap_or_else(|e| {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                });
            plan.seed = lseed;
            plan
        };
        cluster.set_link_fault_plan(plan);
    }

    let method = args.get_or("method", "fs");
    let inner = match args.get_or("inner", "svrg") {
        "svrg" => InnerSolver::Svrg,
        "sag" => InnerSolver::Sag,
        "sgd" => InnerSolver::Sgd,
        "lbfgs" => InnerSolver::Lbfgs,
        "tron" => InnerSolver::Tron,
        other => panic!("unknown inner solver {other:?}"),
    };
    let fs_config = FsConfig {
        loss,
        lam,
        epochs,
        batch,
        inner,
        safeguard: match args.get("theta-deg") {
            Some(_) => Safeguard::from_degrees(args.f64("theta-deg", 90.0)),
            None => Safeguard::default(),
        },
        seed,
        pipeline: args.bool("pipeline", false),
        master: match args.get_or("master", "auto") {
            "auto" => MasterMode::Auto,
            "dense" => MasterMode::Dense,
            "compact" => MasterMode::Compact,
            other => panic!("unknown --master {other:?} (auto|dense|compact)"),
        },
        ..Default::default()
    };
    let driver: Box<dyn Driver> = match method {
        "fs" if args.bool("async-fs", false) => {
            Box::new(AsyncFsDriver::new(AsyncFsConfig {
                fs: fs_config,
                policy: async_policy(args, nodes),
                speculate: args.bool("speculate", false),
            }))
        }
        "fs" => Box::new(FsDriver::new(fs_config)),
        "sqm" => Box::new(SqmDriver::new(SqmConfig {
            loss,
            lam,
            ..Default::default()
        })),
        "sqm-lbfgs" => Box::new(SqmDriver::new(SqmConfig {
            loss,
            lam,
            core: CoreOpt::Lbfgs,
            ..Default::default()
        })),
        "hybrid" => {
            let h = HybridConfig {
                sqm: SqmConfig { loss, lam, ..Default::default() },
                ..Default::default()
            };
            Box::new(HybridDriver::with_objective(h))
        }
        "parammix" => Box::new(ParamMixDriver::new(ParamMixConfig {
            loss,
            lam,
            epochs,
            seed,
            ..Default::default()
        })),
        "autoswitch" => Box::new(AutoSwitchDriver::new(AutoSwitchConfig {
            fs: fs_config,
            ..Default::default()
        })),
        other => panic!("unknown method {other:?}"),
    };

    // --metrics-out: install the flight-recorder sink and stream the
    // run-manifest header before the first round
    let metrics_out = args.get("metrics-out");
    if let Some(path) = metrics_out {
        let rec = JsonlRecorder::create(path).unwrap_or_else(|e| {
            eprintln!("error: cannot create {path}: {e}");
            std::process::exit(2);
        });
        cluster.set_recorder(Box::new(rec));
        let is_async = method == "fs" && args.bool("async-fs", false);
        cluster.record_manifest(&RunManifest {
            method: driver.name(),
            nodes,
            threads: cluster.threads,
            examples: cluster.shards.iter().map(|s| s.n_examples()).sum(),
            features: cluster.dim,
            loss: loss.name().to_string(),
            lam,
            iters,
            seed,
            master: args.get_or("master", "auto").to_string(),
            pipeline: args.bool("pipeline", false),
            staleness: is_async.then(|| args.usize("staleness", 1)),
            quorum: is_async.then(|| {
                args.usize("quorum", nodes.saturating_sub(1).max(1))
            }),
            policy: is_async.then(|| async_policy(args, nodes).tag()),
            fault: args.get("fault").map(str::to_string),
            fault_seed: args
                .get("fault")
                .map(|_| args.usize("fault-seed", 42) as u64),
            link_profile: args.get("link-profile").map(str::to_string),
            link_fault: args.get("link-fault").map(str::to_string),
            link_seed: (args.has("link-profile")
                || args.has("link-fault"))
            .then(|| args.usize("link-seed", 42) as u64),
        });
    }

    eprintln!(
        "running {} on {} nodes (loss={}, λ={lam}, s={epochs})",
        driver.name(),
        cluster.n_nodes(),
        loss.name()
    );
    let test_opt = if test_set.n_examples() > 0 { Some(&test_set) } else { None };
    let run = driver.run(&mut cluster, test_opt, &StopRule::iters(iters));

    // optional high-accuracy f* for relative gaps
    let f_star = if args.bool("fstar", false) {
        eprintln!("computing f* to high accuracy (TRON)...");
        let mut fresh = cluster.fork_fresh();
        let sqm = SqmDriver::new(SqmConfig { loss, lam, ..Default::default() });
        let mut stop = StopRule::iters(500);
        stop.gnorm_rel = 1e-12;
        sqm.run(&mut fresh, None, &stop).f
    } else {
        run.f
    };

    println!("method,iters,f,comm_passes,sim_seconds,auprc");
    let last = run.trace.last().cloned().unwrap_or_default();
    println!(
        "{},{},{:.8e},{},{:.3},{:.4}",
        driver.name(),
        run.trace.points.len(),
        run.f,
        last.comm_passes,
        last.seconds,
        last.auprc
    );
    if let Some(path) = metrics_out {
        cluster.finish_recording();
        // the same render `--report-from PATH` reproduces offline,
        // byte-for-byte (tests/obs.rs pins the equality)
        println!("\n{}", render_run_report(&run.trace, &run.ledger, run.f));
        eprintln!("metrics written to {path}");
    }
    if let Some(path) = args.get("trace") {
        run.trace.to_table(f_star).save(path).expect("write trace");
        eprintln!("trace written to {path}");
    }
    if let Some(path) = args.get("trace-timeline") {
        // the cluster export = engine timeline + the resilience block
        // (staleness/fallback counters, fault accounting, liveness)
        std::fs::write(path, cluster.timeline_json().to_json(1))
            .expect("write timeline");
        eprintln!(
            "timeline written to {path} (makespan {:.3}s, {} events)",
            cluster.engine.makespan(),
            cluster.engine.events().len()
        );
    }
}

#[cfg(not(feature = "xla"))]
fn info(_args: &Args) {
    eprintln!(
        "psgd was built without the `xla` feature: the PJRT runtime \
         (and `psgd info`) is unavailable in the offline build.\n\
         Rebuild with `cargo build --features xla` in an environment \
         that provides the xla_extension runtime (see rust/Cargo.toml)."
    );
    std::process::exit(1);
}

#[cfg(feature = "xla")]
fn info(args: &Args) {
    let dir = args.get_or("artifacts", "artifacts");
    match psgd::runtime::DenseRuntime::load(dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!(
                "artifact shapes: n={} d={} batch={} loss={} dtype={}",
                rt.manifest.n,
                rt.manifest.d,
                rt.manifest.batch,
                rt.manifest.loss,
                rt.manifest.dtype
            );
            for (name, path) in &rt.manifest.artifacts {
                println!("  {name}: {}", path.display());
            }
        }
        Err(e) => {
            eprintln!("could not load runtime from {dir}: {e:#}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    }
}
