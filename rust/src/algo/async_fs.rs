//! **Bounded-staleness asynchronous FS** — stale-tolerant directions
//! in the maths, not just the schedule.
//!
//! PR 3's `--pipeline` mode overlapped the *control plane* with node
//! compute but kept the algorithm synchronous: every outer round's
//! direction still waits for every node's fresh local solve, so one
//! straggler gates the whole cluster. This driver relaxes exactly that
//! barrier, the way the asynchronous SGD literature does (Keuper &
//! Pfreundt, arXiv:1505.04956) but *without* trading away the paper's
//! strong-convergence guarantee (the gap sound-combiner approaches,
//! Maleki et al. arXiv:1705.08030, close only for linear learners):
//!
//! - **Solver lanes.** Each node's local solves run on a per-node
//!   solver lane the driver schedules itself: a solve for round r
//!   starts when the node is idle and gʳ has landed, and takes the
//!   node's measured solve seconds × its
//!   [`NodeProfile`](crate::cluster::NodeProfile) speed. The
//!   node's *main* lane keeps doing gradient sweeps and line-search
//!   scalars every round (the cheap, synchronous commit path), so a
//!   straggler's slow solver never blocks the gradient allreduce.
//!   A solve whose reference has fallen more than τ rounds behind is
//!   aborted — the node re-solves against the newest reference
//!   (bounded staleness, enforced at the node).
//!
//! - **Arrival-ordered quorum.** At round r the master combines
//!   whatever has arrived by the engine's virtual clock: it waits
//!   until `q` of the P nodes' *round-r* solves have landed (or all
//!   of them, when stragglers mid-solve leave fewer than q in flight
//!   for this round), then every node contributes its freshest solve
//!   available by that deadline — a straggler is represented by its
//!   most recent completed [`HybridDir`], computed for some round
//!   r′ ≥ r − τ. Stale hybrids are re-based onto the
//!   current wʳ through the same affine machinery the wire format
//!   already uses: d_p = a_w·wʳ′ + a_g·gʳ′ + corr targets the point
//!   wʳ′ + d_p, so its re-based form is d̃_p = d_p + (wʳ′ − wʳ) —
//!   per distinct stale reference the master folds
//!   (a_w + 1, a_g) onto its stored (wʳ′, gʳ′) pair and −1 onto the
//!   current wʳ. Nodes still ship only (a_w, a_g) + a support-sized
//!   correction; the master keeps the last τ+1 references — O(τ·|U|)
//!   memory under the union-support compact master (the default in
//!   the paper's sparse regime; see [`crate::algo::fs`]), O(τ·d) only
//!   when the dense master is selected. Never per-node.
//!
//! - **The safeguard is the correctness gate.** Fresh contributions
//!   get Algorithm 1's per-direction safeguard at their own reference,
//!   exactly as the synchronous driver applies it. Stale re-based
//!   contributions are accepted on faith — and the *combined*
//!   direction must then pass the same θ-cone test against the
//!   current −gʳ
//!   ([`Safeguard::accepts_combined`](crate::algo::safeguard::Safeguard::accepts_combined)).
//!   A convex
//!   combination of per-part-safeguarded fresh directions always
//!   passes, so a rejection isolates genuine stale contamination: the
//!   round discards the quorum direction, aborts every solver lane
//!   and falls back to the synchronous barrier direction (fresh
//!   solves from all P nodes, per-part safeguard, the shared
//!   [`combine_hybrids`] path) — which is why tier-1 convergence
//!   holds for any (τ, q): every committed direction is either
//!   θ-cone descent or the certified synchronous one, and the
//!   strong-Wolfe line search runs on it unchanged.
//!
//! - **Speculative solver lanes** (`speculate: true`). Between
//!   shipping its round-r solve and receiving the round-r commit, a
//!   node's solver lane used to sit idle. Speculation lets it start
//!   the round-(r+1) solve immediately on a *predicted* iterate — its
//!   own uncombined hybrid applied to wʳ — reconciling via the same
//!   affine re-basing above when the real commit lands. The
//!   classification mirrors the correctness gate: when the node's
//!   re-based round-r direction still sits inside the safeguard's θ
//!   cone around −gʳ⁺¹ the prediction was sound and the fresh solve
//!   keeps its early start on the virtual clock (a `spec_solve`
//!   event — a free head start); otherwise the speculative window is
//!   discarded as a `speculation_rebase` (charged to
//!   [`Ledger::spec_rebase_seconds`](crate::cluster::Ledger::spec_rebase_seconds))
//!   and the solve restarts at the commit, exactly the plain-async
//!   schedule. Hit or miss, the solve's *arithmetic* is computed
//!   against the true (wʳ⁺¹, gʳ⁺¹) reference and the safeguard still
//!   gates the combined direction — speculation moves the schedule,
//!   never the maths, so strong convergence is untouched and a
//!   misprediction costs a resync, never correctness. With
//!   `speculate: false` this block is dead code and the driver is
//!   bit-identical to its pre-speculation self (`tests/speculation.rs`
//!   pins it).
//!
//! - **Adaptive (τ, q).** Under [`Asynchrony::Adaptive`] a
//!   [`Controller`](crate::algo::adapt::Controller) re-tunes the
//!   staleness bound and quorum per round from ledger state (fallback
//!   spikes shrink τ, a widening straggler gap shrinks q, calm
//!   weather re-expands both inside the configured bounds) — every
//!   decision a pure ledger function, so seeded runs replay their
//!   [`Ledger::tune_trace`](crate::cluster::Ledger::tune_trace)
//!   bit-identically. See [`crate::algo::adapt`] for the rules.
//!
//! **When async ≡ sync:** under [`Asynchrony::Sync`] (τ = 0, q = P)
//! only fresh solves are eligible and the deadline is the last of
//! them, so every round is exactly Algorithm 1's — the driver produces
//! *bit-identical* iterates to [`FsDriver`](crate::algo::fs::FsDriver)
//! (`tests/async_fs.rs` pins this). The win appears when q < P under
//! heterogeneous profiles: rounds advance at the pace of the q-th
//! node, the straggler contributes stale (≤ τ) directions when they
//! arrive, and `benches/async_fs.rs` asserts the makespan-to-ε
//! strictly beats the pipelined synchronous schedule on the straggler
//! profile. `benches/speculation.rs` extends the chain: speculative
//! mode must strictly beat plain async by absolute virtual seconds on
//! the straggler and seeded-chaos matrices.
//!
//! Per-round staleness lands in
//! [`Ledger::staleness_hist`](crate::cluster::Ledger::staleness_hist) /
//! [`Ledger::fallback_rounds`](crate::cluster::Ledger::fallback_rounds),
//! speculation outcomes in
//! [`Ledger::spec_hits`](crate::cluster::Ledger::spec_hits) /
//! [`Ledger::spec_misses`](crate::cluster::Ledger::spec_misses),
//! per-event staleness in the timeline
//! export (`--trace-timeline`), and the CLI drives it with
//! `psgd train --method fs --async-fs --staleness τ --quorum q
//! [--adaptive] [--speculate]`.

use std::collections::VecDeque;

use crate::algo::adapt::Asynchrony;

use crate::algo::common::{global_value_grad_fleet, TestProbe};
use crate::algo::fs::{
    combine_hybrids_members, combine_weights, local_direction, FsConfig,
};
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::allreduce::Reduced;
use crate::cluster::Cluster;
use crate::data::dataset::Dataset;
use crate::linalg::dense;
use crate::linalg::sparse::SparseVec;
use crate::metrics::trace::{Trace, TracePoint};
use crate::objective::compact::{GlobalDots, HybridDir};
use crate::obs::RoundObs;
use crate::opt::linesearch::{strong_wolfe, MarginPhi, PhiLambda};

#[derive(Clone, Debug)]
pub struct AsyncFsConfig {
    pub fs: FsConfig,
    /// The asynchrony policy: [`Asynchrony::Sync`] (bit-identical to
    /// [`FsDriver`](crate::algo::fs::FsDriver)),
    /// [`Asynchrony::Bounded`] (fixed τ + [`Quorum`]), or
    /// [`Asynchrony::Adaptive`] (self-tuning (τ, q) inside bounds).
    ///
    /// [`Quorum`]: crate::algo::adapt::Quorum
    pub policy: Asynchrony,
    /// Speculative solver lanes: nodes start the next round's solve on
    /// a predicted iterate instead of idling until the commit (see the
    /// module docs). `false` keeps the exact pre-speculation schedule.
    pub speculate: bool,
}

impl Default for AsyncFsConfig {
    fn default() -> Self {
        AsyncFsConfig {
            fs: FsConfig::default(),
            policy: Asynchrony::default(),
            speculate: false,
        }
    }
}

pub struct AsyncFsDriver {
    pub config: AsyncFsConfig,
}

impl AsyncFsDriver {
    pub fn new(config: AsyncFsConfig) -> AsyncFsDriver {
        AsyncFsDriver { config }
    }
}

/// One local solve on a node's solver lane.
struct Solve {
    /// outer round whose (wʳ, gʳ) the solve used
    for_round: usize,
    /// virtual completion time on the solver lane
    done: f64,
    dir: HybridDir,
}

/// A node's solver-lane state: at most one solve in flight plus the
/// most recent completed one (reusable until it exceeds τ).
#[derive(Default)]
struct SolverLane {
    inflight: Option<Solve>,
    latest: Option<Solve>,
}

/// One contribution the master combines at a round.
struct Contribution {
    node: usize,
    /// r − for_round at the combining round
    staleness: usize,
    /// virtual time it reached the master (≥ the round start)
    arrival: f64,
    dir: HybridDir,
}

/// The stored (wʳ′, gʳ′) pair a stale hybrid re-bases against.
fn lookup_ref(
    history: &VecDeque<(usize, Vec<f64>, Vec<f64>)>,
    round: usize,
) -> (&[f64], &[f64]) {
    history
        .iter()
        .find(|(r, _, _)| *r == round)
        .map(|(_, w, g)| (w.as_slice(), g.as_slice()))
        .expect("stale reference inside the τ window")
}

impl Driver for AsyncFsDriver {
    fn name(&self) -> String {
        let spec = if self.config.speculate { "-spec" } else { "" };
        format!(
            "afs-{}-{}{}",
            self.config.policy.tag(),
            self.config.fs.epochs,
            spec
        )
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        let c = &self.config.fs;
        let p_nodes = cluster.n_nodes();
        // the policy resolves to a starting (τ, q); the adaptive
        // controller (when present) re-tunes the pair per round from
        // ledger state — see crate::algo::adapt for the rules
        let (mut tau, mut q) = self.config.policy.initial(p_nodes);
        let mut controller = self.config.policy.controller(p_nodes);
        let speculate = self.config.speculate;
        let dim = cluster.dim;
        // master frame: the union-support compact master shrinks every
        // master-side buffer — including the τ+1-deep re-basing ring —
        // from O(d) to O(|U|) (see algo::fs module docs)
        let (compact, sparse) = c.master.resolve(cluster);
        let fdim = if compact { cluster.umap.len() } else { dim };
        // the async schedule is its own: solver lanes self-pace, the
        // main lanes barrier on the gradient/commit path
        cluster.set_pipeline(false);
        let mut w = vec![0.0; fdim];
        let mut trace = Trace::new(self.name());
        // ship w⁰ — O(|U|) payload in the compact regime
        if compact {
            cluster.broadcast_support(fdim);
        } else {
            cluster.broadcast_vec();
        }
        let probe = TestProbe::new(test, compact.then_some(&cluster.umap));
        let mut gnorm0 = f64::INFINITY;
        let mut f = f64::INFINITY;
        let mut last_hits = 0usize;
        let mut margins: Vec<Vec<f64>> = Vec::new();
        let mut lanes: Vec<SolverLane> =
            (0..p_nodes).map(|_| SolverLane::default()).collect();
        // master-side reference ring for stale re-basing: the last
        // τ+1 (round, wʳ, gʳ) triples — O(τ·|U|) under the compact
        // master (O(τ·d) only in the dense regime), master only
        let mut history: VecDeque<(usize, Vec<f64>, Vec<f64>)> =
            VecDeque::new();
        // flight recorder: begin() runs before the weather so this
        // round's fault events land inside its record window
        let mut obs = RoundObs::new(cluster);

        for r in 0.. {
            obs.begin(cluster, r);
            // --- step 0: this round's fleet weather (clear skies and
            // full membership without a fault plan — the zero-fault
            // path is bit-identical to the pre-fault driver) ---
            let weather = cluster.apply_fault_weather(r);
            for &p in &weather.crashed {
                // a crashed node loses its solver-lane state and its
                // margin cache; its shard is simply absent until it
                // rejoins
                lanes[p] = SolverLane::default();
                if p < margins.len() {
                    margins[p].clear();
                }
            }
            for &p in &weather.restarted {
                // rejoin: the master re-bases the node onto the
                // current iterate via the O(fdim) compact unicast;
                // its margins recompute cold in the next sweep
                cluster.rejoin_rebase(p, fdim);
                lanes[p] = SolverLane::default();
                if p < margins.len() {
                    margins[p].clear();
                }
            }
            for &p in &weather.healed {
                // a healed partition component re-bases onto the
                // current iterate (it never saw the partition-era
                // commits) but KEEPS its solver lanes: a solve still
                // within the staleness bound rejoins the quorum below,
                // anything older was already expired by the τ check
                cluster.rejoin_rebase(p, fdim);
                if p < margins.len() {
                    margins[p].clear();
                }
            }
            let members = &weather.members;
            if obs.on() {
                obs.rec().rebased =
                    weather.restarted.len() + weather.healed.len();
            }

            // --- adaptive policy: one pure-ledger observation per
            // round; every full window re-decides (τ, q) and records
            // the decision on the tune trace (seeded runs replay it
            // bit-identically) ---
            if let Some(ctrl) = controller.as_mut() {
                if let Some(decision) =
                    ctrl.observe(&cluster.ledger, members.len())
                {
                    (tau, q) = decision;
                    cluster.ledger.tune_trace.push(decision);
                }
                if obs.on() {
                    let rec = obs.rec();
                    rec.ctrl_tau = Some(tau);
                    rec.ctrl_q = Some(q);
                }
            }

            // --- step 1: synchronous gradient allreduce at wʳ over
            // the members (the cheap commit path every surviving
            // node's main lane walks); per-member warm/cold handled
            // inside the fleet round ---
            let (f_r, g, grad_parts) = global_value_grad_fleet(
                cluster, members, &mut margins, &w, c.loss, c.lam, true,
                sparse, compact,
            );
            f = f_r;
            let gnorm = dense::norm(&g);
            if r == 0 {
                gnorm0 = gnorm;
            }
            let pt = TracePoint {
                iter: r,
                f,
                gnorm,
                comm_passes: cluster.ledger.comm_passes,
                seconds: cluster.ledger.seconds(),
                auprc: probe.auprc(&w),
                safeguard_hits: last_hits,
            };
            obs.trace_point(&pt);
            if obs.on() {
                let rec = obs.rec();
                rec.compact = compact;
                rec.live_u = fdim;
                rec.members.extend_from_slice(members);
            }
            trace.push(pt);
            if gnorm == 0.0
                || stop.should_stop(r, f, gnorm, gnorm0, &cluster.ledger)
            {
                obs.commit(cluster);
                break;
            }

            let dots = GlobalDots::compute(&w, &g);
            history.push_back((r, w.clone(), g.clone()));
            while history.len() > tau + 1 {
                history.pop_front();
            }
            // gʳ is on every node once the grad allreduce lands
            let t_round = cluster.engine.makespan();

            // --- solver lanes: promote finished work, abort work the
            // staleness bound has already expired, refill idle
            // solvers with fresh round-r solves ---
            let mut fresh: Vec<usize> = Vec::new();
            for (p, lane) in lanes.iter_mut().enumerate() {
                if lane
                    .inflight
                    .as_ref()
                    .is_some_and(|s| s.done <= t_round)
                {
                    lane.latest = lane.inflight.take();
                }
                if lane
                    .inflight
                    .as_ref()
                    .is_some_and(|s| s.for_round + tau < r)
                {
                    lane.inflight = None;
                }
                if lane
                    .latest
                    .as_ref()
                    .is_some_and(|s| s.for_round + tau < r)
                {
                    lane.latest = None;
                }
                // only members start new solves: a dead node has no
                // lane, a flapped one sits this round out (its
                // in-flight solve keeps running)
                if lane.inflight.is_none() && members.contains(&p) {
                    fresh.push(p);
                }
            }
            // --- speculation: a fresh node whose round-(r−1) solve
            // finished before this round's gradient landed has been
            // speculating on its own predicted iterate (wʳ⁻¹ plus its
            // uncombined hybrid) since that moment. Classify each such
            // window now that the true (wʳ, gʳ) is known: the
            // prediction was sound iff the node's re-based previous
            // direction still sits inside the safeguard's θ cone
            // around −gʳ — the same test that gates the combined
            // direction. A hit keeps the early start on the virtual
            // clock; a miss discards the window as a
            // speculation_rebase. Timing only: the solve arithmetic
            // below runs against the true reference either way, so
            // speculation never perturbs the maths.
            let spec: Vec<Option<(f64, bool)>> = lanes
                .iter()
                .enumerate()
                .map(|(p, lane)| {
                    if !speculate || !fresh.contains(&p) {
                        return None;
                    }
                    let s = lane.latest.as_ref()?;
                    if s.for_round + 1 != r || s.done >= t_round {
                        return None;
                    }
                    // τ ≥ 1 here — a round-(r−1) solve survives the
                    // staleness abort above only then — so the
                    // (wʳ⁻¹, gʳ⁻¹) pair is still in the ring
                    let (w_old, g_old) = lookup_ref(&history, r - 1);
                    let mut dp = s.dir.to_dense(w_old, g_old);
                    for ((vj, wo), wc) in dp.iter_mut().zip(w_old).zip(&w)
                    {
                        *vj += wo - wc;
                    }
                    Some((s.done, c.safeguard.accepts_combined(&g, &dp)))
                })
                .collect();
            let w_ref = &w;
            let g_ref = &g;
            let gp_ref = &grad_parts;
            let solved = cluster.map_nodes_timed(&fresh, |p, shard, s| {
                local_direction(
                    c, p, shard, s, fdim, compact, &dots, w_ref, g_ref,
                    gp_ref, r,
                )
            });
            let scale = cluster.cost.compute_scale;
            let mut max_dur = 0.0f64;
            let (mut spec_hits_r, mut spec_misses_r) = (0usize, 0usize);
            for (&p, (dir, secs)) in fresh.iter().zip(solved) {
                let dur = secs * scale * cluster.engine.profile.scale(p);
                max_dur = max_dur.max(dur);
                let start = match spec[p] {
                    // hit: a free head start — the solve is scheduled
                    // from the moment the previous one finished, not
                    // from the commit
                    Some((s0, true)) => {
                        spec_hits_r += 1;
                        cluster
                            .engine
                            .solver_event("spec_solve", p, s0, s0 + dur);
                        s0
                    }
                    // miss: the speculative window was wasted work;
                    // the lane re-bases and the solve restarts at the
                    // commit — exactly the plain-async schedule, so a
                    // misprediction never loses to not speculating
                    Some((s0, false)) => {
                        spec_misses_r += 1;
                        cluster.ledger.spec_rebase_seconds += t_round - s0;
                        cluster.engine.solver_event(
                            "speculation_rebase",
                            p,
                            s0,
                            t_round,
                        );
                        cluster.engine.solver_event(
                            "async_solve",
                            p,
                            t_round,
                            t_round + dur,
                        );
                        t_round
                    }
                    None => {
                        cluster.engine.solver_event(
                            "async_solve",
                            p,
                            t_round,
                            t_round + dur,
                        );
                        t_round
                    }
                };
                lanes[p].inflight =
                    Some(Solve { for_round: r, done: start + dur, dir });
            }
            cluster.ledger.spec_hits += spec_hits_r;
            cluster.ledger.spec_misses += spec_misses_r;
            if obs.on() {
                let rec = obs.rec();
                rec.spec_hits = spec_hits_r;
                rec.spec_misses = spec_misses_r;
            }
            // flat barrier-equivalent component; the schedule itself
            // lives on the solver lanes
            cluster.ledger.compute_seconds += max_dur;

            // --- arrival-ordered quorum collection ---
            // the quorum counts FRESH responses: the master waits
            // until q nodes' round-r solves have arrived on its
            // virtual clock (when stragglers mid-solve leave fewer
            // than q in flight for round r, it waits for all of
            // those; with none at all it combines immediately)
            let mut fresh_avail: Vec<f64> = lanes
                .iter()
                .filter_map(|lane| {
                    lane.inflight
                        .as_ref()
                        .filter(|s| s.for_round == r)
                        .map(|s| s.done)
                })
                .collect();
            fresh_avail
                .sort_by(|a, b| a.partial_cmp(b).expect("finite avail"));
            let deadline = match fresh_avail.len() {
                0 => t_round,
                n => fresh_avail[n.min(q) - 1],
            };
            // each member at the deadline delivers its freshest solve
            // available by then (a finished in-flight beats `latest`);
            // non-members deliver nothing, a dropped member's message
            // was lost even after the retry, a delayed member's retry
            // pushes its arrival
            let mut contribs: Vec<Contribution> = Vec::new();
            for (p, lane) in lanes.iter().enumerate() {
                if !members.contains(&p) || weather.dropped.contains(&p) {
                    continue;
                }
                let chosen = lane
                    .inflight
                    .as_ref()
                    .filter(|s| s.done <= deadline)
                    .or_else(|| lane.latest.as_ref());
                if let Some(s) = chosen {
                    let delay = weather
                        .delayed
                        .iter()
                        .find(|&&(dp, _)| dp == p)
                        .map(|&(_, d)| d)
                        .unwrap_or(0.0);
                    contribs.push(Contribution {
                        node: p,
                        staleness: r - s.for_round,
                        arrival: s.done.max(t_round) + delay,
                        dir: s.dir.clone(),
                    });
                }
            }
            let full_fresh = contribs.len() == p_nodes
                && contribs.iter().all(|cb| cb.staleness == 0);
            if obs.on() {
                let rec = obs.rec();
                for cb in &contribs {
                    rec.quorum.push(cb.node);
                    rec.staleness.push(cb.staleness);
                }
            }

            // --- step 6 on the fresh parts (Algorithm 1's safeguard
            // at their own — current — reference) ---
            let mut hits = 0usize;
            for cb in contribs.iter_mut().filter(|cb| cb.staleness == 0) {
                let h = c.safeguard.apply_hybrid(
                    &dots,
                    &w,
                    &g,
                    std::slice::from_mut(&mut cb.dir),
                );
                if h > 0 && obs.on() {
                    obs.rec().sg_replaced.push(cb.node);
                }
                hits += h;
            }

            // --- step 7 over the quorum: fresh parts combine exactly
            // like the synchronous driver; each stale part re-bases
            // onto wʳ via its stored reference pair ---
            let contrib_nodes: Vec<usize> =
                contribs.iter().map(|cb| cb.node).collect();
            let weights = combine_weights(cluster, c.combine, &contrib_nodes);
            let arrivals: Vec<(usize, f64, usize)> = contribs
                .iter()
                .map(|cb| (cb.node, cb.arrival, cb.staleness))
                .collect();
            let mut d: Vec<f64> = if contribs.is_empty() {
                // every member contribution was lost on the wire (or
                // no solve has ever finished): nothing to combine —
                // the round routes straight to the synchronous
                // fallback below instead of hanging on the quorum
                Vec::new()
            } else if sparse {
                let mut a_w_sum = 0.0;
                let mut a_g_sum = 0.0;
                // per distinct stale reference round: the (wʳ′, gʳ′)
                // coefficient pair its re-based hybrids contribute
                let mut old: Vec<(usize, f64, f64)> = Vec::new();
                let mut parts: Vec<SparseVec> =
                    Vec::with_capacity(contribs.len());
                for (cb, &cw) in contribs.iter().zip(&weights) {
                    if cb.staleness == 0 {
                        a_w_sum += cw * cb.dir.a_w;
                        a_g_sum += cw * cb.dir.a_g;
                    } else {
                        // d̃ = a_w·wʳ′ + a_g·gʳ′ + corr + (wʳ′ − wʳ)
                        let rr = r - cb.staleness;
                        match old.iter_mut().find(|o| o.0 == rr) {
                            Some(o) => {
                                o.1 += cw * (cb.dir.a_w + 1.0);
                                o.2 += cw * cb.dir.a_g;
                            }
                            None => old.push((
                                rr,
                                cw * (cb.dir.a_w + 1.0),
                                cw * cb.dir.a_g,
                            )),
                        }
                        a_w_sum -= cw; // the −wʳ re-basing term
                    }
                    let mut sv = cb.dir.corr.clone();
                    sv.scale(cw);
                    parts.push(sv);
                }
                // the per-contribution (a_w, a_g) pairs ride a scalar
                // round alongside the corr reduce, as in the sync path
                cluster.charge_scalar_round_members(2, members);
                let (reduced, _landed) = cluster
                    .async_quorum_reduce_sparse_members(
                        &parts, &arrivals, true, members,
                    );
                let mut d: Vec<f64> = w
                    .iter()
                    .zip(&g)
                    .map(|(wj, gj)| a_w_sum * wj + a_g_sum * gj)
                    .collect();
                match reduced {
                    Reduced::Sparse(sv) => sv.axpy_into(1.0, &mut d),
                    Reduced::Dense(v) => dense::axpy(1.0, &v, &mut d),
                }
                for (rr, aw, ag) in old {
                    let (w_old, g_old) = lookup_ref(&history, rr);
                    for ((dj, wj), gj) in
                        d.iter_mut().zip(w_old).zip(g_old)
                    {
                        *dj += aw * wj + ag * gj;
                    }
                }
                d
            } else {
                let parts: Vec<Vec<f64>> = contribs
                    .iter()
                    .zip(&weights)
                    .map(|(cb, &cw)| {
                        let mut dd = if cb.staleness == 0 {
                            cb.dir.to_dense(&w, &g)
                        } else {
                            let (w_old, g_old) =
                                lookup_ref(&history, r - cb.staleness);
                            let mut v = cb.dir.to_dense(w_old, g_old);
                            // re-base the stale target point onto wʳ
                            for ((vj, wo), wc) in
                                v.iter_mut().zip(w_old).zip(&w)
                            {
                                *vj += wo - wc;
                            }
                            v
                        };
                        dense::scale(&mut dd, cw);
                        dd
                    })
                    .collect();
                cluster
                    .async_quorum_reduce_members(
                        &parts, &arrivals, true, members,
                    )
                    .0
            };

            // --- the correctness gate: a full fresh quorum IS the
            // synchronous round and skips it; anything less must sit
            // inside the θ cone around −gʳ or the round falls back to
            // the synchronous barrier direction ---
            let mut fell_back = false;
            if weather.heal_resync {
                // a master-isolating partition healed this round: the
                // certified synchronous fallback resynchronizes the
                // whole fleet on one iterate regardless of what the
                // quorum produced — the PR-7 escape hatch, so no link
                // state can leave the components disagreeing
                fell_back = true;
                if obs.on() {
                    obs.rec().fallback = Some("partition-heal");
                }
            } else if contribs.is_empty() {
                fell_back = true;
                if obs.on() {
                    obs.rec().fallback = Some("empty-quorum");
                }
            } else if !full_fresh {
                // (a full fresh quorum IS the synchronous round and
                // skips the combined test, exactly as before)
                let ok = c.safeguard.accepts_combined(&g, &d);
                if obs.on() {
                    obs.rec().combined_ok = Some(ok);
                }
                if !ok {
                    fell_back = true;
                    if obs.on() {
                        obs.rec().fallback = Some("safeguard");
                    }
                }
            }
            if fell_back {
                // abort every solver lane (the master broadcasts the
                // resync); resolve every *member* freshly at wʳ on the
                // barrier'd main lanes and run the exact Algorithm-1
                // round over the current membership — stale work
                // bought nothing this round
                for lane in lanes.iter_mut() {
                    lane.inflight = None;
                    lane.latest = None;
                }
                cluster.engine.set_phase("fallback_solve");
                let mut dirs: Vec<HybridDir> = cluster
                    .map_each_scratch_members(members, |p, shard, s| {
                        local_direction(
                            c, p, shard, s, fdim, compact, &dots, w_ref,
                            g_ref, gp_ref, r,
                        )
                    });
                hits += if obs.on() {
                    let rec = obs.rec();
                    let start = rec.sg_replaced.len();
                    let h = c.safeguard.apply_hybrid_flagged(
                        &dots,
                        &w,
                        &g,
                        &mut dirs,
                        Some(&mut rec.sg_replaced),
                    );
                    // flagged indices are positions into `dirs` —
                    // remap onto the member node ids
                    for v in rec.sg_replaced[start..].iter_mut() {
                        *v = members[*v];
                    }
                    h
                } else {
                    c.safeguard.apply_hybrid(&dots, &w, &g, &mut dirs)
                };
                let weights = combine_weights(cluster, c.combine, members);
                d = combine_hybrids_members(
                    cluster, dirs, &weights, &w, &g, sparse, members,
                );
            }
            last_hits = hits;
            let staleness_seen: Vec<usize> =
                contribs.iter().map(|cb| cb.staleness).collect();
            cluster.ledger.record_async_round(&staleness_seen, fell_back);
            if obs.on() {
                // marks the record as having run the quorum path —
                // the offline reader replays `record_async_round`
                // from exactly the (staleness, fallback) pair above
                obs.rec().is_async = true;
            }

            // --- step 8: distributed line search on margins (the
            // synchronous driver's, verbatim): dʳ·xᵢ lands in each
            // node's reusable NodeScratch::dz ---
            let d_ref = &d;
            cluster.engine.set_phase("dir_matvec");
            cluster.map_each_scratch_ctrl_members(members, |_, shard, s| {
                shard.gather_frame(compact, d_ref, &mut s.buf);
                s.dz.resize(shard.xl.n_rows(), 0.0);
                shard.xl.matvec(&s.buf, &mut s.dz);
            });
            let lam_part = PhiLambda::new(c.lam, &w, &d);
            let loss_kind = c.loss;
            let margins_ref = &margins;
            let ls = strong_wolfe(
                |t| {
                    let [lsum, dlsum] = cluster
                        .map_reduce_scalars_scratch_members(
                            members,
                            |p, shard, s| {
                                let phi = MarginPhi {
                                    z: &margins_ref[p],
                                    dz: &s.dz,
                                    y: &shard.y,
                                    loss: loss_kind,
                                };
                                let (a, b) = phi.partial(t);
                                [a, b]
                            },
                        );
                    lam_part.compose(t, lsum, dlsum)
                },
                &c.wolfe,
            );
            let t = match ls {
                Ok(res) => {
                    f = res.phi_t;
                    if obs.on() {
                        let rec = obs.rec();
                        rec.step = Some(res.t);
                        rec.ls_evals = Some(res.evals);
                    }
                    res.t
                }
                Err(_) => {
                    obs.commit(cluster);
                    break;
                }
            };
            // --- step 9: members advance their margin caches (only
            // they have current margins and a fresh dʳ·xᵢ in dz) ---
            dense::axpy(t, &d, &mut w);
            for &p in members {
                let s = cluster.scratch[p].lock().expect("scratch lock");
                dense::axpy(t, &s.dz, &mut margins[p]);
            }
            obs.commit(cluster);
        }
        // the compact master's single O(d) pass
        let w = if compact { cluster.umap.expand(&w, dim) } else { w };
        RunResult { w, f, trace, ledger: cluster.ledger.clone() }
    }
}
