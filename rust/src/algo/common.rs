//! Shared driver machinery: distributed value/gradient rounds, the
//! master-side view of f as an [`Objective`] (for SQM's TRON/L-BFGS),
//! and ledger-free diagnostics.
//!
//! Every per-node phase here runs in the shard's compact support
//! coordinates: the global iterate is gathered onto the support
//! (O(|support_p|)), the shard sweep accumulates into a support-aligned
//! scratch buffer, and the result either scatters to a dense wire
//! vector (dense regime — where the per-node O(d) wire buffer is the
//! payload itself and support ≈ d anyway) or ships directly as
//! index/value pairs (sparse regime, where no node touches a size-d
//! buffer at all).

use std::cell::RefCell;

use crate::cluster::{Cluster, Shard};
use crate::data::dataset::Dataset;
use crate::linalg::dense;
use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::loss::LossKind;
use crate::metrics::auprc::auprc;
use crate::objective::{
    shard_loss_grad_compact, shard_loss_grad_compact_cached, Objective,
};

/// One distributed value+gradient round at `w`:
/// nodes compute (Σ_p l, ∇L_p) from their shard; the gradient parts are
/// tree-reduced. Returns (f(w), ∇f(w), per-node ∇L_p, per-node margins).
///
/// Communication charged: `passes` (2 = allreduce, nodes keep gʳ — what
/// FS needs for the tilt; 1 = master-only reduce — what SQM needs).
/// The per-node margins zᵢ = w·xᵢ are the paper's step-1 by-product,
/// kept node-local for the line search.
pub fn global_value_grad(
    cluster: &mut Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
) -> (f64, Vec<f64>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let dim = cluster.dim;
    cluster.engine.set_phase("grad_sweep");
    let parts: Vec<(f64, Vec<f64>, Vec<f64>)> =
        cluster.map_each_scratch(|_, shard, s| {
            shard.map.gather(w, &mut s.wloc);
            // lint: allow(no-alloc-in-steady-state) — cold-start round:
            // the fresh margins are this round's product (the caller
            // keeps them) and steady state uses the cached variant
            let mut z = Vec::new();
            let val = shard_loss_grad_compact(
                &shard.xl,
                &shard.y,
                &s.wloc,
                loss,
                &mut s.vals,
                Some(&mut z),
            );
            // lint: allow(no-dense-master, no-alloc-in-steady-state) — dense
            // regime wire payload: support ≈ d here and this O(d)
            // buffer IS the message the dense reduction moves
            let mut grad = vec![0.0; dim];
            shard.map.scatter_add(&s.vals, 1.0, &mut grad);
            (val, grad, z)
        });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    let mut margins = Vec::with_capacity(parts.len());
    for (v, g, z) in parts {
        loss_sum += v;
        grad_parts.push(g);
        margins.push(z);
    }
    let mut g = cluster.reduce_parts(&grad_parts, all);
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, grad_parts, margins)
}

/// Like [`global_value_grad`] but with the margins zᵢ = w·xᵢ already
/// node-local (the FS driver maintains them incrementally across outer
/// iterations: z ← z + t·(dʳ·x) after each line search). Skips the
/// X·w matvec — one data pass instead of two (§Perf), and needs no
/// gather of w at all.
pub fn global_value_grad_cached(
    cluster: &mut Cluster,
    margins: &[Vec<f64>],
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
) -> (f64, Vec<f64>, Vec<Vec<f64>>) {
    let dim = cluster.dim;
    cluster.engine.set_phase("grad_sweep");
    let parts: Vec<(f64, Vec<f64>)> =
        cluster.map_each_scratch(|p, shard, s| {
            let z = &margins[p];
            debug_assert_eq!(z.len(), shard.xl.n_rows());
            let val = shard_loss_grad_compact_cached(
                &shard.xl,
                &shard.y,
                z,
                loss,
                &mut s.vals,
            );
            // lint: allow(no-dense-master, no-alloc-in-steady-state) — dense
            // regime wire payload: support ≈ d here and this O(d)
            // buffer IS the message the dense reduction moves
            let mut grad = vec![0.0; dim];
            shard.map.scatter_add(&s.vals, 1.0, &mut grad);
            (val, grad)
        });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    for (v, g) in parts {
        loss_sum += v;
        grad_parts.push(g);
    }
    let mut g = cluster.reduce_parts(&grad_parts, all);
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, grad_parts)
}

/// Per-node loss gradients from one distributed round — dense vectors
/// on the dense path, support-aligned index/value pairs on the sparse
/// path (node p's `idx` is exactly the shard support, zeros kept, so
/// `val` doubles as the support-aligned ∇L_p the compact tilt needs).
pub enum LocalGrads {
    Dense(Vec<Vec<f64>>),
    Sparse(Vec<SparseVec>),
}

impl LocalGrads {
    pub fn len(&self) -> usize {
        match self {
            LocalGrads::Dense(v) => v.len(),
            LocalGrads::Sparse(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Node p's tilt for the paper's eq. (2): gʳ − λwʳ − ∇L_p(wʳ),
    /// materialized in full space (reference/tests; the drivers use
    /// [`Self::support_vals`] and stay compact).
    pub fn tilt(&self, p: usize, lam: f64, w_r: &[f64], g_r: &[f64]) -> Vec<f64> {
        let mut t: Vec<f64> =
            w_r.iter().zip(g_r).map(|(w, g)| g - lam * w).collect();
        match self {
            LocalGrads::Dense(gs) => {
                for (tj, gj) in t.iter_mut().zip(&gs[p]) {
                    *tj -= gj;
                }
            }
            LocalGrads::Sparse(gs) => gs[p].axpy_into(-1.0, &mut t),
        }
        t
    }

    /// Node p's ∇L_p(wʳ) aligned to its shard support. Sparse parts are
    /// stored support-aligned already (indexed by global column on the
    /// dense master, by U position on the compact master — `val` is
    /// the same support-aligned slice either way); dense parts gather
    /// into `buf`.
    pub fn support_vals<'a>(
        &'a self,
        p: usize,
        map: &SupportMap,
        buf: &'a mut Vec<f64>,
    ) -> &'a [f64] {
        match self {
            LocalGrads::Sparse(gs) => {
                debug_assert_eq!(gs[p].val.len(), map.len());
                &gs[p].val
            }
            LocalGrads::Dense(gs) => {
                map.gather(&gs[p], buf);
                buf
            }
        }
    }
}

/// [`global_value_grad`] with the gradient round routed through the
/// sparse phases when `sparse` is set: each node ships its
/// support-restricted ∇L_p as index/value pairs, the tree merges by
/// column, and λw is applied at the master after the reduce. Identical
/// math either way — only the wire format and its ledger charge differ.
pub fn global_value_grad_auto(
    cluster: &mut Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
) -> (f64, Vec<f64>, LocalGrads, Vec<Vec<f64>>) {
    global_value_grad_master(cluster, w, loss, lam, all, sparse, false)
}

/// Master-frame-aware gradient round. With `compact` set the whole
/// round runs in the cluster's union support U: `w` is the length-|U|
/// compact iterate, nodes gather it through their composed U
/// positions, ship U-position-indexed payloads (dim |U|), and the
/// returned gradient is the length-|U| compact ∇f — no O(d) buffer
/// anywhere. The index remap is a monotone bijection, so sums land
/// coordinate-for-coordinate identical to the dense-master sparse
/// round. `compact` implies the sparse wire format.
pub fn global_value_grad_master(
    cluster: &mut Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
    compact: bool,
) -> (f64, Vec<f64>, LocalGrads, Vec<Vec<f64>>) {
    if !sparse && !compact {
        let (f, g, parts, margins) =
            global_value_grad(cluster, w, loss, lam, all);
        return (f, g, LocalGrads::Dense(parts), margins);
    }
    let fdim = if compact { cluster.umap.len() } else { cluster.dim };
    cluster.engine.set_phase("grad_sweep");
    let parts: Vec<(f64, SparseVec, Vec<f64>)> =
        cluster.map_each_scratch(|_, shard, s| {
            shard.gather_frame(compact, w, &mut s.wloc);
            // lint: allow(no-alloc-in-steady-state) — cold-start round:
            // the fresh margins are this round's product (the caller
            // keeps them) and steady state uses the cached variant
            let mut z = Vec::new();
            let val = shard_loss_grad_compact(
                &shard.xl,
                &shard.y,
                &s.wloc,
                loss,
                &mut s.vals,
                Some(&mut z),
            );
            (val, shard.support_sparse(compact, fdim, &s.vals), z)
        });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    let mut margins = Vec::with_capacity(parts.len());
    for (v, g, z) in parts {
        loss_sum += v;
        grad_parts.push(g);
        margins.push(z);
    }
    let mut g = cluster.reduce_parts_sparse(&grad_parts, all).into_dense();
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, LocalGrads::Sparse(grad_parts), margins)
}

/// Cached-margin counterpart of [`global_value_grad_auto`].
pub fn global_value_grad_cached_auto(
    cluster: &mut Cluster,
    margins: &[Vec<f64>],
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
) -> (f64, Vec<f64>, LocalGrads) {
    global_value_grad_cached_master(
        cluster, margins, w, loss, lam, all, sparse, false,
    )
}

/// Cached-margin counterpart of [`global_value_grad_master`].
#[allow(clippy::too_many_arguments)]
pub fn global_value_grad_cached_master(
    cluster: &mut Cluster,
    margins: &[Vec<f64>],
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
    compact: bool,
) -> (f64, Vec<f64>, LocalGrads) {
    if !sparse && !compact {
        let (f, g, parts) =
            global_value_grad_cached(cluster, margins, w, loss, lam, all);
        return (f, g, LocalGrads::Dense(parts));
    }
    let fdim = if compact { cluster.umap.len() } else { cluster.dim };
    cluster.engine.set_phase("grad_sweep");
    let parts: Vec<(f64, SparseVec)> =
        cluster.map_each_scratch(|p, shard, s| {
            debug_assert_eq!(margins[p].len(), shard.xl.n_rows());
            let val = shard_loss_grad_compact_cached(
                &shard.xl,
                &shard.y,
                &margins[p],
                loss,
                &mut s.vals,
            );
            (val, shard.support_sparse(compact, fdim, &s.vals))
        });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    for (v, g) in parts {
        loss_sum += v;
        grad_parts.push(g);
    }
    let mut g = cluster.reduce_parts_sparse(&grad_parts, all).into_dense();
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, LocalGrads::Sparse(grad_parts))
}

/// Elastic-membership gradient round for the fault-tolerant drivers:
/// only `members` (the round's [`RoundWeather`] survivors) sweep their
/// shards, and each member runs warm (cached margins) or cold (fresh
/// X·w matvec — a node re-based after a rejoin) *per node*, so one
/// recovering straggler doesn't force the whole fleet back to the
/// two-pass round. `margins` is the driver's full-length cache: member
/// entries are refreshed in place (cold members get brand-new margins),
/// non-member entries are left untouched. The returned [`LocalGrads`]
/// is full-length with empty placeholders for non-members — drivers
/// index it by node id and only ever read member slots.
///
/// With full membership this delegates outright to
/// [`global_value_grad_master`] (all-cold) or
/// [`global_value_grad_cached_master`] (all-warm), so a zero-fault run
/// is structurally bit-identical to the pre-fault path. The returned f
/// during a degraded round is the objective over the *member* shards
/// (plus the full λ‖w‖²/2) — the honest value the quorum can see.
///
/// [`RoundWeather`]: crate::cluster::RoundWeather
#[allow(clippy::too_many_arguments)]
pub fn global_value_grad_fleet(
    cluster: &mut Cluster,
    members: &[usize],
    margins: &mut Vec<Vec<f64>>,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
    compact: bool,
) -> (f64, Vec<f64>, LocalGrads) {
    let n = cluster.n_nodes();
    let full = members.len() == n;
    if full && margins.is_empty() {
        let (f, g, gp, z) = global_value_grad_master(
            cluster, w, loss, lam, all, sparse, compact,
        );
        *margins = z;
        return (f, g, gp);
    }
    if margins.len() != n {
        margins.resize(n, Vec::new());
    }
    let all_warm = (0..n)
        .all(|p| margins[p].len() == cluster.shards[p].xl.n_rows());
    if full && all_warm {
        return global_value_grad_cached_master(
            cluster, margins, w, loss, lam, all, sparse, compact,
        );
    }
    let fdim = if compact { cluster.umap.len() } else { cluster.dim };
    cluster.engine.set_phase("grad_sweep");
    if sparse || compact {
        let parts: Vec<(f64, SparseVec, Option<Vec<f64>>)> = {
            let margins_ref: &Vec<Vec<f64>> = margins;
            cluster.map_each_scratch_members(members, |p, shard, s| {
                if margins_ref[p].len() == shard.xl.n_rows() {
                    let val = shard_loss_grad_compact_cached(
                        &shard.xl,
                        &shard.y,
                        &margins_ref[p],
                        loss,
                        &mut s.vals,
                    );
                    (val, shard.support_sparse(compact, fdim, &s.vals), None)
                } else {
                    shard.gather_frame(compact, w, &mut s.wloc);
                    // lint: allow(no-alloc-in-steady-state) — cold rejoin
                    // round: the fresh margins are this round's product
                    // (the caller keeps them); warm members stay cached
                    let mut z = Vec::new();
                    let val = shard_loss_grad_compact(
                        &shard.xl,
                        &shard.y,
                        &s.wloc,
                        loss,
                        &mut s.vals,
                        Some(&mut z),
                    );
                    (
                        val,
                        shard.support_sparse(compact, fdim, &s.vals),
                        Some(z),
                    )
                }
            })
        };
        let mut loss_sum = 0.0;
        let mut member_parts: Vec<SparseVec> =
            Vec::with_capacity(parts.len());
        for (&p, (v, gpart, z)) in members.iter().zip(parts) {
            loss_sum += v;
            if let Some(z) = z {
                margins[p] = z;
            }
            member_parts.push(gpart);
        }
        let mut g = cluster
            .reduce_parts_sparse_members(&member_parts, all, members)
            .into_dense();
        dense::axpy(lam, w, &mut g);
        let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
        let mut grads: Vec<SparseVec> =
            (0..n).map(|_| SparseVec::new(fdim)).collect();
        for (&p, gpart) in members.iter().zip(member_parts) {
            grads[p] = gpart;
        }
        (f, g, LocalGrads::Sparse(grads))
    } else {
        let dim = cluster.dim;
        let parts: Vec<(f64, Vec<f64>, Option<Vec<f64>>)> = {
            let margins_ref: &Vec<Vec<f64>> = margins;
            cluster.map_each_scratch_members(members, |p, shard, s| {
                let (val, z) = if margins_ref[p].len()
                    == shard.xl.n_rows()
                {
                    let val = shard_loss_grad_compact_cached(
                        &shard.xl,
                        &shard.y,
                        &margins_ref[p],
                        loss,
                        &mut s.vals,
                    );
                    (val, None)
                } else {
                    shard.map.gather(w, &mut s.wloc);
                    // lint: allow(no-alloc-in-steady-state) — cold rejoin
                    // round: the fresh margins are this round's product
                    let mut z = Vec::new();
                    let val = shard_loss_grad_compact(
                        &shard.xl,
                        &shard.y,
                        &s.wloc,
                        loss,
                        &mut s.vals,
                        Some(&mut z),
                    );
                    (val, Some(z))
                };
                // lint: allow(no-dense-master, no-alloc-in-steady-state) — dense
                // regime wire payload: support ≈ d here and this O(d)
                // buffer IS the message the dense reduction moves
                let mut grad = vec![0.0; dim];
                shard.map.scatter_add(&s.vals, 1.0, &mut grad);
                (val, grad, z)
            })
        };
        let mut loss_sum = 0.0;
        let mut member_parts: Vec<Vec<f64>> =
            Vec::with_capacity(parts.len());
        for (&p, (v, gpart, z)) in members.iter().zip(parts) {
            loss_sum += v;
            if let Some(z) = z {
                margins[p] = z;
            }
            member_parts.push(gpart);
        }
        let mut g =
            cluster.reduce_parts_members(&member_parts, all, members);
        dense::axpy(lam, w, &mut g);
        let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
        let mut grads: Vec<Vec<f64>> =
            (0..n).map(|_| Vec::new()).collect();
        for (&p, gpart) in members.iter().zip(member_parts) {
            grads[p] = gpart;
        }
        (f, g, LocalGrads::Dense(grads))
    }
}

/// Ledger-free objective evaluation (plot diagnostics, f* computation).
pub fn global_f_diagnostic(
    cluster: &Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
) -> f64 {
    global_f_frame(cluster, w, loss, lam, false)
}

/// Frame-aware [`global_f_diagnostic`]: with `compact` the iterate is
/// the length-|U| compact vector and shards gather through their U
/// positions. Same value either way (coordinates outside U are 0).
pub fn global_f_frame(
    cluster: &Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    compact: bool,
) -> f64 {
    let mut v = 0.5 * lam * dense::norm_sq(w);
    let mut wl = Vec::new();
    for shard in &cluster.shards {
        shard.gather_frame(compact, w, &mut wl);
        for i in 0..shard.xl.n_rows() {
            v += loss.value(shard.xl.row_dot(i, &wl), shard.y[i]);
        }
    }
    v
}

/// Test-set AUPRC — diagnostics, never charged.
pub fn test_auprc(test: Option<&Dataset>, w: &[f64]) -> f64 {
    match test {
        None => f64::NAN,
        Some(t) => {
            let mut z = vec![0.0; t.n_examples()];
            t.x.matvec(w, &mut z);
            auprc(&z, &t.y)
        }
    }
}

/// Per-round test-set probe that works in whichever frame the driver's
/// master iterate lives in. The compact variant remaps the test matrix
/// onto the union support ONCE at construction (columns outside U
/// carry weight exactly 0 — they have no training data — so dropping
/// their terms changes no margin), keeping the per-round probe
/// O(nnz_test) with no full-d materialization.
pub enum TestProbe<'a> {
    None,
    /// dense master: score the size-d iterate directly
    Dense(&'a Dataset),
    /// compact master: test matrix with columns remapped to U positions
    Compact { x: crate::linalg::Csr, y: &'a [f64] },
}

impl<'a> TestProbe<'a> {
    /// `umap = Some(U)` selects the compact probe (the master iterate
    /// is length |U|); `None` keeps the classic dense scoring.
    pub fn new(
        test: Option<&'a Dataset>,
        umap: Option<&SupportMap>,
    ) -> TestProbe<'a> {
        match (test, umap) {
            (None, _) => TestProbe::None,
            (Some(t), None) => TestProbe::Dense(t),
            (Some(t), Some(u)) => {
                TestProbe::Compact { x: u.remap_csr(&t.x), y: &t.y }
            }
        }
    }

    /// AUPRC of the current master iterate (NaN without a test set).
    pub fn auprc(&self, w: &[f64]) -> f64 {
        match self {
            TestProbe::None => f64::NAN,
            TestProbe::Dense(t) => test_auprc(Some(*t), w),
            TestProbe::Compact { x, y } => {
                let mut z = vec![0.0; x.n_rows()];
                x.matvec(w, &mut z);
                auprc(&z, y)
            }
        }
    }
}

/// Master-side view of the full distributed objective for TRON/L-BFGS:
/// every `value_grad` costs a w-broadcast (1 pass) + gradient reduce
/// (1 pass); every `hess_vec` costs a v-broadcast + Hv reduce (the SQM
/// communication pattern the paper contrasts against).
pub struct DistributedObjective<'a> {
    pub cluster: RefCell<&'a mut Cluster>,
    pub loss: LossKind,
    pub lam: f64,
    /// route gradient/Hv rounds through the sparse phases (decided once
    /// from the cluster's shard support density)
    pub sparse: bool,
}

impl<'a> DistributedObjective<'a> {
    pub fn new(
        cluster: &'a mut Cluster,
        loss: LossKind,
        lam: f64,
    ) -> DistributedObjective<'a> {
        let sparse = cluster.prefer_sparse();
        DistributedObjective { cluster: RefCell::new(cluster), loss, lam, sparse }
    }
}

impl<'a> Objective for DistributedObjective<'a> {
    fn dim(&self) -> usize {
        self.cluster.borrow().dim
    }

    fn value(&self, w: &[f64]) -> f64 {
        let mut out = vec![0.0; w.len()];
        self.value_grad(w, &mut out)
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        self.value_grad(w, out);
    }

    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        let cluster = &mut **self.cluster.borrow_mut();
        // master ships the trial w — O(|U|) payload under the
        // compact-master density gate (SQM iterates live in U too:
        // w⁰ = 0 and every update is a combination of gradients and
        // Hv products, both supported in U)
        cluster.broadcast_master();
        let (f, g, _, _) = global_value_grad_auto(
            cluster, w, self.loss, self.lam, false, self.sparse,
        );
        out.copy_from_slice(&g);
        f
    }

    /// H·v = λv + Σ_p X_pᵀ D_p X_p v, computed node-local over compact
    /// support buffers and reduced. The loss part of each node's
    /// product is supported on the shard's columns; the branches differ
    /// only in whether the support-aligned values scatter to a dense
    /// wire vector or ship as index/value pairs.
    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        let cluster = &mut **self.cluster.borrow_mut();
        cluster.broadcast_master(); // ship v (CG directions live in U)
        let loss = self.loss;
        let dim = cluster.dim;
        cluster.engine.set_phase("hv_product");
        let hv = if self.sparse {
            let parts: Vec<SparseVec> =
                cluster.map_each_scratch(|_, shard, s| {
                    shard.map.gather(w, &mut s.wloc);
                    shard.map.gather(v, &mut s.gloc);
                    hess_vals(shard, loss, &s.wloc, &s.gloc, &mut s.vals);
                    shard.map.to_sparse_aligned(dim, &s.vals)
                });
            cluster.reduce_parts_sparse(&parts, false).into_dense()
        } else {
            let parts: Vec<Vec<f64>> =
                cluster.map_each_scratch(|_, shard, s| {
                    shard.map.gather(w, &mut s.wloc);
                    shard.map.gather(v, &mut s.gloc);
                    hess_vals(shard, loss, &s.wloc, &s.gloc, &mut s.vals);
                    // lint: allow(no-dense-master, no-alloc-in-steady-state) — dense
                    // branch: this O(d) buffer IS the wire message the
                    // dense Hv reduction moves
                    let mut hv = vec![0.0; dim];
                    shard.map.scatter_add(&s.vals, 1.0, &mut hv);
                    hv
                });
            cluster.reduce_parts(&parts, false)
        };
        out.copy_from_slice(&hv);
        dense::axpy(self.lam, v, out);
    }
}

/// One shard's Hessian-vector row sweep over compact coordinates:
/// vals ← Σᵢ dᵢᵢ·(xᵢ·v)·xᵢ accumulated support-aligned.
fn hess_vals(
    shard: &Shard,
    loss: LossKind,
    wl: &[f64],
    vl: &[f64],
    vals: &mut Vec<f64>,
) {
    vals.clear();
    vals.resize(shard.xl.n_cols, 0.0);
    for i in 0..shard.xl.n_rows() {
        let zi = shard.xl.row_dot(i, wl);
        let dii = loss.second_deriv(zi, shard.y[i]);
        if dii != 0.0 {
            let xv = shard.xl.row_dot(i, vl);
            shard.xl.add_row_scaled(i, dii * xv, vals);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;
    use crate::linalg::Csr;
    use crate::objective::RegularizedLoss;

    fn setup() -> (Cluster, Dataset) {
        let data = SynthConfig {
            n_examples: 90,
            n_features: 20,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(4);
        let test = SynthConfig {
            n_examples: 50,
            n_features: 20,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(5);
        (Cluster::partition(data, 3, CostModel::free()), test)
    }

    #[test]
    fn distributed_value_grad_matches_single_machine() {
        let (mut cluster, _) = setup();
        // reassemble the full dataset for the oracle
        let loss = LossKind::Logistic;
        let lam = 0.2;
        let w: Vec<f64> = (0..20).map(|j| (j as f64 * 0.07).sin()).collect();
        let (f, g, grad_parts, margins) =
            global_value_grad(&mut cluster, &w, loss, lam, true);

        // oracle: stitch shards together
        let mut val = 0.5 * lam * dense::norm_sq(&w);
        let mut grad = vec![0.0; 20];
        for shard in &cluster.shards {
            let x = shard.stitch(20);
            let o = RegularizedLoss { x: &x, y: &shard.y, loss, lam: 0.0 };
            let mut gs = vec![0.0; 20];
            val += o.value_grad(&w, &mut gs);
            dense::axpy(1.0, &gs, &mut grad);
        }
        dense::axpy(lam, &w, &mut grad);
        assert!((f - val).abs() < 1e-9);
        assert!(dense::max_abs_diff(&g, &grad) < 1e-9);
        assert_eq!(grad_parts.len(), 3);
        assert_eq!(margins.len(), 3);
        // margins really are the per-shard X·w
        let mut wl = Vec::new();
        for (shard, z) in cluster.shards.iter().zip(&margins) {
            shard.map.gather(&w, &mut wl);
            for i in 0..shard.xl.n_rows() {
                assert!((z[i] - shard.xl.row_dot(i, &wl)).abs() < 1e-12);
            }
        }
        assert_eq!(cluster.ledger.comm_passes, 2.0);
    }

    #[test]
    fn distributed_objective_matches_and_charges() {
        let (mut cluster, _) = setup();
        let w: Vec<f64> = (0..20).map(|j| 0.05 * j as f64).collect();
        let v: Vec<f64> = (0..20).map(|j| ((j * 13 % 7) as f64) - 3.0).collect();
        // oracle over the stitched data
        let stitched: Vec<(Csr, Vec<f64>)> = cluster
            .shards
            .iter()
            .map(|s| (s.stitch(20), s.y.clone()))
            .collect();
        let obj = DistributedObjective::new(&mut cluster, LossKind::Logistic, 0.3);
        let mut g = vec![0.0; 20];
        let f = obj.value_grad(&w, &mut g);
        let mut hv = vec![0.0; 20];
        obj.hess_vec(&w, &v, &mut hv);

        let mut f_want = 0.5 * 0.3 * dense::norm_sq(&w);
        let mut g_want = vec![0.0; 20];
        let mut hv_want = vec![0.0; 20];
        for (x, y) in &stitched {
            let o = RegularizedLoss {
                x,
                y,
                loss: LossKind::Logistic,
                lam: 0.0,
            };
            let mut gs = vec![0.0; 20];
            f_want += o.value_grad(&w, &mut gs);
            dense::axpy(1.0, &gs, &mut g_want);
            let mut hvs = vec![0.0; 20];
            o.hess_vec(&w, &v, &mut hvs);
            dense::axpy(1.0, &hvs, &mut hv_want);
        }
        dense::axpy(0.3, &w, &mut g_want);
        dense::axpy(0.3, &v, &mut hv_want);
        assert!((f - f_want).abs() < 1e-9);
        assert!(dense::max_abs_diff(&g, &g_want) < 1e-9);
        assert!(dense::max_abs_diff(&hv, &hv_want) < 1e-9);
        // 2 passes per value_grad (bcast + reduce), 2 per hess_vec
        assert_eq!(cluster.ledger.comm_passes, 4.0);
    }

    #[test]
    fn sparse_auto_round_matches_dense_round() {
        // high-d/low-nnz so the sparse path is a genuine restriction
        let data = SynthConfig {
            n_examples: 90,
            n_features: 2_000,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(6);
        let c0 = Cluster::partition(data, 3, CostModel::default());
        let mut c_dense = c0.fork_fresh();
        let mut c_sparse = c0.fork_fresh();
        assert!(c_sparse.prefer_sparse(), "density {}", c_sparse.support_density());
        let w: Vec<f64> =
            (0..2_000).map(|j| (j as f64 * 0.013).sin() * 0.2).collect();
        let loss = LossKind::Logistic;
        let (f_d, g_d, parts_d, z_d) =
            global_value_grad(&mut c_dense, &w, loss, 0.3, true);
        let (f_s, g_s, parts_s, z_s) =
            global_value_grad_auto(&mut c_sparse, &w, loss, 0.3, true, true);
        assert!((f_d - f_s).abs() < 1e-12 * (1.0 + f_d.abs()));
        assert!(dense::max_abs_diff(&g_d, &g_s) < 1e-12);
        assert_eq!(z_d, z_s);
        // tilts agree between the dense and sparse representations
        assert_eq!(parts_s.len(), parts_d.len());
        let wrapped = LocalGrads::Dense(parts_d);
        for p in 0..parts_s.len() {
            let t_dense = wrapped.tilt(p, 0.3, &w, &g_d);
            let t_sparse = parts_s.tilt(p, 0.3, &w, &g_s);
            assert!(dense::max_abs_diff(&t_dense, &t_sparse) < 1e-12, "node {p}");
            // ...and the support-aligned view matches the dense gather
            let map = &c_sparse.shards[p].map;
            let mut buf = Vec::new();
            let sv = parts_s.support_vals(p, map, &mut buf);
            let mut buf2 = Vec::new();
            let dv = wrapped.support_vals(p, map, &mut buf2);
            assert_eq!(sv, dv, "node {p} support values");
        }
        // same logical passes, fewer bytes and seconds on the wire
        assert_eq!(
            c_dense.ledger.comm_passes,
            c_sparse.ledger.comm_passes
        );
        assert!(c_sparse.ledger.comm_bytes < c_dense.ledger.comm_bytes);
        assert!(c_sparse.ledger.comm_seconds < c_dense.ledger.comm_seconds);
        // the sparse round recorded its per-level wire profile
        assert_eq!(c_sparse.ledger.sparse_reductions, 1);
        assert!(!c_sparse.ledger.level_bytes.is_empty());
        assert!(!c_sparse.ledger.level_profile().is_empty());
        // cached round agrees too
        let (fc, gc, _) = global_value_grad_cached_auto(
            &mut c_sparse, &z_s, &w, loss, 0.3, true, true,
        );
        assert!((fc - f_s).abs() < 1e-12 * (1.0 + f_s.abs()));
        assert!(dense::max_abs_diff(&gc, &g_s) < 1e-12);
    }

    #[test]
    fn ring_and_tree_sparse_reductions_charge_same_bytes() {
        // satellite: the ring path is charged by actual nnz payload —
        // identical bytes to the tree (payload is payload), different
        // (modeled) seconds, both far below the dense-pass charge
        let data = SynthConfig {
            n_examples: 400,
            n_features: 50_000,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(17);
        let c0 = Cluster::partition(data, 8, CostModel::default());
        let mut c_tree = c0.fork_fresh();
        let mut c_ring = c0.fork_fresh();
        c_ring.cost.topology = crate::cluster::cost::Topology::Ring;
        assert!(c_tree.prefer_sparse() && c_ring.prefer_sparse());
        let w = vec![0.0; c0.dim];
        let loss = LossKind::Logistic;
        let _ = global_value_grad_auto(&mut c_tree, &w, loss, 0.5, true, true);
        let _ = global_value_grad_auto(&mut c_ring, &w, loss, 0.5, true, true);
        assert_eq!(c_tree.ledger.comm_bytes, c_ring.ledger.comm_bytes);
        assert!(c_ring.ledger.comm_seconds > 0.0);
        // both beat the dense wire charge for the same round
        let mut c_dense = c0.fork_fresh();
        let _ = global_value_grad(&mut c_dense, &w, loss, 0.5, true);
        assert!(c_tree.ledger.comm_bytes < c_dense.ledger.comm_bytes);
        assert!(c_ring.ledger.comm_bytes < c_dense.ledger.comm_bytes);
    }

    #[test]
    fn diagnostics_charge_nothing() {
        let (cluster, test) = setup();
        let w = vec![0.1; 20];
        let f = global_f_diagnostic(&cluster, &w, LossKind::Logistic, 0.2);
        assert!(f.is_finite() && f > 0.0);
        let a = test_auprc(Some(&test), &w);
        assert!((0.0..=1.0).contains(&a));
        assert!(test_auprc(None, &w).is_nan());
        assert_eq!(cluster.ledger.comm_passes, 0.0);
    }
}
