//! Shared driver machinery: distributed value/gradient rounds, the
//! master-side view of f as an [`Objective`] (for SQM's TRON/L-BFGS),
//! and ledger-free diagnostics.

use std::cell::RefCell;

use crate::cluster::{Cluster, Shard};
use crate::data::dataset::Dataset;
use crate::linalg::dense;
use crate::linalg::sparse::SparseVec;
use crate::loss::LossKind;
use crate::metrics::auprc::auprc;
use crate::objective::{
    shard_loss_grad, shard_loss_grad_sparse, shard_loss_grad_sparse_cached,
    Objective,
};

/// One distributed value+gradient round at `w`:
/// nodes compute (Σ_p l, ∇L_p) from their shard; the gradient parts are
/// tree-reduced. Returns (f(w), ∇f(w), per-node ∇L_p, per-node margins).
///
/// Communication charged: `passes` (2 = allreduce, nodes keep gʳ — what
/// FS needs for the tilt; 1 = master-only reduce — what SQM needs).
/// The per-node margins zᵢ = w·xᵢ are the paper's step-1 by-product,
/// kept node-local for the line search.
pub fn global_value_grad(
    cluster: &mut Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
) -> (f64, Vec<f64>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let dim = cluster.dim;
    let parts: Vec<(f64, Vec<f64>, Vec<f64>)> = cluster.map_each(|_, shard| {
        let mut grad = vec![0.0; dim];
        let mut z = Vec::new();
        let val =
            shard_loss_grad(&shard.x, &shard.y, w, loss, &mut grad, Some(&mut z));
        (val, grad, z)
    });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    let mut margins = Vec::with_capacity(parts.len());
    for (v, g, z) in parts {
        loss_sum += v;
        grad_parts.push(g);
        margins.push(z);
    }
    let mut g = cluster.reduce_parts(&grad_parts, all);
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, grad_parts, margins)
}

/// Like [`global_value_grad`] but with the margins zᵢ = w·xᵢ already
/// node-local (the FS driver maintains them incrementally across outer
/// iterations: z ← z + t·(dʳ·x) after each line search). Skips the
/// X·w matvec — one data pass instead of two (§Perf).
pub fn global_value_grad_cached(
    cluster: &mut Cluster,
    margins: &[Vec<f64>],
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
) -> (f64, Vec<f64>, Vec<Vec<f64>>) {
    let dim = cluster.dim;
    let parts: Vec<(f64, Vec<f64>)> = cluster.map_each(|p, shard| {
        let z = &margins[p];
        debug_assert_eq!(z.len(), shard.x.n_rows());
        let mut grad = vec![0.0; dim];
        let mut val = 0.0;
        for i in 0..shard.x.n_rows() {
            val += loss.value(z[i], shard.y[i]);
            let r = loss.deriv(z[i], shard.y[i]);
            if r != 0.0 {
                shard.x.add_row_scaled(i, r, &mut grad);
            }
        }
        (val, grad)
    });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    for (v, g) in parts {
        loss_sum += v;
        grad_parts.push(g);
    }
    let mut g = cluster.reduce_parts(&grad_parts, all);
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, grad_parts)
}

/// Per-node loss gradients from one distributed round — dense vectors
/// on the dense path, index/value pairs restricted to each shard's
/// support on the sparse path. FS only ever consumes these through
/// [`LocalGrads::tilt`], so the wire format stays an implementation
/// detail of the round.
pub enum LocalGrads {
    Dense(Vec<Vec<f64>>),
    Sparse(Vec<SparseVec>),
}

impl LocalGrads {
    pub fn len(&self) -> usize {
        match self {
            LocalGrads::Dense(v) => v.len(),
            LocalGrads::Sparse(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Node p's tilt for the paper's eq. (2): gʳ − λwʳ − ∇L_p(wʳ).
    pub fn tilt(&self, p: usize, lam: f64, w_r: &[f64], g_r: &[f64]) -> Vec<f64> {
        let mut t: Vec<f64> =
            w_r.iter().zip(g_r).map(|(w, g)| g - lam * w).collect();
        match self {
            LocalGrads::Dense(gs) => {
                for (tj, gj) in t.iter_mut().zip(&gs[p]) {
                    *tj -= gj;
                }
            }
            LocalGrads::Sparse(gs) => gs[p].axpy_into(-1.0, &mut t),
        }
        t
    }
}

/// [`global_value_grad`] with the gradient round routed through the
/// sparse phases when `sparse` is set: each node ships its
/// support-restricted ∇L_p as index/value pairs, the tree merges by
/// column, and λw is applied at the master after the reduce. Identical
/// math either way — only the wire format and its ledger charge differ.
pub fn global_value_grad_auto(
    cluster: &mut Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
) -> (f64, Vec<f64>, LocalGrads, Vec<Vec<f64>>) {
    if !sparse {
        let (f, g, parts, margins) =
            global_value_grad(cluster, w, loss, lam, all);
        return (f, g, LocalGrads::Dense(parts), margins);
    }
    let parts: Vec<(f64, SparseVec, Vec<f64>)> =
        cluster.map_each(|_, shard| {
            let mut z = Vec::new();
            let (val, grad) = shard_loss_grad_sparse(
                &shard.x, &shard.y, w, loss, &shard.map, Some(&mut z),
            );
            (val, grad, z)
        });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    let mut margins = Vec::with_capacity(parts.len());
    for (v, g, z) in parts {
        loss_sum += v;
        grad_parts.push(g);
        margins.push(z);
    }
    let mut g = cluster.reduce_parts_sparse(&grad_parts, all).into_dense();
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, LocalGrads::Sparse(grad_parts), margins)
}

/// Cached-margin counterpart of [`global_value_grad_auto`].
pub fn global_value_grad_cached_auto(
    cluster: &mut Cluster,
    margins: &[Vec<f64>],
    w: &[f64],
    loss: LossKind,
    lam: f64,
    all: bool,
    sparse: bool,
) -> (f64, Vec<f64>, LocalGrads) {
    if !sparse {
        let (f, g, parts) =
            global_value_grad_cached(cluster, margins, w, loss, lam, all);
        return (f, g, LocalGrads::Dense(parts));
    }
    let parts: Vec<(f64, SparseVec)> = cluster.map_each(|p, shard| {
        debug_assert_eq!(margins[p].len(), shard.x.n_rows());
        shard_loss_grad_sparse_cached(
            &shard.x,
            &shard.y,
            &margins[p],
            loss,
            &shard.map,
        )
    });
    let mut loss_sum = 0.0;
    let mut grad_parts = Vec::with_capacity(parts.len());
    for (v, g) in parts {
        loss_sum += v;
        grad_parts.push(g);
    }
    let mut g = cluster.reduce_parts_sparse(&grad_parts, all).into_dense();
    dense::axpy(lam, w, &mut g);
    let f = loss_sum + 0.5 * lam * dense::norm_sq(w);
    (f, g, LocalGrads::Sparse(grad_parts))
}

/// Ledger-free objective evaluation (plot diagnostics, f* computation).
pub fn global_f_diagnostic(
    cluster: &Cluster,
    w: &[f64],
    loss: LossKind,
    lam: f64,
) -> f64 {
    let mut v = 0.5 * lam * dense::norm_sq(w);
    for shard in &cluster.shards {
        for i in 0..shard.x.n_rows() {
            v += loss.value(shard.x.row_dot(i, w), shard.y[i]);
        }
    }
    v
}

/// Test-set AUPRC — diagnostics, never charged.
pub fn test_auprc(test: Option<&Dataset>, w: &[f64]) -> f64 {
    match test {
        None => f64::NAN,
        Some(t) => {
            let mut z = vec![0.0; t.n_examples()];
            t.x.matvec(w, &mut z);
            auprc(&z, &t.y)
        }
    }
}

/// Master-side view of the full distributed objective for TRON/L-BFGS:
/// every `value_grad` costs a w-broadcast (1 pass) + gradient reduce
/// (1 pass); every `hess_vec` costs a v-broadcast + Hv reduce (the SQM
/// communication pattern the paper contrasts against).
pub struct DistributedObjective<'a> {
    pub cluster: RefCell<&'a mut Cluster>,
    pub loss: LossKind,
    pub lam: f64,
    /// route gradient/Hv rounds through the sparse phases (decided once
    /// from the cluster's shard support density)
    pub sparse: bool,
}

impl<'a> DistributedObjective<'a> {
    pub fn new(
        cluster: &'a mut Cluster,
        loss: LossKind,
        lam: f64,
    ) -> DistributedObjective<'a> {
        let sparse = cluster.prefer_sparse();
        DistributedObjective { cluster: RefCell::new(cluster), loss, lam, sparse }
    }
}

impl<'a> Objective for DistributedObjective<'a> {
    fn dim(&self) -> usize {
        self.cluster.borrow().dim
    }

    fn value(&self, w: &[f64]) -> f64 {
        let mut out = vec![0.0; w.len()];
        self.value_grad(w, &mut out)
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        self.value_grad(w, out);
    }

    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        let cluster = &mut **self.cluster.borrow_mut();
        cluster.broadcast_vec(); // master ships the trial w
        let (f, g, _, _) = global_value_grad_auto(
            cluster, w, self.loss, self.lam, false, self.sparse,
        );
        out.copy_from_slice(&g);
        f
    }

    /// H·v = λv + Σ_p X_pᵀ D_p X_p v, computed node-local and reduced.
    /// The loss part of each node's product is supported on the shard's
    /// columns, so the sparse path ships it as index/value pairs. The
    /// row math lives once in [`hess_rows`]; the branches differ only
    /// in where each row's dᵢᵢ·(xᵢ·v) lands.
    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        let cluster = &mut **self.cluster.borrow_mut();
        cluster.broadcast_vec(); // ship v
        let loss = self.loss;
        let hv = if self.sparse {
            let parts: Vec<SparseVec> = cluster.map_each(|_, shard: &Shard| {
                let mut vals = vec![0.0; shard.map.support.len()];
                hess_rows(shard, loss, w, v, |i, a| {
                    shard.map.add_row_scaled(&shard.x, i, a, &mut vals)
                });
                SparseVec::from_support(
                    shard.x.n_cols,
                    &shard.map.support,
                    &vals,
                )
            });
            cluster.reduce_parts_sparse(&parts, false).into_dense()
        } else {
            let parts: Vec<Vec<f64>> = cluster.map_each(|_, shard: &Shard| {
                let mut hv = vec![0.0; v.len()];
                hess_rows(shard, loss, w, v, |i, a| {
                    shard.x.add_row_scaled(i, a, &mut hv)
                });
                hv
            });
            cluster.reduce_parts(&parts, false)
        };
        out.copy_from_slice(&hv);
        dense::axpy(self.lam, v, out);
    }
}

/// One shard's Hessian-vector row sweep: calls `add(i, dᵢᵢ·(xᵢ·v))`
/// for every row with curvature, leaving the accumulation target
/// (dense buffer vs support-restricted values) to the caller.
fn hess_rows(
    shard: &Shard,
    loss: LossKind,
    w: &[f64],
    v: &[f64],
    mut add: impl FnMut(usize, f64),
) {
    for i in 0..shard.x.n_rows() {
        let zi = shard.x.row_dot(i, w);
        let dii = loss.second_deriv(zi, shard.y[i]);
        if dii != 0.0 {
            let xv = shard.x.row_dot(i, v);
            add(i, dii * xv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;
    use crate::objective::RegularizedLoss;

    fn setup() -> (Cluster, Dataset) {
        let data = SynthConfig {
            n_examples: 90,
            n_features: 20,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(4);
        let test = SynthConfig {
            n_examples: 50,
            n_features: 20,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(5);
        (Cluster::partition(data, 3, CostModel::free()), test)
    }

    #[test]
    fn distributed_value_grad_matches_single_machine() {
        let (mut cluster, _) = setup();
        // reassemble the full dataset for the oracle
        let loss = LossKind::Logistic;
        let lam = 0.2;
        let w: Vec<f64> = (0..20).map(|j| (j as f64 * 0.07).sin()).collect();
        let (f, g, grad_parts, margins) =
            global_value_grad(&mut cluster, &w, loss, lam, true);

        // oracle: stitch shards together
        let mut val = 0.5 * lam * dense::norm_sq(&w);
        let mut grad = vec![0.0; 20];
        for shard in &cluster.shards {
            let o = RegularizedLoss { x: &shard.x, y: &shard.y, loss, lam: 0.0 };
            let mut gs = vec![0.0; 20];
            val += o.value_grad(&w, &mut gs);
            dense::axpy(1.0, &gs, &mut grad);
        }
        dense::axpy(lam, &w, &mut grad);
        assert!((f - val).abs() < 1e-9);
        assert!(dense::max_abs_diff(&g, &grad) < 1e-9);
        assert_eq!(grad_parts.len(), 3);
        assert_eq!(margins.len(), 3);
        // margins really are the per-shard X·w
        for (shard, z) in cluster.shards.iter().zip(&margins) {
            for i in 0..shard.x.n_rows() {
                assert!((z[i] - shard.x.row_dot(i, &w)).abs() < 1e-12);
            }
        }
        assert_eq!(cluster.ledger.comm_passes, 2.0);
    }

    #[test]
    fn distributed_objective_matches_and_charges() {
        let (mut cluster, _) = setup();
        let w: Vec<f64> = (0..20).map(|j| 0.05 * j as f64).collect();
        let v: Vec<f64> = (0..20).map(|j| ((j * 13 % 7) as f64) - 3.0).collect();
        // oracle over the stitched data
        let shards = cluster.shards.clone();
        let obj = DistributedObjective::new(&mut cluster, LossKind::Logistic, 0.3);
        let mut g = vec![0.0; 20];
        let f = obj.value_grad(&w, &mut g);
        let mut hv = vec![0.0; 20];
        obj.hess_vec(&w, &v, &mut hv);

        let mut f_want = 0.5 * 0.3 * dense::norm_sq(&w);
        let mut g_want = vec![0.0; 20];
        let mut hv_want = vec![0.0; 20];
        for s in &shards {
            let o = RegularizedLoss {
                x: &s.x,
                y: &s.y,
                loss: LossKind::Logistic,
                lam: 0.0,
            };
            let mut gs = vec![0.0; 20];
            f_want += o.value_grad(&w, &mut gs);
            dense::axpy(1.0, &gs, &mut g_want);
            let mut hvs = vec![0.0; 20];
            o.hess_vec(&w, &v, &mut hvs);
            dense::axpy(1.0, &hvs, &mut hv_want);
        }
        dense::axpy(0.3, &w, &mut g_want);
        dense::axpy(0.3, &v, &mut hv_want);
        assert!((f - f_want).abs() < 1e-9);
        assert!(dense::max_abs_diff(&g, &g_want) < 1e-9);
        assert!(dense::max_abs_diff(&hv, &hv_want) < 1e-9);
        // 2 passes per value_grad (bcast + reduce), 2 per hess_vec
        assert_eq!(cluster.ledger.comm_passes, 4.0);
    }

    #[test]
    fn sparse_auto_round_matches_dense_round() {
        // high-d/low-nnz so the sparse path is a genuine restriction
        let data = SynthConfig {
            n_examples: 90,
            n_features: 2_000,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(6);
        let c0 = Cluster::partition(data, 3, CostModel::default());
        let mut c_dense = c0.fork_fresh();
        let mut c_sparse = c0.fork_fresh();
        assert!(c_sparse.prefer_sparse(), "density {}", c_sparse.support_density());
        let w: Vec<f64> =
            (0..2_000).map(|j| (j as f64 * 0.013).sin() * 0.2).collect();
        let loss = LossKind::Logistic;
        let (f_d, g_d, parts_d, z_d) =
            global_value_grad(&mut c_dense, &w, loss, 0.3, true);
        let (f_s, g_s, parts_s, z_s) =
            global_value_grad_auto(&mut c_sparse, &w, loss, 0.3, true, true);
        assert!((f_d - f_s).abs() < 1e-12 * (1.0 + f_d.abs()));
        assert!(dense::max_abs_diff(&g_d, &g_s) < 1e-12);
        assert_eq!(z_d, z_s);
        // tilts agree between the dense and sparse representations
        assert_eq!(parts_s.len(), parts_d.len());
        let wrapped = LocalGrads::Dense(parts_d);
        for p in 0..parts_s.len() {
            let t_dense = wrapped.tilt(p, 0.3, &w, &g_d);
            let t_sparse = parts_s.tilt(p, 0.3, &w, &g_s);
            assert!(dense::max_abs_diff(&t_dense, &t_sparse) < 1e-12, "node {p}");
        }
        // same logical passes, fewer bytes and seconds on the wire
        assert_eq!(
            c_dense.ledger.comm_passes,
            c_sparse.ledger.comm_passes
        );
        assert!(c_sparse.ledger.comm_bytes < c_dense.ledger.comm_bytes);
        assert!(c_sparse.ledger.comm_seconds < c_dense.ledger.comm_seconds);
        // cached round agrees too
        let (fc, gc, _) = global_value_grad_cached_auto(
            &mut c_sparse, &z_s, &w, loss, 0.3, true, true,
        );
        assert!((fc - f_s).abs() < 1e-12 * (1.0 + f_s.abs()));
        assert!(dense::max_abs_diff(&gc, &g_s) < 1e-12);
    }

    #[test]
    fn diagnostics_charge_nothing() {
        let (cluster, test) = setup();
        let w = vec![0.1; 20];
        let f = global_f_diagnostic(&cluster, &w, LossKind::Logistic, 0.2);
        assert!(f.is_finite() && f > 0.0);
        let a = test_auprc(Some(&test), &w);
        assert!((0.0..=1.0).contains(&a));
        assert!(test_auprc(None, &w).is_nan());
        assert_eq!(cluster.ledger.comm_passes, 0.0);
    }
}
