//! The distributed training methods Figure 1 compares:
//!
//! - [`fs`] — **the paper's contribution** (Algorithm 1): batch descent
//!   whose direction comes from parallel SVRG on gradient-consistent
//!   local approximations. "FS-s" = s inner epochs.
//! - [`sqm`] — the Statistical Query Model baseline [10, 8]:
//!   distributed batch gradients feeding a master-side TRON (or L-BFGS).
//! - [`hybrid`] — SQM initialized by one round of parameter mixing.
//! - [`param_mix`] — iterative parameter mixing [5, 6] (the method the
//!   introduction critiques).
//! - [`autoswitch`] — the §Discussion (c) extension: FS early,
//!   SQM near the optimum.
//! - [`safeguard`] — Algorithm 1 step 6 (angle test vs −gʳ).
//! - [`async_fs`] — bounded-staleness asynchronous FS: an
//!   arrival-ordered quorum of (possibly stale, re-based) hybrid
//!   directions, with the safeguard as the correctness gate and a
//!   synchronous-barrier fallback.
//! - [`adapt`] — the typed [`adapt::Asynchrony`] policy the async
//!   driver runs under (Sync / Bounded / Adaptive) and the
//!   self-tuning (τ, q) controller driven by ledger state.

pub mod adapt;
pub mod async_fs;
pub mod autoswitch;
pub mod common;
pub mod fs;
pub mod hybrid;
pub mod param_mix;
pub mod safeguard;
pub mod sqm;
pub mod theory;

use crate::cluster::{Cluster, Ledger};
use crate::data::dataset::Dataset;
use crate::metrics::trace::Trace;

/// Termination policy shared by every driver. Whichever bound trips
/// first stops the run.
#[derive(Clone, Debug)]
pub struct StopRule {
    pub max_outer_iters: usize,
    /// stop when f ≤ target (used with a precomputed f* + ε)
    pub target_f: Option<f64>,
    /// stop when ‖g‖ ≤ rel·‖g⁰‖
    pub gnorm_rel: f64,
    pub max_comm_passes: f64,
    pub max_seconds: f64,
}

impl StopRule {
    /// Plain iteration budget.
    pub fn iters(n: usize) -> StopRule {
        StopRule {
            max_outer_iters: n,
            target_f: None,
            gnorm_rel: 1e-12,
            max_comm_passes: f64::INFINITY,
            max_seconds: f64::INFINITY,
        }
    }

    /// Budget on the paper's x-axes (passes and simulated seconds).
    pub fn budget(passes: f64, seconds: f64) -> StopRule {
        StopRule {
            max_outer_iters: usize::MAX,
            target_f: None,
            gnorm_rel: 1e-12,
            max_comm_passes: passes,
            max_seconds: seconds,
        }
    }

    pub fn with_target(mut self, f: f64) -> StopRule {
        self.target_f = Some(f);
        self
    }

    pub fn should_stop(
        &self,
        iter: usize,
        f: f64,
        gnorm: f64,
        gnorm0: f64,
        ledger: &Ledger,
    ) -> bool {
        iter >= self.max_outer_iters
            || self.target_f.map(|t| f <= t).unwrap_or(false)
            || gnorm <= self.gnorm_rel * gnorm0
            || ledger.comm_passes >= self.max_comm_passes
            || ledger.seconds() >= self.max_seconds
    }
}

/// What every driver returns.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub w: Vec<f64>,
    pub f: f64,
    pub trace: Trace,
    pub ledger: Ledger,
}

/// A distributed training method that can be driven over a cluster.
/// `test` (optional) is scored for AUPRC each outer iteration —
/// diagnostics only, never charged to the ledger.
pub trait Driver {
    fn name(&self) -> String;
    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_rule_trips_on_each_bound() {
        let l0 = Ledger::default();
        let l_comm = Ledger { comm_passes: 100.0, ..Ledger::default() };
        let l_time = Ledger { comm_seconds: 50.0, ..Ledger::default() };

        let r = StopRule::iters(10);
        assert!(r.should_stop(10, 1.0, 1.0, 1.0, &l0));
        assert!(!r.should_stop(9, 1.0, 1.0, 1.0, &l0));

        let r = StopRule::budget(50.0, 10.0);
        assert!(r.should_stop(0, 1.0, 1.0, 1.0, &l_comm));
        assert!(r.should_stop(0, 1.0, 1.0, 1.0, &l_time));
        assert!(!r.should_stop(0, 1.0, 1.0, 1.0, &l0));

        let r = StopRule::iters(100).with_target(0.5);
        assert!(r.should_stop(0, 0.4, 1.0, 1.0, &l0));
        assert!(!r.should_stop(0, 0.6, 1.0, 1.0, &l0));

        let mut r = StopRule::iters(100);
        r.gnorm_rel = 1e-3;
        assert!(r.should_stop(0, 1.0, 1e-4, 1.0, &l0));
    }
}
