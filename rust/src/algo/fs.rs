//! **Algorithm 1** — the paper's parallel SGD method ("FS-s").
//!
//! Per outer iteration r:
//! 1. distributed batch gradient gʳ at wʳ (margins zᵢ = wʳ·xᵢ kept
//!    node-local as the by-product);
//! 2. exit if gʳ = 0;
//! 3–5. every node builds the gradient-consistent approximation f̂_p
//!    (eq. 2) **in its shard's compact support coordinates** (see
//!    [`CompactApprox`]) and runs s epochs of SVRG from wʳ → w_p; the
//!    deviation d_p = w_p − wʳ leaves the node as a hybrid
//!    a_w·wʳ + a_g·gʳ + sparse-correction ([`HybridDir`]) — O(|S_p|)
//!    buffers and wire bytes, never O(d) per node;
//! 6. safeguard: ∠(−gʳ, d_p) ≥ θ ⇒ d_p ← −gʳ (computed from shared
//!    scalars + sparse dots, no densification);
//! 7. dʳ = convex combination of the d_p: coefficient sums + one sparse
//!    allreduce of the corrections; the master materializes dʳ in O(d);
//! 8. distributed Armijo–Wolfe line search on φ(t) = f(wʳ + t·dʳ),
//!    each trial costing one *scalar* aggregation round (the margins
//!    and dʳ·xᵢ are node-local) — the reason FS needs so few size-d
//!    communication passes;
//! 9. wʳ⁺¹ = wʳ + t·dʳ.
//!
//! Communication per iteration: one gradient allreduce (2 passes) + one
//! direction allreduce (2 passes) = 4, versus SQM/TRON's 2 + 2·(CG
//! iterations). That 4-vs-many gap is exactly Figure 1's left panels.
//!
//! **Pipelined mode** ([`FsConfig::pipeline`], CLI `--pipeline`):
//! round r's direction allreduce, safeguard scalars, broadcast and
//! line search ride the event engine's *control lane* and overlap
//! round r+1's gradient sweeps/solves on the self-paced node clocks —
//! the safeguard consumes the reduced direction when it lands. This is
//! a schedule, not an algorithm change: the simulated arithmetic (and
//! hence the objective trace) is bit-identical with pipelining on or
//! off; only the modeled makespan differs. It is the
//! optimistic-overlap bound of the async-parallel SGD literature
//! (arXiv:1505.04956, arXiv:1705.08030): a real async deployment hides
//! the control plane behind speculative node compute and reconciles
//! when the committed step lands.
//!
//! For asynchrony in the *maths* — stale directions combined under a
//! bounded-staleness quorum, with this module's safeguard as the
//! correctness gate — see [`crate::algo::async_fs`], which shares this
//! driver's per-node solve (`local_direction`) and step-7 combine
//! (`combine_hybrids`) verbatim.
//!
//! **Union-support compact master** ([`MasterMode`], CLI `--master`):
//! in the paper's regime (d ≫ nnz columns) the *nodes* have been
//! O(|support_p|) since the compact-coordinate pipeline, but a naive
//! master still burns several dense O(d) passes per outer round —
//! ‖gʳ‖, the shared `GlobalDots`, the dʳ materialization of step 7,
//! the line search's λ scalars and the step-9 axpy. Since every
//! iterate, gradient and direction of the outer loop is an affine
//! combination of w⁰ = 0, loss gradients (supported in
//! U = ⋃_p support_p) and support-sized corrections, the whole loop
//! provably lives in U: under the density gate
//! ([`Cluster::prefer_compact_master`]) this driver runs every master
//! buffer at length |U| — wʳ, gʳ, dʳ, the safeguard dots, `PhiLambda`
//! and the AUPRC probe — and materializes the full-d vector exactly
//! once, into [`RunResult::w`]. The U-position index remap is a
//! monotone bijection of the global columns, so every sum runs in the
//! same coordinate order and the two masters produce ε-identical
//! traces and safeguard decisions (`tests/compact_master.rs` pins
//! this across shard shapes, inner solvers and the async driver).
//! Wire payloads on the direction/gradient rounds are unchanged
//! (same nnz); broadcasts shrink to O(|U|)
//! ([`Cluster::broadcast_support`]).

use crate::algo::common::{
    global_value_grad_cached_master, global_value_grad_master, LocalGrads,
    TestProbe,
};
use crate::algo::safeguard::Safeguard;
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::allreduce::Reduced;
use crate::cluster::{Cluster, NodeScratch, Shard};
use crate::data::dataset::Dataset;
use crate::linalg::dense;
use crate::linalg::sparse::SparseVec;
use crate::loss::LossKind;
use crate::metrics::trace::{Trace, TracePoint};
use crate::objective::compact::{CompactApprox, GlobalDots, HybridDir};
use crate::obs::RoundObs;
use crate::opt::lbfgs::{self, LbfgsParams};
use crate::opt::linesearch::{strong_wolfe, MarginPhi, PhiLambda, WolfeParams};
use crate::opt::sag::{sag_epochs_with, SagParams};
use crate::opt::sgd::{sgd_epochs_shrink, SgdParams};
use crate::opt::svrg::{svrg_epochs_with, SvrgParams};
use crate::opt::tron::{self, TronParams};

/// Which local solver step 5 uses (paper §Discussion (b): SVRG is the
/// paper's choice; L-BFGS/TRON are the "interesting possibilities";
/// plain SGD deliberately lacks the strong-convergence property).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InnerSolver {
    Svrg,
    /// SAG [2] — the other strongly-convergent choice Theorem 2 covers
    Sag,
    Sgd,
    Lbfgs,
    Tron,
}

/// Step 7 policy. Any convex combination preserves descent; the paper
/// recommends simple averaging. Size-weighting is the natural ablation
/// when shards are unbalanced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    Average,
    SizeWeighted,
}

/// Which master-side representation the outer loop runs in (see the
/// module docs). `Auto` follows the cluster's union-support density
/// gate; the forced modes exist for the equivalence tests and the
/// `master_side` bench, which time both masters on identical data.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MasterMode {
    /// compact when `Cluster::prefer_compact_master()` (|U|/d < 0.5)
    #[default]
    Auto,
    /// force the classic full-d dense master
    Dense,
    /// force the O(|U|) union-support compact master
    Compact,
}

#[derive(Clone, Debug)]
pub struct FsConfig {
    pub loss: LossKind,
    pub lam: f64,
    /// s — SGD epochs per node per outer iteration
    pub epochs: usize,
    pub batch: usize,
    /// inner learning rate; None → 1/L̂ per shard
    pub lr: Option<f64>,
    pub safeguard: Safeguard,
    pub combine: Combine,
    pub wolfe: WolfeParams,
    pub inner: InnerSolver,
    pub seed: u64,
    /// pipelined schedule: overlap the direction allreduce + line
    /// search (control lane) with the next round's node compute.
    /// Timing-model only — results are bit-identical (see module docs).
    pub pipeline: bool,
    /// master-side frame: `Auto` (density-gated), or forced
    /// dense/compact for equivalence tests and benches.
    pub master: MasterMode,
}

impl Default for FsConfig {
    fn default() -> Self {
        FsConfig {
            loss: LossKind::Logistic,
            lam: 1e-3,
            epochs: 2,
            batch: 64,
            lr: None,
            safeguard: Safeguard::default(),
            combine: Combine::Average,
            wolfe: WolfeParams::default(),
            inner: InnerSolver::Svrg,
            seed: 0,
            pipeline: false,
            master: MasterMode::Auto,
        }
    }
}

impl MasterMode {
    /// Resolve the mode against the cluster's density gates. Returns
    /// `(compact, sparse)`: whether the master runs in the length-|U|
    /// compact frame, and whether gradient/direction rounds use the
    /// sparse wire format (the compact master always does — its
    /// payloads are U-position index/value pairs).
    pub(crate) fn resolve(self, cluster: &Cluster) -> (bool, bool) {
        let sparse = cluster.prefer_sparse();
        let compact = match self {
            MasterMode::Auto => sparse && cluster.prefer_compact_master(),
            MasterMode::Compact => true,
            MasterMode::Dense => false,
        };
        (compact, sparse || compact)
    }
}

pub struct FsDriver {
    pub config: FsConfig,
}

/// A compact local solve's raw outcome.
enum SolveOut {
    /// solver output point in compact coordinates (support + tail)
    Point(Vec<f64>),
    /// untilted SGD: support iterate + total off-support L2 shrink
    Shrink(Vec<f64>, f64),
}

impl FsDriver {
    pub fn new(config: FsConfig) -> FsDriver {
        FsDriver { config }
    }
}

/// Run the configured inner solver on the compact f̂_p from its own wʳ
/// (free function so the async driver reruns the exact same solves).
fn solve_local(
    c: &FsConfig,
    approx: &CompactApprox,
    node: usize,
    iter: usize,
    scratch: &mut NodeScratch,
) -> SolveOut {
    let seed = c
        .seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((iter as u64) << 20)
        .wrapping_add(node as u64);
    match c.inner {
            InnerSolver::Svrg => SolveOut::Point(
                svrg_epochs_with(
                    approx,
                    &approx.w_r,
                    &SvrgParams {
                        epochs: c.epochs,
                        batch: c.batch,
                        lr: c.lr,
                        seed,
                    },
                    &mut scratch.svrg,
                )
                .0,
            ),
            InnerSolver::Sag => SolveOut::Point(sag_epochs_with(
                approx,
                &approx.w_r,
                &SagParams { epochs: c.epochs, lr: c.lr, seed },
                &mut scratch.sag,
            )),
            InnerSolver::Sgd => {
                // plain SGD lacks the tilt machinery (it optimizes the
                // *untilted* f̃_p of eq. 1) — the ablation showing why
                // gradient consistency matters. Off-support coordinates
                // only ever L2-shrink, so the scalar Π(1−η_tλ) carries
                // the whole off-support story.
                let m = approx.m;
                let (w_c, shrink) = sgd_epochs_shrink(
                    approx.x,
                    approx.y,
                    c.loss,
                    c.lam,
                    &approx.w_r[..m],
                    &SgdParams {
                        epochs: c.epochs,
                        eta0: c.lr.unwrap_or(0.05),
                        seed,
                    },
                );
                SolveOut::Shrink(w_c, shrink)
            }
            InnerSolver::Lbfgs => SolveOut::Point(
                lbfgs::minimize(
                    approx,
                    &approx.w_r,
                    &LbfgsParams {
                        max_iter: c.epochs.max(1) * 2,
                        eps: 1e-10,
                        ..Default::default()
                    },
                )
                .w,
            ),
        InnerSolver::Tron => SolveOut::Point(
            tron::minimize(
                approx,
                &approx.w_r,
                &TronParams {
                    max_iter: c.epochs.max(1),
                    eps: 1e-10,
                    ..Default::default()
                },
            )
            .w,
        ),
    }
}

/// One node's steps 3–5: gather (wʳ, gʳ) onto the shard support, build
/// the compact f̂_p at the given reference, run the inner solver and
/// package the deviation as a [`HybridDir`]. Shared verbatim by the
/// synchronous driver (inside `map_each_scratch`) and the
/// bounded-staleness async driver (on its solver lanes), so the two
/// produce bit-identical directions from identical references.
///
/// `fdim`/`compact` name the master frame the reference vectors live
/// in: (d, false) for the dense master, (|U|, true) for the
/// union-support compact master — the gathered support values are
/// identical either way, only the correction's index dictionary
/// changes ([`Shard::dir_idx`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn local_direction(
    c: &FsConfig,
    p: usize,
    shard: &Shard,
    s: &mut NodeScratch,
    fdim: usize,
    compact: bool,
    dots: &GlobalDots,
    w: &[f64],
    g: &[f64],
    grads: &LocalGrads,
    iter: usize,
) -> HybridDir {
    shard.gather_frame(compact, w, &mut s.wloc);
    shard.gather_frame(compact, g, &mut s.gloc);
    let glp = grads.support_vals(p, &shard.map, &mut s.vals);
    let approx = CompactApprox::build(
        &shard.xl, &shard.y, c.loss, c.lam, dots, &s.wloc, &s.gloc, glp,
    );
    let out = solve_local(c, &approx, p, iter, s);
    let idx = shard.dir_idx(compact);
    match out {
        SolveOut::Point(w_p) => {
            let (a_w, a_g) = approx.off_support_coeffs(&w_p);
            HybridDir::from_compact_idx(
                idx,
                fdim,
                a_w,
                a_g,
                &w_p,
                &approx.w_r[..approx.m],
                &s.gloc,
            )
        }
        SolveOut::Shrink(w_c, shrink) => HybridDir::from_compact_idx(
            idx,
            fdim,
            shrink - 1.0,
            0.0,
            &w_c,
            &approx.w_r[..approx.m],
            &s.gloc,
        ),
    }
}

/// Algorithm 1 step 7 — the convex combination of safeguarded
/// directions, exactly as the synchronous driver runs it: coefficient
/// sums + one sparse allreduce of the weighted corrections in the
/// sparse regime, materialized dense parts through the classic dense
/// allreduce otherwise. Frame-agnostic: `w`/`g` and the correction
/// indices are whatever master frame the caller runs in (full-d dense
/// or length-|U| compact — the compact master materializes dʳ in
/// O(|U|) here, never O(d)). Shared by the FS driver and the async
/// driver's synchronous-fallback path so "the barrier direction" is
/// one implementation, not two.
pub(crate) fn combine_hybrids(
    cluster: &mut Cluster,
    dirs: Vec<HybridDir>,
    weights: &[f64],
    w: &[f64],
    g: &[f64],
    sparse: bool,
) -> Vec<f64> {
    let members: Vec<usize> = (0..cluster.n_nodes()).collect();
    combine_hybrids_members(cluster, dirs, weights, w, g, sparse, &members)
}

/// [`combine_hybrids`] under elastic membership: `dirs[i]` is member
/// `members[i]`'s safeguarded direction and the reduction tree spans
/// only those members — the fault-tolerant fallback path resolves the
/// barrier direction over whoever is actually alive this round. With
/// the full node set this IS [`combine_hybrids`] (the legacy entry
/// point delegates here).
#[allow(clippy::too_many_arguments)]
pub(crate) fn combine_hybrids_members(
    cluster: &mut Cluster,
    dirs: Vec<HybridDir>,
    weights: &[f64],
    w: &[f64],
    g: &[f64],
    sparse: bool,
    members: &[usize],
) -> Vec<f64> {
    debug_assert_eq!(dirs.len(), members.len());
    if sparse {
        let mut a_w_sum = 0.0;
        let mut a_g_sum = 0.0;
        let mut parts: Vec<SparseVec> = Vec::with_capacity(dirs.len());
        for (dp, &cw) in dirs.into_iter().zip(weights) {
            a_w_sum += cw * dp.a_w;
            a_g_sum += cw * dp.a_g;
            // scale in place — the direction set is consumed
            // here, so no support-sized copies
            let mut sv = dp.corr;
            sv.scale(cw);
            parts.push(sv);
        }
        // the (a_w, a_g) pair each node contributes rides a
        // scalar aggregation round alongside the corr reduce;
        // both land on the control lane so a pipelined
        // schedule overlaps them with the next round's sweeps
        cluster.charge_scalar_round_members(2, members);
        let reduced =
            cluster.reduce_parts_sparse_ctrl_members(&parts, true, members);
        let mut d: Vec<f64> = w
            .iter()
            .zip(g)
            .map(|(wj, gj)| a_w_sum * wj + a_g_sum * gj)
            .collect();
        match reduced {
            Reduced::Sparse(sv) => sv.axpy_into(1.0, &mut d),
            Reduced::Dense(v) => dense::axpy(1.0, &v, &mut d),
        }
        d
    } else {
        let parts: Vec<Vec<f64>> = dirs
            .into_iter()
            .zip(weights)
            .map(|(dp, &cw)| {
                let mut dd = dp.to_dense(w, g);
                dense::scale(&mut dd, cw);
                dd
            })
            .collect();
        cluster.reduce_parts_ctrl_members(&parts, true, members)
    }
}

/// Step 7's convex weights over the given shard set (node indices),
/// shared by the synchronous and async drivers.
pub(crate) fn combine_weights(
    cluster: &Cluster,
    combine: Combine,
    nodes: &[usize],
) -> Vec<f64> {
    match combine {
        Combine::Average => {
            let n = nodes.len() as f64;
            vec![1.0 / n; nodes.len()]
        }
        Combine::SizeWeighted => {
            let total: f64 = nodes
                .iter()
                .map(|&p| cluster.shards[p].n_examples() as f64)
                .sum();
            nodes
                .iter()
                .map(|&p| cluster.shards[p].n_examples() as f64 / total)
                .collect()
        }
    }
}

impl Driver for FsDriver {
    fn name(&self) -> String {
        let tag = match self.config.inner {
            InnerSolver::Svrg => "fs",
            InnerSolver::Sag => "fs+sag",
            InnerSolver::Sgd => "fs+sgd",
            InnerSolver::Lbfgs => "fs+lbfgs",
            InnerSolver::Tron => "fs+tron",
        };
        let pipe = if self.config.pipeline { "+pipe" } else { "" };
        format!("{}{}-{}", tag, pipe, self.config.epochs)
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        let c = &self.config;
        let dim = cluster.dim;
        // route gradient/direction rounds through the sparse phases
        // when the shards' column supports are small relative to d (the
        // paper's high-dimensional regime), and — one gate further —
        // run the whole master side in union-support compact
        // coordinates when |U|/d is small too (see module docs)
        let (compact, sparse) = c.master.resolve(cluster);
        // the master frame: length-|U| compact buffers or full-d dense
        let fdim = if compact { cluster.umap.len() } else { dim };
        cluster.set_pipeline(c.pipeline);
        let mut w = vec![0.0; fdim];
        let mut trace = Trace::new(self.name());
        // ship w⁰ — O(|U|) payload in the compact regime
        if compact {
            cluster.broadcast_support(fdim);
        } else {
            cluster.broadcast_vec();
        }
        // AUPRC probe in the master frame (test columns remapped onto
        // U once — never a full-d materialization per round)
        let probe = TestProbe::new(test, compact.then_some(&cluster.umap));
        let mut gnorm0 = f64::INFINITY;
        let mut f = f64::INFINITY;
        let mut last_hits = 0usize;
        // node-local margins zᵢ = w·xᵢ, maintained incrementally
        // (z ← z + t·dz after each line search) so the gradient pass
        // needs one data sweep, not two (§Perf)
        let mut margins: Vec<Vec<f64>> = Vec::new();
        // step 7's convex weights are round-independent — hoisted out
        // of the loop along with the node list (§Perf)
        let all_nodes: Vec<usize> = (0..cluster.n_nodes()).collect();
        let weights = combine_weights(cluster, c.combine, &all_nodes);
        // flight recorder: every hook below is an early-return when no
        // sink is installed — the off path is the pre-recorder loop
        let mut obs = RoundObs::new(cluster);

        for r in 0.. {
            obs.begin(cluster, r);
            // --- step 1: gʳ (allreduce: nodes need it for the tilt) ---
            let (f_r, g, grad_parts) = if margins.is_empty() {
                let (f_r, g, gp, z) = global_value_grad_master(
                    cluster, &w, c.loss, c.lam, true, sparse, compact,
                );
                margins = z;
                (f_r, g, gp)
            } else {
                global_value_grad_cached_master(
                    cluster, &margins, &w, c.loss, c.lam, true, sparse,
                    compact,
                )
            };
            f = f_r;
            let gnorm = dense::norm(&g);
            if r == 0 {
                gnorm0 = gnorm;
            }
            let p = TracePoint {
                iter: r,
                f,
                gnorm,
                comm_passes: cluster.ledger.comm_passes,
                seconds: cluster.ledger.seconds(),
                auprc: probe.auprc(&w),
                safeguard_hits: last_hits,
            };
            obs.trace_point(&p);
            if obs.on() {
                let rec = obs.rec();
                rec.compact = compact;
                rec.live_u = fdim;
                rec.members.extend_from_slice(&all_nodes);
            }
            trace.push(p);
            // --- step 2 + stop rules ---
            if gnorm == 0.0 || stop.should_stop(r, f, gnorm, gnorm0, &cluster.ledger) {
                obs.commit(cluster);
                break;
            }

            // --- steps 3–5: parallel compact local solves on f̂_p ---
            // shared master-frame dots once (O(|U|) compact, O(d)
            // dense); per node everything below is O(|support_p|)
            let dots = GlobalDots::compute(&w, &g);
            let w_ref = &w;
            let g_ref = &g;
            let gp_ref = &grad_parts;
            cluster.engine.set_phase("local_solve");
            let mut dirs: Vec<HybridDir> =
                cluster.map_each_scratch(|p, shard, s| {
                    local_direction(
                        c, p, shard, s, fdim, compact, &dots, w_ref, g_ref,
                        gp_ref, r,
                    )
                });

            // --- step 6: safeguard on shared scalars + sparse dots ---
            // (the flagged form also logs *which* nodes were replaced;
            // identical arithmetic — see `apply_hybrid_flagged`)
            let flags = if obs.on() {
                Some(&mut obs.rec().sg_replaced)
            } else {
                None
            };
            last_hits =
                c.safeguard.apply_hybrid_flagged(&dots, &w, &g, &mut dirs, flags);

            // --- step 7: convex combination ---
            // sparse regime: sum the affine coefficients (two scalars
            // per node on the wire) and sparse-allreduce the weighted
            // corrections; every node can rebuild dʳ from its own
            // (wʳ, gʳ) copies, the master materializes it in the frame
            // (O(|U|) compact, O(d) dense).
            // dense regime: materialize the weighted d_p per node and
            // run the classic dense allreduce (same accounting as the
            // dense gradient path).
            let d = combine_hybrids(cluster, dirs, &weights, &w, &g, sparse);

            // --- step 8: distributed line search on margins ---
            // nodes compute dʳ·xᵢ locally (compute-only phase, compact
            // gather of dʳ onto the support) into their reusable
            // NodeScratch::dz — steady-state rounds allocate nothing
            let d_ref = &d;
            cluster.engine.set_phase("dir_matvec");
            cluster.map_each_scratch_ctrl(|_, shard, s| {
                shard.gather_frame(compact, d_ref, &mut s.buf);
                s.dz.resize(shard.xl.n_rows(), 0.0);
                shard.xl.matvec(&s.buf, &mut s.dz);
            });
            let lam_part = PhiLambda::new(c.lam, &w, &d);
            let loss_kind = c.loss;
            let margins_ref = &margins;
            let ls = strong_wolfe(
                |t| {
                    let [lsum, dlsum] =
                        cluster.map_reduce_scalars_scratch(|p, shard, s| {
                            let phi = MarginPhi {
                                z: &margins_ref[p],
                                dz: &s.dz,
                                y: &shard.y,
                                loss: loss_kind,
                            };
                            let (a, b) = phi.partial(t);
                            [a, b]
                        });
                    lam_part.compose(t, lsum, dlsum)
                },
                &c.wolfe,
            );
            let t = match ls {
                Ok(res) => {
                    f = res.phi_t;
                    if obs.on() {
                        let rec = obs.rec();
                        rec.step = Some(res.t);
                        rec.ls_evals = Some(res.evals);
                    }
                    res.t
                }
                Err(_) => {
                    // dʳ not descent (can only happen when every node's
                    // safeguarded −gʳ got averaged into numerically
                    // nothing) — bail out rather than loop forever
                    obs.commit(cluster);
                    break;
                }
            };
            // --- step 9 (nodes reconstruct wʳ⁺¹ locally from t) ---
            dense::axpy(t, &d, &mut w);
            // nodes update their margin cache from their scratch dz:
            // z ← z + t·dz (O(n_p))
            for (p, z) in margins.iter_mut().enumerate() {
                let s = cluster.scratch[p].lock().expect("scratch lock");
                dense::axpy(t, &s.dz, z);
            }
            obs.commit(cluster);
        }
        // the compact master's single O(d) pass: materialize the
        // returned iterate into full space
        let w = if compact { cluster.umap.expand(&w, dim) } else { w };
        RunResult { w, f, trace, ledger: cluster.ledger.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::global_f_diagnostic;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;
    use crate::objective::RegularizedLoss;
    use crate::opt::tron::TronParams;

    fn make_cluster(nodes: usize, seed: u64) -> (Cluster, Dataset) {
        let data = SynthConfig {
            n_examples: 400,
            n_features: 60,
            nnz_per_example: 8,
            skew: 1.0,
            ..SynthConfig::default()
        }
        .generate(seed);
        let (train, test) = data.split(0.8, 1);
        (Cluster::partition(train, nodes, CostModel::free()), test)
    }

    fn f_star(cluster: &Cluster, loss: LossKind, lam: f64) -> f64 {
        // stitch shards → exact optimum via TRON
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for s in &cluster.shards {
            for i in 0..s.xl.n_rows() {
                rows.push(s.row_global(i));
                ys.push(s.y[i]);
            }
        }
        let x = crate::linalg::Csr::from_rows(cluster.dim, &rows);
        let obj = RegularizedLoss { x: &x, y: &ys, loss, lam };
        let w0 = vec![0.0; cluster.dim];
        tron::minimize(&obj, &w0, &TronParams {
            eps: 1e-12,
            max_iter: 200,
            ..Default::default()
        })
        .f
    }

    #[test]
    fn monotone_descent_and_convergence() {
        let (mut cluster, test) = make_cluster(4, 2);
        let cfg = FsConfig { lam: 0.5, epochs: 2, ..Default::default() };
        let fstar = f_star(&cluster, cfg.loss, cfg.lam);
        let driver = FsDriver::new(cfg);
        let run = driver.run(&mut cluster, Some(&test), &StopRule::iters(60));
        // monotone decrease of f across outer iterations
        for k in 1..run.trace.points.len() {
            assert!(
                run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-10,
                "f increased at iter {k}"
            );
        }
        // reaches small relative gap
        let gap = (run.f - fstar) / fstar;
        assert!(gap < 1e-4, "gap={gap}");
        // AUPRC recorded and sane
        let a = run.trace.last().unwrap().auprc;
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn linear_rate_theorem1() {
        // Theorem 1: (f(w^{r+1}) − f*)/(f(w^r) − f*) ≤ δ < 1 ∀r
        let (mut cluster, _) = make_cluster(5, 3);
        let cfg = FsConfig { lam: 1.0, epochs: 2, ..Default::default() };
        let fstar = f_star(&cluster, cfg.loss, cfg.lam);
        let run = FsDriver::new(cfg)
            .run(&mut cluster, None, &StopRule::iters(15));
        let gaps: Vec<f64> = run
            .trace
            .points
            .iter()
            .map(|p| p.f - fstar)
            .filter(|g| *g > 1e-13)
            .collect();
        let mut worst: f64 = 0.0;
        for k in 1..gaps.len() {
            worst = worst.max(gaps[k] / gaps[k - 1]);
        }
        assert!(worst < 1.0, "no linear contraction: worst ratio {worst}");
    }

    #[test]
    fn four_passes_per_iteration() {
        let (mut cluster, _) = make_cluster(4, 5);
        let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(6));
        let pts = &run.trace.points;
        // first point: 1 (w⁰ bcast) + 2 (grad allreduce)
        assert_eq!(pts[0].comm_passes, 3.0);
        for k in 1..pts.len() {
            assert_eq!(
                pts[k].comm_passes - pts[k - 1].comm_passes,
                4.0,
                "iteration {k} should cost exactly 4 passes"
            );
        }
    }

    #[test]
    fn more_epochs_fewer_outer_iterations() {
        // the role of s the paper highlights: larger s → better local
        // solves → fewer outer iterations to a fixed gap
        let (mut c1, _) = make_cluster(4, 7);
        let (mut c8, _) = make_cluster(4, 7);
        let fstar = f_star(&c1, LossKind::Logistic, 0.5);
        let target = fstar * (1.0 + 1e-5);
        let stop = StopRule::iters(60).with_target(target);
        let r1 = FsDriver::new(FsConfig { lam: 0.5, epochs: 1, ..Default::default() })
            .run(&mut c1, None, &stop);
        let r8 = FsDriver::new(FsConfig { lam: 0.5, epochs: 8, ..Default::default() })
            .run(&mut c8, None, &stop);
        assert!(r1.f <= target * 1.01 || r8.f <= target * 1.01);
        assert!(
            r8.trace.points.len() <= r1.trace.points.len(),
            "s=8 took {} iters vs s=1 {}",
            r8.trace.points.len(),
            r1.trace.points.len()
        );
    }

    #[test]
    fn single_node_still_works() {
        let (mut cluster, _) = make_cluster(1, 9);
        let run = FsDriver::new(FsConfig { lam: 0.5, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(10));
        let f_end = global_f_diagnostic(
            &cluster,
            &run.w,
            LossKind::Logistic,
            0.5,
        );
        assert!((f_end - run.f).abs() < 1e-9);
    }

    #[test]
    fn inner_solver_variants_all_descend() {
        for inner in [
            InnerSolver::Svrg,
            InnerSolver::Sag,
            InnerSolver::Sgd,
            InnerSolver::Lbfgs,
            InnerSolver::Tron,
        ] {
            let (mut cluster, _) = make_cluster(3, 11);
            let cfg = FsConfig {
                lam: 0.5,
                inner,
                lr: if inner == InnerSolver::Sgd { Some(0.01) } else { None },
                ..Default::default()
            };
            let run =
                FsDriver::new(cfg).run(&mut cluster, None, &StopRule::iters(6));
            let pts = &run.trace.points;
            assert!(
                pts.last().unwrap().f < pts[0].f,
                "{inner:?} failed to descend"
            );
        }
    }

    #[test]
    fn sequential_and_threaded_runs_are_identical() {
        // determinism: outputs are slotted by node index and reductions
        // are tree-ordered, so the thread count must not change a bit
        let (mut c1, _) = make_cluster(5, 13);
        let (mut cn, _) = make_cluster(5, 13);
        c1.threads = 1;
        cn.threads = 4;
        let cfg = FsConfig { lam: 0.5, ..Default::default() };
        let r1 = FsDriver::new(cfg.clone())
            .run(&mut c1, None, &StopRule::iters(8));
        let rn = FsDriver::new(cfg).run(&mut cn, None, &StopRule::iters(8));
        assert_eq!(r1.w, rn.w, "iterates diverged across thread counts");
        let f1: Vec<f64> = r1.trace.points.iter().map(|p| p.f).collect();
        let fn_: Vec<f64> = rn.trace.points.iter().map(|p| p.f).collect();
        assert_eq!(f1, fn_, "trace diverged across thread counts");
    }
}
