//! (Iterative) parameter mixing [5, 6, 7] — the method whose weak
//! convergence motivates the paper. Each major iteration: every node
//! runs SGD epochs on its *untilted* local view f̃_p (eq. 1) from the
//! current iterate, and the results are averaged.
//!
//! The two failure modes the introduction describes are observable
//! here: (a) node heterogeneity makes the average drift from w*;
//! (b) large epoch counts make each node converge to argmin f̃_p,
//! rendering the major iterations useless (no contraction).

use crate::algo::common::{global_f_frame, TestProbe};
use crate::algo::fs::MasterMode;
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::Cluster;
use crate::data::dataset::Dataset;
use crate::linalg::sparse::SparseVec;
use crate::loss::LossKind;
use crate::metrics::trace::{Trace, TracePoint};
use crate::obs::RoundObs;
use crate::opt::sgd::{sgd_epochs_shrink, SgdParams};

#[derive(Clone, Debug)]
pub struct ParamMixConfig {
    pub loss: LossKind,
    pub lam: f64,
    /// SGD epochs per node per major iteration
    pub epochs: usize,
    pub eta0: f64,
    pub seed: u64,
}

impl Default for ParamMixConfig {
    fn default() -> Self {
        ParamMixConfig {
            loss: LossKind::Logistic,
            lam: 1e-3,
            epochs: 1,
            eta0: 0.05,
            seed: 0,
        }
    }
}

pub struct ParamMixDriver {
    pub config: ParamMixConfig,
}

impl ParamMixDriver {
    pub fn new(config: ParamMixConfig) -> ParamMixDriver {
        ParamMixDriver { config }
    }

    /// One mixing round from `w`: node-local SGD in compact support
    /// coordinates, then average. Each node's w_p decomposes as
    /// shrink_p·w + corr_p: off its support, SGD only ever applies the
    /// L2 shrink, so a single scalar plus a |support_p|-sized
    /// correction reconstructs the full iterate. Charges 2 passes
    /// (allreduce); on sparse clusters only the corrections travel —
    /// every node rebuilds the average from its own copy of w.
    /// `w` is a full-d dense iterate (the Hybrid warm start's frame).
    pub fn round(&self, cluster: &mut Cluster, w: &[f64], iter: usize) -> Vec<f64> {
        self.round_frame(cluster, w, iter, false)
    }

    /// [`Self::round`] in an explicit master frame: with `compact` the
    /// iterate is the length-|U| union-support vector, gathers run
    /// through the shards' U positions and the correction reduce is
    /// U-position-indexed — the averaged iterate never touches a
    /// full-d buffer (same arithmetic as the dense frame; see
    /// `algo::fs`).
    fn round_frame(
        &self,
        cluster: &mut Cluster,
        w: &[f64],
        iter: usize,
        compact: bool,
    ) -> Vec<f64> {
        let c = &self.config;
        let n_nodes = cluster.n_nodes() as f64;
        let fdim = if compact { cluster.umap.len() } else { cluster.dim };
        let sparse = cluster.prefer_sparse() || compact;
        cluster.engine.set_phase("mix_sgd");
        let parts: Vec<(f64, SparseVec)> =
            cluster.map_each_scratch(|p, shard, s| {
                let seed = c
                    .seed
                    .wrapping_add((iter as u64) << 24)
                    .wrapping_add(p as u64);
                shard.gather_frame(compact, w, &mut s.wloc);
                let (w_c, shrink) = sgd_epochs_shrink(
                    &shard.xl,
                    &shard.y,
                    c.loss,
                    c.lam,
                    &s.wloc,
                    &SgdParams { epochs: c.epochs, eta0: c.eta0, seed },
                );
                let vals: Vec<f64> = w_c
                    .iter()
                    .zip(s.wloc.iter())
                    .map(|(a, b)| a - shrink * b)
                    .collect();
                let corr = SparseVec::from_support(
                    fdim,
                    shard.dir_idx(compact),
                    &vals,
                );
                (shrink, corr)
            });
        let shrink_avg: f64 = parts.iter().map(|(sh, _)| sh / n_nodes).sum();
        if sparse {
            let scaled: Vec<SparseVec> = parts
                .into_iter()
                .map(|(_, mut sv)| {
                    sv.scale(1.0 / n_nodes);
                    sv
                })
                .collect();
            // each node's shrink scalar rides a scalar round alongside
            // the correction reduce
            cluster.charge_scalar_round(1);
            let corr_sum =
                cluster.reduce_parts_sparse(&scaled, true).into_dense();
            let mut out: Vec<f64> =
                w.iter().map(|wj| shrink_avg * wj).collect();
            for (o, cval) in out.iter_mut().zip(&corr_sum) {
                *o += cval;
            }
            out
        } else {
            // dense wire: materialize each node's scaled w_p (classic
            // parameter-mixing accounting; never taken in the compact
            // frame — `sparse` is forced on there)
            let dense_parts: Vec<Vec<f64>> = parts
                .iter()
                .map(|(sh, sv)| {
                    let mut wp: Vec<f64> =
                        w.iter().map(|wj| sh * wj / n_nodes).collect();
                    sv.axpy_into(1.0 / n_nodes, &mut wp);
                    wp
                })
                .collect();
            cluster.reduce_parts(&dense_parts, true)
        }
    }
}

impl Driver for ParamMixDriver {
    fn name(&self) -> String {
        format!("parammix-{}", self.config.epochs)
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        let dim = cluster.dim;
        // density-gated union-support compact master, exactly as in FS:
        // the iterate, every correction and the averaged result live in
        // U, so the driver's own loop never allocates O(d)
        let (compact, _) = MasterMode::Auto.resolve(cluster);
        let fdim = if compact { cluster.umap.len() } else { dim };
        let mut w = vec![0.0; fdim];
        let mut trace = Trace::new(self.name());
        // w⁰ — O(|U|) payload in the compact regime
        if compact {
            cluster.broadcast_support(fdim);
        } else {
            cluster.broadcast_vec();
        }
        let probe = TestProbe::new(test, compact.then_some(&cluster.umap));
        let mut f = global_f_frame(
            cluster, &w, self.config.loss, self.config.lam, compact,
        );
        let mut obs = RoundObs::new(cluster);
        let all_nodes: Vec<usize> = (0..cluster.n_nodes()).collect();
        for r in 0.. {
            obs.begin(cluster, r);
            let p = TracePoint {
                iter: r,
                f,
                gnorm: f64::NAN, // gradient never formed — that's the point
                comm_passes: cluster.ledger.comm_passes,
                seconds: cluster.ledger.seconds(),
                auprc: probe.auprc(&w),
                safeguard_hits: 0,
            };
            obs.trace_point(&p);
            if obs.on() {
                let rec = obs.rec();
                rec.compact = compact;
                rec.live_u = fdim;
                rec.members.extend_from_slice(&all_nodes);
            }
            trace.push(p);
            if stop.should_stop(r, f, f64::INFINITY, 1.0, &cluster.ledger) {
                obs.commit(cluster);
                break;
            }
            w = self.round_frame(cluster, &w, r, compact);
            f = global_f_frame(
                cluster, &w, self.config.loss, self.config.lam, compact,
            );
            obs.commit(cluster);
        }
        // single O(d) materialization at RunResult construction
        let w = if compact { cluster.umap.expand(&w, dim) } else { w };
        RunResult { w, f, trace, ledger: cluster.ledger.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;

    fn make_cluster(nodes: usize, skew: f64) -> Cluster {
        let data = SynthConfig {
            n_examples: 300,
            n_features: 40,
            nnz_per_example: 6,
            skew,
            ..SynthConfig::default()
        }
        .generate(31);
        Cluster::partition(data, nodes, CostModel::free())
    }

    #[test]
    fn mixing_improves_over_zero_initially() {
        let mut cluster = make_cluster(4, 0.5);
        let run = ParamMixDriver::new(ParamMixConfig {
            lam: 0.5,
            ..Default::default()
        })
        .run(&mut cluster, None, &StopRule::iters(5));
        let pts = &run.trace.points;
        assert!(pts.last().unwrap().f < pts[0].f);
    }

    #[test]
    fn two_passes_per_round() {
        let mut cluster = make_cluster(4, 0.5);
        let run = ParamMixDriver::new(ParamMixConfig::default())
            .run(&mut cluster, None, &StopRule::iters(4));
        let pts = &run.trace.points;
        for k in 1..pts.len() {
            assert_eq!(pts[k].comm_passes - pts[k - 1].comm_passes, 2.0);
        }
    }

    #[test]
    fn stalls_above_true_optimum_with_heterogeneous_shards() {
        // the paper's issue (a)/(b): with skewed shards and many local
        // epochs, iterative mixing plateaus above f*
        use crate::objective::RegularizedLoss;
        use crate::opt::tron::{self, TronParams};

        let mut cluster = make_cluster(6, 3.0);
        // exact optimum on the stitched data
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for s in &cluster.shards {
            for i in 0..s.xl.n_rows() {
                rows.push(s.row_global(i));
                ys.push(s.y[i]);
            }
        }
        let x = crate::linalg::Csr::from_rows(cluster.dim, &rows);
        let obj = RegularizedLoss {
            x: &x,
            y: &ys,
            loss: LossKind::Logistic,
            lam: 0.5,
        };
        let w0 = vec![0.0; cluster.dim];
        let fstar = tron::minimize(
            &obj,
            &w0,
            &TronParams { eps: 1e-12, ..Default::default() },
        )
        .f;
        let run = ParamMixDriver::new(ParamMixConfig {
            lam: 0.5,
            epochs: 8, // many local epochs — converges to local minima
            ..Default::default()
        })
        .run(&mut cluster, None, &StopRule::iters(25));
        let gap = (run.f - fstar) / fstar;
        assert!(
            gap > 1e-4,
            "parameter mixing should NOT reach the optimum here (gap={gap})"
        );
    }
}
