//! Theory-check instrumentation: the quantitative objects Theorems 1–2
//! talk about, computed on a concrete problem so runs can *verify* the
//! theory's premises instead of assuming them.
//!
//! - [`lipschitz_global`] — L̂, a power-iteration estimate of the
//!   Lipschitz constant of ∇f over the whole cluster's data;
//! - [`theta_bound`] — cos⁻¹(λ/L), Theorem 2's lower limit for θ;
//! - [`DirectionAudit`] — per-iteration angles ∠(−gʳ, d_p), their
//!   maximum, and whether the Theorem-2 condition θ > cos⁻¹(λ/L) held.

use crate::cluster::Cluster;
use crate::linalg::dense;
use crate::loss::LossKind;

/// Power-iteration estimate of λ_max(XᵀX) over ALL shards (the global
/// data matrix), giving L̂ = λ + l''_max · λ_max.
///
/// Runs entirely in the cluster's union support U: columns outside U
/// are identically zero in every shard, so XᵀX is supported on U×U and
/// the U-compact iteration walks the exact same Krylov sequence as the
/// dense one — identical σ, O(|U|) buffers instead of two O(d) ones.
/// The U remap is a monotone column bijection, so partial sums land in
/// the same order and the estimate is bit-identical.
pub fn lipschitz_global(
    cluster: &Cluster,
    loss: LossKind,
    lam: f64,
    iters: usize,
) -> f64 {
    let u = cluster.umap.len();
    // the dense iteration starts from the union-support indicator; in U
    // coordinates that indicator is all-ones
    let mut v = vec![1.0f64; u];
    let n0 = dense::norm(&v).max(f64::MIN_POSITIVE);
    dense::scale(&mut v, 1.0 / n0);
    let mut sigma = 0.0;
    let mut vl = Vec::new();
    let mut gl: Vec<f64> = Vec::new();
    for _ in 0..iters {
        let mut vnew = vec![0.0f64; u];
        for shard in &cluster.shards {
            // gather v onto the shard support through the composed U
            // positions, run the compact mat-vecs, scatter back into U
            shard.gather_frame(true, &v, &mut vl);
            let mut z = vec![0.0; shard.xl.n_rows()];
            shard.xl.matvec(&vl, &mut z);
            gl.clear();
            gl.resize(shard.xl.n_cols, 0.0);
            shard.xl.tmatvec(&z, &mut gl);
            for (l, &p) in shard.upos.iter().enumerate() {
                vnew[p as usize] += gl[l];
            }
        }
        sigma = dense::norm(&vnew);
        if sigma <= f64::MIN_POSITIVE {
            break;
        }
        dense::scale(&mut vnew, 1.0 / sigma);
        v = vnew;
    }
    lam + loss.dd_max() * sigma
}

/// Theorem 2's angle threshold: θ must satisfy
/// π/2 > θ > cos⁻¹(λ/L). Returns cos⁻¹(λ/L) in radians.
pub fn theta_bound(lam: f64, lipschitz: f64) -> f64 {
    (lam / lipschitz.max(lam)).clamp(-1.0, 1.0).acos()
}

/// Records the angles the safeguard would inspect, for post-hoc checks
/// of the Theorem-2 story.
#[derive(Clone, Debug, Default)]
pub struct DirectionAudit {
    /// per outer iteration: the max over nodes of ∠(−gʳ, d_p)
    pub max_angles: Vec<f64>,
}

impl DirectionAudit {
    /// Audit one iteration's directions against the gradient.
    pub fn record(&mut self, g: &[f64], dirs: &[Vec<f64>]) {
        let neg_g: Vec<f64> = g.iter().map(|x| -x).collect();
        let worst = dirs
            .iter()
            .filter_map(|d| dense::angle(&neg_g, d))
            .fold(0.0f64, f64::max);
        self.max_angles.push(worst);
    }

    /// Fraction of iterations whose worst angle exceeded `theta`.
    pub fn exceed_rate(&self, theta: f64) -> f64 {
        if self.max_angles.is_empty() {
            return 0.0;
        }
        self.max_angles.iter().filter(|&&a| a >= theta).count() as f64
            / self.max_angles.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;
    use crate::util::rng::Rng;

    fn cluster() -> Cluster {
        let data = SynthConfig {
            n_examples: 200,
            n_features: 40,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(5);
        Cluster::partition(data, 4, CostModel::free())
    }

    #[test]
    fn global_lipschitz_dominates_shard_estimates() {
        let c = cluster();
        let lam = 0.3;
        let global = lipschitz_global(&c, LossKind::Logistic, lam, 25);
        for shard in &c.shards {
            // the compact matrix has the same spectrum as the
            // global-column shard (untouched columns are zero)
            let local = crate::opt::svrg::lipschitz_estimate(
                &shard.xl,
                LossKind::Logistic.dd_max(),
                lam,
                25,
            );
            assert!(
                global >= local * 0.999,
                "global {global} < shard {local}"
            );
        }
    }

    #[test]
    fn theta_bound_in_range_and_monotone() {
        // λ → L gives bound → 0; λ → 0 gives bound → π/2
        let b_tight = theta_bound(1.0, 1.0);
        let b_loose = theta_bound(1e-6, 1.0);
        assert!(b_tight < 1e-6);
        assert!(b_loose > 1.57 && b_loose <= std::f64::consts::FRAC_PI_2);
        assert!(theta_bound(0.5, 1.0) < theta_bound(0.1, 1.0));
    }

    #[test]
    fn audit_counts_exceedances() {
        let mut audit = DirectionAudit::default();
        let g = vec![1.0, 0.0];
        audit.record(&g, &[vec![-1.0, 0.0]]); // angle 0
        audit.record(&g, &[vec![-1.0, 1.0]]); // 45°
        audit.record(&g, &[vec![0.0, 1.0]]); // 90°
        assert_eq!(audit.max_angles.len(), 3);
        assert!((audit.exceed_rate(1.0) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(audit.exceed_rate(0.0), 1.0);
    }

    #[test]
    fn fs_directions_respect_theorem2_bound_statistically() {
        // run a few FS-like local solves and check the observed angles
        // sit below cos⁻¹(λ/L̂) — the geometric heart of Theorem 2
        use crate::objective::{shard_loss_grad, LocalApprox};
        use crate::opt::svrg::{svrg_epochs, SvrgParams};

        let c = cluster();
        let lam = 2.0; // strong regularization → tight angle bound
        let lhat = lipschitz_global(&c, LossKind::Logistic, lam, 30);
        let bound = theta_bound(lam, lhat);
        let dim = c.dim;
        let mut rng = Rng::new(7);
        let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.1).collect();
        // rebuild global-column shard matrices for the full-space oracle
        let stitched: Vec<crate::linalg::Csr> =
            c.shards.iter().map(|s| s.stitch(dim)).collect();
        // global gradient
        let mut g = vec![0.0; dim];
        let mut parts = Vec::new();
        for (s, x) in c.shards.iter().zip(&stitched) {
            let mut gl = vec![0.0; dim];
            shard_loss_grad(x, &s.y, &w_r, LossKind::Logistic, &mut gl, None);
            dense::axpy(1.0, &gl, &mut g);
            parts.push(gl);
        }
        dense::axpy(lam, &w_r, &mut g);
        let mut audit = DirectionAudit::default();
        let dirs: Vec<Vec<f64>> = c
            .shards
            .iter()
            .zip(&stitched)
            .zip(&parts)
            .map(|((s, x), gl)| {
                let approx = LocalApprox::new(
                    x, &s.y, LossKind::Logistic, lam, &w_r, &g, gl,
                );
                let (w_p, _) = svrg_epochs(
                    &approx,
                    &w_r,
                    &SvrgParams { epochs: 12, ..Default::default() },
                );
                dense::sub(&w_p, &w_r)
            })
            .collect();
        audit.record(&g, &dirs);
        let worst = audit.max_angles[0];
        assert!(
            worst <= bound + 0.2,
            "observed angle {worst} far above Theorem-2 bound {bound}"
        );
    }
}
