//! SQM — the Statistical Query Model baseline [10, 8]: a batch
//! gradient-based descent method whose gradient (and Hessian-vector
//! products) are computed distributed and aggregated over the AllReduce
//! tree, with the optimizer state at the master. The paper's
//! implementation uses TRON as the core optimizer ("instead of L-BFGS
//! we use the better-performing TRON"); L-BFGS is kept as the [8]
//! variant for the ablation bench.
//!
//! Communication per TRON iteration: w-broadcast + gradient reduce
//! (2 passes) + 2 passes per CG iteration — the many-passes profile
//! Figure 1's left panels show.
//!
//! Timing rides the event engine like every driver: each
//! broadcast/reduce is scheduled on the per-node virtual clocks
//! (labels "grad_sweep"/"hv_product" in the exported timeline), so a
//! heterogeneous [`NodeProfile`](crate::cluster::NodeProfile) shows
//! SQM's many synchronization points paying the straggler tax once
//! per pass — the contrast the paper draws against FS.

use crate::algo::common::{test_auprc, DistributedObjective};
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::Cluster;
use crate::data::dataset::Dataset;
use crate::loss::LossKind;
use crate::metrics::trace::{Trace, TracePoint};
use crate::obs::RoundObs;
use crate::opt::lbfgs::{self, LbfgsParams};
use crate::opt::tron::{self, TronParams};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreOpt {
    Tron,
    Lbfgs,
}

#[derive(Clone, Debug)]
pub struct SqmConfig {
    pub loss: LossKind,
    pub lam: f64,
    pub core: CoreOpt,
    pub tron: TronParams,
    pub lbfgs: LbfgsParams,
}

impl Default for SqmConfig {
    fn default() -> Self {
        SqmConfig {
            loss: LossKind::Logistic,
            lam: 1e-3,
            core: CoreOpt::Tron,
            tron: TronParams::default(),
            lbfgs: LbfgsParams::default(),
        }
    }
}

pub struct SqmDriver {
    pub config: SqmConfig,
    /// optional warm start (Hybrid sets this)
    pub w0: Option<Vec<f64>>,
}

impl SqmDriver {
    pub fn new(config: SqmConfig) -> SqmDriver {
        SqmDriver { config, w0: None }
    }

    pub fn with_start(config: SqmConfig, w0: Vec<f64>) -> SqmDriver {
        SqmDriver { config, w0: Some(w0) }
    }
}

impl Driver for SqmDriver {
    fn name(&self) -> String {
        match self.config.core {
            CoreOpt::Tron => "sqm".to_string(),
            CoreOpt::Lbfgs => "sqm+lbfgs".to_string(),
        }
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        let dim = cluster.dim;
        let n_nodes = cluster.n_nodes();
        let w0 = self.w0.clone().unwrap_or_else(|| vec![0.0; dim]);
        let trace = std::cell::RefCell::new(Trace::new(self.name()));
        let counter = std::cell::Cell::new(0usize);
        // flight recorder: the optimizer owns the loop here, so the
        // callback commits round i and opens round i+1 (the last
        // opened round has no trace point and is never emitted)
        let mut ob = RoundObs::new(cluster);
        ob.begin(cluster, 0);
        let obs = std::cell::RefCell::new(ob);

        // The objective holds the cluster; the per-iteration callback
        // snapshots the ledger through it.
        let obj =
            DistributedObjective::new(cluster, self.config.loss, self.config.lam);

        let (w, f) = match self.config.core {
            CoreOpt::Tron => {
                // translate the StopRule budgets into TRON params where
                // possible; budget overruns are cut in the callback via
                // max_iter (TRON has no external abort hook)
                let params = TronParams {
                    max_iter: stop.max_outer_iters.min(10_000),
                    eps: stop.gnorm_rel.max(1e-14),
                    ..self.config.tron
                };
                let res = tron::minimize_cb(&obj, &w0, &params, |it, w_now| {
                    let i = counter.get();
                    counter.set(i + 1);
                    let mut c = obj.cluster.borrow_mut();
                    let p = TracePoint {
                        iter: i,
                        f: it.f,
                        gnorm: it.gnorm,
                        comm_passes: c.ledger.comm_passes,
                        seconds: c.ledger.seconds(),
                        auprc: test_auprc(test, w_now),
                        safeguard_hits: 0,
                    };
                    let mut ob = obs.borrow_mut();
                    ob.trace_point(&p);
                    if ob.on() {
                        let rec = ob.rec();
                        rec.live_u = dim;
                        rec.members.extend(0..n_nodes);
                    }
                    trace.borrow_mut().push(p);
                    ob.commit(&mut c);
                    ob.begin(&c, i + 1);
                });
                (res.w, res.f)
            }
            CoreOpt::Lbfgs => {
                let params = LbfgsParams {
                    max_iter: stop.max_outer_iters.min(10_000),
                    eps: stop.gnorm_rel.max(1e-14),
                    ..self.config.lbfgs.clone()
                };
                let res = lbfgs::minimize_cb(&obj, &w0, &params, |it, w_now| {
                    let i = counter.get();
                    counter.set(i + 1);
                    let mut c = obj.cluster.borrow_mut();
                    let p = TracePoint {
                        iter: i,
                        f: it.f,
                        gnorm: it.gnorm,
                        comm_passes: c.ledger.comm_passes,
                        seconds: c.ledger.seconds(),
                        auprc: test_auprc(test, w_now),
                        safeguard_hits: 0,
                    };
                    let mut ob = obs.borrow_mut();
                    ob.trace_point(&p);
                    if ob.on() {
                        let rec = ob.rec();
                        rec.live_u = dim;
                        rec.members.extend(0..n_nodes);
                    }
                    trace.borrow_mut().push(p);
                    ob.commit(&mut c);
                    ob.begin(&c, i + 1);
                });
                (res.w, res.f)
            }
        };
        drop(obj);
        RunResult {
            w,
            f,
            trace: trace.into_inner(),
            ledger: cluster.ledger.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;

    fn make_cluster(nodes: usize) -> Cluster {
        let data = SynthConfig {
            n_examples: 300,
            n_features: 40,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(21);
        Cluster::partition(data, nodes, CostModel::free())
    }

    #[test]
    fn tron_core_converges_distributed() {
        let mut cluster = make_cluster(4);
        let run = SqmDriver::new(SqmConfig { lam: 0.5, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(100));
        assert!(run.trace.points.len() > 1);
        let last = run.trace.last().unwrap();
        assert!(last.gnorm < 1e-6 * run.trace.points[0].gnorm.max(1.0));
    }

    #[test]
    fn lbfgs_core_matches_tron_objective() {
        let mut c1 = make_cluster(3);
        let mut c2 = make_cluster(3);
        let r_tron = SqmDriver::new(SqmConfig { lam: 0.5, ..Default::default() })
            .run(&mut c1, None, &StopRule::iters(200));
        let r_lb = SqmDriver::new(SqmConfig {
            lam: 0.5,
            core: CoreOpt::Lbfgs,
            ..Default::default()
        })
        .run(&mut c2, None, &StopRule::iters(400));
        assert!(
            (r_tron.f - r_lb.f).abs() < 1e-5 * r_tron.f.abs().max(1.0),
            "tron {} vs lbfgs {}",
            r_tron.f,
            r_lb.f
        );
    }

    #[test]
    fn comm_passes_grow_with_cg_iterations() {
        // SQM must charge ≥ 4 passes per outer iteration (2 for the
        // value/grad + 2 per CG iteration, ≥1 CG iteration)
        let mut cluster = make_cluster(4);
        let run = SqmDriver::new(SqmConfig { lam: 0.5, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(30));
        let pts = &run.trace.points;
        for k in 1..pts.len() {
            let delta = pts[k].comm_passes - pts[k - 1].comm_passes;
            assert!(delta >= 4.0, "iteration {k} charged only {delta} passes");
        }
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let mut c1 = make_cluster(3);
        let cold = SqmDriver::new(SqmConfig { lam: 0.5, ..Default::default() })
            .run(&mut c1, None, &StopRule::iters(100));
        // warm-start from the cold solution: should converge almost
        // immediately (eps_abs guards the self-referential relative
        // test when w0 is already optimal)
        let mut c2 = make_cluster(3);
        let mut cfg = SqmConfig { lam: 0.5, ..Default::default() };
        cfg.tron.eps_abs = 1e-6;
        let warm = SqmDriver::with_start(cfg, cold.w.clone())
            .run(&mut c2, None, &StopRule::iters(100));
        assert!(
            warm.trace.points.len() <= 3,
            "warm start took {} iterations",
            warm.trace.points.len()
        );
    }
}
