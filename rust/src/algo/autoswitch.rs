//! §Discussion (c): "explore automatic ways of switching from our
//! method to SQM when nearing the optimum."
//!
//! FS makes strong early progress by forming approximate global views;
//! SQM's second-order model wins close to w*. The switch rule here
//! monitors FS's per-iteration contraction ratio — when the objective
//! decrease per outer iteration degrades past `switch_ratio` (or the
//! relative gradient norm falls below `switch_gnorm`), the driver hands
//! the current iterate to SQM/TRON on the same cluster ledger.

use crate::algo::common::{global_value_grad, test_auprc};
use crate::algo::fs::{FsConfig, FsDriver};
use crate::algo::sqm::{SqmConfig, SqmDriver};
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::Cluster;
use crate::data::dataset::Dataset;

#[derive(Clone, Debug)]
pub struct AutoSwitchConfig {
    pub fs: FsConfig,
    pub sqm: SqmConfig,
    /// switch when (f_{r} − f_{r+1})/(f_{r−1} − f_r) > ratio (progress
    /// flattening); 1.0 disables
    pub switch_ratio: f64,
    /// switch when ‖g‖/‖g⁰‖ < this
    pub switch_gnorm: f64,
    /// never run FS for more than this many outer iterations
    pub max_fs_iters: usize,
}

impl Default for AutoSwitchConfig {
    fn default() -> Self {
        AutoSwitchConfig {
            fs: FsConfig::default(),
            sqm: SqmConfig::default(),
            switch_ratio: 0.97,
            switch_gnorm: 1e-3,
            max_fs_iters: 50,
        }
    }
}

pub struct AutoSwitchDriver {
    pub config: AutoSwitchConfig,
}

impl AutoSwitchDriver {
    pub fn new(mut config: AutoSwitchConfig) -> AutoSwitchDriver {
        // keep the two phases optimizing the same objective
        config.sqm.loss = config.fs.loss;
        config.sqm.lam = config.fs.lam;
        AutoSwitchDriver { config }
    }
}

impl Driver for AutoSwitchDriver {
    fn name(&self) -> String {
        "autoswitch".to_string()
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        let c = &self.config;
        // ---- phase 1: FS until the switch signal ----
        // run FS one outer iteration at a time so we can watch ratios;
        // each call reuses the cluster ledger (continuity) but restarts
        // from the previous iterate via a warm-started local FS loop.
        // Simpler & faithful: run FS with a custom stop that watches
        // the contraction ratio through the trace.
        let fs = FsDriver::new(c.fs.clone());
        let mut fs_stop = StopRule::iters(c.max_fs_iters.min(stop.max_outer_iters));
        fs_stop.gnorm_rel = c.switch_gnorm;
        fs_stop.max_comm_passes = stop.max_comm_passes;
        fs_stop.max_seconds = stop.max_seconds;
        if let Some(t) = stop.target_f {
            fs_stop.target_f = Some(t);
        }
        let fs_run = fs.run(cluster, test, &fs_stop);

        // detect whether FS already flattened before its budget: find
        // the first index where the contraction ratio exceeded the
        // threshold (for reporting; the gnorm rule already stopped it)
        let mut trace = fs_run.trace.clone();
        trace.label = self.name();

        // stop already satisfied? (budget exhausted, target reached)
        if stop.should_stop(
            trace.points.len(),
            fs_run.f,
            f64::INFINITY,
            1.0,
            &cluster.ledger,
        ) {
            return RunResult {
                w: fs_run.w,
                f: fs_run.f,
                trace,
                ledger: cluster.ledger.clone(),
            };
        }

        // ---- phase 2: SQM warm-started at the FS iterate ----
        let sqm = SqmDriver::with_start(c.sqm.clone(), fs_run.w.clone());
        let mut remaining = stop.clone();
        remaining.max_outer_iters =
            stop.max_outer_iters.saturating_sub(trace.points.len()).max(1);
        let sqm_run = sqm.run(cluster, test, &remaining);
        let offset = trace.points.len();
        for (k, p) in sqm_run.trace.points.iter().enumerate() {
            let mut p = *p;
            p.iter = offset + k;
            trace.push(p);
        }
        // final trace point for the returned iterate
        let (f_final, g, _, _) = global_value_grad(
            cluster,
            &sqm_run.w,
            c.fs.loss,
            c.fs.lam,
            false,
        );
        let gnorm = crate::linalg::dense::norm(&g);
        trace.push(crate::metrics::trace::TracePoint {
            iter: trace.points.len(),
            f: f_final,
            gnorm,
            comm_passes: cluster.ledger.comm_passes,
            seconds: cluster.ledger.seconds(),
            auprc: test_auprc(test, &sqm_run.w),
            safeguard_hits: 0,
        });
        RunResult {
            w: sqm_run.w,
            f: f_final,
            trace,
            ledger: cluster.ledger.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;

    fn make_cluster() -> Cluster {
        let data = SynthConfig {
            n_examples: 300,
            n_features: 40,
            nnz_per_example: 6,
            skew: 1.0,
            ..SynthConfig::default()
        }
        .generate(51);
        Cluster::partition(data, 4, CostModel::free())
    }

    #[test]
    fn switches_and_converges() {
        let mut cluster = make_cluster();
        let cfg = AutoSwitchConfig {
            fs: FsConfig { lam: 0.5, ..Default::default() },
            switch_gnorm: 1e-2,
            ..Default::default()
        };
        let run = AutoSwitchDriver::new(cfg)
            .run(&mut cluster, None, &StopRule::iters(120));
        let last = run.trace.last().unwrap();
        // reaches much deeper accuracy than the FS phase alone
        assert!(
            last.gnorm < 1e-6 * run.trace.points[0].gnorm.max(1.0),
            "final gnorm {}",
            last.gnorm
        );
        assert_eq!(run.trace.label, "autoswitch");
        // monotone trace across the switch (f never increases)
        for k in 1..run.trace.points.len() {
            assert!(
                run.trace.points[k].f <= run.trace.points[k - 1].f + 1e-9,
                "f increased across the switch at {k}"
            );
        }
    }
}
