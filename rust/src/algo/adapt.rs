//! First-class asynchrony policy + the self-tuning (τ, q) controller.
//!
//! Until this module landed, the async FS driver's schedule was
//! configured through two raw fields (`staleness: usize`,
//! `quorum: usize` with `usize::MAX` as a "wait for everyone"
//! sentinel). [`Asynchrony`] replaces them with a typed policy the
//! driver, the CLI, `util::validate` and the obs manifest all consume
//! uniformly:
//!
//! - [`Asynchrony::Sync`] — the empty policy: τ = 0, quorum = P. The
//!   async driver under it is bit-identical to the synchronous
//!   [`FsDriver`](crate::algo::fs::FsDriver) (`tests/speculation.rs`
//!   pins this, extending the PR-4 τ=0 ∧ q=P equivalence).
//! - [`Asynchrony::Bounded`] — the PR-4 regime: a fixed staleness
//!   bound τ and a [`Quorum`] (`All` kills the old `usize::MAX`
//!   sentinel; `AtLeast(q)` is the partial quorum).
//! - [`Asynchrony::Adaptive`] — (τ, q) start at `init` and a
//!   [`Controller`] re-tunes them per round from the
//!   [`Ledger`](crate::cluster::Ledger)'s staleness histogram and
//!   fallback/fault counters, clamped inside [`TuneBounds`].
//!
//! **Determinism.** Every controller decision is a pure function of
//! the ledger counters at the decision point — no wall clocks, no
//! randomness, no iteration over unordered containers — so a seeded
//! run replays its (τ, q) trajectory bit-identically
//! ([`Ledger::tune_trace`](crate::cluster::Ledger::tune_trace) records
//! it, `tests/speculation.rs` pins the replay).
//!
//! **The rules** (evaluated once per [`TUNE_WINDOW`] async rounds,
//! over that window's ledger deltas):
//!
//! 1. fallback rate > [`FALLBACK_SHRINK_RATE`] → shrink τ by 1: the
//!    safeguard keeps rejecting stale-contaminated quorums, so tighten
//!    the staleness bound toward the certified synchronous regime.
//! 2. else the window saw link retry/reroute activity AND its payload
//!    stall share (retry seconds over total wire seconds) exceeds
//!    [`CONGEST_STALL_SHARE`] → **widen τ by 1 and shrink q by 1**:
//!    the wire is congested, so tolerate staler directions (they're
//!    late because of the links, not the maths) and stop waiting for
//!    payloads that must cross the congested edges.
//! 3. else stale share > [`STALE_SHRINK_SHARE`] → shrink q by 1 (never
//!    below `q_min`): most contributions arrive stale, i.e. the
//!    straggler gap has widened past what the fresh deadline absorbs —
//!    stop letting the slow tail gate the round.
//! 4. else if the window saw fault events (node weather *or* link
//!    weather) → hold: weather is moving, don't chase it.
//! 5. else (calm) → re-expand: τ toward `tau_max`, q toward the live
//!    membership.

use crate::cluster::Ledger;

/// How many fresh (round-r) contributions the async master waits for
/// before combining. Replaces the raw `usize` whose `usize::MAX` value
/// meant "everyone".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quorum {
    /// wait for every node's fresh solve (q = P)
    All,
    /// combine once q fresh solves have arrived (clamped to 1..=P at
    /// run time)
    AtLeast(usize),
}

impl Quorum {
    /// The concrete quorum size against a cluster of `p` nodes.
    pub fn resolve(&self, p: usize) -> usize {
        match *self {
            Quorum::All => p.max(1),
            Quorum::AtLeast(q) => q.clamp(1, p.max(1)),
        }
    }
}

/// The clamp box the adaptive controller moves (τ, q) inside: τ never
/// exceeds `tau_max`, q never drops below `q_min` (and never exceeds
/// the live membership). `tests/speculation.rs` pins both bounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneBounds {
    pub tau_max: usize,
    pub q_min: usize,
}

impl Default for TuneBounds {
    fn default() -> Self {
        TuneBounds { tau_max: 4, q_min: 1 }
    }
}

/// The asynchrony policy the async FS driver runs under — the one
/// typed surface behind `--staleness`/`--quorum`/`--adaptive`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Asynchrony {
    /// τ = 0, quorum = P: every round is exactly Algorithm 1's
    /// synchronous round (bit-identical to `FsDriver`).
    Sync,
    /// Fixed bounded staleness — the PR-4 regime.
    Bounded { tau: usize, quorum: Quorum },
    /// (τ, q) start at `init` and the [`Controller`] re-tunes them per
    /// round inside `bounds`.
    Adaptive { init: (usize, usize), bounds: TuneBounds },
}

impl Default for Asynchrony {
    fn default() -> Self {
        Asynchrony::Bounded { tau: 1, quorum: Quorum::All }
    }
}

impl Asynchrony {
    /// The starting (τ, q) against a cluster of `p` nodes — already
    /// clamped (q into 1..=p, and for the adaptive policy τ into
    /// `..=tau_max`, q above `q_min`).
    pub fn initial(&self, p: usize) -> (usize, usize) {
        let p = p.max(1);
        match *self {
            Asynchrony::Sync => (0, p),
            Asynchrony::Bounded { tau, quorum } => (tau, quorum.resolve(p)),
            Asynchrony::Adaptive { init: (tau, q), bounds } => (
                tau.min(bounds.tau_max),
                q.clamp(bounds.q_min.min(p).max(1), p),
            ),
        }
    }

    /// The per-round tuner — `Some` only for the adaptive policy.
    pub fn controller(&self, p: usize) -> Option<Controller> {
        match *self {
            Asynchrony::Adaptive { bounds, .. } => {
                let (tau, q) = self.initial(p);
                Some(Controller::new(tau, q, bounds))
            }
            _ => None,
        }
    }

    /// Compact policy descriptor for driver names and the obs
    /// manifest: `sync`, `t2-qall`, `t2-q3`, `adapt-t1.4-q4.1`
    /// (init.bound on each axis).
    pub fn tag(&self) -> String {
        match *self {
            Asynchrony::Sync => "sync".to_string(),
            Asynchrony::Bounded { tau, quorum: Quorum::All } => {
                format!("t{tau}-qall")
            }
            Asynchrony::Bounded { tau, quorum: Quorum::AtLeast(q) } => {
                format!("t{tau}-q{q}")
            }
            Asynchrony::Adaptive { init: (tau, q), bounds } => {
                format!(
                    "adapt-t{tau}.{}-q{q}.{}",
                    bounds.tau_max, bounds.q_min
                )
            }
        }
    }
}

/// Window length (in async combine rounds) between controller
/// decisions: long enough that the fallback/staleness rates are more
/// than one round's noise, short enough to track moving weather.
pub const TUNE_WINDOW: usize = 4;

/// Window fallback rate above which rule 1 shrinks τ.
pub const FALLBACK_SHRINK_RATE: f64 = 0.25;

/// Window stale-contribution share above which rule 3 shrinks q.
pub const STALE_SHRINK_SHARE: f64 = 0.5;

/// Window payload-stall share (retry seconds over total wire seconds)
/// above which, together with any link retry/reroute activity, rule 2
/// treats the wire as congested and widens τ / shrinks q.
pub const CONGEST_STALL_SHARE: f64 = 0.2;

/// The ledger counters one decision window is measured against. All
/// monotone, so window deltas are plain subtractions.
#[derive(Clone, Copy, Debug, Default)]
struct LedgerMark {
    async_rounds: usize,
    fallback_rounds: usize,
    fresh_contribs: usize,
    total_contribs: usize,
    fault_events: usize,
    link_events: usize,
    comm_seconds: f64,
    retry_seconds: f64,
}

impl LedgerMark {
    fn take(l: &Ledger) -> LedgerMark {
        LedgerMark {
            async_rounds: l.async_rounds,
            fallback_rounds: l.fallback_rounds,
            fresh_contribs: l.staleness_hist.first().copied().unwrap_or(0),
            total_contribs: l.staleness_hist.iter().sum(),
            // link weather counts as weather: a window with link
            // activity never looks "calm" to rule 5
            fault_events: l.crash_events
                + l.rejoin_rebases
                + l.lost_messages
                + l.degrade_events
                + l.flap_events
                + l.link_retries
                + l.reroutes
                + l.congested_hops
                + l.partition_events,
            link_events: l.link_retries + l.reroutes,
            comm_seconds: l.comm_seconds,
            retry_seconds: l.retry_seconds,
        }
    }
}

/// The self-tuning (τ, q) state machine behind
/// [`Asynchrony::Adaptive`]. Feed it the ledger once per round
/// ([`Controller::observe`]); every [`TUNE_WINDOW`] async rounds it
/// re-decides (τ, q) from that window's deltas by the module-doc
/// rules. Decisions are pure functions of the ledger, so a seeded run
/// replays them bit-identically.
#[derive(Clone, Debug)]
pub struct Controller {
    tau: usize,
    q: usize,
    bounds: TuneBounds,
    mark: LedgerMark,
}

impl Controller {
    pub fn new(tau: usize, q: usize, bounds: TuneBounds) -> Controller {
        Controller { tau, q, bounds, mark: LedgerMark::default() }
    }

    /// The current (τ, q).
    pub fn current(&self) -> (usize, usize) {
        (self.tau, self.q)
    }

    /// One per-round observation. Returns `Some((τ, q))` when a full
    /// window has elapsed and a (possibly unchanged) decision was
    /// taken, `None` mid-window. `p_alive` is the live membership —
    /// the ceiling q re-expands toward and is clamped under.
    pub fn observe(
        &mut self,
        ledger: &Ledger,
        p_alive: usize,
    ) -> Option<(usize, usize)> {
        let now = LedgerMark::take(ledger);
        let rounds = now.async_rounds - self.mark.async_rounds;
        if rounds < TUNE_WINDOW {
            return None;
        }
        let fallback_rate = (now.fallback_rounds - self.mark.fallback_rounds)
            as f64
            / rounds as f64;
        let total = now.total_contribs - self.mark.total_contribs;
        let fresh = now.fresh_contribs - self.mark.fresh_contribs;
        let stale_share = if total == 0 {
            0.0
        } else {
            1.0 - fresh as f64 / total as f64
        };
        let faults = now.fault_events - self.mark.fault_events;
        let link_events = now.link_events - self.mark.link_events;
        let retry_delta = now.retry_seconds - self.mark.retry_seconds;
        let wire_delta =
            (now.comm_seconds - self.mark.comm_seconds) + retry_delta;
        let stall_share = if wire_delta <= 0.0 {
            0.0
        } else {
            retry_delta / wire_delta
        };
        self.mark = now;
        if fallback_rate > FALLBACK_SHRINK_RATE {
            self.tau = self.tau.saturating_sub(1);
        } else if link_events > 0 && stall_share > CONGEST_STALL_SHARE {
            // congestion: the wire, not the maths, is late — widen the
            // staleness bound and stop waiting for payloads that must
            // cross the congested edges
            self.tau = (self.tau + 1).min(self.bounds.tau_max);
            self.q = self.q.saturating_sub(1);
        } else if stale_share > STALE_SHRINK_SHARE {
            self.q = self.q.saturating_sub(1);
        } else if faults == 0 {
            self.tau = (self.tau + 1).min(self.bounds.tau_max);
            self.q += 1;
        }
        // rule 4 (faults in a calm-looking window) falls through to
        // the clamp with (τ, q) held
        let p_alive = p_alive.max(1);
        self.q = self.q.clamp(self.bounds.q_min.min(p_alive), p_alive);
        Some((self.tau, self.q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger_with(
        rounds: usize,
        fallbacks: usize,
        hist: Vec<usize>,
        faults: usize,
    ) -> Ledger {
        Ledger {
            async_rounds: rounds,
            fallback_rounds: fallbacks,
            staleness_hist: hist,
            crash_events: faults,
            ..Ledger::default()
        }
    }

    #[test]
    fn quorum_resolves_without_sentinels() {
        assert_eq!(Quorum::All.resolve(8), 8);
        assert_eq!(Quorum::AtLeast(3).resolve(8), 3);
        // clamped into 1..=P
        assert_eq!(Quorum::AtLeast(0).resolve(8), 1);
        assert_eq!(Quorum::AtLeast(99).resolve(8), 8);
        assert_eq!(Quorum::All.resolve(0), 1);
    }

    #[test]
    fn policy_initial_and_tags() {
        assert_eq!(Asynchrony::Sync.initial(6), (0, 6));
        assert_eq!(Asynchrony::Sync.tag(), "sync");
        let b = Asynchrony::Bounded { tau: 2, quorum: Quorum::AtLeast(4) };
        assert_eq!(b.initial(6), (2, 4));
        assert_eq!(b.tag(), "t2-q4");
        assert_eq!(Asynchrony::default().tag(), "t1-qall");
        let a = Asynchrony::Adaptive {
            init: (9, 9),
            bounds: TuneBounds { tau_max: 3, q_min: 2 },
        };
        // init is clamped into the bounds box at resolution time
        assert_eq!(a.initial(6), (3, 6));
        assert_eq!(a.tag(), "adapt-t9.3-q9.2");
        assert!(Asynchrony::Sync.controller(6).is_none());
        assert!(b.controller(6).is_none());
        assert_eq!(a.controller(6).unwrap().current(), (3, 6));
    }

    #[test]
    fn controller_holds_mid_window() {
        let mut c = Controller::new(1, 4, TuneBounds::default());
        let l = ledger_with(TUNE_WINDOW - 1, 0, vec![6], 0);
        assert_eq!(c.observe(&l, 6), None);
        assert_eq!(c.current(), (1, 4));
    }

    #[test]
    fn fallback_spike_shrinks_tau() {
        let mut c = Controller::new(2, 4, TuneBounds::default());
        // 2 fallbacks in a 4-round window: rate 0.5 > 0.25
        let l = ledger_with(TUNE_WINDOW, 2, vec![10, 2], 0);
        assert_eq!(c.observe(&l, 6), Some((1, 4)));
        // τ saturates at 0, never underflows
        let l2 = ledger_with(2 * TUNE_WINDOW, 4, vec![20, 4], 0);
        assert_eq!(c.observe(&l2, 6), Some((0, 4)));
        let l3 = ledger_with(3 * TUNE_WINDOW, 6, vec![30, 6], 0);
        assert_eq!(c.observe(&l3, 6), Some((0, 4)));
    }

    #[test]
    fn stale_share_shrinks_quorum_to_q_min() {
        let bounds = TuneBounds { tau_max: 4, q_min: 3 };
        let mut c = Controller::new(2, 4, bounds);
        // 1 fresh of 8 contributions: stale share 7/8 > 0.5
        let l = ledger_with(TUNE_WINDOW, 0, vec![1, 3, 4], 0);
        assert_eq!(c.observe(&l, 6), Some((2, 3)));
        // clamped at q_min even if the share stays high
        let l2 = ledger_with(2 * TUNE_WINDOW, 0, vec![2, 6, 8], 0);
        assert_eq!(c.observe(&l2, 6), Some((2, 3)));
    }

    #[test]
    fn calm_weather_re_expands_inside_bounds() {
        let bounds = TuneBounds { tau_max: 3, q_min: 1 };
        let mut c = Controller::new(0, 2, bounds);
        for k in 1..=5usize {
            // all-fresh, no fallback, no faults: pure calm
            let l =
                ledger_with(k * TUNE_WINDOW, 0, vec![6 * k * TUNE_WINDOW], 0);
            let (tau, q) = c.observe(&l, 5).unwrap();
            // τ caps at tau_max, q at the live membership
            assert!(tau <= bounds.tau_max, "tau {tau} round {k}");
            assert!(q <= 5, "q {q} round {k}");
        }
        assert_eq!(c.current(), (3, 5));
    }

    #[test]
    fn congested_window_widens_tau_and_shrinks_quorum() {
        let mut c = Controller::new(1, 4, TuneBounds::default());
        // retries present and half the wire time stalled on backoff
        // rungs: rule 2 widens τ and sheds a quorum slot
        let mut l = ledger_with(TUNE_WINDOW, 0, vec![12], 0);
        l.link_retries = 6;
        l.retry_seconds = 1.0;
        l.comm_seconds = 1.0;
        assert_eq!(c.observe(&l, 6), Some((2, 3)));
        // link activity below the stall threshold only *holds*: it
        // counts as weather (rule 4), so no calm re-expansion either
        let mut l2 = ledger_with(2 * TUNE_WINDOW, 0, vec![24], 0);
        l2.link_retries = 7;
        l2.retry_seconds = 1.01;
        l2.comm_seconds = 101.0;
        assert_eq!(c.observe(&l2, 6), Some((2, 3)));
        // τ stays inside tau_max under sustained congestion
        let bounds = TuneBounds { tau_max: 2, q_min: 1 };
        let mut c2 = Controller::new(2, 2, bounds);
        let mut l3 = ledger_with(TUNE_WINDOW, 0, vec![12], 0);
        l3.reroutes = 1;
        l3.retry_seconds = 3.0;
        l3.comm_seconds = 1.0;
        assert_eq!(c2.observe(&l3, 6), Some((2, 1)));
    }

    #[test]
    fn fault_window_holds_and_quorum_tracks_membership() {
        let mut c = Controller::new(1, 4, TuneBounds::default());
        // calm rates but fault activity: rule 3 holds (τ, q) ...
        let l = ledger_with(TUNE_WINDOW, 0, vec![12], 2);
        assert_eq!(c.observe(&l, 6), Some((1, 4)));
        // ... except that q always clamps under the live membership
        let l2 = ledger_with(2 * TUNE_WINDOW, 0, vec![24], 4);
        assert_eq!(c.observe(&l2, 3), Some((1, 3)));
    }

    #[test]
    fn decisions_are_pure_ledger_functions() {
        // identical ledger sequences ⇒ identical decision traces,
        // regardless of when/where the controller runs
        let feed = |c: &mut Controller| {
            let mut trace = Vec::new();
            for k in 1..=6usize {
                let fall = if k % 2 == 0 { 2 * k } else { k };
                let l = ledger_with(
                    k * TUNE_WINDOW,
                    fall,
                    vec![3 * k, 2 * k, k],
                    k / 3,
                );
                if let Some(d) = c.observe(&l, 6) {
                    trace.push(d);
                }
            }
            trace
        };
        let mut a = Controller::new(2, 5, TuneBounds::default());
        let mut b = Controller::new(2, 5, TuneBounds::default());
        assert_eq!(feed(&mut a), feed(&mut b));
    }
}
