//! Algorithm 1 step 6 — the "safe artifact" step: if the angle between
//! d_p and −gʳ reaches θ, replace d_p by −gʳ. Theorems 1–2 need
//! θ < π/2 (and θ > cos⁻¹(λ/L) for the probability bound); the paper's
//! practical recommendation is to accept anything that is a strict
//! descent direction, which corresponds to θ → π/2⁻ here.

use crate::linalg::dense;

#[derive(Clone, Copy, Debug)]
pub struct Safeguard {
    /// threshold θ in radians, 0 ≤ θ < π/2 ... π/2 itself encodes the
    /// practical "any descent direction" policy
    pub theta: f64,
}

impl Default for Safeguard {
    fn default() -> Self {
        // practical setting: accept strict descent directions
        Safeguard { theta: std::f64::consts::FRAC_PI_2 }
    }
}

impl Safeguard {
    pub fn from_degrees(deg: f64) -> Safeguard {
        Safeguard { theta: deg.to_radians() }
    }

    /// Returns true if d_p must be replaced by −gʳ:
    /// ∠(−gʳ, d_p) ≥ θ, or d_p is numerically zero / non-descent.
    pub fn rejects(&self, g: &[f64], d_p: &[f64]) -> bool {
        let neg_g: Vec<f64> = g.iter().map(|x| -x).collect();
        match dense::angle(&neg_g, d_p) {
            None => true, // zero direction — replace
            Some(a) => {
                // at θ = π/2 exactly, demand strict descent (a < π/2)
                a >= self.theta
            }
        }
    }

    /// Apply the step to a batch of directions; returns how many were
    /// replaced (the `safeguard_hits` trace column).
    pub fn apply(&self, g: &[f64], dirs: &mut [Vec<f64>]) -> usize {
        let mut hits = 0;
        for d in dirs.iter_mut() {
            if self.rejects(g, d) {
                for (dj, gj) in d.iter_mut().zip(g) {
                    *dj = -gj;
                }
                hits += 1;
            }
        }
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_negative_gradient_itself() {
        let g = vec![1.0, -2.0, 0.5];
        let d: Vec<f64> = g.iter().map(|x| -x).collect();
        assert!(!Safeguard::default().rejects(&g, &d));
        assert!(!Safeguard::from_degrees(10.0).rejects(&g, &d));
    }

    #[test]
    fn rejects_ascent_and_orthogonal() {
        let g = vec![1.0, 0.0];
        let ascent = vec![1.0, 0.0]; // along +g
        let orth = vec![0.0, 1.0];
        let sg = Safeguard::default();
        assert!(sg.rejects(&g, &ascent));
        assert!(sg.rejects(&g, &orth)); // exactly π/2: not strict descent
    }

    #[test]
    fn tighter_theta_rejects_more() {
        let g = vec![1.0, 0.0];
        // 45° off −g
        let d = vec![-1.0, 1.0];
        assert!(!Safeguard::default().rejects(&g, &d));
        assert!(!Safeguard::from_degrees(46.0).rejects(&g, &d));
        assert!(Safeguard::from_degrees(44.0).rejects(&g, &d));
    }

    #[test]
    fn zero_direction_replaced() {
        let g = vec![1.0, 1.0];
        assert!(Safeguard::default().rejects(&g, &[0.0, 0.0]));
    }

    #[test]
    fn apply_replaces_and_counts() {
        let g = vec![1.0, 0.0];
        let mut dirs = vec![
            vec![-1.0, 0.1],  // fine
            vec![1.0, 0.0],   // ascent → replaced
            vec![0.0, 0.0],   // zero → replaced
        ];
        let hits = Safeguard::default().apply(&g, &mut dirs);
        assert_eq!(hits, 2);
        assert_eq!(dirs[1], vec![-1.0, 0.0]);
        assert_eq!(dirs[2], vec![-1.0, 0.0]);
        // replaced directions now pass the test
        assert!(!Safeguard::default().rejects(&g, &dirs[1]));
    }
}
