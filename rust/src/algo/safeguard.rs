//! Algorithm 1 step 6 — the "safe artifact" step: if the angle between
//! d_p and −gʳ reaches θ, replace d_p by −gʳ. Theorems 1–2 need
//! θ < π/2 (and θ > cos⁻¹(λ/L) for the probability bound); the paper's
//! practical recommendation is to accept anything that is a strict
//! descent direction, which corresponds to θ → π/2⁻ here.

use crate::linalg::dense;
use crate::objective::compact::{GlobalDots, HybridDir};

#[derive(Clone, Copy, Debug)]
pub struct Safeguard {
    /// threshold θ in radians, 0 ≤ θ < π/2 ... π/2 itself encodes the
    /// practical "any descent direction" policy
    pub theta: f64,
}

impl Default for Safeguard {
    fn default() -> Self {
        // practical setting: accept strict descent directions
        Safeguard { theta: std::f64::consts::FRAC_PI_2 }
    }
}

impl Safeguard {
    pub fn from_degrees(deg: f64) -> Safeguard {
        Safeguard { theta: deg.to_radians() }
    }

    /// Returns true if d_p must be replaced by −gʳ:
    /// ∠(−gʳ, d_p) ≥ θ, or d_p is numerically zero / non-descent.
    pub fn rejects(&self, g: &[f64], d_p: &[f64]) -> bool {
        let neg_g: Vec<f64> = g.iter().map(|x| -x).collect();
        match dense::angle(&neg_g, d_p) {
            None => true, // zero direction — replace
            Some(a) => {
                // at θ = π/2 exactly, demand strict descent (a < π/2)
                a >= self.theta
            }
        }
    }

    /// Apply the step to a batch of directions; returns how many were
    /// replaced (the `safeguard_hits` trace column).
    pub fn apply(&self, g: &[f64], dirs: &mut [Vec<f64>]) -> usize {
        let mut hits = 0;
        for d in dirs.iter_mut() {
            if self.rejects(g, d) {
                for (dj, gj) in d.iter_mut().zip(g) {
                    *dj = -gj;
                }
                hits += 1;
            }
        }
        hits
    }

    /// The async FS *correctness gate*: is the combined (possibly
    /// stale-contaminated) direction `d` acceptable — inside the θ
    /// cone around −gʳ and numerically nonzero? This is Algorithm 1's
    /// safeguard applied to the combined direction rather than a
    /// per-node one: any convex combination of per-node directions
    /// that each pass the angle test also passes it (the cosine bound
    /// survives convex combination), so a *rejection* here can only be
    /// caused by stale re-based contributions (or a numerically
    /// vanished sum) — exactly the contamination the bounded-staleness
    /// driver must discard before falling back to the synchronous
    /// barrier direction.
    pub fn accepts_combined(&self, g: &[f64], d: &[f64]) -> bool {
        !self.rejects(g, d)
    }

    /// Hybrid-direction form of [`Self::apply`]: the angle test runs on
    /// the shared global dots plus O(|support_p|) sparse dots — no node
    /// (or master) materializes any d_p. Mirrors `dense::angle`'s
    /// zero-vector policy (numerically zero d_p ⇒ replace by −gʳ).
    pub fn apply_hybrid(
        &self,
        dots: &GlobalDots,
        w: &[f64],
        g: &[f64],
        dirs: &mut [HybridDir],
    ) -> usize {
        self.apply_hybrid_flagged(dots, w, g, dirs, None)
    }

    /// [`Self::apply_hybrid`] with per-direction outcome capture: when
    /// `replaced` is given, the index of every rejected direction is
    /// pushed onto it (in slice order) — the flight recorder's
    /// `sg_replaced` field. Arithmetic is identical with or without
    /// the flag; `apply_hybrid` is this with `None`.
    pub fn apply_hybrid_flagged(
        &self,
        dots: &GlobalDots,
        w: &[f64],
        g: &[f64],
        dirs: &mut [HybridDir],
        mut replaced: Option<&mut Vec<usize>>,
    ) -> usize {
        let gnorm = dots.gg.sqrt();
        debug_assert!(
            gnorm.is_finite(),
            "non-finite ‖g‖ reached the safeguard angle test"
        );
        let mut hits = 0;
        for (i, d) in dirs.iter_mut().enumerate() {
            let dnorm = d.norm_sq(dots, w, g).sqrt();
            debug_assert!(
                dnorm.is_finite(),
                "non-finite hybrid-direction norm in the safeguard"
            );
            let reject = if gnorm <= f64::EPSILON || dnorm <= f64::EPSILON {
                true
            } else {
                let dg = d.dot_g(dots, g);
                debug_assert!(
                    dg.is_finite(),
                    "non-finite safeguard dot product d·g"
                );
                let cosang = (-dg / (gnorm * dnorm)).clamp(-1.0, 1.0);
                cosang.acos() >= self.theta
            };
            if reject {
                *d = HybridDir::neg_gradient(w.len());
                hits += 1;
                if let Some(out) = replaced.as_deref_mut() {
                    out.push(i);
                }
            }
        }
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_negative_gradient_itself() {
        let g = vec![1.0, -2.0, 0.5];
        let d: Vec<f64> = g.iter().map(|x| -x).collect();
        assert!(!Safeguard::default().rejects(&g, &d));
        assert!(!Safeguard::from_degrees(10.0).rejects(&g, &d));
    }

    #[test]
    fn rejects_ascent_and_orthogonal() {
        let g = vec![1.0, 0.0];
        let ascent = vec![1.0, 0.0]; // along +g
        let orth = vec![0.0, 1.0];
        let sg = Safeguard::default();
        assert!(sg.rejects(&g, &ascent));
        assert!(sg.rejects(&g, &orth)); // exactly π/2: not strict descent
    }

    #[test]
    fn tighter_theta_rejects_more() {
        let g = vec![1.0, 0.0];
        // 45° off −g
        let d = vec![-1.0, 1.0];
        assert!(!Safeguard::default().rejects(&g, &d));
        assert!(!Safeguard::from_degrees(46.0).rejects(&g, &d));
        assert!(Safeguard::from_degrees(44.0).rejects(&g, &d));
    }

    #[test]
    fn zero_direction_replaced() {
        let g = vec![1.0, 1.0];
        assert!(Safeguard::default().rejects(&g, &[0.0, 0.0]));
    }

    #[test]
    fn hybrid_apply_matches_dense_apply() {
        use crate::linalg::sparse::SparseVec;
        let w = vec![0.2, -0.5, 1.0, 0.0];
        let g = vec![1.0, 0.5, -0.25, 2.0];
        let dots = GlobalDots::compute(&w, &g);
        let mk = |a_w: f64, a_g: f64, pairs: Vec<(u32, f64)>| HybridDir {
            a_w,
            a_g,
            corr: SparseVec::from_pairs(4, pairs),
        };
        let mut dirs = vec![
            mk(0.0, -1.0, vec![(1, 0.1)]), // near −g: kept
            mk(0.0, 1.0, vec![]),          // along +g: replaced
            mk(0.0, 0.0, vec![]),          // zero: replaced
        ];
        let mut dense_dirs: Vec<Vec<f64>> =
            dirs.iter().map(|d| d.to_dense(&w, &g)).collect();
        let sg = Safeguard::default();
        let hits_dense = sg.apply(&g, &mut dense_dirs);
        let hits_hybrid = sg.apply_hybrid(&dots, &w, &g, &mut dirs);
        assert_eq!(hits_dense, hits_hybrid);
        assert_eq!(hits_hybrid, 2);
        for (hd, dd) in dirs.iter().zip(&dense_dirs) {
            assert!(
                dense::max_abs_diff(&hd.to_dense(&w, &g), dd) < 1e-12
            );
        }
    }

    #[test]
    fn flagged_apply_reports_replaced_indices() {
        use crate::linalg::sparse::SparseVec;
        let w = vec![0.2, -0.5, 1.0, 0.0];
        let g = vec![1.0, 0.5, -0.25, 2.0];
        let dots = GlobalDots::compute(&w, &g);
        let mk = |a_w: f64, a_g: f64, pairs: Vec<(u32, f64)>| HybridDir {
            a_w,
            a_g,
            corr: SparseVec::from_pairs(4, pairs),
        };
        let fixture = || {
            vec![
                mk(0.0, -1.0, vec![(1, 0.1)]), // near −g: kept
                mk(0.0, 1.0, vec![]),          // along +g: replaced
                mk(0.0, 0.0, vec![]),          // zero: replaced
            ]
        };
        let sg = Safeguard::default();

        let mut plain = fixture();
        let hits_plain = sg.apply_hybrid(&dots, &w, &g, &mut plain);

        let mut flagged = fixture();
        let mut replaced = Vec::new();
        let hits_flagged = sg.apply_hybrid_flagged(
            &dots,
            &w,
            &g,
            &mut flagged,
            Some(&mut replaced),
        );

        assert_eq!(hits_plain, hits_flagged);
        assert_eq!(replaced, vec![1, 2]);
        assert_eq!(replaced.len(), hits_flagged);
        for (a, b) in plain.iter().zip(&flagged) {
            assert_eq!(a.to_dense(&w, &g), b.to_dense(&w, &g));
        }
    }

    #[test]
    fn combined_gate_mirrors_rejects() {
        let g = vec![1.0, 0.0];
        let sg = Safeguard::default();
        assert!(sg.accepts_combined(&g, &[-1.0, 0.2]));
        assert!(!sg.accepts_combined(&g, &[0.0, 1.0]));
        assert!(!sg.accepts_combined(&g, &[0.0, 0.0]));
    }

    #[test]
    fn apply_replaces_and_counts() {
        let g = vec![1.0, 0.0];
        let mut dirs = vec![
            vec![-1.0, 0.1],  // fine
            vec![1.0, 0.0],   // ascent → replaced
            vec![0.0, 0.0],   // zero → replaced
        ];
        let hits = Safeguard::default().apply(&g, &mut dirs);
        assert_eq!(hits, 2);
        assert_eq!(dirs[1], vec![-1.0, 0.0]);
        assert_eq!(dirs[2], vec![-1.0, 0.0]);
        // replaced directions now pass the test
        assert!(!Safeguard::default().rejects(&g, &dirs[1]));
    }
}
