//! Hybrid — SQM warm-started by one round of parameter mixing, exactly
//! as the paper describes: "Each node p does one epoch of SGD [1] on
//! its examples; then the weights from various nodes are averaged to
//! form a weight vector that is used to initialize SQM."

use crate::algo::param_mix::{ParamMixConfig, ParamMixDriver};
use crate::algo::sqm::{SqmConfig, SqmDriver};
use crate::algo::{Driver, RunResult, StopRule};
use crate::cluster::Cluster;
use crate::data::dataset::Dataset;

#[derive(Clone, Debug, Default)]
pub struct HybridConfig {
    pub sqm: SqmConfig,
    pub mix: ParamMixConfig,
}

pub struct HybridDriver {
    pub config: HybridConfig,
}

impl HybridDriver {
    pub fn new(config: HybridConfig) -> HybridDriver {
        HybridDriver { config }
    }

    /// Convenience: consistent loss/λ across both phases.
    pub fn with_objective(mut config: HybridConfig) -> HybridDriver {
        config.mix.loss = config.sqm.loss;
        config.mix.lam = config.sqm.lam;
        HybridDriver { config }
    }
}

impl Driver for HybridDriver {
    fn name(&self) -> String {
        "hybrid".to_string()
    }

    fn run(
        &self,
        cluster: &mut Cluster,
        test: Option<&Dataset>,
        stop: &StopRule,
    ) -> RunResult {
        // phase 1: one parameter-mixing round (1 SGD epoch per node,
        // average) — 1 bcast + 1 allreduce. SQM consumes the warm
        // start as a full-d vector, so the mixing round stays in the
        // dense frame here; the zero start is a named binding rather
        // than a throwaway temporary on the call.
        cluster.broadcast_vec();
        let mixer = ParamMixDriver::new(self.config.mix.clone());
        let w0 = vec![0.0; cluster.dim];
        let w_init = mixer.round(cluster, &w0, 0);

        // phase 2: SQM from the mixed start; ledger carries over
        let sqm = SqmDriver::with_start(self.config.sqm.clone(), w_init);
        let mut result = sqm.run(cluster, test, stop);
        result.trace.label = self.name();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::synth::SynthConfig;
    use crate::loss::LossKind;

    fn make_cluster() -> Cluster {
        let data = SynthConfig {
            n_examples: 300,
            n_features: 40,
            nnz_per_example: 6,
            ..SynthConfig::default()
        }
        .generate(41);
        Cluster::partition(data, 4, CostModel::free())
    }

    fn cfg() -> HybridConfig {
        HybridConfig {
            sqm: SqmConfig {
                lam: 0.5,
                loss: LossKind::Logistic,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn converges_like_sqm() {
        let mut cluster = make_cluster();
        let run = HybridDriver::with_objective(cfg())
            .run(&mut cluster, None, &StopRule::iters(100));
        let last = run.trace.last().unwrap();
        assert!(last.gnorm < 1e-6 * run.trace.points[0].gnorm.max(1.0));
        assert_eq!(run.trace.label, "hybrid");
    }

    #[test]
    fn warm_start_at_least_as_good_early() {
        // at equal comm-pass budget, hybrid's first recorded f should
        // not be (much) worse than cold SQM's — usually better
        let mut c_cold = make_cluster();
        let mut c_warm = make_cluster();
        let sqm_run = SqmDriver::new(SqmConfig {
            lam: 0.5,
            ..Default::default()
        })
        .run(&mut c_cold, None, &StopRule::iters(2));
        let hyb_run = HybridDriver::with_objective(cfg())
            .run(&mut c_warm, None, &StopRule::iters(2));
        assert!(
            hyb_run.trace.points[0].f <= sqm_run.trace.points[0].f * 1.001,
            "hybrid start {} vs sqm start {}",
            hyb_run.trace.points[0].f,
            sqm_run.trace.points[0].f
        );
    }
}
