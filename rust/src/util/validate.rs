//! Up-front validation for `psgd train` flags: every rejection is a
//! clear one-line error *before* the run starts, instead of a panic
//! three modules deep once the cluster is already built (`--quorum 9`
//! on 4 nodes used to die inside the quorum clamp; `--staleness` with
//! plain `--fs` was silently ignored; a malformed `--straggler` spec
//! panicked mid-profile-construction). `main` prints the message and
//! exits 2; the checks themselves are pure so every rejection is
//! unit-testable.

use crate::cluster::{FaultPlan, LinkFaultPlan, LinkProfile};
use crate::util::cli::Args;

/// Validate the `train` flag set against the resolved node count.
/// Returns the first problem found as a one-line message.
pub fn validate_train(args: &Args, nodes: usize) -> Result<(), String> {
    let method = args.get_or("method", "fs");
    let is_async = method == "fs"
        && matches!(args.get("async-fs"), Some("true" | "1" | "yes"));

    if let Some(q) = args.get("quorum") {
        if !is_async {
            return Err(
                "--quorum only applies to --async-fs runs (method fs)"
                    .to_string(),
            );
        }
        let q: usize = q.parse().map_err(|_| {
            format!("--quorum expects a positive integer, got {q:?}")
        })?;
        if q == 0 {
            return Err("--quorum must be at least 1".to_string());
        }
        if q > nodes {
            return Err(format!(
                "--quorum {q} exceeds the cluster size (P = {nodes})"
            ));
        }
    }

    if let Some(t) = args.get("staleness") {
        if !is_async {
            return Err(
                "--staleness only applies to --async-fs runs (method fs)"
                    .to_string(),
            );
        }
        t.parse::<usize>().map_err(|_| {
            format!("--staleness expects a non-negative integer, got {t:?}")
        })?;
    }

    let is_adaptive =
        matches!(args.get("adaptive"), Some("true" | "1" | "yes"));
    if is_adaptive && !is_async {
        return Err(
            "--adaptive only applies to --async-fs runs (method fs)"
                .to_string(),
        );
    }
    if let Some(t) = args.get("tau-max") {
        if !is_adaptive {
            return Err(
                "--tau-max requires --adaptive (the self-tuning policy)"
                    .to_string(),
            );
        }
        t.parse::<usize>().map_err(|_| {
            format!("--tau-max expects a non-negative integer, got {t:?}")
        })?;
    }
    if let Some(q) = args.get("q-min") {
        if !is_adaptive {
            return Err(
                "--q-min requires --adaptive (the self-tuning policy)"
                    .to_string(),
            );
        }
        let q: usize = q.parse().map_err(|_| {
            format!("--q-min expects a positive integer, got {q:?}")
        })?;
        if q == 0 {
            return Err("--q-min must be at least 1".to_string());
        }
        if q > nodes {
            return Err(format!(
                "--q-min {q} exceeds the cluster size (P = {nodes})"
            ));
        }
    }
    if matches!(args.get("speculate"), Some("true" | "1" | "yes"))
        && !is_async
    {
        return Err(
            "--speculate only applies to --async-fs runs (method fs)"
                .to_string(),
        );
    }

    if let Some(spec) = args.get("straggler") {
        parse_straggler(spec, nodes)?;
    }

    if let Some(x) = args.get("profile-spread") {
        let x: f64 = x.parse().map_err(|_| {
            format!("--profile-spread expects a number, got {x:?}")
        })?;
        if x.is_nan() || x < 0.0 {
            return Err(format!(
                "--profile-spread must be non-negative, got {x}"
            ));
        }
    }
    if let Some(s) = args.get("profile-seed") {
        s.parse::<u64>().map_err(|_| {
            format!("--profile-seed expects an integer, got {s:?}")
        })?;
    }

    if let Some(spec) = args.get("fault") {
        if !is_async {
            return Err(
                "--fault requires --async-fs (the fault-tolerant driver)"
                    .to_string(),
            );
        }
        if spec != "seeded" {
            FaultPlan::parse(spec, nodes)?;
        }
    }
    if let Some(s) = args.get("fault-seed") {
        s.parse::<u64>().map_err(|_| {
            format!("--fault-seed expects an integer, got {s:?}")
        })?;
    }

    // link weather: the profile shapes every method's tree hops, so it
    // is method-agnostic; the fault plan needs the retrying/rerouting
    // reduction paths, which only the async driver exercises.
    if let Some(spec) = args.get("link-profile") {
        if spec != "seeded" && spec != "uniform" {
            LinkProfile::parse(spec, nodes)?;
        }
    }
    if let Some(spec) = args.get("link-fault") {
        if !is_async {
            return Err(
                "--link-fault requires --async-fs (the fault-tolerant \
                 driver)"
                    .to_string(),
            );
        }
        if spec != "seeded" {
            LinkFaultPlan::parse(spec, nodes)?;
        }
    }
    if let Some(s) = args.get("link-seed") {
        s.parse::<u64>().map_err(|_| {
            format!("--link-seed expects an integer, got {s:?}")
        })?;
    }

    Ok(())
}

/// Parse and range-check a `--straggler N:F` spec.
pub fn parse_straggler(
    spec: &str,
    nodes: usize,
) -> Result<(usize, f64), String> {
    let (node, factor) = spec
        .split_once(':')
        .ok_or_else(|| format!("--straggler expects N:F, got {spec:?}"))?;
    let node: usize = node.parse().map_err(|_| {
        format!("--straggler node index must be an integer, got {node:?}")
    })?;
    let factor: f64 = factor.parse().map_err(|_| {
        format!("--straggler factor must be a number, got {factor:?}")
    })?;
    if node >= nodes {
        return Err(format!(
            "--straggler node {node} out of range (cluster has {nodes} \
             nodes, indices 0..{nodes})"
        ));
    }
    if factor.is_nan() || factor <= 0.0 {
        return Err(format!(
            "--straggler factor must be positive, got {factor}"
        ));
    }
    Ok((node, factor))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    fn err(s: &str, nodes: usize) -> String {
        validate_train(&args(s), nodes).unwrap_err()
    }

    #[test]
    fn quorum_over_cluster_size_is_rejected() {
        let e = err("train --async-fs --quorum 9", 4);
        assert!(e.contains("exceeds the cluster size"), "{e}");
        assert!(!e.contains('\n'), "one line: {e}");
    }

    #[test]
    fn quorum_and_staleness_require_async() {
        let e = err("train --quorum 2", 4);
        assert!(e.contains("--async-fs"), "{e}");
        let e = err("train --staleness 1", 4);
        assert!(e.contains("--async-fs"), "{e}");
        // fine on the async driver
        assert!(validate_train(
            &args("train --async-fs --quorum 2 --staleness 1"),
            4
        )
        .is_ok());
    }

    #[test]
    fn quorum_zero_and_garbage_are_rejected() {
        assert!(err("train --async-fs --quorum 0", 4)
            .contains("at least 1"));
        assert!(err("train --async-fs --quorum abc", 4)
            .contains("positive integer"));
        assert!(err("train --async-fs --staleness -1", 4)
            .contains("non-negative"));
    }

    #[test]
    fn speculation_and_adaptive_flags_require_async() {
        let e = err("train --speculate", 4);
        assert!(e.contains("--async-fs"), "{e}");
        let e = err("train --adaptive", 4);
        assert!(e.contains("--async-fs"), "{e}");
        // tuning bounds require the adaptive policy itself
        let e = err("train --async-fs --tau-max 4", 4);
        assert!(e.contains("--adaptive"), "{e}");
        let e = err("train --async-fs --q-min 2", 4);
        assert!(e.contains("--adaptive"), "{e}");
        // bound sanity
        assert!(err("train --async-fs --adaptive --q-min 0", 4)
            .contains("at least 1"));
        assert!(err("train --async-fs --adaptive --q-min 9", 4)
            .contains("exceeds the cluster size"));
        assert!(err("train --async-fs --adaptive --tau-max x", 4)
            .contains("non-negative"));
        // the full adaptive + speculation flag set is accepted
        assert!(validate_train(
            &args(
                "train --async-fs --adaptive --tau-max 4 --q-min 2 \
                 --speculate"
            ),
            4
        )
        .is_ok());
    }

    #[test]
    fn malformed_straggler_specs_are_rejected() {
        for (spec, what) in [
            ("3", "expects N:F"),
            ("a:2", "must be an integer"),
            ("0:x", "must be a number"),
            ("9:2", "out of range"),
            ("0:0", "must be positive"),
            ("0:-3", "must be positive"),
        ] {
            let e = err(&format!("train --straggler {spec}"), 4);
            assert!(e.contains(what), "{spec}: {e}");
            assert!(!e.contains('\n'), "one line: {e}");
        }
        assert_eq!(parse_straggler("2:3.5", 4), Ok((2, 3.5)));
    }

    #[test]
    fn profile_spread_is_range_checked() {
        assert!(err("train --profile-spread -0.5", 4)
            .contains("non-negative"));
        assert!(err("train --profile-spread abc", 4)
            .contains("expects a number"));
        assert!(err("train --profile-seed 1.5", 4)
            .contains("expects an integer"));
        assert!(
            validate_train(&args("train --profile-spread 0.5"), 4).is_ok()
        );
    }

    #[test]
    fn fault_flag_requires_async_and_a_parsable_plan() {
        let e = err("train --fault crash:1@r2", 4);
        assert!(e.contains("requires --async-fs"), "{e}");
        let e = err("train --async-fs --fault crash:9@r2", 4);
        assert!(e.contains("bad --fault spec"), "{e}");
        assert!(!e.contains('\n'), "one line: {e}");
        assert!(validate_train(
            &args("train --async-fs --fault crash:1@r2,restart:1@r5"),
            4
        )
        .is_ok());
        assert!(validate_train(
            &args("train --async-fs --fault seeded --fault-seed 7"),
            4
        )
        .is_ok());
    }

    #[test]
    fn link_profile_is_validated_on_any_method() {
        // out-of-range node index: rejected with a one-line error
        let e = err("train --link-profile uplink:9:2x", 4);
        assert!(e.contains("bad --link-profile spec"), "{e}");
        assert!(e.contains("out of range"), "{e}");
        assert!(!e.contains('\n'), "one line: {e}");
        // the profile shapes hops on every method — no async gate
        assert!(validate_train(
            &args("train --link-profile uplink:1:2.5x,level:2:2x"),
            4
        )
        .is_ok());
        assert!(validate_train(
            &args("train --link-profile seeded --link-seed 7"),
            4
        )
        .is_ok());
        assert!(err("train --link-seed 1.5", 4)
            .contains("expects an integer"));
    }

    #[test]
    fn link_fault_requires_async_and_a_parsable_plan() {
        let e = err("train --link-fault congest:p=0.2", 4);
        assert!(e.contains("requires --async-fs"), "{e}");
        // out-of-range partition node: rejected with a one-line error
        let e = err("train --async-fs --link-fault part:9@r1..r3", 4);
        assert!(e.contains("bad --link-fault spec"), "{e}");
        assert!(e.contains("out of range"), "{e}");
        assert!(!e.contains('\n'), "one line: {e}");
        assert!(validate_train(
            &args(
                "train --async-fs --link-fault \
                 congest:p=0.1:4x,part:2+3@r5..r8,timeout:0.01,budget:2"
            ),
            4
        )
        .is_ok());
        assert!(validate_train(
            &args("train --async-fs --link-fault seeded --link-seed 7"),
            4
        )
        .is_ok());
    }
}
