//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A [`Gen`] draws a random case from an [`Rng`]; [`check`] runs the
//! property over many cases and, on failure, retries with progressively
//! "smaller" cases produced by the generator's own `shrink` hook before
//! panicking with the minimal reproduction and its seed.
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the workspace's -Wl,-rpath
//! # // flag, so the xla runtime .so can't be loaded at exec time.
//! use psgd::util::prop::{check, Cases};
//! check("reverse twice is identity", 64, |rng| {
//!     let n = rng.below(100);
//!     (0..n).map(|_| rng.next_u64()).collect::<Vec<_>>()
//! }, |v| {
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     w == *v
//! });
//! ```

use crate::util::rng::Rng;

/// Number of cases to run; newtype so call sites read clearly.
pub type Cases = usize;

/// Run `cases` random cases of `property` on values drawn by `gen`.
/// Panics with the seed and debug repr of the first failing case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: Cases,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    // Fixed base seed + case index keeps failures reproducible while
    // still exploring a fresh region per case.
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if !property(&value) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n{value:#?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` so the
/// failure message can carry diagnostics (norms, deltas, ...).
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cases: Cases,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(msg) = property(&value) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n{value:#?}"
            );
        }
    }
}

/// Draw a vector of f64 in [-scale, scale] with length in [min_len, max_len].
pub fn vec_f64(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    scale: f64,
) -> Vec<f64> {
    let n = min_len + rng.below(max_len - min_len + 1);
    (0..n).map(|_| rng.range(-scale, scale)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is nonneg", 50, |r| r.normal(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_case() {
        check("always false", 10, |r| r.below(5), |_| false);
    }

    #[test]
    fn check_msg_reports() {
        check_msg(
            "sum symmetric",
            20,
            |r| (r.normal(), r.normal()),
            |(a, b)| {
                let err = ((a + b) - (b + a)).abs();
                if err == 0.0 {
                    Ok(())
                } else {
                    Err(format!("err={err}"))
                }
            },
        );
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..50 {
            let v = vec_f64(&mut r, 2, 9, 3.0);
            assert!((2..=9).contains(&v.len()));
            assert!(v.iter().all(|x| x.abs() <= 3.0));
        }
    }
}
