//! Deterministic pseudo-random numbers: xoshiro256++ seeded via
//! splitmix64. Every stochastic component in the library (data
//! generation, SGD shuffling, property tests) takes an explicit seed so
//! runs are exactly reproducible.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; plenty
/// for simulation workloads. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the Box–Muller pair
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-node / per-epoch RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// ±1 with equal probability (labels).
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Geometric-ish power-law sample over [0, n): index i drawn with
    /// probability ∝ (i+1)^{-alpha}. Used by the synthetic generator to
    /// mimic kdd2010's long-tailed feature frequencies. Implemented by
    /// inverse-CDF over a precomputed table — see `data::synth`.
    pub fn zipf_u01_to_index(u: f64, cdf: &[f64]) -> usize {
        match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(6);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
