//! Experiment config files: a TOML-subset (`key = value` lines with
//! `[section]` headers, `#` comments, strings/numbers/bools). Enough to
//! drive the launcher (`psgd train --config exp.toml`) without serde.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// section -> key -> raw value; the "" section holds top-level keys.
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line
                .strip_prefix('[')
                .and_then(|r| r.strip_suffix(']'))
            {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(format!(
                "line {}: expected `key = value`, got {raw:?}",
                lineno + 1
            ))?;
            let v = v.trim();
            let v = v
                .strip_prefix('"')
                .and_then(|s| s.strip_suffix('"'))
                .unwrap_or(v);
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.to_string());
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&src)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections
            .get(section)
            .and_then(|m| m.get(key))
            .map(|s| s.as_str())
    }

    pub fn usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .map(|v| v.parse().expect("integer config value"))
            .unwrap_or(default)
    }

    pub fn f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .map(|v| v.parse().expect("numeric config value"))
            .unwrap_or(default)
    }

    pub fn bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .map(|v| v == "true" || v == "1")
            .unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
# experiment config
seed = 42
[data]
examples = 200000   # kdd2010-shaped
features = 500000
[fs]
epochs = 2
theta_deg = 0       # practical setting from the paper
lambda = 1e-5
name = "fs-2"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SRC).unwrap();
        assert_eq!(c.usize("", "seed", 0), 42);
        assert_eq!(c.usize("data", "examples", 0), 200_000);
        assert_eq!(c.f64("fs", "lambda", 0.0), 1e-5);
        assert_eq!(c.get("fs", "name"), Some("fs-2"));
        assert_eq!(c.usize("fs", "missing", 9), 9);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("just words\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = Config::parse("# only a comment\n\n").unwrap();
        assert_eq!(c, Config::default());
    }
}
