//! Wall-clock scoping helpers used by the cluster simulator to measure
//! per-node compute phases.

use std::time::{Duration, Instant};

/// Accumulates named durations; the cluster's "compute clock" per node.
#[derive(Clone, Debug, Default)]
pub struct Stopwatch {
    total: Duration,
}

impl Stopwatch {
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed();
        out
    }

    pub fn add(&mut self, d: Duration) {
        self.total += d;
    }

    pub fn seconds(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn reset(&mut self) -> Duration {
        std::mem::take(&mut self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::default();
        sw.add(Duration::from_millis(5));
        sw.add(Duration::from_millis(7));
        assert!((sw.seconds() - 0.012).abs() < 1e-9);
        assert_eq!(sw.reset(), Duration::from_millis(12));
        assert_eq!(sw.seconds(), 0.0);
    }

    #[test]
    fn times_closures() {
        let mut sw = Stopwatch::default();
        let x = sw.time(|| 21 * 2);
        assert_eq!(x, 42);
    }
}
