//! In-tree substrates that a networked build would pull from crates.io:
//! RNG, CLI parsing, config files, JSON/CSV emission, property testing.
//! (The image's offline cargo registry has none of rand/clap/serde/
//! proptest — DESIGN.md §3.)

pub mod cli;
pub mod config;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
pub mod validate;
