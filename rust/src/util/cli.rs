//! Tiny argv parser: `--flag`, `--key value`, `--key=value` and
//! positionals, with typed getters and a generated usage string.
//! (clap is unavailable offline — DESIGN.md §3.)

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw argv (excluding the binary name). A repeated
    /// `--key value` accumulates comma-joined (`--fault a --fault b`
    /// ≡ `--fault a,b` — fault scripts, like every comma-separated
    /// spec here, merge instead of silently last-wins).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    Self::put(&mut out.flags, k, v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    Self::put(&mut out.flags, body, v);
                } else {
                    Self::put(&mut out.flags, body, "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Insert a flag value, comma-joining onto any previous occurrence.
    fn put(flags: &mut BTreeMap<String, String>, key: &str, val: String) {
        match flags.get_mut(key) {
            Some(old) => {
                old.push(',');
                old.push_str(&val);
            }
            None => {
                flags.insert(key.to_string(), val);
            }
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a bool, got {v:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_all_forms() {
        // note: a bare token right after a flag binds as its value, so
        // positionals go before flags (or use --flag=value)
        let a = args("train extra --nodes 25 --loss=logistic --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize("nodes", 0), 25);
        assert_eq!(a.get("loss"), Some("logistic"));
        assert!(a.bool("verbose", false));
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.usize("nodes", 4), 4);
        assert_eq!(a.f64("lambda", 1e-5), 1e-5);
        assert!(!a.bool("quiet", false));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = args("--fast --nodes 8");
        assert!(a.bool("fast", false));
        assert_eq!(a.usize("nodes", 0), 8);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        args("--nodes abc").usize("nodes", 0);
    }

    #[test]
    fn repeated_flags_comma_join() {
        let a = args("--fault crash:3@r2 --fault flap:2:p=0.05");
        assert_eq!(a.get("fault"), Some("crash:3@r2,flap:2:p=0.05"));
        let b = args("--fault=crash:1@r2 --fault restart:1@r6");
        assert_eq!(b.get("fault"), Some("crash:1@r2,restart:1@r6"));
    }
}
