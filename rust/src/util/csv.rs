//! CSV emission for experiment series (the figure-regeneration benches
//! write their panel data through this).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A rectangular table with a header row; numeric cells formatted with
/// full precision so downstream plotting is lossless.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Table {
        Table {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row arity must match header"
        );
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            let mut first = true;
            for v in row {
                if !first {
                    out.push(',');
                }
                first = false;
                let _ = write!(out, "{v}");
            }
            out.push('\n');
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Parse a numeric CSV produced by [`Table::to_csv`].
pub fn parse(src: &str) -> Result<Table, String> {
    let mut lines = src.lines();
    let header = lines.next().ok_or("empty csv")?;
    let columns: Vec<String> =
        header.split(',').map(|s| s.to_string()).collect();
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row: Result<Vec<f64>, _> =
            line.split(',').map(|c| c.trim().parse::<f64>()).collect();
        rows.push(row.map_err(|e| format!("row {}: {e}", i + 2))?);
        if rows.last().unwrap().len() != columns.len() {
            return Err(format!("row {} arity mismatch", i + 2));
        }
    }
    Ok(Table { columns, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(&["pass", "relgap"]);
        t.push(vec![1.0, 0.5]);
        t.push(vec![2.0, 0.125]);
        let parsed = parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.columns, t.columns);
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec![1.0]);
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(parse("a,b\n1,x\n").is_err());
    }
}
