//! Minimal JSON: a `Value` tree, an emitter, and a small recursive-
//! descent parser (enough to read `artifacts/manifest.json` and our own
//! run records; not a general-purpose library).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Serialize; `indent=0` → compact, else pretty with that step.
    pub fn to_json(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, indent, 0);
        out
    }

    fn write(&self, out: &mut String, step: usize, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Value::Num(x) => write_num(out, *x),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(xs) => {
                write_seq(out, step, depth, '[', ']', xs.len(), |o, i| {
                    xs[i].write(o, step, depth + 1)
                })
            }
            Value::Obj(m) => {
                let keys: Vec<&String> = m.keys().collect();
                write_seq(out, step, depth, '{', '}', m.len(), |o, i| {
                    write_escaped(o, keys[i]);
                    o.push_str(": ");
                    m[keys[i]].write(o, step, depth + 1);
                })
            }
        }
    }
}

/// Append one JSON number — the exact emission `Value::Num` uses:
/// integral values with |x| < 1e15 print as integers, other finite
/// values via shortest-round-trip `Display`, and non-finite values
/// (the `TracePoint::auprc` NaN sentinel) as `null`, since JSON has no
/// Inf/NaN tokens. Public so the allocation-free JSONL round writer
/// ([`crate::obs::JsonlRecorder`]) emits byte-identical numbers that
/// [`parse`] round-trips to the same `f64` bits.
pub fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    step: usize,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize),
) {
    out.push(open);
    for i in 0..len {
        if step > 0 {
            out.push('\n');
            out.push_str(&" ".repeat(step * (depth + 1)));
        }
        item(out, i);
        if i + 1 < len {
            out.push(',');
            if step == 0 {
                out.push(' ');
            }
        }
    }
    if step > 0 && len > 0 {
        out.push('\n');
        out.push_str(&" ".repeat(step * depth));
    }
    out.push(close);
}

/// Parse a JSON document. Returns Err(position, message) on failure.
pub fn parse(src: &str) -> Result<Value, String> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            })
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(xs));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Value::obj(vec![
            ("n", Value::Num(2048.0)),
            ("name", Value::Str("svrg_epoch".into())),
            (
                "xs",
                Value::Arr(vec![Value::Num(1.5), Value::Bool(true), Value::Null]),
            ),
        ]);
        for indent in [0, 2] {
            let s = v.to_json(indent);
            assert_eq!(parse(&s).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"n": 2048, "d": 1024, "artifacts": {"margins": "margins.hlo.txt"}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(2048));
        assert_eq!(
            v.get("artifacts").unwrap().get("margins").unwrap().as_str(),
            Some("margins.hlo.txt")
        );
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        let s = v.to_json(0);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn non_finite_floats_serialize_as_null_and_round_trip() {
        // JSON has no Inf/NaN tokens: emitting them raw would produce
        // an unparseable document. The auprc NaN sentinel must come
        // back as Null, not break the stream.
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Value::Arr(vec![Value::Num(x), Value::Num(1.5)]);
            let s = v.to_json(0);
            assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
            let back = parse(&s).unwrap();
            assert_eq!(
                back,
                Value::Arr(vec![Value::Null, Value::Num(1.5)]),
                "{s}"
            );
        }
    }

    #[test]
    fn write_num_matches_value_num_byte_for_byte() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -17.0,
            0.1,
            1.25e-9,
            9.9e14,
            1.1e15,
            f64::NAN,
            f64::INFINITY,
        ] {
            let mut direct = String::new();
            write_num(&mut direct, x);
            assert_eq!(direct, Value::Num(x).to_json(0), "x={x}");
        }
    }

    #[test]
    fn finite_floats_round_trip_to_identical_bits() {
        for x in [0.1, 1.0 / 3.0, 6.02214076e23, -2.5e-308, 42.0] {
            let mut s = String::new();
            write_num(&mut s, x);
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{s}");
        }
    }
}
