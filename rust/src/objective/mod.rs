//! Objectives: the regularized risk f(w) and the paper's
//! gradient-consistent local approximation f̂_p (eq. 2).
//!
//! `f(w) = (λ/2)‖w‖² + Σ_i l(w·x_i, y_i)` — note the paper uses the
//! *sum* of losses, not the mean; λ is scaled accordingly by callers.

use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::linalg::{dense, Csr};
use crate::loss::LossKind;

/// A differentiable objective on R^d. Implemented by the full
/// regularized risk (single-machine view) and by the tilted local
/// approximation each node optimizes in Algorithm 1 step 5.
pub trait Objective {
    fn dim(&self) -> usize;
    fn value(&self, w: &[f64]) -> f64;
    /// out ← ∇f(w)
    fn grad(&self, w: &[f64], out: &mut [f64]);
    /// Fused value+gradient (one pass over the data).
    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        self.grad(w, out);
        self.value(w)
    }
    /// out ← ∇²f(w)·v — needed by TRON; optional elsewhere.
    fn hess_vec(&self, _w: &[f64], _v: &[f64], _out: &mut [f64]) {
        unimplemented!("Hessian-vector product not provided")
    }
}

/// Shard-level loss pass: returns Σ l_i and accumulates Xᵀ l' into
/// `grad` (which the caller zeroes); also exposes the margin by-product
/// z_i = w·x_i the paper reuses for its line search (step 1).
pub fn shard_loss_grad(
    x: &Csr,
    y: &[f64],
    w: &[f64],
    loss: LossKind,
    grad: &mut [f64],
    margins_out: Option<&mut Vec<f64>>,
) -> f64 {
    debug_assert_eq!(x.n_rows(), y.len());
    let mut val = 0.0;
    if let Some(z) = margins_out {
        z.resize(x.n_rows(), 0.0);
        for i in 0..x.n_rows() {
            let zi = x.row_dot(i, w);
            z[i] = zi;
            val += loss.value(zi, y[i]);
            let r = loss.deriv(zi, y[i]);
            if r != 0.0 {
                x.add_row_scaled(i, r, grad);
            }
        }
    } else {
        for i in 0..x.n_rows() {
            let zi = x.row_dot(i, w);
            val += loss.value(zi, y[i]);
            let r = loss.deriv(zi, y[i]);
            if r != 0.0 {
                x.add_row_scaled(i, r, grad);
            }
        }
    }
    val
}

/// Sparse shard-level loss pass: like [`shard_loss_grad`] but the
/// gradient is accumulated over the shard's column support only
/// (O(|support|) memory instead of O(d)) and returned as index/value
/// pairs ready for the sparse tree reduction. The λ term is NOT
/// included — the master applies it lazily after the merge, which is
/// exact because λw is common to every node.
///
/// Accumulation visits rows (and entries within a row) in the same
/// order as the dense pass, so the two agree coordinate-for-coordinate,
/// not just to rounding tolerance.
pub fn shard_loss_grad_sparse(
    x: &Csr,
    y: &[f64],
    w: &[f64],
    loss: LossKind,
    map: &SupportMap,
    margins_out: Option<&mut Vec<f64>>,
) -> (f64, SparseVec) {
    debug_assert_eq!(x.n_rows(), y.len());
    match margins_out {
        Some(z) => {
            z.resize(x.n_rows(), 0.0);
            sparse_loss_pass(x, y, loss, map, |i| {
                let zi = x.row_dot(i, w);
                z[i] = zi;
                zi
            })
        }
        None => sparse_loss_pass(x, y, loss, map, |i| x.row_dot(i, w)),
    }
}

/// Cached-margin variant of [`shard_loss_grad_sparse`] (FS keeps
/// zᵢ = w·xᵢ node-local across outer iterations): one data pass, no
/// X·w matvec.
pub fn shard_loss_grad_sparse_cached(
    x: &Csr,
    y: &[f64],
    z: &[f64],
    loss: LossKind,
    map: &SupportMap,
) -> (f64, SparseVec) {
    debug_assert_eq!(x.n_rows(), z.len());
    sparse_loss_pass(x, y, loss, map, |i| z[i])
}

/// The shared sparse loss sweep: rows in order, margin supplied by the
/// caller (computed, computed-and-recorded, or cached), gradient
/// accumulated over the support coordinates.
fn sparse_loss_pass(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    map: &SupportMap,
    mut margin_of: impl FnMut(usize) -> f64,
) -> (f64, SparseVec) {
    let mut vals = vec![0.0; map.support.len()];
    let mut val = 0.0;
    for i in 0..x.n_rows() {
        let zi = margin_of(i);
        val += loss.value(zi, y[i]);
        let r = loss.deriv(zi, y[i]);
        if r != 0.0 {
            map.add_row_scaled(x, i, r, &mut vals);
        }
    }
    (val, SparseVec::from_support(x.n_cols, &map.support, &vals))
}

/// The full regularized risk over one dataset (single-machine view and
/// per-test oracle): f(w) = (λ/2)‖w‖² + Σ l(w·xᵢ, yᵢ).
pub struct RegularizedLoss<'a> {
    pub x: &'a Csr,
    pub y: &'a [f64],
    pub loss: LossKind,
    pub lam: f64,
}

impl<'a> Objective for RegularizedLoss<'a> {
    fn dim(&self) -> usize {
        self.x.n_cols
    }

    fn value(&self, w: &[f64]) -> f64 {
        let mut v = 0.5 * self.lam * dense::norm_sq(w);
        for i in 0..self.x.n_rows() {
            v += self.loss.value(self.x.row_dot(i, w), self.y[i]);
        }
        v
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        out.iter_mut().for_each(|g| *g = 0.0);
        shard_loss_grad(self.x, self.y, w, self.loss, out, None);
        dense::axpy(self.lam, w, out);
    }

    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        out.iter_mut().for_each(|g| *g = 0.0);
        let lv = shard_loss_grad(self.x, self.y, w, self.loss, out, None);
        dense::axpy(self.lam, w, out);
        lv + 0.5 * self.lam * dense::norm_sq(w)
    }

    /// H·v = λv + Xᵀ D X v, D_ii = l''(zᵢ, yᵢ)
    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        out.iter_mut().for_each(|g| *g = 0.0);
        for i in 0..self.x.n_rows() {
            let zi = self.x.row_dot(i, w);
            let dii = self.loss.second_deriv(zi, self.y[i]);
            if dii != 0.0 {
                let xv = self.x.row_dot(i, v);
                self.x.add_row_scaled(i, dii * xv, out);
            }
        }
        dense::axpy(self.lam, v, out);
    }
}

/// The paper's eq. (2): the gradient-consistent local approximation
///
/// f̂_p(w) = (λ/2)‖w‖² + L_p(w) + tilt·(w − wʳ),
/// tilt = gʳ − λwʳ − ∇L_p(wʳ)
///
/// so ∇f̂_p(wʳ) = gʳ exactly. Owns copies of wʳ/tilt (they change every
/// outer iteration), borrows the immutable shard.
pub struct LocalApprox<'a> {
    pub x: &'a Csr,
    pub y: &'a [f64],
    pub loss: LossKind,
    pub lam: f64,
    pub w_r: Vec<f64>,
    pub tilt: Vec<f64>,
}

impl<'a> LocalApprox<'a> {
    /// Build from the global iterate and gradient. `grad_lp_wr` is
    /// ∇L_p(wʳ) (the shard's loss-gradient at wʳ, no λ term).
    pub fn new(
        x: &'a Csr,
        y: &'a [f64],
        loss: LossKind,
        lam: f64,
        w_r: &[f64],
        g_r: &[f64],
        grad_lp_wr: &[f64],
    ) -> LocalApprox<'a> {
        let tilt: Vec<f64> = (0..w_r.len())
            .map(|j| g_r[j] - lam * w_r[j] - grad_lp_wr[j])
            .collect();
        Self::from_tilt(x, y, loss, lam, w_r, tilt)
    }

    /// Build from a precomputed tilt vector. The sparse pipeline
    /// computes tilts from index/value local gradients (see
    /// `algo::common::LocalGrads::tilt`); [`Self::new`] is the dense
    /// convenience wrapper over this.
    pub fn from_tilt(
        x: &'a Csr,
        y: &'a [f64],
        loss: LossKind,
        lam: f64,
        w_r: &[f64],
        tilt: Vec<f64>,
    ) -> LocalApprox<'a> {
        LocalApprox { x, y, loss, lam, w_r: w_r.to_vec(), tilt }
    }
}

impl<'a> Objective for LocalApprox<'a> {
    fn dim(&self) -> usize {
        self.x.n_cols
    }

    fn value(&self, w: &[f64]) -> f64 {
        let mut v = 0.5 * self.lam * dense::norm_sq(w);
        for i in 0..self.x.n_rows() {
            v += self.loss.value(self.x.row_dot(i, w), self.y[i]);
        }
        // tilt·(w − wʳ)
        v + dense::dot(&self.tilt, w) - dense::dot(&self.tilt, &self.w_r)
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        out.copy_from_slice(&self.tilt);
        shard_loss_grad(self.x, self.y, w, self.loss, out, None);
        dense::axpy(self.lam, w, out);
    }

    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        // the tilt is linear — same Hessian as the untilted local risk
        RegularizedLoss { x: self.x, y: self.y, loss: self.loss, lam: self.lam }
            .hess_vec(w, v, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::loss::ALL_LOSSES;
    use crate::util::rng::Rng;

    fn fd_grad(obj: &impl Objective, w: &[f64]) -> Vec<f64> {
        let eps = 1e-6;
        let mut g = vec![0.0; w.len()];
        let mut wp = w.to_vec();
        for j in 0..w.len() {
            wp[j] = w[j] + eps;
            let fp = obj.value(&wp);
            wp[j] = w[j] - eps;
            let fm = obj.value(&wp);
            wp[j] = w[j];
            g[j] = (fp - fm) / (2.0 * eps);
        }
        g
    }

    fn tiny_problem() -> (crate::data::dataset::Dataset, Vec<f64>) {
        let d = SynthConfig {
            n_examples: 40,
            n_features: 12,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(11);
        let mut rng = Rng::new(3);
        let w: Vec<f64> = (0..12).map(|_| rng.normal() * 0.3).collect();
        (d, w)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (d, w) = tiny_problem();
        for loss in ALL_LOSSES {
            let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam: 0.3 };
            let mut g = vec![0.0; 12];
            obj.grad(&w, &mut g);
            let fd = fd_grad(&obj, &w);
            assert!(
                dense::max_abs_diff(&g, &fd) < 1e-4,
                "{loss:?}: {g:?} vs {fd:?}"
            );
        }
    }

    #[test]
    fn value_grad_consistent_with_parts() {
        let (d, w) = tiny_problem();
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam: 0.1,
        };
        let mut g1 = vec![0.0; 12];
        let v1 = obj.value_grad(&w, &mut g1);
        let mut g2 = vec![0.0; 12];
        obj.grad(&w, &mut g2);
        assert!((v1 - obj.value(&w)).abs() < 1e-12);
        assert_eq!(g1, g2);
    }

    #[test]
    fn hess_vec_matches_gradient_difference() {
        let (d, w) = tiny_problem();
        for loss in [LossKind::Logistic, LossKind::LeastSquares] {
            let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam: 0.2 };
            let mut rng = Rng::new(5);
            let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
            let eps = 1e-6;
            let wp = dense::add_scaled(&w, eps, &v);
            let wm = dense::add_scaled(&w, -eps, &v);
            let mut gp = vec![0.0; 12];
            let mut gm = vec![0.0; 12];
            obj.grad(&wp, &mut gp);
            obj.grad(&wm, &mut gm);
            let fd: Vec<f64> = gp
                .iter()
                .zip(&gm)
                .map(|(a, b)| (a - b) / (2.0 * eps))
                .collect();
            let mut hv = vec![0.0; 12];
            obj.hess_vec(&w, &v, &mut hv);
            assert!(
                dense::max_abs_diff(&hv, &fd) < 1e-4,
                "{loss:?}: {hv:?} vs {fd:?}"
            );
        }
    }

    #[test]
    fn local_approx_gradient_consistency_at_wr() {
        // ∇f̂_p(wʳ) = gʳ for any shard and any claimed global gradient —
        // the identity the whole method rests on.
        let (d, w_r) = tiny_problem();
        let mut rng = Rng::new(8);
        let g_r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for loss in ALL_LOSSES {
            let lam = 0.15;
            let mut grad_lp = vec![0.0; 12];
            shard_loss_grad(&d.x, &d.y, &w_r, loss, &mut grad_lp, None);
            let approx =
                LocalApprox::new(&d.x, &d.y, loss, lam, &w_r, &g_r, &grad_lp);
            let mut g = vec![0.0; 12];
            approx.grad(&w_r, &mut g);
            assert!(
                dense::max_abs_diff(&g, &g_r) < 1e-10,
                "{loss:?}: consistency violated"
            );
        }
    }

    #[test]
    fn local_approx_value_grad_fd() {
        let (d, w_r) = tiny_problem();
        let mut rng = Rng::new(9);
        let g_r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..12).map(|_| rng.normal() * 0.5).collect();
        let mut grad_lp = vec![0.0; 12];
        shard_loss_grad(
            &d.x, &d.y, &w_r, LossKind::Logistic, &mut grad_lp, None,
        );
        let approx = LocalApprox::new(
            &d.x, &d.y, LossKind::Logistic, 0.15, &w_r, &g_r, &grad_lp,
        );
        let mut g = vec![0.0; 12];
        approx.grad(&w, &mut g);
        let fd = fd_grad(&approx, &w);
        assert!(dense::max_abs_diff(&g, &fd) < 1e-4);
    }

    #[test]
    fn sparse_shard_grad_matches_dense_exactly() {
        let (d, w) = tiny_problem();
        let map = crate::linalg::SupportMap::build(&d.x);
        for loss in ALL_LOSSES {
            let mut g_dense = vec![0.0; 12];
            let mut z_dense = Vec::new();
            let v_dense = shard_loss_grad(
                &d.x, &d.y, &w, loss, &mut g_dense, Some(&mut z_dense),
            );
            let mut z_sparse = Vec::new();
            let (v_sparse, g_sparse) = shard_loss_grad_sparse(
                &d.x, &d.y, &w, loss, &map, Some(&mut z_sparse),
            );
            assert_eq!(v_dense, v_sparse, "{loss:?}");
            assert_eq!(g_dense, g_sparse.to_dense(), "{loss:?}");
            assert_eq!(z_dense, z_sparse, "{loss:?}");
            // cached variant agrees given the same margins
            let (v_cached, g_cached) = shard_loss_grad_sparse_cached(
                &d.x, &d.y, &z_dense, loss, &map,
            );
            assert_eq!(v_dense, v_cached, "{loss:?}");
            assert_eq!(g_sparse, g_cached, "{loss:?}");
        }
    }

    #[test]
    fn margins_byproduct_correct() {
        let (d, w) = tiny_problem();
        let mut grad = vec![0.0; 12];
        let mut z = Vec::new();
        shard_loss_grad(
            &d.x, &d.y, &w, LossKind::Logistic, &mut grad, Some(&mut z),
        );
        for i in 0..d.n_examples() {
            assert!((z[i] - d.x.row_dot(i, &w)).abs() < 1e-14);
        }
    }
}
