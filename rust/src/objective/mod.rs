//! Objectives: the regularized risk f(w) and the paper's
//! gradient-consistent local approximation f̂_p (eq. 2).
//!
//! `f(w) = (λ/2)‖w‖² + Σ_i l(w·x_i, y_i)` — note the paper uses the
//! *sum* of losses, not the mean; λ is scaled accordingly by callers.

pub mod compact;

pub use compact::{CompactApprox, GlobalDots};

use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::linalg::{dense, Csr};
use crate::loss::LossKind;

/// A differentiable objective on R^d. Implemented by the full
/// regularized risk (single-machine view) and by the tilted local
/// approximation each node optimizes in Algorithm 1 step 5.
pub trait Objective {
    fn dim(&self) -> usize;
    fn value(&self, w: &[f64]) -> f64;
    /// out ← ∇f(w)
    fn grad(&self, w: &[f64], out: &mut [f64]);
    /// Fused value+gradient (one pass over the data).
    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        self.grad(w, out);
        self.value(w)
    }
    /// out ← ∇²f(w)·v — needed by TRON; optional elsewhere.
    fn hess_vec(&self, _w: &[f64], _v: &[f64], _out: &mut [f64]) {
        unimplemented!("Hessian-vector product not provided")
    }
}

/// The structural shape the stochastic inner solvers exploit:
/// f(w) = (λ/2)‖w‖² + Σᵢ l(xᵢ·w, yᵢ) + tilt·(w − const). Coordinates
/// `x().n_cols..dim()` (a compact tail, if any) carry only the
/// quadratic + linear terms — no data rows touch them, which is
/// exactly what the solvers' lazy dense-affine bookkeeping assumes.
/// Implemented by the full-space [`LocalApprox`] and the
/// support-compact [`CompactApprox`].
pub trait TiltedShard: Objective {
    fn shard_x(&self) -> &Csr;
    fn shard_y(&self) -> &[f64];
    fn loss_kind(&self) -> LossKind;
    fn l2(&self) -> f64;
    /// linear tilt coefficients, length == `dim()`
    fn tilt_coeffs(&self) -> &[f64];
}

/// Shard-level loss pass: returns Σ l_i and accumulates Xᵀ l' into
/// `grad` (which the caller zeroes); also exposes the margin by-product
/// z_i = w·x_i the paper reuses for its line search (step 1).
pub fn shard_loss_grad(
    x: &Csr,
    y: &[f64],
    w: &[f64],
    loss: LossKind,
    grad: &mut [f64],
    margins_out: Option<&mut Vec<f64>>,
) -> f64 {
    debug_assert_eq!(x.n_rows(), y.len());
    let mut val = 0.0;
    if let Some(z) = margins_out {
        z.resize(x.n_rows(), 0.0);
        for i in 0..x.n_rows() {
            let zi = x.row_dot(i, w);
            z[i] = zi;
            val += loss.value(zi, y[i]);
            let r = loss.deriv(zi, y[i]);
            if r != 0.0 {
                x.add_row_scaled(i, r, grad);
            }
        }
    } else {
        for i in 0..x.n_rows() {
            let zi = x.row_dot(i, w);
            val += loss.value(zi, y[i]);
            let r = loss.deriv(zi, y[i]);
            if r != 0.0 {
                x.add_row_scaled(i, r, grad);
            }
        }
    }
    val
}

/// Compact shard-level loss pass over a *local-column* CSR: the
/// gradient is accumulated into the support-aligned `vals` buffer
/// (resized to `xl.n_cols`, O(|support|) memory instead of O(d)).
/// `w_c` is the support-gathered iterate (`w_c.len() ≥ xl.n_cols`; a
/// longer compact-tail vector is fine — the rows never index past the
/// support). The λ term is NOT included — the master applies it lazily
/// after the merge, which is exact because λw is common to every node.
///
/// Accumulation visits rows (and entries within a row) in the same
/// order as the global dense pass, so the two agree
/// coordinate-for-coordinate, not just to rounding tolerance.
pub fn shard_loss_grad_compact(
    xl: &Csr,
    y: &[f64],
    w_c: &[f64],
    loss: LossKind,
    vals: &mut Vec<f64>,
    margins_out: Option<&mut Vec<f64>>,
) -> f64 {
    debug_assert_eq!(xl.n_rows(), y.len());
    match margins_out {
        Some(z) => {
            z.resize(xl.n_rows(), 0.0);
            compact_loss_pass(xl, y, loss, vals, |i| {
                let zi = xl.row_dot(i, w_c);
                z[i] = zi;
                zi
            })
        }
        None => compact_loss_pass(xl, y, loss, vals, |i| xl.row_dot(i, w_c)),
    }
}

/// Cached-margin variant of [`shard_loss_grad_compact`] (FS keeps
/// zᵢ = w·xᵢ node-local across outer iterations): one data pass, no
/// X·w matvec, and no need for the gathered iterate at all.
pub fn shard_loss_grad_compact_cached(
    xl: &Csr,
    y: &[f64],
    z: &[f64],
    loss: LossKind,
    vals: &mut Vec<f64>,
) -> f64 {
    debug_assert_eq!(xl.n_rows(), z.len());
    compact_loss_pass(xl, y, loss, vals, |i| z[i])
}

/// [`shard_loss_grad_compact`] packaged for the wire: returns the
/// support-aligned gradient as a global-index [`SparseVec`] (every
/// support coordinate carried, zeros included, so `val` stays aligned
/// with the shard support at the receiver).
pub fn shard_loss_grad_sparse(
    xl: &Csr,
    y: &[f64],
    w_c: &[f64],
    loss: LossKind,
    map: &SupportMap,
    dim: usize,
    margins_out: Option<&mut Vec<f64>>,
) -> (f64, SparseVec) {
    let mut vals = Vec::new();
    let v = shard_loss_grad_compact(xl, y, w_c, loss, &mut vals, margins_out);
    (v, map.to_sparse_aligned(dim, &vals))
}

/// Cached-margin variant of [`shard_loss_grad_sparse`].
pub fn shard_loss_grad_sparse_cached(
    xl: &Csr,
    y: &[f64],
    z: &[f64],
    loss: LossKind,
    map: &SupportMap,
    dim: usize,
) -> (f64, SparseVec) {
    let mut vals = Vec::new();
    let v = shard_loss_grad_compact_cached(xl, y, z, loss, &mut vals);
    (v, map.to_sparse_aligned(dim, &vals))
}

/// The shared compact loss sweep: rows in order, margin supplied by the
/// caller (computed, computed-and-recorded, or cached), gradient
/// accumulated over the local columns.
fn compact_loss_pass(
    xl: &Csr,
    y: &[f64],
    loss: LossKind,
    vals: &mut Vec<f64>,
    mut margin_of: impl FnMut(usize) -> f64,
) -> f64 {
    vals.clear();
    vals.resize(xl.n_cols, 0.0);
    let mut val = 0.0;
    for i in 0..xl.n_rows() {
        let zi = margin_of(i);
        val += loss.value(zi, y[i]);
        let r = loss.deriv(zi, y[i]);
        if r != 0.0 {
            xl.add_row_scaled(i, r, vals);
        }
    }
    val
}

/// Shared tilted-objective kernels — ONE implementation of the
/// value/gradient/Hessian-vector math of
/// f(w) = (λ/2)‖w‖² + Σᵢ l(xᵢ·w, yᵢ) + tilt·(w − wʳ), used by both the
/// full-space [`LocalApprox`] and the support-compact
/// [`CompactApprox`] so the two views can never drift apart.
pub(crate) fn tilted_value(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    tilt: &[f64],
    w_r: &[f64],
    w: &[f64],
) -> f64 {
    let mut v = 0.5 * lam * dense::norm_sq(w);
    for i in 0..x.n_rows() {
        v += loss.value(x.row_dot(i, w), y[i]);
    }
    v + dense::dot(tilt, w) - dense::dot(tilt, w_r)
}

pub(crate) fn tilted_grad(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    tilt: &[f64],
    w: &[f64],
    out: &mut [f64],
) {
    out.copy_from_slice(tilt);
    shard_loss_grad(x, y, w, loss, out, None);
    dense::axpy(lam, w, out);
}

/// H·v = λv + Xᵀ D X v, D_ii = l''(zᵢ, yᵢ) — the tilt is linear, so
/// tilted and untilted objectives share this Hessian.
pub(crate) fn regularized_hess_vec(
    x: &Csr,
    y: &[f64],
    loss: LossKind,
    lam: f64,
    w: &[f64],
    v: &[f64],
    out: &mut [f64],
) {
    out.iter_mut().for_each(|g| *g = 0.0);
    for i in 0..x.n_rows() {
        let zi = x.row_dot(i, w);
        let dii = loss.second_deriv(zi, y[i]);
        if dii != 0.0 {
            let xv = x.row_dot(i, v);
            x.add_row_scaled(i, dii * xv, out);
        }
    }
    dense::axpy(lam, v, out);
}

/// The full regularized risk over one dataset (single-machine view and
/// per-test oracle): f(w) = (λ/2)‖w‖² + Σ l(w·xᵢ, yᵢ).
pub struct RegularizedLoss<'a> {
    pub x: &'a Csr,
    pub y: &'a [f64],
    pub loss: LossKind,
    pub lam: f64,
}

impl<'a> Objective for RegularizedLoss<'a> {
    fn dim(&self) -> usize {
        self.x.n_cols
    }

    fn value(&self, w: &[f64]) -> f64 {
        let mut v = 0.5 * self.lam * dense::norm_sq(w);
        for i in 0..self.x.n_rows() {
            v += self.loss.value(self.x.row_dot(i, w), self.y[i]);
        }
        v
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        out.iter_mut().for_each(|g| *g = 0.0);
        shard_loss_grad(self.x, self.y, w, self.loss, out, None);
        dense::axpy(self.lam, w, out);
    }

    fn value_grad(&self, w: &[f64], out: &mut [f64]) -> f64 {
        out.iter_mut().for_each(|g| *g = 0.0);
        let lv = shard_loss_grad(self.x, self.y, w, self.loss, out, None);
        dense::axpy(self.lam, w, out);
        lv + 0.5 * self.lam * dense::norm_sq(w)
    }

    /// H·v = λv + Xᵀ D X v, D_ii = l''(zᵢ, yᵢ)
    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        regularized_hess_vec(self.x, self.y, self.loss, self.lam, w, v, out);
    }
}

/// The paper's eq. (2): the gradient-consistent local approximation
///
/// f̂_p(w) = (λ/2)‖w‖² + L_p(w) + tilt·(w − wʳ),
/// tilt = gʳ − λwʳ − ∇L_p(wʳ)
///
/// so ∇f̂_p(wʳ) = gʳ exactly. Owns copies of wʳ/tilt (they change every
/// outer iteration), borrows the immutable shard.
pub struct LocalApprox<'a> {
    pub x: &'a Csr,
    pub y: &'a [f64],
    pub loss: LossKind,
    pub lam: f64,
    pub w_r: Vec<f64>,
    pub tilt: Vec<f64>,
}

impl<'a> LocalApprox<'a> {
    /// Build from the global iterate and gradient. `grad_lp_wr` is
    /// ∇L_p(wʳ) (the shard's loss-gradient at wʳ, no λ term).
    pub fn new(
        x: &'a Csr,
        y: &'a [f64],
        loss: LossKind,
        lam: f64,
        w_r: &[f64],
        g_r: &[f64],
        grad_lp_wr: &[f64],
    ) -> LocalApprox<'a> {
        let tilt: Vec<f64> = (0..w_r.len())
            .map(|j| g_r[j] - lam * w_r[j] - grad_lp_wr[j])
            .collect();
        Self::from_tilt(x, y, loss, lam, w_r, tilt)
    }

    /// Build from a precomputed tilt vector. The sparse pipeline
    /// computes tilts from index/value local gradients (see
    /// `algo::common::LocalGrads::tilt`); [`Self::new`] is the dense
    /// convenience wrapper over this.
    pub fn from_tilt(
        x: &'a Csr,
        y: &'a [f64],
        loss: LossKind,
        lam: f64,
        w_r: &[f64],
        tilt: Vec<f64>,
    ) -> LocalApprox<'a> {
        LocalApprox { x, y, loss, lam, w_r: w_r.to_vec(), tilt }
    }
}

impl<'a> Objective for LocalApprox<'a> {
    fn dim(&self) -> usize {
        self.x.n_cols
    }

    fn value(&self, w: &[f64]) -> f64 {
        tilted_value(
            self.x, self.y, self.loss, self.lam, &self.tilt, &self.w_r, w,
        )
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        tilted_grad(self.x, self.y, self.loss, self.lam, &self.tilt, w, out);
    }

    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        // the tilt is linear — same Hessian as the untilted local risk
        regularized_hess_vec(self.x, self.y, self.loss, self.lam, w, v, out);
    }
}

impl<'a> TiltedShard for LocalApprox<'a> {
    fn shard_x(&self) -> &Csr {
        self.x
    }
    fn shard_y(&self) -> &[f64] {
        self.y
    }
    fn loss_kind(&self) -> LossKind {
        self.loss
    }
    fn l2(&self) -> f64 {
        self.lam
    }
    fn tilt_coeffs(&self) -> &[f64] {
        &self.tilt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::loss::ALL_LOSSES;
    use crate::util::rng::Rng;

    fn fd_grad(obj: &impl Objective, w: &[f64]) -> Vec<f64> {
        let eps = 1e-6;
        let mut g = vec![0.0; w.len()];
        let mut wp = w.to_vec();
        for j in 0..w.len() {
            wp[j] = w[j] + eps;
            let fp = obj.value(&wp);
            wp[j] = w[j] - eps;
            let fm = obj.value(&wp);
            wp[j] = w[j];
            g[j] = (fp - fm) / (2.0 * eps);
        }
        g
    }

    fn tiny_problem() -> (crate::data::dataset::Dataset, Vec<f64>) {
        let d = SynthConfig {
            n_examples: 40,
            n_features: 12,
            nnz_per_example: 4,
            ..SynthConfig::default()
        }
        .generate(11);
        let mut rng = Rng::new(3);
        let w: Vec<f64> = (0..12).map(|_| rng.normal() * 0.3).collect();
        (d, w)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (d, w) = tiny_problem();
        for loss in ALL_LOSSES {
            let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam: 0.3 };
            let mut g = vec![0.0; 12];
            obj.grad(&w, &mut g);
            let fd = fd_grad(&obj, &w);
            assert!(
                dense::max_abs_diff(&g, &fd) < 1e-4,
                "{loss:?}: {g:?} vs {fd:?}"
            );
        }
    }

    #[test]
    fn value_grad_consistent_with_parts() {
        let (d, w) = tiny_problem();
        let obj = RegularizedLoss {
            x: &d.x,
            y: &d.y,
            loss: LossKind::Logistic,
            lam: 0.1,
        };
        let mut g1 = vec![0.0; 12];
        let v1 = obj.value_grad(&w, &mut g1);
        let mut g2 = vec![0.0; 12];
        obj.grad(&w, &mut g2);
        assert!((v1 - obj.value(&w)).abs() < 1e-12);
        assert_eq!(g1, g2);
    }

    #[test]
    fn hess_vec_matches_gradient_difference() {
        let (d, w) = tiny_problem();
        for loss in [LossKind::Logistic, LossKind::LeastSquares] {
            let obj = RegularizedLoss { x: &d.x, y: &d.y, loss, lam: 0.2 };
            let mut rng = Rng::new(5);
            let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
            let eps = 1e-6;
            let wp = dense::add_scaled(&w, eps, &v);
            let wm = dense::add_scaled(&w, -eps, &v);
            let mut gp = vec![0.0; 12];
            let mut gm = vec![0.0; 12];
            obj.grad(&wp, &mut gp);
            obj.grad(&wm, &mut gm);
            let fd: Vec<f64> = gp
                .iter()
                .zip(&gm)
                .map(|(a, b)| (a - b) / (2.0 * eps))
                .collect();
            let mut hv = vec![0.0; 12];
            obj.hess_vec(&w, &v, &mut hv);
            assert!(
                dense::max_abs_diff(&hv, &fd) < 1e-4,
                "{loss:?}: {hv:?} vs {fd:?}"
            );
        }
    }

    #[test]
    fn local_approx_gradient_consistency_at_wr() {
        // ∇f̂_p(wʳ) = gʳ for any shard and any claimed global gradient —
        // the identity the whole method rests on.
        let (d, w_r) = tiny_problem();
        let mut rng = Rng::new(8);
        let g_r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for loss in ALL_LOSSES {
            let lam = 0.15;
            let mut grad_lp = vec![0.0; 12];
            shard_loss_grad(&d.x, &d.y, &w_r, loss, &mut grad_lp, None);
            let approx =
                LocalApprox::new(&d.x, &d.y, loss, lam, &w_r, &g_r, &grad_lp);
            let mut g = vec![0.0; 12];
            approx.grad(&w_r, &mut g);
            assert!(
                dense::max_abs_diff(&g, &g_r) < 1e-10,
                "{loss:?}: consistency violated"
            );
        }
    }

    #[test]
    fn local_approx_value_grad_fd() {
        let (d, w_r) = tiny_problem();
        let mut rng = Rng::new(9);
        let g_r: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..12).map(|_| rng.normal() * 0.5).collect();
        let mut grad_lp = vec![0.0; 12];
        shard_loss_grad(
            &d.x, &d.y, &w_r, LossKind::Logistic, &mut grad_lp, None,
        );
        let approx = LocalApprox::new(
            &d.x, &d.y, LossKind::Logistic, 0.15, &w_r, &g_r, &grad_lp,
        );
        let mut g = vec![0.0; 12];
        approx.grad(&w, &mut g);
        let fd = fd_grad(&approx, &w);
        assert!(dense::max_abs_diff(&g, &fd) < 1e-4);
    }

    #[test]
    fn sparse_shard_grad_matches_dense_exactly() {
        let (d, w) = tiny_problem();
        let (map, xl) = crate::linalg::SupportMap::compact(&d.x);
        let mut w_c = Vec::new();
        map.gather(&w, &mut w_c);
        for loss in ALL_LOSSES {
            let mut g_dense = vec![0.0; 12];
            let mut z_dense = Vec::new();
            let v_dense = shard_loss_grad(
                &d.x, &d.y, &w, loss, &mut g_dense, Some(&mut z_dense),
            );
            let mut z_sparse = Vec::new();
            let (v_sparse, g_sparse) = shard_loss_grad_sparse(
                &xl, &d.y, &w_c, loss, &map, 12, Some(&mut z_sparse),
            );
            assert_eq!(v_dense, v_sparse, "{loss:?}");
            assert_eq!(g_dense, g_sparse.to_dense(), "{loss:?}");
            assert_eq!(z_dense, z_sparse, "{loss:?}");
            // cached variant agrees given the same margins
            let (v_cached, g_cached) = shard_loss_grad_sparse_cached(
                &xl, &d.y, &z_dense, loss, &map, 12,
            );
            assert_eq!(v_dense, v_cached, "{loss:?}");
            assert_eq!(g_sparse, g_cached, "{loss:?}");
        }
    }

    #[test]
    fn margins_byproduct_correct() {
        let (d, w) = tiny_problem();
        let mut grad = vec![0.0; 12];
        let mut z = Vec::new();
        shard_loss_grad(
            &d.x, &d.y, &w, LossKind::Logistic, &mut grad, Some(&mut z),
        );
        for i in 0..d.n_examples() {
            assert!((z[i] - d.x.row_dot(i, &w)).abs() < 1e-14);
        }
    }
}
