//! Support-compact view of the paper's tilted local approximation
//! f̂_p (eq. 2), plus the hybrid direction representation the FS driver
//! aggregates.
//!
//! A node's loss only touches its shard's support columns S_p, but the
//! tilt gʳ − λwʳ − ∇L_p(wʳ) moves *every* coordinate, so a naive
//! support restriction would change the solve. The observation that
//! makes compact solves exact: off the support, f̂_p is a separable
//! quadratic whose entire trajectory (for any of our inner solvers)
//! stays inside span{wʳ_off, tilt_off}. [`CompactApprox`] therefore
//! optimizes over m = |S_p| support coordinates plus at most **two
//! tail coordinates** expressed in an *orthonormal* basis of that
//! span — Euclidean dots in the compact space equal full-space dots, so
//! SVRG, SAG, L-BFGS and TRON run unmodified and reproduce the
//! full-space solve to rounding error with O(|S_p|) working set.
//!
//! The basis is built from three scalars (‖wʳ‖²_off, wʳ_off·tilt_off,
//! ‖tilt_off‖²_off) obtained by subtracting support-local dots from the
//! master's global dots — zero O(d) work per node.
//!
//! The solve result converts to a [`HybridDir`]
//! d_p = a_w·wʳ + a_g·gʳ + corr (corr supported on S_p): nodes already
//! hold wʳ and gʳ after the gradient allreduce, so the direction
//! allreduce ships only |S_p|-sized corrections plus two scalars.

use crate::linalg::sparse::{SparseVec, SupportMap};
use crate::linalg::{dense, Csr};
use crate::loss::LossKind;
use crate::objective::{
    regularized_hess_vec, tilted_grad, tilted_value, Objective, TiltedShard,
};

/// Master-side dot products shared by every node's tail construction,
/// computed once per outer iteration (O(d) at the master only).
#[derive(Clone, Copy, Debug, Default)]
pub struct GlobalDots {
    pub ww: f64,
    pub wg: f64,
    pub gg: f64,
}

impl GlobalDots {
    pub fn compute(w: &[f64], g: &[f64]) -> GlobalDots {
        GlobalDots {
            ww: dense::norm_sq(w),
            wg: dense::dot(w, g),
            gg: dense::norm_sq(g),
        }
    }
}

/// Relative threshold below which an off-support basis vector carries
/// no recoverable mass (its squared norm is cancellation noise) and is
/// dropped from the tail.
const TAIL_REL_TOL: f64 = 1e-24;

/// Orthonormalized basis of span{wʳ_off, tilt_off} — `k ≤ 2` tail
/// coordinates appended to the m support coordinates.
#[derive(Clone, Copy, Debug, Default)]
pub struct OffSupportTail {
    /// number of tail coordinates (0, 1 or 2)
    pub k: usize,
    /// wʳ_off in the q-basis
    pub wr: [f64; 2],
    /// tilt_off in the q-basis
    pub tilt: [f64; 2],
    /// q1 points along wʳ_off when true, along tilt_off when false
    pub on_w: bool,
    /// ‖wʳ_off‖ (q1 scale when `on_w`)
    pub nu: f64,
    /// tilt_off·q1 (when `on_w`)
    pub c: f64,
    /// ‖tilt_off − c·q1‖ when `on_w` (q2 scale); ‖tilt_off‖ otherwise
    pub rv: f64,
}

impl OffSupportTail {
    fn build(
        lam: f64,
        dots: &GlobalDots,
        wr_c: &[f64],
        g_c: &[f64],
    ) -> OffSupportTail {
        // off-support dots by subtraction (clamped: cancellation can
        // push a true zero slightly negative)
        let suu = (dots.ww - dense::norm_sq(wr_c)).max(0.0);
        let sug = dots.wg - dense::dot(wr_c, g_c);
        let sgg = (dots.gg - dense::norm_sq(g_c)).max(0.0);
        // u = wʳ_off, v = tilt_off = (gʳ − λwʳ)_off
        let suv = sug - lam * suu;
        let svv = (sgg - 2.0 * lam * sug + lam * lam * suu).max(0.0);
        let mut t = OffSupportTail::default();
        if suu > TAIL_REL_TOL * (dots.ww + f64::MIN_POSITIVE) {
            t.on_w = true;
            t.nu = suu.sqrt();
            // Cauchy–Schwarz clamp |v·q1| ≤ ‖v‖: keeps a noise-level nu
            // from amplifying suv into a phantom tilt
            let vmax = svv.sqrt();
            t.c = (suv / t.nu).clamp(-vmax, vmax);
            let r2 = (svv - t.c * t.c).max(0.0);
            t.wr = [t.nu, 0.0];
            if r2 > TAIL_REL_TOL * (svv + f64::MIN_POSITIVE) {
                t.k = 2;
                t.rv = r2.sqrt();
                t.tilt = [t.c, t.rv];
            } else {
                t.k = 1;
                t.tilt = [t.c, 0.0];
            }
        } else {
            let vscale = dots.gg + lam * lam * dots.ww;
            if svv > TAIL_REL_TOL * (vscale + f64::MIN_POSITIVE) {
                t.k = 1;
                t.on_w = false;
                t.rv = svv.sqrt();
                t.tilt = [t.rv, 0.0];
            }
        }
        t
    }

    /// Tail-coordinate deltas → coefficients on (wʳ_off, tilt_off):
    /// d_off = a_u·wʳ_off + a_v·tilt_off.
    fn delta_coeffs(&self, d0: f64, d1: f64) -> (f64, f64) {
        match (self.k, self.on_w) {
            (0, _) => (0.0, 0.0),
            (1, true) => (d0 / self.nu, 0.0),
            (1, false) => (0.0, d0 / self.rv),
            _ => (
                d0 / self.nu - d1 * self.c / (self.nu * self.rv),
                d1 / self.rv,
            ),
        }
    }
}

/// f̂_p in compact coordinates: m support values followed by the k tail
/// coordinates. Implements [`Objective`] (dimension m + k), so every
/// optimizer in `opt` runs on it unchanged; the tail coordinates carry
/// only the quadratic + linear terms (no data row touches them).
pub struct CompactApprox<'a> {
    /// shard matrix with local column ids 0..m
    pub x: &'a Csr,
    pub y: &'a [f64],
    pub loss: LossKind,
    pub lam: f64,
    /// support coordinate count (x.n_cols)
    pub m: usize,
    /// start point wʳ in compact coordinates (length m + k)
    pub w_r: Vec<f64>,
    /// tilt in compact coordinates (length m + k)
    pub tilt: Vec<f64>,
    pub tail: OffSupportTail,
}

impl<'a> CompactApprox<'a> {
    /// Build node p's compact view of f̂_p at (wʳ, gʳ). `wr_c` and
    /// `g_c` are the support gathers of wʳ and gʳ, `grad_lp` the
    /// support-aligned ∇L_p(wʳ), `dots` the master's shared global dot
    /// products. All inputs are O(m); nothing here touches d.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        xl: &'a Csr,
        y: &'a [f64],
        loss: LossKind,
        lam: f64,
        dots: &GlobalDots,
        wr_c: &[f64],
        g_c: &[f64],
        grad_lp: &[f64],
    ) -> CompactApprox<'a> {
        let m = xl.n_cols;
        debug_assert_eq!(wr_c.len(), m);
        debug_assert_eq!(g_c.len(), m);
        debug_assert_eq!(grad_lp.len(), m);
        let tail = OffSupportTail::build(lam, dots, wr_c, g_c);
        let k = tail.k;
        let mut w_r = Vec::with_capacity(m + k);
        w_r.extend_from_slice(wr_c);
        w_r.extend_from_slice(&tail.wr[..k]);
        let mut tilt = Vec::with_capacity(m + k);
        for l in 0..m {
            tilt.push(g_c[l] - lam * wr_c[l] - grad_lp[l]);
        }
        tilt.extend_from_slice(&tail.tilt[..k]);
        CompactApprox { x: xl, y, loss, lam, m, w_r, tilt, tail }
    }

    /// Off-support part of a solve result as (a_w, a_g) coefficients on
    /// the global (wʳ, gʳ): d_off = a_w·wʳ_off + a_g·gʳ_off.
    pub fn off_support_coeffs(&self, w_p: &[f64]) -> (f64, f64) {
        let k = self.tail.k;
        let d0 = if k >= 1 { w_p[self.m] - self.w_r[self.m] } else { 0.0 };
        let d1 = if k >= 2 {
            w_p[self.m + 1] - self.w_r[self.m + 1]
        } else {
            0.0
        };
        let (a_u, a_v) = self.tail.delta_coeffs(d0, d1);
        // tilt_off = (gʳ − λwʳ)_off folds v's coefficient into both
        (a_u - self.lam * a_v, a_v)
    }
}

impl<'a> Objective for CompactApprox<'a> {
    fn dim(&self) -> usize {
        self.m + self.tail.k
    }

    // the exact same tilted kernels as the full-space LocalApprox —
    // compact vs full differ only in the coordinate space, never in
    // the math (tests/compact.rs holds the two to ε)

    fn value(&self, w: &[f64]) -> f64 {
        tilted_value(
            self.x, self.y, self.loss, self.lam, &self.tilt, &self.w_r, w,
        )
    }

    fn grad(&self, w: &[f64], out: &mut [f64]) {
        tilted_grad(self.x, self.y, self.loss, self.lam, &self.tilt, w, out);
    }

    fn hess_vec(&self, w: &[f64], v: &[f64], out: &mut [f64]) {
        regularized_hess_vec(self.x, self.y, self.loss, self.lam, w, v, out);
    }
}

impl<'a> TiltedShard for CompactApprox<'a> {
    fn shard_x(&self) -> &Csr {
        self.x
    }
    fn shard_y(&self) -> &[f64] {
        self.y
    }
    fn loss_kind(&self) -> LossKind {
        self.loss
    }
    fn l2(&self) -> f64 {
        self.lam
    }
    fn tilt_coeffs(&self) -> &[f64] {
        &self.tilt
    }
}

/// A node's local-solve outcome in hybrid affine + sparse form:
/// d_p = a_w·wʳ + a_g·gʳ + corr, with corr supported on the shard's
/// columns. Every node holds wʳ and gʳ after the gradient allreduce, so
/// the direction round's wire payload is corr plus two scalars — the
/// step-7 combination happens on coefficients and a sparse reduce.
#[derive(Clone, Debug)]
pub struct HybridDir {
    pub a_w: f64,
    pub a_g: f64,
    pub corr: SparseVec,
}

impl HybridDir {
    /// The safeguard's replacement direction −gʳ.
    pub fn neg_gradient(dim: usize) -> HybridDir {
        HybridDir { a_w: 0.0, a_g: -1.0, corr: SparseVec::new(dim) }
    }

    /// Package a compact solve result (support deviations minus the
    /// affine part; tail deltas already folded into the coefficients).
    pub fn from_compact(
        map: &SupportMap,
        dim: usize,
        a_w: f64,
        a_g: f64,
        w_p: &[f64],
        wr_c: &[f64],
        g_c: &[f64],
    ) -> HybridDir {
        Self::from_compact_idx(&map.support, dim, a_w, a_g, w_p, wr_c, g_c)
    }

    /// [`Self::from_compact`] over an explicit support dictionary —
    /// the corr indices are whatever master frame the driver runs in:
    /// global columns over dim d (dense master, `map.support`) or
    /// union-support positions over dim |U| (compact master,
    /// `Shard::upos`). The two encodings are related by a monotone
    /// index bijection, so every downstream dot/merge sums in the same
    /// order and the frames stay ε-identical.
    pub fn from_compact_idx(
        idx: &[u32],
        dim: usize,
        a_w: f64,
        a_g: f64,
        w_p: &[f64],
        wr_c: &[f64],
        g_c: &[f64],
    ) -> HybridDir {
        let m = idx.len();
        debug_assert!(w_p.len() >= m && wr_c.len() >= m && g_c.len() >= m);
        let vals: Vec<f64> = (0..m)
            .map(|l| (w_p[l] - wr_c[l]) - a_w * wr_c[l] - a_g * g_c[l])
            .collect();
        HybridDir {
            a_w,
            a_g,
            corr: SparseVec::from_support(dim, idx, &vals),
        }
    }

    /// d_p·gʳ from the shared scalars plus one O(nnz) sparse dot.
    pub fn dot_g(&self, dots: &GlobalDots, g: &[f64]) -> f64 {
        self.a_w * dots.wg + self.a_g * dots.gg + self.corr.dot_dense(g)
    }

    /// ‖d_p‖² from the shared scalars plus O(nnz) sparse dots.
    pub fn norm_sq(&self, dots: &GlobalDots, w: &[f64], g: &[f64]) -> f64 {
        let affine = self.a_w * self.a_w * dots.ww
            + self.a_g * self.a_g * dots.gg
            + 2.0 * self.a_w * self.a_g * dots.wg;
        let cross = 2.0
            * (self.a_w * self.corr.dot_dense(w)
                + self.a_g * self.corr.dot_dense(g));
        (affine + cross + self.corr.norm_sq()).max(0.0)
    }

    /// Materialize the full-space direction (tests, dense wire path).
    pub fn to_dense(&self, w: &[f64], g: &[f64]) -> Vec<f64> {
        let mut d: Vec<f64> = w
            .iter()
            .zip(g)
            .map(|(wj, gj)| self.a_w * wj + self.a_g * gj)
            .collect();
        self.corr.axpy_into(1.0, &mut d);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;
    use crate::objective::{shard_loss_grad, LocalApprox};
    use crate::util::rng::Rng;

    /// Build matched full-space and compact views of the same f̂_p.
    fn matched_views(
        seed: u64,
        lam: f64,
    ) -> (crate::data::dataset::Dataset, Vec<f64>, Vec<f64>, Vec<f64>) {
        let d = SynthConfig {
            n_examples: 50,
            n_features: 40,
            nnz_per_example: 5,
            ..SynthConfig::default()
        }
        .generate(seed);
        let dim = d.n_features();
        let mut rng = Rng::new(seed ^ 0xABCD);
        let w_r: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
        let g_r: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut grad_lp = vec![0.0; dim];
        shard_loss_grad(
            &d.x, &d.y, &w_r, LossKind::Logistic, &mut grad_lp, None,
        );
        let _ = lam;
        (d, w_r, g_r, grad_lp)
    }

    #[test]
    fn compact_gradient_matches_full_space_at_wr() {
        // ∇f̂_p(wʳ) = gʳ in both views: the compact gradient at the
        // compact start must have exactly ‖gʳ‖ mass, split between the
        // support gather of gʳ and the tail coordinates of gʳ_off.
        for seed in [1u64, 2, 3] {
            let lam = 0.4;
            let (d, w_r, g_r, grad_lp) = matched_views(seed, lam);
            let (map, xl) = SupportMap::compact(&d.x);
            let mut wr_c = Vec::new();
            let mut g_c = Vec::new();
            map.gather(&w_r, &mut wr_c);
            map.gather(&g_r, &mut g_c);
            let mut glp_c = Vec::new();
            map.gather(&grad_lp, &mut glp_c);
            let dots = GlobalDots::compute(&w_r, &g_r);
            let ca = CompactApprox::build(
                &xl, &d.y, LossKind::Logistic, lam, &dots, &wr_c, &g_c,
                &glp_c,
            );
            let mut gc = vec![0.0; ca.dim()];
            ca.grad(&ca.w_r.clone(), &mut gc);
            // support part equals the gathered gʳ
            for l in 0..ca.m {
                assert!(
                    (gc[l] - g_c[l]).abs() < 1e-10,
                    "seed {seed} support coord {l}"
                );
            }
            // total mass equals ‖gʳ‖²
            let full = dense::norm_sq(&g_r);
            let got = dense::norm_sq(&gc);
            assert!(
                (full - got).abs() < 1e-8 * (1.0 + full),
                "seed {seed}: ‖g‖² {full} vs compact {got}"
            );
        }
    }

    #[test]
    fn compact_value_matches_full_space_along_tilt_moves() {
        // move the tail coordinates and check the value agrees with the
        // corresponding full-space move via the hybrid reconstruction
        let lam = 0.7;
        let (d, w_r, g_r, grad_lp) = matched_views(7, lam);
        let full = LocalApprox::new(
            &d.x, &d.y, LossKind::Logistic, lam, &w_r, &g_r, &grad_lp,
        );
        let (map, xl) = SupportMap::compact(&d.x);
        let (mut wr_c, mut g_c, mut glp_c) =
            (Vec::new(), Vec::new(), Vec::new());
        map.gather(&w_r, &mut wr_c);
        map.gather(&g_r, &mut g_c);
        map.gather(&grad_lp, &mut glp_c);
        let dots = GlobalDots::compute(&w_r, &g_r);
        let ca = CompactApprox::build(
            &xl, &d.y, LossKind::Logistic, lam, &dots, &wr_c, &g_c, &glp_c,
        );
        // a deterministic compact move: shift every coordinate
        let mut wp = ca.w_r.clone();
        for (j, v) in wp.iter_mut().enumerate() {
            *v += 0.01 * ((j % 5) as f64 - 2.0);
        }
        let (a_w, a_g) = ca.off_support_coeffs(&wp);
        let hd = HybridDir::from_compact(
            &map, d.n_features(), a_w, a_g, &wp, &wr_c, &g_c,
        );
        let w_full = {
            let mut w = w_r.clone();
            dense::axpy(1.0, &hd.to_dense(&w_r, &g_r), &mut w);
            w
        };
        let v_full = full.value(&w_full);
        let v_compact = ca.value(&wp);
        assert!(
            (v_full - v_compact).abs() < 1e-7 * (1.0 + v_full.abs()),
            "{v_full} vs {v_compact}"
        );
        // hybrid scalar algebra matches the dense reconstruction
        let dd = hd.to_dense(&w_r, &g_r);
        assert!(
            (hd.dot_g(&dots, &g_r) - dense::dot(&dd, &g_r)).abs()
                < 1e-9 * (1.0 + dense::norm(&dd) * dense::norm(&g_r)),
        );
        assert!(
            (hd.norm_sq(&dots, &w_r, &g_r) - dense::norm_sq(&dd)).abs()
                < 1e-9 * (1.0 + dense::norm_sq(&dd)),
        );
    }

    #[test]
    fn zero_start_has_tilt_only_tail() {
        // first outer iteration: w = 0 ⇒ the tail is 1-dimensional
        let (d, _, g_r, grad_lp) = matched_views(11, 0.3);
        let w0 = vec![0.0; d.n_features()];
        let (map, xl) = SupportMap::compact(&d.x);
        let (mut wr_c, mut g_c, mut glp_c) =
            (Vec::new(), Vec::new(), Vec::new());
        map.gather(&w0, &mut wr_c);
        map.gather(&g_r, &mut g_c);
        map.gather(&grad_lp, &mut glp_c);
        let dots = GlobalDots::compute(&w0, &g_r);
        let ca = CompactApprox::build(
            &xl, &d.y, LossKind::Logistic, 0.3, &dots, &wr_c, &g_c, &glp_c,
        );
        assert!(ca.tail.k <= 1, "tail k = {}", ca.tail.k);
        assert!(!ca.tail.on_w || ca.tail.k == 0);
    }
}
