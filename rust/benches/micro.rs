//! Microbenchmarks over the L3 hot-path primitives (in-tree harness,
//! DESIGN.md §3): sparse matvec / transposed matvec, the lazy SVRG
//! epoch vs its dense reference, tree reduction, AUPRC, and the dense
//! vector kernels. These are the §Perf baseline numbers.

use psgd::bench::{run, BenchConfig};
use psgd::cluster::allreduce::tree_sum;
use psgd::data::synth::SynthConfig;
use psgd::linalg::dense;
use psgd::loss::LossKind;
use psgd::metrics::auprc::auprc;
use psgd::objective::{shard_loss_grad, LocalApprox};
use psgd::opt::svrg::{svrg_epochs, svrg_epochs_dense, SvrgParams};
use psgd::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::default();
    let mut results = Vec::new();

    // --- data: one "node shard" at repro scale ---
    let shard = SynthConfig {
        n_examples: 8_000,
        n_features: 100_000,
        nnz_per_example: 35,
        ..SynthConfig::default()
    }
    .generate(1);
    let d = shard.n_features();
    let mut rng = Rng::new(2);
    let w: Vec<f64> = (0..d).map(|_| rng.normal() * 0.01).collect();
    let r: Vec<f64> = (0..shard.n_examples()).map(|_| rng.normal()).collect();

    let mut z = vec![0.0; shard.n_examples()];
    results.push(run("csr_matvec 8k x 100k (280k nnz)", &cfg, || {
        shard.x.matvec(&w, &mut z);
        z[0]
    }));
    let mut g = vec![0.0; d];
    results.push(run("csr_tmatvec same", &cfg, || {
        g.iter_mut().for_each(|v| *v = 0.0);
        shard.x.tmatvec(&r, &mut g);
        g[0]
    }));
    results.push(run("shard_loss_grad (fused pass)", &cfg, || {
        g.iter_mut().for_each(|v| *v = 0.0);
        shard_loss_grad(&shard.x, &shard.y, &w, LossKind::Logistic, &mut g, None)
    }));
    // the FS driver's cached-margin gradient pass (§Perf): margins held
    // from the previous line search, so no X·w matvec
    let mut zc = vec![0.0; shard.n_examples()];
    shard.x.matvec(&w, &mut zc);
    results.push(run("grad pass w/ cached margins", &cfg, || {
        g.iter_mut().for_each(|v| *v = 0.0);
        let mut val = 0.0;
        for i in 0..shard.x.n_rows() {
            val += LossKind::Logistic.value(zc[i], shard.y[i]);
            let r = LossKind::Logistic.deriv(zc[i], shard.y[i]);
            if r != 0.0 {
                shard.x.add_row_scaled(i, r, &mut g);
            }
        }
        val
    }));

    // --- SVRG epoch: lazy vs dense reference ---
    let lam = 1e-5 * shard.n_examples() as f64;
    let mut grad_lp = vec![0.0; d];
    shard_loss_grad(
        &shard.x, &shard.y, &w, LossKind::Logistic, &mut grad_lp, None,
    );
    let mut g_r = grad_lp.clone();
    dense::axpy(lam, &w, &mut g_r);
    let approx = LocalApprox::new(
        &shard.x, &shard.y, LossKind::Logistic, lam, &w, &g_r, &grad_lp,
    );
    let macro_cfg = BenchConfig::macro_bench();
    results.push(run("svrg_epoch lazy (per-example, 1 epoch)", &macro_cfg, || {
        svrg_epochs(&approx, &w, &SvrgParams { epochs: 1, ..Default::default() }).0[0]
    }));
    results.push(run("svrg_epoch dense-ref (batch 256)", &macro_cfg, || {
        svrg_epochs_dense(
            &approx,
            &w,
            &SvrgParams { epochs: 1, batch: 256, ..Default::default() },
        )
        .0[0]
    }));

    // --- reduction + metrics + dense kernels ---
    let parts: Vec<Vec<f64>> = (0..25)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    results.push(run("tree_sum 25 nodes x 100k", &cfg, || {
        tree_sum(&parts)[0]
    }));
    let scores: Vec<f64> = (0..100_000).map(|_| rng.normal()).collect();
    let labels: Vec<f64> = (0..100_000).map(|_| rng.sign()).collect();
    results.push(run("auprc 100k examples", &cfg, || {
        auprc(&scores, &labels)
    }));
    let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    results.push(run("dense dot 100k", &cfg, || dense::dot(&a, &b)));
    let mut y = b.clone();
    results.push(run("dense axpy 100k", &cfg, || {
        dense::axpy(0.5, &a, &mut y);
        y[0]
    }));

    println!("\n### micro benches (psgd in-tree harness)");
    for s in &results {
        println!("{}", s.report());
    }
}
