//! Makespan-to-ε: barrier vs pipelined vs bounded-staleness async FS
//! across three node profiles (homogeneous, seeded skew, 3× straggler).
//!
//! The pipelined schedule hides the *control plane* but still waits
//! for every node's fresh local solve each round; async with a
//! partial quorum stops waiting for the straggler entirely and lets
//! its stale hybrid ride along instead. The honest comparison is
//! virtual seconds to a fixed objective target (async may need more
//! rounds — its directions are built from a quorum — so raw per-round
//! makespans would flatter it).
//!
//! Smoke contract for CI (`make bench-smoke`): on the straggler
//! profile the async makespan-to-ε strictly beats the pipelined
//! schedule by an absolute virtual-seconds margin. The run also
//! writes `BENCH_async_fs.json` (uploaded by CI) so the perf
//! trajectory is machine-readable.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, NodeProfile};
use psgd::data::synth::SynthConfig;
use psgd::util::json::Value;

const NODES: usize = 8;
const ITERS: usize = 10;
const TAU: usize = 2;
const QUORUM: usize = 6;

fn fs_cfg(pipeline: bool) -> FsConfig {
    FsConfig { lam: 1.0, epochs: 2, pipeline, ..Default::default() }
}

fn run(
    c0: &Cluster,
    profile: &NodeProfile,
    driver: &dyn Driver,
    stop: &StopRule,
) -> RunResult {
    let mut cluster = c0.fork_fresh();
    cluster.set_profile(profile.clone());
    driver.run(&mut cluster, None, stop)
}

fn main() {
    let data = SynthConfig {
        n_examples: 8_000,
        n_features: 20_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    // comm heavy enough that schedules differ, modeled compute large
    // enough to dwarf measurement noise
    let cost = CostModel {
        latency_s: 0.02,
        compute_scale: 20_000.0,
        ..CostModel::default()
    };
    let mut c0 = Cluster::partition(data, NODES, cost);
    c0.threads = 1; // contention-free measured per-node compute
    println!(
        "### async_fs bench: FS on {NODES} nodes, τ={TAU}, q={QUORUM} \
         (sparse path: {})",
        c0.prefer_sparse()
    );
    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>7} {:>9}",
        "scenario", "barrier s", "pipeline s", "async s", "rounds", "speedup"
    );

    let scenarios: Vec<(&str, NodeProfile)> = vec![
        ("homogeneous", NodeProfile::homogeneous(NODES)),
        ("skewed", NodeProfile::seeded(NODES, 7, 1.5)),
        ("straggler3x", NodeProfile::with_straggler(NODES, 0, 3.0)),
    ];

    let mut scen_json: Vec<(&str, Value)> = Vec::new();
    let mut straggler_margin = f64::NAN;
    for (name, profile) in &scenarios {
        // ε: 99.9% of the objective progress the synchronous run makes
        // in ITERS rounds — reachable by every schedule
        let reference =
            run(&c0, profile, &FsDriver::new(fs_cfg(false)), &StopRule::iters(ITERS));
        let f0 = reference.trace.points[0].f;
        let target = reference.f + 1e-3 * (f0 - reference.f);
        let stop = StopRule::iters(80).with_target(target);

        let barrier = run(&c0, profile, &FsDriver::new(fs_cfg(false)), &stop);
        let piped = run(&c0, profile, &FsDriver::new(fs_cfg(true)), &stop);
        let asynchronous = run(
            &c0,
            profile,
            &AsyncFsDriver::new(AsyncFsConfig {
                fs: fs_cfg(false),
                policy: Asynchrony::Bounded {
                    tau: TAU,
                    quorum: Quorum::AtLeast(QUORUM),
                },
                ..Default::default()
            }),
            &stop,
        );
        for (label, r) in
            [("barrier", &barrier), ("pipelined", &piped), ("async", &asynchronous)]
        {
            assert!(
                r.f <= target,
                "{name}/{label} never reached the target: {} > {target}",
                r.f
            );
        }
        let (bs, ps, als) = (
            barrier.ledger.seconds(),
            piped.ledger.seconds(),
            asynchronous.ledger.seconds(),
        );
        println!(
            "{:<14} {:>11.2} {:>11.2} {:>11.2} {:>7} {:>8.2}x",
            name,
            bs,
            ps,
            als,
            asynchronous.trace.points.len(),
            ps / als
        );
        println!(
            "  staleness: {}",
            asynchronous.ledger.staleness_profile()
        );
        if *name == "straggler3x" {
            straggler_margin = ps - als;
            // the load-bearing smoke assert: async strictly beats the
            // pipelined schedule to the same ε on the straggler — in
            // absolute virtual seconds, robust to host speed
            assert!(
                als < ps - 1.0,
                "straggler: async {als} not strictly below pipelined {ps}"
            );
        }
        scen_json.push((
            *name,
            Value::obj(vec![
                ("barrier_s", Value::Num(bs)),
                ("pipelined_s", Value::Num(ps)),
                ("async_s", Value::Num(als)),
                (
                    "async_rounds",
                    Value::Num(asynchronous.trace.points.len() as f64),
                ),
                (
                    "fallback_rounds",
                    Value::Num(asynchronous.ledger.fallback_rounds as f64),
                ),
                (
                    "async_comm_bytes",
                    Value::Num(asynchronous.ledger.comm_bytes),
                ),
            ]),
        ));
    }

    let out = Value::obj(vec![
        ("bench", Value::Str("async_fs".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("staleness", Value::Num(TAU as f64)),
        ("quorum", Value::Num(QUORUM as f64)),
        ("scenarios", Value::obj(scen_json)),
        (
            "async_vs_pipeline_margin_s",
            Value::Num(straggler_margin),
        ),
    ]);
    std::fs::write("BENCH_async_fs.json", out.to_json(1))
        .expect("write BENCH_async_fs.json");
    println!("\nwrote BENCH_async_fs.json (straggler margin {straggler_margin:.2}s)");

    println!(
        "\nreading: pipelining hides the control plane but still \
         barriers on the slowest local solve; the bounded-staleness \
         quorum stops waiting for the straggler and re-bases its stale \
         hybrid instead — same ε, strictly shorter critical path."
    );
}
