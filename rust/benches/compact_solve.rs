//! Compact-space vs full-space inner solve at the paper's
//! high-dimensional regime: one node's shard (2k rows × ~10 nnz) over
//! d = 500k and d = 5M columns. The full-space SVRG solve sweeps
//! length-d buffers (anchor/μ/b/last + the O(d) epoch flush and the
//! O(d) Lipschitz power-iteration vectors); the compact solve runs the
//! *same* epochs in |support| + ≤2 coordinates. The gap — and the
//! O(|support|) vs O(d) working set — is the whole point of the
//! support-compact pipeline.

use psgd::bench::{run, BenchConfig};
use psgd::data::synth::SynthConfig;
use psgd::linalg::{dense, SupportMap};
use psgd::loss::LossKind;
use psgd::objective::compact::{CompactApprox, GlobalDots, HybridDir};
use psgd::objective::{shard_loss_grad, LocalApprox, Objective};
use psgd::opt::svrg::{svrg_epochs, SvrgParams};
use psgd::util::json::Value;
use psgd::util::rng::Rng;

fn bench_at(d: usize, check_equivalence: bool) -> Value {
    let data = SynthConfig {
        n_examples: 2_000,
        n_features: d,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(3);
    let mut rng = Rng::new(5);
    let w_r: Vec<f64> = (0..d).map(|_| rng.normal() * 0.01).collect();
    let lam = 1e-5 * data.n_examples() as f64;
    let mut grad_lp = vec![0.0; d];
    shard_loss_grad(
        &data.x, &data.y, &w_r, LossKind::Logistic, &mut grad_lp, None,
    );
    let mut g_r = grad_lp.clone();
    dense::axpy(lam, &w_r, &mut g_r);
    let full = LocalApprox::new(
        &data.x, &data.y, LossKind::Logistic, lam, &w_r, &g_r, &grad_lp,
    );

    let (map, xl) = SupportMap::compact(&data.x);
    let (mut wr_c, mut g_c, mut glp_c) = (Vec::new(), Vec::new(), Vec::new());
    map.gather(&w_r, &mut wr_c);
    map.gather(&g_r, &mut g_c);
    map.gather(&grad_lp, &mut glp_c);
    let dots = GlobalDots::compute(&w_r, &g_r);
    let ca = CompactApprox::build(
        &xl, &data.y, LossKind::Logistic, lam, &dots, &wr_c, &g_c, &glp_c,
    );

    let params = SvrgParams { epochs: 2, batch: 16, lr: None, seed: 1 };
    let cfg = BenchConfig::macro_bench();
    let full_stats = run(&format!("svrg full-space   d = {d}"), &cfg, || {
        svrg_epochs(&full, &w_r, &params).0[0]
    });
    let compact_stats =
        run(&format!("svrg compact  dim = {}", ca.dim()), &cfg, || {
            svrg_epochs(&ca, &ca.w_r, &params).0[0]
        });
    println!("{}", full_stats.report());
    println!("{}", compact_stats.report());
    // solver working set: 4×f64 + 1×u32 per solve-space coordinate
    // (w, μ, anchor, b, last) — the buffers the epochs actually sweep
    let ws_full = 36 * d;
    let ws_compact = 36 * ca.dim();
    println!(
        "working set: full {:.1} MB vs compact {:.3} MB ({}x smaller)\n",
        ws_full as f64 / 1e6,
        ws_compact as f64 / 1e6,
        ws_full / ws_compact.max(1),
    );
    assert!(
        compact_stats.median_s < full_stats.median_s,
        "compact solve must be strictly faster: {} vs {}",
        compact_stats.median_s,
        full_stats.median_s
    );

    if check_equivalence {
        let (w_f, _) = svrg_epochs(&full, &w_r, &params);
        let (w_c, _) = svrg_epochs(&ca, &ca.w_r, &params);
        let (a_w, a_g) = ca.off_support_coeffs(&w_c);
        let hd =
            HybridDir::from_compact(&map, d, a_w, a_g, &w_c, &wr_c, &g_c);
        let mut w_rec = w_r.clone();
        dense::axpy(1.0, &hd.to_dense(&w_r, &g_r), &mut w_rec);
        let diff = dense::max_abs_diff(&w_f, &w_rec);
        println!("full-vs-compact solve max |Δ| = {diff:.3e}");
        assert!(diff < 1e-8, "solves diverged: {diff}");
    }

    Value::obj(vec![
        ("dim", Value::Num(d as f64)),
        ("full_median_s", Value::Num(full_stats.median_s)),
        ("compact_median_s", Value::Num(compact_stats.median_s)),
        (
            "compact_speedup",
            Value::Num(full_stats.median_s / compact_stats.median_s),
        ),
        (
            "working_set_ratio",
            Value::Num(ws_full as f64 / ws_compact.max(1) as f64),
        ),
    ])
}

fn main() {
    println!("### compact_solve benches (2k rows × 10 nnz per shard)\n");
    let at_500k = bench_at(500_000, true);
    let at_5m = bench_at(5_000_000, false);
    // machine-readable record for the CI perf trajectory
    let out = Value::obj(vec![
        ("bench", Value::Str("compact_solve".to_string())),
        ("d500k", at_500k),
        ("d5m", at_5m),
    ]);
    std::fs::write("BENCH_compact_solve.json", out.to_json(1))
        .expect("write BENCH_compact_solve.json");
    println!("wrote BENCH_compact_solve.json");
}
