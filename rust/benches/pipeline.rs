//! Pipelined-schedule bench: FS makespan under the event engine's
//! barrier vs `--pipeline` schedules, across three node-profile
//! scenarios (homogeneous, seeded skew, one 3× straggler).
//!
//! The pipelined schedule overlaps round r's direction allreduce +
//! safeguard + line search (control lane) with round r+1's self-paced
//! node compute; the arithmetic is bit-identical (asserted below), so
//! the whole difference is schedule. Smoke contract for CI
//! (`make bench-smoke`): pipelining never loses, and on the straggler
//! scenario it wins strictly — the ROADMAP's "async pipeline of local
//! solves with the reduction" made measurable.

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, NodeProfile};
use psgd::data::synth::SynthConfig;
use psgd::util::json::Value;

const NODES: usize = 8;
const ITERS: usize = 10;

fn run_fs(c0: &Cluster, profile: &NodeProfile, pipeline: bool) -> RunResult {
    let mut cluster = c0.fork_fresh();
    cluster.set_profile(profile.clone());
    let driver = FsDriver::new(FsConfig {
        lam: 1.0,
        epochs: 2,
        pipeline,
        ..Default::default()
    });
    driver.run(&mut cluster, None, &StopRule::iters(ITERS))
}

fn main() {
    let data = SynthConfig {
        n_examples: 8_000,
        n_features: 20_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    // comm heavy enough that the control plane is worth hiding, and
    // modeled compute large enough to dwarf measurement noise
    let cost = CostModel {
        latency_s: 0.02,
        compute_scale: 20_000.0,
        ..CostModel::default()
    };
    let mut c0 = Cluster::partition(data, NODES, cost);
    c0.threads = 1; // contention-free measured per-node compute
    println!(
        "### pipeline bench: FS on {NODES} nodes, {ITERS} outer iters \
         (sparse path: {})",
        c0.prefer_sparse()
    );
    println!(
        "{:<14} {:>14} {:>14} {:>9}",
        "scenario", "barrier s", "pipelined s", "speedup"
    );

    let scenarios: Vec<(&str, NodeProfile)> = vec![
        ("homogeneous", NodeProfile::homogeneous(NODES)),
        ("skewed", NodeProfile::seeded(NODES, 7, 1.5)),
        ("straggler3x", NodeProfile::with_straggler(NODES, 0, 3.0)),
    ];

    let mut scen_json: Vec<(&str, Value)> = Vec::new();
    let mut straggler_margin = f64::NAN;
    for (name, profile) in &scenarios {
        let barrier = run_fs(&c0, profile, false);
        let piped = run_fs(&c0, profile, true);
        // schedule only: the iterates and objective traces must match
        // bit-for-bit between the two schedules
        assert_eq!(
            barrier.w, piped.w,
            "{name}: pipelined arithmetic diverged"
        );
        for (b, p) in barrier.trace.points.iter().zip(&piped.trace.points) {
            assert_eq!(b.f, p.f, "{name}: trace diverged at iter {}", b.iter);
        }
        let mb = barrier.ledger.seconds();
        let mp = piped.ledger.seconds();
        println!(
            "{:<14} {:>14.2} {:>14.2} {:>8.2}x",
            name,
            mb,
            mp,
            mb / mp
        );
        // smoke contract: pipelining never loses. Makespans fold in
        // wall-clock compute measured in two independent runs, so
        // allow generous noise headroom here — the load-bearing
        // assertion is the absolute-margin straggler win below.
        assert!(
            mp <= mb * 1.10 + 0.5,
            "{name}: pipelined {mp} exceeds barrier {mb}"
        );
        // ...and strictly wins when one node straggles: the control
        // plane hides under the straggler's self-paced compute. The
        // margin is absolute virtual seconds (≈ one round's control
        // plane), robust to host speed.
        if *name == "straggler3x" {
            straggler_margin = mb - mp;
            assert!(
                mp < mb - 0.25,
                "straggler: pipelined {mp} not strictly below barrier {mb}"
            );
        }
        scen_json.push((
            *name,
            Value::obj(vec![
                ("barrier_s", Value::Num(mb)),
                ("pipelined_s", Value::Num(mp)),
                ("comm_bytes", Value::Num(piped.ledger.comm_bytes)),
            ]),
        ));
    }

    // machine-readable record for the CI perf trajectory
    let out = Value::obj(vec![
        ("bench", Value::Str("pipeline".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("iters", Value::Num(ITERS as f64)),
        ("scenarios", Value::obj(scen_json)),
        ("pipeline_margin_s", Value::Num(straggler_margin)),
    ]);
    std::fs::write("BENCH_pipeline.json", out.to_json(1))
        .expect("write BENCH_pipeline.json");
    println!("\nwrote BENCH_pipeline.json");

    println!(
        "\nreading: the barrier schedule serializes every direction \
         allreduce + line search behind the slowest node; the pipelined \
         schedule hides that control plane under the next round's \
         sweeps/solves. Identical math, shorter critical path."
    );
}
