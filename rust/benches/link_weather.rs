//! Link-weather chaos bench: async FS under heterogeneous link speeds,
//! congestion, flaps and partitions on the reduction tree.
//!
//! Four gates, all on fully modeled time (compute_scale 0.0, so the
//! virtual clocks — and therefore every comparison below — are
//! bit-reproducible):
//!
//! 1. **Uniform inertness** — the uniform [`LinkProfile`] plus the
//!    empty [`LinkFaultPlan`] are bit-identical to no link state at
//!    all: iterates, trace seconds, full ledger.
//! 2. **Weather never moves the maths** — rack-skewed links and
//!    congested/flapping weather change only the virtual clock;
//!    iterates stay bit-identical to the clean arm, and every cell
//!    still reaches the clean run's objective target.
//! 3. **Retry strictly beats waiting** — on the same flap timeline,
//!    the timeout/retry/backoff discipline (`budget` retries, then
//!    reroute around the dead edge) reaches the same iterate in
//!    strictly fewer absolute virtual seconds than the `noretry`
//!    control arm that waits out each dead link in full.
//! 4. **Bitwise seed replay** — one link seed replays the identical
//!    weather log, iterate, and ledger; partitions (including one
//!    isolating the master) terminate through the quorum + certified
//!    fallback, never a hang.
//!
//! The run writes `BENCH_link_weather.json` (uploaded by the CI
//! `chaos` job) so the link-resilience trajectory is machine-readable.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::FsConfig;
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, Ledger, LinkFaultPlan, LinkProfile};
use psgd::data::synth::SynthConfig;
use psgd::util::json::Value;

const NODES: usize = 6;
const ITERS: usize = 10;
const TAU: usize = 2;

fn driver() -> AsyncFsDriver {
    AsyncFsDriver::new(AsyncFsConfig {
        fs: FsConfig { lam: 1.0, epochs: 2, ..Default::default() },
        policy: Asynchrony::Bounded {
            tau: TAU,
            quorum: Quorum::AtLeast(NODES - 1),
        },
        ..Default::default()
    })
}

fn run_with_links(
    c0: &Cluster,
    profile: Option<LinkProfile>,
    plan: Option<LinkFaultPlan>,
    stop: &StopRule,
) -> (RunResult, Ledger) {
    let mut cluster = c0.fork_fresh();
    if let Some(p) = profile {
        cluster.set_link_profile(p);
    }
    if let Some(p) = plan {
        cluster.set_link_fault_plan(p);
    }
    let run = driver().run(&mut cluster, None, stop);
    (run, cluster.ledger.clone())
}

fn plan(script: &str, seed: u64) -> LinkFaultPlan {
    let mut p = LinkFaultPlan::parse(script, NODES)
        .expect("bench link script must parse");
    p.seed = seed;
    p
}

fn main() {
    let data = SynthConfig {
        n_examples: 4_000,
        n_features: 10_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    // fully modeled time: link weather is a comm-layer story, and a
    // measured compute share would blur the strict-win comparison
    let cost = CostModel {
        latency_s: 0.02,
        compute_scale: 0.0,
        ..CostModel::default()
    };
    let mut c0 = Cluster::partition(data, NODES, cost);
    c0.threads = 1;
    println!(
        "### link_weather bench: async FS on {NODES} nodes, τ={TAU}, \
         q={} under link-level weather",
        NODES - 1
    );

    // clean reference + the ε bar every weather cell must still clear
    let (clean, clean_ledger) =
        run_with_links(&c0, None, None, &StopRule::iters(ITERS));
    let f0 = clean.trace.points[0].f;
    let target = clean.f + 1e-3 * (f0 - clean.f);
    let stop = StopRule::iters(80).with_target(target);
    let clean_s = clean_ledger.seconds();
    println!(
        "clean reference: f={:.6e} in {} rounds, {clean_s:.2}s",
        clean.f,
        clean.trace.points.len()
    );

    // --- gate 1: uniform profile + empty plan are structurally inert
    let (inert, inert_ledger) = run_with_links(
        &c0,
        Some(LinkProfile::uniform(NODES)),
        Some(LinkFaultPlan::default()),
        &StopRule::iters(ITERS),
    );
    assert_eq!(clean.w, inert.w, "uniform links perturbed the iterates");
    assert_eq!(
        clean_ledger, inert_ledger,
        "uniform links perturbed the ledger"
    );
    println!("uniform gate: bit-identical to no link state");

    println!(
        "{:<10} {:>9} {:>7} {:>9} {:>8} {:>9}",
        "scenario", "chaos s", "rounds", "retry s", "reroutes", "overhead"
    );

    let mut cells: Vec<(String, Value)> = Vec::new();
    let mut record = |name: &str, run: &RunResult, ledger: &Ledger| {
        let secs = ledger.seconds();
        println!(
            "{:<10} {:>9.2} {:>7} {:>9.3} {:>8} {:>8.2}x",
            name,
            secs,
            run.trace.points.len(),
            ledger.retry_seconds,
            ledger.reroutes,
            secs / clean_s
        );
        cells.push((
            name.to_string(),
            Value::obj(vec![
                ("seconds", Value::Num(secs)),
                ("rounds", Value::Num(run.trace.points.len() as f64)),
                ("retry_seconds", Value::Num(ledger.retry_seconds)),
                ("link_retries", Value::Num(ledger.link_retries as f64)),
                ("reroutes", Value::Num(ledger.reroutes as f64)),
                (
                    "congested_hops",
                    Value::Num(ledger.congested_hops as f64),
                ),
                (
                    "partition_events",
                    Value::Num(ledger.partition_events as f64),
                ),
                (
                    "fallback_rounds",
                    Value::Num(ledger.fallback_rounds as f64),
                ),
                ("overhead_x", Value::Num(secs / clean_s)),
            ]),
        ));
    };

    // --- gate 2a: rack-skewed uplinks — timing-only, same maths
    let (skew, skew_ledger) = run_with_links(
        &c0,
        Some(LinkProfile::seeded(NODES, 1)),
        None,
        &stop,
    );
    assert!(
        skew.f <= target,
        "rack_skew never reached the clean target: {} > {target}",
        skew.f
    );
    assert!(
        skew_ledger.comm_seconds > clean_ledger.comm_seconds,
        "a seeded rack skew charged no extra comm time"
    );
    record("rack_skew", &skew, &skew_ledger);

    // --- gate 2b: congested weather — retries/backoff charged to the
    // distinct retry_seconds counter, target still reached
    let congest_script = "congest:p=0.3:6x,flap:p=0.3,timeout:0.05";
    let (cong, cong_ledger) = run_with_links(
        &c0,
        Some(LinkProfile::seeded(NODES, 1)),
        Some(plan(congest_script, 7)),
        &stop,
    );
    assert!(
        cong.f <= target,
        "congested never reached the clean target: {} > {target}",
        cong.f
    );
    assert!(
        cong_ledger.link_retries > 0 && cong_ledger.retry_seconds > 0.0,
        "p=0.3 flaps never cost a retry"
    );
    assert!(
        cong_ledger.congested_hops > 0,
        "p=0.3 congestion never fired"
    );
    record("congested", &cong, &cong_ledger);

    // --- gate 3: retry/reroute strictly beats waiting out dead links.
    // Same seed → same flap timeline; flaps are pure timing, so both
    // arms walk the identical iterate sequence and the only difference
    // is the per-hop recovery discipline. `noretry` pays the full dead
    // window T·2^k per flapped hop; retry pays the backoff T·(2^k−1),
    // or reroutes past the budget — strictly less on every hop.
    let flap_script = "flap:p=0.4,timeout:0.05,budget:3";
    let (retry, retry_ledger) = run_with_links(
        &c0,
        None,
        Some(plan(flap_script, 11)),
        &StopRule::iters(12),
    );
    let (wait, wait_ledger) = run_with_links(
        &c0,
        None,
        Some(plan(&format!("{flap_script},noretry"), 11)),
        &StopRule::iters(12),
    );
    assert_eq!(
        retry.w, wait.w,
        "recovery discipline moved the maths (it must be timing-only)"
    );
    assert!(
        retry_ledger.link_retries > 0,
        "p=0.4 flap weather never fired; the strict-win gate is vacuous"
    );
    let (retry_s, wait_s) =
        (retry_ledger.seconds(), wait_ledger.seconds());
    assert!(
        retry_s < wait_s,
        "retry+reroute failed to beat waiting out dead links: \
         {retry_s:.3}s vs {wait_s:.3}s"
    );
    record("retry", &retry, &retry_ledger);
    record("noretry", &wait, &wait_ledger);
    println!(
        "strict win: retry {retry_s:.2}s < noretry {wait_s:.2}s \
         ({:.1}% saved on the same flap timeline)",
        100.0 * (wait_s - retry_s) / wait_s
    );

    // --- gate 4a: partitions (incl. master-isolating) never hang
    let part_script = "part:1+2@r3..r6,part:1+2+3+4+5@r8..r10";
    let (part, part_ledger) = run_with_links(
        &c0,
        None,
        Some(plan(part_script, 13)),
        &StopRule::iters(14),
    );
    assert!(part.f.is_finite(), "partition weather hung the run");
    assert_eq!(
        part_ledger.partition_events, 2,
        "both scripted cuts must apply"
    );
    assert!(
        part_ledger.fallback_rounds >= 1,
        "the master-isolating heal skipped the certified fallback"
    );
    record("partition", &part, &part_ledger);

    // --- gate 4b: bitwise seed replay of the congested cell
    let replay = |seed: u64| {
        run_with_links(
            &c0,
            Some(LinkProfile::seeded(NODES, 1)),
            Some(plan(congest_script, seed)),
            &StopRule::iters(12),
        )
    };
    let (run_a, ledger_a) = replay(7);
    let (run_b, ledger_b) = replay(7);
    assert_eq!(run_a.w, run_b.w, "iterate failed to replay bitwise");
    assert_eq!(ledger_a, ledger_b, "ledger failed to replay bitwise");
    let (_, ledger_c) = replay(8);
    assert_ne!(
        ledger_a, ledger_c,
        "the link seed had no effect on the weather"
    );
    println!(
        "determinism gate: {} link retries replay bit-identically",
        ledger_a.link_retries
    );

    let out = Value::obj(vec![
        ("bench", Value::Str("link_weather".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("staleness", Value::Num(TAU as f64)),
        ("quorum", Value::Num((NODES - 1) as f64)),
        ("clean_seconds", Value::Num(clean_s)),
        (
            "cells",
            Value::obj(
                cells
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        ),
        ("uniform_bit_identical", Value::Bool(true)),
        ("retry_strict_win", Value::Bool(true)),
        ("retry_seconds_saved", Value::Num(wait_s - retry_s)),
        ("deterministic_replay", Value::Bool(true)),
    ]);
    std::fs::write("BENCH_link_weather.json", out.to_json(1))
        .expect("write BENCH_link_weather.json");
    println!("\nwrote BENCH_link_weather.json");

    println!(
        "\nreading: heterogeneous and congested links stretch only the \
         virtual clock — the maths never moves — and the timeout/retry/\
         backoff discipline strictly beats waiting out dead links to \
         the same iterate; partitions heal through the certified \
         fallback and every link decision replays from its seed."
    );
}
