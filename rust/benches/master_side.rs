//! Master-side cost per outer round: classic dense master (several
//! O(d) passes per round — ‖gʳ‖, the shared dots, the step-7 dʳ
//! materialization, PhiLambda, the step-9 axpy, plus the O(d)
//! densification of the reduced gradient) versus the union-support
//! compact master (every one of those on length-|U| buffers, full-d
//! materialized exactly once at RunResult construction).
//!
//! The regime is the paper's: d ∈ {5M, 50M} hashed columns, |U| ≈ 100k
//! columns actually touched by data. Node-side work is identical in
//! both runs (the PR 2 compact pipeline), so wall-clock seconds/round
//! isolate the master-side O(d)-vs-O(|U|) gap.
//!
//! Smoke contract for CI (`make bench-smoke`):
//! - the compact master is strictly faster per round at BOTH dims;
//! - the two masters are ε-equivalent (objective trace + final w);
//! - the d = 50M case runs inside CI memory — including the async
//!   driver with τ = 2, whose master reference ring is O(τ·|U|) under
//!   the compact master instead of O(τ·d) (2.4 GB it never allocates).
//! Writes `BENCH_master_side.json` (uploaded by CI).

use std::time::Instant;

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::{FsConfig, FsDriver, MasterMode};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::dataset::Dataset;
use psgd::data::synth::SynthConfig;
use psgd::linalg::dense;
use psgd::util::json::Value;

const NODES: usize = 8;
const TAU: usize = 2;

/// kdd2010-shaped data whose support is dense in a ~u-sized head,
/// lifted onto d columns by a constant index stride — |U| stays ≈ u
/// while the master's dense frame is the full d (exactly the hashed
/// feature-space shape: enormous d, comparatively few live columns).
fn lifted_data(d: usize, u_target: usize, rows: usize, seed: u64) -> Dataset {
    let base = SynthConfig {
        n_examples: rows,
        n_features: u_target,
        nnz_per_example: 12,
        ..SynthConfig::default()
    }
    .generate(seed);
    let stride = (d / u_target).max(1) as u32;
    let mut x = base.x.clone();
    // scaling every index by a constant keeps within-row order sorted
    for c in x.indices.iter_mut() {
        *c *= stride;
    }
    x.n_cols = d;
    Dataset::new(x, base.y)
}

fn fs_cfg(master: MasterMode) -> FsConfig {
    FsConfig { lam: 1.0, epochs: 2, master, ..Default::default() }
}

fn timed_run(c0: &Cluster, master: MasterMode, iters: usize) -> (RunResult, f64) {
    let mut cluster = c0.fork_fresh();
    let t0 = Instant::now();
    let run = FsDriver::new(fs_cfg(master)).run(
        &mut cluster,
        None,
        &StopRule::iters(iters),
    );
    let wall = t0.elapsed().as_secs_f64();
    let rounds = (run.trace.points.len().saturating_sub(1)).max(1);
    (run, wall / rounds as f64)
}

fn assert_equivalent(d: &RunResult, c: &RunResult, tag: &str) {
    assert_eq!(d.trace.points.len(), c.trace.points.len(), "{tag}: rounds");
    for (pd, pc) in d.trace.points.iter().zip(&c.trace.points) {
        assert!(
            (pd.f - pc.f).abs() <= 1e-9 * (1.0 + pd.f.abs()),
            "{tag}: trace diverged at iter {}: {} vs {}",
            pd.iter,
            pd.f,
            pc.f
        );
    }
    let diff = dense::max_abs_diff(&d.w, &c.w);
    assert!(diff <= 1e-9, "{tag}: final iterates diverged by {diff}");
}

fn bench_at(d: usize, iters: usize) -> Value {
    let data = lifted_data(d, 100_000, 30_000, 42);
    let mut c0 = Cluster::partition(data, NODES, CostModel::free());
    c0.threads = 1; // contention-free, deterministic wall measurement
    let u = c0.umap.len();
    assert!(
        c0.prefer_compact_master(),
        "lifted data must gate compact on (|U|/d = {})",
        c0.union_density()
    );

    let (run_dense, dense_spr) = timed_run(&c0, MasterMode::Dense, iters);
    let (run_compact, compact_spr) = timed_run(&c0, MasterMode::Compact, iters);
    assert_equivalent(&run_dense, &run_compact, &format!("d={d}"));
    drop(run_dense);

    // resident master vectors (w, g, d) per round + the async τ-ring
    let master_dense = 3 * d * 8;
    let master_compact = 3 * u * 8;
    let ring_dense = 2 * (TAU + 1) * d * 8;
    let ring_compact = 2 * (TAU + 1) * u * 8;
    println!(
        "{:>9} {:>9} {:>13.1} {:>13.2} {:>8.0}x {:>11.1} {:>10.3}",
        fmt_dim(d),
        u,
        dense_spr * 1e3,
        compact_spr * 1e3,
        dense_spr / compact_spr,
        master_dense as f64 / 1e6,
        master_compact as f64 / 1e6,
    );

    // the load-bearing smoke assert: strictly faster per round
    assert!(
        compact_spr < dense_spr,
        "d={d}: compact master {compact_spr}s/round not strictly below \
         dense {dense_spr}s/round"
    );

    Value::obj(vec![
        ("dim", Value::Num(d as f64)),
        ("union_support", Value::Num(u as f64)),
        ("dense_s_per_round", Value::Num(dense_spr)),
        ("compact_s_per_round", Value::Num(compact_spr)),
        ("speedup", Value::Num(dense_spr / compact_spr)),
        ("master_bytes_dense", Value::Num(master_dense as f64)),
        ("master_bytes_compact", Value::Num(master_compact as f64)),
        ("async_ring_bytes_dense", Value::Num(ring_dense as f64)),
        ("async_ring_bytes_compact", Value::Num(ring_compact as f64)),
    ])
}

fn fmt_dim(d: usize) -> String {
    format!("{}M", d / 1_000_000)
}

fn main() {
    println!(
        "### master_side bench: dense vs union-support compact master \
         ({NODES} nodes, |U| ≈ 100k)\n"
    );
    println!(
        "{:>9} {:>9} {:>13} {:>13} {:>9} {:>11} {:>10}",
        "d", "|U|", "dense ms/rd", "compact ms/rd", "speedup",
        "dense MB", "compact MB"
    );
    let at_5m = bench_at(5_000_000, 3);
    let at_50m = bench_at(50_000_000, 2);

    // the O(τ·|U|) demonstration: bounded-staleness async FS at d=50M
    // runs in CI memory precisely because the compact master's
    // re-basing ring holds τ+1 length-|U| reference pairs, not τ+1
    // full-d ones (which alone would be ~2.4 GB here)
    let data = lifted_data(50_000_000, 100_000, 30_000, 43);
    let mut c_async = Cluster::partition(data, NODES, CostModel::free());
    c_async.threads = 1;
    let t0 = Instant::now();
    let async_run = AsyncFsDriver::new(AsyncFsConfig {
        fs: fs_cfg(MasterMode::Compact),
        policy: Asynchrony::Bounded { tau: TAU, quorum: Quorum::All },
        ..Default::default()
    })
    .run(&mut c_async, None, &StopRule::iters(2));
    let async_wall = t0.elapsed().as_secs_f64();
    assert!(async_run.f.is_finite());
    println!(
        "\nasync compact master at d=50M (τ={TAU}): {async_wall:.2}s wall, \
         ring = {} × |U| reference pairs (O(τ·|U|) master memory)",
        TAU + 1
    );

    let out = Value::obj(vec![
        ("bench", Value::Str("master_side".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("d5m", at_5m),
        ("d50m", at_50m),
        ("async_50m_wall_s", Value::Num(async_wall)),
    ]);
    std::fs::write("BENCH_master_side.json", out.to_json(1))
        .expect("write BENCH_master_side.json");
    println!("wrote BENCH_master_side.json");

    println!(
        "\nreading: node-side work is identical in both columns — the \
         gap is purely the master's O(d) passes (norms, dots, combine, \
         λ scalars, axpy, gradient densification) collapsing to O(|U|). \
         The full-d vector is materialized once, at RunResult::w."
    );
}
