//! Bench: regenerate Figure 1's LEFT panels — (f − f*)/f* (log scale)
//! versus number of communication passes, for 25 and 100 nodes.
//! Prints the series the paper plots; CSV lands in results/.

use psgd::bench::figure1::{self, Figure1Config, Panel};
use psgd::bench::plot::AsciiPlot;

fn main() {
    for nodes in [25usize, 100] {
        let cfg = Figure1Config::small(nodes);
        let out = figure1::run(&cfg);
        println!(
            "\n### Figure 1 (left, {} nodes): gap vs communication passes",
            nodes
        );
        println!("f* = {:.6e}   [{}]", out.f_star, out.config_label);
        println!("{:<10} {:>8} {:>12}", "method", "passes", "rel_gap");
        for trace in &out.traces {
            for (x, y) in Panel::GapVsPasses.series(trace, out.f_star) {
                println!("{:<10} {:>8.0} {:>12.4e}", trace.label, x, y);
            }
            let path =
                format!("results/bench_fig1_comm_{nodes}n_{}.csv", trace.label);
            let _ = trace.to_table(out.f_star).save(&path);
        }
        let series: Vec<(String, Vec<(f64, f64)>)> = out
            .traces
            .iter()
            .map(|t| {
                (
                    t.label.clone(),
                    Panel::GapVsPasses
                        .series(t, out.f_star)
                        .into_iter()
                        .filter(|&(_, y)| y > 0.0)
                        .collect(),
                )
            })
            .collect();
        println!(
            "{}",
            AsciiPlot::default().render(Panel::GapVsPasses.title(), &series)
        );
    }
}
