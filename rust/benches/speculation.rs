//! Speculative-lane payoff bench: plain async FS vs speculation under
//! a 3× straggler and under seeded fleet weather.
//!
//! Plain bounded-staleness async stops *waiting* for stragglers, but
//! every fresh solve still starts at the round commit — the quorum's
//! critical path pays the full solve each round. A speculating lane
//! whose round-(r−1) solve finished early has already been solving
//! against its predicted basis; when the commit confirms the
//! prediction (the same θ-cone test that gates the combined
//! direction), that solve keeps its early start and the commit-to-
//! commit gap collapses toward the communication floor. A miss costs
//! nothing over not speculating: the lane re-bases and restarts at
//! the commit, exactly the plain schedule.
//!
//! Smoke contract for CI (the `chaos` job): on both matrices the
//! speculative run reaches the same ε strictly faster than plain
//! async by an absolute virtual-seconds margin, the spec-off ledger
//! stays clean of speculation, and the adaptive controller's seeded
//! (τ, q) trace replays bit-identically under modeled time. The run
//! writes `BENCH_speculation.json` (uploaded by CI).

use psgd::algo::adapt::{Asynchrony, Quorum, TuneBounds};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::FsConfig;
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, FaultPlan, Ledger, NodeProfile};
use psgd::data::synth::SynthConfig;
use psgd::util::json::Value;

const NODES: usize = 8;
const ITERS: usize = 10;
const TAU: usize = 2;
const QUORUM: usize = 6;

fn config(speculate: bool) -> AsyncFsConfig {
    AsyncFsConfig {
        fs: FsConfig { lam: 1.0, epochs: 2, ..Default::default() },
        policy: Asynchrony::Bounded {
            tau: TAU,
            quorum: Quorum::AtLeast(QUORUM),
        },
        speculate,
    }
}

fn run_cell(
    c0: &Cluster,
    profile: &NodeProfile,
    plan: Option<FaultPlan>,
    speculate: bool,
    stop: &StopRule,
) -> (RunResult, Ledger) {
    let mut cluster = c0.fork_fresh();
    cluster.set_profile(profile.clone());
    if let Some(p) = plan {
        cluster.set_fault_plan(p);
    }
    let run =
        AsyncFsDriver::new(config(speculate)).run(&mut cluster, None, stop);
    let ledger = cluster.ledger.clone();
    (run, ledger)
}

fn main() {
    let data = SynthConfig {
        n_examples: 8_000,
        n_features: 20_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    // comm heavy enough that schedules differ, modeled compute large
    // enough that a hidden solve is worth whole virtual seconds
    let cost = CostModel {
        latency_s: 0.02,
        compute_scale: 20_000.0,
        ..CostModel::default()
    };
    let mut c0 = Cluster::partition(data, NODES, cost);
    c0.threads = 1;
    println!(
        "### speculation bench: async FS on {NODES} nodes, τ={TAU}, \
         q={QUORUM}, plain vs speculative lanes"
    );
    println!(
        "{:<12} {:>10} {:>9} {:>9} {:>7} {:>7} {:>9}",
        "scenario", "plain s", "spec s", "margin", "hits", "misses", "speedup"
    );

    let chaos_plan = || {
        let mut plan = FaultPlan::parse(
            "crash:1@r2,restart:1@r6,loss:p=0.05",
            NODES,
        )
        .expect("bench fault script must parse");
        plan.seed = 3;
        Some(plan)
    };
    // (name, profile, weather, required margin in virtual seconds)
    let matrix: Vec<(&str, NodeProfile, Option<FaultPlan>, f64)> = vec![
        (
            "straggler3x",
            NodeProfile::with_straggler(NODES, 0, 3.0),
            None,
            1.0,
        ),
        ("chaos", NodeProfile::homogeneous(NODES), chaos_plan(), 0.5),
    ];

    let mut scen_json: Vec<(&str, Value)> = Vec::new();
    for (name, profile, plan, min_margin) in &matrix {
        // ε: 99.9% of the progress plain async makes in ITERS rounds —
        // the same bar for both schedules, so the comparison is
        // seconds-to-ε, not seconds-per-round
        let (reference, _) = run_cell(
            &c0,
            profile,
            plan.clone(),
            false,
            &StopRule::iters(ITERS),
        );
        let f0 = reference.trace.points[0].f;
        let target = reference.f + 1e-3 * (f0 - reference.f);
        let stop = StopRule::iters(80).with_target(target);

        let (plain, plain_ledger) =
            run_cell(&c0, profile, plan.clone(), false, &stop);
        let (spec, spec_ledger) =
            run_cell(&c0, profile, plan.clone(), true, &stop);
        for (label, r) in [("plain", &plain), ("spec", &spec)] {
            assert!(
                r.f <= target,
                "{name}/{label} never reached the target: {} > {target}",
                r.f
            );
        }
        // spec-off gate: the flag really is off — nothing speculative
        // on the plain ledger
        assert_eq!(
            plain_ledger.spec_hits + plain_ledger.spec_misses,
            0,
            "{name}: spec-off run recorded speculation windows"
        );
        assert_eq!(plain_ledger.spec_rebase_seconds, 0.0);
        // ...and the speculative run really speculated
        assert!(
            spec_ledger.spec_hits > 0,
            "{name}: no speculation window ever hit"
        );

        let (ps, ss) = (plain_ledger.seconds(), spec_ledger.seconds());
        let margin = ps - ss;
        println!(
            "{:<12} {:>10.2} {:>9.2} {:>8.2}s {:>7} {:>7} {:>8.2}x",
            name,
            ps,
            ss,
            margin,
            spec_ledger.spec_hits,
            spec_ledger.spec_misses,
            ps / ss
        );
        let profile_line = spec_ledger.speculation_profile();
        if !profile_line.is_empty() {
            println!("  speculation: {profile_line}");
        }
        // the load-bearing smoke assert: speculation strictly beats
        // plain async to the same ε — in absolute virtual seconds,
        // robust to host speed
        assert!(
            ss < ps - min_margin,
            "{name}: speculative {ss} not strictly below plain {ps} \
             (margin {min_margin})"
        );
        scen_json.push((
            *name,
            Value::obj(vec![
                ("plain_s", Value::Num(ps)),
                ("spec_s", Value::Num(ss)),
                ("margin_s", Value::Num(margin)),
                ("plain_rounds", Value::Num(plain.trace.points.len() as f64)),
                ("spec_rounds", Value::Num(spec.trace.points.len() as f64)),
                ("spec_hits", Value::Num(spec_ledger.spec_hits as f64)),
                ("spec_misses", Value::Num(spec_ledger.spec_misses as f64)),
                (
                    "spec_rebase_s",
                    Value::Num(spec_ledger.spec_rebase_seconds),
                ),
                (
                    "fallback_rounds",
                    Value::Num(spec_ledger.fallback_rounds as f64),
                ),
            ]),
        ));
    }

    // controller replay gate: fully modeled time (no measured compute
    // share) so clocks are bit-reproducible; the adaptive policy under
    // seeded weather must re-derive the identical (τ, q) trace — every
    // decision is a pure ledger function
    let modeled = CostModel {
        latency_s: 0.02,
        compute_scale: 0.0,
        ..CostModel::default()
    };
    let mut m0 = c0.fork_fresh();
    m0.cost = modeled;
    let replay = || {
        let mut cluster = m0.fork_fresh();
        cluster.set_fault_plan(FaultPlan::seeded(NODES, 7));
        let run = AsyncFsDriver::new(AsyncFsConfig {
            fs: FsConfig { lam: 1.0, epochs: 2, ..Default::default() },
            policy: Asynchrony::Adaptive {
                init: (1, NODES - 1),
                bounds: TuneBounds { tau_max: 4, q_min: 1 },
            },
            speculate: true,
        })
        .run(&mut cluster, None, &StopRule::iters(24));
        (run, cluster.ledger.clone())
    };
    let (run_a, ledger_a) = replay();
    let (run_b, ledger_b) = replay();
    assert!(
        !ledger_a.tune_trace.is_empty(),
        "adaptive replay gate never completed a tuning window"
    );
    assert_eq!(
        ledger_a.tune_trace, ledger_b.tune_trace,
        "(τ, q) trace failed to replay bitwise"
    );
    assert_eq!(run_a.w, run_b.w, "adaptive iterate failed to replay");
    assert_eq!(ledger_a, ledger_b, "adaptive ledger failed to replay");
    println!(
        "controller replay gate: {} (τ, q) decisions replay \
         bit-identically",
        ledger_a.tune_trace.len()
    );

    let out = Value::obj(vec![
        ("bench", Value::Str("speculation".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("staleness", Value::Num(TAU as f64)),
        ("quorum", Value::Num(QUORUM as f64)),
        ("scenarios", Value::obj(scen_json)),
        ("controller_replay", Value::Bool(true)),
        (
            "tune_decisions",
            Value::Num(ledger_a.tune_trace.len() as f64),
        ),
    ]);
    std::fs::write("BENCH_speculation.json", out.to_json(1))
        .expect("write BENCH_speculation.json");
    println!("\nwrote BENCH_speculation.json");

    println!(
        "\nreading: a confirmed speculative window hides the whole local \
         solve under the previous round's tail, collapsing the commit \
         gap toward the communication floor; a miss re-bases at the \
         commit and never loses to not speculating — so the speculative \
         schedule dominates plain async on both matrices."
    );
}
