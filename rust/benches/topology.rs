//! Cluster-substrate ablation: tree vs ring reduction topology, and
//! straggler sensitivity via [`NodeProfile`]. The pass COUNT (the
//! paper's metric) is topology-independent; modeled TIME is not — the
//! ring amortizes bandwidth at large P while the tree pays log₂P
//! full-size hops. Also quantifies how a slow node on every 4th slot
//! stretches FS's compute phases.

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::{Driver, StopRule};
use psgd::bench::figure1::kdd_equivalent_cost;
use psgd::cluster::cost::Topology;
use psgd::cluster::engine::NodeProfile;
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;

fn main() {
    let data = SynthConfig {
        n_examples: 20_000,
        n_features: 1_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;

    println!("### reduction topology (time model only; passes identical)");
    println!(
        "{:>5} {:>14} {:>14} {:>10}",
        "P", "tree sim-sec", "ring sim-sec", "ring/tree"
    );
    for nodes in [8usize, 25, 100] {
        let part = Partition::shuffled(data.n_examples(), nodes, 3);
        let mut secs = Vec::new();
        for topo in [Topology::Tree, Topology::Ring] {
            let cost = CostModel { topology: topo, ..kdd_equivalent_cost(1_000) };
            let mut cluster =
                Cluster::partition_with(data.clone(), &part, cost);
            let run = FsDriver::new(FsConfig {
                lam,
                epochs: 2,
                ..Default::default()
            })
            .run(&mut cluster, None, &StopRule::iters(10));
            secs.push(run.ledger.seconds());
        }
        println!(
            "{:>5} {:>14.1} {:>14.1} {:>10.3}",
            nodes,
            secs[0],
            secs[1],
            secs[1] / secs[0]
        );
    }

    println!("\n### straggler sensitivity (every 4th node slowed)");
    println!("{:>10} {:>14}", "slowdown", "sim-seconds");
    for slowdown in [0.0, 1.0, 3.0] {
        let nodes = 16;
        let part = Partition::shuffled(data.n_examples(), nodes, 3);
        let mut cluster = Cluster::partition_with(
            data.clone(),
            &part,
            kdd_equivalent_cost(1_000),
        );
        // every 4th node runs (1 + slowdown)× slower
        cluster.set_profile(NodeProfile {
            speed: (0..nodes)
                .map(|p| if p % 4 == 0 { 1.0 + slowdown } else { 1.0 })
                .collect(),
        });
        let run = FsDriver::new(FsConfig { lam, epochs: 2, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(10));
        println!("{:>10.1} {:>14.1}", slowdown, run.ledger.seconds());
    }
    println!(
        "\nreading: ring wins time at large P (bandwidth-optimal), the \
         tree wins at small P (latency); stragglers stretch only the \
         compute share — FS's comm-light design keeps the hit linear in \
         the compute fraction."
    );
}
