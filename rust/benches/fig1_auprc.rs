//! Bench: regenerate Figure 1's RIGHT panels — test AUPRC versus
//! simulated time, for 25 and 100 nodes. The paper's observation:
//! FS reaches stable generalization much quicker than SQM/Hybrid.

use psgd::bench::figure1::{self, Figure1Config, Panel};
use psgd::bench::plot::AsciiPlot;

fn main() {
    for nodes in [25usize, 100] {
        let cfg = Figure1Config::small(nodes);
        let out = figure1::run(&cfg);
        println!("\n### Figure 1 (right, {} nodes): AUPRC vs time", nodes);
        println!("[{}]", out.config_label);
        println!("{:<10} {:>10} {:>8}", "method", "sim_sec", "auprc");
        for trace in &out.traces {
            for (x, y) in Panel::AuprcVsTime.series(trace, out.f_star) {
                if !y.is_nan() {
                    println!("{:<10} {:>10.3} {:>8.4}", trace.label, x, y);
                }
            }
        }
        // time for each method to reach 99% of its own final AUPRC —
        // the "reaches stable generalization quicker" claim, quantified
        println!("\n{:<10} {:>22}", "method", "sec to 99% final AUPRC");
        for trace in &out.traces {
            let series = Panel::AuprcVsTime.series(trace, out.f_star);
            let last = series
                .iter()
                .rev()
                .find(|(_, a)| !a.is_nan())
                .map(|&(_, a)| a)
                .unwrap_or(f64::NAN);
            let t99 = series
                .iter()
                .find(|(_, a)| *a >= 0.99 * last)
                .map(|&(t, _)| t)
                .unwrap_or(f64::NAN);
            println!("{:<10} {:>22.3}", trace.label, t99);
        }
        let series: Vec<(String, Vec<(f64, f64)>)> = out
            .traces
            .iter()
            .map(|t| {
                (
                    t.label.clone(),
                    Panel::AuprcVsTime
                        .series(t, out.f_star)
                        .into_iter()
                        .filter(|(_, y)| !y.is_nan())
                        .collect(),
                )
            })
            .collect();
        let plot = AsciiPlot { log_y: false, ..Default::default() };
        println!("{}", plot.render(Panel::AuprcVsTime.title(), &series));
    }
}
