//! Node-scaling bench (paper: "When the number of nodes is increased,
//! SQM and Hybrid come closer to our method"): sweep P and report
//! passes-to-target for FS-2/FS-8 vs SQM, showing the narrowing gap —
//! f̂_p approximates f worse as shards shrink.

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::sqm::{SqmConfig, SqmDriver};
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;

fn passes_to(run: &RunResult, target: f64) -> f64 {
    run.trace
        .points
        .iter()
        .find(|p| p.f <= target)
        .map(|p| p.comm_passes)
        .unwrap_or(f64::NAN)
}

fn main() {
    let data = SynthConfig {
        n_examples: 20_000,
        n_features: 1_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;

    let mut rc = Cluster::partition(data.clone(), 1, CostModel::free());
    let mut rcfg = SqmConfig { lam, ..Default::default() };
    rcfg.tron.eps = 1e-12;
    let fstar = SqmDriver::new(rcfg).run(&mut rc, None, &StopRule::iters(400)).f;
    let target = fstar * (1.0 + 1e-4);

    println!("### node scaling, target gap 1e-4, λ={lam:.2e}");
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>14}",
        "P", "fs-2 passes", "fs-8 passes", "sqm passes", "fs8/sqm ratio"
    );
    for nodes in [5usize, 10, 25, 50, 100] {
        let part = Partition::shuffled(data.n_examples(), nodes, 3);
        let fresh = || Cluster::partition_with(data.clone(), &part, CostModel::free());
        let fs2 = FsDriver::new(FsConfig { lam, epochs: 2, ..Default::default() })
            .run(&mut fresh(), None, &StopRule::iters(120).with_target(target));
        let fs8 = FsDriver::new(FsConfig { lam, epochs: 8, ..Default::default() })
            .run(&mut fresh(), None, &StopRule::iters(120).with_target(target));
        let sqm = SqmDriver::new(SqmConfig { lam, ..Default::default() })
            .run(&mut fresh(), None, &StopRule::iters(120));
        let (p2, p8, ps) = (
            passes_to(&fs2, target),
            passes_to(&fs8, target),
            passes_to(&sqm, target),
        );
        println!(
            "{:>5} {:>12.0} {:>12.0} {:>12.0} {:>14.3}",
            nodes,
            p2,
            p8,
            ps,
            p8 / ps
        );
    }
    println!(
        "\nreading: SQM's pass count is P-independent (CG structure), \
         while FS needs more outer iterations as P grows — the gap \
         narrows, matching the paper's 25- vs 100-node panels."
    );
}
