//! §Discussion (b) bench: swap the inner solver of Algorithm 1 step 5 —
//! SVRG (the paper's choice) vs plain SGD vs L-BFGS vs TRON on f̂_p —
//! and compare outer iterations / passes to a fixed gap plus wall
//! compute time. "Our method can also use other algorithms ... leading
//! to interesting possibilities."

use psgd::algo::fs::{FsConfig, FsDriver, InnerSolver};
use psgd::algo::sqm::{SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;
use std::time::Instant;

fn main() {
    let data = SynthConfig {
        n_examples: 20_000,
        n_features: 1_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;
    let nodes = 16;
    let part = Partition::shuffled(data.n_examples(), nodes, 3);

    let mut rc = Cluster::partition(data.clone(), 1, CostModel::free());
    let mut rcfg = SqmConfig { lam, ..Default::default() };
    rcfg.tron.eps = 1e-12;
    let fstar = SqmDriver::new(rcfg).run(&mut rc, None, &StopRule::iters(400)).f;
    let target = fstar * (1.0 + 1e-5);

    println!("### inner-solver swap, {nodes} nodes, target gap 1e-5");
    println!(
        "{:>8} {:>4} {:>8} {:>8} {:>12} {:>10}",
        "inner", "s", "iters", "passes", "final gap", "wall (s)"
    );
    for (inner, s, lr) in [
        (InnerSolver::Svrg, 2, None),
        (InnerSolver::Svrg, 8, None),
        (InnerSolver::Sgd, 2, Some(0.05)),
        (InnerSolver::Sgd, 8, Some(0.05)),
        (InnerSolver::Lbfgs, 4, None),
        (InnerSolver::Tron, 2, None),
    ] {
        let mut cluster =
            Cluster::partition_with(data.clone(), &part, CostModel::free());
        let t0 = Instant::now();
        let run = FsDriver::new(FsConfig {
            lam,
            epochs: s,
            inner,
            lr,
            ..Default::default()
        })
        .run(&mut cluster, None, &StopRule::iters(80).with_target(target));
        let last = run.trace.points.last().unwrap();
        println!(
            "{:>8} {:>4} {:>8} {:>8.0} {:>12.3e} {:>10.2}",
            format!("{inner:?}").to_lowercase(),
            s,
            run.trace.points.len(),
            last.comm_passes,
            (run.f - fstar) / fstar,
            t0.elapsed().as_secs_f64()
        );
    }
    println!(
        "\nreading: tilted second-order inner solvers (TRON/L-BFGS on \
         f̂_p) buy the fewest outer iterations; SVRG is the sweet spot \
         when local passes are the budget unit; untilted plain SGD \
         converges but wastes iterations fighting its own bias."
    );
}
