//! Ablation bench (paper text: "The value of s, the number of SGD
//! epochs plays a key role in determining the rate of linear
//! convergence"): sweep s ∈ {1, 2, 4, 8, 16} and report, per s, the
//! outer iterations and communication passes to a fixed relative gap,
//! plus the measured per-iteration contraction ratio δ.

use psgd::algo::fs::{FsConfig, FsDriver};
use psgd::algo::sqm::{SqmConfig, SqmDriver};
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;

fn main() {
    let data = SynthConfig {
        n_examples: 20_000,
        n_features: 1_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;
    let nodes = 16;
    let part = Partition::shuffled(data.n_examples(), nodes, 3);

    // reference optimum
    let mut rc = Cluster::partition(data.clone(), 1, CostModel::free());
    let mut rcfg = SqmConfig { lam, ..Default::default() };
    rcfg.tron.eps = 1e-12;
    let fstar = SqmDriver::new(rcfg).run(&mut rc, None, &StopRule::iters(400)).f;
    let target = fstar * (1.0 + 1e-5);

    println!("### epochs sweep (s), {nodes} nodes, λ={lam:.2e}, target gap 1e-5");
    println!(
        "{:>4} {:>8} {:>8} {:>12} {:>12} {:>10}",
        "s", "iters", "passes", "mean δ", "final gap", "sgd-steps"
    );
    for s in [1usize, 2, 4, 8, 16] {
        let mut cluster =
            Cluster::partition_with(data.clone(), &part, CostModel::free());
        let run = FsDriver::new(FsConfig { lam, epochs: s, ..Default::default() })
            .run(&mut cluster, None, &StopRule::iters(100).with_target(target));
        let gaps: Vec<f64> = run
            .trace
            .points
            .iter()
            .map(|p| (p.f - fstar) / fstar)
            .collect();
        // geometric-mean contraction over the recorded iterations
        let mut ratios = Vec::new();
        for k in 1..gaps.len() {
            if gaps[k] > 1e-14 && gaps[k - 1] > 1e-14 {
                ratios.push(gaps[k] / gaps[k - 1]);
            }
        }
        let delta = if ratios.is_empty() {
            f64::NAN
        } else {
            (ratios.iter().map(|r| r.ln()).sum::<f64>() / ratios.len() as f64)
                .exp()
        };
        let last = run.trace.points.last().unwrap();
        println!(
            "{:>4} {:>8} {:>8.0} {:>12.4} {:>12.3e} {:>10}",
            s,
            run.trace.points.len(),
            last.comm_passes,
            delta,
            gaps.last().unwrap(),
            s * (data.n_examples() / nodes) * run.trace.points.len(),
        );
    }
    println!(
        "\nreading: larger s ⇒ better local solves ⇒ smaller δ (faster \
         linear rate), at the cost of s× local compute per iteration — \
         the communication-computation trade-off the paper describes."
    );
}
