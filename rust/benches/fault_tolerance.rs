//! Fleet-weather chaos bench: async FS under seeded fault injection.
//!
//! A 3-seed × {crash, flap, degrade} matrix runs the bounded-staleness
//! async driver through scripted weather and checks it still reaches
//! the clean run's objective target — the elastic membership + partial
//! quorum + safeguard stack absorbing the faults instead of hanging or
//! stalling. A separate determinism gate replays one seed twice under
//! fully modeled time and requires the bit-identical fault timeline
//! and iterate.
//!
//! Smoke contract for CI (`make bench-smoke` / the `chaos` job): every
//! chaos cell reaches its clean target within the round cap, each
//! scenario records the fault activity its script injects, and the
//! replay gate holds. The run writes `BENCH_fault_tolerance.json`
//! (uploaded by CI) so the resilience trajectory is machine-readable.

use psgd::algo::adapt::{Asynchrony, Quorum};
use psgd::algo::async_fs::{AsyncFsConfig, AsyncFsDriver};
use psgd::algo::fs::FsConfig;
use psgd::algo::{Driver, RunResult, StopRule};
use psgd::cluster::{Cluster, CostModel, FaultPlan, Ledger};
use psgd::data::synth::SynthConfig;
use psgd::util::json::Value;

const NODES: usize = 6;
const ITERS: usize = 10;
const TAU: usize = 2;
const SEEDS: [u64; 3] = [1, 2, 3];

fn driver() -> AsyncFsDriver {
    AsyncFsDriver::new(AsyncFsConfig {
        fs: FsConfig { lam: 1.0, epochs: 2, ..Default::default() },
        policy: Asynchrony::Bounded {
            tau: TAU,
            quorum: Quorum::AtLeast(NODES - 1),
        },
        ..Default::default()
    })
}

fn run_with_plan(
    c0: &Cluster,
    plan: Option<FaultPlan>,
    stop: &StopRule,
) -> (RunResult, Ledger) {
    let mut cluster = c0.fork_fresh();
    if let Some(p) = plan {
        cluster.set_fault_plan(p);
    }
    let run = driver().run(&mut cluster, None, stop);
    (run, cluster.ledger.clone())
}

fn main() {
    let data = SynthConfig {
        n_examples: 4_000,
        n_features: 10_000,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(42);
    let cost = CostModel {
        latency_s: 0.02,
        compute_scale: 20_000.0,
        ..CostModel::default()
    };
    let mut c0 = Cluster::partition(data, NODES, cost);
    c0.threads = 1;
    println!(
        "### fault_tolerance bench: async FS on {NODES} nodes, τ={TAU}, \
         q={} under seeded fleet weather",
        NODES - 1
    );

    // ε: 99.9% of the progress the clean async run makes in ITERS
    // rounds — the bar every chaos cell must still clear
    let (clean, clean_ledger) =
        run_with_plan(&c0, None, &StopRule::iters(ITERS));
    let f0 = clean.trace.points[0].f;
    let target = clean.f + 1e-3 * (f0 - clean.f);
    let stop = StopRule::iters(80).with_target(target);
    let clean_s = clean_ledger.seconds();
    println!(
        "clean reference: f={:.6e} in {} rounds, {clean_s:.2}s",
        clean.f,
        clean.trace.points.len()
    );
    println!(
        "{:<9} {:>5} {:>9} {:>7} {:>10} {:>9}",
        "scenario", "seed", "chaos s", "rounds", "fallbacks", "overhead"
    );

    // round-indexed scripts so the weather replays exactly under
    // measured compute; the seed drives the flap/loss coins
    let scenarios: [(&str, &str); 3] = [
        ("crash", "crash:1@r2,restart:1@r6,loss:p=0.05"),
        ("flap", "flap:2:p=0.15,flap:4:p=0.1,loss:p=0.05"),
        ("degrade", "degrade:3@r1:0.3x,loss:p=0.05"),
    ];

    let mut cells: Vec<(String, Value)> = Vec::new();
    for (name, script) in &scenarios {
        for seed in SEEDS {
            let mut plan = FaultPlan::parse(script, NODES)
                .expect("bench fault script must parse");
            plan.seed = seed;
            let (run, ledger) = run_with_plan(&c0, Some(plan), &stop);
            assert!(
                run.f <= target,
                "{name}/seed{seed} never reached the clean target: \
                 {} > {target}",
                run.f
            );
            match *name {
                "crash" => assert!(
                    ledger.crash_events >= 1 && ledger.rejoin_rebases >= 1,
                    "{name}/seed{seed}: scripted crash+restart not recorded"
                ),
                "flap" => assert!(
                    ledger.flap_events >= 1,
                    "{name}/seed{seed}: flap weather never fired"
                ),
                _ => assert!(
                    ledger.degrade_events >= 1,
                    "{name}/seed{seed}: degrade not recorded"
                ),
            }
            let secs = ledger.seconds();
            println!(
                "{:<9} {:>5} {:>9.2} {:>7} {:>10} {:>8.2}x",
                name,
                seed,
                secs,
                run.trace.points.len(),
                ledger.fallback_rounds,
                secs / clean_s
            );
            let profile = ledger.fault_profile();
            if !profile.is_empty() {
                println!("  weather: {profile}");
            }
            cells.push((
                format!("{name}_seed{seed}"),
                Value::obj(vec![
                    ("seconds", Value::Num(secs)),
                    ("rounds", Value::Num(run.trace.points.len() as f64)),
                    (
                        "fallback_rounds",
                        Value::Num(ledger.fallback_rounds as f64),
                    ),
                    ("crash_events", Value::Num(ledger.crash_events as f64)),
                    (
                        "rejoin_rebases",
                        Value::Num(ledger.rejoin_rebases as f64),
                    ),
                    ("lost_messages", Value::Num(ledger.lost_messages as f64)),
                    ("retry_rounds", Value::Num(ledger.retry_rounds as f64)),
                    (
                        "degrade_events",
                        Value::Num(ledger.degrade_events as f64),
                    ),
                    ("flap_events", Value::Num(ledger.flap_events as f64)),
                    (
                        "recovery_seconds",
                        Value::Num(ledger.recovery_seconds),
                    ),
                    ("overhead_x", Value::Num(secs / clean_s)),
                ]),
            ));
        }
    }

    // determinism gate: fully modeled time (no measured compute share)
    // so clocks are bit-reproducible; one seed, two runs, identical
    // fault timeline + iterate + ledger
    let modeled = CostModel {
        latency_s: 0.02,
        compute_scale: 0.0,
        ..CostModel::default()
    };
    let mut m0 = c0.fork_fresh();
    m0.cost = modeled;
    let replay = |seed: u64| {
        let mut cluster = m0.fork_fresh();
        let mut plan = FaultPlan::parse(
            "crash:1@r2,restart:1@r6,flap:2:p=0.2,loss:p=0.1",
            NODES,
        )
        .unwrap();
        plan.seed = seed;
        cluster.set_fault_plan(plan);
        let run = driver().run(&mut cluster, None, &StopRule::iters(15));
        let log = cluster.faults.as_ref().unwrap().log.clone();
        (run, log, cluster.ledger.clone())
    };
    let (run_a, log_a, ledger_a) = replay(7);
    let (run_b, log_b, ledger_b) = replay(7);
    assert!(!log_a.is_empty(), "determinism gate saw no weather");
    assert_eq!(log_a, log_b, "fault timeline failed to replay");
    assert_eq!(run_a.w, run_b.w, "iterate failed to replay bitwise");
    assert_eq!(ledger_a, ledger_b, "ledger failed to replay");
    println!(
        "determinism gate: {} applied faults replay bit-identically",
        log_a.len()
    );

    let out = Value::obj(vec![
        ("bench", Value::Str("fault_tolerance".to_string())),
        ("nodes", Value::Num(NODES as f64)),
        ("staleness", Value::Num(TAU as f64)),
        ("quorum", Value::Num((NODES - 1) as f64)),
        ("clean_seconds", Value::Num(clean_s)),
        (
            "cells",
            Value::obj(
                cells
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        ),
        ("deterministic_replay", Value::Bool(true)),
        (
            "replay_fault_count",
            Value::Num(log_a.len() as f64),
        ),
    ]);
    std::fs::write("BENCH_fault_tolerance.json", out.to_json(1))
        .expect("write BENCH_fault_tolerance.json");
    println!("\nwrote BENCH_fault_tolerance.json");

    println!(
        "\nreading: the quorum + safeguard stack absorbs crashes, flaps \
         and slow nodes — chaos cells pay a bounded makespan overhead \
         to the same ε, and the seeded weather replays bit-identically."
    );
}
