//! The sparse gradient pipeline at the paper's regime: per-node
//! gradients supported on ~20k of d = 500k columns (kdd2010-shaped,
//! ~10 nnz/row). Times the merge-by-index tree reduction against the
//! dense tree_sum, and reports the modeled wire cost of one FS
//! gradient allreduce on each format — the comm-seconds drop the
//! sparse pipeline exists for.

use psgd::algo::common::{global_value_grad, global_value_grad_auto};
use psgd::bench::{run, BenchConfig};
use psgd::cluster::allreduce::{tree_sum, tree_sum_sparse};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::synth::SynthConfig;
use psgd::linalg::SparseVec;
use psgd::loss::LossKind;
use psgd::util::json::Value;
use psgd::util::rng::Rng;

const D: usize = 500_000;
const NODES: usize = 16;

fn main() {
    let mut rng = Rng::new(7);
    // per-node sparse gradients: ~2k rows × 10 nnz each
    let parts_sparse: Vec<SparseVec> = (0..NODES)
        .map(|_| {
            let pairs: Vec<(u32, f64)> = (0..20_000)
                .map(|_| (rng.below(D) as u32, rng.normal()))
                .collect();
            SparseVec::from_pairs(D, pairs)
        })
        .collect();
    let parts_dense: Vec<Vec<f64>> =
        parts_sparse.iter().map(|s| s.to_dense()).collect();

    let cfg = BenchConfig::macro_bench();
    let mut results = Vec::new();
    results.push(run("tree_sum dense 16 x 500k", &cfg, || {
        tree_sum(&parts_dense)[0]
    }));
    results.push(run("tree_sum_sparse 16 x ~20k nnz", &cfg, || {
        tree_sum_sparse(&parts_sparse).0.nnz()
    }));

    // one FS gradient allreduce (the per-outer-iteration round) on each
    // wire format, charged by the default Hadoop-era cost model
    let data = SynthConfig {
        n_examples: 32_000,
        n_features: D,
        nnz_per_example: 10,
        ..SynthConfig::default()
    }
    .generate(3);
    let c0 = Cluster::partition(data, NODES, CostModel::default());
    let w = vec![0.0; D];
    let mut c_dense = c0.fork_fresh();
    let _ = global_value_grad(&mut c_dense, &w, LossKind::Logistic, 0.5, true);
    let mut c_sparse = c0.fork_fresh();
    let _ = global_value_grad_auto(
        &mut c_sparse,
        &w,
        LossKind::Logistic,
        0.5,
        true,
        true,
    );

    println!("\n### sparse_grad benches (d = {D}, {NODES} nodes)");
    for s in &results {
        println!("{}", s.report());
    }
    println!(
        "\nFS gradient allreduce, modeled wire cost (default cost model):\n\
         {:<8} {:>14} {:>16}\n\
         {:<8} {:>14.0} {:>16.4}\n\
         {:<8} {:>14.0} {:>16.4}",
        "format", "payload bytes", "comm seconds",
        "dense", c_dense.ledger.comm_bytes, c_dense.ledger.comm_seconds,
        "sparse", c_sparse.ledger.comm_bytes, c_sparse.ledger.comm_seconds,
    );
    // per-tree-level wire profile of the sparse reduction (mean largest
    // message per level, leaves → root): union growth up the tree
    println!(
        "sparse tree wire profile: {}",
        c_sparse.ledger.level_profile()
    );

    // machine-readable record for the CI perf trajectory
    let out = Value::obj(vec![
        ("bench", Value::Str("sparse_grad".to_string())),
        ("dim", Value::Num(D as f64)),
        ("nodes", Value::Num(NODES as f64)),
        ("tree_sum_dense_s", Value::Num(results[0].median_s)),
        ("tree_sum_sparse_s", Value::Num(results[1].median_s)),
        ("dense_wire_bytes", Value::Num(c_dense.ledger.comm_bytes)),
        ("sparse_wire_bytes", Value::Num(c_sparse.ledger.comm_bytes)),
        ("dense_comm_s", Value::Num(c_dense.ledger.comm_seconds)),
        ("sparse_comm_s", Value::Num(c_sparse.ledger.comm_seconds)),
        (
            "wire_ratio",
            Value::Num(
                c_dense.ledger.comm_bytes / c_sparse.ledger.comm_bytes,
            ),
        ),
    ]);
    std::fs::write("BENCH_sparse_grad.json", out.to_json(1))
        .expect("write BENCH_sparse_grad.json");
    println!("wrote BENCH_sparse_grad.json");
}
