//! Theorem-2 bench: how often does the step-6 safeguard trigger, as a
//! function of (a) the inner solver's convergence strength (SVRG vs
//! plain SGD) and (b) the epoch count s? The theory predicts
//! Prob(∠(−gʳ, d_p) ≥ θ) < γ with s = O(log 1/γ) for strongly
//! convergent sgd — so SVRG's trigger rate should be ~0 even at s = 1,
//! while plain SGD (no strong convergence, optimizes the *untilted*
//! f̃_p) should trip it visibly. Also sweeps θ.

use psgd::algo::fs::{FsConfig, FsDriver, InnerSolver};
use psgd::algo::safeguard::Safeguard;
use psgd::algo::{Driver, StopRule};
use psgd::cluster::{Cluster, CostModel};
use psgd::data::partition::Partition;
use psgd::data::synth::SynthConfig;

fn main() {
    let data = SynthConfig {
        n_examples: 6_000,
        n_features: 1_500,
        nnz_per_example: 10,
        skew: 1.5, // heterogeneous shards stress the safeguard
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-5 * data.n_examples() as f64;
    let nodes = 12;
    let part = Partition::contiguous(data.n_examples(), nodes);
    let iters = 15;

    println!("### safeguard trigger frequency ({nodes} nodes, {iters} iters)");
    println!(
        "{:>7} {:>4} {:>10} {:>16} {:>12}",
        "inner", "s", "θ (deg)", "hits/directions", "final f"
    );
    for (inner, name) in
        [(InnerSolver::Svrg, "svrg"), (InnerSolver::Sgd, "sgd")]
    {
        for s in [1usize, 4] {
            for theta_deg in [89.99f64, 60.0, 30.0] {
                let mut cluster = Cluster::partition_with(
                    data.clone(),
                    &part,
                    CostModel::free(),
                );
                let run = FsDriver::new(FsConfig {
                    lam,
                    epochs: s,
                    inner,
                    lr: if inner == InnerSolver::Sgd {
                        Some(0.05)
                    } else {
                        None
                    },
                    safeguard: Safeguard::from_degrees(theta_deg),
                    ..Default::default()
                })
                .run(&mut cluster, None, &StopRule::iters(iters));
                let hits: usize =
                    run.trace.points.iter().map(|p| p.safeguard_hits).sum();
                let total = nodes * run.trace.points.len().max(1);
                println!(
                    "{:>7} {:>4} {:>10.2} {:>9}/{:<6} {:>12.5e}",
                    name, s, theta_deg, hits, total, run.f
                );
            }
        }
    }
    println!(
        "\nreading: SVRG (strong stochastic convergence, Thm 2) almost \
         never trips the safeguard; plain SGD on the untilted objective \
         trips it increasingly as θ tightens — and still converges, \
         because the safeguard replaces bad directions with −gʳ."
    );
}
