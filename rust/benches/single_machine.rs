//! The introduction's motivating claim: "example-wise methods such as
//! SGD ... and dual coordinate ascent are much faster than batch
//! gradient-based methods for reaching weights with sufficient training
//! optimality". Single machine, one pass-budget axis: epochs (data
//! passes) to reach a moderate relative gap, for DCA [4], SVRG [3] and
//! batch TRON / L-BFGS.

use psgd::data::synth::SynthConfig;
use psgd::linalg::dense;
use psgd::loss::LossKind;
use psgd::objective::{shard_loss_grad, LocalApprox, Objective, RegularizedLoss};
use psgd::opt::dca::{self, DcaParams};
use psgd::opt::lbfgs::{self, LbfgsParams};
use psgd::opt::svrg::{svrg_epochs, SvrgParams};
use psgd::opt::tron::{self, TronParams};
use std::time::Instant;

fn main() {
    let data = SynthConfig {
        n_examples: 30_000,
        n_features: 5_000,
        nnz_per_example: 15,
        ..SynthConfig::default()
    }
    .generate(42);
    let lam = 1e-3 * data.n_examples() as f64; // C ≈ 0.03 regime
    let loss = LossKind::SquaredHinge;
    let dim = data.n_features();
    let obj = RegularizedLoss { x: &data.x, y: &data.y, loss, lam };

    // high-accuracy reference
    let fstar = tron::minimize(&obj, &vec![0.0; dim], &TronParams {
        eps: 1e-12,
        max_iter: 400,
        ..Default::default()
    })
    .f;
    let target = fstar * (1.0 + 1e-3);
    let gap = |w: &[f64]| (obj.value(w) - fstar) / fstar;

    println!("### single-machine: epochs (data passes) to 1e-3 rel gap");
    println!("{:<22} {:>8} {:>12} {:>10}", "method", "passes", "gap", "wall s");

    // --- DCA: one epoch = one data pass ---
    {
        let t0 = Instant::now();
        let mut passes = 0;
        let mut g = f64::INFINITY;
        for epochs in 1..=60 {
            let r = dca::solve(&data.x, &data.y, loss, lam,
                               &DcaParams { epochs, seed: 1 });
            passes = epochs;
            g = gap(&r.w);
            if obj.value(&r.w) <= target {
                break;
            }
        }
        println!(
            "{:<22} {:>8} {:>12.3e} {:>10.2}",
            "dca (example-wise)", passes, g, t0.elapsed().as_secs_f64()
        );
    }

    // --- SVRG on the untilted objective (single machine: tilt = 0,
    //     LocalApprox with the exact gradient) ---
    {
        let t0 = Instant::now();
        let w0 = vec![0.0; dim];
        let mut grad_lp = vec![0.0; dim];
        shard_loss_grad(&data.x, &data.y, &w0, loss, &mut grad_lp, None);
        let mut g_r = grad_lp.clone();
        dense::axpy(lam, &w0, &mut g_r);
        let approx =
            LocalApprox::new(&data.x, &data.y, loss, lam, &w0, &g_r, &grad_lp);
        let mut passes = 0;
        let mut g = f64::INFINITY;
        for epochs in [1usize, 2, 4, 8, 16, 32] {
            // batch 64: at n = 30k the per-example (b = 1) scaled
            // estimator has stochastic Lipschitz ~n·l''·‖x‖², far above
            // the full-gradient L the auto-lr targets — minibatching
            // restores the stability margin on a single machine
            let (w, _) = svrg_epochs(&approx, &w0, &SvrgParams {
                epochs,
                batch: 64,
                ..Default::default()
            });
            passes = epochs * 2; // anchor pass + stochastic pass
            g = gap(&w);
            if obj.value(&w) <= target {
                break;
            }
        }
        println!(
            "{:<22} {:>8} {:>12.3e} {:>10.2}",
            "svrg (example-wise)", passes, g, t0.elapsed().as_secs_f64()
        );
    }

    // --- batch TRON: one iteration ≈ 1 grad pass + cg_iters Hv passes ---
    {
        let t0 = Instant::now();
        let trace = std::cell::RefCell::new((0usize, f64::INFINITY));
        let r = tron::minimize_cb(
            &obj,
            &vec![0.0; dim],
            &TronParams { eps: 1e-10, max_iter: 200, ..Default::default() },
            |it, w_now| {
                let mut t = trace.borrow_mut();
                if t.1 > 0.0 && obj.value(w_now) > target {
                    t.0 += 1 + it.cg_iters; // data passes this iter
                }
                t.1 = it.gnorm;
            },
        );
        let passes = trace.borrow().0;
        println!(
            "{:<22} {:>8} {:>12.3e} {:>10.2}",
            "tron (batch)", passes, gap(&r.w), t0.elapsed().as_secs_f64()
        );
    }

    // --- batch L-BFGS: one iteration ≈ ls_evals grad passes ---
    {
        let t0 = Instant::now();
        let passes = std::cell::Cell::new(0usize);
        let done = std::cell::Cell::new(false);
        let r = lbfgs::minimize_cb(
            &obj,
            &vec![0.0; dim],
            &LbfgsParams { eps: 1e-10, max_iter: 400, ..Default::default() },
            |it, w_now| {
                if !done.get() {
                    passes.set(passes.get() + it.ls_evals + 1);
                    if obj.value(w_now) <= target {
                        done.set(true);
                    }
                }
            },
        );
        println!(
            "{:<22} {:>8} {:>12.3e} {:>10.2}",
            "lbfgs (batch)",
            passes.get(),
            gap(&r.w),
            t0.elapsed().as_secs_f64()
        );
    }
    println!(
        "\nreading: the example-wise methods reach moderate optimality in \
         a handful of data passes; the batch methods burn many passes — \
         the single-machine fact that motivates parallelizing SGD rather \
         than abandoning it (paper, introduction)."
    );
}
