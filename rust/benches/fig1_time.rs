//! Bench: regenerate Figure 1's MIDDLE panels — (f − f*)/f* (log scale)
//! versus simulated time, for 25 and 100 nodes. Simulated time =
//! measured per-node compute (max over concurrent nodes per phase) +
//! the AllReduce-tree cost model (DESIGN.md §2).

use psgd::bench::figure1::{self, Figure1Config, Panel};
use psgd::bench::plot::AsciiPlot;

fn main() {
    for nodes in [25usize, 100] {
        let cfg = Figure1Config::small(nodes);
        let out = figure1::run(&cfg);
        println!(
            "\n### Figure 1 (middle, {} nodes): gap vs simulated seconds",
            nodes
        );
        println!("f* = {:.6e}   [{}]", out.f_star, out.config_label);
        println!("{:<10} {:>10} {:>12}", "method", "sim_sec", "rel_gap");
        for trace in &out.traces {
            for (x, y) in Panel::GapVsTime.series(trace, out.f_star) {
                println!("{:<10} {:>10.3} {:>12.4e}", trace.label, x, y);
            }
            let path =
                format!("results/bench_fig1_time_{nodes}n_{}.csv", trace.label);
            let _ = trace.to_table(out.f_star).save(&path);
        }
        let series: Vec<(String, Vec<(f64, f64)>)> = out
            .traces
            .iter()
            .map(|t| {
                (
                    t.label.clone(),
                    Panel::GapVsTime
                        .series(t, out.f_star)
                        .into_iter()
                        .filter(|&(_, y)| y > 0.0)
                        .collect(),
                )
            })
            .collect();
        println!(
            "{}",
            AsciiPlot::default().render(Panel::GapVsTime.title(), &series)
        );
    }
}
